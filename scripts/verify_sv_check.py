#!/usr/bin/env python3
"""Toolchain-free mirror of rust/src/check/sv.rs (the `mase check`
SystemVerilog analyzer): tokenizer, module parser, const-expr evaluator
and the MC0xx checks, kept line-for-line transliterable with the Rust
implementation so the algorithm stays debuggable in this container.

Claims checked:
  S1  zero diagnostics on every mirrored emit::templates generator
      across a (format, mantissa, tile, channel) grid;
  S2  zero diagnostics on a mirrored full-design top-level (the new
      emit::verilog wiring) for block and element-wise formats;
  S3  the known-bad corpus under rust/tests/corpus/ reproduces the three
      PR 5 review findings with the expected stable codes
      (MC002 reversed part-select, MC004 port-width mismatch,
      MC001 undeclared identifier) plus MC005/MC006 seeds;
  S4  the select-bounds checker accepts exactly the in-range selects of
      a width table and rejects off-by-one variants.
"""
import os, re, sys

# ---------------------------------------------------------------------------
# diagnostics
# ---------------------------------------------------------------------------

WARNING, ERROR = "warning", "error"

CODES = {
    "MC001": (ERROR, "undeclared identifier"),
    "MC002": (ERROR, "reversed or empty part-select"),
    "MC003": (ERROR, "select out of declared bounds"),
    "MC004": (ERROR, "port connection width mismatch"),
    "MC005": (ERROR, "multiply-driven signal"),
    "MC006": (WARNING, "declared but never referenced"),
    "MC007": (WARNING, "instantiation of unknown module"),
    "MC008": (ERROR, "connection to unknown port"),
    "MC009": (ERROR, "parse error"),
    "MC010": (ERROR, "duplicate declaration"),
}


class Diag:
    def __init__(self, code, file, line, message):
        self.code, self.file, self.line, self.message = code, file, line, message
        self.severity = CODES[code][0]

    def __repr__(self):
        return f"{self.file}:{self.line}: {self.code} [{self.severity}] {self.message}"


class ParseErr(Exception):
    def __init__(self, line, msg):
        super().__init__(msg)
        self.line, self.msg = line, msg


# ---------------------------------------------------------------------------
# tokenizer
# ---------------------------------------------------------------------------

KEYWORDS = {
    "module", "endmodule", "input", "output", "inout", "logic", "wire", "reg",
    "signed", "unsigned", "parameter", "localparam", "assign", "always",
    "always_ff", "always_comb", "always_latch", "begin", "end", "if", "else",
    "for", "generate", "endgenerate", "genvar", "integer", "posedge",
    "negedge", "or", "and", "case", "endcase", "default", "initial",
    "function", "endfunction", "typedef", "enum", "struct", "packed", "int",
    "bit", "byte", "return", "void",
}

_ID_RE = re.compile(r"[A-Za-z_][A-Za-z0-9_]*")
_SYS_RE = re.compile(r"\$[A-Za-z_][A-Za-z0-9_]*")
_NUM_RE = re.compile(r"(\d[\d_]*)?'[sS]?[bBdDoOhH][0-9a-fA-FxXzZ_?]+|'[01xXzZ]|\d[\d_]*")
PUNCTS2 = ("<=", ">=", "==", "!=", "&&", "||", "<<", ">>", "+:", "-:")


def tokenize(text):
    """-> list of (kind, text, line); kind in id/num/sys/punct/str."""
    toks, i, n, line = [], 0, len(text), 1
    while i < n:
        c = text[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue
        if text.startswith("//", i):
            j = text.find("\n", i)
            i = n if j < 0 else j
            continue
        if text.startswith("/*", i):
            j = text.find("*/", i + 2)
            if j < 0:
                raise ParseErr(line, "unterminated block comment")
            line += text.count("\n", i, j)
            i = j + 2
            continue
        if c == '"':
            j = text.find('"', i + 1)
            if j < 0:
                raise ParseErr(line, "unterminated string")
            toks.append(("str", text[i : j + 1], line))
            i = j + 1
            continue
        if c.isalpha() or c == "_":
            m = _ID_RE.match(text, i)
            toks.append(("id", m.group(0), line))
            i = m.end()
            continue
        if c == "$":
            m = _SYS_RE.match(text, i)
            if m:
                toks.append(("sys", m.group(0), line))
                i = m.end()
                continue
            raise ParseErr(line, "stray '$'")
        if c.isdigit() or c == "'":
            m = _NUM_RE.match(text, i)
            if m:
                toks.append(("num", m.group(0), line))
                i = m.end()
                continue
            # bare ' (e.g. '{ aggregate) — not in our subset
            raise ParseErr(line, "unsupported literal")
        two = text[i : i + 2]
        if two in PUNCTS2:
            toks.append(("punct", two, line))
            i += 2
            continue
        if c in "()[]{};:,.@#?!~^&|+-*/%<>=":
            toks.append(("punct", c, line))
            i += 1
            continue
        raise ParseErr(line, f"unexpected character {c!r}")
    return toks


def num_info(txt):
    """-> (width or None, value or None, flexible)."""
    if "'" in txt:
        head, _, rest = txt.partition("'")
        rest = rest.lstrip("sS")
        if head == "" and rest and rest[0] in "01xXzZ":
            v = {"0": 0, "1": 1}.get(rest[0])
            return (None, v, True)  # unbased-unsized: stretches to context
        base = {"b": 2, "d": 10, "o": 8, "h": 16}[rest[0].lower()]
        digits = rest[1:].replace("_", "")
        val = None
        if not re.search(r"[xXzZ?]", digits):
            val = int(digits, base)
        width = int(head.replace("_", "")) if head else None
        return (width, val, width is None)
    return (None, int(txt.replace("_", "")), True)


# ---------------------------------------------------------------------------
# parser: token stream -> module structures
# ---------------------------------------------------------------------------

class Port:
    def __init__(self, name, dir_, rng, line):
        self.name, self.dir, self.rng, self.line = name, dir_, rng, line


class Decl:
    def __init__(self, name, kind, rng, unpacked, line):
        # kind: net | var | integer | genvar | param | localparam | port
        self.name, self.kind, self.rng = name, kind, rng
        self.unpacked, self.line = unpacked, line


class Module:
    def __init__(self, name, line):
        self.name, self.line = name, line
        self.params = []  # (name, default_toks, line)
        self.ports = []  # Port
        self.localparams = []  # (name, toks, line)
        self.decls = []  # Decl (nets/vars/integers/genvars)
        self.items = []  # structured body items


class Parser:
    def __init__(self, toks):
        self.toks, self.i = toks, 0

    def peek(self, k=0):
        j = self.i + k
        return self.toks[j] if j < len(self.toks) else ("eof", "", self.line())

    def line(self):
        if self.i < len(self.toks):
            return self.toks[self.i][2]
        return self.toks[-1][2] if self.toks else 0

    def next(self):
        t = self.peek()
        self.i += 1
        return t

    def at(self, text):
        return self.peek()[1] == text and self.peek()[0] != "str"

    def accept(self, text):
        if self.at(text):
            self.i += 1
            return True
        return False

    def expect(self, text):
        t = self.next()
        if t[1] != text:
            raise ParseErr(t[2], f"expected {text!r}, found {t[1]!r}")
        return t

    def expect_id(self):
        t = self.next()
        if t[0] != "id" or t[1] in KEYWORDS:
            raise ParseErr(t[2], f"expected identifier, found {t[1]!r}")
        return t

    # -- expression token collection (no evaluation here) --
    def toks_until(self, stops):
        """Collect tokens until a depth-0 stop punct; stop not consumed."""
        out, depth = [], 0
        while True:
            k, txt, ln = self.peek()
            if k == "eof":
                raise ParseErr(ln, f"eof looking for one of {stops}")
            if depth == 0 and k == "punct" and txt in stops:
                return out
            if k == "punct" and txt in "([{":
                depth += 1
            elif k == "punct" and txt in ")]}":
                if depth == 0:
                    raise ParseErr(ln, f"unbalanced {txt!r}")
                depth -= 1
            out.append(self.next())

    def parenthesized(self):
        """Consume '(' ... matching ')'; return inner tokens."""
        self.expect("(")
        out = self.toks_until((")",))
        self.expect(")")
        return out

    def packed_range(self):
        """'[' msb ':' lsb ']' -> (msb_toks, lsb_toks); None if absent."""
        if not self.at("["):
            return None
        self.expect("[")
        msb = self.toks_until((":",))
        self.expect(":")
        lsb = self.toks_until(("]",))
        self.expect("]")
        return (msb, lsb)

    def unpacked_dim(self):
        self.expect("[")
        size = self.toks_until(("]", ":"))
        if self.at(":"):  # [0:N-1] style unpacked range — size = msb..lsb
            self.expect(":")
            hi = self.toks_until(("]",))
            self.expect("]")
            return ("range", size, hi)
        self.expect("]")
        return ("size", size, None)

    # -- modules --
    def parse_file(self):
        mods = []
        while self.peek()[0] != "eof":
            if self.at("module"):
                mods.append(self.parse_module())
            else:
                self.next()  # tolerate leading directives/garbage between modules
        return mods

    def parse_module(self):
        ln = self.expect("module")[2]
        m = Module(self.expect_id()[1], ln)
        if self.accept("#"):
            self.expect("(")
            while not self.at(")"):
                self.accept("parameter")
                while self.peek()[1] in ("logic", "int", "integer", "bit", "signed", "unsigned"):
                    self.next()
                name = self.expect_id()
                self.expect("=")
                dflt = self.toks_until((",", ")"))
                m.params.append((name[1], dflt, name[2]))
                if not self.accept(","):
                    break
            self.expect(")")
        self.expect("(")
        dir_ = None
        while not self.at(")"):
            if self.peek()[1] in ("input", "output", "inout"):
                dir_ = self.next()[1]
            while self.peek()[1] in ("logic", "wire", "reg", "signed", "unsigned"):
                self.next()
            rng = self.packed_range()
            name = self.expect_id()
            m.ports.append(Port(name[1], dir_, rng, name[2]))
            if not self.accept(","):
                break
        self.expect(")")
        self.expect(";")
        m.items = self.parse_items(("endmodule",))
        self.expect("endmodule")
        return m

    # -- body items --
    def parse_items(self, terminators):
        items = []
        while True:
            k, txt, ln = self.peek()
            if k == "eof":
                raise ParseErr(ln, f"eof looking for {terminators}")
            if txt in terminators:
                return items
            if txt == ";":
                self.next()
                continue
            if txt == "localparam":
                self.next()
                while self.peek()[1] in ("logic", "int", "integer", "bit", "signed", "unsigned"):
                    self.next()
                name = self.expect_id()
                self.expect("=")
                val = self.toks_until((";",))
                self.expect(";")
                items.append(("localparam", name[1], val, name[2]))
                continue
            if txt in ("genvar", "integer"):
                kind = txt
                self.next()
                while True:
                    name = self.expect_id()
                    items.append(("decl", Decl(name[1], kind, None, [], name[2]), None))
                    if not self.accept(","):
                        break
                self.expect(";")
                continue
            if txt in ("logic", "wire", "reg"):
                self.next()
                self.accept("signed") or self.accept("unsigned")
                rng = self.packed_range()
                while True:
                    name = self.expect_id()
                    unpacked = []
                    while self.at("["):
                        unpacked.append(self.unpacked_dim())
                    init = None
                    if self.accept("="):
                        init = self.toks_until((";", ","))
                    items.append(("decl", Decl(name[1], "net", rng, unpacked, name[2]), init))
                    if not self.accept(","):
                        break
                self.expect(";")
                continue
            if txt == "assign":
                ln0 = self.next()[2]
                lhs = self.toks_until(("=",))
                self.expect("=")
                rhs = self.toks_until((";",))
                self.expect(";")
                items.append(("assign", lhs, rhs, ln0))
                continue
            if txt in ("always_ff", "always_comb", "always", "always_latch"):
                self.next()
                sens = []
                if self.accept("@"):
                    sens = self.parenthesized()
                stmt = self.parse_stmt()
                items.append(("always", sens, stmt, ln))
                continue
            if txt == "generate":
                self.next()
                inner = self.parse_items(("endgenerate",))
                self.expect("endgenerate")
                items.extend(inner)
                continue
            if txt == "for":
                items.append(self.parse_gen_for())
                continue
            if txt == "if":
                items.append(self.parse_gen_if())
                continue
            if txt == "begin":
                self.next()
                if self.accept(":"):
                    self.expect_id()
                inner = self.parse_items(("end",))
                self.expect("end")
                items.extend(inner)
                continue
            if k == "id" and txt not in KEYWORDS:
                items.append(self.parse_instance())
                continue
            raise ParseErr(ln, f"unexpected token {txt!r} in module body")

    def gen_body(self):
        """A generate construct body: begin[:label] items end, or one item."""
        if self.at("begin"):
            self.next()
            if self.accept(":"):
                self.expect_id()
            inner = self.parse_items(("end",))
            self.expect("end")
            return inner
        return self.parse_items_one()

    def parse_items_one(self):
        before = len(self.toks)  # unused; single-item path
        items = []
        k, txt, ln = self.peek()
        if txt == "assign":
            self.next()
            lhs = self.toks_until(("=",))
            self.expect("=")
            rhs = self.toks_until((";",))
            self.expect(";")
            items.append(("assign", lhs, rhs, ln))
        elif txt == "for":
            items.append(self.parse_gen_for())
        elif txt == "if":
            items.append(self.parse_gen_if())
        else:
            raise ParseErr(ln, f"unsupported single generate item {txt!r}")
        return items

    def parse_gen_for(self):
        ln = self.expect("for")[2]
        self.expect("(")
        self.accept("genvar")
        var = self.expect_id()[1]
        self.expect("=")
        init = self.toks_until((";",))
        self.expect(";")
        cond = self.toks_until((";",))
        self.expect(";")
        step_var = self.expect_id()[1]
        self.expect("=")
        step = self.toks_until((")",))
        self.expect(")")
        if step_var != var:
            raise ParseErr(ln, "generate for must step its own genvar")
        body = self.gen_body()
        return ("gen_for", var, init, cond, step, body, ln)

    def parse_gen_if(self):
        ln = self.expect("if")[2]
        cond = self.parenthesized()
        then = self.gen_body()
        els = []
        if self.accept("else"):
            if self.at("if"):
                els = [self.parse_gen_if()]
            else:
                els = self.gen_body()
        return ("gen_if", cond, then, els, ln)

    def parse_instance(self):
        mod = self.expect_id()
        overrides = []
        if self.accept("#"):
            self.expect("(")
            while not self.at(")"):
                self.expect(".")
                pname = self.expect_id()
                val = self.parenthesized()
                overrides.append((pname[1], val, pname[2]))
                if not self.accept(","):
                    break
            self.expect(")")
        inst = self.expect_id()
        self.expect("(")
        conns = []
        while not self.at(")"):
            self.expect(".")
            pname = self.expect_id()
            conn = self.parenthesized()
            conns.append((pname[1], conn, pname[2]))
            if not self.accept(","):
                break
        self.expect(")")
        self.expect(";")
        return ("inst", mod[1], overrides, inst[1], conns, mod[2])

    # -- statements (inside always) --
    def parse_stmt(self):
        k, txt, ln = self.peek()
        if txt == "begin":
            self.next()
            if self.accept(":"):
                self.expect_id()
            stmts = []
            while not self.at("end"):
                if self.peek()[0] == "eof":
                    raise ParseErr(ln, "eof in begin block")
                stmts.append(self.parse_stmt())
            self.expect("end")
            return ("block", stmts, ln)
        if txt == "if":
            self.next()
            cond = self.parenthesized()
            then = self.parse_stmt()
            els = None
            if self.accept("else"):
                els = self.parse_stmt()
            return ("if", cond, then, els, ln)
        if txt == "for":
            self.next()
            self.expect("(")
            init = self.split_assign(self.toks_until((";",)), ln)
            self.expect(";")
            cond = self.toks_until((";",))
            self.expect(";")
            step = self.split_assign(self.toks_until((")",)), ln)
            self.expect(")")
            body = self.parse_stmt()
            return ("for", init, cond, step, body, ln)
        toks = self.toks_until((";",))
        self.expect(";")
        return self.split_assign(toks, ln)

    @staticmethod
    def split_assign(toks, ln):
        depth = 0
        for j, (k, txt, _) in enumerate(toks):
            if k == "punct" and txt in "([{":
                depth += 1
            elif k == "punct" and txt in ")]}":
                depth -= 1
            elif depth == 0 and k == "punct" and txt in ("<=", "="):
                return ("passign", toks[:j], toks[j + 1 :], ln)
        return ("expr", toks, ln)


# ---------------------------------------------------------------------------
# analyzer
# ---------------------------------------------------------------------------

GEN_UNROLL_CAP = 65536  # analyze every iteration up to this many
GEN_SAMPLE = 512  # beyond the cap: first/last this many iterations
LOOP_GUARD = 1 << 21  # hard stop for runaway const loops


class Sym:
    def __init__(self, decl, dir_=None, width=None, unpacked_sizes=None):
        self.decl = decl
        self.dir = dir_  # input/output/inout for ports, else None
        self.rng = width  # (lo, hi) ints, or None (1-bit), or "unknown"
        self.unpacked = unpacked_sizes or []  # list of int or None
        self.refs = 0
        self.drivers = []  # (site_id, (lo, hi) or None, line)


class ExprInfo:
    __slots__ = ("val", "width", "flexible")

    def __init__(self, val=None, width=None, flexible=False):
        self.val, self.width, self.flexible = val, width, flexible


class ModAnalyzer:
    def __init__(self, mod, mtab, file, diags):
        self.mod, self.mtab, self.file, self.diags = mod, mtab, file, diags
        self.env = {}
        self.syms = {}
        self.next_site = 0
        self.genvars = set()

    def diag(self, code, line, msg):
        self.diags.append(Diag(code, self.file, line, msg))

    def site(self):
        self.next_site += 1
        return self.next_site

    # -- setup: params, localparams, symbols --
    def run(self):
        m = self.mod
        for name, toks, ln in m.params:
            self.env[name] = self.const_eval(toks)
        for it in m.items:
            if it[0] == "localparam":
                _, name, toks, ln = it
                self.env[name] = self.const_eval(toks)

        def add_sym(name, sym, line, what):
            if name in self.syms:
                self.diag("MC010", line, f"duplicate declaration of `{name}`")
            else:
                self.syms[name] = sym

        for p in m.ports:
            s = Sym(p, dir_=p.dir, width=self.eval_range(p.rng))
            add_sym(p.name, s, p.line, "port")
            if p.dir == "input":
                s.drivers.append((self.site(), None, p.line))
        for name, _toks, ln in m.params:
            add_sym(name, Sym(None, width="param"), ln, "parameter")
            self.syms[name].kind = "param"
        def collect(items, gen_scoped):
            for it in items:
                if it[0] == "localparam":
                    _, name, _toks, ln = it
                    add_sym(name, Sym(None, width="param"), ln, "localparam")
                    self.syms[name].kind = "param"
                elif it[0] == "decl":
                    d = it[1]
                    if gen_scoped and d.name in self.syms:
                        continue  # replicated per generate iteration/branch
                    sizes = []
                    for dim in d.unpacked:
                        kind, a, b = dim
                        if kind == "size":
                            sizes.append(self.const_eval(a))
                        else:
                            lo, hi = self.const_eval(a), self.const_eval(b)
                            sizes.append(hi - lo + 1 if lo is not None and hi is not None else None)
                    s = Sym(d, width=self.eval_range(d.rng), unpacked_sizes=sizes)
                    s.kind = d.kind
                    s.gen_scoped = gen_scoped
                    add_sym(d.name, s, d.line, d.kind)
                    if d.kind == "genvar":
                        self.genvars.add(d.name)
                elif it[0] == "gen_for":
                    collect(it[5], True)
                elif it[0] == "gen_if":
                    _, cond, then, els, _ln = it
                    c = self.const_eval(cond)
                    if c is None:
                        collect(then, True)
                        collect(els, True)
                    elif c != 0:
                        collect(then, True)
                    else:
                        collect(els, True)

        collect(m.items, False)

        # walk
        self.walk_items(m.items, {})

        # MC005: multiply-driven
        for name, s in self.syms.items():
            kind = getattr(s, "kind", "port" if s.dir else "net")
            if kind in ("genvar", "integer", "param"):
                continue
            if getattr(s, "gen_scoped", False):
                continue  # per-iteration nets: each elaborated copy has one driver
            if len(s.drivers) > 1:
                ranges = [r for (_sid, r, _ln) in s.drivers]
                if all(r is not None for r in ranges):
                    spans = sorted(ranges)
                    overlap = any(spans[i][1] >= spans[i + 1][0] for i in range(len(spans) - 1))
                    if not overlap:
                        continue
                sites = {sid for (sid, _r, _ln) in s.drivers}
                if len(sites) > 1:
                    ln = s.drivers[1][2]
                    self.diag("MC005", ln, f"`{name}` driven from {len(sites)} sites")
        # MC006: declared but never referenced
        for name, s in self.syms.items():
            kind = getattr(s, "kind", None)
            if s.dir is not None or kind in ("param", "genvar"):
                continue
            ext = sum(1 for (sid, _r, _ln) in s.drivers)
            if s.refs == 0 and ext == 0:
                line = s.decl.line if s.decl else self.mod.line
                self.diag("MC006", line, f"`{name}` is never referenced")

    def eval_range(self, rng):
        if rng is None:
            return None
        msb, lsb = self.const_eval(rng[0]), self.const_eval(rng[1])
        if msb is None or lsb is None:
            return "unknown"
        return (min(msb, lsb), max(msb, lsb))

    # -- item walking --
    def walk_items(self, items, genv):
        for it in items:
            kind = it[0]
            if kind in ("localparam",):
                continue
            elif kind == "decl":
                d, init = it[1], it[2]
                if init is not None:
                    self.scan_expr(init, genv, it[1].line)
                    s = self.syms.get(d.name)
                    if s is not None:
                        s.drivers.append((self.site(), None, d.line))
            elif kind == "assign":
                _, lhs, rhs, ln = it
                self.drive_lhs(lhs, genv, ln, self.site())
                self.scan_expr(rhs, genv, ln)
            elif kind == "always":
                _, sens, stmt, ln = it
                self.scan_sensitivity(sens, ln)
                self.walk_stmt(stmt, genv, self.site())
            elif kind == "gen_for":
                self.walk_gen_for(it, genv)
            elif kind == "gen_if":
                _, cond, then, els, ln = it
                c = self.const_eval(cond, genv)
                if c is None:
                    # non-elaborable condition: walk both branches
                    self.walk_items(then, genv)
                    self.walk_items(els, genv)
                elif c != 0:
                    self.walk_items(then, genv)
                else:
                    self.walk_items(els, genv)
            elif kind == "inst":
                self.walk_inst(it, genv)
            else:
                raise AssertionError(kind)

    def walk_gen_for(self, it, genv):
        _, var, init, cond, step, body, ln = it
        v = self.const_eval(init, genv)
        if v is None:
            self.walk_items(body, dict(genv, **{var: None}))
            return
        # count iterations first to decide sampling
        vals, x, guard = [], v, 0
        while True:
            genv2 = dict(genv)
            genv2[var] = x
            c = self.const_eval(cond, genv2)
            if c is None or c == 0:
                break
            vals.append(x)
            x2 = self.const_eval(step, genv2)
            if x2 is None or x2 == x:
                break
            x = x2
            guard += 1
            if guard > LOOP_GUARD:
                break
        sample = vals
        if len(vals) > GEN_UNROLL_CAP:
            sample = vals[:GEN_SAMPLE] + vals[-GEN_SAMPLE:]
        for x in sample:
            genv2 = dict(genv)
            genv2[var] = x
            self.walk_items(body, genv2)

    def scan_sensitivity(self, sens, ln):
        for k, txt, tln in sens:
            if k == "id" and txt not in KEYWORDS:
                self.ref_read(txt, tln)

    def walk_stmt(self, stmt, genv, site):
        kind = stmt[0]
        if kind == "block":
            for s in stmt[1]:
                self.walk_stmt(s, genv, site)
        elif kind == "if":
            _, cond, then, els, ln = stmt
            self.scan_expr(cond, genv, ln)
            self.walk_stmt(then, genv, site)
            if els is not None:
                self.walk_stmt(els, genv, site)
        elif kind == "for":
            _, init, cond, step, body, ln = stmt
            for sub in (init, step):
                if sub[0] == "passign":
                    self.drive_lhs(sub[1], genv, sub[3], site)
                    self.scan_expr(sub[2], genv, sub[3])
            self.scan_expr(cond, genv, ln)
            self.walk_stmt(body, genv, site)
        elif kind == "passign":
            _, lhs, rhs, ln = stmt
            self.drive_lhs(lhs, genv, ln, site)
            self.scan_expr(rhs, genv, ln)
        elif kind == "expr":
            self.scan_expr(stmt[1], genv, stmt[2])

    # -- instances --
    def walk_inst(self, it, genv):
        _, modname, overrides, inst, conns, ln = it
        target = self.mtab.get(modname)
        if target is None:
            self.diag("MC007", ln, f"instantiation of unknown module `{modname}`")
        # parameter env of the instantiated module
        tenv = {}
        if target is not None:
            over = {}
            for pname, vtoks, pln in overrides:
                if pname not in {p[0] for p in target.params}:
                    self.diag("MC008", pln, f"`{modname}` has no parameter `{pname}`")
                over[pname] = self.const_eval(vtoks, genv)
                self.scan_expr(vtoks, genv, pln)
            for pname, dflt, _pln in target.params:
                tenv[pname] = over.get(pname, const_eval_in(dflt, tenv))
            for jt in target.items:
                if jt[0] == "localparam":
                    tenv[jt[1]] = const_eval_in(jt[2], tenv)
            fports = {p.name: p for p in target.ports}
        else:
            for pname, vtoks, pln in overrides:
                self.scan_expr(vtoks, genv, pln)
            fports = {}
        for pname, conn, pln in conns:
            if target is not None and pname not in fports:
                self.diag("MC008", pln, f"`{modname}` has no port `{pname}`")
            if not conn:  # explicitly unconnected: .out_exp()
                continue
            fp = fports.get(pname)
            drives = fp is not None and fp.dir == "output"
            if drives:
                self.drive_lhs(conn, genv, pln, self.site())
            else:
                info = self.scan_expr(conn, genv, pln)
                info_w = info.width
                self._check_conn_width(modname, pname, fp, tenv, info, pln)
                continue
            # width check for output conns too
            info = self.lhs_info
            self._check_conn_width(modname, pname, fp, tenv, info, pln)

    def _check_conn_width(self, modname, pname, fp, tenv, info, ln):
        if fp is None or info is None:
            return
        if fp.rng is None:
            formal = 1
        else:
            msb = const_eval_in(fp.rng[0], tenv)
            lsb = const_eval_in(fp.rng[1], tenv)
            if msb is None or lsb is None:
                return
            formal = abs(msb - lsb) + 1
        if info.flexible or info.width is None:
            return
        if info.width != formal:
            self.diag(
                "MC004",
                ln,
                f"port `{pname}` of `{modname}` is {formal} bits but connection is {info.width} bits",
            )

    # -- reference bookkeeping --
    def ref_read(self, name, ln):
        s = self.syms.get(name)
        if s is None:
            if name in self.env or name in self.genvars:
                return
            self.diag("MC001", ln, f"`{name}` is not declared")
            return
        s.refs += 1

    def drive_lhs(self, toks, genv, ln, site):
        """LHS of an assignment / output-port connection."""
        self.lhs_info = None
        if not toks:
            return
        if toks[0][1] == "{" and toks[0][0] == "punct":
            # concat LHS: drive each element
            inner = toks[1:-1]
            for part in split_top(inner, ","):
                self.drive_lhs(part, genv, ln, site)
            self.lhs_info = None
            return
        k, name, tln = toks[0]
        if k != "id" or name in KEYWORDS:
            self.scan_expr(toks, genv, ln)
            return
        s = self.syms.get(name)
        if s is None:
            if name not in self.genvars and name not in self.env:
                self.diag("MC001", tln, f"`{name}` is not declared")
            # genvar loop index: not a driver site
            if toks[1:]:
                self.scan_expr(toks, genv, ln)
            return
        kind = getattr(s, "kind", None)
        # parse trailing selects: reads for the index exprs + bounds checks
        rng = self.check_selects(s, name, toks[1:], genv, ln)
        if kind in ("genvar", "integer"):
            return
        s.drivers.append((site, rng, ln))
        w = None
        if rng is not None:
            w = rng[1] - rng[0] + 1
        elif not toks[1:]:
            if s.rng is None:
                w = 1 if not s.unpacked else None
            elif s.rng != "unknown" and not s.unpacked:
                w = s.rng[1] - s.rng[0] + 1
        self.lhs_info = ExprInfo(val=None, width=w, flexible=False)

    def check_selects(self, s, name, sel_toks, genv, ln):
        """Walk `[...]` select groups after an identifier; returns the
        final const (lo, hi) bit range into the packed vector, if known."""
        groups = []
        i = 0
        while i < len(sel_toks):
            if sel_toks[i][1] != "[":
                # stray tokens after selects: scan conservatively
                self.scan_expr(sel_toks[i:], genv, ln)
                break
            depth, j = 1, i + 1
            while j < len(sel_toks) and depth:
                t = sel_toks[j][1]
                if sel_toks[j][0] == "punct":
                    if t in "([{":
                        depth += 1
                    elif t == "[":
                        depth += 1
                    elif t in ")]}":
                        depth -= 1
                j += 1
            groups.append(sel_toks[i + 1 : j - 1])
            i = j
        unpacked_left = list(s.unpacked)
        final = None
        for g in groups:
            parts = split_sel(g)
            for p in parts[1]:
                self.scan_expr(p, genv, ln)
            kind, exprs = parts
            vals = [self.const_eval(e, genv) for e in exprs]
            if unpacked_left:
                size = unpacked_left.pop(0)
                if kind == "index" and vals[0] is not None and size is not None:
                    if not (0 <= vals[0] < size):
                        self.diag("MC003", ln, f"`{name}` index {vals[0]} outside [0:{size - 1}]")
                elif kind != "index":
                    self.diag("MC003", ln, f"part-select on unpacked dimension of `{name}`")
                continue
            rng = s.rng
            if rng == "unknown":
                continue
            lo, hi = (0, 0) if rng is None else rng
            if kind == "index":
                if vals[0] is not None and not (lo <= vals[0] <= hi):
                    self.diag("MC003", ln, f"`{name}[{vals[0]}]` outside [{hi}:{lo}]")
                if vals[0] is not None:
                    final = (vals[0], vals[0])
                rng = None
                s = _BIT  # further selects treated as 1-bit
            elif kind == "range":
                a, b = vals
                if a is not None and b is not None:
                    if a < b:
                        self.diag("MC002", ln, f"reversed part-select `{name}[{a}:{b}]`")
                    elif not (lo <= b and a <= hi):
                        self.diag("MC003", ln, f"`{name}[{a}:{b}]` outside [{hi}:{lo}]")
                    else:
                        final = (b, a)
            elif kind == "plus":
                base, w = vals
                if w is not None and w <= 0:
                    self.diag("MC002", ln, f"empty `+:` width {w} on `{name}`")
                elif base is not None and w is not None:
                    if not (lo <= base and base + w - 1 <= hi):
                        self.diag(
                            "MC003", ln, f"`{name}[{base} +: {w}]` outside [{hi}:{lo}]"
                        )
                    else:
                        final = (base, base + w - 1)
            elif kind == "minus":
                base, w = vals
                if w is not None and w <= 0:
                    self.diag("MC002", ln, f"empty `-:` width {w} on `{name}`")
                elif base is not None and w is not None:
                    if not (lo <= base - w + 1 and base <= hi):
                        self.diag(
                            "MC003", ln, f"`{name}[{base} -: {w}]` outside [{hi}:{lo}]"
                        )
                    else:
                        final = (base - w + 1, base)
        return final

    # -- expressions --
    def scan_expr(self, toks, genv, ln):
        """Scan an expression: record reads, run select checks, and return
        ExprInfo (const value / width / flexible) when derivable."""
        try:
            p = _EP(self, toks, genv, ln)
            info = p.expr()
            return info
        except _EvalBail:
            return ExprInfo()

    def const_eval(self, toks, genv=None):
        saved = list(self.diags)
        # const evaluation must not double-report: diagnostics and ref
        # counting happen in scan; here we evaluate silently
        try:
            p = _EP(self, toks, genv or {}, 0, silent=True)
            info = p.expr()
            return info.val
        except _EvalBail:
            return None
        finally:
            del self.diags[:]
            self.diags.extend(saved)


class _BitSym:
    rng = None
    unpacked = []


_BIT = _BitSym()


def const_eval_in(toks, env):
    """Evaluate with a plain env only (no module symbols)."""
    try:
        p = _EP(None, toks, env, 0, silent=True)
        return p.expr().val
    except _EvalBail:
        return None


def split_top(toks, sep):
    out, cur, depth = [], [], 0
    for t in toks:
        if t[0] == "punct":
            if t[1] in "([{":
                depth += 1
            elif t[1] in ")]}":
                depth -= 1
            elif t[1] == sep and depth == 0:
                out.append(cur)
                cur = []
                continue
        cur.append(t)
    out.append(cur)
    return out


def split_sel(toks):
    """Classify one select group: index/range/plus/minus + part exprs."""
    depth = 0
    for j, t in enumerate(toks):
        if t[0] == "punct":
            if t[1] in "([{":
                depth += 1
            elif t[1] in ")]}":
                depth -= 1
            elif depth == 0 and t[1] == "+:":
                return ("plus", [toks[:j], toks[j + 1 :]])
            elif depth == 0 and t[1] == "-:":
                return ("minus", [toks[:j], toks[j + 1 :]])
            elif depth == 0 and t[1] == ":":
                return ("range", [toks[:j], toks[j + 1 :]])
    return ("index", [toks])


class _EvalBail(Exception):
    pass


class _EP:
    """Pratt-style expression parser: records reads + select checks via
    the owning ModAnalyzer (unless silent) and computes const value /
    width / flexibility where derivable."""

    def __init__(self, an, toks, env, ln, silent=False):
        self.an, self.toks, self.env, self.ln = an, toks, env, ln
        self.silent = silent
        self.i = 0

    def peek(self):
        return self.toks[self.i] if self.i < len(self.toks) else ("eof", "", self.ln)

    def next(self):
        t = self.peek()
        self.i += 1
        return t

    def at(self, txt):
        return self.peek()[1] == txt and self.peek()[0] == "punct"

    def expr(self):
        info = self.ternary()
        # trailing junk is tolerated (scanned conservatively)
        while self.peek()[0] != "eof":
            t = self.next()
            if t[0] == "id" and t[1] not in KEYWORDS:
                self.read(t[1], t[2])
            info = ExprInfo()
        return info

    def read(self, name, ln):
        if self.an is None:
            return
        if self.silent:
            return
        self.an.ref_read(name, ln)

    def lookup(self, name):
        if name in self.env:
            return self.env[name]
        if self.an is not None and name in self.an.env:
            return self.an.env[name]
        return None

    def ternary(self):
        c = self.binary(0)
        if self.at("?"):
            self.next()
            a = self.ternary()
            if self.at(":"):
                self.next()
            b = self.ternary()
            if c.val is not None:
                return a if c.val != 0 else b
            w = a.width if a.width == b.width else None
            return ExprInfo(None, w, a.flexible and b.flexible)
        return c

    LEVELS = [
        ("||",),
        ("&&",),
        ("|",),
        ("^",),
        ("&",),
        ("==", "!="),
        ("<", ">", "<=", ">="),
        ("<<", ">>"),
        ("+", "-"),
        ("*", "/", "%"),
    ]

    def binary(self, lvl):
        if lvl >= len(self.LEVELS):
            return self.unary()
        ops = self.LEVELS[lvl]
        left = self.binary(lvl + 1)
        while self.peek()[0] == "punct" and self.peek()[1] in ops:
            op = self.next()[1]
            right = self.binary(lvl + 1)
            left = self.apply(op, left, right)
        return left

    @staticmethod
    def apply(op, a, b):
        if a.val is None or b.val is None:
            return ExprInfo()
        x, y = a.val, b.val
        try:
            v = {
                "||": lambda: int(bool(x) or bool(y)),
                "&&": lambda: int(bool(x) and bool(y)),
                "|": lambda: x | y,
                "^": lambda: x ^ y,
                "&": lambda: x & y,
                "==": lambda: int(x == y),
                "!=": lambda: int(x != y),
                "<": lambda: int(x < y),
                ">": lambda: int(x > y),
                "<=": lambda: int(x <= y),
                ">=": lambda: int(x >= y),
                "<<": lambda: x << y,
                ">>": lambda: x >> y,
                "+": lambda: x + y,
                "-": lambda: x - y,
                "*": lambda: x * y,
                "/": lambda: x // y if y else None,
                "%": lambda: x % y if y else None,
            }[op]()
        except (ValueError, OverflowError):
            v = None
        return ExprInfo(v, None, False)

    def unary(self):
        k, txt, ln = self.peek()
        if k == "punct" and txt in ("!", "~", "-", "+", "&", "|", "^"):
            self.next()
            a = self.unary()
            if a.val is None:
                return ExprInfo()
            v = {
                "!": lambda: int(a.val == 0),
                "~": lambda: ~a.val,
                "-": lambda: -a.val,
                "+": lambda: a.val,
                "&": lambda: int(a.val != 0),  # approximate reductions
                "|": lambda: int(a.val != 0),
                "^": lambda: None,
            }[txt]()
            if v is None:
                return ExprInfo()
            return ExprInfo(v, None, False)
        return self.primary()

    def primary(self):
        k, txt, ln = self.next()
        if k == "num":
            w, v, flex = num_info(txt)
            return ExprInfo(v, w if w is not None else None, flex)
        if k == "sys":
            # $clog2(expr) and friends
            if self.at("("):
                self.next()
                depth = 1
                inner = []
                while depth:
                    t = self.next()
                    if t[0] == "eof":
                        raise _EvalBail()
                    if t[0] == "punct" and t[1] == "(":
                        depth += 1
                    elif t[0] == "punct" and t[1] == ")":
                        depth -= 1
                        if depth == 0:
                            break
                    inner.append(t)
                sub = _EP(self.an, inner, self.env, ln, self.silent)
                a = sub.expr()
                if txt == "$clog2" and a.val is not None and a.val >= 0:
                    return ExprInfo(clog2(a.val), None, True)
                return ExprInfo()
            return ExprInfo()
        if k == "punct" and txt == "(":
            inner = self.balanced_until(")")
            sub = _EP(self.an, inner, self.env, ln, self.silent)
            return sub.ternary_all()
        if k == "punct" and txt == "{":
            inner = self.balanced_until("}")
            return self.concat(inner, ln)
        if k == "id" and txt not in KEYWORDS:
            self.read(txt, ln)
            v = self.lookup(txt)
            # trailing selects
            sel = []
            while self.at("["):
                self.next()
                inner = self.balanced_until("]")
                sel.append(inner)
            if sel:
                return self.select_info(txt, sel, ln)
            width = None
            if self.an is not None and txt in self.an.syms:
                s = self.an.syms[txt]
                if s.rng is None and not s.unpacked:
                    width = 1
                elif isinstance(s.rng, tuple) and not s.unpacked:
                    width = s.rng[1] - s.rng[0] + 1
            if v is not None:
                return ExprInfo(v, width, width is None)
            return ExprInfo(None, width, False)
        raise _EvalBail()

    def ternary_all(self):
        info = self.ternary()
        if self.peek()[0] != "eof":
            while self.peek()[0] != "eof":
                t = self.next()
                if t[0] == "id" and t[1] not in KEYWORDS:
                    self.read(t[1], t[2])
            return ExprInfo()
        return info

    def balanced_until(self, close):
        opener = {")": "(", "]": "[", "}": "{"}[close]
        depth, out = 1, []
        while True:
            t = self.next()
            if t[0] == "eof":
                raise _EvalBail()
            if t[0] == "punct":
                if t[1] in "([{":
                    depth += 1
                elif t[1] in ")]}":
                    depth -= 1
                    if depth == 0:
                        break
            out.append(t)
        return out

    def select_info(self, name, sel_groups, ln):
        """Identifier followed by select groups (already read-marked by
        check_selects via the analyzer when not silent)."""
        if self.an is None or self.silent:
            return ExprInfo()
        s = self.an.syms.get(name)
        if s is None:
            # undeclared already reported by self.read
            return ExprInfo()
        flat = []
        for g in sel_groups:
            flat.append(("punct", "[", ln))
            flat.extend(g)
            flat.append(("punct", "]", ln))
        rng = self.an.check_selects(s, name, flat, self.env, ln)
        if rng is not None:
            return ExprInfo(None, rng[1] - rng[0] + 1, False)
        # non-const select of a packed vector: single index = 1 bit wide
        unpacked = len(s.unpacked)
        packed_groups = len(sel_groups) - unpacked
        if packed_groups == 1 and split_sel(sel_groups[-1])[0] == "index":
            return ExprInfo(None, 1, False)
        if packed_groups <= 0 and unpacked and len(sel_groups) == unpacked:
            # full unpacked index: element width = packed range
            if isinstance(s.rng, tuple):
                return ExprInfo(None, s.rng[1] - s.rng[0] + 1, False)
            if s.rng is None:
                return ExprInfo(None, 1, False)
        return ExprInfo()

    def concat(self, inner, ln):
        """{a, b, c} or replication {N{expr}}."""
        parts = split_top(inner, ",")
        if len(parts) == 1:
            # check replication: expr { ... } — find a depth-0 '{'
            depth = 0
            for j, t in enumerate(parts[0]):
                if t[0] == "punct":
                    if t[1] == "{" and depth == 0 and j > 0:
                        count_toks = parts[0][:j]
                        # inner body is parts[0][j+1:-1] (strip closing '}')
                        body = parts[0][j + 1 : -1]
                        cnt = _EP(self.an, count_toks, self.env, ln, True).safe_val()
                        scan = _EP(self.an, body, self.env, ln, self.silent)
                        b = scan.ternary_all()
                        # count tokens are reads too
                        _EP(self.an, count_toks, self.env, ln, self.silent).ternary_all()
                        if cnt is not None and cnt < 0:
                            if self.an is not None and not self.silent:
                                self.an.diag("MC002", ln, f"negative replication count {cnt}")
                            return ExprInfo()
                        if cnt is not None and b.width is not None:
                            return ExprInfo(None, cnt * b.width, False)
                        if cnt == 0:
                            return ExprInfo(None, 0, False)
                        return ExprInfo()
                    if t[1] in "([{":
                        depth += 1
                    elif t[1] in ")]}":
                        depth -= 1
        widths, total = [], 0
        known = True
        for p in parts:
            sub = _EP(self.an, p, self.env, ln, self.silent)
            info = sub.ternary_all()
            if info.width is None:
                known = False
            else:
                total += info.width
        if known and parts:
            return ExprInfo(None, total, False)
        return ExprInfo()

    def safe_val(self):
        try:
            return self.ternary_all().val
        except _EvalBail:
            return None


def clog2(v):
    if v <= 1:
        return 0
    return (v - 1).bit_length()


# ---------------------------------------------------------------------------
# file-set entry point (mirrors check::sv::check_files)
# ---------------------------------------------------------------------------

def check_files(files):
    """files: dict name -> source. Returns (diags, module_table)."""
    diags, mtab, parsed = [], {}, []
    for fname in sorted(files):
        try:
            mods = Parser(tokenize(files[fname])).parse_file()
            for m in mods:
                mtab[m.name] = m
            parsed.append((fname, mods))
        except ParseErr as e:
            diags.append(Diag("MC009", fname, e.line, e.msg))
    for fname, mods in parsed:
        for m in mods:
            ModAnalyzer(m, mtab, fname, diags).run()
    # dedup (code, file, line, message)
    seen, out = set(), []
    for d in diags:
        key = (d.code, d.file, d.line, d.message)
        if key not in seen:
            seen.add(key)
            out.append(d)
    return out, mtab


def params_of(mtab, name):
    """Evaluated default parameters of a module (for contract checks)."""
    m = mtab.get(name)
    if m is None:
        return None
    env = {}
    for pname, toks, _ln in m.params:
        env[pname] = const_eval_in(toks, env)
    for it in m.items:
        if it[0] == "localparam":
            env[it[1]] = const_eval_in(it[2], env)
    return env


# ---------------------------------------------------------------------------
# emit::templates mirrors (structural equivalents of the Rust generators)
# ---------------------------------------------------------------------------

BLOCK_FORMATS = ("mxint", "bmf", "bl")


def ceil_div(a, b):
    return -(-a // b)


def elem_bits(fmt, knob):
    return {
        "mxint": knob + 1,
        "bmf": knob + 4,
        "bl": knob + 2,
        "int": knob,
        "fp8": 8,
        "fp32": 32,
    }[fmt]


def unpacker_cfg(fmt, m, tile, channel_bits):
    """Mirror of templates::unpacker_config (single sizing source)."""
    r, c = tile
    groups = ceil_div(r, 16) * ceil_div(c, 2)
    eb = elem_bits(fmt, m)
    group_w = ceil_div(32 * eb, 64) * 64
    tile_bits = groups * (group_w + 8)
    chan = max(tile_bits, 1) if channel_bits == 0 else channel_bits
    beats = max(ceil_div(tile_bits, chan), 1)
    return dict(
        chan=chan, beats=beats, elem=eb, groups=groups,
        group_w=group_w, tile_bits=tile_bits, lanes=r * c,
    )


def mxint_acc_bits(m):
    return 2 * (m + 1) + 5 - 1


def handshake_ports(in_w, out_w):
    return (
        "    input  logic                 clk,\n"
        "    input  logic                 rst_n,\n"
        "    input  logic                 in_valid,\n"
        "    output logic                 in_ready,\n"
        f"    input  logic [{in_w}-1:0]  in_data,\n"
        "    output logic                 out_valid,\n"
        "    input  logic                 out_ready,\n"
        f"    output logic [{out_w}-1:0] out_data"
    )


def mxint_dot_product(module, mantissa, tile_r, tile_c):
    lanes = tile_r * tile_c
    w = mantissa + 1
    acc_w = mxint_acc_bits(mantissa)
    ports = handshake_ports("2*LANES*MAN_W", "LANES*MAN_W*2")
    return f"""// MXInt dot-product operator (python mirror)
module {module} #(
    parameter MAN_W  = {w},
    parameter TILE_R = {tile_r},
    parameter TILE_C = {tile_c},
    parameter LANES  = {lanes},
    parameter ACC_W  = {acc_w}
) (
{ports},
    input  logic [7:0]           in_exp_a,
    input  logic [7:0]           in_exp_b,
    output logic [7:0]           out_exp
);
    logic signed [MAN_W-1:0] mant_a [LANES];
    logic signed [MAN_W-1:0] mant_b [LANES];
    logic signed [ACC_W-1:0] acc    [LANES];
    integer i;
    always_ff @(posedge clk or negedge rst_n) begin
        if (!rst_n) begin
            for (i = 0; i < LANES; i = i + 1) acc[i] <= '0;
            out_valid <= 1'b0;
        end else if (in_valid && in_ready) begin
            for (i = 0; i < LANES; i = i + 1) begin
                mant_a[i] <= in_data[i*MAN_W +: MAN_W];
                mant_b[i] <= in_data[(LANES+i)*MAN_W +: MAN_W];
                acc[i]    <= acc[i] + mant_a[i] * mant_b[i];
            end
            out_valid <= 1'b1;
        end else if (out_valid && out_ready) begin
            out_valid <= 1'b0;
        end
    end
    assign out_exp  = in_exp_a + in_exp_b;
    assign in_ready = !out_valid || out_ready;
    assign out_data = {{acc[0][ACC_W-1:ACC_W-MAN_W*2], {{(LANES-1)*MAN_W*2{{1'b0}}}}}};
endmodule
"""


def mx_unpacker(module, fmt, m, tile, channel_bits):
    cfg = unpacker_cfg(fmt, m, tile, channel_bits)
    shift_update = (
        "shift <= {in_data, shift[BEATS*CHAN_W-1:CHAN_W]};"
        if cfg["beats"] > 1
        else "shift <= in_data; // single-beat tile"
    )
    ports = handshake_ports("CHAN_W", "LANES*ELEM_W")
    return f"""// packed-word stream unpacker (python mirror)
module {module} #(
    parameter CHAN_W    = {cfg['chan']},
    parameter ELEM_W    = {cfg['elem']},
    parameter LANES     = {cfg['lanes']},
    parameter TILE_C    = {tile[1]},
    parameter GROUPS    = {cfg['groups']},
    parameter GROUP_W   = {cfg['group_w']},
    parameter BEATS     = {cfg['beats']},
    parameter TILE_BITS = {cfg['tile_bits']}
) (
{ports},
    output logic [8*GROUPS-1:0]  out_exp
);
    logic [BEATS*CHAN_W-1:0] shift;
    logic [$clog2(BEATS+1)-1:0] cnt;
    always_ff @(posedge clk or negedge rst_n) begin
        if (!rst_n) begin
            cnt <= '0;
            out_valid <= 1'b0;
        end else begin
            if (out_valid && out_ready) begin
                out_valid <= 1'b0;
            end
            if (in_valid && in_ready) begin
                {shift_update}
                if (cnt == BEATS - 1) begin
                    cnt <= '0;
                    out_valid <= 1'b1;
                end else begin
                    cnt <= cnt + 1'b1;
                end
            end
        end
    end
    genvar gi;
    genvar ge;
    generate
        for (gi = 0; gi < LANES; gi = gi + 1) begin : g_lane
            assign out_data[gi*ELEM_W +: ELEM_W] = shift[
                (((gi/TILE_C)/16)*(TILE_C/2) + (gi%TILE_C)/2)*GROUP_W
                + (((gi/TILE_C)%16)*2 + (gi%TILE_C)%2)*ELEM_W +: ELEM_W];
        end
        for (ge = 0; ge < GROUPS; ge = ge + 1) begin : g_exp
            assign out_exp[ge*8 +: 8] = shift[GROUPS*GROUP_W + ge*8 +: 8];
        end
    endgenerate
    assign in_ready = !out_valid || out_ready;
endmodule
"""


def block_exponent_unit(module):
    ports = handshake_ports("N*8", "N*8")
    return f"""// shared-exponent (max-tree) unit (python mirror)
module {module} #(
    parameter N = 32
) (
{ports}
);
    logic [7:0] exps [N];
    logic [7:0] max_exp;
    integer i;
    always_comb begin
        max_exp = 8'd0;
        for (i = 0; i < N; i = i + 1) begin
            exps[i] = in_data[i*8 +: 8];
            if (exps[i] > max_exp) max_exp = exps[i];
        end
    end
    assign out_data  = {{{{(N-1)*8{{1'b0}}}}, max_exp}};
    assign out_valid = in_valid;
    assign in_ready  = out_ready;
endmodule
"""


def mxint_cast(module, from_m, to_m):
    ports = handshake_ports("FROM_W", "TO_W")
    return f"""// MXInt precision cast (python mirror)
module {module} (
{ports}
);
    localparam FROM_W = {from_m + 1};
    localparam TO_W   = {to_m + 1};
    generate
        if (TO_W >= FROM_W) begin : g_extend
            assign out_data = {{in_data, {{(TO_W-FROM_W){{1'b0}}}}}};
        end else begin : g_truncate_rne
            wire guard  = in_data[FROM_W-TO_W-1];
            wire sticky = |in_data[FROM_W-TO_W-1:0];
            wire lsb    = in_data[FROM_W-TO_W];
            assign out_data = in_data[FROM_W-1:FROM_W-TO_W] + (guard & (sticky | lsb));
        end
    endgenerate
    assign out_valid = in_valid;
    assign in_ready  = out_ready;
endmodule
"""


def stream_fifo(module, depth):
    ports = handshake_ports("W", "W")
    return f"""// handshake FIFO (python mirror)
module {module} #(
    parameter W = 32,
    parameter DEPTH = {depth}
) (
{ports}
);
    logic [W-1:0] mem [DEPTH];
    logic [$clog2(DEPTH):0] count;
    logic [$clog2(DEPTH)-1:0] rd_ptr, wr_ptr;
    wire do_write = in_valid && in_ready;
    wire do_read  = out_valid && out_ready;
    always_ff @(posedge clk or negedge rst_n) begin
        if (!rst_n) begin
            count <= '0; rd_ptr <= '0; wr_ptr <= '0;
        end else begin
            if (do_write) begin mem[wr_ptr] <= in_data; wr_ptr <= wr_ptr + 1'b1; end
            if (do_read)  begin rd_ptr <= rd_ptr + 1'b1; end
            count <= count + do_write - do_read;
        end
    end
    assign in_ready  = (count < DEPTH);
    assign out_valid = (count > 0);
    assign out_data  = mem[rd_ptr];
endmodule
"""


def fixed_function(module, kind, lanes):
    ports = handshake_ports("W*LANES", "W*LANES")
    return f"""// {kind} operator (python mirror)
module {module} #(
    parameter W = 32,
    parameter LANES = {lanes}
) (
{ports}
);
    logic [W*LANES-1:0] stage;
    always_ff @(posedge clk or negedge rst_n) begin
        if (!rst_n) begin
            stage <= '0; out_valid <= 1'b0;
        end else if (in_valid && in_ready) begin
            stage <= in_data;
            out_valid <= 1'b1;
        end else if (out_valid && out_ready) begin
            out_valid <= 1'b0;
        end
    end
    assign out_data = stage;
    assign in_ready = !out_valid || out_ready;
endmodule
"""


def template_for(kind, design_fmt, mantissa, tile):
    name = f"{design_fmt}_{kind}_m{mantissa}_t{tile[0]}x{tile[1]}"
    if kind in ("linear", "attention"):
        return name, mxint_dot_product(name, max(mantissa, 1), tile[0], tile[1])
    return name, fixed_function(name, kind, tile[0] * tile[1])


# ---------------------------------------------------------------------------
# the NEW emit::verilog top-level wiring (blueprint for the Rust rewrite)
# ---------------------------------------------------------------------------

def adapt(net, frm, to):
    if frm == to:
        return net
    if frm > to:
        return f"{net}[{to - 1}:0]"
    return "{" + "{" + str(to - frm) + "{1'b0}}" + ", " + net + "}"


def gen_top(name, ops, channel_bits, design_fmt):
    files = {
        "stream_fifo.sv": stream_fifo("stream_fifo", 4),
        "block_exponent.sv": block_exponent_unit("block_exponent"),
    }
    vals = {op["result"]: op for op in ops if op.get("result") is not None}
    width = {}
    for op in ops:
        r = op.get("result")
        if r is None:
            continue
        lanes = op["tile"][0] * op["tile"][1]
        if op["kind"] in ("input", "output"):
            width[r] = 32
        elif op["kind"] in ("linear", "attention"):
            width[r] = lanes * (max(op["m"], 1) + 1) * 2
        else:
            width[r] = 32 * lanes
    wires, body = [], []
    ready_of, streams = {}, []
    instances = 0
    src_ready_expr = None
    sink_done = False
    for op in ops:
        kind = op["kind"]
        if kind == "input":
            r = op["result"]
            net = f"v{r}"
            wires.append(
                f"    logic {net}_q_valid, {net}_q_ready;\n"
                f"    logic [31:0] {net}_q_data;\n"
            )
            streams.append(r)
            if src_ready_expr is None:
                body.append(
                    f"    assign {net}_q_valid = src_valid;\n"
                    f"    assign {net}_q_data = src_data;\n"
                )
                src_ready_expr = f"{net}_q_ready"
            else:
                body.append(
                    f"    assign {net}_q_valid = 1'b0;\n"
                    f"    assign {net}_q_data = '0;\n"
                )
            continue
        if kind == "output":
            if sink_done or not op["args"]:
                continue
            a = op["args"][0]
            body.append(
                f"    assign sink_valid = v{a}_q_valid;\n"
                f"    assign sink_data = {adapt(f'v{a}_q_data', width[a], 32)};\n"
            )
            ready_of.setdefault(a, []).append("sink_ready")
            sink_done = True
            continue
        r = op["result"]
        net = f"v{r}"
        w_out = width[r]
        tile = op["tile"]
        mod_name, src = template_for(kind, design_fmt, op["m"], tile)
        files.setdefault(f"{mod_name}.sv", src)
        wires.append(
            f"    logic {net}_valid, {net}_ready, {net}_q_valid, {net}_q_ready;\n"
            f"    logic [{w_out - 1}:0] {net}_data;\n"
            f"    logic [{w_out - 1}:0] {net}_q_data;\n"
            f"    logic {net}_in_rdy;\n"
        )
        streams.append(r)
        is_gemm = kind in ("linear", "attention")
        a = op["args"][0] if op["args"] else None
        if a is not None:
            ready_of.setdefault(a, []).append(f"{net}_in_rdy")
        up = None
        if is_gemm and a is not None:
            va = vals.get(a)
            if va is not None and va["fmt"] in BLOCK_FORMATS:
                m_in = max(va["m"], 1)
                cfg = unpacker_cfg(va["fmt"], m_in, va["tile"], channel_bits)
                up_name = (
                    f"{va['fmt']}_unpack_m{m_in}_t{va['tile'][0]}x{va['tile'][1]}"
                    f"_c{channel_bits}"
                )
                files.setdefault(
                    f"{up_name}.sv",
                    mx_unpacker(up_name, va["fmt"], m_in, va["tile"], channel_bits),
                )
                upw = cfg["lanes"] * cfg["elem"]
                wires.append(
                    f"    logic {net}_up_valid, {net}_up_ready;\n"
                    f"    logic [{upw - 1}:0] {net}_up_data;\n"
                    f"    logic [{8 * cfg['groups'] - 1}:0] {net}_up_exp;\n"
                )
                body.append(
                    f"    {up_name} u_{net}_up (\n"
                    "        .clk(clk), .rst_n(rst_n),\n"
                    f"        .in_valid(v{a}_q_valid), .in_ready({net}_in_rdy),"
                    f" .in_data({adapt(f'v{a}_q_data', width[a], cfg['chan'])}),\n"
                    f"        .out_valid({net}_up_valid), .out_ready({net}_up_ready),"
                    f" .out_data({net}_up_data),\n"
                    f"        .out_exp({net}_up_exp)\n"
                    "    );\n"
                )
                instances += 1
                up = (f"{net}_up", upw)
        if up is not None:
            feed_valid = f"{up[0]}_valid"
            feed_rdy = f"{up[0]}_ready"
            feed_data = adapt(f"{up[0]}_data", up[1], w_out)
            exp_a = f"{net}_up_exp[7:0]"
        elif a is not None:
            feed_valid = f"v{a}_q_valid"
            feed_rdy = f"{net}_in_rdy"
            feed_data = adapt(f"v{a}_q_data", width[a], w_out)
            exp_a = "8'd0"
        else:
            feed_valid = "1'b0"
            feed_rdy = f"{net}_in_rdy"
            feed_data = "'0"
            exp_a = "8'd0"
        extra = (
            f",\n        .in_exp_a({exp_a}), .in_exp_b(8'd0), .out_exp()"
            if is_gemm
            else ""
        )
        body.append(
            f"    {mod_name} u_{net} (\n"
            "        .clk(clk), .rst_n(rst_n),\n"
            f"        .in_valid({feed_valid}), .in_ready({feed_rdy}),"
            f" .in_data({feed_data}),\n"
            f"        .out_valid({net}_valid), .out_ready({net}_ready),"
            f" .out_data({net}_data){extra}\n"
            "    );\n"
        )
        instances += 1
        body.append(
            f"    stream_fifo #(.W({w_out}), .DEPTH(4)) fifo_{net} (\n"
            "        .clk(clk), .rst_n(rst_n),\n"
            f"        .in_valid({net}_valid), .in_ready({net}_ready),"
            f" .in_data({net}_data),\n"
            f"        .out_valid({net}_q_valid), .out_ready({net}_q_ready),"
            f" .out_data({net}_q_data)\n"
            "    );\n"
        )
        instances += 1
    for r in streams:
        rdys = ready_of.pop(r, [])
        expr = " & ".join(rdys) if rdys else "1'b1"
        body.append(f"    assign v{r}_q_ready = {expr};\n")
    tail = ""
    if src_ready_expr is not None:
        tail += f"    assign src_ready  = {src_ready_expr};\n"
    else:
        tail += "    assign src_ready  = 1'b1;\n"
    if not sink_done:
        tail += "    assign sink_valid = 1'b0;\n    assign sink_data  = 32'd0;\n"
    top = (
        f"// top-level dataflow accelerator for @{name} (python mirror)\n"
        f"module {name}_top (\n"
        "    input  logic        clk,\n"
        "    input  logic        rst_n,\n"
        "    input  logic        src_valid,\n"
        "    output logic        src_ready,\n"
        "    input  logic [31:0] src_data,\n"
        "    output logic        sink_valid,\n"
        "    input  logic        sink_ready,\n"
        "    output logic [31:0] sink_data\n"
        ");\n" + "".join(wires) + "\n" + "".join(body) + tail + "endmodule\n"
    )
    files["top.sv"] = top
    return files, instances


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------

FAILS = []


def check(label, cond, detail=""):
    mark = "PASS" if cond else "FAIL"
    print(f"  [{mark}] {label}" + ("" if cond else f"  <-- {detail}"))
    if not cond:
        FAILS.append(label)


def fmt_diags(diags):
    return "; ".join(f"{d.code}@{d.file}:{d.line} {d.message}" for d in diags[:6])


def expect_clean(label, files):
    diags, _ = check_files(files)
    check(label, not diags, fmt_diags(diags))


def s1_template_grid():
    print("S1: per-template zero-diagnostics grid")
    for m in (1, 3, 5, 8):
        for tile in ((8, 4), (16, 2), (32, 4)):
            name = f"mxint_linear_m{m}_t{tile[0]}x{tile[1]}"
            expect_clean(f"dot-product m={m} t={tile}", {f"{name}.sv": mxint_dot_product(name, m, *tile)})
    for fmt in BLOCK_FORMATS:
        for m in (1, 3, 5):
            for tile in ((8, 4), (16, 2), (32, 4)):
                for chan in (512, 64, 0):
                    cfg = unpacker_cfg(fmt, m, tile, chan)
                    name = f"{fmt}_unpack_m{m}_t{tile[0]}x{tile[1]}_c{chan}"
                    expect_clean(
                        f"unpacker {fmt} m={m} t={tile} c={chan} (beats={cfg['beats']})",
                        {f"{name}.sv": mx_unpacker(name, fmt, m, tile, chan)},
                    )
    expect_clean("block_exponent_unit", {"be.sv": block_exponent_unit("block_exponent")})
    for fm, tm in ((8, 4), (4, 8), (5, 5)):
        expect_clean(f"mxint_cast {fm}->{tm}", {"c.sv": mxint_cast(f"cast_{fm}_{tm}", fm, tm)})
    for depth in (2, 4, 8):
        expect_clean(f"stream_fifo depth={depth}", {"f.sv": stream_fifo("stream_fifo", depth)})
    for kind in ("layernorm", "gelu", "add", "meanpool", "embed"):
        expect_clean(f"fixed_function {kind}", {"x.sv": fixed_function(f"fx_{kind}", kind, 32)})


def realistic_ops(fmt, m):
    t = (16, 2)
    return [
        dict(kind="input", result=0, args=[], tile=t, fmt="fp32", m=32),
        dict(kind="embed", result=1, args=[0], tile=t, fmt="fp32", m=32),
        dict(kind="layernorm", result=2, args=[1], tile=t, fmt=fmt, m=m),
        dict(kind="linear", result=3, args=[2], tile=t, fmt="fp32", m=32),
        dict(kind="reorder", result=4, args=[3], tile=t, fmt="fp32", m=32),
        dict(kind="transpose", result=5, args=[3], tile=t, fmt="fp32", m=32),
        dict(kind="attention", result=6, args=[4], tile=t, fmt=fmt, m=max(m - 1, 1)),
        dict(kind="linear", result=7, args=[6], tile=t, fmt="fp32", m=32),
        dict(kind="add", result=8, args=[1, 7], tile=t, fmt="fp32", m=32),
        dict(kind="meanpool", result=9, args=[8], tile=t, fmt=fmt, m=max(m - 2, 1)),
        dict(kind="linear", result=10, args=[9], tile=t, fmt="fp32", m=32),
        dict(kind="output", result=None, args=[10], tile=t, fmt="fp32", m=32),
    ]


def s2_full_designs():
    print("S2: full-design zero-diagnostics (new top-level wiring)")
    for fmt, m in (("mxint", 5), ("bmf", 3), ("bl", 4)):
        for chan in (512, 64, 0):
            files, n_inst = gen_top(f"net_{fmt}{m}_c{chan}", realistic_ops(fmt, m), chan, fmt)
            diags, _ = check_files(files)
            check(
                f"design {fmt} m={m} chan={chan} ({len(files)} files, {n_inst} instances)",
                not diags,
                fmt_diags(diags),
            )
            check(f"  has unpackers ({fmt} chan={chan})", any("_unpack_" in f for f in files))
    files, _ = gen_top("net_int", realistic_ops("int", 6), 512, "int")
    diags, _ = check_files(files)
    check("design int m=6 (no unpackers)", not diags, fmt_diags(diags))
    check("  int design has no unpackers", not any("_unpack_" in f for f in files))


def s3_corpus():
    print("S3: known-bad corpus reproduces the PR 5 findings")
    import os
    cdir = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "rust", "tests", "corpus")
    expect = {
        "bad_reversed_select.sv": "MC002",
        "bad_port_width.sv": "MC004",
        "bad_undeclared.sv": "MC001",
        "bad_multidriven.sv": "MC005",
        "bad_unused.sv": "MC006",
    }
    for fname, code in sorted(expect.items()):
        with open(os.path.join(cdir, fname)) as fh:
            src = fh.read()
        diags, _ = check_files({fname: src})
        codes = {d.code for d in diags}
        check(f"{fname} -> {code}", code in codes, f"got {fmt_diags(diags) or 'none'}")
        check(f"{fname} parses (no MC009)", "MC009" not in codes, fmt_diags(diags))


def s4_micro():
    print("S4: analyzer micro-tests")

    def codes_of(src):
        diags, _ = check_files({"t.sv": src})
        return [d.code for d in diags], diags

    hdr = "module t (input logic clk, input logic [7:0] a, output logic [7:0] y);\n"

    c, d = codes_of(hdr + "  assign y = a[7:0];\nendmodule\n")
    check("in-bounds select clean", not c, fmt_diags(d))
    c, _ = codes_of(hdr + "  assign y = a[8:1];\nendmodule\n")
    check("upper bound overflow -> MC003", "MC003" in c)
    c, _ = codes_of(hdr + "  assign y = a[0:7];\nendmodule\n")
    check("reversed select -> MC002", "MC002" in c)
    c, _ = codes_of(hdr + "  assign y = {8{a[0]}};\nendmodule\n")
    check("replication clean", not c)
    c, d = codes_of(
        hdr + "  logic [7:0] s;\n  assign s[3:0] = a[3:0];\n"
        "  assign s[7:4] = a[7:4];\n  assign y = s;\nendmodule\n"
    )
    check("disjoint-range drivers clean", "MC005" not in c, fmt_diags(d))
    c, _ = codes_of(
        hdr + "  logic [7:0] s;\n  assign s[4:0] = a[4:0];\n"
        "  assign s[7:4] = a[7:4];\n  assign y = s;\nendmodule\n"
    )
    check("overlapping-range drivers -> MC005", "MC005" in c)
    c, _ = codes_of(hdr + "  unknown_mod u0 (.clk(clk));\n  assign y = a;\nendmodule\n")
    check("unknown module -> MC007", "MC007" in c)
    c, _ = codes_of(
        "module leaf (input logic clk);\nendmodule\n"
        + hdr + "  leaf u0 (.clk(clk), .nope(a[0]));\n  assign y = a;\nendmodule\n"
    )
    check("unknown port -> MC008", "MC008" in c)
    c, _ = codes_of(hdr + "  logic [3:0] s;\n  logic [3:0] s;\n  assign y = a;\nendmodule\n")
    check("duplicate decl -> MC010", "MC010" in c)
    c, d = codes_of(
        "module t #(parameter W = 8) (input logic [W-1:0] a, output logic [W-1:0] y);\n"
        "  generate\n    if (W >= 8) begin : g_a\n      assign y = a;\n"
        "    end else begin : g_b\n      assign y = {a, {(8-W){1'b0}}};\n"
        "    end\n  endgenerate\nendmodule\n"
    )
    check("untaken generate branch skipped", not c, fmt_diags(d))
    c, d = codes_of(
        "/* block comment with keywords: module wire assign\n   spanning lines */\n"
        + hdr + "  assign y = a; // trailing\n  /* inline */ endmodule\n"
    )
    check("block comments stripped", not c, fmt_diags(d))
    c, _ = codes_of(hdr + "  assign y = b;\nendmodule\n")
    check("undeclared ref -> MC001", "MC001" in c)
    # contract helper spot-checks (mirrors of check::contracts closed forms)
    check("acc width m=5 -> 16", mxint_acc_bits(5) == 16)
    cfg = unpacker_cfg("mxint", 5, (16, 2), 512)
    check(
        "unpacker cfg mxint m=5 t=16x2 c=512",
        cfg == dict(chan=512, beats=1, elem=6, groups=1, group_w=192, tile_bits=200, lanes=32),
        str(cfg),
    )
    cfg0 = unpacker_cfg("mxint", 5, (16, 2), 0)
    check("chan=0 falls back to tile_bits", cfg0["chan"] == 200 and cfg0["beats"] == 1, str(cfg0))
    pm = params_of(check_files({"f.sv": stream_fifo("stream_fifo", 4)})[1], "stream_fifo")
    check("params_of stream_fifo", pm == {"W": 32, "DEPTH": 4}, str(pm))


def main():
    s1_template_grid()
    s2_full_designs()
    s3_corpus()
    s4_micro()
    print()
    if FAILS:
        print(f"FAILED ({len(FAILS)}): " + ", ".join(FAILS[:10]))
        return 1
    print("verify_sv_check: ALL CHECKS PASSED")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
