#!/usr/bin/env bash
# Run a named bench and append a dated, host-stamped entry to
# BENCH_RESULTS.md — the one-command version of the "run the bench on a
# toolchain host and record the numbers" convention (README, ROADMAP).
#
#   scripts/record_bench.sh fig1_dataflow_schedule
#   scripts/record_bench.sh table4_runtime -- --quiet   # extra cargo args
#   MASE_TRIALS=8 scripts/record_bench.sh fig4_search_algorithms
#
# The entry records the bench name, date, git revision, core count and
# the bench's full stdout in a fenced block, so CI can upload
# BENCH_RESULTS.md as an artifact and CHANGES.md can cite it instead of
# inlining tables.

set -euo pipefail

if [[ $# -lt 1 ]]; then
  echo "usage: $(basename "$0") <bench-name> [-- <extra cargo bench args>]" >&2
  echo "benches live in rust/benches/ (e.g. fig1_dataflow_schedule)" >&2
  exit 2
fi

bench="$1"
shift
if [[ "${1:-}" == "--" ]]; then
  shift
fi

repo_root="$(cd "$(dirname "$0")/.." && pwd)"
results="$repo_root/BENCH_RESULTS.md"
cd "$repo_root/rust"

if [[ ! -f "benches/$bench.rs" ]]; then
  echo "unknown bench '$bench'; available:" >&2
  ls benches/*.rs | sed 's|benches/||; s|\.rs$||; s|^common$||' | grep -v '^$' >&2
  exit 2
fi

stamp="$(date -u +%Y-%m-%dT%H:%M:%SZ)"
rev="$(git -C "$repo_root" rev-parse --short HEAD 2>/dev/null || echo unknown)"
cores="$(nproc 2>/dev/null || sysctl -n hw.ncpu 2>/dev/null || echo '?')"

out="$(mktemp)"
trap 'rm -f "$out"' EXIT
echo "==> cargo bench --bench $bench $*"
# tee so the operator still sees the live output
cargo bench --bench "$bench" "$@" 2>&1 | tee "$out"

{
  echo
  echo "## $bench — $stamp"
  echo
  echo "- git: \`$rev\` · cores: $cores · host: $(uname -sm)"
  echo
  echo '```'
  cat "$out"
  echo '```'
  # Benches that fold their accounting into the obs registry (PR 8)
  # print a delimited TraceSummary block; lift it verbatim into its own
  # section so the counters are scannable without the full transcript.
  if grep -q '== trace summary ==' "$out"; then
    echo
    echo "### trace summary"
    echo
    echo '```'
    sed -n '/== trace summary ==/,/== end trace summary ==/p' "$out"
    echo '```'
  fi
} >>"$results"

echo "recorded to ${results#"$repo_root"/}"
