#!/usr/bin/env python3
"""Numerical verification of the PR-9 serving scheduler
(rust/src/serve/scheduler.rs + the per-slot Decoder surface in
rust/src/runtime/decode.rs), mirrored in numpy — this container has no
Rust toolchain, so the continuous-batching determinism contract is
validated here the same way verify_interp_math.py validates the
interpreter and decode engine.

Mirrored, op-for-op, on top of the PR-4/PR-7 mirrors (slice-imported
from verify_interp_math.py): per-slot context starts (each slot embeds
at its *logical* position `pos - starts[slot]` and attends only
`starts[slot]..=pos`), eviction (zero the slot's cached K/V rows,
advance its start), cache compaction (drop positions before
min(starts)), and the BatchEngine lane protocol — admission between
steps, prompt-as-decode feeding, greedy harvest, retirement after
`prompt_len + max_tokens` fed positions, idle lanes ticking at context
one.

Claims checked (the assertions of rust/tests/serve_batching.rs, same
corpus streams and request shapes):
  S1  MXInt(7) (16-row lanes), Int(8, calibrated frac 5) and fp32
      (1-row lanes): continuously-batched tokens AND per-position logits
      are bitwise identical to a fresh per-request sequential decode,
      under staggered admissions, a mid-flight join, and a lane reused
      after retirement.
  S2  the 16-row replication lemma block formats rely on: identical rows
      fed through a lane stay bitwise identical at every position (the
      shared block exponent is insensitive to replication).
  S3  counted attention work matches the closed form: each request costs
      exactly its solo decode (admission never recomputes a prefix) plus
      one dot per (slot, head, layer) per idle-lane tick.
  S4  eviction hygiene: a reused lane's output never depends on the
      evicted tenant (implied by S1 — request C decodes on a lane that
      previously held request A).
"""
import os
import sys

import numpy as np

f32 = np.float32

# ---- slice-import the PR-4 defs + PR-7 decode mirrors (no checks) -------
_here = os.path.dirname(os.path.abspath(__file__))
_im_path = os.path.join(_here, "verify_interp_math.py")
_im_src = open(_im_path).read()
_ns = {"__file__": _im_path, "__name__": "_interp_mirror"}
exec(_im_src[: _im_src.index("# ------------------------------- checks")], _ns)
exec(
    _im_src[_im_src.index("def d_attn_row") : _im_src.index("lmD = DecodeNet")],
    _ns,
)
DecodeNet = _ns["DecodeNet"]
MarkovCorpus = _ns["MarkovCorpus"]
cached_run = _ns["cached_run"]
d_attn_row = _ns["d_attn_row"]
layer_norm = _ns["layer_norm"]
gelu = _ns["gelu"]
qcfg_uniform = _ns["qcfg_uniform"]
qtensor_names = _ns["qtensor_names"]

fails = []


def check(name, ok):
    print(("PASS  " if ok else "FAIL  ") + name)
    if not ok:
        fails.append(name)


# --------------- per-slot decode step (Decoder::decode_step) -------------
def serve_step(netD, toks, cache, starts, pos, fmt, qc, path, dots):
    """One position for the whole group with per-slot context windows.
    Mirrors decode.rs::decode_step: slot bi embeds at logical position
    pos - starts[bi] and attends K/V rows starts[bi]..=pos. dots is a
    one-element counter of score dot-products (DecodeStats mirror)."""
    b = toks.shape[0]
    d, heads = netD.d, netD.heads
    dh = d // heads
    scale = f32(np.sqrt(f32(dh)))
    x = np.stack(
        [
            (netD.p["embed"][toks[bi]] + netD.p["pos"][pos - starts[bi]]).astype(f32)
            for bi in range(b)
        ]
    ).astype(f32)
    for i in range(netD.L):
        pre = f"layer{i}."
        h = layer_norm(x, netD.p[pre + "ln1_g"], netD.p[pre + "ln1_b"], i)
        qkv = netD.qmm(h, pre + "a_attn_in", pre + "w_qkv", fmt, qc, path)
        K = np.concatenate([cache[i][0], qkv[:, None, d : 2 * d]], axis=1)
        V = np.concatenate([cache[i][1], qkv[:, None, 2 * d :]], axis=1)
        cache[i] = [K, V]
        o = np.zeros((b, d), f32)
        for bi in range(b):
            st = starts[bi]
            n_ctx = pos + 1 - st
            for hh in range(heads):
                off = hh * dh
                o[bi, off : off + dh] = d_attn_row(
                    qkv[bi, off : off + dh].astype(np.float64),
                    K[bi, st : pos + 1, off : off + dh].astype(np.float64),
                    V[bi, st : pos + 1, off : off + dh].astype(np.float64),
                    scale,
                    n_ctx,
                    n_ctx,
                )
                dots[0] += n_ctx
        o = netD.qmm(o, pre + "a_proj_in", pre + "w_proj", fmt, qc, path)
        x = (x + o).astype(f32)
        h = layer_norm(x, netD.p[pre + "ln2_g"], netD.p[pre + "ln2_b"], i)
        h = netD.qmm(h, pre + "a_fc1_in", pre + "w_fc1", fmt, qc, path)
        h = gelu(h)
        h = netD.qmm(h, pre + "a_fc2_in", pre + "w_fc2", fmt, qc, path)
        x = (x + h).astype(f32)
    xf = layer_norm(x, netD.p["lnf_g"], netD.p["lnf_b"], None)
    return netD.qmm(xf, "a_head_in", "head_w", fmt, qc, path)


def evict(cache, starts, length, slot):
    """Decoder::evict mirror: zero the slot's cached rows (hygiene — the
    window below excludes them; zeroing proves no stale-bit dependence),
    advance its context start to the present."""
    for lay in cache:
        lay[0][slot, starts[slot] : length, :] = 0.0
        lay[1][slot, starts[slot] : length, :] = 0.0
    starts[slot] = length


def compact(cache, starts, length):
    """Decoder::compact mirror: drop cache positions no slot can attend.
    Returns the new length."""
    base = min(min(starts), length)
    if base == 0:
        return length
    for i, lay in enumerate(cache):
        cache[i] = [lay[0][:, base:, :].copy(), lay[1][:, base:, :].copy()]
    for bi in range(len(starts)):
        starts[bi] -= base
    return length - base


# ----------------- BatchEngine mirror (serve/scheduler.rs) ---------------
class EngineMirror:
    def __init__(self, netD, fmt, qc, path, lanes, width):
        self.netD, self.fmt, self.qc, self.path = netD, fmt, qc, path
        self.width = width
        self.group = lanes * width
        self.lanes = [None] * lanes
        d = netD.d
        self.cache = [
            [np.zeros((self.group, 0, d), f32), np.zeros((self.group, 0, d), f32)]
            for _ in range(netD.L)
        ]
        self.starts = [0] * self.group
        self.len = 0
        self.dots = [0]
        self.idle_slot_steps = 0

    def free_lane(self):
        for i, lane in enumerate(self.lanes):
            if lane is None:
                return i
        return -1

    def is_idle(self):
        return all(lane is None for lane in self.lanes)

    def evict_lane(self, lane):
        for s in range(lane * self.width, (lane + 1) * self.width):
            evict(self.cache, self.starts, self.len, s)

    def admit(self, rid, prompt, max_tokens):
        lane = self.free_lane()
        assert lane >= 0, "admit with no free lane"
        self.evict_lane(lane)
        self.lanes[lane] = dict(
            id=rid, prompt=list(prompt), max=max_tokens, fed=0, gen=[], logits=[]
        )

    def step(self):
        if self.is_idle():
            return []
        self.len = compact(self.cache, self.starts, self.len)
        toks = np.zeros(self.group, np.int64)
        for lane, l in enumerate(self.lanes):
            if l is None:
                self.evict_lane(lane)
                self.idle_slot_steps += self.width
            else:
                t = (
                    l["prompt"][l["fed"]]
                    if l["fed"] < len(l["prompt"])
                    else l["gen"][l["fed"] - len(l["prompt"])]
                )
                toks[lane * self.width : (lane + 1) * self.width] = t
        lg = serve_step(
            self.netD, toks, self.cache, self.starts, self.len,
            self.fmt, self.qc, self.path, self.dots,
        )
        self.len += 1
        done = []
        for lane, l in enumerate(self.lanes):
            if l is None:
                continue
            row = lg[lane * self.width]
            # S2: the replication lemma — every row of a live lane is
            # bitwise the lane-representative row
            for r in range(1, self.width):
                assert (
                    lg[lane * self.width + r].tobytes() == row.tobytes()
                ), "lane rows diverged: the replication lemma is broken"
            Lp = len(l["prompt"])
            l["fed"] += 1
            l["logits"].append(row.copy())
            if l["fed"] >= Lp:
                if l["fed"] - Lp < l["max"]:
                    l["gen"].append(int(row.argmax()))
                if l["fed"] == Lp + l["max"]:
                    done.append(l)
                    self.lanes[lane] = None
                    self.evict_lane(lane)
        return done


def run_staggered(eng, reqs):
    """The rust test's schedule: A before tick 0; B joins the live group
    after 2 ticks; C waits for a free lane (A's retirement) and reuses
    it while B is still mid-flight."""
    eng.admit(0, reqs[0][0], reqs[0][1])
    pending = [(2, 3), (1, 2)]  # (id, admissible after N ticks), popped from the back
    done = []
    tick = 0
    while True:
        assert tick < 64, "engine failed to drain in 64 ticks"
        done += eng.step()
        while pending:
            rid, at = pending[-1]
            if tick + 1 >= at and eng.free_lane() >= 0:
                pending.pop()
                eng.admit(rid, reqs[rid][0], reqs[rid][1])
            else:
                break
        if not pending and eng.is_idle():
            break
        tick += 1
    assert len(done) == 3
    return sorted(done, key=lambda l: l["id"])


def expected_decode_dots(group, heads, layers, prefill, n_tokens):
    """DecodeStats::expected_decode_dots mirror."""
    return group * heads * layers * sum(
        p + 1 for p in range(prefill, prefill + n_tokens)
    )


# ------------------------------- checks ----------------------------------
print("== PR 9 serve mirror: continuous batching vs sequential decode ==")
netD = DecodeNet(kind="lm")
corpus = MarkovCorpus(7)
reqs = [
    (list(corpus.batch(21, 1, 5)[0]), 4),
    (list(corpus.batch(22, 1, 3)[0]), 6),
    (list(corpus.batch(23, 1, 7)[0]), 3),
]
int_fracs = {n: 5.0 for n in qtensor_names(1)}  # absmax 4.0, bits 8 -> frac 5

for fmt, bits, fracs, width in [
    ("mxint", 7.0, None, 16),
    ("int", 8.0, int_fracs, 1),
    ("fp32", 32.0, None, 1),
]:
    qc = qcfg_uniform(1, bits, fracs)
    eng = EngineMirror(netD, fmt, qc, "packed", lanes=2, width=width)
    done = run_staggered(eng, reqs)

    all_tokens_ok, all_logits_ok = True, True
    for l, (prompt, mx) in zip(done, reqs):
        rep = np.tile(np.asarray(prompt, np.int64), (width, 1))
        toks, step_logits = cached_run(netD, rep, len(prompt), mx, fmt, qc, "packed", True)
        want_gen = [int(t) for t in toks[0, len(prompt) :]]
        all_tokens_ok &= l["gen"] == want_gen
        all_logits_ok &= len(l["logits"]) == len(step_logits) and all(
            got.tobytes() == want[0].tobytes()
            for got, want in zip(l["logits"], step_logits)
        )
    check(f"S1 {fmt}({bits:g}) batched tokens == sequential, all 3 requests",
          all_tokens_ok)
    check(f"S1 {fmt}({bits:g}) per-position logits bitwise sequential",
          all_logits_ok)

    per_req = sum(
        expected_decode_dots(width, netD.heads, netD.L, 0, len(p) + mx)
        for p, mx in reqs
    )
    idle = netD.heads * netD.L * eng.idle_slot_steps
    check(
        f"S3 {fmt}({bits:g}) score dots == closed form "
        f"({per_req} solo + {idle} idle)",
        eng.dots[0] == per_req + idle,
    )

# S2 is asserted inside EngineMirror.step on every live lane of every
# tick (hard assert, not a check — a violation aborts the run). S4 is
# implied by S1: request C ran on the lane request A vacated.
print()
if fails:
    print(f"{len(fails)} FAILED: {fails}")
    sys.exit(1)
print("all serve-protocol mirror checks passed")
