#!/usr/bin/env python3
"""Numerical verification of the rust/src/packed design, mirrored in numpy
float32 (IEEE single, correctly rounded per op — same semantics as Rust f32).

Mirrors:  formats::{mxint,bmf,bl,fixed,minifloat} quantizers (Rust semantics,
incl. copysign signed zeros), packed::layout encode/decode, packed::kernels
dot/gemm integer datapath and the f64 references.

Claims checked:
  C1  unpack(pack(x)) bit-identical to quantize(x), all 5 formats,
      random scales + subnormal-heavy + all-zero blocks + signed zeros
      (fixed point: modulo -0.0 -> +0.0).
  C2  BMF magnitude always fits m+1 bits; BL code fits eb+1 bits;
      MXInt magnitude fits m bits (the field-width claims).
  C3  MXInt packed integer dot == f64 block-order reference, bitwise.
  C4  Int packed dot == f64 group-order reference, bitwise.
  C5  BMF/FP8/BL dot within n*2^-50*sum|ab| of reference.
  C6  MXInt packed GEMM == segmented f64 reference, bitwise (2-wide segs).
  C7  my numpy transcription of the quantizers agrees with ref.py (jax)
      on clean data (sanity that the transcription is faithful).
"""
import numpy as np
import struct, sys

f32 = np.float32

def bits(x):  return struct.unpack('<I', struct.pack('<f', f32(x)))[0]

def pow2(e):  # Rust formats::pow2
    e = int(np.clip(e, -149, 127))
    if e >= -126: return f32(struct.unpack('<f', struct.pack('<I', (e + 127) << 23))[0])
    return f32(struct.unpack('<f', struct.pack('<I', 1 << (e + 149)))[0])

def floor_log2(x):  # Rust formats::floor_log2 (x > 0 finite)
    b = bits(x); exp = (b >> 23) & 0xff
    if exp == 0:
        mant = b & 0x7fffff
        return (mant.bit_length() - 1) - 149
    return exp - 127

def rte(x):  # round ties even, f32
    return f32(np.rint(f32(x)))

def is_neg(x): return bool(bits(x) >> 31)

SHARED_EXP_MIN, LOCAL_EXP_BITS = -126, 2

def shared_exponent(maxabs):
    if maxabs == 0.0 or not np.isfinite(maxabs): return SHARED_EXP_MIN
    return int(np.clip(floor_log2(maxabs), -126, 127))

# ---------------- Rust-semantics quantizers (f32 op-for-op) --------------
def resolve_m(b, floor_=1.0): return int(max(f32(np.round(f32(b))), floor_)) if not np.isnan(b) else int(floor_)

def q_mxint(x, rows, cols, mb):
    m = resolve_m(mb); q = x.copy()
    for s, blk in blocks(rows, cols):
        e = shared_exponent(maxabs(x, s, cols))
        sc = pow2(e + 1 - m); qm = f32(pow2(m) - f32(1.0))
        for i in blk:
            q[i] = f32(f32(np.clip(rte(f32(x[i] / sc)), -qm, qm)) * sc)
    return q

def q_bmf(x, rows, cols, mb):
    m = resolve_m(mb); e_min = -(int(pow2(LOCAL_EXP_BITS)) - 1); q = x.copy()
    for s, blk in blocks(rows, cols):
        bias = shared_exponent(maxabs(x, s, cols))
        top = f32(pow2(bias + 1) - pow2(bias - m))
        for i in blk:
            xi = x[i]
            if xi == 0.0: q[i] = f32(0.0); continue
            a = f32(abs(xi)); e_loc = int(np.clip(floor_log2(a) - bias, e_min, 0))
            sc = pow2(e_loc + bias - m)
            v = f32(min(f32(rte(f32(a / sc)) * sc), top))
            q[i] = f32(np.copysign(v, xi))
    return q

def q_bl(x, rows, cols, eb):
    ebi = resolve_m(eb); levels = int(pow2(ebi)) - 1; q = x.copy()
    for s, blk in blocks(rows, cols):
        bias = shared_exponent(maxabs(x, s, cols))
        e_min = bias - levels; under = pow2(e_min - 1)
        for i in blk:
            xi = x[i]
            if xi == 0.0: q[i] = f32(0.0); continue
            a = f32(abs(xi))
            if a < under: q[i] = f32(np.copysign(f32(0.0), xi)); continue
            e = int(np.clip(round(float(np.log2(float(a)))), e_min, bias))
            q[i] = f32(np.copysign(pow2(e), xi))
    return q

def q_int(x, w_, f_):
    w = int(max(f32(np.round(f32(w_))), 2.0)); f = int(f32(np.round(f32(f_))))
    sc = pow2(-f); qmax = f32(pow2(w - 1) - f32(1.0)); qmin = f32(-pow2(w - 1))
    return np.array([f32(f32(np.clip(rte(f32(v / sc)), qmin, qmax)) * sc) for v in x], f32)

def q_fp8(x, e=4, m=3, bias=7):
    e_min = 1 - bias; e_max = int(pow2(e)) - 2 - bias
    top = f32(pow2(e_max + 1) - pow2(e_max - m)); under = pow2(e_min - 1)
    out = x.copy()
    for i, xi in enumerate(x):
        if xi == 0.0: continue
        a = f32(abs(xi))
        if a < under: out[i] = f32(np.copysign(f32(0.0), xi)); continue
        ee = int(np.clip(floor_log2(a), e_min, e_max))
        sc = pow2(ee - m)
        out[i] = f32(np.copysign(f32(min(f32(rte(f32(a / sc)) * sc), top)), xi))
    return out

def blocks(rows, cols):
    out = []
    for rb in range(rows // 16):
        for cb in range(cols // 2):
            s = rb * 16 * cols + cb * 2
            out.append((s, [s + r * cols + c for r in range(16) for c in range(2)]))
    return out

def maxabs(x, s, cols):
    return f32(max(abs(x[s + r * cols + c]) for r in range(16) for c in range(2)))

# ---------------- packed encode/decode (mirrors layout.rs) ---------------
def enc_mxint(v, e, m):
    sc = pow2(e + 1 - m); qq = f32(v / sc); mag = int(abs(qq))
    assert float(abs(qq)).is_integer() and mag <= (1 << m) - 1, (v, e, m)
    return (int(is_neg(v)) << m) | mag

def dec_mxint(code, e, m):
    sc = pow2(e + 1 - m); mag = f32(code & ((1 << m) - 1))
    val = f32(mag * sc)
    return f32(-val) if (code >> m) & 1 else val

def enc_bmf(v, bias, m):
    e_min = -(int(pow2(LOCAL_EXP_BITS)) - 1)
    if v == 0.0: return int(is_neg(v)) << (LOCAL_EXP_BITS + m + 1)
    a = f32(abs(v)); e_loc = int(np.clip(floor_log2(a) - bias, e_min, 0))
    sc = pow2(e_loc + bias - m); qq = f32(a / sc); k = int(qq)
    assert float(qq).is_integer() and 1 <= k <= (1 << (m + 1)) - 1, (v, bias, m, qq)
    return (int(is_neg(v)) << (LOCAL_EXP_BITS + m + 1)) | ((e_loc - e_min) << (m + 1)) | k

def dec_bmf(code, bias, m):
    e_min = -(int(pow2(LOCAL_EXP_BITS)) - 1)
    sign = (code >> (LOCAL_EXP_BITS + m + 1)) & 1
    k = code & ((1 << (m + 1)) - 1)
    if k == 0: return f32(-0.0) if sign else f32(0.0)
    ec = (code >> (m + 1)) & ((1 << LOCAL_EXP_BITS) - 1)
    val = f32(f32(k) * pow2(e_min + ec + bias - m))
    return f32(-val) if sign else val

def enc_bl(v, bias, eb):
    if v == 0.0: return int(is_neg(v)) << (eb + 1)
    e_min = bias - (int(pow2(eb)) - 1)
    c = floor_log2(f32(abs(v))) - e_min + 1
    assert 1 <= c <= (1 << eb), (v, bias, eb, c)
    return (int(is_neg(v)) << (eb + 1)) | c

def dec_bl(code, bias, eb):
    sign = (code >> (eb + 1)) & 1
    c = code & ((1 << (eb + 1)) - 1)
    if c == 0: return f32(-0.0) if sign else f32(0.0)
    e_min = bias - (int(pow2(eb)) - 1)
    val = pow2(e_min + c - 1)
    return f32(-val) if sign else val

def enc_int(v, w, f):
    k = int(f32(v / pow2(-f)))
    assert -(1 << (w - 1)) <= k <= (1 << (w - 1)) - 1
    return k & ((1 << w) - 1)

def dec_int(code, w, f):
    k = code if code < (1 << (w - 1)) else code - (1 << w)
    return f32(f32(k) * pow2(-f))

def enc_fp8(v, m=3, bias=7):
    if v == 0.0: return int(is_neg(v)) << 7
    a = f32(abs(v)); unb = floor_log2(a); e_min = 1 - bias
    if unb < e_min:
        q = f32(a / pow2(e_min - m)); t = int(q)
        assert float(q).is_integer() and 1 <= t < (1 << m), v
        return (int(is_neg(v)) << 7) | t
    t = (bits(a) >> (23 - m)) & 0x7
    assert bits(a) & ((1 << (23 - m)) - 1) == 0, v
    return (int(is_neg(v)) << 7) | ((unb + bias) << m) | t

def dec_fp8(code, m=3, bias=7):
    sign = (code >> 7) & 1
    ec = (code >> m) & 0xf
    t = code & 0x7
    if ec == 0:
        if t == 0: return f32(-0.0) if sign else f32(0.0)
        val = f32(f32(t) * pow2(1 - bias - m))
        return f32(-val) if sign else val
    val = f32(f32((1 << m) + t) * pow2(ec - bias - m))
    return f32(-val) if sign else val

# fields: (mant, exp) with value == mant*2^exp exactly
def fld_mxint(code, e, m):
    mag = code & ((1 << m) - 1)
    mant = -mag if (code >> m) & 1 else mag
    return mant, int(np.clip(e + 1 - m, -149, 127))

def fld_bmf(code, bias, m):
    e_min = -(int(pow2(LOCAL_EXP_BITS)) - 1)
    sign = (code >> (LOCAL_EXP_BITS + m + 1)) & 1
    k = code & ((1 << (m + 1)) - 1)
    if k == 0: return 0, 0
    ec = (code >> (m + 1)) & 3
    return (-k if sign else k), int(np.clip(e_min + ec + bias - m, -149, 127))

def fld_bl(code, bias, eb):
    sign = (code >> (eb + 1)) & 1
    c = code & ((1 << (eb + 1)) - 1)
    if c == 0: return 0, 0
    e_min = bias - (int(pow2(eb)) - 1)
    return (-1 if sign else 1), int(np.clip(e_min + c - 1, -149, 127))

def fld_int(code, w, f):
    k = code if code < (1 << (w - 1)) else code - (1 << w)
    return k, int(np.clip(-f, -149, 127))

def fld_fp8(code, m=3, bias=7):
    sign = (code >> 7) & 1
    ec = (code >> m) & 0xf
    t = code & 0x7
    if ec == 0:
        if t == 0: return 0, 0
        return (-t if sign else t), 1 - bias - m
    k = (1 << m) + t
    return (-k if sign else k), ec - bias - m

# ---------------- kernels (mirrors kernels.rs) ---------------------------
MAX_SHIFT = 63

def flush(total, prods):
    if not prods: return total
    emin = min(e for _, e in prods); emax = max(e for _, e in prods)
    if emax - emin <= MAX_SHIFT:
        acc = sum(mm << (e - emin) for mm, e in prods)
        if acc != 0:
            total += np.float64(acc) * np.float64(2.0) ** emin  # exact: |acc|<2^53 path checked
    else:
        for mm, e in prods:
            total += np.float64(mm) * np.float64(2.0) ** emin_pow(e)
    return total

def emin_pow(e): return e  # clarity

def packed_dot(fa, fb):  # lists of (mant, exp) in group order, len%group handled by caller
    total = np.float64(0.0); prods = []
    for i, ((ma, ea), (mb, eb)) in enumerate(zip(fa, fb)):
        if ma != 0 and mb != 0: prods.append((ma * mb, ea + eb))
        if i % 32 == 31: total = flush(total, prods); prods = []
    return flush(total, prods)

def dot_ref_grouped(qa, qb):
    total = np.float64(0.0)
    for g in range(0, len(qa), 32):
        partial = np.float64(0.0)
        for i in range(g, min(g + 32, len(qa))):
            partial += np.float64(qa[i]) * np.float64(qb[i])
        total += partial
    return total

rng = np.random.default_rng(0)
fails = []

def check(name, ok):
    print(("PASS " if ok else "FAIL ") + name)
    if not ok: fails.append(name)

# ============ C1/C2: round trips ============
def roundtrip_block(fmt, qfn, efn, dfn, rows, cols, x, knob):
    q = qfn(x, rows, cols, knob)
    m = resolve_m(knob)
    out = np.empty_like(q)
    for s, blk in blocks(rows, cols):
        e = shared_exponent(maxabs(x, s, cols))
        for i in blk:
            out[i] = dfn(efn(q[i], e, m), e, m)
    return q, out

regimes = {
    "normal": lambda n: rng.normal(size=n).astype(f32),
    "big": lambda n: (rng.normal(size=n) * 1e3).astype(f32),
    "tiny": lambda n: (rng.normal(size=n) * 1e-3).astype(f32),
    "subnormal": lambda n: (rng.normal(size=n) * 1e-41).astype(f32),
    "zeros": lambda n: np.zeros(n, f32),
}
for reg, gen in regimes.items():
    for knob in [1.0, 4.0, 4.9, 7.0, 10.0]:
        rows, cols = 32, 4
        x = gen(rows * cols)
        if len(x) > 3: x[1] = f32(-0.0); x[2] = f32(-1e-7)
        for fmt, qfn, efn, dfn in [("mxint", q_mxint, enc_mxint, dec_mxint),
                                    ("bmf", q_bmf, enc_bmf, dec_bmf),
                                    ("bl", q_bl, enc_bl, dec_bl)]:
            q, out = roundtrip_block(fmt, qfn, efn, dfn, rows, cols, x, knob)
            ok = all(bits(a) == bits(b) for a, b in zip(q, out))
            check(f"C1 {fmt} {reg} knob={knob}", ok)
        # element-wise formats, incl. partial-group lengths
        xi = gen(67)
        if len(xi) > 3: xi[1] = f32(-0.0)
        w = max(int(round(knob)) + 1, 2); fr = 3
        q = q_int(xi, w, fr)
        out = np.array([dec_int(enc_int(v, w, fr), w, fr) for v in q], f32)
        ok = all(bits(a) == bits(b) or (a == 0.0 and b == 0.0) for a, b in zip(q, out))
        check(f"C1 int {reg} w={w}", ok)
        q = q_fp8(xi)
        out = np.array([dec_fp8(enc_fp8(v)) for v in q], f32)
        check(f"C1 fp8 {reg}", all(bits(a) == bits(b) for a, b in zip(q, out)))

# adversarial BMF: binade-bump + top-clamp cases (C2 guard-bit claim)
for trial in range(2000):
    rows, cols = 16, 2
    x = (rng.normal(size=32) * (10.0 ** rng.integers(-40, 35))).astype(f32)
    m = int(rng.integers(1, 13))
    q = q_bmf(x, rows, cols, float(m))
    e = shared_exponent(maxabs(x, 0, cols))
    for i in range(32):
        c = enc_bmf(q[i], e, m)   # asserts k <= 2^(m+1)-1 inside
        assert bits(dec_bmf(c, e, m)) == bits(q[i]), (trial, i)
check("C2 bmf adversarial 2000 blocks bit-exact + guard bit holds", True)

# ============ C3: MXInt dot exact ============
def mxint_fields(x, rows, cols, mb):
    q = q_mxint(x, rows, cols, mb); m = resolve_m(mb)
    fl, qord = [], []
    for s, blk in blocks(rows, cols):
        e = shared_exponent(maxabs(x, s, cols))
        for i in blk:
            fl.append(fld_mxint(enc_mxint(q[i], e, m), e, m)); qord.append(q[i])
    return fl, np.array(qord, f32)

ok = True
for scale, (ma, mb) in [(1.0, (7, 7)), (1e3, (7, 4)), (1e-3, (3, 10)), (1e-40, (2, 2)), (1e20, (8, 8))]:
    rows, cols = 48, 6
    x = (rng.normal(size=rows * cols) * scale).astype(f32)
    y = (rng.normal(size=rows * cols) * scale).astype(f32)
    fa, qa = mxint_fields(x, rows, cols, float(ma))
    fb, qb = mxint_fields(y, rows, cols, float(mb))
    d = packed_dot(fa, fb); r = dot_ref_grouped(qa, qb)
    if struct.pack('<d', d) != struct.pack('<d', r):
        ok = False; print("  mismatch", scale, ma, mb, d, r)
check("C3 mxint packed dot bitwise == f64 block reference (5 scale/prec cases)", ok)

# ============ C4: Int dot exact ============
xi = (rng.normal(size=207) * 3).astype(f32); yi = (rng.normal(size=207) * 3).astype(f32)
w, fr = 8, 4
qa = q_int(xi, w, fr); qb = q_int(yi, w, fr)
fa = [fld_int(enc_int(v, w, fr), w, fr) for v in qa]
fb = [fld_int(enc_int(v, w, fr), w, fr) for v in qb]
d = packed_dot(fa, fb); r = dot_ref_grouped(qa, qb)
check("C4 int packed dot bitwise == reference", struct.pack('<d', d) == struct.pack('<d', r))

# ============ C5: BMF/FP8/BL bound ============
def fields_block(fmt, x, rows, cols, knob):
    m = resolve_m(knob)
    qfn = {"bmf": q_bmf, "bl": q_bl}[fmt]
    efn = {"bmf": enc_bmf, "bl": enc_bl}[fmt]
    ffn = {"bmf": fld_bmf, "bl": fld_bl}[fmt]
    q = qfn(x, rows, cols, knob)
    fl, qord = [], []
    for s, blk in blocks(rows, cols):
        e = shared_exponent(maxabs(x, s, cols))
        for i in blk:
            fl.append(ffn(efn(q[i], e, m), e, m)); qord.append(q[i])
    return fl, np.array(qord, f32)

ok = True
for fmt, knob in [("bmf", 5.0), ("bl", 7.0), ("bl", 3.0)]:
    for scale in [1.0, 1e3, 1e-3, 1e-30]:
        rows, cols = 32, 8
        x = (rng.normal(size=rows * cols) * scale).astype(f32)
        y = rng.normal(size=rows * cols).astype(f32)
        fa, qa = fields_block(fmt, x, rows, cols, knob)
        fb, qb = fields_block(fmt, y, rows, cols, knob)
        d = packed_dot(fa, fb); r = dot_ref_grouped(qa, qb)
        gross = sum(abs(np.float64(a) * np.float64(b)) for a, b in zip(qa, qb))
        bound = len(qa) * 2.0 ** -50 * gross
        if abs(d - r) > bound: ok = False; print("  C5 fail", fmt, knob, scale, d, r, bound)
# fp8
x = rng.normal(size=256).astype(f32); y = rng.normal(size=256).astype(f32)
qa = q_fp8(x); qb = q_fp8(y)
fa = [fld_fp8(enc_fp8(v)) for v in qa]; fb = [fld_fp8(enc_fp8(v)) for v in qb]
d = packed_dot(fa, fb); r = dot_ref_grouped(qa, qb)
gross = sum(abs(np.float64(a) * np.float64(b)) for a, b in zip(qa, qb))
if abs(d - r) > len(qa) * 2.0 ** -50 * gross: ok = False; print("  C5 fp8 fail")
check("C5 bmf/bl/fp8 dot within documented bound", ok)

# ============ C6: GEMM segmented exactness ============
def mx_pack_mat(x, rows, cols, mb):
    q = q_mxint(x.ravel(), rows, cols, mb).reshape(rows, cols)
    m = resolve_m(mb)
    exps = {}
    for s, blk in blocks(rows, cols):
        rb, cb = (s // cols) // 16, (s % cols) // 2
        exps[(rb, cb)] = shared_exponent(maxabs(x.ravel(), s, cols))
    def fld(r, c):
        e = exps[(r // 16, c // 2)]
        return fld_mxint(enc_mxint(q[r, c], e, m), e, m)
    return q, fld

M, K, N = 32, 48, 10
A = rng.normal(size=(M, K)).astype(f32); B = rng.normal(size=(K, N)).astype(f32)
qA, fldA = mx_pack_mat(A, M, K, 7.0)
qB, fldB = mx_pack_mat(B, K, N, 4.0)
ok = True
for i in range(M):
    for j in range(N):
        total = np.float64(0.0); prods = []
        ref = np.float64(0.0)
        for kk in range(0, K, 2):
            for t in range(kk, min(kk + 2, K)):
                ma, ea = fldA(i, t); mb_, eb = fldB(t, j)
                if ma != 0 and mb_ != 0: prods.append((ma * mb_, ea + eb))
            total = flush(total, prods); prods = []
            part = np.float64(0.0)
            for t in range(kk, min(kk + 2, K)):
                part += np.float64(qA[i, t]) * np.float64(qB[t, j])
            ref += part
        if bits(f32(total)) != bits(f32(ref)):
            ok = False; print("  C6 fail", i, j, total, ref)
check("C6 mxint gemm segment datapath bitwise == f64 segmented reference", ok)

# ============ C7 (optional, needs jax): transcription vs ref.py ============
# Cross-check against the L2 jax reference grids. Self-skips when jax is
# unavailable (e.g. the toolchain-free CI job installs only numpy): C1-C6
# carry the load-bearing claims; C7 only pins the transcription to ref.py.
try:
    import os as _os
    sys.path.insert(0, _os.path.join(_os.path.dirname(_os.path.abspath(__file__)), "..", "python"))
    from compile.kernels import ref as R
    import jax.numpy as jnp
    x = (rng.normal(size=(32, 8)) * 2.0).astype(f32)
    pairs = [
        ("mxint", q_mxint(x.ravel(), 32, 8, 5.0), np.array(R.mxint_quantize(jnp.asarray(x), 5.0)).ravel()),
        ("bmf", q_bmf(x.ravel(), 32, 8, 4.0), np.array(R.bmf_quantize(jnp.asarray(x), 4.0)).ravel()),
        ("bl", q_bl(x.ravel(), 32, 8, 6.0), np.array(R.bl_quantize(jnp.asarray(x), 6.0)).ravel()),
        ("int", q_int(x.ravel(), 8, 4), np.array(R.int_quantize(jnp.asarray(x), 8.0, 4.0)).ravel()),
        ("fp8", q_fp8(x.ravel()), np.array(R.minifloat_quantize(jnp.asarray(x))).ravel()),
    ]
    for name, mine, theirs in pairs:
        same = np.array_equal(mine, theirs)
        check(f"C7 {name} transcription == ref.py grid", bool(same))
except ImportError as e:
    print(f"  (C7 skipped: jax/ref.py unavailable here: {e})")

# ====== C8: PR 6 bitwidth-contract closed forms (rust/src/check/contracts.rs) ======
# Toolchain-free mirror of the cross-layer static checker's arithmetic:
# accumulator width (MC023), alignment-shift span (MC024) and tile
# payload bits (MC020) are re-derived here exactly as check::contracts
# re-derives them from formats + packed::layout, so the closed forms
# gate even where cargo is unavailable.

GROUP_ELEMS = 32
BLOCK_SHAPE = (16, 2)
LOCAL_EXP_BITS = 2   # formats::bmf::LOCAL_EXP_BITS
MAX_ALIGN_SHIFT = 63  # packed::kernels::MAX_ALIGN_SHIFT

def c8_elem_bits(fmt, knob):
    # packed::layout::ElemLayout::new element widths
    return {"mxint": 1 + knob, "bmf": 1 + LOCAL_EXP_BITS + knob + 1,
            "bl": 1 + knob + 1, "int": knob, "fp8": 8, "fp32": 32}[fmt]

def c8_tile_payload_bits(fmt, knob, tr, tc):
    # contracts::tile_payload_bits: block formats only; each (16,2) block
    # is one word-aligned 32-element group plus an 8-bit shared exponent
    if fmt not in ("mxint", "bmf", "bl"):
        return None
    eb = c8_elem_bits(fmt, knob)
    blocks = -(-tr // BLOCK_SHAPE[0]) * -(-tc // BLOCK_SHAPE[1])
    group_w = -(-(GROUP_ELEMS * eb) // 64) * 64
    return blocks * (group_w + 8)

def c8_mxint_acc_bits(m):
    # packed::kernels::mxint_acc_bits: 2*(m+1) + ilog2(32) - 1
    return 2 * (m + 1) + 5 - 1

def c8_acc_bits_needed(m):
    # contracts::acc_bits_needed: worst case |prod| = (2^m - 1)^2 per
    # lane, 32 lanes, plus a sign bit
    total = max((2**m - 1) ** 2, 1) * GROUP_ELEMS
    return total.bit_length() + 1

def c8_align_span(fmt, knob):
    # contracts::align_span_bound: worst-case |e_a + e_b| swing of the
    # per-element exponent fields inside one group
    if fmt in ("mxint", "int", "fp32"):
        return 0
    if fmt == "bmf":
        return 2 * (2**LOCAL_EXP_BITS - 1)
    if fmt == "fp8":
        return 28
    return 2 * (2**knob - 1)  # bl

# MC020: payload closed form against known packed-layout values
check("C8 mxint m=4 (16,2) tile payload = 200 bits",
      c8_tile_payload_bits("mxint", 4, 16, 2) == 200)
check("C8 mxint m=4 (8,4) tile payload = 400 bits (2 padded blocks)",
      c8_tile_payload_bits("mxint", 4, 8, 4) == 400)
check("C8 bmf m=2 (16,2) tile payload = 200 bits (6-bit elems)",
      c8_tile_payload_bits("bmf", 2, 16, 2) == 200)
check("C8 element-wise formats have no block payload",
      c8_tile_payload_bits("int", 8, 16, 2) is None)
# MC022: beat count at a finite channel
check("C8 200-bit tile over 64-bit channel = 4 beats",
      -(-c8_tile_payload_bits("mxint", 4, 16, 2) // 64) == 4)
# MC023: the kernel's accumulator closed form covers the worst case for
# every searchable mantissa, and is exact where the search lands
check("C8 acc width sufficient for m in 1..24",
      all(c8_mxint_acc_bits(m) >= c8_acc_bits_needed(m) for m in range(1, 25)))
check("C8 acc width exact at m=4/5/7",
      all(c8_mxint_acc_bits(m) == c8_acc_bits_needed(m) for m in (4, 5, 7)))
# MC024: alignment span vs the aligner's MAX_ALIGN_SHIFT fallback
check("C8 mxint/int never leave the integer aligner",
      c8_align_span("mxint", 7) == 0 and c8_align_span("int", 8) == 0)
check("C8 bmf span = 6, fp8 span = 28 (both within the aligner)",
      c8_align_span("bmf", 4) == 6 and c8_align_span("fp8", 0) == 28
      and 28 <= MAX_ALIGN_SHIFT)
check("C8 bl eb=7 span exceeds MAX_ALIGN_SHIFT (predicts f64 fallback)",
      c8_align_span("bl", 7) > MAX_ALIGN_SHIFT
      and c8_align_span("bl", 5) <= MAX_ALIGN_SHIFT)

# ====== C9: PR 7 GEMV loop restructure (kernels.rs::packed_gemv_tall) ======
# Decode produces m <= 16 activations; the kernel's GEMV path pre-extracts
# A's (mant, exp) fields once and walks j-outer / k-segment-middle /
# i-inner with per-row f64 accumulators. Claim: per output element the
# same products hit the same flush in the same k order, so the result is
# bitwise identical to the general per-(i, j) tiled loop.
Mv, Kv = 16, 48
Av = rng.normal(size=(Mv, Kv)).astype(f32)
qAv, fldAv = mx_pack_mat(Av, Mv, Kv, 7.0)
qBv, fldBv = mx_pack_mat(B[:Kv], Kv, N, 4.0)
general = np.zeros((Mv, N), f32)
for i in range(Mv):
    for j in range(N):
        total = np.float64(0.0)
        prods = []
        for kk in range(0, Kv, 2):
            for t in range(kk, min(kk + 2, Kv)):
                ma, ea = fldAv(i, t); mb_, eb = fldBv(t, j)
                if ma != 0 and mb_ != 0: prods.append((ma * mb_, ea + eb))
            total = flush(total, prods); prods = []
        general[i, j] = f32(total)
af = [[fldAv(i, t) for t in range(Kv)] for i in range(Mv)]  # pre-extracted once
gemv = np.zeros((Mv, N), f32)
for j in range(N):
    acc = [np.float64(0.0) for _ in range(Mv)]
    for kk in range(0, Kv, 2):
        bf = [fldBv(t, j) for t in range(kk, min(kk + 2, Kv))]
        for i in range(Mv):
            prods = []
            for t in range(kk, min(kk + 2, Kv)):
                ma, ea = af[i][t]; mb_, eb = bf[t - kk]
                if ma != 0 and mb_ != 0: prods.append((ma * mb_, ea + eb))
            acc[i] = flush(acc[i], prods)
    for i in range(Mv):
        gemv[i, j] = f32(acc[i])
check("C9 GEMV j-outer/i-inner restructure bitwise == general tiled loop",
      general.tobytes() == gemv.tobytes())

print()
print("ALL PASS" if not fails else f"{len(fails)} FAILURES: {fails}")
sys.exit(1 if fails else 0)
