#!/usr/bin/env python3
"""Toolchain-free mirror of the `.mxa` packed-weight artifact format.

Re-derives the container byte format of rust/src/packed/artifact.rs from
the prose spec alone — FNV-1a/64 hashing, the fixed-width header line,
the hex-integer JSON manifest, 64-byte chunk alignment, and the layout
sizing equations — then checks, with no cargo anywhere:

  1. the FNV-1a/64 implementation against published reference vectors;
  2. writer -> reader round trips of a self-built container across every
     format (including zero-element tensors and element-wise shapes with
     a partial trailing pack group);
  3. fail-closed behaviour: a flipped chunk byte, a truncated file, a
     bumped version and a misaligned chunk must all be rejected, and the
     chunk errors must name the offending tensor;
  4. (optionally) real artifacts written by `mase pack --out x.mxa`:
     pass paths on the command line and every header, manifest field,
     alignment rule, chunk size and chunk hash is re-validated here,
     byte-for-byte, by an implementation that shares no code with the
     Rust one.

Shared conventions mirrored from the Rust side:
  - every integer crosses JSON as a fixed-width 16-digit lowercase hex
    string ({:016x}); f32 format knobs cross as the f64 bit pattern;
  - manifest keys are alphabetical and the rendering is compact, so
    json.dumps(obj, sort_keys=True, separators=(",", ":")) reproduces
    crate::util::json byte-for-byte;
  - the artifact content hash is FNV-1a/64 over the manifest bytes.

numpy is the only dependency (deterministic f32 test data + the
source-hash mirror over little-endian f32 bytes).
"""

import json
import struct
import sys

import numpy as np

# ----------------------------------------------------------- harness --

FAILURES = []


def check(name, ok):
    tag = "ok" if ok else "FAIL"
    print(f"  [{tag}] {name}")
    if not ok:
        FAILURES.append(name)


def expect_raise(name, fn, needle=""):
    try:
        fn()
    except FormatError as e:
        check(f"{name} [{e}]" if needle else name, needle in str(e))
    else:
        check(f"{name} (did not fail)", False)


class FormatError(Exception):
    pass


def fail(msg):
    raise FormatError(msg)


# ------------------------------------------------------------ hashing --

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
MASK64 = (1 << 64) - 1


def fnv1a(data, h=FNV_OFFSET):
    for b in data:
        h = ((h ^ b) * FNV_PRIME) & MASK64
    return h


def source_hash(w):
    """FNV-1a/64 over the little-endian f32 bytes (Rust source_hash)."""
    return fnv1a(np.asarray(w, dtype="<f4").tobytes())


def hex16(v):
    return f"{v & MASK64:016x}"


# ----------------------------------------------- layout sizing mirror --
# Mirrors ElemLayout::new + words_per_group + artifact::expected_sizes.

FORMATS = ["fp32", "int", "fp8", "mxint", "bmf", "bl"]
BLOCK_FORMATS = {"mxint", "bmf", "bl"}
BLOCK_SHAPE = (16, 2)
GROUP_ELEMS = BLOCK_SHAPE[0] * BLOCK_SHAPE[1]
SHARED_EXPONENT_BITS = 8
LOCAL_EXP_BITS = 2  # BMF local minifloat exponent
FP8_EXP_BITS, FP8_MAN_BITS = 4, 3
DEFAULT_BITS = {"fp32": 32.0, "bmf": 5.0, "int": 8.0, "fp8": 8.0, "mxint": 7.0, "bl": 7.0}
MAX_KNOB = {"fp32": 32, "fp8": FP8_MAN_BITS, "int": 25, "mxint": 24, "bmf": 23, "bl": 16}


def resolve_knob(fmt, bits):
    if fmt == "fp32":
        return 32
    if fmt == "fp8":
        return FP8_MAN_BITS
    floor = 2.0 if fmt == "int" else 1.0
    return min(int(max(float(np.round(np.float32(bits))), floor)), MAX_KNOB[fmt])


def elem_bits(fmt, knob):
    return {
        "fp32": 32,
        "fp8": 1 + FP8_EXP_BITS + FP8_MAN_BITS,
        "int": knob,
        "mxint": 1 + knob,
        "bmf": 1 + LOCAL_EXP_BITS + knob + 1,
        "bl": 1 + knob + 1,
    }[fmt]


def layout_for(fmt, bits, frac):
    knob = resolve_knob(fmt, bits)
    return {
        "fmt": fmt,
        "knob": knob,
        "frac": int(np.round(np.float32(frac))) if fmt == "int" else 0,
        "elem_bits": elem_bits(fmt, knob),
        "shared_exp_bits": SHARED_EXPONENT_BITS if fmt in BLOCK_FORMATS else 0,
    }


def words_per_group(eb, n):
    return -(-(n * eb) // 64)  # ceil-div


def expected_sizes(layout, rows, cols):
    """(exps bytes, words count) the layout equations demand."""
    eb = layout["elem_bits"]
    if layout["fmt"] in BLOCK_FORMATS:
        blocks = (rows // BLOCK_SHAPE[0]) * (cols // BLOCK_SHAPE[1])
        return blocks, blocks * words_per_group(eb, GROUP_ELEMS)
    n = rows * cols
    rem = n % GROUP_ELEMS
    tail = words_per_group(eb, rem) if rem else 0
    return 0, (n // GROUP_ELEMS) * words_per_group(eb, GROUP_ELEMS) + tail


# ------------------------------------------------------------- writer --

MAGIC = b"MXA1 "
SCHEMA = "mase-packed-artifact"
VERSION = 1
CHUNK_ALIGN = 64
HEADER_LEN = 22


def f64_bits(x):
    return struct.unpack("<Q", struct.pack("<d", float(np.float32(x))))[0]


def render_manifest(obj):
    """The crate::util::json rendering: compact, alphabetical keys."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":")).encode()


class Writer:
    def __init__(self, model, fmt, bits=None, frac=0.0):
        bits = DEFAULT_BITS[fmt] if bits is None else bits
        self.model, self.fmt, self.bits, self.frac = model, fmt, bits, frac
        self.tensors, self.chunks, self.data = [], [], bytearray()

    def _push_chunk(self, payload):
        pad = -len(self.data) % CHUNK_ALIGN
        self.data += b"\0" * pad
        off = len(self.data)
        self.data += payload
        self.chunks.append({"off": off, "len": len(payload), "fnv": fnv1a(payload)})
        return len(self.chunks) - 1

    def add_tensor(self, name, kind, rows, cols, source, exps, words):
        lay = layout_for(self.fmt, self.bits, self.frac)
        want_exps, want_words = expected_sizes(lay, rows, cols)
        assert len(exps) == want_exps and len(words) == want_words, name
        rec = {
            "name": name,
            "kind": kind,
            "rows": hex16(rows),
            "cols": hex16(cols),
            "layout": {
                "fmt": lay["fmt"],
                "knob": hex16(lay["knob"]),
                "frac": hex16(lay["frac"]),
                "elem_bits": hex16(lay["elem_bits"]),
                "shared_exp_bits": hex16(lay["shared_exp_bits"]),
            },
            "source_hash": hex16(source_hash(source)),
        }
        if lay["fmt"] in BLOCK_FORMATS:
            rec["exps_chunk"] = hex16(self._push_chunk(bytes(exps)))
        rec["words_chunk"] = hex16(self._push_chunk(np.asarray(words, dtype="<u8").tobytes()))
        self.tensors.append(rec)

    def to_bytes(self):
        manifest = render_manifest({
            "schema": SCHEMA,
            "version": hex16(VERSION),
            "model": self.model,
            "format": {
                "kind": self.fmt,
                "bits": hex16(f64_bits(self.bits)),
                "frac": hex16(f64_bits(self.frac)),
            },
            "tensors": self.tensors,
            "chunks": [
                {"off": hex16(c["off"]), "len": hex16(c["len"]), "fnv": hex16(c["fnv"])}
                for c in self.chunks
            ],
        })
        out = MAGIC + hex16(len(manifest)).encode() + b"\n"
        assert len(out) == HEADER_LEN
        out += manifest
        out += b"\0" * (-len(out) % CHUNK_ALIGN)
        return out + bytes(self.data), fnv1a(manifest)


# ------------------------------------------------------------- reader --


def parse_hex(s, what):
    if not (isinstance(s, str) and len(s) == 16):
        fail(f"{what}: not a 16-digit hex string: {s!r}")
    try:
        return int(s, 16)
    except ValueError:
        fail(f"{what}: bad hex {s!r}")


def read_artifact(blob):
    """Full fail-closed validation; returns (content_hash, manifest, tensors)."""
    if len(blob) < HEADER_LEN:
        fail(f"truncated artifact: no {HEADER_LEN}-byte header")
    header = blob[:HEADER_LEN]
    if not (header.startswith(MAGIC) and header.endswith(b"\n")):
        fail("bad artifact magic")
    mlen = parse_hex(header[len(MAGIC) : HEADER_LEN - 1].decode(), "header manifest length")
    if HEADER_LEN + mlen > len(blob):
        fail(f"truncated artifact: manifest claims {mlen} bytes")
    mbytes = blob[HEADER_LEN : HEADER_LEN + mlen]
    content = fnv1a(mbytes)
    try:
        root = json.loads(mbytes)
    except ValueError as e:
        fail(f"unreadable manifest: {e}")
    if render_manifest(root) != mbytes:
        fail("manifest is not in canonical (compact, sorted-key) form")
    if root.get("schema") != SCHEMA:
        fail(f"artifact schema {root.get('schema')!r} is not {SCHEMA!r}")
    if parse_hex(root.get("version", ""), "version") != VERSION:
        fail(f"artifact version {root.get('version')!r} (this mirror reads {VERSION})")
    fspec = root["format"]
    if fspec["kind"] not in FORMATS:
        fail(f"unknown format kind {fspec['kind']!r}")
    data_base = -(-(HEADER_LEN + mlen) // CHUNK_ALIGN) * CHUNK_ALIGN

    chunks = []
    for i, c in enumerate(root.get("chunks", [])):
        off = parse_hex(c["off"], f"chunk {i} off")
        ln = parse_hex(c["len"], f"chunk {i} len")
        fnv = parse_hex(c["fnv"], f"chunk {i} fnv")
        if off % CHUNK_ALIGN:
            fail(f"chunk {i}: offset {off} is not 64-byte aligned")
        if data_base + off + ln > len(blob):
            fail(f"truncated artifact: chunk {i} ends at byte {data_base + off + ln}, "
                 f"file has {len(blob)}")
        chunks.append((off, ln, fnv))

    tensors = {}
    for t in root.get("tensors", []):
        name = t["name"]
        if name in tensors:
            fail(f"duplicate tensor {name!r} in manifest")
        rows = parse_hex(t["rows"], f"tensor {name!r} rows")
        cols = parse_hex(t["cols"], f"tensor {name!r} cols")
        lay = t["layout"]
        fmt = lay["fmt"]
        knob = parse_hex(lay["knob"], f"tensor {name!r} knob")
        frac = parse_hex(lay["frac"], f"tensor {name!r} frac")
        frac -= (1 << 64) if frac >= (1 << 63) else 0  # i64 two's complement
        rebuilt = layout_for(fmt, float(knob), float(frac))
        if (rebuilt["knob"] != knob
                or rebuilt["frac"] != frac
                or parse_hex(lay["elem_bits"], "elem_bits") != rebuilt["elem_bits"]
                or parse_hex(lay["shared_exp_bits"], "seb") != rebuilt["shared_exp_bits"]):
            fail(f"tensor {name!r}: layout record does not match the layout equations")
        want_exps, want_words = expected_sizes(rebuilt, rows, cols)

        def load_chunk(key, want_len):
            ix = parse_hex(t[key], f"tensor {name!r} {key}")
            if ix >= len(chunks):
                fail(f"tensor {name!r}: {key} {ix} out of chunk-table bounds")
            off, ln, want_fnv = chunks[ix]
            if ln != want_len:
                fail(f"tensor {name!r}: {key} holds {ln} bytes, layout demands {want_len}")
            payload = blob[data_base + off : data_base + off + ln]
            if fnv1a(payload) != want_fnv:
                fail(f"corrupt artifact: chunk {ix} (tensor {name!r}) "
                     f"hash {fnv1a(payload):016x} != manifest {want_fnv:016x}")
            return payload

        if fmt in BLOCK_FORMATS:
            if rows % BLOCK_SHAPE[0] or cols % BLOCK_SHAPE[1]:
                fail(f"tensor {name!r}: {rows}x{cols} does not tile into {BLOCK_SHAPE} blocks")
            exps = load_chunk("exps_chunk", want_exps)
        else:
            if "exps_chunk" in t:
                fail(f"tensor {name!r}: element-wise layout with an exps chunk")
            exps = b""
        words = np.frombuffer(load_chunk("words_chunk", want_words * 8), dtype="<u8")
        tensors[name] = {
            "kind": t["kind"],
            "rows": rows,
            "cols": cols,
            "layout": rebuilt,
            "source_hash": parse_hex(t["source_hash"], "source_hash"),
            "exps": exps,
            "words": words,
        }
    return content, root, tensors


# ---------------------------------------------------------- self-test --


def synth_tensor(layout, rows, cols, seed):
    """Deterministic fake payloads of the exact sizes the layout demands."""
    rng = np.random.default_rng(seed)
    want_exps, want_words = expected_sizes(layout, rows, cols)
    source = rng.standard_normal(rows * cols).astype(np.float32)
    exps = rng.integers(0, 256, size=want_exps, dtype=np.uint8).tobytes()
    words = rng.integers(0, 1 << 63, size=want_words, dtype=np.uint64)
    return source, exps, words


def self_test():
    print("== fnv1a reference vectors ==")
    check("fnv1a('') offset basis", fnv1a(b"") == 0xCBF29CE484222325)
    check("fnv1a('a')", fnv1a(b"a") == 0xAF63DC4C8601EC8C)
    check("fnv1a('foobar')", fnv1a(b"foobar") == 0x85944171F73967E8)
    check("incremental == one-shot", fnv1a(b"bar", fnv1a(b"foo")) == fnv1a(b"foobar"))
    check("source_hash is bit-sensitive",
          source_hash([0.0]) != source_hash([-0.0])
          and source_hash([1.0, 2.0]) != source_hash([2.0, 1.0]))

    print("== writer -> reader round trip, every format ==")
    for fmt in FORMATS:
        lay = layout_for(fmt, DEFAULT_BITS[fmt], 0.0)
        shapes = [(32, 4), (0, 2)] if fmt in BLOCK_FORMATS else [(3, 11), (0, 7)]
        w = Writer("rt-model", fmt)
        made = {}
        for i, (r, c) in enumerate(shapes):
            name = f"t{i}"
            source, exps, words = synth_tensor(lay, r, c, seed=100 + i)
            w.add_tensor(name, "weight", r, c, source, exps, words)
            made[name] = (r, c, source_hash(source), exps, words)
        blob, want_hash = w.to_bytes()
        content, root, tensors = read_artifact(blob)
        ok = content == want_hash and root["model"] == "rt-model" and len(tensors) == len(made)
        for name, (r, c, sh, exps, words) in made.items():
            t = tensors[name]
            ok = (ok and t["rows"] == r and t["cols"] == c and t["source_hash"] == sh
                  and bytes(t["exps"]) == exps and np.array_equal(t["words"], words))
        check(f"{fmt}: round trip (shapes {shapes})", ok)
        data_base = -(-(HEADER_LEN + int(blob[5:21], 16)) // CHUNK_ALIGN) * CHUNK_ALIGN
        check(f"{fmt}: data base 64-byte aligned", data_base % 64 == 0)

    print("== fail-closed ==")
    lay = layout_for("mxint", 7.0, 0.0)
    w = Writer("m", "mxint")
    source, exps, words = synth_tensor(lay, 32, 2, seed=7)
    w.add_tensor("layer3.fc1", "weight", 32, 2, source, exps, words)
    blob, _ = w.to_bytes()

    flipped = bytearray(blob)
    flipped[-1] ^= 0x40  # inside the final (words) chunk
    expect_raise("flipped chunk byte names the tensor",
                 lambda: read_artifact(bytes(flipped)), "layer3.fc1")
    expect_raise("truncation mid-chunk", lambda: read_artifact(blob[:-16]), "truncated")
    expect_raise("truncation mid-header", lambda: read_artifact(blob[:10]), "header")

    bumped = blob.replace(b'"version":"' + hex16(VERSION).encode(),
                          b'"version":"' + hex16(VERSION + 1).encode())
    assert bumped != blob
    expect_raise("version bump refused", lambda: read_artifact(bumped), "version")

    bad_schema = blob.replace(SCHEMA.encode(), b"mase-posted-artifact")
    expect_raise("wrong schema refused", lambda: read_artifact(bad_schema), "schema")


# ------------------------------------------- real artifacts (from CI) --


def verify_file(path):
    print(f"== {path} ==")
    with open(path, "rb") as f:
        blob = f.read()
    content, root, tensors = read_artifact(blob)
    n_chunks = len(root["chunks"])
    print(f"  model {root['model']!r}, format {root['format']['kind']}, "
          f"{len(tensors)} tensors, {n_chunks} chunks, content {content:016x}")
    check("at least one tensor", len(tensors) > 0)
    check("every tensor kind is weight|embed",
          all(t["kind"] in ("weight", "embed") for t in tensors.values()))
    # every chunk is referenced exactly once
    refs = []
    for t in root["tensors"]:
        refs.append(int(t["words_chunk"], 16))
        if "exps_chunk" in t:
            refs.append(int(t["exps_chunk"], 16))
    check("chunk table fully referenced, no sharing",
          sorted(refs) == list(range(n_chunks)))
    # a flipped byte in the last chunk must be caught by the mirror too
    flipped = bytearray(blob)
    flipped[-1] ^= 1
    expect_raise("mirror rejects a flipped trailing byte",
                 lambda: read_artifact(bytes(flipped)), "corrupt")


def main():
    self_test()
    for path in sys.argv[1:]:
        verify_file(path)
    if FAILURES:
        print(f"\n{len(FAILURES)} FAILED: {FAILURES}")
        return 1
    print("\nall artifact-format checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
