#!/usr/bin/env python3
"""Toolchain-free mirror of the PR 8 trace subsystem: the dataflow
simulator's beat-model event loop (rust/src/sim/engine.rs), the Chrome
Trace Event exporter (rust/src/obs/chrome.rs) and the `mase-trace` v1
JSONL schema (rust/src/obs/jsonl.rs), kept line-for-line transliterable
with the Rust implementation so both stay debuggable in this container.

Claims checked:
  T1  the python sim mirror + chrome renderer reproduce the committed
      golden trace (rust/tests/golden/fig1_toy_trace.json) byte for
      byte on the Fig. 1 toy fork-join graph — the same bytes the Rust
      golden test (rust/tests/trace_determinism.rs) asserts;
  T2  closed-form firing accounting: per node, the trace holds exactly
      tiles_per_inference * inferences firings whose occupancies sum to
      SimReport.busy, and the last completion equals SimReport.cycles;
  T3  stall attribution: per edge, logged stall intervals sum to
      EdgeReport.transfer_stalled, and only transfer-bound channels are
      ever charged;
  T4  the rendered Chrome JSON is self-consistent: per-PE slice
      durations sum to busy, every stalled edge owns exactly one named
      xfer track, and all events carry the complete/metadata shape;
  T5  (with a file argument) a `mase trace --format jsonl` /
      `--trace FILE` artifact obeys the mase-trace v1 schema: header
      line, 16-digit lowercase hex u64s, (path, seq) sort order,
      per-path contiguous seq, counter deltas that sum to their totals,
      and no wall-clock keys in the stream.

Usage:
  verify_trace_schema.py            run T1-T4 against the golden file
  verify_trace_schema.py --regen    rewrite the golden file, then check
  verify_trace_schema.py FILE.jsonl ...also validate FILE.jsonl (T5)
"""
import math
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(REPO, "rust", "tests", "golden", "fig1_toy_trace.json")

FAILS = []


def check(name, ok, detail=""):
    status = "ok" if ok else "FAIL"
    print(f"  [{status}] {name}" + ("" if ok else f": {detail}"))
    if not ok:
        FAILS.append(name)


# ---------------------------------------------------------------------------
# sim mirror (rust/src/sim/engine.rs::simulate_with)
# ---------------------------------------------------------------------------

EPS = 1e-9


class Node:
    def __init__(self, name, preds, ii, tiles, is_source, out_tile_bits):
        self.name = name
        self.preds = preds
        self.pred_buffer = []
        self.ii = ii
        self.tiles_per_inference = tiles
        self.is_source = is_source
        self.out_tile_bits = out_tile_bits


def toy_nodes():
    # the Fig. 1 toy fork-join graph — mirrored line-for-line in
    # rust/src/obs/chrome.rs and rust/tests/trace_determinism.rs
    return [
        Node("src", [], 1, 8, True, 256),
        Node("a", [0], 2, 8, False, 128),
        Node("b", [0], 3, 8, False, 128),
        Node("join", [1, 2], 1, 8, False, 0),
    ]


TOY_CFG = dict(inferences=2, fifo_depth=2, sequential=False, channel_bits=32)


def simulate_traced(nodes, cfg):
    """Mirror of simulate_with(nodes, cfg, Some(trace)). All channel
    fractions here are dyadic rationals (1/8, exact in binary floating
    point), so the python f64 arithmetic is bit-identical to Rust's."""
    n = len(nodes)
    fifo = [[0.0] * len(nd.preds) for nd in nodes]

    def beats(i):
        if cfg["channel_bits"] == 0 or nodes[i].out_tile_bits == 0:
            return 1
        return -(-nodes[i].out_tile_bits // cfg["channel_bits"])  # div_ceil

    def occupancy(i):
        return max(nodes[i].ii, beats(i))

    def transfer_bound(i):
        return beats(i) > nodes[i].ii

    edges = []  # dicts mirroring EdgeReport
    edge_of = [[] for _ in range(n)]
    succs = [[] for _ in range(n)]
    for i, nd in enumerate(nodes):
        for slot, p in enumerate(nd.preds):
            e = len(edges)
            edges.append(
                dict(
                    producer=p,
                    consumer=i,
                    slot=slot,
                    tile_bits=nodes[p].out_tile_bits,
                    beats_per_tile=beats(p),
                    transfer_cycles=0,
                    transfer_stalled=0,
                )
            )
            edge_of[i].append(e)
            succs[p].append((i, slot, e))

    def frac(i):
        return 1.0 / max(nodes[i].tiles_per_inference, 1)

    def cap(p, c, slot):
        buf = nodes[c].pred_buffer[slot] if slot < len(nodes[c].pred_buffer) else 0.0
        return cfg["fifo_depth"] * max(frac(p), frac(c)) + buf

    total_tiles = [nd.tiles_per_inference * cfg["inferences"] for nd in nodes]
    emitted = [0] * n
    busy_until = [0] * n
    busy = [0] * n
    stalled = [0] * n
    firings = []  # (node, t, occupancy)
    stall_log = []  # (edge, t, dt)

    t = 0
    while not all(e >= tt for e, tt in zip(emitted, total_tiles)):
        one_busy = any(b > t for b in busy_until)
        fired_any = False
        blocked = [False] * n
        edge_charged = [False] * len(edges)
        for i in range(n):
            if emitted[i] >= total_tiles[i] or busy_until[i] > t:
                continue
            if cfg["sequential"] and one_busy:
                continue
            need = frac(i)
            inputs_ok = nodes[i].is_source or all(q + EPS >= need for q in fifo[i])
            outputs_ok = all(
                emitted[c] >= total_tiles[c] or fifo[c][slot] + frac(i) <= cap(i, c, slot) + EPS
                for (c, slot, _e) in succs[i]
            )
            if inputs_ok and outputs_ok:
                if not nodes[i].is_source:
                    for slot in range(len(fifo[i])):
                        fifo[i][slot] -= need
                occ = occupancy(i)
                busy_until[i] = t + occ
                busy[i] += occ
                emitted[i] += 1
                firings.append((i, t, occ))
                for (c, slot, e) in succs[i]:
                    fifo[c][slot] += frac(i)
                    edges[e]["transfer_cycles"] += edges[e]["beats_per_tile"]
                fired_any = True
                if cfg["sequential"]:
                    break
            elif inputs_ok or outputs_ok:
                def starved(q):
                    return q + EPS < need

                channel_fault = (not inputs_ok) and all(
                    (not starved(q))
                    or (transfer_bound(nodes[i].preds[slot]) and busy_until[nodes[i].preds[slot]] > t)
                    for slot, q in enumerate(fifo[i])
                )
                if channel_fault:
                    for slot, q in enumerate(fifo[i]):
                        if starved(q):
                            edge_charged[edge_of[i][slot]] = True
                else:
                    blocked[i] = True
        if fired_any:
            dt = 1
        else:
            pending = [b for b in busy_until if b > t]
            if not pending:
                raise RuntimeError(f"dataflow deadlock at t={t}")
            dt = min(pending) - t
        for i in range(n):
            if blocked[i]:
                stalled[i] += dt
        for e, charged in enumerate(edge_charged):
            if charged:
                edges[e]["transfer_stalled"] += dt
                stall_log.append((e, t, dt))
        t += dt
    cycles = max(max(busy_until, default=t), t)
    report = dict(cycles=cycles, busy=busy, stalled=stalled, edges=edges)
    trace = dict(firings=firings, stalls=stall_log)
    return report, trace


# ---------------------------------------------------------------------------
# chrome renderer mirror (rust/src/obs/chrome.rs::sim_chrome_json)
# + compact printer mirror (rust/src/util/json.rs::Display)
# ---------------------------------------------------------------------------


def jstr(s):
    out = ['"']
    for c in s:
        if c == '"':
            out.append('\\"')
        elif c == "\\":
            out.append("\\\\")
        elif c == "\n":
            out.append("\\n")
        elif c == "\t":
            out.append("\\t")
        elif c == "\r":
            out.append("\\r")
        elif ord(c) < 0x20:
            out.append("\\u%04x" % ord(c))
        else:
            out.append(c)
    out.append('"')
    return "".join(out)


def jdump(v):
    """Compact printer matching util::json::Json::Display: sorted object
    keys, no whitespace, whole numbers printed as integers."""
    if isinstance(v, bool):
        return "true" if v else "false"
    if v is None:
        return "null"
    if isinstance(v, (int, float)):
        f = float(v)
        if f == math.floor(f) and abs(f) < 1e15:
            return str(int(f))
        return repr(f)
    if isinstance(v, str):
        return jstr(v)
    if isinstance(v, list):
        return "[" + ",".join(jdump(x) for x in v) + "]"
    if isinstance(v, dict):
        return "{" + ",".join(f"{jstr(k)}:{jdump(v[k])}" for k in sorted(v)) + "}"
    raise TypeError(type(v))


def thread_name(tid, name):
    return {"args": {"name": name}, "name": "thread_name", "ph": "M", "pid": 0, "tid": tid}


def complete(name, cat, ts, dur, tid):
    return {"cat": cat, "dur": dur, "name": name, "ph": "X", "pid": 0, "tid": tid, "ts": ts}


def sim_chrome_json(nodes, report, trace):
    events = [thread_name(i, nd.name) for i, nd in enumerate(nodes)]
    edge_tid = {}
    for e, edge in enumerate(report["edges"]):
        if edge["transfer_stalled"] > 0:
            tid = len(nodes) + len(edge_tid)
            edge_tid[e] = tid
            label = f"xfer:{nodes[edge['producer']].name}->{nodes[edge['consumer']].name}"
            events.append(thread_name(tid, label))
    for (node, t, occ) in trace["firings"]:
        events.append(complete(nodes[node].name, "firing", t, occ, node))
    for (e, t, dt) in trace["stalls"]:
        if e in edge_tid:
            events.append(complete("transfer_stalled", "stall", t, dt, edge_tid[e]))
    return {"displayTimeUnit": "ns", "traceEvents": events}


def render_golden():
    nodes = toy_nodes()
    report, trace = simulate_traced(nodes, TOY_CFG)
    return nodes, report, trace, jdump(sim_chrome_json(nodes, report, trace)) + "\n"


# ---------------------------------------------------------------------------
# T1-T4
# ---------------------------------------------------------------------------


def t1_golden(regen):
    nodes, report, trace, text = render_golden()
    if regen:
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "w") as f:
            f.write(text)
        print(f"  regenerated {os.path.relpath(GOLDEN, REPO)} ({len(text)} bytes)")
    if not os.path.exists(GOLDEN):
        check("T1 golden file exists", False, f"{GOLDEN} missing — run with --regen")
        return nodes, report, trace, text
    committed = open(GOLDEN).read()
    check("T1 mirror reproduces committed golden byte-for-byte", committed == text,
          f"{len(committed)} vs {len(text)} bytes")
    return nodes, report, trace, text


def t2_firings(nodes, report, trace):
    for i, nd in enumerate(nodes):
        fires = [(t, occ) for (node, t, occ) in trace["firings"] if node == i]
        want = nd.tiles_per_inference * TOY_CFG["inferences"]
        check(f"T2 {nd.name}: firing count == tiles*inferences", len(fires) == want,
              f"{len(fires)} vs {want}")
        check(f"T2 {nd.name}: occupancy sum == busy", sum(o for _, o in fires) == report["busy"][i],
              f"{sum(o for _, o in fires)} vs {report['busy'][i]}")
    end = max(t + occ for (_n, t, occ) in trace["firings"])
    check("T2 last completion == cycles", end == report["cycles"],
          f"{end} vs {report['cycles']}")


def t3_stalls(nodes, report, trace):
    for e, edge in enumerate(report["edges"]):
        logged = sum(dt for (ee, _t, dt) in trace["stalls"] if ee == e)
        check(f"T3 edge {e}: stall intervals sum to transfer_stalled",
              logged == edge["transfer_stalled"], f"{logged} vs {edge['transfer_stalled']}")
        if edge["transfer_stalled"] > 0:
            p = edge["producer"]
            bound = edge["beats_per_tile"] > nodes[p].ii
            check(f"T3 edge {e}: only transfer-bound channels charged", bound,
                  f"producer {nodes[p].name} ii={nodes[p].ii} beats={edge['beats_per_tile']}")
    check("T3 starved 32b fabric logs stalls", len(trace["stalls"]) > 0)


def t4_chrome(nodes, report, trace):
    j = sim_chrome_json(nodes, report, trace)
    events = j["traceEvents"]
    for i in range(len(nodes)):
        dur = sum(e["dur"] for e in events
                  if e["ph"] == "X" and e.get("cat") == "firing" and e["tid"] == i)
        check(f"T4 PE {nodes[i].name}: slice durations sum to busy", dur == report["busy"][i],
              f"{dur} vs {report['busy'][i]}")
    stalled_edges = sum(1 for e in report["edges"] if e["transfer_stalled"] > 0)
    xfer_tracks = sum(1 for e in events
                      if e["ph"] == "M" and e["args"]["name"].startswith("xfer:"))
    check("T4 one xfer track per stalled edge", stalled_edges == xfer_tracks,
          f"{stalled_edges} vs {xfer_tracks}")
    shapes_ok = all(
        (e["ph"] == "M" and set(e) == {"args", "name", "ph", "pid", "tid"})
        or (e["ph"] == "X" and set(e) == {"cat", "dur", "name", "ph", "pid", "tid", "ts"})
        for e in events
    )
    check("T4 every event is a metadata or complete record", shapes_ok)


# ---------------------------------------------------------------------------
# T5: mase-trace v1 JSONL schema validation
# ---------------------------------------------------------------------------

HEX16 = re.compile(r"^[0-9a-f]{16}$")


def parse_json_line(line, lineno):
    import json

    try:
        return json.loads(line)
    except ValueError as e:
        check(f"T5 line {lineno} parses", False, str(e))
        return None


def t5_jsonl(path):
    lines = open(path).read().splitlines()
    check("T5 header line", bool(lines) and lines[0] == '{"schema":"mase-trace","version":1}',
          lines[0] if lines else "<empty>")
    events = []  # (path, seq, obj)
    totals = {}
    sums = {}
    in_totals = False
    for ln, line in enumerate(lines[1:], start=2):
        o = parse_json_line(line, ln)
        if o is None:
            continue
        kind = o.get("kind")
        if kind == "total":
            in_totals = True
            ok = set(o) == {"kind", "name", "path", "value"} and HEX16.match(o["value"])
            check(f"T5 line {ln}: total shape", bool(ok), line)
            totals[(o["path"], o["name"])] = int(o["value"], 16)
            continue
        check(f"T5 line {ln}: events precede totals", not in_totals, line)
        if kind == "span":
            ok = set(o) == {"kind", "path", "seq", "tags"} and HEX16.match(o["seq"])
        elif kind == "counter":
            ok = (set(o) == {"delta", "kind", "name", "path", "seq"}
                  and HEX16.match(o["seq"]) and HEX16.match(o["delta"]))
            key = (o["path"], o["name"])
            sums[key] = sums.get(key, 0) + int(o["delta"], 16)
        else:
            ok = False
        check(f"T5 line {ln}: event shape ({kind})", bool(ok), line)
        events.append((o["path"], int(o["seq"], 16)))
        check(f"T5 line {ln}: no wall-clock keys", "wall" not in o and "secs" not in o, line)
    keys = [(p, s) for (p, s) in events]
    check("T5 events sorted by (path, seq)", keys == sorted(keys))
    by_path = {}
    for p, s in events:
        by_path.setdefault(p, []).append(s)
    contiguous = all(seqs == list(range(len(seqs))) for seqs in by_path.values())
    check("T5 per-path seq is contiguous from 0", contiguous,
          str({p: s[:6] for p, s in by_path.items() if s != list(range(len(s)))}))
    check("T5 counter deltas sum to totals", sums == totals,
          f"sums={sums} totals={totals}")
    print(f"  validated {len(lines)} lines: {len(events)} events, {len(totals)} totals")


# ---------------------------------------------------------------------------


def main(argv):
    regen = "--regen" in argv
    jsonl_files = [a for a in argv if not a.startswith("--")]
    print("verify_trace_schema: Fig. 1 toy fork-join graph, "
          f"cfg={TOY_CFG}")
    nodes, report, trace, _text = t1_golden(regen)
    t2_firings(nodes, report, trace)
    t3_stalls(nodes, report, trace)
    t4_chrome(nodes, report, trace)
    for f in jsonl_files:
        print(f"  -- validating {f}")
        t5_jsonl(f)
    print()
    if FAILS:
        print(f"FAILED ({len(FAILS)}): " + ", ".join(FAILS[:10]))
        return 1
    print("verify_trace_schema: ALL CHECKS PASSED")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
