#!/usr/bin/env bash
# One-shot CI gate for MASE-RS: format check, lints, then the tier-1
# verify (build + tests). Run from anywhere; operates on rust/.
#
#   scripts/ci.sh            # everything
#   SKIP_LINTS=1 scripts/ci.sh   # tier-1 only (e.g. toolchain w/o clippy)
#
# Lint policy: `cargo clippy -- -D warnings` with a small documented
# allowlist (below) instead of per-line attributes, so the codebase stays
# annotation-free while the gate stays strict.

set -euo pipefail
cd "$(dirname "$0")/../rust"

# Allowlist rationale:
#  - too_many_arguments: ModelMeta::synthetic mirrors the python manifest
#    generator's positional signature on purpose (drift is caught by the
#    manifest round-trip test, and a builder would hide that symmetry).
#  - needless_range_loop: index loops in the formats/sim hot paths mirror
#    the emitted hardware's addressing; iterator rewrites obscure that.
CLIPPY_ALLOW=(
  -A clippy::too_many_arguments
  -A clippy::needless_range_loop
)

if [[ -z "${SKIP_LINTS:-}" ]]; then
  echo "==> cargo fmt --check"
  if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
  else
    echo "  (rustfmt not installed; skipping format check)"
  fi

  echo "==> cargo clippy -- -D warnings ($(( ${#CLIPPY_ALLOW[@]} / 2 )) allowlisted lints)"
  if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings "${CLIPPY_ALLOW[@]}"
  else
    echo "  (clippy not installed; skipping lints)"
  fi

  # Docs gate: rustdoc warnings (broken intra-doc links, bad code fences,
  # missing docs where required) are errors, so the architecture docs in
  # lib.rs and the module headers cannot rot silently.
  echo "==> cargo doc --no-deps (rustdoc warnings are errors)"
  RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
fi

# Bench/example targets are plain binaries that tier-1 never builds;
# type-check them so APIs they exercise (e.g. packed::layout in the
# table1/fig5 benches) cannot rot silently.
echo "==> cargo check --benches --examples"
cargo check --benches --examples

echo "==> tier-1 verify: cargo build --release && cargo test -q"
cargo build --release
cargo test -q

# Artifact-free CPU-backend smoke: the packed-arithmetic interpreter path
# must stay executable end to end (search -> evaluate -> emit) on a host
# with no PJRT artifacts, so every gate exercises `--backend cpu`.
echo "==> cpu-backend smoke: mase e2e --backend cpu (artifact-free)"
SMOKE_DIR="$(mktemp -d)"
trap 'rm -rf "$SMOKE_DIR"' EXIT
./target/release/mase e2e --backend cpu --model toy-sim --task sst2 \
  --trials 4 --batch 2 --eval-batches 1 --threads 1 \
  --artifacts "$SMOKE_DIR/artifacts" --out "$SMOKE_DIR/design"
test -n "$(ls "$SMOKE_DIR/design" 2>/dev/null)" || {
  echo "cpu-backend smoke emitted no design files"; exit 1;
}

echo "CI gate passed."
