#!/usr/bin/env bash
# CI gate for MASE-RS, split into selectable stages so the GitHub
# workflow can fan them out as matrix jobs and developers can run one
# stage locally. Run from anywhere; operates on rust/.
#
#   scripts/ci.sh                # all stages (the classic one-shot gate)
#   scripts/ci.sh all            # same
#   scripts/ci.sh fmt            # rustfmt check only
#   scripts/ci.sh clippy         # clippy -D warnings (with allowlist)
#   scripts/ci.sh doc            # rustdoc gate (warnings are errors)
#   scripts/ci.sh test           # bench/example check + tier-1 build+test
#   scripts/ci.sh smoke          # artifact-free cpu-backend e2e smoke
#   scripts/ci.sh decode         # KV-cached `mase generate` smoke
#   scripts/ci.sh check          # `mase check` static analysis on an
#                                # artifact-free emitted design
#   scripts/ci.sh trace          # `mase trace` export smoke + traced e2e
#                                # + JSONL schema validation (PR 8)
#   scripts/ci.sh fmt clippy     # any combination, run in order given
#
#   SKIP_LINTS=1 scripts/ci.sh   # `all` minus fmt/clippy/doc (e.g. a
#                                # toolchain without clippy/rustfmt)
#
# Lint policy: `cargo clippy -- -D warnings` with a small documented
# allowlist (below) instead of per-line attributes, so the codebase stays
# annotation-free while the gate stays strict.

set -euo pipefail
cd "$(dirname "$0")/../rust"

# smoke-stage scratch space, cleaned on ANY exit (incl. failures — a
# RETURN trap would not fire when set -e aborts mid-stage)
SMOKE_DIR=""
cleanup() { [[ -n "$SMOKE_DIR" ]] && rm -rf "$SMOKE_DIR" || true; }
trap cleanup EXIT

# Allowlist rationale:
#  - too_many_arguments: ModelMeta::synthetic mirrors the python manifest
#    generator's positional signature on purpose (drift is caught by the
#    manifest round-trip test, and a builder would hide that symmetry).
#  - needless_range_loop: index loops in the formats/sim hot paths mirror
#    the emitted hardware's addressing; iterator rewrites obscure that.
#  - collapsible_if: check/sv.rs mirrors scripts/verify_sv_check.py
#    line-for-line (the toolchain-free reference analyzer); collapsing
#    its nested if-lets would break that correspondence.
CLIPPY_ALLOW=(
  -A clippy::too_many_arguments
  -A clippy::needless_range_loop
  -A clippy::collapsible_if
)

stage_fmt() {
  echo "==> cargo fmt --check"
  if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
  else
    echo "  (rustfmt not installed; skipping format check)"
  fi
}

stage_clippy() {
  echo "==> cargo clippy -- -D warnings ($(( ${#CLIPPY_ALLOW[@]} / 2 )) allowlisted lints)"
  if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings "${CLIPPY_ALLOW[@]}"
  else
    echo "  (clippy not installed; skipping lints)"
  fi
}

stage_doc() {
  # Docs gate: rustdoc warnings (broken intra-doc links, bad code fences,
  # missing docs where required) are errors, so the architecture docs in
  # lib.rs and the module headers cannot rot silently.
  echo "==> cargo doc --no-deps (rustdoc warnings are errors)"
  RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
}

stage_test() {
  # Bench/example targets are plain binaries that tier-1 never builds;
  # type-check them so APIs they exercise (e.g. packed::layout in the
  # table1/fig5 benches) cannot rot silently.
  echo "==> cargo check --benches --examples"
  cargo check --benches --examples

  echo "==> tier-1 verify: cargo build --release && cargo test -q"
  cargo build --release
  cargo test -q
}

stage_smoke() {
  # Artifact-free CPU-backend smoke: the packed-arithmetic interpreter
  # path must stay executable end to end (search -> evaluate -> emit) on
  # a host with no PJRT artifacts, so every gate exercises --backend cpu.
  echo "==> cpu-backend smoke: mase e2e --backend cpu (artifact-free)"
  if [[ ! -x target/release/mase ]]; then
    echo "  (target/release/mase missing; building first)"
    cargo build --release
  fi
  SMOKE_DIR="$(mktemp -d)"
  ./target/release/mase e2e --backend cpu --model toy-sim --task sst2 \
    --trials 4 --batch 2 --eval-batches 1 --threads 1 \
    --artifacts "$SMOKE_DIR/artifacts" --out "$SMOKE_DIR/design"
  test -n "$(ls "$SMOKE_DIR/design" 2>/dev/null)" || {
    echo "cpu-backend smoke emitted no design files"; exit 1;
  }
}

stage_decode() {
  # Autoregressive-decode smoke (PR 7): greedy KV-cached generation on
  # the toy LM must produce exactly the requested token count with
  # finite logits. The binary itself hard-fails on a count mismatch or a
  # non-finite loss; the greps below also pin the report format so the
  # counters cannot silently vanish from the output.
  echo "==> decode smoke: mase generate --backend cpu --model toy-lm"
  if [[ ! -x target/release/mase ]]; then
    echo "  (target/release/mase missing; building first)"
    cargo build --release
  fi
  cleanup
  SMOKE_DIR="$(mktemp -d)"
  local out
  out="$(./target/release/mase generate --backend cpu --model toy-lm \
    --tokens 8 --prompt-len 4 --threads 1 --artifacts "$SMOKE_DIR/artifacts")"
  echo "$out"
  echo "$out" | grep -q "decode ok: 128 tokens across 16 seqs" || {
    echo "decode smoke: expected 16 seqs x 8 tokens = 128 generated tokens"; exit 1;
  }
  echo "$out" | grep -Eq "loss [0-9]+\.[0-9]+" || {
    echo "decode smoke: loss is not a finite number"; exit 1;
  }
  echo "$out" | grep -q "cached score dots over 8 steps" || {
    echo "decode smoke: counted-attention report line missing"; exit 1;
  }
}

stage_check() {
  # Static-analysis gate: `mase check` emits a design in memory for a
  # synthetic model (artifact-free) and runs the real SV analyzer plus
  # the cross-layer bitwidth contracts over it — the same check::
  # entry point the emit pass gates on. Nonzero exit on any error-level
  # MC0xx diagnostic. A second invocation covers the known-bad corpus
  # path via --sv to prove the analyzer still fires.
  echo "==> static analysis: mase check (artifact-free emitted design)"
  if [[ ! -x target/release/mase ]]; then
    echo "  (target/release/mase missing; building first)"
    cargo build --release
  fi
  cleanup  # reclaim the smoke stage's scratch dir before making our own
  SMOKE_DIR="$(mktemp -d)"
  ./target/release/mase check --artifacts "$SMOKE_DIR/artifacts"
  ./target/release/mase check --artifacts "$SMOKE_DIR/artifacts" --fmt int --bits 8
  if ./target/release/mase check --sv tests/corpus/bad_undeclared.sv \
      >/dev/null 2>&1; then
    echo "mase check failed to flag the known-bad corpus"; exit 1
  fi
}

stage_trace() {
  # Observability gate (PR 8): `mase trace` simulates a synthetic design
  # artifact-free and exports both trace formats; a traced e2e run must
  # print the shared summary block and write a schema-valid JSONL; the
  # toolchain-free python mirror re-derives the sim's closed-form
  # accounting and validates every JSONL artifact (stdlib only).
  echo "==> trace smoke: mase trace exports + traced e2e + schema validation"
  if [[ ! -x target/release/mase ]]; then
    echo "  (target/release/mase missing; building first)"
    cargo build --release
  fi
  cleanup
  SMOKE_DIR="$(mktemp -d)"
  local out
  out="$(./target/release/mase trace --artifacts "$SMOKE_DIR/artifacts" \
    --chan 32 --out "$SMOKE_DIR/sim_trace.json")"
  echo "$out"
  echo "$out" | grep -q "trace written to" || {
    echo "trace smoke: chrome export missing"; exit 1;
  }
  grep -q '"traceEvents"' "$SMOKE_DIR/sim_trace.json" || {
    echo "trace smoke: chrome file lacks traceEvents"; exit 1;
  }
  ./target/release/mase trace --artifacts "$SMOKE_DIR/artifacts" --chan 32 \
    --trace-format jsonl --out "$SMOKE_DIR/sim_trace.jsonl" >/dev/null
  out="$(./target/release/mase trace --run e2e --backend cpu --model toy-sim \
    --task sst2 --trials 4 --batch 2 --eval-batches 1 --threads 1 \
    --artifacts "$SMOKE_DIR/artifacts" --out "$SMOKE_DIR/design" \
    --trace "$SMOKE_DIR/e2e_trace.jsonl")"
  echo "$out" | grep -q "== trace summary ==" || {
    echo "trace smoke: traced e2e did not print the summary block"; exit 1;
  }
  echo "$out" | grep -q "search/trial" || {
    echo "trace smoke: per-trial spans missing from the summary"; exit 1;
  }
  python3 ../scripts/verify_trace_schema.py \
    "$SMOKE_DIR/sim_trace.jsonl" "$SMOKE_DIR/e2e_trace.jsonl"
}

run_stage() {
  case "$1" in
    fmt)    stage_fmt ;;
    clippy) stage_clippy ;;
    doc)    stage_doc ;;
    test)   stage_test ;;
    smoke)  stage_smoke ;;
    decode) stage_decode ;;
    check)  stage_check ;;
    trace)  stage_trace ;;
    all)
      if [[ -z "${SKIP_LINTS:-}" ]]; then
        stage_fmt
        stage_clippy
        stage_doc
      fi
      stage_test
      stage_smoke
      stage_decode
      stage_check
      stage_trace
      ;;
    *)
      echo "unknown stage '$1' (expected fmt|clippy|doc|test|smoke|decode|check|trace|all)" >&2
      exit 2
      ;;
  esac
}

if [[ $# -eq 0 ]]; then
  run_stage all
else
  for stage in "$@"; do
    run_stage "$stage"
  done
fi

echo "CI gate passed."
