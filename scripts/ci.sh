#!/usr/bin/env bash
# CI gate for MASE-RS, split into selectable stages so the GitHub
# workflow can fan them out as matrix jobs and developers can run one
# stage locally. Run from anywhere; operates on rust/.
#
#   scripts/ci.sh                # all stages (the classic one-shot gate)
#   scripts/ci.sh all            # same
#   scripts/ci.sh fmt            # rustfmt check only
#   scripts/ci.sh clippy         # clippy -D warnings (with allowlist)
#   scripts/ci.sh doc            # rustdoc gate (warnings are errors)
#   scripts/ci.sh test           # bench/example check + tier-1 build+test
#   scripts/ci.sh smoke          # artifact-free cpu-backend e2e smoke
#   scripts/ci.sh decode         # KV-cached `mase generate` smoke
#   scripts/ci.sh check          # `mase check` static analysis on an
#                                # artifact-free emitted design
#   scripts/ci.sh trace          # `mase trace` export smoke + traced e2e
#                                # + JSONL schema validation (PR 8)
#   scripts/ci.sh serve          # `mase serve` HTTP smoke: ephemeral
#                                # port, raw-socket client, SIGTERM (PR 9)
#   scripts/ci.sh artifact       # `.mxa` packed-weight artifact smoke:
#                                # pack -> --weights warm start with zero
#                                # re-pack, bit-identical output, fail-
#                                # closed corruption, python mirror
#   scripts/ci.sh fmt clippy     # any combination, run in order given
#
#   SKIP_LINTS=1 scripts/ci.sh   # `all` minus fmt/clippy/doc (e.g. a
#                                # toolchain without clippy/rustfmt)
#
# Lint policy: `cargo clippy -- -D warnings` with a small documented
# allowlist (below) instead of per-line attributes, so the codebase stays
# annotation-free while the gate stays strict.

set -euo pipefail
cd "$(dirname "$0")/../rust"

# smoke-stage scratch space, cleaned on ANY exit (incl. failures — a
# RETURN trap would not fire when set -e aborts mid-stage). The serve
# stage also parks its background server PID here so a failed assertion
# can never leak a listener.
SMOKE_DIR=""
SERVE_PID=""
cleanup() {
  if [[ -n "$SERVE_PID" ]]; then
    kill "$SERVE_PID" 2>/dev/null || true
    wait "$SERVE_PID" 2>/dev/null || true
    SERVE_PID=""
  fi
  [[ -n "$SMOKE_DIR" ]] && rm -rf "$SMOKE_DIR" || true
}
trap cleanup EXIT

# Allowlist rationale:
#  - too_many_arguments: ModelMeta::synthetic mirrors the python manifest
#    generator's positional signature on purpose (drift is caught by the
#    manifest round-trip test, and a builder would hide that symmetry).
#  - needless_range_loop: index loops in the formats/sim hot paths mirror
#    the emitted hardware's addressing; iterator rewrites obscure that.
#  - collapsible_if: check/sv.rs mirrors scripts/verify_sv_check.py
#    line-for-line (the toolchain-free reference analyzer); collapsing
#    its nested if-lets would break that correspondence.
CLIPPY_ALLOW=(
  -A clippy::too_many_arguments
  -A clippy::needless_range_loop
  -A clippy::collapsible_if
)

stage_fmt() {
  echo "==> cargo fmt --check"
  if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
  else
    echo "  (rustfmt not installed; skipping format check)"
  fi
}

stage_clippy() {
  echo "==> cargo clippy -- -D warnings ($(( ${#CLIPPY_ALLOW[@]} / 2 )) allowlisted lints)"
  if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings "${CLIPPY_ALLOW[@]}"
  else
    echo "  (clippy not installed; skipping lints)"
  fi
}

stage_doc() {
  # Docs gate: rustdoc warnings (broken intra-doc links, bad code fences,
  # missing docs where required) are errors, so the architecture docs in
  # lib.rs and the module headers cannot rot silently.
  echo "==> cargo doc --no-deps (rustdoc warnings are errors)"
  RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
}

stage_test() {
  # Bench/example targets are plain binaries that tier-1 never builds;
  # type-check them so APIs they exercise (e.g. packed::layout in the
  # table1/fig5 benches) cannot rot silently.
  echo "==> cargo check --benches --examples"
  cargo check --benches --examples

  echo "==> tier-1 verify: cargo build --release && cargo test -q"
  cargo build --release
  cargo test -q
}

stage_smoke() {
  # Artifact-free CPU-backend smoke: the packed-arithmetic interpreter
  # path must stay executable end to end (search -> evaluate -> emit) on
  # a host with no PJRT artifacts, so every gate exercises --backend cpu.
  echo "==> cpu-backend smoke: mase e2e --backend cpu (artifact-free)"
  if [[ ! -x target/release/mase ]]; then
    echo "  (target/release/mase missing; building first)"
    cargo build --release
  fi
  SMOKE_DIR="$(mktemp -d)"
  ./target/release/mase e2e --backend cpu --model toy-sim --task sst2 \
    --trials 4 --batch 2 --eval-batches 1 --threads 1 \
    --artifacts "$SMOKE_DIR/artifacts" --out "$SMOKE_DIR/design"
  test -n "$(ls "$SMOKE_DIR/design" 2>/dev/null)" || {
    echo "cpu-backend smoke emitted no design files"; exit 1;
  }
}

stage_decode() {
  # Autoregressive-decode smoke (PR 7): greedy KV-cached generation on
  # the toy LM must produce exactly the requested token count with
  # finite logits. The binary itself hard-fails on a count mismatch or a
  # non-finite loss; the greps below also pin the report format so the
  # counters cannot silently vanish from the output.
  echo "==> decode smoke: mase generate --backend cpu --model toy-lm"
  if [[ ! -x target/release/mase ]]; then
    echo "  (target/release/mase missing; building first)"
    cargo build --release
  fi
  cleanup
  SMOKE_DIR="$(mktemp -d)"
  local out
  out="$(./target/release/mase generate --backend cpu --model toy-lm \
    --tokens 8 --prompt-len 4 --threads 1 --artifacts "$SMOKE_DIR/artifacts")"
  echo "$out"
  echo "$out" | grep -q "decode ok: 128 tokens across 16 seqs" || {
    echo "decode smoke: expected 16 seqs x 8 tokens = 128 generated tokens"; exit 1;
  }
  echo "$out" | grep -Eq "loss [0-9]+\.[0-9]+" || {
    echo "decode smoke: loss is not a finite number"; exit 1;
  }
  echo "$out" | grep -q "cached score dots over 8 steps" || {
    echo "decode smoke: counted-attention report line missing"; exit 1;
  }
}

stage_check() {
  # Static-analysis gate: `mase check` emits a design in memory for a
  # synthetic model (artifact-free) and runs the real SV analyzer plus
  # the cross-layer bitwidth contracts over it — the same check::
  # entry point the emit pass gates on. Nonzero exit on any error-level
  # MC0xx diagnostic. A second invocation covers the known-bad corpus
  # path via --sv to prove the analyzer still fires.
  echo "==> static analysis: mase check (artifact-free emitted design)"
  if [[ ! -x target/release/mase ]]; then
    echo "  (target/release/mase missing; building first)"
    cargo build --release
  fi
  cleanup  # reclaim the smoke stage's scratch dir before making our own
  SMOKE_DIR="$(mktemp -d)"
  ./target/release/mase check --artifacts "$SMOKE_DIR/artifacts"
  ./target/release/mase check --artifacts "$SMOKE_DIR/artifacts" --fmt int --bits 8
  if ./target/release/mase check --sv tests/corpus/bad_undeclared.sv \
      >/dev/null 2>&1; then
    echo "mase check failed to flag the known-bad corpus"; exit 1
  fi
}

stage_trace() {
  # Observability gate (PR 8): `mase trace` simulates a synthetic design
  # artifact-free and exports both trace formats; a traced e2e run must
  # print the shared summary block and write a schema-valid JSONL; the
  # toolchain-free python mirror re-derives the sim's closed-form
  # accounting and validates every JSONL artifact (stdlib only).
  echo "==> trace smoke: mase trace exports + traced e2e + schema validation"
  if [[ ! -x target/release/mase ]]; then
    echo "  (target/release/mase missing; building first)"
    cargo build --release
  fi
  cleanup
  SMOKE_DIR="$(mktemp -d)"
  local out
  out="$(./target/release/mase trace --artifacts "$SMOKE_DIR/artifacts" \
    --chan 32 --out "$SMOKE_DIR/sim_trace.json")"
  echo "$out"
  echo "$out" | grep -q "trace written to" || {
    echo "trace smoke: chrome export missing"; exit 1;
  }
  grep -q '"traceEvents"' "$SMOKE_DIR/sim_trace.json" || {
    echo "trace smoke: chrome file lacks traceEvents"; exit 1;
  }
  ./target/release/mase trace --artifacts "$SMOKE_DIR/artifacts" --chan 32 \
    --trace-format jsonl --out "$SMOKE_DIR/sim_trace.jsonl" >/dev/null
  out="$(./target/release/mase trace --run e2e --backend cpu --model toy-sim \
    --task sst2 --trials 4 --batch 2 --eval-batches 1 --threads 1 \
    --artifacts "$SMOKE_DIR/artifacts" --out "$SMOKE_DIR/design" \
    --trace "$SMOKE_DIR/e2e_trace.jsonl")"
  echo "$out" | grep -q "== trace summary ==" || {
    echo "trace smoke: traced e2e did not print the summary block"; exit 1;
  }
  echo "$out" | grep -q "search/trial" || {
    echo "trace smoke: per-trial spans missing from the summary"; exit 1;
  }
  python3 ../scripts/verify_trace_schema.py \
    "$SMOKE_DIR/sim_trace.jsonl" "$SMOKE_DIR/e2e_trace.jsonl"
}

stage_serve() {
  # Serving gate (PR 9): boot `mase serve` on an ephemeral port, parse
  # the port from the listening line (stdout contract), then drive the
  # whole protocol through a raw-socket stdlib-python client: /healthz,
  # two identical /v1/generate calls (the determinism contract makes the
  # replies bit-identical even though the second one decodes in a reused
  # lane of a warm engine), /metrics counters, a 400 and a 404. Finally
  # SIGTERM — the binary installs no handler on purpose (no durable
  # state, connection: close), so default disposition must kill it fast.
  echo "==> serve smoke: mase serve --backend cpu --model toy-lm (ephemeral port)"
  if [[ ! -x target/release/mase ]]; then
    echo "  (target/release/mase missing; building first)"
    cargo build --release
  fi
  cleanup
  SMOKE_DIR="$(mktemp -d)"
  ./target/release/mase serve --backend cpu --model toy-lm --port 0 \
    --lanes 2 --queue-timeout-ms 10000 \
    --artifacts "$SMOKE_DIR/artifacts" >"$SMOKE_DIR/serve.log" 2>&1 &
  SERVE_PID=$!
  local port=""
  for _ in $(seq 1 300); do
    port="$(sed -n 's#^mase serve: listening on http://127\.0\.0\.1:\([0-9]*\).*#\1#p' \
      "$SMOKE_DIR/serve.log" 2>/dev/null || true)"
    [[ -n "$port" ]] && break
    if ! kill -0 "$SERVE_PID" 2>/dev/null; then
      cat "$SMOKE_DIR/serve.log"
      echo "serve smoke: server exited before listening"; exit 1
    fi
    sleep 0.1
  done
  [[ -n "$port" ]] || {
    cat "$SMOKE_DIR/serve.log"
    echo "serve smoke: no listening line within 30s"; exit 1;
  }
  if ! python3 - "$port" <<'PY'
import json, socket, sys

port = int(sys.argv[1])

def rpc(method, path, body=None):
    payload = b"" if body is None else json.dumps(body).encode()
    head = (
        f"{method} {path} HTTP/1.1\r\nhost: localhost\r\n"
        f"content-length: {len(payload)}\r\nconnection: close\r\n\r\n"
    )
    with socket.create_connection(("127.0.0.1", port), timeout=120) as s:
        s.settimeout(120)
        s.sendall(head.encode() + payload)
        buf = b""
        while True:
            chunk = s.recv(65536)
            if not chunk:
                break
            buf += chunk
    header, _, resp_body = buf.partition(b"\r\n\r\n")
    return int(header.split()[1]), resp_body.decode()

st, body = rpc("GET", "/healthz")
assert st == 200, (st, body)
h = json.loads(body)
assert h["status"] == "ok" and h["model"] == "toy-lm", h
assert h["lanes"] == 2 and h["width"] >= 1, h

gen = {"prompt_len": 4, "stream": 11, "max_tokens": 6}
st, body = rpc("POST", "/v1/generate", gen)
assert st == 200, (st, body)
r = json.loads(body)
assert r["prompt_len"] == 4 and len(r["tokens"]) == 6, r
assert all(isinstance(t, int) and 0 <= t < 512 for t in r["tokens"]), r

st, body = rpc("POST", "/v1/generate", gen)
assert st == 200, (st, body)
assert json.loads(body)["tokens"] == r["tokens"], "repeat request not deterministic"

st, body = rpc("GET", "/metrics")
assert st == 200, (st, body)
assert "serve/scheduler" in body and "admitted" in body, body
assert "serve/engine" in body and "serve/http" in body, body

st, body = rpc("POST", "/v1/generate", {"prompt": [1, 9999]})
assert st == 400, (st, body)
st, body = rpc("GET", "/no-such-route")
assert st == 404, (st, body)
print(f"serve smoke client: protocol ok on port {port}, tokens {r['tokens']}")
PY
  then
    cat "$SMOKE_DIR/serve.log"
    echo "serve smoke: protocol client failed"; exit 1
  fi
  kill -TERM "$SERVE_PID" 2>/dev/null || {
    cat "$SMOKE_DIR/serve.log"
    echo "serve smoke: server died before SIGTERM"; exit 1;
  }
  local alive=1
  for _ in $(seq 1 100); do
    if ! kill -0 "$SERVE_PID" 2>/dev/null; then alive=0; break; fi
    sleep 0.1
  done
  if [[ "$alive" -ne 0 ]]; then
    kill -9 "$SERVE_PID" 2>/dev/null || true
    echo "serve smoke: server ignored SIGTERM for 10s"; exit 1
  fi
  wait "$SERVE_PID" 2>/dev/null || true
  SERVE_PID=""
  echo "serve smoke: SIGTERM shut the server down cleanly"
}

stage_artifact() {
  # Packed-artifact gate (the `.mxa` container): `mase pack --out
  # model.mxa` must warm-start `--weights` sessions with ZERO re-pack
  # work and bit-identical output, the e2e flow must report identical
  # results through the loader, the toolchain-free python mirror must
  # re-derive the container byte-for-byte, and a corrupted container
  # must fail closed naming the offending tensor.
  echo "==> artifact smoke: mase pack --out .mxa -> --weights warm start"
  if [[ ! -x target/release/mase ]]; then
    echo "  (target/release/mase missing; building first)"
    cargo build --release
  fi
  cleanup
  SMOKE_DIR="$(mktemp -d)"
  local art="$SMOKE_DIR/artifacts"
  ./target/release/mase pack --model toy-lm --out "$SMOKE_DIR/toy.mxa" \
    --artifacts "$art" | tail -n 2
  ./target/release/mase pack --model toy-sim --task sst2 \
    --out "$SMOKE_DIR/toy-sim.mxa" --artifacts "$art" >/dev/null
  test -s "$SMOKE_DIR/toy.mxa" && test -s "$SMOKE_DIR/toy-sim.mxa" || {
    echo "artifact smoke: pack wrote no .mxa"; exit 1;
  }

  # toolchain-free mirror: stdlib+numpy re-parse of header, manifest,
  # chunk alignment and every FNV-1a/64 hash (while the files are clean)
  python3 ../scripts/verify_artifact_format.py \
    "$SMOKE_DIR/toy.mxa" "$SMOKE_DIR/toy-sim.mxa"

  # decode: the warm run must pack nothing and emit the same bits
  local cold warm
  cold="$(./target/release/mase generate --backend cpu --model toy-lm \
    --tokens 8 --prompt-len 4 --threads 1 --artifacts "$art")"
  warm="$(./target/release/mase generate --backend cpu --model toy-lm \
    --tokens 8 --prompt-len 4 --threads 1 --artifacts "$art" \
    --weights "$SMOKE_DIR/toy.mxa")"
  echo "$warm" | grep "weight packs in-session:"
  echo "$warm" | grep -q "weight packs in-session: 0 " || {
    echo "$warm"; echo "artifact smoke: warm --weights run re-packed weights"; exit 1;
  }
  if echo "$cold" | grep -q "weight packs in-session: 0 "; then
    echo "artifact smoke: cold run claims zero packs (counter broken)"; exit 1
  fi
  [[ "$(echo "$cold" | grep '^decode ok:')" == "$(echo "$warm" | grep '^decode ok:')" ]] || {
    echo "cold: $cold"; echo "warm: $warm";
    echo "artifact smoke: warm decode diverged from the in-memory path"; exit 1;
  }

  # e2e: search through the loader (per-trial layouts repack, still
  # bit-identical) — the result lines must match digit-for-digit
  local e_cold e_warm
  e_cold="$(./target/release/mase e2e --backend cpu --model toy-sim --task sst2 \
    --trials 4 --batch 2 --eval-batches 1 --threads 1 \
    --artifacts "$art" --out "$SMOKE_DIR/design")"
  e_warm="$(./target/release/mase e2e --backend cpu --model toy-sim --task sst2 \
    --trials 4 --batch 2 --eval-batches 1 --threads 1 \
    --artifacts "$art" --out "$SMOKE_DIR/design2" \
    --weights "$SMOKE_DIR/toy-sim.mxa")"
  local want got
  want="$(echo "$e_cold" | grep -E '^(fp32|best) ')"
  got="$(echo "$e_warm" | grep -E '^(fp32|best) ')"
  [[ -n "$want" && "$want" == "$got" ]] || {
    echo "cold: $want"; echo "warm: $got";
    echo "artifact smoke: e2e through --weights diverged from the in-memory path"; exit 1;
  }

  # fail closed: flip one byte in the last chunk; the loader must refuse
  # with an error naming the offending tensor, never serve partial bits
  python3 - "$SMOKE_DIR/toy.mxa" <<'PY'
import sys
p = sys.argv[1]
b = bytearray(open(p, "rb").read())
b[-1] ^= 1
open(p, "wb").write(b)
PY
  local out
  if out="$(./target/release/mase generate --backend cpu --model toy-lm \
      --tokens 2 --prompt-len 4 --threads 1 --artifacts "$art" \
      --weights "$SMOKE_DIR/toy.mxa" 2>&1)"; then
    echo "$out"; echo "artifact smoke: corrupted artifact was accepted"; exit 1
  fi
  echo "$out" | grep -q "corrupt" || {
    echo "$out"; echo "artifact smoke: corruption not reported as such"; exit 1;
  }
  echo "$out" | grep -q "embed" || {
    echo "$out"; echo "artifact smoke: error does not name the offending tensor"; exit 1;
  }
  echo "artifact smoke: zero-repack warm start, bit-identical output, fail-closed corruption"
}

run_stage() {
  case "$1" in
    fmt)    stage_fmt ;;
    clippy) stage_clippy ;;
    doc)    stage_doc ;;
    test)   stage_test ;;
    smoke)  stage_smoke ;;
    decode) stage_decode ;;
    check)  stage_check ;;
    trace)  stage_trace ;;
    serve)  stage_serve ;;
    artifact) stage_artifact ;;
    all)
      if [[ -z "${SKIP_LINTS:-}" ]]; then
        stage_fmt
        stage_clippy
        stage_doc
      fi
      stage_test
      stage_smoke
      stage_decode
      stage_check
      stage_trace
      stage_serve
      stage_artifact
      ;;
    *)
      echo "unknown stage '$1' (expected fmt|clippy|doc|test|smoke|decode|check|trace|serve|artifact|all)" >&2
      exit 2
      ;;
  esac
}

if [[ $# -eq 0 ]]; then
  run_stage all
else
  for stage in "$@"; do
    run_stage "$stage"
  done
fi

echo "CI gate passed."
