#!/usr/bin/env python3
"""Numerical verification of the PR-4 CPU execution backend
(rust/src/runtime/interp.rs), mirrored in numpy — this container has no
Rust toolchain, so the interpreter's parity claims are validated here the
same way scripts/verify_packed_math.py validated the PR-3 packed kernels.

Mirrors, op-for-op: util::rng::Rng (xoshiro256** + SplitMix64 seeding,
Box-Muller normals), data::tasks::sst2 sampling + data::batches,
data::MarkovCorpus, frontend::{param layout, init_params}, and the
interpreter forward (embed+pos, pinned-outlier LayerNorm, fused MHA,
tanh-GELU, mean-pool / causal-LM head, cross-entropy) with BOTH matmul
datapaths: the packed integer-segment model (mant*2^exp fields, 2-wide
k-segments, MAX_ALIGN_SHIFT=63 fallback — exactly kernels.rs::flush_group)
and the f64-segmented float reference (gemm_f64_segmented).

Claims checked (the assertions of rust/tests/backend_parity.rs, on the
exact same model/seeds/batches the Rust test uses):
  I1  MXInt(4), MXInt(7), Int(8), Int(5): packed-path loss bitwise equal
      to reference-path loss, correct-counts equal (classifier), and
      MXInt(6) on the causal LM.
  I2  BMF(5)/BL(7)/FP8: relative loss disagreement FAR below the 1e-6
      test tolerance (measured and printed), correct-counts equal.
  I3  fp32 loss finite; MXInt(1) perturbs the loss (oracle sensitivity).
  I4  all intermediate activations finite for every format (no LN/softmax
      blowups from the injected outlier gains).
  I5  every packed 2-segment with alignment span <= 63 is bitwise equal
      to the reference segment partial (the structural exactness lemma),
      counted across every GEMM of every forward.

PR 7 adds the KV-cache decode mirror (runtime/decode.rs contracts):
  K1  MXInt/Int KV-cached greedy decode is token-for-token and
      logit-bitwise identical to a full no-cache recompute of the whole
      prefix at every step (and loss-bitwise via the shared NLL helper);
      BMF/BL/FP8 stay within the documented 1e-6 relative bound.
  K2  the single-query attention row (buffer length = context) is bitwise
      equal to the full causal row (buffer length = seq, -1e9 mask tail):
      exp(-1e9 - m) underflows to exactly 0.0f32 and trailing +0.0 /
      +0.0*v terms are exact no-ops under sequential f64 accumulation.
  K3  position-major [p*b, k] activation blocking equals stacked
      per-position [b, k] blocking bitwise when b % 16 == 0 (block
      membership never straddles positions), for every block format.
  K4  for element-wise formats (Int/fp32) the decode-convention forward
      matches the batch-major interpreter forward (semantic grounding;
      asserted bitwise in Rust where both paths share sequential sums).
  K5  negative control: for block formats the batch-major forward
      DIFFERS bitwise from the decode convention (block membership of
      [b*s, k] rows depends on s) — why decode defines its own blocking.
"""
import math
import struct
import sys

import numpy as np

f32 = np.float32

# ---- reuse the PR-3 quantizer/field mirrors (defined before its checks) --
import os

_pm_src = open(os.path.join(os.path.dirname(__file__), "verify_packed_math.py")).read()
_pm_ns = {"np": np, "struct": struct, "sys": sys}
exec(_pm_src[: _pm_src.index("def check(")], _pm_ns)
q_mxint, q_bmf, q_bl, q_int, q_fp8 = (
    _pm_ns["q_mxint"],
    _pm_ns["q_bmf"],
    _pm_ns["q_bl"],
    _pm_ns["q_int"],
    _pm_ns["q_fp8"],
)
resolve_m = _pm_ns["resolve_m"]
shared_exponent = _pm_ns["shared_exponent"]
maxabs = _pm_ns["maxabs"]
blocks = _pm_ns["blocks"]

M64 = (1 << 64) - 1
fails = []


def check(name, ok):
    print(("PASS  " if ok else "FAIL  ") + name)
    if not ok:
        fails.append(name)


# ------------------------- util::rng::Rng mirror -------------------------
class Rng:
    def __init__(self, seed):
        z = (seed + 0x9E3779B97F4A7C15) & M64
        s = []
        for _ in range(4):
            z = (z + 0x9E3779B97F4A7C15) & M64
            x = z
            x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & M64
            x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & M64
            s.append(x ^ (x >> 31))
        self.s = s
        self.spare = None

    def next_u64(self):
        s = self.s
        result = ((((s[1] * 5) & M64) << 7 | ((s[1] * 5) & M64) >> 57) & M64) * 9 & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = ((s[3] << 45) | (s[3] >> 19)) & M64
        return result

    def uniform(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        return self.next_u64() % n

    def normal(self):
        if self.spare is not None:
            v, self.spare = self.spare, None
            return v
        u1, u2 = max(self.uniform(), 1e-300), self.uniform()
        r = math.sqrt(-2.0 * math.log(u1))
        theta = 2.0 * math.pi * u2
        self.spare = r * math.sin(theta)
        return r * math.cos(theta)

    def shuffle(self, v):
        for i in range(len(v) - 1, 0, -1):
            j = self.below(i + 1)
            v[i], v[j] = v[j], v[i]


# ------------------------- data::tasks::sst2 mirror ----------------------
BG0, POS0, NEG0 = 100, 10, 40
SST2_TAG = 5  # enum order: BoolQ, Mnli, Qnli, Qqp, Rte, Sst2


def sst2_sample(split, idx, seq):
    seed = (
        SST2_TAG * 0x9E3779B97F4A7C15
        + split * 0xD1B54A32D192ED03
        + idx * 0x2545F4914F6CDD1D
    ) & M64
    rng = Rng(seed)
    label = rng.below(2)
    minor = rng.below(seq // 8)
    major = minor + 2 + rng.below(3)
    k_pos, k_neg = (major, minor) if label == 1 else (minor, major)
    tokens = []
    for _ in range(seq):
        u = rng.uniform()
        tokens.append(BG0 + int((512 - BG0) * u * u))
    slots = list(range(seq))
    rng.shuffle(slots)
    s = 0
    for _ in range(k_pos):
        tokens[slots[s]] = POS0 + rng.below(30)
        s += 1
    for _ in range(k_neg):
        tokens[slots[s]] = NEG0 + rng.below(30)
        s += 1
    return tokens, label


def sst2_batches(n_batches, batch, seq, split=1):
    out = []
    for b in range(n_batches):
        toks, labs = [], []
        for i in range(batch):
            t, l = sst2_sample(split, b * batch + i, seq)
            toks.extend(t)
            labs.append(l)
        out.append((np.array(toks).reshape(batch, seq), np.array(labs)))
    return out


# ------------------------- data::MarkovCorpus mirror ---------------------
class MarkovCorpus:
    VOCAB, SUCC = 512, 8

    def __init__(self, seed):
        rng = Rng(seed ^ 0xC0FFEE)
        self.succ = []
        for _ in range(self.VOCAB):
            row = []
            for _ in range(self.SUCC):
                u = rng.uniform()
                row.append(int(self.VOCAB * u * u) % self.VOCAB)
            self.succ.append(row)
        w = [1.0 / (k + 1) ** 1.5 for k in range(self.SUCC)]
        total = sum(w)
        self.cum = []
        acc = 0.0
        for k in range(self.SUCC):
            acc += w[k] / total
            self.cum.append(acc)
        self.noise = 0.05

    def batch(self, stream, batch, seq):
        out = []
        for b in range(batch):
            rng = Rng((stream * 0xA24BAED4963EE407 + b) & M64)
            state = rng.below(self.VOCAB)
            for _ in range(seq):
                out.append(state)
                if rng.uniform() < self.noise:
                    state = rng.below(self.VOCAB)
                else:
                    u = rng.uniform()
                    k = next((i for i, c in enumerate(self.cum) if u <= c), self.SUCC - 1)
                    state = self.succ[state][k]
        return np.array(out).reshape(batch, seq)


# ---------------- frontend::{param_spec, init_params} mirror -------------
OUTLIER_CHANNELS, OUTLIER_BASE_GAIN = 4, 16.0


def param_spec(L, d, vocab, seq, out_dim):
    dff = 4 * d
    spec = [("embed", (vocab, d)), ("pos", (seq, d))]
    for i in range(L):
        p = f"layer{i}."
        spec += [
            (p + "ln1_g", (d,)), (p + "ln1_b", (d,)),
            (p + "w_qkv", (d, 3 * d)), (p + "b_qkv", (3 * d,)),
            (p + "w_proj", (d, d)), (p + "b_proj", (d,)),
            (p + "ln2_g", (d,)), (p + "ln2_b", (d,)),
            (p + "w_fc1", (d, dff)), (p + "b_fc1", (dff,)),
            (p + "w_fc2", (dff, d)), (p + "b_fc2", (d,)),
        ]
    spec += [("lnf_g", (d,)), ("lnf_b", (d,)), ("head_w", (d, out_dim)), ("head_b", (out_dim,))]
    return spec


def qtensor_names(L):
    names = []
    for i in range(L):
        p = f"layer{i}."
        names += [p + "a_attn_in", p + "w_qkv", p + "a_proj_in", p + "w_proj",
                  p + "a_fc1_in", p + "w_fc1", p + "a_fc2_in", p + "w_fc2"]
    return names + ["a_head_in", "head_w"]


def init_params(spec, seed):
    rng = Rng(seed)
    params = {}
    for name, shape in spec:
        n = int(np.prod(shape))
        if name.endswith("_b"):
            params[name] = np.zeros(shape, f32)
        elif name.endswith("_g"):
            params[name] = np.ones(shape, f32)
        else:
            fan_in = shape[0]
            fan_out = shape[-1]
            std = math.sqrt(2.0 / (fan_in + fan_out))
            vals = np.array([f32(rng.normal() * std) for _ in range(n)], f32).reshape(shape)
            if ".w_qkv" in name or ".w_fc1" in name:
                layer = int(name.split(".")[0][len("layer"):])
                gain = f32(OUTLIER_BASE_GAIN * (1.0 + layer))
                k = min(OUTLIER_CHANNELS, shape[0])
                vals[:k, :] = (vals[:k, :] / gain).astype(f32)
            params[name] = vals
    return params


# ------------------- quantizers + field exponents, 2-D ------------------
def quantize2d(fmt, x2, bits, frac):
    rows, cols = x2.shape
    flat = x2.ravel().copy()
    if fmt == "fp32":
        return x2.copy()
    if fmt == "mxint":
        return q_mxint(flat, rows, cols, bits).reshape(rows, cols)
    if fmt == "bmf":
        return q_bmf(flat, rows, cols, bits).reshape(rows, cols)
    if fmt == "bl":
        return q_bl(flat, rows, cols, bits).reshape(rows, cols)
    if fmt == "int":
        return q_int(flat, bits, frac).reshape(rows, cols)
    if fmt == "fp8":
        return q_fp8(flat).reshape(rows, cols)
    raise ValueError(fmt)


def floor_log2_arr(a64):
    """floor(log2 |a|) for nonzero f64 array (f32 subnormals are normal)."""
    m, e = np.frexp(np.abs(a64))
    return (e - 1).astype(np.int64)


def field_exps(fmt, q2, x2, bits, frac):
    """Per-element field exponent of the packed mant*2^exp decomposition
    (mirrors layout.rs fld_*); value only meaningful where q != 0."""
    rows, cols = q2.shape
    q64 = q2.astype(np.float64)
    nz = q64 != 0.0
    e = np.zeros((rows, cols), np.int64)
    if fmt in ("mxint", "bmf", "bl"):
        eblk = np.zeros((rows, cols), np.int64)
        flatx = x2.ravel()
        for s, blk in blocks(rows, cols):
            eb = shared_exponent(maxabs(flatx, s, cols))
            for i in blk:
                eblk[i // cols, i % cols] = eb
        if fmt == "mxint":
            m = resolve_m(bits)
            e = np.clip(eblk + 1 - m, -149, 127)
        elif fmt == "bmf":
            m = resolve_m(bits)
            fl = np.where(nz, floor_log2_arr(np.where(nz, q64, 1.0)), 0)
            e_loc = np.clip(fl - eblk, -3, 0)
            e = np.clip(e_loc + eblk - m, -149, 127)
        else:  # bl: value = sign * 2^e
            e = np.clip(np.where(nz, floor_log2_arr(np.where(nz, q64, 1.0)), 0), -149, 127)
    elif fmt == "int":
        f = int(math.floor(abs(frac) + 0.5)) * (1 if frac >= 0 else -1)  # f32::round
        e = np.full((rows, cols), int(np.clip(-f, -149, 127)), np.int64)
    elif fmt == "fp8":
        m, bias = 3, 7
        fl = np.where(nz, floor_log2_arr(np.where(nz, q64, 1.0)), 0)
        denorm = np.abs(q64) < 2.0 ** (1 - bias)
        e = np.where(denorm, 1 - bias - m, fl - m)
    else:  # fp32: 24-bit mantissa
        fl = np.where(nz, floor_log2_arr(np.where(nz, q64, 1.0)), 0)
        e = fl - 23
    return e


SEG_EXACT = {}


def gemm_two_path(qa, qb, ea, eb, fmt):
    """Both datapaths over the same quantized operands.

    reference: total += RN(p1 + p2) per 2-wide k-segment (f64), out f32.
    packed:    identical when the field-exponent span <= 63 (the flush
               lemma: integer acc + one f64 round == RN(p1+p2)); per-term
               adds otherwise. Segment-level bitwise equality of the two
               partials is COUNTED for claim I5.
    """
    R, K = qa.shape
    N = qb.shape[1]
    a64, b64 = qa.astype(np.float64), qb.astype(np.float64)
    ref = np.zeros((R, N))
    pk = np.zeros((R, N))
    for kk in range(0, K, 2):
        p1 = a64[:, kk][:, None] * b64[kk][None, :]
        p2 = a64[:, kk + 1][:, None] * b64[kk + 1][None, :]
        part = p1 + p2
        e1 = ea[:, kk][:, None] + eb[kk][None, :]
        e2 = ea[:, kk + 1][:, None] + eb[kk + 1][None, :]
        both = (p1 != 0.0) & (p2 != 0.0)
        fallback = both & (np.abs(e1 - e2) > 63)
        st = SEG_EXACT.setdefault(fmt, {"count": 0, "fallback": 0})
        st["count"] += int(both.size)
        st["fallback"] += int(fallback.sum())
        ref = ref + part
        pk = np.where(fallback, (pk + p1) + p2, pk + part)
    return ref.astype(f32), pk.astype(f32)


# --------------------------- interpreter mirror --------------------------
def layer_norm(x, g, b, layer_idx):
    """x: [rows, d] f32. layer_idx None = plain LN (lnf)."""
    x64 = x.astype(np.float64)
    mu = x64.mean(axis=1, keepdims=True)
    var = ((x64 - mu) ** 2).mean(axis=1, keepdims=True)
    core = ((x64 - mu) / np.sqrt(var + 1e-5)).astype(f32)
    g2, b2 = g.copy(), b.copy()
    if layer_idx is not None:
        g2[:OUTLIER_CHANNELS] = 1.0
        b2[:OUTLIER_CHANNELS] = 0.0
    y = (core * g2[None, :]).astype(f32) + b2[None, :]
    y = y.astype(f32)
    if layer_idx is not None:
        gain = f32(OUTLIER_BASE_GAIN * (1.0 + layer_idx))
        y[:, :OUTLIER_CHANNELS] = (y[:, :OUTLIER_CHANNELS] * gain).astype(f32)
    return y


def softmax_rows(s):
    m = s.max(axis=-1, keepdims=True)
    e = np.exp(s - m, dtype=f32)
    return (e.astype(np.float64) / e.astype(np.float64).sum(axis=-1, keepdims=True)).astype(f32)


def attention(qkv, b, s, d, heads, causal):
    dh = d // heads
    scale = f32(np.sqrt(f32(dh)))
    out = np.zeros((b, s, d), f32)
    for bi in range(b):
        for h in range(heads):
            off = h * dh
            Q = qkv[bi, :, off:off + dh].astype(np.float64)
            K = qkv[bi, :, d + off:d + off + dh].astype(np.float64)
            V = qkv[bi, :, 2 * d + off:2 * d + off + dh].astype(np.float64)
            S = (Q @ K.T).astype(f32) / scale
            if causal:
                S = np.where(np.tril(np.ones((s, s), bool)), S, f32(-1e9)).astype(f32)
            A = softmax_rows(S)
            out[bi, :, off:off + dh] = (A.astype(np.float64) @ V).astype(f32)
    return out


def gelu(x):
    c = f32(0.79788456)
    inner = (c * (x + f32(0.044715) * x * x * x)).astype(f32)
    return (f32(0.5) * x * (f32(1.0) + np.tanh(inner))).astype(f32)


class Net:
    def __init__(self, L=1, d=32, heads=2, vocab=512, seq=16, batch=16,
                 kind="classifier", n_classes=4, seed=0xC0DE):
        self.L, self.d, self.heads = L, d, heads
        self.vocab, self.seq, self.batch = vocab, seq, batch
        self.kind = kind
        self.out_dim = vocab if kind == "lm" else n_classes
        self.spec = param_spec(L, d, vocab, seq, self.out_dim)
        self.p = init_params(self.spec, seed)
        self.qidx = {n: i for i, n in enumerate(qtensor_names(L))}

    def qmm(self, x2, act_name, w_name, fmt, qcfg, path):
        """x2 [rows,k] @ p[w_name] + bias — one datapath's output."""
        ai, wi = self.qidx[act_name], self.qidx[w_name]
        w = self.p[w_name]
        qa = quantize2d(fmt, x2, qcfg[ai][0], qcfg[ai][1])
        qw = quantize2d(fmt, w, qcfg[wi][0], qcfg[wi][1])
        ea = field_exps(fmt, qa, x2, qcfg[ai][0], qcfg[ai][1])
        ew = field_exps(fmt, qw, w, qcfg[wi][0], qcfg[wi][1])
        ref, pk = gemm_two_path(qa, qw, ea, ew, fmt)
        y = ref if path == "reference" else pk
        bias_name = "head_b" if w_name == "head_w" else w_name.replace("w_", "b_", 1)
        return (y + self.p[bias_name][None, :]).astype(f32)

    def forward(self, tokens, fmt, qcfg, path):
        b, s, d = tokens.shape[0], self.seq, self.d
        x = (self.p["embed"][tokens] + self.p["pos"][None, :s, :]).astype(f32)
        causal = self.kind == "lm"
        for i in range(self.L):
            pre = f"layer{i}."
            h = layer_norm(x.reshape(b * s, d), self.p[pre + "ln1_g"], self.p[pre + "ln1_b"], i)
            qkv = self.qmm(h, pre + "a_attn_in", pre + "w_qkv", fmt, qcfg, path)
            o = attention(qkv.reshape(b, s, 3 * d), b, s, d, self.heads, causal)
            o = self.qmm(o.reshape(b * s, d), pre + "a_proj_in", pre + "w_proj", fmt, qcfg, path)
            x = (x + o.reshape(b, s, d)).astype(f32)
            h = layer_norm(x.reshape(b * s, d), self.p[pre + "ln2_g"], self.p[pre + "ln2_b"], i)
            h = self.qmm(h, pre + "a_fc1_in", pre + "w_fc1", fmt, qcfg, path)
            h = gelu(h)
            h = self.qmm(h, pre + "a_fc2_in", pre + "w_fc2", fmt, qcfg, path)
            x = (x + h.reshape(b, s, d)).astype(f32)
        xf = layer_norm(x.reshape(b * s, d), self.p["lnf_g"], self.p["lnf_b"], None)
        if self.kind == "lm":
            logits = self.qmm(xf, "a_head_in", "head_w", fmt, qcfg, path)
            return logits.reshape(b, s, self.out_dim)
        pooled = xf.reshape(b, s, d).astype(np.float64).mean(axis=1).astype(f32)
        return self.qmm(pooled, "a_head_in", "head_w", fmt, qcfg, path)

    def eval_batch(self, tokens, labels, fmt, qcfg, path):
        logits = self.forward(tokens, fmt, qcfg, path)
        if self.kind == "lm":
            b, s, v = logits.shape
            lg = logits[:, :-1, :].reshape(-1, v)
            tgt = tokens[:, 1:].reshape(-1)
        else:
            lg = logits
            tgt = labels
        m = lg.max(axis=1).astype(np.float64)
        lse = m + np.log(np.exp(lg.astype(np.float64) - m[:, None]).sum(axis=1))
        nll = lse - lg.astype(np.float64)[np.arange(len(tgt)), tgt]
        correct = int((lg.argmax(axis=1) == tgt).sum())
        return f32(nll.mean()), correct


def qcfg_uniform(L, bits, frac_by_name=None):
    names = qtensor_names(L)
    return [(bits, (frac_by_name or {}).get(n, 0.0)) for n in names]


def calibrate_int_fracs(net, batches_, bits):
    """profile absmax (fp32 forward taps) -> fixed::calibrate_frac."""
    # taps: activation inputs of each qmm + weights, on an fp32 forward.
    # Here only absmax is needed; reuse the reference forward pieces.
    absmax = {}

    class TapNet(Net):
        def qmm(self, x2, act_name, w_name, fmt, qcfg, path):
            absmax[act_name] = max(absmax.get(act_name, 0.0), float(np.abs(x2).max()))
            absmax[w_name] = max(absmax.get(w_name, 0.0), float(np.abs(self.p[w_name]).max()))
            return Net.qmm(self, x2, act_name, w_name, fmt, qcfg, path)

    tn = TapNet(L=net.L, d=net.d, heads=net.heads, vocab=net.vocab, seq=net.seq,
                batch=net.batch, kind=net.kind, seed=0xC0DE)
    z = qcfg_uniform(net.L, 32.0)
    tn.eval_batch(batches_[0][0], batches_[0][1], "fp32", z, "reference")

    def calibrate_frac(w, amax):
        # fixed.rs::calibrate_frac mirror
        if amax <= 0:
            return 0.0
        int_bits = math.ceil(math.log2(amax))
        return float(int(w) - 1 - int_bits)

    return {n: float(calibrate_frac(bits, a)) for n, a in absmax.items()}


# ------------------------------- checks ----------------------------------
def run(net, batches_, fmt, qcfg):
    """(loss_ref, loss_pk, correct_ref, correct_pk) mean-loss over batches
    like EvalAccumulator::mean_loss (f64 mean of f32 per-batch losses)."""
    lr, lp, cr, cp = [], [], 0, 0
    for toks, labs in batches_:
        l1, c1 = net.eval_batch(toks, labs, fmt, qcfg, "reference")
        l2, c2 = net.eval_batch(toks, labs, fmt, qcfg, "packed")
        lr.append(float(l1))
        lp.append(float(l2))
        cr += c1
        cp += c2
    return sum(lr) / len(lr), sum(lp) / len(lp), cr, cp


def bits64(x):
    return struct.pack("<d", x)


print("== mirroring rust/tests/backend_parity.rs on the tiny models ==")
net = Net()
bat = sst2_batches(2, 16, 16)

# I1: exact formats, classifier
int_fracs8 = calibrate_int_fracs(net, bat, 8.0)
int_fracs5 = calibrate_int_fracs(net, bat, 5.0)
ok = True
for fmt, bits, fracs in [
    ("mxint", 4.0, None), ("mxint", 7.0, None),
    ("int", 8.0, int_fracs8), ("int", 5.0, int_fracs5),
]:
    qc = qcfg_uniform(1, bits, fracs)
    l_ref, l_pk, c_ref, c_pk = run(net, bat, fmt, qc)
    exact = bits64(l_ref) == bits64(l_pk) and c_ref == c_pk
    print(f"  {fmt}{int(bits)}: loss {l_pk:.6f} correct {c_pk}/32 exact={exact}")
    ok &= exact
check("I1 classifier MXInt/Int packed loss bitwise == reference", ok)

# I1b: causal LM, MXInt(6)
lm = Net(kind="lm")
corpus = MarkovCorpus(7)
lm_bat = [(corpus.batch(500 + i, 16, 16), np.zeros(16, np.int64)) for i in range(2)]
l_ref, l_pk, c_ref, c_pk = run(lm, lm_bat, "mxint", qcfg_uniform(1, 6.0))
print(f"  lm mxint6: loss {l_pk:.6f} correct {c_pk}/240")
check("I1b LM MXInt(6) packed loss bitwise == reference",
      bits64(l_ref) == bits64(l_pk) and c_ref == c_pk)

# I2: bounded formats
ok = True
worst = 0.0
for fmt, bits in [("bmf", 5.0), ("bl", 7.0), ("fp8", 8.0)]:
    l_ref, l_pk, c_ref, c_pk = run(net, bat, fmt, qcfg_uniform(1, bits))
    rel = abs(l_pk - l_ref) / max(abs(l_ref), 1e-12)
    worst = max(worst, rel)
    print(f"  {fmt}{int(bits)}: loss {l_pk:.6f} rel-delta {rel:.3e} correct equal={c_ref == c_pk}")
    ok &= rel < 1e-6 and c_ref == c_pk
check(f"I2 bmf/bl/fp8 rel loss delta < 1e-6 (worst {worst:.3e})", ok)

# I3: fp32 finite + sensitivity
l32, _, _, _ = run(net, bat, "fp32", qcfg_uniform(1, 32.0))
l1, _, _, _ = run(net, bat, "mxint", qcfg_uniform(1, 1.0))
print(f"  fp32 loss {l32:.6f}, mxint1 loss {l1:.6f}")
check("I3 fp32 loss finite and MXInt(1) perturbs it",
      np.isfinite(l32) and np.isfinite(l1) and l1 != l32)

# I4: finiteness of the forward (worst format: bl with wide exponents)
logits = net.forward(bat[0][0], "bl", qcfg_uniform(1, 7.0), "packed")
check("I4 activations/logits finite under BL(7) with outlier gains",
      bool(np.isfinite(logits).all()))

# I5: the structural exactness lemma — every format the Rust test asserts
# bitwise (mxint/int) — and in fact bmf/fp8 too — must never hit the
# span>63 fallback in 2-wide GEMM segments; only BL (and potentially raw
# fp32) may. This is what licenses asserting bit-equality of the loss.
ok = True
for fmt, st in sorted(SEG_EXACT.items()):
    pct = 100.0 * st["fallback"] / max(st["count"], 1)
    print(f"  {fmt}: {st['count']} segments, {st['fallback']} fallback ({pct:.4f}%)")
    if fmt in ("mxint", "int", "bmf", "fp8"):
        ok &= st["fallback"] == 0
check("I5 span<=63 holds for every mxint/int/bmf/fp8 segment (bitwise lemma)", ok)

# I6 (optional, needs jax): the interpreter mirror vs the REAL L2 model —
# same weights (mirror init flattened in param_spec order), same tokens,
# eval_batch loss/correct must agree to f32 noise. This pins the
# interpreter's semantics (embed+pos, pinned-outlier LN, MHA, gelu,
# pooled head, loss) to the true oracle, not just to itself.
try:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "python"))
    from compile import model as M
    import jax.numpy as jnp

    cfg = M.ModelConfig("tiny", 1, 32, 2, vocab=512, seq_len=16, n_classes=4,
                        kind="classifier", batch=16)
    flat = np.concatenate([net.p[name].ravel() for name, _ in net.spec]).astype(f32)
    assert flat.size == M.param_size(cfg)
    toks, labs = bat[0]
    ok = True
    for fmt, bits_ in [("fp32", 32.0), ("mxint", 4.0), ("bmf", 5.0)]:
        qc = np.zeros((M.num_qtensors(cfg), 2), f32)
        qc[:, 0] = bits_
        jloss, jcorrect = M.eval_batch(
            cfg, jnp.asarray(flat), jnp.asarray(toks.astype(np.int32)),
            jnp.asarray(labs.astype(np.int32)), jnp.asarray(qc), fmt=fmt)
        my_loss, my_correct = net.eval_batch(toks, labs, fmt,
                                             qcfg_uniform(1, bits_), "reference")
        rel = abs(float(jloss) - float(my_loss)) / max(abs(float(jloss)), 1e-9)
        print(f"  {fmt}: L2 jax loss {float(jloss):.6f}/{int(jcorrect)} vs "
              f"interp {float(my_loss):.6f}/{my_correct} (rel {rel:.2e})")
        ok &= rel < 2e-3 and int(jcorrect) == my_correct
    check("I6 interpreter semantics match the real L2 jax model", ok)
except ImportError as e:
    print(f"  (I6 skipped: jax/L2 model unavailable here: {e})")

# ================= PR 7: KV-cache decode mirror (runtime/decode.rs) ======
print()
print("== PR 7 decode mirror: KV-cached decode vs full recompute ==")


def d_attn_row(q64, K64, V64, scale, n_ctx, buf_len):
    """One attention query row, mirroring interp.rs::attn_query_row.

    Scores for j < n_ctx, -1e9 mask tail up to buf_len, then softmax and
    the f64 value mix. Sums are SEQUENTIAL f64 (matching the Rust loops,
    not numpy pairwise) so that a trailing mask region is an exact no-op:
    exp(-1e9 - m) -> 0.0f32, and appending +0.0 to the softmax sum or
    +0.0*v to the mix never changes a partial. That lemma is what makes
    the cached single-query call (buf_len == n_ctx) bitwise equal to the
    full causal row (buf_len == s)."""
    att = np.full(buf_len, f32(-1e9), f32)
    for j in range(n_ctx):
        att[j] = f32(np.float64((q64 * K64[j]).sum())) / scale
    m = att.max()
    e = np.exp(att - m, dtype=f32)
    tot = 0.0
    for v in e:
        tot += float(v)
    att_n = (e.astype(np.float64) / tot).astype(f32)
    acc = np.zeros(V64.shape[1], np.float64)
    for j in range(buf_len):
        acc += np.float64(att_n[j]) * V64[j]
    return acc.astype(f32)


class DecodeNet(Net):
    """Decode-convention forward (runtime/decode.rs mirror): activations
    are position-major [t*b, d] (row si*b + bi), so each position's b rows
    fill whole (16,2) blocks (b % 16 == 0) and the blocking of old
    positions is independent of how many positions exist — the property a
    KV cache needs and the batch-major [b*s, d] layout lacks (K5)."""

    def attn_full(self, qkv3, b, t, d):
        heads = self.heads
        dh = d // heads
        scale = f32(np.sqrt(f32(dh)))
        out = np.zeros((b, t, d), f32)
        for bi in range(b):
            for h in range(heads):
                off = h * dh
                K = qkv3[bi, :, d + off:d + off + dh].astype(np.float64)
                V = qkv3[bi, :, 2 * d + off:2 * d + off + dh].astype(np.float64)
                for si in range(t):
                    q = qkv3[bi, si, off:off + dh].astype(np.float64)
                    out[bi, si, off:off + dh] = d_attn_row(q, K, V, scale, si + 1, t)
        return out

    def forward_block(self, tokens, fmt, qcfg, path, cache=None):
        """Full forward over tokens [b, t] in the decode convention.
        cache (if a list) is filled with per-layer [K, V] of [b, t, dh*h].
        Returns position-major logits [t, b, out_dim]."""
        b, t = tokens.shape
        d = self.d
        x = np.concatenate(
            [(self.p["embed"][tokens[:, si]] + self.p["pos"][si][None, :])
             for si in range(t)], axis=0).astype(f32)
        for i in range(self.L):
            pre = f"layer{i}."
            h = layer_norm(x, self.p[pre + "ln1_g"], self.p[pre + "ln1_b"], i)
            qkv = self.qmm(h, pre + "a_attn_in", pre + "w_qkv", fmt, qcfg, path)
            qkv3 = qkv.reshape(t, b, 3 * d).transpose(1, 0, 2)
            if cache is not None:
                cache.append([qkv3[:, :, d:2 * d].copy(), qkv3[:, :, 2 * d:].copy()])
            o = self.attn_full(qkv3, b, t, d)
            o = self.qmm(o.transpose(1, 0, 2).reshape(t * b, d),
                         pre + "a_proj_in", pre + "w_proj", fmt, qcfg, path)
            x = (x + o).astype(f32)
            h = layer_norm(x, self.p[pre + "ln2_g"], self.p[pre + "ln2_b"], i)
            h = self.qmm(h, pre + "a_fc1_in", pre + "w_fc1", fmt, qcfg, path)
            h = gelu(h)
            h = self.qmm(h, pre + "a_fc2_in", pre + "w_fc2", fmt, qcfg, path)
            x = (x + h).astype(f32)
        xf = layer_norm(x, self.p["lnf_g"], self.p["lnf_b"], None)
        logits = self.qmm(xf, "a_head_in", "head_w", fmt, qcfg, path)
        return logits.reshape(t, b, self.out_dim)

    def decode_step(self, toks, pos_idx, cache, fmt, qcfg, path):
        """One token per sequence through the layers, appending K/V to the
        cache and attending with the single-query row. Returns [b, V]."""
        b = toks.shape[0]
        d = self.d
        heads = self.heads
        dh = d // heads
        scale = f32(np.sqrt(f32(dh)))
        x = (self.p["embed"][toks] + self.p["pos"][pos_idx][None, :]).astype(f32)
        for i in range(self.L):
            pre = f"layer{i}."
            h = layer_norm(x, self.p[pre + "ln1_g"], self.p[pre + "ln1_b"], i)
            qkv = self.qmm(h, pre + "a_attn_in", pre + "w_qkv", fmt, qcfg, path)
            K = np.concatenate([cache[i][0], qkv[:, None, d:2 * d]], axis=1)
            V = np.concatenate([cache[i][1], qkv[:, None, 2 * d:]], axis=1)
            cache[i] = [K, V]
            t1 = K.shape[1]
            o = np.zeros((b, d), f32)
            for bi in range(b):
                for hh in range(heads):
                    off = hh * dh
                    o[bi, off:off + dh] = d_attn_row(
                        qkv[bi, off:off + dh].astype(np.float64),
                        K[bi, :, off:off + dh].astype(np.float64),
                        V[bi, :, off:off + dh].astype(np.float64),
                        scale, t1, t1)
            o = self.qmm(o, pre + "a_proj_in", pre + "w_proj", fmt, qcfg, path)
            x = (x + o).astype(f32)
            h = layer_norm(x, self.p[pre + "ln2_g"], self.p[pre + "ln2_b"], i)
            h = self.qmm(h, pre + "a_fc1_in", pre + "w_fc1", fmt, qcfg, path)
            h = gelu(h)
            h = self.qmm(h, pre + "a_fc2_in", pre + "w_fc2", fmt, qcfg, path)
            x = (x + h).astype(f32)
        xf = layer_norm(x, self.p["lnf_g"], self.p["lnf_b"], None)
        return self.qmm(xf, "a_head_in", "head_w", fmt, qcfg, path)


def cached_run(netD, toks0, p0, n_steps, fmt, qc, path, greedy):
    """Prefill p0 positions (batched, fills the cache), then n_steps
    decode steps — greedy argmax continuations or teacher-forced tokens.
    Returns (tokens [b, p0+n_steps], per-step logits [b, V] list)."""
    cache = []
    lg_pre = netD.forward_block(toks0[:, :p0], fmt, qc, path, cache)
    step_logits = [lg_pre[si] for si in range(p0)]
    toks = toks0[:, :p0]
    for t in range(p0, p0 + n_steps):
        nxt = step_logits[-1].argmax(axis=1) if greedy else toks0[:, t]
        nxt = nxt.astype(toks0.dtype)
        toks = np.concatenate([toks, nxt[:, None]], axis=1)
        step_logits.append(netD.decode_step(nxt, t, cache, fmt, qc, path))
    return toks, step_logits


def nll_score(step_logits, toks):
    """Teacher-forced next-token NLL + argmax-correct, accumulated
    bi-outer/si-inner like interp.rs::eval_batch (shared by the cached and
    oracle paths so logit equality implies loss bit-equality)."""
    b, T = toks.shape
    nll_sum, correct = 0.0, 0
    for bi in range(b):
        for si in range(T - 1):
            lg = step_logits[si][bi].astype(np.float64)
            m = lg.max()
            nll_sum += m + math.log(np.exp(lg - m).sum()) - lg[toks[bi, si + 1]]
            correct += int(lg.argmax() == toks[bi, si + 1])
    return f32(nll_sum / (b * (T - 1))), correct


lmD = DecodeNet(kind="lm")
toksD = MarkovCorpus(7).batch(700, 16, 16)
int_frac4 = {n: 4.0 for n in qtensor_names(1)}

# K1: cached decode vs full recompute of every prefix, all five formats.
ok_exact, ok_tol, worst = True, True, 0.0
for fmt, bits_, fracs, p0, greedy in [
    ("mxint", 7.0, None, 3, True),
    ("mxint", 6.0, None, 1, True),   # prompt-len-1 edge
    ("int", 8.0, int_frac4, 3, True),
    ("bmf", 5.0, None, 3, False),
    ("bl", 7.0, None, 3, False),
    ("fp8", 8.0, None, 3, False),
]:
    qc = qcfg_uniform(1, bits_, fracs)
    toks, steps = cached_run(lmD, toksD, p0, 16 - p0, fmt, qc, "packed", greedy)
    exact = fmt in ("mxint", "int")
    for t in range(toks.shape[1]):
        oracle = lmD.forward_block(toks[:, :t + 1], fmt, qc, "packed")[-1]
        if exact:
            ok_exact &= steps[t].tobytes() == oracle.tobytes()
            # generated tokens start at step p0-1; earlier next-tokens
            # are prompt tokens, not argmaxes
            if greedy and p0 - 1 <= t < toks.shape[1] - 1:
                ok_exact &= bool((oracle.argmax(axis=1) == toks[:, t + 1]).all())
        else:
            rel = float(np.abs(steps[t].astype(np.float64) - oracle.astype(np.float64)).max()
                        / max(float(np.abs(oracle).max()), 1e-12))
            worst = max(worst, rel)
            ok_tol &= rel < 1e-6
    l_c, c_c = nll_score(steps, toks)
    oracle_steps = [lmD.forward_block(toks[:, :t + 1], fmt, qc, "packed")[-1]
                    for t in range(toks.shape[1])]
    l_o, c_o = nll_score(oracle_steps, toks)
    if exact:
        ok_exact &= bits64(float(l_c)) == bits64(float(l_o)) and c_c == c_o
    mode = "greedy" if greedy else "forced"
    print(f"  {fmt}{int(bits_)} p0={p0} {mode}: loss {l_c:.6f} correct {c_c} "
          f"(oracle {l_o:.6f}/{c_o})")
check("K1 mxint/int cached decode bitwise == full recompute at every step "
      "(tokens, logits, loss; incl. prompt len 1)", ok_exact)
check(f"K1b bmf/bl/fp8 cached decode rel delta < 1e-6 (worst {worst:.3e})", ok_tol)

# K2: the mask-tail lemma in isolation — single-query row vs full causal
# row with garbage (but finite) K/V rows beyond the context.
krng = np.random.default_rng(7)
dh = 16
K2 = krng.standard_normal((19, dh)).astype(f32).astype(np.float64)
V2 = krng.standard_normal((19, dh)).astype(f32).astype(np.float64)
q2 = krng.standard_normal(dh).astype(f32).astype(np.float64)
sc = f32(np.sqrt(f32(dh)))
full = d_attn_row(q2, K2, V2, sc, 11, 19)
single = d_attn_row(q2, K2[:11], V2[:11], sc, 11, 11)
check("K2 single-query row bitwise == full causal row (mask tail is a no-op)",
      full.tobytes() == single.tobytes())

# K3: position-major [p*b, k] blocking == stacked per-position [b, k].
ok = True
x3 = krng.standard_normal((5 * 16, 32)).astype(f32)
for fmt, bits_ in [("mxint", 7.0), ("bmf", 5.0), ("bl", 7.0)]:
    whole = quantize2d(fmt, x3, bits_, 0.0)
    per = np.vstack([quantize2d(fmt, x3[p * 16:(p + 1) * 16], bits_, 0.0)
                     for p in range(5)])
    ok &= whole.tobytes() == per.tobytes()
check("K3 position-major blocking == per-position blocking (b=16, block fmts)", ok)

# K4: element-wise formats — decode convention vs batch-major forward.
# (Bitwise in Rust where both share sequential sums; here the batch-major
# attention uses numpy matmul, so assert a tight tolerance instead.)
ok = True
for fmt, bits_, fracs in [("int", 8.0, int_frac4), ("fp32", 32.0, None)]:
    qc = qcfg_uniform(1, bits_, fracs)
    lgD = lmD.forward_block(toksD, fmt, qc, "packed")           # [t, b, V]
    lgB = lmD.forward(toksD, fmt, qc, "packed")                 # [b, t, V]
    rel = float(np.abs(lgD.transpose(1, 0, 2).astype(np.float64)
                       - lgB.astype(np.float64)).max()
                / max(float(np.abs(lgB).max()), 1e-12))
    same_tok = bool((lgD.transpose(1, 0, 2).argmax(axis=2)
                     == lgB.argmax(axis=2)).all())
    print(f"  {fmt}: decode-convention vs batch-major rel {rel:.3e}")
    ok &= rel < 1e-6 and same_tok
check("K4 element-wise decode convention matches batch-major forward", ok)

# K5: negative control — block formats MUST differ between the two
# layouts (this is exactly why a batch-major KV cache cannot be bitwise).
qc = qcfg_uniform(1, 7.0)
lgD = lmD.forward_block(toksD, "mxint", qc, "packed")
lgB = lmD.forward(toksD, "mxint", qc, "packed")
check("K5 negative control: mxint batch-major forward differs from the "
      "decode convention", lgD.transpose(1, 0, 2).tobytes() != lgB.tobytes())

print()
print("ALL PASS" if not fails else f"{len(fails)} FAILURES: {fails}")
sys.exit(1 if fails else 0)
