# pytest: L2 model — shapes, quant sensitivity, training behaviour.
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.MODEL_ZOO["opt-125m-sim"]
LM = M.MODEL_ZOO["llama-sim"]


@pytest.fixture(scope="module")
def setup():
    p = M.init_params(CFG, 0)
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)), jnp.int32)
    lab = jnp.asarray(rng.integers(0, 2, (CFG.batch,)), jnp.int32)
    return p, tok, lab


def _qc(cfg, bits, frac=0.0):
    c = jnp.full((M.num_qtensors(cfg), 2), float(bits))
    return c.at[:, 1].set(float(frac))


class TestParamPacking:
    def test_param_size_matches_spec(self):
        total = 0
        for _, shape in M.param_spec(CFG):
            n = 1
            for s in shape:
                n *= s
            total += n
        assert total == M.param_size(CFG)

    def test_unpack_shapes(self):
        p = M.unpack_params(CFG, M.init_params(CFG, 0))
        for name, shape in M.param_spec(CFG):
            assert p[name].shape == shape

    def test_qtensor_count(self):
        assert len(M.qtensor_names(CFG)) == M.num_qtensors(CFG)
        assert M.num_qtensors(CFG) == 8 * CFG.n_layers + 2

    def test_all_zoo_dims_tile_into_blocks(self):
        for cfg in M.MODEL_ZOO.values():
            assert cfg.d_model % 16 == 0
            assert cfg.seq_len % 16 == 0
            assert (cfg.batch * cfg.seq_len) % 16 == 0
            assert cfg.d_ff % 16 == 0
            assert cfg.n_classes % 2 == 0


class TestForward:
    def test_classifier_logit_shape(self, setup):
        p, tok, _ = setup
        out = M.forward(CFG, p, tok, _qc(CFG, 7), "mxint")
        assert out.shape == (CFG.batch, CFG.n_classes)

    def test_lm_logit_shape(self):
        p = M.init_params(LM, 1)
        tok = jnp.zeros((LM.batch, LM.seq_len), jnp.int32)
        out = M.forward(LM, p, tok, _qc(LM, 7), "mxint")
        assert out.shape == (LM.batch, LM.seq_len, LM.vocab)

    def test_fp32_ignores_qconfig(self, setup):
        p, tok, _ = setup
        a = M.forward(CFG, p, tok, _qc(CFG, 2), "fp32")
        b = M.forward(CFG, p, tok, _qc(CFG, 8), "fp32")
        np.testing.assert_array_equal(a, b)

    def test_quant_error_decreases_with_bits(self, setup):
        p, tok, _ = setup
        exact = M.forward(CFG, p, tok, _qc(CFG, 8), "fp32")
        errs = []
        for bits in [2, 4, 8]:
            q = M.forward(CFG, p, tok, _qc(CFG, bits), "mxint")
            errs.append(float(jnp.mean(jnp.abs(q - exact))))
        assert errs[0] > errs[1] > errs[2]

    def test_pallas_path_matches_jnp_path(self, setup):
        # The L1 Pallas kernel inside the full model == the jnp emulation.
        p, tok, _ = setup
        a = M.forward(CFG, p, tok, _qc(CFG, 5), "mxint")
        b = M.forward(CFG, p, tok, _qc(CFG, 5), "mxint_pallas")
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_mixed_precision_config_is_per_tensor(self, setup):
        # Changing one tensor's bits changes the output; others' rows are
        # genuinely independent knobs.
        p, tok, _ = setup
        base = M.forward(CFG, p, tok, _qc(CFG, 4), "mxint")
        c2 = _qc(CFG, 4).at[1, 0].set(8.0)  # layer0.w_qkv
        alt = M.forward(CFG, p, tok, c2, "mxint")
        assert float(jnp.max(jnp.abs(alt - base))) > 0


class TestLossAndTraining:
    def test_train_step_reduces_loss(self, setup):
        p, tok, lab = setup
        # A few steps on one batch must reduce its loss (overfit check).
        losses = []
        for _ in range(25):
            # lr matched to the coordinator's stable schedule: the injected
            # outlier channels make lr=0.5 oscillate on a single batch
            p, l = M.train_step(CFG, p, tok, lab, jnp.float32(0.15))
            losses.append(float(l))
        assert min(losses[-5:]) < losses[0]

    def test_qat_step_reduces_quantized_loss(self, setup):
        p, tok, lab = setup
        qc = _qc(CFG, 3)
        losses = []
        for _ in range(25):
            p, l = M.qat_step(CFG, p, tok, lab, qc, jnp.float32(0.15), "mxint")
            losses.append(float(l))
        assert min(losses[-5:]) < losses[0]

    def test_lm_loss_is_log_perplexity(self):
        # Untrained LM on uniform random tokens: NLL close to log(vocab).
        p = M.init_params(LM, 2)
        rng = np.random.default_rng(3)
        tok = jnp.asarray(rng.integers(0, LM.vocab, (LM.batch, LM.seq_len)), jnp.int32)
        loss, _ = M.eval_batch(LM, p, tok, jnp.zeros((LM.batch,), jnp.int32),
                               _qc(LM, 7), "fp32")
        assert abs(float(loss) - np.log(LM.vocab)) < 1.0

    def test_eval_batch_correct_count_bounds(self, setup):
        p, tok, lab = setup
        _, corr = M.eval_batch(CFG, p, tok, lab, _qc(CFG, 7), "mxint")
        assert 0 <= int(corr) <= CFG.batch


class TestProfile:
    def test_profile_shape_and_positivity(self, setup):
        p, tok, _ = setup
        st = M.profile_forward(CFG, p, tok)
        assert st.shape == (M.num_qtensors(CFG), 3)
        assert bool(jnp.all(st[:, 1] > 0))  # absmax of every tensor > 0

    def test_profile_absmax_bounds_absmean(self, setup):
        p, tok, _ = setup
        st = M.profile_forward(CFG, p, tok)
        assert bool(jnp.all(st[:, 1] >= st[:, 2]))
