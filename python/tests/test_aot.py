# pytest: AOT pipeline — lowering produces loadable HLO text + manifest.
import json

import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


class TestLowering:
    def test_quant_ref_lowers_to_hlo_text(self):
        text = aot.to_hlo_text(aot.lower_quant_ref("mxint"))
        assert "HloModule" in text
        assert "ENTRY" in text

    @pytest.mark.parametrize("entry,fmt", [
        ("eval", "mxint"), ("eval", "int"), ("profile", "fp32"),
        ("train", "fp32"), ("qat", "mxint"),
    ])
    def test_entries_lower(self, entry, fmt):
        cfg = M.MODEL_ZOO["opt-125m-sim"]
        text = aot.to_hlo_text(aot.lower_entry(cfg, entry, fmt))
        assert text.startswith("HloModule")

    def test_pallas_variant_lowers_to_plain_hlo(self):
        # interpret=True must not leave custom-calls the CPU PJRT client
        # cannot execute (a real-TPU lowering would emit Mosaic calls).
        cfg = M.MODEL_ZOO["opt-125m-sim"]
        text = aot.to_hlo_text(aot.lower_entry(cfg, "eval", "mxint_pallas"))
        assert "custom-call" not in text or "Mosaic" not in text


class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self, tmp_path_factory):
        return aot.build_manifest(str(tmp_path_factory.mktemp("a")))

    def test_every_model_present(self, manifest):
        assert set(manifest["models"]) == set(M.MODEL_ZOO)

    def test_param_spec_offsets_are_dense(self, manifest):
        for name, meta in manifest["models"].items():
            off = 0
            for ent in meta["param_spec"]:
                assert ent["offset"] == off
                n = 1
                for s in ent["shape"]:
                    n *= s
                off += n
            assert off == meta["param_size"]

    def test_qtensor_order_matches_model(self, manifest):
        for name, meta in manifest["models"].items():
            assert meta["qtensors"] == M.qtensor_names(M.MODEL_ZOO[name])

    def test_block_config_matches_paper(self, manifest):
        assert manifest["block_shape"] == [16, 2]
        assert manifest["shared_exponent_bits"] == 8

    def test_manifest_is_json_serializable(self, manifest):
        json.dumps(manifest)
