# pytest: Pallas kernel vs pure-jnp oracle — the CORE correctness signal.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.mxint_gemm import (
    mxint_qmatmul,
    mxint_quantize_pallas,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


class TestQMatmulVsRef:
    @pytest.mark.parametrize("m_bits", [2.0, 4.0, 7.0])
    def test_square_matches_ref(self, m_bits):
        a, b = _rand((32, 32), 0), _rand((32, 32), 1)
        got = mxint_qmatmul(a, b, m_bits, m_bits)
        want = ref.mxint_matmul_ref(a, b, m_bits, m_bits)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_rectangular(self):
        a, b = _rand((16, 64), 2), _rand((64, 48), 3)
        got = mxint_qmatmul(a, b, 5.0, 3.0)
        want = ref.mxint_matmul_ref(a, b, 5.0, 3.0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_multi_k_tile_accumulation(self):
        # K spans several grid steps: exercises the in-place accumulate.
        a, b = _rand((16, 128), 4), _rand((128, 16), 5)
        got = mxint_qmatmul(a, b, 6.0, 6.0, bk=32)
        want = ref.mxint_matmul_ref(a, b, 6.0, 6.0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_mixed_operand_precision(self):
        a, b = _rand((32, 32), 6), _rand((32, 32), 7)
        lo = mxint_qmatmul(a, b, 2.0, 2.0)
        hi = mxint_qmatmul(a, b, 8.0, 8.0)
        exact = a @ b
        # Higher mantissa width must be closer to the exact product.
        assert jnp.mean(jnp.abs(hi - exact)) < jnp.mean(jnp.abs(lo - exact))

    def test_traced_mantissa_bits(self):
        # The mantissa width is a runtime input — one HLO serves all widths.
        a, b = _rand((16, 32), 8), _rand((32, 16), 9)

        def f(m):
            return mxint_qmatmul(a, b, m, m)

        for m in [2.0, 3.0, 7.0]:
            got = jax.jit(f)(jnp.float32(m))
            want = ref.mxint_matmul_ref(a, b, m, m)
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        mi=st.integers(1, 4),
        ki=st.integers(1, 4),
        ni=st.integers(1, 4),
        m_a=st.integers(2, 8),
        m_b=st.integers(2, 8),
        seed=st.integers(0, 2**31 - 1),
        scale=st.sampled_from([0.01, 1.0, 100.0]),
    )
    def test_hypothesis_shape_sweep(self, mi, ki, ni, m_a, m_b, seed, scale):
        a = _rand((16 * mi, 16 * ki), seed, scale)
        b = _rand((16 * ki, 16 * ni), seed + 1, scale)
        got = mxint_qmatmul(a, b, float(m_a), float(m_b))
        want = ref.mxint_matmul_ref(a, b, float(m_a), float(m_b))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale)


class TestQuantizePallasVsRef:
    @pytest.mark.parametrize("m_bits", [1.0, 3.0, 7.0, 10.0])
    def test_matches_ref(self, m_bits):
        x = _rand((64, 32), 10)
        got = mxint_quantize_pallas(x, m_bits)
        want = ref.mxint_quantize(x, m_bits)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)

    @settings(max_examples=25, deadline=None)
    @given(
        ri=st.integers(1, 6),
        ci=st.integers(1, 8),
        m=st.integers(1, 12),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, ri, ci, m, seed):
        x = _rand((16 * ri, 2 * ci), seed)
        got = mxint_quantize_pallas(x, float(m), bn=2)
        want = ref.mxint_quantize(x, float(m))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)

    def test_tile_independence(self):
        # Quantizing tile-by-tile must equal whole-tensor quantization:
        # blocks never straddle tile boundaries.
        x = _rand((64, 64), 11)
        got_small = mxint_quantize_pallas(x, 4.0, bm=16, bn=16)
        got_big = mxint_quantize_pallas(x, 4.0, bm=64, bn=64)
        np.testing.assert_array_equal(got_small, got_big)


class TestStructuralEstimates:
    def test_vmem_footprint_monotone(self):
        assert vmem_footprint_bytes(32, 32, 32) < vmem_footprint_bytes(64, 64, 64)

    def test_vmem_fits_budget(self):
        # The default artifact tiling must fit comfortably in 16 MiB VMEM.
        assert vmem_footprint_bytes(16, 16, 16) < 16 * 2**20

    def test_mxu_utilization_bounds(self):
        assert 0.0 < mxu_utilization_estimate(16, 16, 16) <= 1.0
        assert mxu_utilization_estimate(128, 128, 128) == 1.0
