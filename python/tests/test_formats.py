# pytest + hypothesis: properties of the fake-quantization oracles.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


@st.composite
def tensors(draw):
    r = 16 * draw(st.integers(1, 4))
    c = 2 * draw(st.integers(1, 16))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = draw(st.sampled_from([1e-3, 1.0, 1e3]))
    return _rand((r, c), seed, scale)


class TestMXInt:
    @settings(max_examples=30, deadline=None)
    @given(x=tensors(), m=st.integers(1, 12))
    def test_idempotent(self, x, m):
        q1 = ref.mxint_quantize(x, float(m))
        q2 = ref.mxint_quantize(q1, float(m))
        np.testing.assert_allclose(q1, q2, rtol=1e-6, atol=1e-8)

    @settings(max_examples=30, deadline=None)
    @given(x=tensors(), m=st.integers(2, 10))
    def test_error_bounded_by_block_step(self, x, m):
        # |x - q(x)| <= half a quantization step of the block it is in;
        # saturation (mantissa clamp at +-(2^m - 1)) can cost up to one
        # full step on the block's extreme element.
        q = np.asarray(ref.mxint_quantize(x, float(m)))
        xb, _ = ref._to_blocks(jnp.asarray(x))
        e = np.asarray(ref._shared_exponent(xb))
        step = 2.0 ** (e + 1.0 - m)
        err_b = np.abs(np.asarray(ref._to_blocks(jnp.asarray(q - np.asarray(x)))[0]))
        assert np.all(err_b <= step * 1.0 + 1e-12)

    @settings(max_examples=30, deadline=None)
    @given(x=tensors(), m=st.integers(2, 10))
    def test_monotone_in_mantissa_bits(self, x, m):
        e_lo = jnp.mean(jnp.abs(ref.mxint_quantize(x, float(m)) - x))
        e_hi = jnp.mean(jnp.abs(ref.mxint_quantize(x, float(m + 2)) - x))
        assert e_hi <= e_lo + 1e-9

    def test_zero_block_stays_zero(self):
        x = jnp.zeros((16, 2))
        np.testing.assert_array_equal(ref.mxint_quantize(x, 4.0), x)

    def test_sign_symmetry(self):
        x = _rand((32, 8), 0)
        np.testing.assert_allclose(
            ref.mxint_quantize(-x, 5.0), -ref.mxint_quantize(x, 5.0), atol=0
        )

    def test_1d_tensor_blocks(self):
        x = _rand((64,), 1)
        q = ref.mxint_quantize(x, 6.0)
        assert q.shape == x.shape
        assert float(jnp.mean(jnp.abs(q - x))) < 0.02

    def test_preserves_large_dynamic_range_across_blocks(self):
        # Each block gets its own exponent: a tensor whose blocks span a
        # 2^20 range must keep per-block relative error small — the whole
        # point of microscaling (paper Fig. 1a motivation).
        blocks = [jnp.full((16, 2), 2.0**k) for k in range(0, 20, 4)]
        x = jnp.concatenate(blocks, axis=1)
        q = ref.mxint_quantize(x, 4.0)
        rel = jnp.abs(q - x) / x
        assert float(jnp.max(rel)) < 0.1

    def test_high_mantissa_exact_on_powers_of_two(self):
        x = jnp.asarray([[2.0 ** (i % 5) for _ in range(2)] for i in range(16)])
        np.testing.assert_allclose(ref.mxint_quantize(x, 12.0), x, rtol=1e-4)


class TestBMF:
    @settings(max_examples=20, deadline=None)
    @given(x=tensors(), m=st.integers(1, 6))
    def test_idempotent(self, x, m):
        q1 = ref.bmf_quantize(x, float(m))
        q2 = ref.bmf_quantize(q1, float(m))
        np.testing.assert_allclose(q1, q2, rtol=1e-5, atol=1e-8)

    def test_flushes_small_values_in_block(self):
        # Limited local range: elements far below the block max vanish —
        # the mechanism behind the catastrophic BMF8 row of Table 1.
        x = jnp.full((16, 2), 1e-6).at[0, 0].set(1.0)
        q = ref.bmf_quantize(x, 4.0, exp_bits=2.0)
        assert float(q[0, 0]) == pytest.approx(1.0, rel=0.1)
        assert float(jnp.sum(jnp.abs(q[1:, :]))) == 0.0

    def test_keeps_near_peak_values(self):
        x = jnp.full((16, 2), 0.5).at[0, 0].set(1.0)
        q = ref.bmf_quantize(x, 4.0)
        np.testing.assert_allclose(q, x, rtol=0.1)


class TestBL:
    @settings(max_examples=20, deadline=None)
    @given(x=tensors(), eb=st.integers(3, 8))
    def test_values_are_powers_of_two(self, x, eb):
        q = np.asarray(ref.bl_quantize(x, float(eb)))
        nz = q[q != 0]
        log = np.log2(np.abs(nz))
        np.testing.assert_allclose(log, np.round(log), atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(x=tensors(), eb=st.integers(3, 8))
    def test_idempotent(self, x, eb):
        q1 = ref.bl_quantize(x, float(eb))
        q2 = ref.bl_quantize(q1, float(eb))
        np.testing.assert_allclose(q1, q2, rtol=1e-5, atol=1e-8)

    def test_relative_error_bounded(self):
        # Power-of-two grid: worst case ~2^(1/2) relative step.
        x = _rand((32, 16), 2) + 3.0
        q = ref.bl_quantize(x, 7.0)
        rel = jnp.abs(q - x) / jnp.abs(x)
        assert float(jnp.max(rel)) < 0.5


class TestInt:
    @settings(max_examples=30, deadline=None)
    @given(x=tensors(), w=st.integers(3, 12), f=st.integers(0, 10))
    def test_idempotent(self, x, w, f):
        q1 = ref.int_quantize(x, float(w), float(f))
        q2 = ref.int_quantize(q1, float(w), float(f))
        np.testing.assert_allclose(q1, q2, atol=1e-8)

    def test_saturates(self):
        x = jnp.asarray([[1e6, -1e6]])
        q = ref.int_quantize(x, 8.0, 4.0)
        np.testing.assert_allclose(q, [[127 / 16.0, -128 / 16.0]])

    def test_grid_is_scaled_integers(self):
        x = _rand((16, 4), 3)
        q = np.asarray(ref.int_quantize(x, 8.0, 5.0)) * 32.0
        np.testing.assert_allclose(q, np.round(q), atol=1e-5)

    def test_no_dynamic_range(self):
        # Fixed-point cannot represent both 1e-4 and 1e4 with 8 bits: this
        # is the Fig. 1a failure that motivates MX formats.
        x = jnp.asarray([[1e-4, 1e4]])
        q = ref.int_quantize(x, 8.0, 0.0)
        assert float(q[0, 0]) == 0.0  # small value lost entirely
        assert float(q[0, 1]) == 127.0  # large value saturated


class TestMinifloat:
    @settings(max_examples=20, deadline=None)
    @given(x=tensors())
    def test_idempotent(self, x):
        q1 = ref.minifloat_quantize(x)
        q2 = ref.minifloat_quantize(q1)
        np.testing.assert_allclose(q1, q2, rtol=1e-5, atol=1e-8)

    def test_known_values_fp8_e4m3_bias7(self):
        x = jnp.asarray([1.0, 1.125, 240.0, 1000.0, 2.0**-7, 0.0])
        q = np.asarray(ref.minifloat_quantize(x.reshape(1, -1))).ravel()
        assert q[0] == 1.0
        assert q[1] == 1.125  # exactly representable with 3 mantissa bits
        assert q[2] == 240.0  # top of the range
        assert q[3] == 240.0  # saturation
        assert q[4] == 2.0**-7  # smallest normal
        assert q[5] == 0.0


class TestAverageBitwidth:
    def test_paper_example(self):
        # MXInt((16,2), 8, 7) has average bitwidth 8.25 (paper §4.1).
        assert ref.average_bitwidth(7.0) == pytest.approx(8.25)

    def test_eq1(self):
        assert ref.average_bitwidth(3.0, block=(8, 4), shared_bits=8.0) == (
            pytest.approx(8.0 / 32.0 + 4.0)
        )
