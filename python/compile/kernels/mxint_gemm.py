"""L1 — Pallas kernels for the MXInt dataflow operators.

These kernels are the TPU re-thinking of the paper's FPGA dataflow
operators (Fig. 3, right): the streaming tiles of the FPGA design become
``BlockSpec`` tiles scheduled HBM->VMEM, and the block-shared exponent is
extracted by a small in-VMEM reduction before the MAC array — the same
structural trick that lets the FPGA MXInt operator drop the per-element
dynamic shifter.

Everything is lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness target
and the TPU mapping is analyzed structurally (DESIGN.md §Hardware-
Adaptation, EXPERIMENTS.md §Perf/L1).

Correctness oracle: :mod:`compile.kernels.ref` (pytest + hypothesis).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BLOCK_SHAPE, SHARED_EXP_MAX, SHARED_EXP_MIN, _pow2

_EPS = 1e-30


def _quant_tile(x, m, block_rows, block_cols):
    """Block-quantize a 2-D tile already resident in VMEM.

    Independent implementation of MXInt fake-quant (kept deliberately
    separate from ref.py so the pytest comparison is meaningful): reshape
    the tile into (block_rows, block_cols) blocks, extract the shared
    exponent with a per-block max-reduction, round mantissas.
    """
    r, c = x.shape
    xb = x.reshape(r // block_rows, block_rows, c // block_cols, block_cols)
    maxabs = jnp.max(jnp.abs(xb), axis=(1, 3), keepdims=True)
    e = jnp.floor(jnp.log2(jnp.maximum(maxabs, _EPS)))
    e = jnp.clip(e, SHARED_EXP_MIN, SHARED_EXP_MAX)
    m = jnp.maximum(m, 1.0)
    scale = _pow2(e + 1.0 - m)
    qmax = _pow2(m) - 1.0
    q = jnp.clip(jnp.round(xb / scale), -qmax, qmax) * scale
    return q.reshape(r, c)


def _qmatmul_kernel(a_ref, b_ref, ma_ref, mb_ref, o_ref, *, block):
    """One (i, j, k) grid step: quantize the A and B tiles, MAC into O.

    The K axis is the innermost grid dim; O is revisited across k steps and
    accumulated in place (the FPGA design's running dot-product register).
    """
    br, bc = block

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # A streams row-major: blocks are (br x bc) over (M, K).
    qa = _quant_tile(a_ref[...], ma_ref[0, 0], br, bc)
    # B streams column-major: blocks are (br x bc) over (K, N).
    qb = _quant_tile(b_ref[...], mb_ref[0, 0], br, bc)
    o_ref[...] += jnp.dot(qa, qb, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def mxint_qmatmul(a, b, m_a, m_b, *, bm=16, bk=16, bn=16, interpret=True):
    """MXInt dot-product operator: ``mxint_q(a) @ mxint_q(b)``.

    ``m_a``/``m_b`` are (possibly traced) mantissa bitwidths for the two
    operands — the mixed-precision knobs the Rust search turns.

    Tile sizes must keep (16, 2) blocks intact: ``bm`` and ``bk`` must be
    multiples of 16 (K-blocks of B span 16 rows), ``bn`` a multiple of 2.
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, f"contraction mismatch {K} vs {K2}"
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    br, bc = BLOCK_SHAPE
    assert bm % br == 0 and bk % br == 0, (bm, bk)
    assert bk % bc == 0 and bn % bc == 0, (bk, bn)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (M, K, N)

    ma = jnp.asarray(m_a, jnp.float32).reshape(1, 1)
    mb = jnp.asarray(m_b, jnp.float32).reshape(1, 1)
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_qmatmul_kernel, block=BLOCK_SHAPE),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(a, b, ma, mb)


def _quantize_kernel(x_ref, m_ref, o_ref, *, block):
    o_ref[...] = _quant_tile(x_ref[...], m_ref[0, 0], *block)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def mxint_quantize_pallas(x, m, *, bm=16, bn=16, interpret=True):
    """Standalone MXInt quantizer over a 2-D tensor (the 'cast' operator).

    Used on its own for the cross-layer golden test against the Rust
    ``formats`` module and as a building block in the emitted designs.
    """
    R, C = x.shape
    br, bc = BLOCK_SHAPE
    bm, bn = min(bm, R), min(bn, C)
    assert bm % br == 0 and bn % bc == 0 and R % bm == 0 and C % bn == 0
    mm = jnp.asarray(m, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_quantize_kernel, block=BLOCK_SHAPE),
        grid=(R // bm, C // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        interpret=interpret,
    )(x, mm)


def vmem_footprint_bytes(bm, bk, bn):
    """Structural VMEM estimate for one grid step of :func:`mxint_qmatmul`.

    A-tile + B-tile + O-tile in f32, plus the quantized copies the compiler
    can reuse in place on TPU (counted once), plus the per-block exponent
    scratch. Used by EXPERIMENTS.md §Perf/L1 to size tiles against the
    ~16 MiB/core VMEM budget.
    """
    br, bc = BLOCK_SHAPE
    a = bm * bk * 4
    b = bk * bn * 4
    o = bm * bn * 4
    exp = ((bm // br) * (bk // bc) + (bk // br) * (bn // bc)) * 4
    return 2 * (a + b) + o + exp


def mxu_utilization_estimate(bm, bk, bn, mxu=(128, 128)):
    """Fraction of MXU lanes a (bm, bk)x(bk, bn) tile keeps busy."""
    return min(1.0, bm / mxu[0]) * min(1.0, bn / mxu[1])
