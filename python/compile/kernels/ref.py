"""Pure-jnp reference implementations of the numeric formats of the paper.

This module is the *oracle* for the Pallas kernels (pytest compares them
against these functions) and also the software emulation library used by the
L2 model (`compile/model.py`) — exactly the paper's "software emulator"
component of Fig. 3: quantize to the custom format, compute in float,
quantize the result.

All functions implement *fake quantization*: they return float32 tensors
whose values lie exactly on the representable grid of the target format.

Formats (paper Fig. 1c):
  - MXInt  (a.k.a. block floating point): block-shared 8-bit exponent,
    per-element sign + m-bit integer mantissa.
  - BMF    (block minifloat): block-shared 8-bit exponent *bias*,
    per-element minifloat with e_loc exponent bits and m mantissa bits.
  - BL     (block logarithm): block-shared 8-bit exponent bias,
    per-element sign + e_el-bit power-of-two exponent (no mantissa).
  - int    (fixed point): per-tensor static (width, frac) Q-format.
  - minifloat (FP8 of Sun et al.): sign + 4-bit exponent + 3-bit mantissa,
    fixed bias 7 (parameterized here).

Bitwidth parameters may be *traced* jax values (scalars or per-tensor
entries), which is what lets a single lowered HLO artifact serve every
point of the mixed-precision search space driven from the Rust coordinator.
"""

import jax.numpy as jnp

# Paper §4.1: unified block shape for all values.
BLOCK_SHAPE = (16, 2)
# Paper §4.1: fixed 8-bit shared exponent for all MXInt blocks.
SHARED_EXPONENT_BITS = 8
# Clamp range of an 8-bit (biased) shared exponent.
SHARED_EXP_MIN = -126.0
SHARED_EXP_MAX = 127.0

_EPS = 1e-30


def _round_knob(v):
    """Round a real-valued precision knob to the nearest integer, half
    AWAY from zero — matching Rust's ``f64::round`` so the L2 emulation
    and the L3 ``formats`` module agree at half-integer knobs (``jnp.round``
    alone is ties-to-even: 4.5 -> 4, but the search convention gives 5).
    Value rounding inside the quantizers stays ties-to-even on purpose.
    """
    v = jnp.asarray(v, jnp.float32)
    return jnp.sign(v) * jnp.floor(jnp.abs(v) + 0.5)


def _pow2(e):
    """Exact 2^e for integer-valued ``e`` (possibly traced).

    XLA CPU's f32 ``exp2`` is a polynomial approximation that is inexact
    even at integer arguments (exp2(-13) != 2^-13 on this backend!), which
    breaks the exactness of quantization grids. ``ldexp`` constructs the
    power of two exactly. Exponents are clamped to the f32 range.
    """
    e = jnp.clip(jnp.asarray(e), -149.0, 127.0)
    return jnp.ldexp(jnp.float32(1.0), e.astype(jnp.int32))


def _to_blocks(x, block=BLOCK_SHAPE):
    """Reshape the last two dims of ``x`` into blocks of ``block``.

    ``x[..., R, C] -> x[..., R//br, C//bc, br, bc]``. 1-D tensors are
    treated as flat blocks of ``br*bc`` elements. R and C must be divisible
    by the block dims (the model zoo only uses dims that are multiples of
    16).
    """
    br, bc = block
    if x.ndim == 1:
        n = br * bc
        assert x.shape[0] % n == 0, f"1-D dim {x.shape[0]} not divisible by {n}"
        return x.reshape(x.shape[0] // n, 1, n, 1), x.shape
    r, c = x.shape[-2], x.shape[-1]
    assert r % br == 0, f"dim {r} not divisible by block {br}"
    assert c % bc == 0, f"dim {c} not divisible by block {bc}"
    lead = x.shape[:-2]
    xb = x.reshape(*lead, r // br, br, c // bc, bc)
    # move block dims to the end: [..., r/br, c/bc, br, bc]
    xb = jnp.moveaxis(xb, -3, -2)
    return xb, x.shape


def _from_blocks(xb, orig_shape, block=BLOCK_SHAPE):
    """Inverse of :func:`_to_blocks`."""
    if len(orig_shape) == 1:
        return xb.reshape(orig_shape)
    xb = jnp.moveaxis(xb, -2, -3)
    return xb.reshape(orig_shape)


def _shared_exponent(xb):
    """floor(log2(max |x| in block)), clamped to the 8-bit shared range.

    ``xb`` has the block dims as the trailing two axes; the reduction is
    over them. Returns an exponent with those axes kept (size 1) so it
    broadcasts back over the block.
    """
    maxabs = jnp.max(jnp.abs(xb), axis=(-1, -2), keepdims=True)
    e = jnp.floor(jnp.log2(jnp.maximum(maxabs, _EPS)))
    return jnp.clip(e, SHARED_EXP_MIN, SHARED_EXP_MAX)


def mxint_quantize(x, mantissa_bits, block=BLOCK_SHAPE):
    """Fake-quantize ``x`` to MXInt(block, 8, mantissa_bits).

    Element value = sign * M * 2^(E + 1 - m) with integer M in
    [0, 2^m - 1] and E the block-shared exponent. ``mantissa_bits`` may be
    a traced scalar (float); it is rounded to the nearest integer (the
    search convention: real-valued precision dims round) and clamped >= 1.
    """
    m = jnp.maximum(_round_knob(mantissa_bits), 1.0)
    xb, shape = _to_blocks(x, block)
    e = _shared_exponent(xb)
    scale = _pow2(e + 1.0 - m)
    qmax = _pow2(m) - 1.0
    q = jnp.clip(jnp.round(xb / scale), -qmax, qmax) * scale
    return _from_blocks(q, shape, block)


def bmf_quantize(x, mantissa_bits, exp_bits=2.0, block=BLOCK_SHAPE):
    """Fake-quantize ``x`` to Block Minifloat (shared exponent *bias*).

    Each element is a minifloat with ``exp_bits`` exponent bits and
    ``mantissa_bits`` mantissa bits; the block shares an 8-bit bias aligned
    so the largest element of the block sits at the top of the local range.
    The local dynamic range is only ``2^(2^exp_bits)``; smaller elements
    flush toward zero — the failure mode behind the paper's catastrophic
    BMF8 perplexity on LLaMA (Table 1).
    """
    m = jnp.maximum(_round_knob(mantissa_bits), 1.0)
    eb = jnp.maximum(_round_knob(exp_bits), 1.0)
    xb, shape = _to_blocks(x, block)
    bias = _shared_exponent(xb)  # shared bias anchors the top of the range
    absx = jnp.abs(xb)
    # Local exponent relative to bias, in [-(2^eb - 1), 0].
    e_loc = jnp.floor(jnp.log2(jnp.maximum(absx, _EPS))) - bias
    e_min = -(_pow2(eb) - 1.0)
    e_loc = jnp.clip(e_loc, e_min, 0.0)
    e_abs = e_loc + bias
    # Quantize the mantissa (in [1, 2) at exponent e_abs) to m bits. At the
    # clamped minimum exponent this acts as denormal-style rounding: values
    # below half the smallest step flush to zero naturally (and, unlike an
    # explicit threshold, idempotently).
    scale = _pow2(e_abs - m)
    q = jnp.round(absx / scale) * scale
    # Saturate at the top of the representable range.
    top = _pow2(bias + 1.0) - _pow2(bias - m)
    q = jnp.minimum(q, top)
    return _from_blocks(jnp.sign(xb) * q, shape, block)


def bl_quantize(x, exp_el_bits=7.0, block=BLOCK_SHAPE):
    """Fake-quantize ``x`` to Block Logarithm: sign * 2^(E_i), shared bias.

    Per-element exponent has ``exp_el_bits`` bits below the shared bias, so
    representable magnitudes are { 2^(bias - k) : 0 <= k < 2^exp_el_bits }
    plus zero. Values are always powers of two (paper Fig. 1c).
    """
    eb = jnp.maximum(_round_knob(exp_el_bits), 1.0)
    xb, shape = _to_blocks(x, block)
    bias = _shared_exponent(xb)
    absx = jnp.maximum(jnp.abs(xb), _EPS)
    # Log-domain rounding of the exponent.
    e = jnp.round(jnp.log2(absx))
    e_min = bias - (_pow2(eb) - 1.0)
    q = _pow2(jnp.clip(e, e_min, bias))
    # Underflow: below half of the smallest representable -> 0.
    q = jnp.where(jnp.abs(xb) < _pow2(e_min - 1.0), 0.0, q)
    return _from_blocks(jnp.sign(xb) * q, shape, block)


def int_quantize(x, width, frac):
    """Fake-quantize ``x`` to a per-tensor fixed-point Q-format.

    ``width`` total bits including sign, ``frac`` fractional bits. Both may
    be traced. value = clamp(round(x * 2^f), -2^(w-1), 2^(w-1)-1) / 2^f.
    No dynamic range: this is what loses accuracy in deep layers (Fig. 1a).
    """
    w = jnp.maximum(_round_knob(width), 2.0)
    f = _round_knob(frac)
    scale = _pow2(-f)
    qmax = _pow2(w - 1.0) - 1.0
    return jnp.clip(jnp.round(x / scale), -qmax - 1.0, qmax) * scale


def minifloat_quantize(x, exp_bits=4.0, mantissa_bits=3.0, bias=7.0):
    """Fake-quantize ``x`` to MiniFloat/FP8 (Sun et al.): fixed bias.

    Normal numbers only; underflow flushes to zero, overflow saturates.
    """
    eb = jnp.asarray(exp_bits, jnp.float32)
    m = jnp.asarray(mantissa_bits, jnp.float32)
    b = jnp.asarray(bias, jnp.float32)
    absx = jnp.maximum(jnp.abs(x), _EPS)
    e = jnp.floor(jnp.log2(absx))
    e_min = 1.0 - b
    e_max = _pow2(eb) - 2.0 - b
    e_c = jnp.clip(e, e_min, e_max)
    scale = _pow2(e_c - m)
    q = jnp.round(absx / scale) * scale
    top = _pow2(e_max + 1.0) - _pow2(e_max - m)
    q = jnp.minimum(q, top)
    q = jnp.where(jnp.abs(x) < _pow2(e_min - 1.0), 0.0, q)
    return jnp.sign(x) * q


def mxint_matmul_ref(a, b, m_a, m_b, block=BLOCK_SHAPE):
    """Reference MXInt dot-product operator: quantize both operands to
    MXInt, multiply in float. Oracle for the Pallas kernel."""
    qa = mxint_quantize(a, m_a, block)
    qb = mxint_quantize(b, m_b, block)
    return qa @ qb


def average_bitwidth(mantissa_bits, block=BLOCK_SHAPE, shared_bits=8.0):
    """Paper Eq. (1): p = e / prod(B) + m + 1."""
    return shared_bits / float(block[0] * block[1]) + mantissa_bits + 1.0
