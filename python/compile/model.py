"""L2 — the JAX transformer model with runtime-parameterized fake-quant.

This is the paper's "software emulator" layer (Fig. 3): every model in the
zoo is a standard pre-LN transformer whose linear-layer operand tensors
(weights *and* activations) are fake-quantized to one of the paper's
formats before each matmul, with the per-tensor precision supplied **as a
runtime input tensor**. A single lowered HLO artifact therefore serves
every point of the mixed-precision search space — the Rust coordinator
turns the knobs without ever re-entering Python.

Key entry points (all lowered by ``compile/aot.py``):
  - :func:`forward`          — logits (classifier) / token logits (LM)
  - :func:`loss_fn`          — scalar loss (cross-entropy / next-token)
  - :func:`profile_forward`  — per-tensor (variance, absmax, absmean) stats
  - :func:`train_step`       — SGD pretraining step (FP32)
  - :func:`qat_step`         — quantization-aware training step (STE)

Parameters are packed into ONE flat f32[P] vector (layout in
:func:`param_spec`); the quantization configuration is ONE f32[V, 2]
tensor, row i = (bits, frac) for quantizable tensor i (see
:func:`qtensor_names`). Both conventions are exported to the Rust side via
``artifacts/manifest.json``.
"""

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.mxint_gemm import mxint_qmatmul

# ---------------------------------------------------------------------------
# Model zoo
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A scaled-down "simulant" of one of the paper's evaluation LLMs.

    Dimensions are multiples of 16 so every tensor tiles exactly into the
    paper's unified (16, 2) MXInt block shape (§4.1).
    """

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    vocab: int = 512
    seq_len: int = 32
    n_classes: int = 4  # padded to 4 so the head tiles into (16,2) blocks
    kind: str = "classifier"  # "classifier" | "lm"
    batch: int = 64

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


def _clf(name, n_layers, d_model, n_heads):
    return ModelConfig(name, n_layers, d_model, n_heads)


#: The ten classifier LLM simulants of Fig. 5/6/7/8 plus the causal-LM
#: simulant used for Table 1 / Fig. 1a perplexity experiments.
MODEL_ZOO: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _clf("bert-base-sim", 3, 64, 4),
        _clf("bert-large-sim", 5, 96, 6),
        _clf("opt-125m-sim", 2, 32, 2),
        _clf("opt-350m-sim", 3, 48, 3),
        _clf("opt-1.3b-sim", 4, 64, 4),
        _clf("opt-2.7b-sim", 5, 96, 4),
        _clf("opt-6.7b-sim", 6, 128, 8),
        _clf("llama-7b-sim", 4, 64, 4),
        _clf("vicuna-7b-sim", 4, 64, 4),
        _clf("alpaca-7b-sim", 4, 64, 4),
        ModelConfig("llama-sim", 4, 64, 4, vocab=512, seq_len=64, kind="lm", batch=16),
    ]
}

#: Format families — each gets its own lowered artifact per model.
FORMATS = ("fp32", "int", "fp8", "mxint", "bmf", "bl", "mxint_pallas")


# ---------------------------------------------------------------------------
# Parameter packing
# ---------------------------------------------------------------------------


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical (name, shape) layout of the flat parameter vector."""
    d, f, s, v = cfg.d_model, cfg.d_ff, cfg.seq_len, cfg.vocab
    spec = [("embed", (v, d)), ("pos", (s, d))]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        spec += [
            (p + "ln1_g", (d,)),
            (p + "ln1_b", (d,)),
            (p + "w_qkv", (d, 3 * d)),
            (p + "b_qkv", (3 * d,)),
            (p + "w_proj", (d, d)),
            (p + "b_proj", (d,)),
            (p + "ln2_g", (d,)),
            (p + "ln2_b", (d,)),
            (p + "w_fc1", (d, f)),
            (p + "b_fc1", (f,)),
            (p + "w_fc2", (f, d)),
            (p + "b_fc2", (d,)),
        ]
    spec += [("lnf_g", (d,)), ("lnf_b", (d,))]
    out = cfg.vocab if cfg.kind == "lm" else cfg.n_classes
    spec += [("head_w", (d, out)), ("head_b", (out,))]
    return spec


def param_size(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.asarray(s))) for _, s in param_spec(cfg))


def unpack_params(cfg: ModelConfig, flat) -> Dict[str, jnp.ndarray]:
    out, off = {}, 0
    for name, shape in param_spec(cfg):
        n = 1
        for s in shape:
            n *= s
        out[name] = flat[off : off + n].reshape(shape)
        off += n
    return out


def init_params(cfg: ModelConfig, seed: int = 0) -> jnp.ndarray:
    """Glorot-ish init, packed flat. Mirrored by the Rust frontend.

    Weight rows that consume the injected outlier channels (w_qkv, w_fc1)
    are scaled by 1/gain so the initial forward pass behaves like the
    outlier-free model — training stays stable while the *activations*
    keep their outliers (which is what quantization must cope with).
    """
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_b", "ln1_b", "ln2_b", "lnf_b")):
            chunks.append(jnp.zeros(shape))
        elif name.endswith(("ln1_g", "ln2_g", "lnf_g")):
            chunks.append(jnp.ones(shape))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = (2.0 / (fan_in + shape[-1])) ** 0.5
            w = jax.random.normal(sub, shape) * std
            if ".w_qkv" in name or ".w_fc1" in name:
                layer = int(name.split(".")[0][len("layer"):])
                gain = OUTLIER_BASE_GAIN * (1.0 + layer)
                w = w.at[:OUTLIER_CHANNELS, :].divide(gain)
            chunks.append(w)
    return jnp.concatenate([c.ravel() for c in chunks]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Quantizable-tensor enumeration (the search space S' = N^v of §4.1)
# ---------------------------------------------------------------------------


def qtensor_names(cfg: ModelConfig) -> List[str]:
    """Order of rows in the f32[V, 2] quant-config input.

    Per layer: 4 weights + 4 activations (inputs to each linear), plus the
    classifier/LM head pair. Activations enter the paper's dataflow graph
    as streamed edges (Fig. 1d); weights as stationary operands.
    """
    names = []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        names += [
            p + "a_attn_in",
            p + "w_qkv",
            p + "a_proj_in",
            p + "w_proj",
            p + "a_fc1_in",
            p + "w_fc1",
            p + "a_fc2_in",
            p + "w_fc2",
        ]
    names += ["a_head_in", "head_w"]
    return names


def num_qtensors(cfg: ModelConfig) -> int:
    return 8 * cfg.n_layers + 2


# ---------------------------------------------------------------------------
# Fake-quantization dispatch
# ---------------------------------------------------------------------------


def _apply_format(x, fmt: str, bits, frac, ste: bool):
    """Quantize ``x`` per the (static) format family with (traced) knobs."""
    if fmt == "fp32":
        return x
    if fmt in ("mxint", "mxint_pallas"):
        q = ref.mxint_quantize(x, bits)
    elif fmt == "int":
        q = ref.int_quantize(x, bits, frac)
    elif fmt == "fp8":
        q = ref.minifloat_quantize(x)
    elif fmt == "bmf":
        q = ref.bmf_quantize(x, bits)
    elif fmt == "bl":
        q = ref.bl_quantize(x, bits)
    else:
        raise ValueError(f"unknown format {fmt}")
    if ste:
        # Straight-through estimator: forward quantized, backward identity.
        return x + jax.lax.stop_gradient(q - x)
    return q


# ---------------------------------------------------------------------------
# Transformer forward
# ---------------------------------------------------------------------------


def _layer_norm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


#: Number of "outlier channels" and their per-layer gain growth.
#:
#: Real LLMs develop a few activation channels whose magnitudes dwarf the
#: rest, growing with depth (LLM.int8(), SmoothQuant; the paper's Fig. 1a
#: shows variances exploding up to 7624x in deeper LLaMA layers). That
#: emergent phenomenon does not appear in 0.1-3M-parameter simulants, so we
#: build it into the architecture: after each pre-attention/pre-FFN
#: LayerNorm, a fixed set of channels is scaled by a gain that grows with
#: depth. The model *trains with these gains in place* (weights adapt), so
#: the quantization problem faced by the search is exactly the paper's:
#: per-tensor static int8 loses log2(gain) bits of resolution to the
#: outliers, while block formats isolate them in their own (16, 2) blocks.
#: Documented as a substitution in DESIGN.md §3.
OUTLIER_CHANNELS = 4
OUTLIER_BASE_GAIN = 16.0


def _inject_outliers(x, layer_idx):
    """Scale the outlier channels; gain grows linearly with depth.

    NOTE (negative result, kept for the record): two stronger variants
    were tried to force the paper's catastrophic int8 row — (a) trainable
    multiplicative outliers, which SGD simply learns to shrink
    ("self-SmoothQuant"), and (b) irreducible nuisance channels, which
    destabilize training of 0.1-3M-parameter simulants outright. The
    shipped variant (multiplicative gain with LN scale pinned on the
    outlier channels) reproduces the Fig. 1a variance structure and the
    per-format quantization *error* mechanism (tested mechanistically in
    rust/tests/integration.rs) while keeping training healthy; the
    resulting int8 accuracy penalty is smaller than the paper's because
    tiny trained models route information around coarse channels — see
    EXPERIMENTS.md Table 1 discussion.
    """
    gain = OUTLIER_BASE_GAIN * (1.0 + layer_idx)
    return x.at[..., :OUTLIER_CHANNELS].multiply(gain)


def _layer_norm_with_outliers(x, g, b, layer_idx):
    """LayerNorm followed by outlier injection, with the learnable scale
    and shift *pinned to (1, 0) on the outlier channels*.

    Without pinning, training learns to shrink ``g[:K]`` by 1/gain and the
    model "SmoothQuants itself" — the outliers vanish from the trained
    activations and int8 stops degrading (observed empirically). Real
    LLMs cannot train their outliers away (they emerge *because of*
    training); pinning reproduces that irreducibility.
    """
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    core = (x - mu) / jnp.sqrt(var + 1e-5)
    g2 = g.at[:OUTLIER_CHANNELS].set(1.0)
    b2 = b.at[:OUTLIER_CHANNELS].set(0.0)
    return _inject_outliers(core * g2 + b2, layer_idx)


def _attention(q, k, v, causal: bool):
    # q,k,v: [B, H, S, Dh]
    s = q.shape[-2]
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(q.shape[-1] * 1.0)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", att, v)


def forward(cfg: ModelConfig, flat_params, tokens, qconfig, fmt="fp32",
            ste=False, taps=None):
    """Quantized forward pass.

    Args:
      flat_params: f32[P] packed parameters.
      tokens: i32[B, S] token ids.
      qconfig: f32[V, 2] per-qtensor (bits, frac); ignored for fp32/fp8.
      fmt: static format family string.
      ste: straight-through gradients (QAT).
      taps: optional list collecting (name, activation) for profiling.

    Returns logits: classifier [B, C] or LM [B, S, vocab].
    """
    p = unpack_params(cfg, flat_params)
    names = qtensor_names(cfg)
    idx = {n: i for i, n in enumerate(names)}
    use_pallas = fmt == "mxint_pallas"
    causal = cfg.kind == "lm"

    def qt(x, name):
        i = idx[name]
        if taps is not None:
            taps.append((name, x))
        return _apply_format(x, fmt, qconfig[i, 0], qconfig[i, 1], ste)

    def qmm(x, w, act_name, w_name):
        """Quantized matmul x @ w over the trailing dim of x."""
        if use_pallas:
            # L1 path: the Pallas MXInt dot-product operator quantizes both
            # operand streams inside the kernel. Block grouping matches the
            # jnp path because S and B*S are multiples of 16.
            if taps is not None:
                taps.append((act_name, x))
                taps.append((w_name, w))
            lead = x.shape[:-1]
            x2 = x.reshape(-1, x.shape[-1])
            y = mxint_qmatmul(x2, w, qconfig[idx[act_name], 0],
                              qconfig[idx[w_name], 0])
            return y.reshape(*lead, w.shape[-1])
        return qt(x, act_name) @ qt(w, w_name)

    b, s = tokens.shape
    x = p["embed"][tokens] + p["pos"][None, :s, :]
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        h = _layer_norm_with_outliers(x, p[pre + "ln1_g"], p[pre + "ln1_b"], i)
        qkv = qmm(h, p[pre + "w_qkv"], pre + "a_attn_in", pre + "w_qkv")
        qkv = qkv + p[pre + "b_qkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(t):
            return t.reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)

        o = _attention(heads(q), heads(k), heads(v), causal)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        o = qmm(o, p[pre + "w_proj"], pre + "a_proj_in", pre + "w_proj")
        x = x + o + p[pre + "b_proj"]

        h = _layer_norm_with_outliers(x, p[pre + "ln2_g"], p[pre + "ln2_b"], i)
        h = qmm(h, p[pre + "w_fc1"], pre + "a_fc1_in", pre + "w_fc1")
        h = jax.nn.gelu(h + p[pre + "b_fc1"])
        h = qmm(h, p[pre + "w_fc2"], pre + "a_fc2_in", pre + "w_fc2")
        x = x + h + p[pre + "b_fc2"]

    x = _layer_norm(x, p["lnf_g"], p["lnf_b"])
    if cfg.kind == "lm":
        logits = qmm(x, p["head_w"], "a_head_in", "head_w") + p["head_b"]
        return logits  # [B, S, vocab]
    pooled = jnp.mean(x, axis=1)  # [B, D] — mean pooling head
    # Mean-pooled vector is [B, D]: rows B multiple of 16 (batch 64).
    logits = qmm(pooled, p["head_w"], "a_head_in", "head_w") + p["head_b"]
    return logits  # [B, C]


# ---------------------------------------------------------------------------
# Losses, metrics, profiling, training
# ---------------------------------------------------------------------------


def _touch(x):
    """Zero-valued dependency on ``x``.

    jax prunes unused arguments from the lowered HLO signature; entry
    points add ``_touch`` of inputs their format path ignores (qconfig for
    fp32/fp8, labels for LMs) so every artifact keeps the full, uniform
    signature the Rust runtime expects.
    """
    return jnp.sum(x.astype(jnp.float32)) * 0.0


def loss_fn(cfg: ModelConfig, flat_params, tokens, labels, qconfig,
            fmt="fp32", ste=False):
    """Mean cross-entropy. For LMs ``labels`` is ignored and the target is
    the next token (shifted input); returns (loss, correct_count)."""
    logits = forward(cfg, flat_params, tokens, qconfig, fmt, ste)
    anchor = _touch(qconfig) + _touch(labels)
    if cfg.kind == "lm":
        tgt = tokens[:, 1:]
        lg = logits[:, :-1, :]
        logp = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        loss = jnp.mean(nll) + anchor
        correct = jnp.sum(jnp.argmax(lg, -1) == tgt)
    else:
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        loss = jnp.mean(nll) + anchor
        correct = jnp.sum(jnp.argmax(logits, -1) == labels)
    return loss, correct


def eval_batch(cfg, flat_params, tokens, labels, qconfig, fmt="fp32"):
    """(loss, correct) for one batch — the Rust `evaluate` pass input.

    For LMs, loss is the mean token NLL, i.e. log(perplexity)."""
    return loss_fn(cfg, flat_params, tokens, labels, qconfig, fmt, False)


def profile_forward(cfg: ModelConfig, flat_params, tokens):
    """The `profile` pass kernel (Fig. 1a): per-qtensor value statistics.

    Returns f32[V, 3] rows = (variance, absmax, absmean) in qtensor order.
    """
    taps: list = []
    zero_cfg = jnp.zeros((num_qtensors(cfg), 2), jnp.float32)
    forward(cfg, flat_params, tokens, zero_cfg, "fp32", taps=taps)
    names = qtensor_names(cfg)
    # qt() taps both activation and weight operands of every quantized
    # matmul (weight qtensor names coincide with param_spec names).
    stats = dict(taps)
    assert set(names) <= set(stats), sorted(set(names) - set(stats))
    rows = []
    for n in names:
        x = stats[n]
        rows.append(
            jnp.stack([jnp.var(x), jnp.max(jnp.abs(x)), jnp.mean(jnp.abs(x))])
        )
    return jnp.stack(rows)


def train_step(cfg: ModelConfig, flat_params, tokens, labels, lr):
    """One sign-SGD pretraining step in FP32. Returns (new_params, loss).

    Sign-SGD (update = lr * sign(grad)) is per-parameter scale-invariant:
    the injected outlier channels make the gradients of the weight rows
    that consume them ~gain x larger than everything else, which starves
    norm-clipped SGD. Signed updates train all parameters at the same
    rate regardless of the gain.
    """
    zero_cfg = jnp.zeros((num_qtensors(cfg), 2), jnp.float32)

    def scalar_loss(p):
        return loss_fn(cfg, p, tokens, labels, zero_cfg, "fp32")[0]

    loss, grad = jax.value_and_grad(scalar_loss)(flat_params)
    return flat_params - lr * jnp.sign(grad), loss


def qat_step(cfg: ModelConfig, flat_params, tokens, labels, qconfig, lr,
             fmt="mxint"):
    """One quantization-aware fine-tune step (STE gradients).

    This is the paper's "trainable IR" claim made concrete: the same
    artifact family the search evaluates can also fine-tune the model
    without leaving the hardware-exploration loop (Fig. 6, QAT rows).
    """

    def scalar_loss(p):
        return loss_fn(cfg, p, tokens, labels, qconfig, fmt, ste=True)[0]

    loss, grad = jax.value_and_grad(scalar_loss)(flat_params)
    return flat_params - lr * jnp.sign(grad), loss
