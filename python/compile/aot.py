"""AOT pipeline: lower every (model, entry-point) pair to HLO *text*.

HLO text — NOT ``lowered.compiler_ir().serialize()`` — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
the xla_extension 0.5.1 bundled with the Rust ``xla`` crate rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs (under ``artifacts/``):
  - ``<model>_<entry>.hlo.txt``   — one artifact per lowered entry point
  - ``manifest.json``             — the Rust side's ground truth for param
    layout, qtensor order, artifact paths and model configs.

Python runs ONCE here; the Rust coordinator never re-enters it.
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ref

jax.config.update("jax_platform_name", "cpu")

# Format families lowered per model kind. fp8 (fixed-config minifloat) is
# only needed for the Table 1 LM comparison; the pallas-kernel variant of
# mxint proves the L1->L3 composition on two representative models.
CLASSIFIER_FORMATS = ("fp32", "int", "mxint", "bmf", "bl")
LM_FORMATS = ("fp32", "int", "fp8", "mxint", "bmf", "bl")
PALLAS_MODELS = ("opt-125m-sim", "llama-sim")
QAT_MODELS = ("opt-125m-sim", "opt-350m-sim", "bert-base-sim")
QAT_FORMATS = ("mxint", "int")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_entry(cfg: M.ModelConfig, entry: str, fmt: str):
    """Build the jitted callable + example specs for one artifact."""
    p = _spec((M.param_size(cfg),))
    tok = _spec((cfg.batch, cfg.seq_len), jnp.int32)
    lab = _spec((cfg.batch,), jnp.int32)
    qc = _spec((M.num_qtensors(cfg), 2))
    lr = _spec((), jnp.float32)

    if entry == "eval":
        def f(params, tokens, labels, qconfig):
            return M.eval_batch(cfg, params, tokens, labels, qconfig, fmt)

        return jax.jit(f).lower(p, tok, lab, qc)
    if entry == "profile":
        def f(params, tokens):
            return (M.profile_forward(cfg, params, tokens),)

        return jax.jit(f).lower(p, tok)
    if entry == "train":
        def f(params, tokens, labels, lr_):
            return M.train_step(cfg, params, tokens, labels, lr_)

        return jax.jit(f).lower(p, tok, lab, lr)
    if entry == "qat":
        def f(params, tokens, labels, qconfig, lr_):
            return M.qat_step(cfg, params, tokens, labels, qconfig, lr_, fmt)

        return jax.jit(f).lower(p, tok, lab, qc, lr)
    raise ValueError(entry)


def lower_quant_ref(fmt: str):
    """Tiny q(x) artifact for the Rust<->Python cross-layer golden test."""
    x = _spec((32, 32))
    c = _spec((2,))

    def f(xv, cv):
        # keep cv in the signature even for fixed-config formats (fp8)
        return (M._apply_format(xv, fmt, cv[0], cv[1], False) + M._touch(cv),)

    return jax.jit(f).lower(x, c)


def _write(path: str, lowered, force: bool) -> float:
    if os.path.exists(path) and not force:
        return 0.0
    t0 = time.time()
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return time.time() - t0


def build_manifest(out_dir: str):
    models = {}
    for name, cfg in M.MODEL_ZOO.items():
        spec, off = [], 0
        for pname, shape in M.param_spec(cfg):
            n = 1
            for s in shape:
                n *= s
            spec.append({"name": pname, "shape": list(shape), "offset": off})
            off += n
        fmts = LM_FORMATS if cfg.kind == "lm" else CLASSIFIER_FORMATS
        arts = {f"eval_{f}": f"{name}_eval_{f}.hlo.txt" for f in fmts}
        if name in PALLAS_MODELS:
            arts["eval_mxint_pallas"] = f"{name}_eval_mxint_pallas.hlo.txt"
        arts["profile"] = f"{name}_profile.hlo.txt"
        arts["train"] = f"{name}_train.hlo.txt"
        if name in QAT_MODELS:
            for f in QAT_FORMATS:
                arts[f"qat_{f}"] = f"{name}_qat_{f}.hlo.txt"
        models[name] = {
            "n_layers": cfg.n_layers,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "vocab": cfg.vocab,
            "seq_len": cfg.seq_len,
            "n_classes": cfg.n_classes,
            "kind": cfg.kind,
            "batch": cfg.batch,
            "param_size": M.param_size(cfg),
            "param_spec": spec,
            "qtensors": M.qtensor_names(cfg),
            "artifacts": arts,
        }
    return {
        "block_shape": list(ref.BLOCK_SHAPE),
        "shared_exponent_bits": ref.SHARED_EXPONENT_BITS,
        "formats": list(CLASSIFIER_FORMATS) + ["fp8", "mxint_pallas"],
        "quant_refs": {f: f"quant_ref_{f}.hlo.txt"
                       for f in ("int", "fp8", "mxint", "bmf", "bl")},
        "models": models,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/manifest.json",
                    help="manifest path; artifacts go to its directory")
    ap.add_argument("--models", default="",
                    help="comma-separated subset of model names")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)
    manifest = build_manifest(out_dir)
    subset = set(filter(None, args.models.split(",")))

    t_all = time.time()
    for fmt, fname in manifest["quant_refs"].items():
        dt = _write(os.path.join(out_dir, fname), lower_quant_ref(fmt),
                    args.force)
        if dt:
            print(f"  quant_ref_{fmt}: {dt:.1f}s", flush=True)

    for name, meta in manifest["models"].items():
        if subset and name not in subset:
            continue
        cfg = M.MODEL_ZOO[name]
        for art, fname in meta["artifacts"].items():
            path = os.path.join(out_dir, fname)
            if os.path.exists(path) and not args.force:
                continue
            t0 = time.time()
            if art.startswith("eval_"):
                lowered = lower_entry(cfg, "eval", art[len("eval_"):])
            elif art == "profile":
                lowered = lower_entry(cfg, "profile", "fp32")
            elif art == "train":
                lowered = lower_entry(cfg, "train", "fp32")
            elif art.startswith("qat_"):
                lowered = lower_entry(cfg, "qat", art[len("qat_"):])
            else:
                raise ValueError(art)
            _write(path, lowered, True)
            print(f"  {name}/{art}: {time.time() - t0:.1f}s", flush=True)

    with open(args.out, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest + artifacts in {out_dir} ({time.time() - t_all:.0f}s)")


if __name__ == "__main__":
    main()
