//! Format comparison — the Table 1 experiment: quantize the LLaMA
//! simulant's causal LM to every format at ~8 average bits and report
//! perplexity on wikitext2-sim plus the memory/arithmetic densities of
//! the hardware GEMM regression model.
//!
//! Run: `cargo run --release --example format_comparison`

use mase::coordinator::{pretrain, Session};
use mase::data::{Batch, MarkovCorpus};
use mase::formats::{FormatKind, Precision};
use mase::hw::{arithmetic_density, memory_density};
use mase::passes::{profile_model, Evaluator, QuantSolution};
use mase::util::Table;

fn main() -> anyhow::Result<()> {
    let session = Session::open(&Session::default_dir())?;
    let meta = session.manifest.model("llama-sim")?.clone();
    let weights = pretrain::pretrain(&session, &meta, None, &Default::default())?;

    // held-out corpus streams
    let corpus = MarkovCorpus::new(7);
    let batches: Vec<Batch> = (0..4)
        .map(|i| Batch {
            tokens: corpus.batch(1000 + i, meta.batch, meta.seq_len),
            labels: vec![0; meta.batch],
            batch: meta.batch,
            seq: meta.seq_len,
        })
        .collect();
    let ev = Evaluator::new(session.pjrt_backend()?, &meta, &weights, &batches)?;
    let profile = profile_model(&ev.backend, &meta, &weights, &batches[..1])?;

    // W8A8-equivalent configurations per format (paper Table 1)
    let rows = [
        (FormatKind::Fp32, 32.0f32, "-"),
        (FormatKind::Int, 8.0, "W8A8"),
        (FormatKind::Fp8, 8.0, "W8A8"),
        (FormatKind::MxInt, 7.0, "W8A8"),
        (FormatKind::Bmf, 5.0, "W8A8"),
        (FormatKind::Bl, 7.0, "W8A8"),
    ];
    let mut t = Table::new(vec!["Approach", "Config", "Perplexity", "MemDensity", "ArithDensity"]);
    for (fmt, bits, config) in rows {
        let sol = QuantSolution::uniform(fmt, bits, &meta, &profile);
        let acc = ev.accuracy(&sol)?;
        let p = Precision::new(bits, sol.fracs[0]);
        t.row(vec![
            fmt.name().to_string(),
            config.to_string(),
            format!("{:.2}", acc.perplexity()),
            format!("{:.2}x", memory_density(fmt, p)),
            format!("{:.1}x", arithmetic_density(fmt, p)),
        ]);
    }
    println!("Table 1 (llama-sim on wikitext2-sim):\n{}", t.render());
    println!("expected shape: int8 blows up; fp8 ~ fp32; mxint8 ~ fp32; bmf/bl degraded");
    Ok(())
}
