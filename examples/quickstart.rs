//! Quickstart: the MASE flow in ~40 lines of API.
//!
//! Loads the AOT artifacts, pretrains (or loads cached) a tiny OPT
//! simulant on sst2-sim, then compares FP32, uniform MXInt8, and a small
//! mixed-precision MXInt search — including the Pallas-kernel variant of
//! the MXInt artifact, proving the L1 (Pallas) -> L2 (JAX) -> L3 (Rust)
//! stack composes.
//!
//! Run: `cargo run --release --example quickstart`

use mase::coordinator::{pretrain, Session};
use mase::data::{batches, Task};
use mase::formats::FormatKind;
use mase::passes::{profile_model, run_search, Evaluator, QuantSolution, SearchConfig};

fn main() -> anyhow::Result<()> {
    let session = Session::open(&Session::default_dir())?;
    let meta = session.manifest.model("opt-125m-sim")?.clone();

    // 1. weights: trained by the Rust coordinator driving the train HLO
    let weights = pretrain::pretrain(&session, &meta, Some(Task::Sst2), &Default::default())?;

    // 2. evaluation set + profile (PJRT backend; swap in
    //    `mase::runtime::CpuBackend::new()` for the artifact-free path)
    let eval = batches(Task::Sst2, 1, 4, meta.batch, meta.seq_len);
    let ev = Evaluator::new(session.pjrt_backend()?, &meta, &weights, &eval)?;
    let profile = profile_model(&ev.backend, &meta, &weights, &eval[..1])?;

    // 3. baselines
    let fp32 = ev.accuracy(&QuantSolution::uniform(FormatKind::Fp32, 32.0, &meta, &profile))?;
    let mxint8_sol = QuantSolution::uniform(FormatKind::MxInt, 7.0, &meta, &profile);
    let mxint8 = ev.accuracy(&mxint8_sol)?;
    // same solution through the Pallas-kernel artifact (L1 on the path)
    let pallas = ev.accuracy_with(&mxint8_sol, "mxint_pallas", &weights)?;

    // 4. mixed-precision search (TPE, 16 trials for the quickstart)
    let outcome = run_search(
        &ev,
        &profile,
        Task::Sst2,
        &SearchConfig { trials: 16, ..Default::default() },
    )?;

    println!("model: {} on sst2-sim", meta.name);
    println!("  fp32 accuracy:            {:.4}", fp32.accuracy());
    println!("  MXInt8 accuracy:          {:.4}", mxint8.accuracy());
    println!("  MXInt8 via Pallas kernel: {:.4}  (must match)", pallas.accuracy());
    assert!((pallas.accuracy() - mxint8.accuracy()).abs() < 1e-9, "L1/L2 paths diverge!");
    let best = &outcome.best_eval;
    println!(
        "  MP MXInt (16 trials):     {:.4} at {:.2} avg bits, {:.0} LUTs, {:.0} inf/s",
        best.accuracy, best.avg_bits, best.design.area_luts, best.design.throughput
    );
    Ok(())
}
