//! END-TO-END driver — proves every layer of the stack composes on a real
//! small workload:
//!
//!   1. *Train*: the Rust coordinator drives the AOT train-step HLO
//!      (fwd+bwd+SGD fused by JAX/XLA) over the synthetic sst2 stream and
//!      logs the loss curve.
//!   2. *Profile*: Fig. 1a activation statistics via the profile artifact.
//!   3. *Co-design search*: TPE over per-tensor MXInt mantissa widths with
//!      the hardware-aware objective (Eq. 4), QAT fine-tuning inside the
//!      loop (trainable IR), accuracy evaluated through PJRT.
//!   4. *Emit*: the winning design as SystemVerilog.
//!   5. *Validate*: the emitted design's dataflow graph in the
//!      cycle-approximate simulator vs the regression model.
//!
//! Run: `cargo run --release --example e2e_codesign`

use mase::coordinator::{pretrain, PretrainConfig, Session};
use mase::data::{batches, Task};
use mase::formats::FormatKind;
use mase::passes::{profile_model, run_search, Evaluator, QuantSolution, SearchConfig};
use mase::runtime::TensorData;

fn main() -> anyhow::Result<()> {
    let session = Session::open(&Session::default_dir())?;
    let model = std::env::var("MASE_MODEL").unwrap_or_else(|_| "bert-base-sim".into());
    let trials = std::env::var("MASE_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(32);
    let meta = session.manifest.model(&model)?.clone();
    let task = Task::Sst2;

    // ---- 1. training (fresh, with a printed loss curve) ----------------
    println!("== 1. pretraining {model} on {} (Rust -> train-step HLO) ==", task.name());
    let train_artifact = meta.artifact("train")?;
    let mut w = mase::frontend::init_params(&meta, 0xC0DE);
    let steps = 300;
    for step in 0..steps {
        let mut bt = mase::data::Batch::new(meta.batch, meta.seq_len);
        for i in 0..meta.batch {
            bt.push(task.sample(0, (step * meta.batch + i) as u64, meta.seq_len));
        }
        let lr = 0.02 * (1.0 - 0.9 * step as f32 / steps as f32); // sign-SGD scale
        let out = session.pjrt()?.execute(
            train_artifact,
            &[
                TensorData::f32(&w, &[meta.param_size as i64]),
                TensorData::i32(&bt.tokens, &[meta.batch as i64, meta.seq_len as i64]),
                TensorData::i32(&bt.labels, &[meta.batch as i64]),
                TensorData::scalar_f32(lr),
            ],
        )?;
        w = out[0].to_vec_f32()?;
        if step % 50 == 0 || step == steps - 1 {
            println!("  step {:>4}  loss {:.4}", step, out[1].scalar_f32()?);
        }
    }

    // ---- 2. profile (Fig. 1a) ------------------------------------------
    println!("\n== 2. profile pass (Fig. 1a statistics) ==");
    let eval = batches(task, 1, 4, meta.batch, meta.seq_len);
    let profile = profile_model(&session.pjrt_backend()?, &meta, &w, &eval[..1])?;
    println!("  variance spread across tensors: {:.1}x", profile.variance_spread());

    // ---- 3. hardware-aware mixed-precision search -----------------------
    println!("\n== 3. TPE co-design search ({trials} trials, Eq. 4 objective) ==");
    let ev = Evaluator::new(session.pjrt_backend()?, &meta, &w, &eval)?;
    let fp32 = ev.accuracy(&QuantSolution::uniform(FormatKind::Fp32, 32.0, &meta, &profile))?;
    let int8 = ev.evaluate(&QuantSolution::uniform(FormatKind::Int, 8.0, &meta, &profile))?;
    let qat_steps = if meta.artifacts.contains_key("qat_mxint") { 2 } else { 0 };
    let outcome = run_search(
        &ev,
        &profile,
        task,
        &SearchConfig { trials, qat_steps, ..Default::default() },
    )?;
    let best = &outcome.best_eval;
    println!("  fp32 acc {:.4} | int8 acc {:.4} | MP MXInt acc {:.4} at {:.2} bits",
        fp32.accuracy(), int8.accuracy, best.accuracy, best.avg_bits);
    println!(
        "  Δacc vs int8: {:+.1}%   area-efficiency vs int8: {:.2}x (paper: ~24% / ~0.97x)",
        100.0 * (best.accuracy - int8.accuracy),
        best.design.area_efficiency() / int8.design.area_efficiency()
    );

    // ---- 4. emit SystemVerilog ------------------------------------------
    println!("\n== 4. emit pass ==");
    let (dp, bits, g) = ev.hardware(&outcome.best)?;
    let out_dir = Session::default_dir().join("designs").join(format!("{model}_e2e"));
    let (design, lines) = mase::passes::emit_pass::emit_to_dir(&g, &out_dir)?;
    println!(
        "  {} SV files, {} lines, {} operator instances -> {}",
        design.files.len(),
        lines,
        design.instances,
        out_dir.display()
    );
    println!("  design: {:.0} LUTs ({:.1}% of U250), {:.0} inf/s, {:.2} avg bits",
        dp.area_luts, 100.0 * dp.utilization, dp.throughput, bits);

    // ---- 5. cross-validate with the dataflow simulator ------------------
    println!("\n== 5. dataflow simulator cross-check ==");
    let sim_thr = mase::sim::simulated_throughput(&g, mase::hw::Device::u250().clock_hz, 8);
    println!(
        "  regression model: {:.0} inf/s | simulator: {:.0} inf/s | ratio {:.2}",
        dp.throughput,
        sim_thr,
        sim_thr / dp.throughput
    );

    // keep the trained weights for the bench suite
    let _ = pretrain::pretrain(&session, &meta, Some(task), &PretrainConfig::default());
    println!("\nE2E complete: all five stages composed.");
    Ok(())
}
