//! Search-algorithm comparison — the Fig. 4 experiment: Random, QMC,
//! NSGA-II and TPE exploring mixed-precision MXInt quantization of
//! OPT-125M-sim on sst2-sim with the SW-only objective `acc + k/b`.
//!
//! Run: `cargo run --release --example mixed_precision_search`

use mase::coordinator::{pretrain, Session};
use mase::data::{batches, Task};
use mase::passes::{profile_model, run_search, Evaluator, Objective, SearchConfig};
use mase::search::{best_curve, Algorithm};
use mase::util::Table;

fn main() -> anyhow::Result<()> {
    let session = Session::open(&Session::default_dir())?;
    let meta = session.manifest.model("opt-125m-sim")?.clone();
    let weights = pretrain::pretrain(&session, &meta, Some(Task::Sst2), &Default::default())?;
    let eval = batches(Task::Sst2, 1, 3, meta.batch, meta.seq_len);
    let mut ev = Evaluator::new(session.pjrt_backend()?, &meta, &weights, &eval)?;
    ev.objective = Objective::sw_only(); // Fig. 4 uses acc + k/b

    let trials = std::env::var("MASE_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(48);
    let profile = profile_model(&ev.backend, &meta, &weights, &eval[..1])?;

    let mut curves = Vec::new();
    for alg in Algorithm::ALL {
        let t0 = std::time::Instant::now();
        let outcome = run_search(
            &ev,
            &profile,
            Task::Sst2,
            &SearchConfig { algorithm: alg, trials, ..Default::default() },
        )?;
        let curve = best_curve(&outcome.history);
        println!(
            "{:>7}: start {:.4} -> best {:.4} (acc {:.4}, {:.2} bits) in {:.1}s",
            alg.name(),
            curve[0],
            curve.last().unwrap(),
            outcome.best_eval.accuracy,
            outcome.best_eval.avg_bits,
            t0.elapsed().as_secs_f64()
        );
        curves.push((alg, curve));
    }

    // Fig. 4 as a table: incumbent objective at checkpoints.
    let mut t = Table::new(vec!["trial", "random", "nsga2", "qmc", "tpe"]);
    let marks: Vec<usize> =
        [1, 2, 4, 8, 16, 24, 32, 48, 64].iter().copied().filter(|&m| m <= trials).collect();
    for m in marks {
        let get = |a: Algorithm| {
            curves
                .iter()
                .find(|(alg, _)| *alg == a)
                .map(|(_, c)| format!("{:.4}", c[m - 1]))
                .unwrap_or_default()
        };
        t.row(vec![
            m.to_string(),
            get(Algorithm::Random),
            get(Algorithm::NsgaII),
            get(Algorithm::Qmc),
            get(Algorithm::Tpe),
        ]);
    }
    println!("\nFig. 4 (objective = acc + k/b, maximization):\n{}", t.render());
    println!("expected shape: TPE ends best; random changes least; QMC plateaus");
    Ok(())
}
