//! Offline stub of the `xla` (xla-rs / PJRT) bindings used by
//! `mase::runtime`.
//!
//! The real crate wraps `xla_extension`, which is unavailable in this
//! environment. This stub keeps the workspace compiling — and the pure
//! Rust majority of the test suite running — without PJRT:
//!  * [`Literal`] is a faithful host-side tensor container, so
//!    `TensorData::prepare()` and friends work for real;
//!  * client / compile / execute entry points return a clear
//!    "PJRT unavailable" [`Error`] instead of crashing, so artifact-driven
//!    paths degrade into ordinary error handling.
//! Every type here is plain owned data, hence `Send + Sync` — which is
//! what lets the parallel search pass share one `Runtime` across worker
//! threads. Swap the `xla` path dependency in `rust/Cargo.toml` for the
//! real bindings to enable PJRT execution (the real client is not
//! thread-safe; see `coordinator::pretrain::pretrain_all`).

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla(stub): {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires the real PJRT runtime; this build uses the offline xla stub (rust/vendor/xla)"
    )))
}

/// Storage for literal elements. Public only so `NativeType` can name it.
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + 'static {
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> LiteralData;
    #[doc(hidden)]
    fn read(d: &LiteralData) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::F32(v)
    }
    fn read(d: &LiteralData) -> Option<&[Self]> {
        match d {
            LiteralData::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::I32(v)
    }
    fn read(d: &LiteralData) -> Option<&[Self]> {
        match d {
            LiteralData::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// A host tensor (or tuple of tensors) in the device literal layout.
#[derive(Debug, Clone)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { data: T::wrap(data.to_vec()), dims: vec![data.len() as i64] }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(t) => t.iter().map(|l| l.element_count()).sum(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Reinterpret with new dimensions (`&[]` = scalar). Element count
    /// must match; an empty dims product counts as 1.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error(format!(
                "reshape to {dims:?} ({want} elements) from {have} elements"
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error("literal element type mismatch".to_string()))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::read(&self.data)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| Error("empty literal or element type mismatch".to_string()))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            LiteralData::Tuple(parts) => Ok(parts.clone()),
            _ => Err(Error("literal is not a tuple".to_string())),
        }
    }
}

/// Parsed HLO module (stub: construction always fails).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client (stub: construction always fails with a clear message).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with per-device argument lists; mirrors the real crate's
    /// `Vec<Vec<PjRtBuffer>>` result shape.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trips_f32_and_i32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.get_first_element::<f32>().unwrap(), 1.0);
        assert!(r.to_vec::<i32>().is_err());
        let i = Literal::vec1(&[7i32]);
        assert_eq!(i.reshape(&[]).unwrap().get_first_element::<i32>().unwrap(), 7);
    }

    #[test]
    fn reshape_checks_element_count() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert!(l.reshape(&[2, 2]).is_err());
    }

    #[test]
    fn pjrt_entry_points_fail_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }

    #[test]
    fn stub_types_are_send_and_sync() {
        fn check<T: Send + Sync>() {}
        check::<PjRtClient>();
        check::<PjRtLoadedExecutable>();
        check::<Literal>();
    }
}
