//! Offline drop-in for the `anyhow` crate.
//!
//! This environment has no crates.io access, so the workspace vendors the
//! API subset `mase` actually uses: [`Error`], [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension trait.
//! Semantics follow the real crate where they matter:
//!  * `{e}` displays the outermost message, `{e:#}` the full context chain
//!    joined by `": "`, and `{e:?}` an indented "Caused by:" listing;
//!  * any `std::error::Error + Send + Sync + 'static` converts via `?`
//!    (which is also why `Error` itself does NOT implement
//!    `std::error::Error` — that keeps the blanket `From` impl coherent).

use std::error::Error as StdError;
use std::fmt;

/// A context-chained error value. `chain[0]` is the outermost context,
/// the last entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to fallible results.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($tt:tt)+) => {
        return Err($crate::anyhow!($($tt)+))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($tt:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($tt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().root_cause(), "missing file");
    }

    #[test]
    fn macros_format_and_bail() {
        let name = "x";
        let e = anyhow!("unknown model '{name}'");
        assert_eq!(format!("{e}"), "unknown model 'x'");
        let e = anyhow!("{} of {}", 1, 2);
        assert_eq!(format!("{e}"), "1 of 2");
        fn guarded(v: i32) -> Result<i32> {
            ensure!(v > 0, "v must be positive, got {v}");
            Ok(v)
        }
        assert!(guarded(1).is_ok());
        assert_eq!(format!("{}", guarded(-1).unwrap_err()), "v must be positive, got -1");
    }
}
