//! Golden cross-checks for the bit-packed subsystem (artifact-free):
//! the packed integer-datapath kernels against the float reference
//! (exact for MXInt / fixed point, ULP-bounded for BMF / BL / FP8), the
//! emitted SystemVerilog operator widths against the golden datapath,
//! and the measured packed storage feeding `hw::memory` through the
//! parallelize pass and the dataflow simulator.

use mase::formats::{quantize_2d, FormatKind, Precision};
use mase::frontend::{build_graph, ModelMeta};
use mase::hw::Device;
use mase::packed::kernels::{
    dot_f64_blocked, dot_f64_grouped, gemm_f64_segmented, mxint_acc_bits, packed_dot, packed_gemm,
};
use mase::packed::layout::{pack, packed_bits_for, ElemLayout};
use mase::util::rng::Rng;

fn rand_tensor(n: usize, seed: u64, scale: f64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
}

fn quantized(fmt: FormatKind, x: &[f32], rows: usize, cols: usize, p: Precision) -> Vec<f32> {
    let mut q = x.to_vec();
    quantize_2d(fmt, &mut q, rows, cols, p);
    q
}

// ---------------------------------------------------------------- dot --

#[test]
fn mxint_packed_dot_is_exact_across_scales_and_precisions() {
    // The headline agreement property: the integer mantissa MAC with
    // shared-exponent alignment reproduces the f64-over-f32 block-order
    // reference EXACTLY — no tolerance.
    for (seed, ma, mb, scale) in [
        (0u64, 7.0f32, 7.0f32, 1.0),
        (1, 7.0, 4.0, 1e3),
        (2, 3.0, 10.0, 1e-3),
        (3, 2.0, 2.0, 1e-40), // subnormal-heavy blocks
        (4, 8.0, 8.0, 1e20),
    ] {
        let (rows, cols) = (48, 6);
        let x = rand_tensor(rows * cols, seed, scale);
        let y = rand_tensor(rows * cols, seed + 50, scale);
        let (pa_prec, pb_prec) = (Precision::new(ma, 0.0), Precision::new(mb, 0.0));
        let pa = pack(&x, rows, cols, FormatKind::MxInt, pa_prec);
        let pb = pack(&y, rows, cols, FormatKind::MxInt, pb_prec);
        let qx = quantized(FormatKind::MxInt, &x, rows, cols, pa_prec);
        let qy = quantized(FormatKind::MxInt, &y, rows, cols, pb_prec);
        let packed = packed_dot(&pa, &pb);
        let reference = dot_f64_blocked(&qx, &qy, rows, cols);
        assert_eq!(
            packed.to_bits(),
            reference.to_bits(),
            "seed {seed} m=({ma},{mb}): {packed} vs {reference}"
        );
    }
}

#[test]
fn int_packed_dot_is_exact() {
    // Fixed point shares one scale per tensor: the whole dot is integer
    // arithmetic, exact up to the documented width/size envelope
    // (w <= 12, n <= 4096 keeps every partial below 2^53).
    for (seed, w, f) in [(0u64, 8.0f32, 4.0f32), (1, 12.0, 6.0), (2, 4.0, 0.0)] {
        let (rows, cols) = (23, 9); // non-multiple of 32: partial group
        let x = rand_tensor(rows * cols, seed + 10, 3.0);
        let y = rand_tensor(rows * cols, seed + 60, 3.0);
        let p = Precision::new(w, f);
        let pa = pack(&x, rows, cols, FormatKind::Int, p);
        let pb = pack(&y, rows, cols, FormatKind::Int, p);
        let qx = quantized(FormatKind::Int, &x, rows, cols, p);
        let qy = quantized(FormatKind::Int, &y, rows, cols, p);
        assert_eq!(packed_dot(&pa, &pb), dot_f64_grouped(&qx, &qy), "seed {seed} w={w}");
    }
}

#[test]
fn bmf_bl_fp8_packed_dot_within_documented_ulp_bound() {
    // Per-element exponents make the accumulation order matter: each
    // 32-element group introduces at most one f64 rounding vs the
    // reference, so |packed - ref| <= n * 2^-50 * sum|a_i b_i| (module
    // docs of packed::kernels). BMF and FP8 are expected to hit the
    // reference exactly in practice; BL's wide exponent spans take the
    // aligner-fallback path and genuinely use the bound.
    for (fmt, bits) in
        [(FormatKind::Bmf, 5.0f32), (FormatKind::Bl, 7.0), (FormatKind::Bl, 3.0), (FormatKind::Fp8, 8.0)]
    {
        for seed in 0..4u64 {
            let (rows, cols) = (32, 8);
            let scale = [1.0, 1e3, 1e-3, 1e-30][seed as usize];
            let x = rand_tensor(rows * cols, seed + 20, scale);
            let y = rand_tensor(rows * cols, seed + 70, 1.0);
            let p = Precision::new(bits, 0.0);
            let pa = pack(&x, rows, cols, fmt, p);
            let pb = pack(&y, rows, cols, fmt, p);
            let qx = quantized(fmt, &x, rows, cols, p);
            let qy = quantized(fmt, &y, rows, cols, p);
            let packed = packed_dot(&pa, &pb);
            let reference = if fmt.is_block_format() {
                dot_f64_blocked(&qx, &qy, rows, cols)
            } else {
                dot_f64_grouped(&qx, &qy)
            };
            let gross: f64 =
                qx.iter().zip(qy.iter()).map(|(a, b)| (*a as f64 * *b as f64).abs()).sum();
            let bound = (qx.len() as f64) * 2f64.powi(-50) * gross;
            assert!(
                (packed - reference).abs() <= bound,
                "{} bits={bits} seed {seed}: {packed} vs {reference} (bound {bound})",
                fmt.name()
            );
        }
    }
}

// --------------------------------------------------------------- gemm --

#[test]
fn mxint_packed_gemm_matches_segmented_reference_exactly() {
    let (m, k, n) = (32, 48, 10);
    let x = rand_tensor(m * k, 31, 1.0);
    let y = rand_tensor(k * n, 32, 1.0);
    let (pa_prec, pb_prec) = (Precision::new(7.0, 0.0), Precision::new(4.0, 0.0));
    let pa = pack(&x, m, k, FormatKind::MxInt, pa_prec);
    let pb = pack(&y, k, n, FormatKind::MxInt, pb_prec);
    let qx = quantized(FormatKind::MxInt, &x, m, k, pa_prec);
    let qy = quantized(FormatKind::MxInt, &y, k, n, pb_prec);

    let packed = packed_gemm(&pa, &pb);
    let reference = gemm_f64_segmented(&qx, &qy, m, k, n);
    for (i, (pv, rv)) in packed.iter().zip(reference.iter()).enumerate() {
        assert_eq!(pv.to_bits(), rv.to_bits(), "C[{i}]: {pv} vs {rv}");
    }

    // And the fixed segment order stays within float-noise of the plain
    // element-order sum (sanity that the order convention is benign).
    for i in 0..m {
        for j in 0..n {
            let mut naive = 0.0f64;
            for t in 0..k {
                naive += qx[i * k + t] as f64 * qy[t * n + j] as f64;
            }
            let got = packed[i * n + j] as f64;
            assert!(
                (got - naive).abs() <= 1e-10 * naive.abs().max(1e-30) + 1e-30,
                "C[{i},{j}]: {got} vs naive {naive}"
            );
        }
    }
}

#[test]
#[should_panic(expected = "inner dimensions")]
fn gemm_dimension_mismatch_panics() {
    let x = rand_tensor(16 * 2, 1, 1.0);
    let p = Precision::new(4.0, 0.0);
    let a = pack(&x, 16, 2, FormatKind::MxInt, p);
    let b = pack(&x, 16, 2, FormatKind::MxInt, p);
    let _ = packed_gemm(&a, &b); // 2 != 16 inner dims must be rejected
}

// ------------------------------------------------- emit cross-checks --

#[test]
fn emitted_operator_widths_cover_the_golden_datapath() {
    // The SystemVerilog dot-product operator must declare (a) a mantissa
    // port exactly as wide as the packed element field and (b) an
    // accumulator at the width the golden software datapath proves
    // sufficient for one block's exact integer dot.
    for m in 1..=12u32 {
        let sv = mase::emit::templates::mxint_dot_product("dp", m, 2, 2);
        let lay = ElemLayout::new(FormatKind::MxInt, Precision::new(m as f32, 0.0));
        assert_eq!(lay.elem_bits, m + 1, "packed MXInt element = sign + m bits");
        assert!(
            sv.contains(&format!("MAN_W  = {}", lay.elem_bits)),
            "m={m}: MAN_W must match the packed element width"
        );
        let acc = mxint_acc_bits(m);
        assert!(sv.contains(&format!("ACC_W  = {acc}")), "m={m}: ACC_W must be {acc}");
        let worst = 32u128 * ((1u128 << m) - 1).pow(2);
        assert!(worst <= (1u128 << (acc - 1)) - 1, "m={m}: ACC_W {acc} too narrow for {worst}");
    }
}

// ------------------------------------- hw::memory / sim cross-checks --

#[test]
fn memory_plan_prices_exactly_what_packing_occupies() {
    let meta = ModelMeta::synthetic("golden", 2, 32, 2, 512, 32, 4, "classifier", 8);
    let mut g = build_graph(&meta);
    let n = meta.num_qtensors();
    mase::frontend::apply_quant_to_graph(&mut g, FormatKind::MxInt, &vec![5.0; n], &[]);

    let device = Device::u250();
    let placements = mase::hw::memory::plan(&g, &device);
    assert!(!placements.is_empty());
    for p in &placements {
        let v = g.values.iter().find(|v| v.name == p.value_name).unwrap();
        assert_eq!(
            p.bits,
            packed_bits_for(v.ty.format, v.ty.precision, &v.ty.shape) as f64,
            "{}: plan must price measured packed storage",
            p.value_name
        );
        // ... which is exactly what packing a real tensor occupies.
        if v.ty.format == FormatKind::MxInt {
            let (r, c) = (v.ty.shape[0], v.ty.shape[1]);
            let data = rand_tensor(r * c, 7, 1.0);
            let t = pack(&data, r, c, v.ty.format, v.ty.precision);
            assert_eq!(p.bits, t.storage_bits() as f64, "{}", p.value_name);
        }
    }

    // The measured numbers flow through parallelize into the simulator's
    // graph without upsetting either (sim cross-check).
    let dp = mase::passes::parallelize(&mut g, &device, 0.3);
    assert!(dp.throughput > 0.0 && dp.throughput.is_finite());
    assert!(dp.offchip_bits >= 0.0);
    let sim = mase::sim::simulated_throughput(&g, device.clock_hz, 4);
    assert!(sim > 0.0 && sim.is_finite());
}
