//! Integration tests over the real AOT artifacts: runtime + passes +
//! coordinator working together. These need `make artifacts` to have run;
//! they pretrain (cached) the tiny opt-125m-sim only, so they stay fast.

use mase::coordinator::{pretrain, PretrainConfig, Session};
use mase::data::{batches, Task};
use mase::formats::FormatKind;
use mase::passes::{profile_model, run_search, Evaluator, QuantSolution, SearchConfig};

fn session() -> Option<Session> {
    let dir = Session::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Session::open(&dir).expect("session"))
}

fn tiny_weights(session: &Session) -> (mase::frontend::ModelMeta, Vec<f32>) {
    let meta = session.manifest.model("opt-125m-sim").unwrap().clone();
    let w = pretrain::pretrain(
        session,
        &meta,
        Some(Task::Sst2),
        &PretrainConfig { steps: 220, log_every: 0, ..Default::default() },
    )
    .expect("pretrain");
    (meta, w)
}

#[test]
fn pretrained_model_beats_chance_and_quantization_degrades_gracefully() {
    let Some(session) = session() else { return };
    let (meta, w) = tiny_weights(&session);
    let eval = batches(Task::Sst2, 1, 3, meta.batch, meta.seq_len);
    let ev = Evaluator::new(session.pjrt_backend().unwrap(), &meta, &w, &eval).unwrap();
    let profile = profile_model(&ev.backend, &meta, &w, &eval[..1]).unwrap();

    let acc_of = |fmt, bits| {
        ev.accuracy(&QuantSolution::uniform(fmt, bits, &meta, &profile)).unwrap().accuracy()
    };
    let fp32 = acc_of(FormatKind::Fp32, 32.0);
    assert!(fp32 > 0.70, "fp32 accuracy too low: {fp32}");

    let mx7 = acc_of(FormatKind::MxInt, 7.0);
    let mx2 = acc_of(FormatKind::MxInt, 2.0);
    assert!(mx7 >= fp32 - 0.05, "MXInt8 should be near fp32: {mx7} vs {fp32}");
    assert!(mx2 <= mx7 + 1e-9, "2-bit mantissa should not beat 7-bit");
}

#[test]
fn outlier_channels_break_int8_resolution_but_not_mxint8() {
    // The Table 1 mechanism, tested mechanistically: on an activation
    // tensor with the injected outlier channels, per-tensor static int8
    // (absmax-calibrated) loses log2(gain) bits of resolution for the
    // non-outlier channels, while MXInt's per-block shared exponents
    // isolate the outliers. Compare mean quantization error on the
    // non-outlier portion of a representative profiled activation.
    let Some(session) = session() else { return };
    let (meta, w) = tiny_weights(&session);
    let eval = batches(Task::Sst2, 1, 1, meta.batch, meta.seq_len);
    let _ = (&w, &eval);
    // synthesize the LN-output distribution the profile measured: unit
    // normals with channels 0..4 scaled by the layer-1 gain (32x)
    let gain = mase::frontend::OUTLIER_BASE_GAIN * 2.0;
    let d = meta.d_model;
    let rows = 64;
    let mut rng = mase::util::rng::Rng::new(5);
    let mut x = vec![0.0f32; rows * d];
    for r in 0..rows {
        for c in 0..d {
            let v = rng.normal() as f32;
            x[r * d + c] =
                if c < mase::frontend::OUTLIER_CHANNELS { v * gain } else { v };
        }
    }
    let absmax = x.iter().fold(0.0f32, |a, v| a.max(v.abs()));
    let err_on_normal = |q: &[f32]| {
        let mut e = 0.0f64;
        let mut n = 0;
        for r in 0..rows {
            for c in mase::frontend::OUTLIER_CHANNELS..d {
                e += (q[r * d + c] - x[r * d + c]).abs() as f64;
                n += 1;
            }
        }
        e / n as f64
    };
    let mut q_int = x.clone();
    mase::formats::int_quantize(
        &mut q_int,
        8.0,
        mase::formats::fixed::calibrate_frac(8.0, absmax),
    );
    let mut q_mx = x.clone();
    mase::formats::mxint_quantize(&mut q_mx, rows, d, 7.0);
    let (ei, em) = (err_on_normal(&q_int), err_on_normal(&q_mx));
    assert!(
        ei > 5.0 * em,
        "int8 error on non-outlier channels ({ei:.4}) should dwarf MXInt8's ({em:.4})"
    );
}

#[test]
fn profile_shows_depth_growing_variance() {
    // Fig. 1a: deeper layers have larger activation variance (built-in
    // outlier gain grows with depth).
    let Some(session) = session() else { return };
    let meta = session.manifest.model("llama-sim").unwrap().clone();
    let w = pretrain::pretrain(&session, &meta, None, &PretrainConfig { steps: 220, log_every: 0, ..Default::default() })
        .unwrap();
    let corpus = mase::data::MarkovCorpus::new(7);
    let b = mase::data::Batch {
        tokens: corpus.batch(99, meta.batch, meta.seq_len),
        labels: vec![0; meta.batch],
        batch: meta.batch,
        seq: meta.seq_len,
    };
    let p = profile_model(&session.pjrt_backend().unwrap(), &meta, &w, &[b]).unwrap();
    let var_of = |name: &str| {
        p.variance[p.names.iter().position(|n| n == name).unwrap()]
    };
    let first = var_of("layer0.a_attn_in");
    let last = var_of(&format!("layer{}.a_attn_in", meta.n_layers - 1));
    assert!(last > first, "variance should grow with depth: {first} vs {last}");
    assert!(p.variance_spread() > 10.0, "spread {}", p.variance_spread());
}

#[test]
fn search_finds_sub_8bit_solution_without_accuracy_collapse() {
    let Some(session) = session() else { return };
    let (meta, w) = tiny_weights(&session);
    let eval = batches(Task::Sst2, 1, 3, meta.batch, meta.seq_len);
    let ev = Evaluator::new(session.pjrt_backend().unwrap(), &meta, &w, &eval).unwrap();
    let profile = profile_model(&ev.backend, &meta, &w, &eval[..1]).unwrap();
    let fp32 = ev
        .accuracy(&QuantSolution::uniform(FormatKind::Fp32, 32.0, &meta, &profile))
        .unwrap()
        .accuracy();
    let outcome = run_search(
        &ev,
        &profile,
        Task::Sst2,
        &SearchConfig { trials: 12, ..Default::default() },
    )
    .unwrap();
    assert!(outcome.best_eval.avg_bits < 8.25);
    assert!(outcome.best_eval.accuracy > fp32 - 0.10);
    assert_eq!(outcome.history.len(), 12);
}

#[test]
fn qat_steps_run_and_return_tuned_weights() {
    let Some(session) = session() else { return };
    let (meta, w) = tiny_weights(&session);
    let eval = batches(Task::Sst2, 1, 2, meta.batch, meta.seq_len);
    let ev = Evaluator::new(session.pjrt_backend().unwrap(), &meta, &w, &eval).unwrap();
    let profile = profile_model(&ev.backend, &meta, &w, &eval[..1]).unwrap();
    let outcome = run_search(
        &ev,
        &profile,
        Task::Sst2,
        &SearchConfig { trials: 3, qat_steps: 2, ..Default::default() },
    )
    .unwrap();
    let tuned = outcome.tuned_weights.expect("QAT should produce tuned weights");
    assert_eq!(tuned.len(), meta.param_size);
    assert!(tuned != w, "fine-tuning must change the weights");
}

#[test]
fn emitted_design_lints_and_simulates() {
    let Some(session) = session() else { return };
    let (meta, w) = tiny_weights(&session);
    let eval = batches(Task::Sst2, 1, 2, meta.batch, meta.seq_len);
    let ev = Evaluator::new(session.pjrt_backend().unwrap(), &meta, &w, &eval).unwrap();
    let profile = profile_model(&ev.backend, &meta, &w, &eval[..1]).unwrap();
    let sol = QuantSolution::uniform(FormatKind::MxInt, 4.0, &meta, &profile);
    let (dp, _bits, g) = ev.hardware(&sol).unwrap();

    let design = mase::emit::emit_design(&g);
    for (name, text) in &design.files {
        let errs = mase::emit::lint_sv(text);
        assert!(errs.is_empty(), "{name}: {errs:?}");
    }
    let sim = mase::sim::simulated_throughput(&g, mase::hw::Device::u250().clock_hz, 4);
    assert!(sim > 0.0 && sim.is_finite());
    assert!(dp.throughput > 0.0);
}

#[test]
fn lm_perplexity_far_below_uniform_after_training() {
    let Some(session) = session() else { return };
    let meta = session.manifest.model("llama-sim").unwrap().clone();
    let w = pretrain::pretrain(&session, &meta, None, &PretrainConfig { steps: 220, log_every: 0, ..Default::default() })
        .unwrap();
    let corpus = mase::data::MarkovCorpus::new(7);
    let bs: Vec<_> = (0..2)
        .map(|i| mase::data::Batch {
            tokens: corpus.batch(2000 + i, meta.batch, meta.seq_len),
            labels: vec![0; meta.batch],
            batch: meta.batch,
            seq: meta.seq_len,
        })
        .collect();
    let ev = Evaluator::new(session.pjrt_backend().unwrap(), &meta, &w, &bs).unwrap();
    let profile = profile_model(&ev.backend, &meta, &w, &bs[..1]).unwrap();
    let acc = ev
        .accuracy(&QuantSolution::uniform(FormatKind::Fp32, 32.0, &meta, &profile))
        .unwrap();
    assert!(
        acc.perplexity() < 0.5 * meta.vocab as f64,
        "trained LM ppl {} should be far below uniform {}",
        acc.perplexity(),
        meta.vocab
    );
}

#[test]
fn failure_injection_bad_inputs_are_clean_errors() {
    let Some(session) = session() else { return };
    // unknown model
    assert!(session.manifest.model("gpt-999").is_err());
    // missing artifact key
    let meta = session.manifest.model("bert-base-sim").unwrap();
    assert!(meta.artifact("qat_bl").is_err());
    // wrong-shaped execution input must error, not crash
    let r = session.pjrt().unwrap().execute(
        meta.artifact("profile").unwrap(),
        &[mase::runtime::TensorData::f32(&[0.0; 8], &[8])],
    );
    assert!(r.is_err());
    // corrupt weights cache is rejected by size check
    let path = mase::coordinator::pretrain::weights_path(&session, "bert-base-sim", "qqp");
    std::fs::create_dir_all(path.parent().unwrap()).unwrap();
    std::fs::write(&path, b"junk").unwrap();
    let w = pretrain::pretrain(
        &session,
        &meta.clone(),
        Some(Task::Qqp),
        &PretrainConfig { steps: 2, log_every: 0, ..Default::default() },
    );
    std::fs::remove_file(&path).ok();
    assert!(w.is_ok(), "corrupt cache should be ignored and retrained");
}
