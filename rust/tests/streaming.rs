//! Tier-1 regressions for the bandwidth-aware packed-word streaming
//! model (PR 5): the simulator and the closed-form throughput model
//! serialize dataflow transfers as `beats = ceil(tile_bits / width)`
//! with tile payloads measured by `packed::packed_bits_for`.
//!
//! The contracts pinned here:
//!  1. unbounded channels degrade bit-identically to the legacy tile
//!     model (the pre-PR-5 simulator);
//!  2. halving a saturated channel's width at least doubles the
//!     transfer-bound cycles;
//!  3. at equal channel width, MXInt4 tiles stream in strictly fewer
//!     beats — and simulate strictly higher throughput — than 8-bit
//!     fixed point on the same graph (the paper's Table 1 memory-density
//!     argument, now visible in simulated time);
//!  4. zero-payload interface tokens and non-word-multiple remainders
//!     round the way streaming hardware rounds.

use mase::formats::{FormatKind, Precision};
use mase::hw::throughput::{op_tile_bits, op_transfer_beats};
use mase::hw::Device;
use mase::ir::{Graph, OpKind, TensorType};
use mase::packed::packed_bits_for;
use mase::sim::{
    nodes_from_graph, simulate, simulated_throughput, simulated_throughput_at, SimConfig,
};

/// A two-stage pipeline whose activations are quantized to `fmt`/`p`:
/// src -> linear -> gelu, all edges tiled (16, 2).
fn pipeline_graph(fmt: FormatKind, p: Precision) -> Graph {
    let mut g = Graph::new("stream");
    let x = g.add_input("x", TensorType::fp32(vec![32, 64]));
    let w = g.new_value(
        "w",
        TensorType { shape: vec![64, 64], format: fmt, precision: p },
        None,
    );
    let h = g.add_op(
        OpKind::Linear,
        vec![x],
        vec![w],
        "h",
        TensorType { shape: vec![32, 64], format: fmt, precision: p },
        None,
    );
    let y = g.add_op(
        OpKind::Gelu,
        vec![h],
        vec![],
        "y",
        TensorType { shape: vec![32, 64], format: fmt, precision: p },
        None,
    );
    g.value_mut(h).attrs.tile = (16, 2);
    g.value_mut(y).attrs.tile = (16, 2);
    g.outputs.push(y);
    g
}

#[test]
fn unbounded_channels_reproduce_the_legacy_tile_model() {
    // The acceptance contract: with the channel width effectively
    // unbounded, the beat model must be bit-identical to the pre-PR tile
    // simulator — same cycles, same stalls, same throughput number.
    let g = pipeline_graph(FormatKind::MxInt, Precision::new(5.0, 0.0));
    let nodes = nodes_from_graph(&g);
    let run = |channel_bits| {
        simulate(
            &nodes,
            &SimConfig { inferences: 8, fifo_depth: 4, sequential: false, channel_bits },
        )
    };
    let unbounded = run(SimConfig::UNBOUNDED);
    let huge = run(1 << 40);
    assert_eq!(unbounded.cycles, huge.cycles);
    assert_eq!(unbounded.busy, huge.busy);
    assert_eq!(unbounded.stalled, huge.stalled);
    // and through the convenience entry points, bit-identical f64s
    let clock = Device::u250().clock_hz;
    let legacy = simulated_throughput(&g, clock, 8);
    assert_eq!(legacy.to_bits(), simulated_throughput_at(&g, clock, 8, 0).to_bits());
    assert_eq!(legacy.to_bits(), simulated_throughput_at(&g, clock, 8, 1 << 40).to_bits());
}

#[test]
fn halving_channel_width_at_least_doubles_transfer_cycles() {
    // MXInt m=7: 8-bit elements, one (16,2) block per tile = 264 bits
    // (4 words + exp byte). Widths 4 and 2 divide it (66 and 132 beats),
    // and 66 beats already exceeds the linear's 64-cycle compute II, so
    // the whole pipeline is transfer-bound at BOTH widths: beats double
    // exactly, and so do the channel's transfer cycles.
    let g = pipeline_graph(FormatKind::MxInt, Precision::new(7.0, 0.0));
    let nodes = nodes_from_graph(&g);
    let run = |channel_bits| {
        simulate(
            &nodes,
            &SimConfig { inferences: 2, fifo_depth: 4, sequential: false, channel_bits },
        )
    };
    let wide = run(4);
    let narrow = run(2);
    // every real edge (producer emits payload) doubles its beat count
    let mut checked = 0;
    for (ew, en) in wide.edges.iter().zip(narrow.edges.iter()) {
        assert_eq!((ew.producer, ew.consumer, ew.slot), (en.producer, en.consumer, en.slot));
        if ew.tile_bits > 0 {
            assert_eq!(en.beats_per_tile, 2 * ew.beats_per_tile, "edge {}->{}", ew.producer, ew.consumer);
            assert_eq!(en.transfer_cycles, 2 * ew.transfer_cycles);
            checked += 1;
        }
    }
    assert!(checked >= 1, "no payload-bearing edges simulated");
    // and the transfer-bound pipeline slows by ~2x end to end
    assert!(
        narrow.cycles as f64 >= 1.8 * wide.cycles as f64,
        "narrow {} vs wide {}",
        narrow.cycles,
        wide.cycles
    );
}

#[test]
fn mxint4_streams_in_strictly_fewer_beats_than_fixed8() {
    // Same graph, same channel width; only the format changes. MXInt4
    // (4-bit elements + amortized shared exponent): 136 bits per (16,2)
    // tile vs fixed-8's 256 — fewer beats on every edge, strictly higher
    // simulated throughput once the fabric is transfer-bound.
    let g4 = pipeline_graph(FormatKind::MxInt, Precision::new(3.0, 0.0));
    let g8 = pipeline_graph(FormatKind::Int, Precision::new(8.0, 4.0));
    // 2-bit channels: even the MXInt4 stream (68 beats/tile) outruns the
    // linear's 64-cycle compute II, so both configurations are
    // transfer-bound and the format gap is visible end to end.
    let width = 2u64;

    for (op4, op8) in g4.ops.iter().zip(g8.ops.iter()) {
        if op4.kind != OpKind::Linear && op4.kind != OpKind::Gelu {
            continue;
        }
        let b4 = op_transfer_beats(&g4, op4, (16, 2), width);
        let b8 = op_transfer_beats(&g8, op8, (16, 2), width);
        assert!(b4 < b8, "{}: mxint4 {b4} beats vs fixed8 {b8}", op4.kind.name());
    }

    let clock = Device::u250().clock_hz;
    let t4 = simulated_throughput_at(&g4, clock, 4, width);
    let t8 = simulated_throughput_at(&g8, clock, 4, width);
    assert!(
        t4 > t8,
        "MXInt4 must simulate strictly faster than fixed-8 through a {width}-bit fabric: {t4} vs {t8}"
    );
    // sanity: at unbounded width the two formats tie (compute-identical)
    let u4 = simulated_throughput(&g4, clock, 4);
    let u8_ = simulated_throughput(&g8, clock, 4);
    assert_eq!(u4.to_bits(), u8_.to_bits(), "formats only differ through the channel model");
}

#[test]
fn zero_and_remainder_payloads_round_like_hardware() {
    // Interface tokens (inputs/outputs) carry no payload: free transfer.
    let g = pipeline_graph(FormatKind::MxInt, Precision::new(5.0, 0.0));
    let nodes = nodes_from_graph(&g);
    assert_eq!(nodes[0].out_tile_bits, 0, "input op streams free tokens");
    let r = simulate(
        &nodes,
        &SimConfig { inferences: 1, fifo_depth: 4, sequential: false, channel_bits: 16 },
    );
    for e in &r.edges {
        if e.tile_bits == 0 {
            assert_eq!(e.beats_per_tile, 1, "zero payload = single beat");
        } else {
            assert_eq!(e.beats_per_tile, e.tile_bits.div_ceil(16), "remainders round up");
        }
    }

    // A partial-block tile is priced as a full padded block — the same
    // rule `hw::memory` applies to partial tensors.
    let op = g.ops.iter().find(|o| o.kind == OpKind::Gelu).unwrap();
    assert_eq!(
        op_tile_bits(&g, op, (3, 1)),
        packed_bits_for(FormatKind::MxInt, Precision::new(5.0, 0.0), &[16, 2]),
        "partial blocks pad to full ones"
    );

    // Remainder beat count: 264-bit tiles over a 16-bit channel is
    // ceil(16.5) = 17 beats, never 16.
    let g8 = pipeline_graph(FormatKind::MxInt, Precision::new(7.0, 0.0));
    let op8 = g8.ops.iter().find(|o| o.kind == OpKind::Gelu).unwrap();
    assert_eq!(op_transfer_beats(&g8, op8, (16, 2), 16), 17.0);
}

#[test]
fn transfer_bound_stalls_are_credited_to_channels_not_consumers() {
    // Mixed precision starves the fabric asymmetrically: the linear's
    // wide MXInt8 tiles (264 bits = 66 beats at 4-bit channels, past its
    // 64-cycle compute II) make it transfer-bound, while the gelu's
    // narrow MXInt4 output (136 bits = 34 beats) finishes each firing
    // early and then *waits on the linear's channel* ~32 of every 66
    // cycles. That wait belongs to the channel's counter; the per-node
    // stall table must stay (mostly) clean.
    let mut g = pipeline_graph(FormatKind::MxInt, Precision::new(7.0, 0.0));
    let y = g.outputs[0];
    g.value_mut(y).ty.precision = Precision::new(3.0, 0.0);
    let nodes = nodes_from_graph(&g);
    let r = simulate(
        &nodes,
        &SimConfig { inferences: 2, fifo_depth: 4, sequential: false, channel_bits: 4 },
    );
    let channel_stalls: u64 = r.edges.iter().map(|e| e.transfer_stalled).sum();
    assert!(channel_stalls > 0, "transfer-bound run must charge its channels");
    // consumers of transfer-bound producers stay un-charged for those waits
    for e in &r.edges {
        if e.transfer_stalled > 0 {
            assert!(
                r.stalled[e.consumer] <= r.cycles / 4,
                "node {} charged {} stall cycles that belong to channel {}->{}",
                e.consumer,
                r.stalled[e.consumer],
                e.producer,
                e.consumer
            );
        }
    }
}

#[test]
fn search_objective_is_bandwidth_sensitive() {
    // The closed form the search scores with must see the channel: the
    // same graph on a channel-starved device yields strictly lower
    // regression throughput.
    use mase::passes::{parallelize, ProfileData, QuantSolution};
    let meta = mase::frontend::manifest::ModelMeta::synthetic(
        "bw", 2, 32, 2, 512, 32, 4, "classifier", 64,
    );
    let profile = ProfileData::uniform(&meta, 4.0);
    let wide_dev = Device::u250();
    let mut narrow_dev = Device::u250();
    narrow_dev.channel_bits = 8;

    let mut g_wide = mase::frontend::build_graph(&meta);
    QuantSolution::uniform(FormatKind::MxInt, 5.0, &meta, &profile).apply(&mut g_wide);
    let dp_wide = parallelize(&mut g_wide, &wide_dev, 0.3);

    let mut g_narrow = mase::frontend::build_graph(&meta);
    QuantSolution::uniform(FormatKind::MxInt, 5.0, &meta, &profile).apply(&mut g_narrow);
    let dp_narrow = parallelize(&mut g_narrow, &narrow_dev, 0.3);

    assert!(
        dp_narrow.throughput < dp_wide.throughput,
        "8-bit channels must cap the design point: {} vs {}",
        dp_narrow.throughput,
        dp_wide.throughput
    );
}
