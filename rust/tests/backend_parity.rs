//! Backend parity and the artifact-free e2e path — tier-1 tests that do
//! NOT self-skip: everything here runs on a bare host with no PJRT
//! artifacts, through `runtime::CpuBackend`.
//!
//! Parity contract (inherited from the PR 3 packed-kernel golden pair):
//!
//!  * MXInt and fixed point: the packed integer datapath and the
//!    fake-quantized float reference produce **bit-identical** GEMMs,
//!    hence bit-identical logits, loss, and accuracy.
//!  * BMF / BL / FP8: each GEMM output is within the documented
//!    `n * 2^-50 * sum|a_i b_i|` bound of the reference; through the
//!    tiny model below that propagates to a relative loss disagreement
//!    around 1e-11, asserted here with a 1e-6 relative tolerance (five
//!    orders of margin) and identical correct-counts.

use mase::coordinator::{run_flow, run_sweep, FlowConfig, Session, SweepConfig};
use mase::data::{batches, Batch, MarkovCorpus, Task};
use mase::formats::FormatKind;
use mase::frontend::ModelMeta;
use mase::passes::{profile_model, Evaluator, QuantSolution};
use mase::runtime::{BackendKind, CpuBackend};
use mase::search::Algorithm;

fn tiny_classifier() -> ModelMeta {
    ModelMeta::synthetic("tiny-sim", 1, 32, 2, 512, 16, 4, "classifier", 16)
}

fn tiny_lm() -> ModelMeta {
    ModelMeta::synthetic("tiny-lm", 1, 32, 2, 512, 16, 4, "lm", 16)
}

fn eval_set(meta: &ModelMeta) -> Vec<Batch> {
    if meta.kind == "lm" {
        let corpus = MarkovCorpus::new(7);
        (0..2)
            .map(|i| Batch {
                tokens: corpus.batch(500 + i, meta.batch, meta.seq_len),
                labels: vec![0; meta.batch],
                batch: meta.batch,
                seq: meta.seq_len,
            })
            .collect()
    } else {
        batches(Task::Sst2, 1, 2, meta.batch, meta.seq_len)
    }
}

/// (mean_loss, correct_count) through both interpreter datapaths.
fn both_paths(meta: &ModelMeta, fmt: FormatKind, bits: f32) -> ((f64, u64), (f64, u64)) {
    let w = mase::frontend::init_params(meta, 0xC0DE);
    let eval = eval_set(meta);
    let profile = profile_model(&CpuBackend::new(), meta, &w, &eval[..1]).expect("profile");
    let sol = QuantSolution::uniform(fmt, bits, meta, &profile);
    let run = |be: CpuBackend| {
        let ev = Evaluator::new(be, meta, &w, &eval).expect("evaluator");
        let acc = ev.accuracy(&sol).expect("accuracy");
        assert!(acc.mean_loss().is_finite(), "{}: non-finite loss", fmt.name());
        (acc.mean_loss(), acc.total_correct)
    };
    (run(CpuBackend::new()), run(CpuBackend::reference()))
}

#[test]
fn mxint_and_fixed_are_bit_exact_between_packed_and_reference() {
    for (meta, fmt, bits) in [
        (tiny_classifier(), FormatKind::MxInt, 4.0),
        (tiny_classifier(), FormatKind::MxInt, 7.0),
        (tiny_classifier(), FormatKind::Int, 8.0),
        (tiny_classifier(), FormatKind::Int, 5.0),
        (tiny_lm(), FormatKind::MxInt, 6.0),
    ] {
        let ((lp, cp), (lr, cr)) = both_paths(&meta, fmt, bits);
        assert_eq!(
            lp.to_bits(),
            lr.to_bits(),
            "{}@{bits} ({}): packed loss {lp} != reference {lr}",
            fmt.name(),
            meta.kind,
        );
        assert_eq!(cp, cr, "{}@{bits}: correct counts diverged", fmt.name());
    }
}

#[test]
fn bounded_formats_agree_within_documented_ulp_bound() {
    for (fmt, bits) in
        [(FormatKind::Bmf, 5.0), (FormatKind::Bl, 7.0), (FormatKind::Fp8, 8.0)]
    {
        let ((lp, cp), (lr, cr)) = both_paths(&tiny_classifier(), fmt, bits);
        let rel = (lp - lr).abs() / lr.abs().max(1e-12);
        assert!(
            rel < 1e-6,
            "{}@{bits}: packed loss {lp} vs reference {lr} (rel {rel:e})",
            fmt.name()
        );
        assert_eq!(cp, cr, "{}@{bits}: correct counts diverged", fmt.name());
    }
}

#[test]
fn fp32_baseline_is_real_and_oracle_responds_to_the_precision_knob() {
    // Sanity on the packed path alone: fp32 scores a real loss, and a
    // brutal 1-bit MXInt mantissa must actually change the measured loss
    // (the oracle is quantization-sensitive, not a constant).
    let meta = tiny_classifier();
    let w = mase::frontend::init_params(&meta, 0xC0DE);
    let eval = eval_set(&meta);
    let profile = profile_model(&CpuBackend::new(), &meta, &w, &eval[..1]).unwrap();
    let ev = Evaluator::new(CpuBackend::new(), &meta, &w, &eval).unwrap();
    let fp32 =
        ev.accuracy(&QuantSolution::uniform(FormatKind::Fp32, 32.0, &meta, &profile)).unwrap();
    let mx1 =
        ev.accuracy(&QuantSolution::uniform(FormatKind::MxInt, 1.0, &meta, &profile)).unwrap();
    assert!(fp32.mean_loss().is_finite() && fp32.accuracy() >= 0.0);
    assert!(mx1.mean_loss().is_finite());
    assert_ne!(
        mx1.mean_loss(),
        fp32.mean_loss(),
        "1-bit MXInt must perturb the loss — the oracle is ignoring precision"
    );
}

#[test]
fn e2e_flow_completes_on_cpu_backend_without_artifacts() {
    // The acceptance criterion: the full search→evaluate→co-design loop
    // on a host with NO artifacts — synthetic manifest, init weights,
    // packed interpreter. This test never self-skips.
    let dir = std::env::temp_dir().join(format!("mase-cpu-e2e-{}", std::process::id()));
    let session = Session::open_for(&dir, BackendKind::Cpu).expect("cpu session");
    assert!(session.runtime.is_none());
    assert!(session.pjrt().is_err(), "cpu session must not expose a PJRT runtime");

    let cfg = FlowConfig {
        model: "toy-sim".into(),
        task: Task::Sst2,
        fmt: FormatKind::MxInt,
        algorithm: Algorithm::Tpe,
        trials: 5,
        eval_batches: 1,
        pretrain_steps: 0,
        threads: 1,
        batch: 2,
        backend: BackendKind::Cpu,
        ..Default::default()
    };
    let report = run_flow(&session, &cfg).expect("cpu flow");
    assert!(report.fp32_accuracy.is_finite(), "fp32 accuracy is NaN");
    let best = &report.outcome.best_eval;
    assert!(best.value.is_finite() && best.accuracy.is_finite());
    assert!(best.mean_loss.is_finite(), "best mean loss is NaN");
    assert!(best.perplexity.is_finite(), "best perplexity is NaN");
    assert!(report.int8_baseline.accuracy.is_finite());
    assert_eq!(report.outcome.history.len(), 5);
    assert!(best.avg_bits > 0.0);
    assert!(report.dag_size > 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_completes_on_cpu_backend_without_artifacts() {
    let dir = std::env::temp_dir().join(format!("mase-cpu-sweep-{}", std::process::id()));
    let session = Session::open_for(&dir, BackendKind::Cpu).expect("cpu session");
    let cfg = SweepConfig {
        models: vec!["toy-sim".into()],
        tasks: vec![Task::Sst2],
        fmts: vec![FormatKind::MxInt],
        trials: 4,
        eval_batches: 1,
        pretrain_steps: 0,
        threads: 1,
        batch: 2,
        backend: BackendKind::Cpu,
        ..Default::default()
    };
    let report = run_sweep(&session, &cfg).expect("cpu sweep");
    assert_eq!(report.rows.len(), 1);
    let row = &report.rows[0];
    assert!(row.cell.accuracy.is_finite() && row.cell.value.is_finite());
    assert_eq!(row.cell.mode, "PTQ");
    assert!(row.cache.misses > 0, "cold sweep must pay evaluations");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cpu_backend_rejects_qat_with_a_clean_error() {
    let dir = std::env::temp_dir().join(format!("mase-cpu-qat-{}", std::process::id()));
    let session = Session::open_for(&dir, BackendKind::Cpu).unwrap();
    let cfg = FlowConfig {
        model: "toy-sim".into(),
        trials: 2,
        eval_batches: 1,
        pretrain_steps: 0,
        qat_steps: 2,
        threads: 1,
        backend: BackendKind::Cpu,
        ..Default::default()
    };
    let err = run_flow(&session, &cfg).unwrap_err();
    assert!(format!("{err:#}").contains("QAT"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}
