//! `.mxa` packed-weight artifact contracts — tier-1, artifact-free
//! (in the PJRT sense: no HLO artifacts needed; the container files
//! live in a temp dir).
//!
//! Two layers of guarantee:
//!
//!  1. **Container round trip** (`every_format_round_trips...`): for all
//!     formats, `load(write(pack(x)))` returns the packed bits
//!     byte-for-byte — including zero-element tensors and element-wise
//!     shapes with a partial trailing pack group.
//!  2. **Interpreter contract** (`artifact_backed_decode_contract`): a
//!     warm `CpuBackend::with_artifact` session performs ZERO weight
//!     pack calls and decodes bit-identically to the in-memory path; an
//!     artifact packed from the WRONG weights falls back to repacking
//!     (still bit-identical, never silently wrong); corruption and
//!     truncation fail closed naming the offending tensor/chunk.
//!
//! The pack counter ([`mase::packed::kernel_tally`]) is process-global,
//! so every `Interp`-constructing assertion lives in the ONE contract
//! test — the round-trip test only drives `pack()`/writer/reader, which
//! never touch the counter.

use mase::data::MarkovCorpus;
use mase::formats::{FormatKind, FormatSpec};
use mase::frontend::{build_graph, init_params, ModelMeta};
use mase::packed::{
    pack, source_hash, ArtifactWeights, ArtifactWriter, TensorDesc,
};
use mase::passes::{ProfileData, QuantSolution};
use mase::runtime::{build_weights_artifact, CpuBackend, Decoder, ExecBackend};
use mase::util::rng::Rng;
use std::sync::Arc;

fn tmp_mxa(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static UNIQUE: AtomicUsize = AtomicUsize::new(0);
    let n = UNIQUE.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("mase_afmt_{tag}_{}_{n}.mxa", std::process::id()))
}

fn rand_tensor(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// Every format: two tensors per artifact (a normal one plus an edge
/// case — zero elements for block formats, a partial trailing pack
/// group for element-wise ones) must survive the container bit-exactly,
/// with the descriptor fields and content hash intact.
#[test]
fn every_format_round_trips_through_the_container() {
    for fmt in FormatKind::ALL {
        let spec = FormatSpec::with_defaults(fmt);
        let prec = spec.precision();
        // block formats must tile into (16, 2); element-wise shapes are
        // free — 3x11 = 33 elements exercises a partial trailing group
        let (shape_a, shape_b) =
            if fmt.is_block_format() { ((32, 4), (0, 2)) } else { ((3, 11), (0, 7)) };
        let xa = rand_tensor(shape_a.0 * shape_a.1, 0xA0 + fmt as u64);
        let xb = rand_tensor(shape_b.0 * shape_b.1, 0xB0 + fmt as u64);
        let ta = pack(&xa, shape_a.0, shape_a.1, fmt, prec);
        let tb = pack(&xb, shape_b.0, shape_b.1, fmt, prec);

        let mut w = ArtifactWriter::new("rt-model", spec);
        w.add_tensor(TensorDesc::for_tensor("layer0.w_qkv", "weight", &ta, &xa), &ta).unwrap();
        w.add_tensor(TensorDesc::for_tensor("edge", "weight", &tb, &xb), &tb).unwrap();
        let path = tmp_mxa(fmt.name());
        let hash = w.write_to(&path).unwrap();

        let loaded = ArtifactWeights::load(&path).unwrap();
        assert_eq!(loaded.content_hash, hash, "{}: content hash", fmt.name());
        assert_eq!(loaded.model, "rt-model");
        assert_eq!(loaded.spec, spec, "{}: header spec", fmt.name());
        assert_eq!(loaded.tensors.len(), 2);

        let la = &loaded.tensors["layer0.w_qkv"];
        assert_eq!(*la.packed, ta, "{}: packed bits must survive byte-for-byte", fmt.name());
        assert_eq!(la.desc.source_hash, source_hash(&xa));
        assert_eq!((la.desc.rows, la.desc.cols), shape_a);
        // unpack equality follows from bit equality, but assert it
        // anyway: it is the value-level contract callers rely on
        assert_eq!(la.packed.unpack(), ta.unpack(), "{}", fmt.name());

        let lb = &loaded.tensors["edge"];
        assert_eq!(*lb.packed, tb, "{}: edge tensor", fmt.name());
        assert_eq!(lb.packed.unpack().len(), shape_b.0 * shape_b.1);
        std::fs::remove_file(&path).ok();
    }
}

/// One-layer causal LM like the decode-parity suite uses.
fn lm(batch: usize) -> ModelMeta {
    ModelMeta::synthetic("mxa-lm", 1, 32, 2, 512, 32, 4, "lm", batch)
}

fn qconfig(meta: &ModelMeta, fmt: FormatKind, bits: f32) -> Vec<f32> {
    let profile = ProfileData::uniform(meta, 4.0);
    QuantSolution::uniform(fmt, bits, meta, &profile).to_qconfig()
}

fn decode(
    backend: &CpuBackend,
    meta: &ModelMeta,
    w: &[f32],
    fmt: FormatKind,
    qcfg: &[f32],
) -> mase::runtime::GenOut {
    let graph = backend.prepare(meta, w, &[]).unwrap();
    let mut dec = Decoder::new(backend, &graph, meta, w, fmt.name(), qcfg, meta.batch).unwrap();
    let prompt = MarkovCorpus::new(7).batch(11, meta.batch, 8);
    dec.generate(&prompt, 8, 6).unwrap()
}

fn assert_bitwise_equal(a: &mase::runtime::GenOut, b: &mase::runtime::GenOut, tag: &str) {
    assert_eq!(a.tokens, b.tokens, "{tag}: token streams diverged");
    assert_eq!(a.step_logits.len(), b.step_logits.len(), "{tag}");
    for (i, (ra, rb)) in a.step_logits.iter().zip(&b.step_logits).enumerate() {
        assert_eq!(ra.len(), rb.len(), "{tag}: step {i}");
        for (j, (x, y)) in ra.iter().zip(rb).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}: step {i} logit {j}: {x} vs {y}");
        }
    }
    assert_eq!(a.score.loss.to_bits(), b.score.loss.to_bits(), "{tag}: loss bits");
}

/// The full loader contract in one (deliberately sequential) test — see
/// the module docs for why the pack-counter assertions cannot be spread
/// across parallel test functions.
#[test]
fn artifact_backed_decode_contract() {
    let meta = lm(4);
    let w = init_params(&meta, 0xC0DE);
    let fmt = FormatKind::MxInt;
    let spec = FormatSpec::with_defaults(fmt);
    let qcfg = qconfig(&meta, fmt, spec.bits);
    let graph = build_graph(&meta);

    let writer = build_weights_artifact(&meta, &graph, &w, spec, &qcfg).unwrap();
    let path = tmp_mxa("contract");
    let hash = writer.write_to(&path).unwrap();
    let art = Arc::new(ArtifactWeights::load(&path).unwrap());
    assert_eq!(art.content_hash, hash);
    // one chunk pair per Linear weight + the embedding table
    assert!(art.tensors.contains_key("embed"), "embed table must be in the artifact");
    assert!(
        art.tensors.keys().any(|k| k.contains("w_qkv")),
        "attention weights must be in the artifact: {:?}",
        art.tensors.keys().collect::<Vec<_>>()
    );

    // cold in-memory path: packs every weight tensor
    let before_cold = mase::packed::kernel_tally();
    let cold = decode(&CpuBackend::new(), &meta, &w, fmt, &qcfg);
    let cold_packs = mase::packed::kernel_tally().delta(&before_cold).weight_packs;
    assert!(cold_packs > 0, "cold session must pack its weights");

    // warm artifact path: ZERO pack calls, bit-identical output, and the
    // backend advertises the content hash for eval-cache scoping
    let warm_be = CpuBackend::with_artifact(art.clone());
    assert_eq!(warm_be.weights_hash(), Some(hash));
    let before_warm = mase::packed::kernel_tally();
    let warm = decode(&warm_be, &meta, &w, fmt, &qcfg);
    let warm_packs = mase::packed::kernel_tally().delta(&before_warm).weight_packs;
    assert_eq!(warm_packs, 0, "warm artifact session must never re-pack");
    assert_bitwise_equal(&cold, &warm, "warm vs cold");

    // an artifact packed from DIFFERENT weights must not poison results:
    // the source-hash mismatch falls back to in-memory packing (counted)
    // and the output still matches the cold path bit-for-bit
    let w_other = init_params(&meta, 0xBEEF);
    let other = build_weights_artifact(&meta, &graph, &w_other, spec, &qcfg).unwrap();
    let other_path = tmp_mxa("other");
    other.write_to(&other_path).unwrap();
    let stale_be =
        CpuBackend::with_artifact(Arc::new(ArtifactWeights::load(&other_path).unwrap()));
    let before_stale = mase::packed::kernel_tally();
    let stale = decode(&stale_be, &meta, &w, fmt, &qcfg);
    let stale_packs = mase::packed::kernel_tally().delta(&before_stale).weight_packs;
    assert!(stale_packs > 0, "mismatched artifact must fall back to packing");
    assert_bitwise_equal(&cold, &stale, "stale-artifact fallback vs cold");

    // a qcfg the artifact was NOT packed at (different bits) must also
    // fall back — layout mismatch, not source mismatch
    let qcfg_narrow = qconfig(&meta, fmt, 4.0);
    let before_narrow = mase::packed::kernel_tally();
    let _ = decode(&warm_be, &meta, &w, fmt, &qcfg_narrow);
    assert!(
        mase::packed::kernel_tally().delta(&before_narrow).weight_packs > 0,
        "artifact at {} bits must not satisfy a 4-bit session",
        spec.bits
    );

    // fail closed: flip one byte inside the LAST chunk (the embedding
    // table's words); the loader must name the tensor, not limp on
    let mut bytes = std::fs::read(&path).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x01;
    let bad_path = tmp_mxa("corrupt");
    std::fs::write(&bad_path, &bytes).unwrap();
    let err = ArtifactWeights::load(&bad_path).unwrap_err().to_string();
    assert!(err.contains("embed"), "corruption error must name the tensor: {err}");
    assert!(err.contains("hash"), "{err}");

    // fail closed: truncation mid-chunk
    bytes[last] ^= 0x01; // restore
    std::fs::write(&bad_path, &bytes[..bytes.len() - 8]).unwrap();
    let err = ArtifactWeights::load(&bad_path).unwrap_err().to_string();
    assert!(err.contains("truncated"), "{err}");

    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&other_path).ok();
    std::fs::remove_file(&bad_path).ok();
}
