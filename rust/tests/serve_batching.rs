//! Continuous-batching determinism — tier-1, artifact-free (PR 9).
//!
//! The serving contract (module docs of `serve::scheduler`): tokens and
//! logits produced by the continuously-batched [`BatchEngine`] — with
//! requests admitted into a *live* decoder group between steps, lanes
//! evicted and reused, idle lanes ticking along — are **bit-identical**
//! to running each request alone through a fresh [`Decoder::generate`].
//! Exercised for a block format (MXInt, 16-row lanes), a fixed-point
//! format and fp32 (1-row lanes), under mixed prompt lengths and
//! staggered admissions, including a lane reused after retirement.
//!
//! Also asserted: queue overflow answers 429 without touching in-flight
//! sequences, and the engine's counted attention work matches the
//! closed form — admission does NOT recompute anyone's prefix (the
//! whole point of continuous batching).

use mase::data::MarkovCorpus;
use mase::formats::FormatKind;
use mase::frontend::ModelMeta;
use mase::ir::Graph;
use mase::obs::Registry;
use mase::passes::{ProfileData, QuantSolution};
use mase::runtime::{CpuBackend, DecodeStats, Decoder, ExecBackend};
use mase::serve::{run_scheduler, BatchEngine, Completion, GenRequest, RequestQueue, ServeError};

/// One-layer causal LM, seq_len 32 (identical shape to `toy-lm`).
fn lm() -> ModelMeta {
    ModelMeta::synthetic("serve-lm", 1, 32, 2, 512, 32, 4, "lm", 16)
}

fn setup(meta: &ModelMeta) -> (Vec<f32>, Graph) {
    let w = mase::frontend::init_params(meta, 0xC0DE);
    let graph = CpuBackend::new().prepare(meta, &w, &[]).expect("prepare");
    (w, graph)
}

fn qconfig(meta: &ModelMeta, fmt: FormatKind, bits: f32) -> Vec<f32> {
    let profile = ProfileData::uniform(meta, 4.0);
    QuantSolution::uniform(fmt, bits, meta, &profile).to_qconfig()
}

fn prompt(stream: u64, len: usize) -> Vec<i32> {
    MarkovCorpus::new(7).batch(stream, 1, len)
}

fn bits_of(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Per-request oracle: a fresh `width`-row decoder on the replicated
/// prompt. Identical rows stay identical through every op (blocks are
/// lane-internal), so row 0 is the request's sequential decode.
fn sequential(
    be: &CpuBackend,
    graph: &Graph,
    meta: &ModelMeta,
    w: &[f32],
    tag: &str,
    qcfg: &[f32],
    width: usize,
    prompt: &[i32],
    max_tokens: usize,
) -> (Vec<i32>, Vec<Vec<f32>>) {
    let rep: Vec<i32> = (0..width).flat_map(|_| prompt.iter().copied()).collect();
    let mut dec = Decoder::new(be, graph, meta, w, tag, qcfg, width).unwrap();
    let out = dec.generate(&rep, prompt.len(), max_tokens).unwrap();
    let toks: Vec<i32> = out.tokens.iter().map(|row| row[0]).collect();
    let logits: Vec<Vec<f32>> =
        out.step_logits.iter().map(|lg| lg[..meta.vocab].to_vec()).collect();
    (toks, logits)
}

/// Drive the engine with staggered admissions on a 2-lane group:
///   before tick 0: A (prompt 5, 4 new) → lane 0; lane 1 idles;
///   after 2 ticks: B (prompt 3, 6 new) joins the *live* group mid-A;
///   C (prompt 7, 3 new) waits for a retirement and reuses A's lane
///   (the slot-reuse path, with B still mid-flight);
///   lane 1 idles again after B retires while C finishes.
fn run_staggered(engine: &mut BatchEngine, reqs: &[(Vec<i32>, usize)]) -> Vec<Completion> {
    engine.keep_logits = true;
    engine.admit(0, reqs[0].0.clone(), reqs[0].1).unwrap();
    // (id, admissible after N ticks) — popped from the back
    let mut pending: Vec<(u64, usize)> = vec![(2, 3), (1, 2)];
    let mut done = Vec::new();
    for tick in 0usize.. {
        assert!(tick < 64, "engine failed to drain in 64 ticks");
        done.extend(engine.step().unwrap());
        while let Some(&(id, at)) = pending.last() {
            if tick + 1 >= at && engine.free_lanes() > 0 {
                pending.pop();
                let (p, m) = &reqs[id as usize];
                engine.admit(id, p.clone(), *m).unwrap();
            } else {
                break;
            }
        }
        if pending.is_empty() && engine.is_idle() {
            break;
        }
    }
    assert_eq!(done.len(), 3, "all three requests must retire");
    done.sort_by_key(|c| c.id);
    done
}

#[test]
fn batched_output_is_bitwise_sequential_across_formats() {
    let meta = lm();
    let (w, graph) = setup(&meta);
    let be = CpuBackend::new();
    let reqs = [(prompt(21, 5), 4usize), (prompt(22, 3), 6), (prompt(23, 7), 3)];
    for (fmt, fbits) in
        [(FormatKind::MxInt, 7.0f32), (FormatKind::Int, 8.0), (FormatKind::Fp32, 32.0)]
    {
        let tag = fmt.name();
        let qcfg = qconfig(&meta, fmt, fbits);
        let mut engine = BatchEngine::new(&be, &graph, &meta, &w, tag, &qcfg, 2).unwrap();
        let width = engine.width();
        assert_eq!(width, if fmt.is_block_format() { 16 } else { 1 }, "{tag}");
        let done = run_staggered(&mut engine, &reqs);

        for (c, (p, max)) in done.iter().zip(reqs.iter()) {
            let (want_toks, want_logits) =
                sequential(&be, &graph, &meta, &w, tag, &qcfg, width, p, *max);
            assert_eq!(c.prompt_len, p.len(), "{tag} req {}", c.id);
            assert_eq!(c.tokens, want_toks, "{tag} req {}: tokens diverged", c.id);
            assert_eq!(c.step_logits.len(), want_logits.len(), "{tag} req {}", c.id);
            for (pos, (got, want)) in c.step_logits.iter().zip(want_logits.iter()).enumerate() {
                assert_eq!(
                    bits_of(got),
                    bits_of(want),
                    "{tag} req {} position {pos}: logits not bit-identical",
                    c.id
                );
            }
        }

        // Counted work is the closed form: each request costs exactly its
        // solo decode (admission never recomputes a prefix — that is the
        // continuous-batching claim), plus one dot per (slot, head,
        // layer) per idle lane tick.
        let s = engine.stats();
        let per_req: u64 = reqs
            .iter()
            .map(|(p, max)| {
                DecodeStats::expected_decode_dots(
                    width,
                    meta.n_heads,
                    meta.n_layers,
                    0,
                    p.len() + max,
                )
            })
            .sum();
        let idle = (meta.n_heads * meta.n_layers) as u64 * engine.idle_slot_steps;
        assert_eq!(s.decode_score_dots, per_req + idle, "{tag}: dots off the closed form");
        assert_eq!(s.full_score_dots, 0, "{tag}: engine must never run full attention");
        assert_eq!(s.full_attn_rows, 0, "{tag}: engine must never materialize prefill rows");
    }
}

#[test]
fn queue_overflow_429_leaves_inflight_results_intact() {
    let meta = lm();
    let (w, graph) = setup(&meta);
    let be = CpuBackend::new();
    let qcfg = qconfig(&meta, FormatKind::Fp32, 32.0);
    let mut engine = BatchEngine::new(&be, &graph, &meta, &w, "fp32", &qcfg, 1).unwrap();
    let queue = RequestQueue::new(2, 60_000);
    let reg = Registry::new();

    // fill the bounded queue before the scheduler runs: admission order
    // is then fixed, so the run is deterministic
    let pa = prompt(31, 4);
    let pb = prompt(32, 2);
    let rx_a = queue.submit(GenRequest { prompt: pa.clone(), max_tokens: 3 }).unwrap();
    let rx_b = queue.submit(GenRequest { prompt: pb.clone(), max_tokens: 5 }).unwrap();
    match queue.submit(GenRequest { prompt: prompt(33, 2), max_tokens: 2 }) {
        Err(ServeError::QueueFull { cap }) => assert_eq!(cap, 2),
        other => panic!("expected 429 QueueFull, got {other:?}"),
    }

    std::thread::scope(|s| {
        s.spawn(|| run_scheduler(&mut engine, &queue, &reg));
        let a = rx_a.recv().unwrap().expect("request A must complete");
        let b = rx_b.recv().unwrap().expect("request B must complete");
        queue.shutdown();
        let (want_a, _) = sequential(&be, &graph, &meta, &w, "fp32", &qcfg, 1, &pa, 3);
        let (want_b, _) = sequential(&be, &graph, &meta, &w, "fp32", &qcfg, 1, &pb, 5);
        assert_eq!(a.tokens, want_a, "overflowed submit corrupted request A");
        assert_eq!(b.tokens, want_b, "overflowed submit corrupted request B");
        assert_eq!((a.id, b.id), (0, 1), "FIFO admission order");
    });

    assert_eq!(reg.counter_total("serve/scheduler", "admitted"), 2);
    assert_eq!(reg.counter_total("serve/scheduler", "retired"), 2);
    assert!(reg.counter_total("serve/scheduler", "steps") > 0);
}

#[test]
fn expired_entry_gets_503_and_later_work_is_unaffected() {
    let meta = lm();
    let (w, graph) = setup(&meta);
    let be = CpuBackend::new();
    let qcfg = qconfig(&meta, FormatKind::Fp32, 32.0);
    let mut engine = BatchEngine::new(&be, &graph, &meta, &w, "fp32", &qcfg, 1).unwrap();
    // zero admission deadline: everything queued before the scheduler
    // wakes has already expired
    let queue = RequestQueue::new(4, 0);
    let reg = Registry::new();
    let rx = queue.submit(GenRequest { prompt: prompt(41, 3), max_tokens: 2 }).unwrap();
    std::thread::scope(|s| {
        s.spawn(|| run_scheduler(&mut engine, &queue, &reg));
        match rx.recv().unwrap() {
            Err(ServeError::QueueTimeout { .. }) => {}
            other => panic!("expected 503 QueueTimeout, got {other:?}"),
        }
        queue.shutdown();
    });
    assert_eq!(reg.counter_total("serve/scheduler", "queue_timeout_503"), 1);
    assert_eq!(reg.counter_total("serve/scheduler", "admitted"), 0);
    assert!(engine.is_idle(), "expired work must never reach the engine");
}
