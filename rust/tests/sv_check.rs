//! Integration tests for the PR 6 static-analysis subsystem: the
//! known-bad corpus under `tests/corpus/` must trip exactly the seeded
//! `MC0xx` diagnostics (the three PR 5 review findings among them), and
//! the clean corpus — every template the emitter can generate, plus
//! full emitted designs — must come back with zero diagnostics.

use mase::check::{check_design, check_sv_files, Severity};
use mase::emit::templates;
use mase::formats::FormatKind;
use mase::frontend::{build_graph, manifest::ModelMeta};
use mase::hw::Device;
use mase::ir::{Graph, OpKind};
use mase::passes::{parallelize, profile::ProfileData, QuantSolution};
use std::collections::BTreeMap;

fn check_source(name: &str, src: &str) -> mase::check::CheckReport {
    let mut files = BTreeMap::new();
    files.insert(name.to_string(), src.to_string());
    check_sv_files(&files)
}

/// Assert that `src` produces at least one diagnostic with `code`, and
/// that every error-level finding carries that code (no collateral
/// noise from the seeded bug).
fn expect_code(name: &str, src: &str, code: &str) {
    let r = check_source(name, src);
    assert!(
        r.diags.iter().any(|d| d.code == code),
        "{name}: expected {code}, got:\n{}",
        r.render()
    );
}

// ---- known-bad corpus: the PR 5 review findings --------------------------

#[test]
fn corpus_reversed_part_select_is_mc002() {
    // PR 5 finding #1: BEATS == 1 elaborates the beat-assembly select to
    // the reversed range [CHAN_W-1:CHAN_W].
    let src = include_str!("corpus/bad_reversed_select.sv");
    expect_code("bad_reversed_select.sv", src, "MC002");
}

#[test]
fn corpus_port_width_mismatch_is_mc004() {
    // PR 5 finding #2: consumer sizes the exponent wire from a hardwired
    // 8 while the producer port is 8*GROUPS = 32 bits.
    let src = include_str!("corpus/bad_port_width.sv");
    expect_code("bad_port_width.sv", src, "MC004");
}

#[test]
fn corpus_undeclared_identifier_is_mc001() {
    // PR 5 finding #3: a rename left one use of the old register name.
    let src = include_str!("corpus/bad_undeclared.sv");
    expect_code("bad_undeclared.sv", src, "MC001");
}

#[test]
fn corpus_multiply_driven_net_is_mc005() {
    let src = include_str!("corpus/bad_multidriven.sv");
    expect_code("bad_multidriven.sv", src, "MC005");
}

#[test]
fn corpus_unused_declaration_is_mc006_warning() {
    let src = include_str!("corpus/bad_unused.sv");
    let r = check_source("bad_unused.sv", src);
    let hits: Vec<_> = r.diags.iter().filter(|d| d.code == "MC006").collect();
    assert!(!hits.is_empty(), "expected MC006:\n{}", r.render());
    assert!(hits.iter().all(|d| d.severity == Severity::Warning));
    // unused declarations warn, they do not fail the gate
    assert!(!r.has_errors(), "{}", r.render());
}

// ---- clean corpus: everything the emitter generates ----------------------

fn assert_clean(name: &str, src: &str) {
    let r = check_source(name, src);
    assert!(r.diags.is_empty(), "{name} not clean:\n{}", r.render());
}

#[test]
fn every_generated_template_is_diagnostic_free() {
    // operator templates across kinds, mantissas and tilings
    let kinds = [
        OpKind::Linear,
        OpKind::Attention,
        OpKind::Embed,
        OpKind::LayerNorm,
        OpKind::Gelu,
        OpKind::Add,
        OpKind::Softmax,
        OpKind::Transpose,
        OpKind::Reorder,
        OpKind::MeanPool,
    ];
    for kind in kinds {
        for (m, tile) in [(4u32, (16usize, 2usize)), (7, (8, 4)), (1, (4, 4))] {
            let (name, src) = templates::template_for(kind, FormatKind::MxInt, m, tile);
            assert_clean(&name, &src);
        }
    }
    // unpackers across block formats, channel widths (0 = unbounded)
    for fmt in [FormatKind::MxInt, FormatKind::Bmf, FormatKind::Bl] {
        for chan in [512u64, 64, 0] {
            for (m, tile) in [(4u32, (16usize, 2usize)), (2, (16, 4))] {
                let (name, src, _groups) =
                    templates::unpacker_for(fmt, m, tile, chan).expect("block format");
                assert_clean(&name, &src);
            }
        }
    }
    // support templates, including the generate-scoped cast both ways
    assert_clean("beu", &templates::block_exponent_unit("beu"));
    assert_clean("cast_8_4", &templates::mxint_cast("cast_8_4", 8, 4));
    assert_clean("cast_4_8", &templates::mxint_cast("cast_4_8", 4, 8));
    assert_clean("fifo2", &templates::stream_fifo("fifo2", 2));
    assert_clean("fifo4", &templates::stream_fifo("fifo4", 4));
}

fn quantized_graph(fmt: FormatKind, bits: f32) -> Graph {
    let m = ModelMeta::synthetic("svck", 2, 32, 2, 512, 32, 4, "classifier", 64);
    let p = ProfileData::uniform(&m, 4.0);
    let mut g = build_graph(&m);
    QuantSolution::uniform(fmt, bits, &m, &p).apply(&mut g);
    parallelize(&mut g, &Device::u250(), 0.2);
    g
}

#[test]
fn full_emitted_designs_are_diagnostic_free() {
    // SV analysis of every file + IR contracts + emitted-parameter
    // agreement, across a block format, a shared-exp-free block format
    // variant and an element-wise format.
    for (fmt, bits) in
        [(FormatKind::MxInt, 5.0), (FormatKind::Bmf, 4.0), (FormatKind::Int, 8.0)]
    {
        let g = quantized_graph(fmt, bits);
        let design = mase::emit::emit_design(&g);
        let r = check_design(&design, &g, mase::hw::DEFAULT_CHANNEL_BITS);
        assert!(
            r.diags.is_empty(),
            "{} design not clean:\n{}",
            fmt.name(),
            r.render()
        );
    }
}

#[test]
fn emit_pass_gate_accepts_clean_designs() {
    // The emit-pass hard gate drives the same check_design entry point;
    // a clean design must still emit.
    let g = quantized_graph(FormatKind::MxInt, 4.0);
    let dir = std::env::temp_dir().join("mase_sv_check_gate");
    let _ = std::fs::remove_dir_all(&dir);
    let (design, _lines) = mase::passes::emit_pass::emit_to_dir(&g, &dir).unwrap();
    assert!(design.files.len() > 3);
    let _ = std::fs::remove_dir_all(&dir);
}
