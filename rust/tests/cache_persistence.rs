//! Persistence guarantees of the cross-sweep evaluation cache, tested
//! end-to-end through the sweep orchestrator's generic core (no PJRT
//! artifacts needed — the objective is a synthetic stand-in counted by
//! an atomic):
//!
//!  * save → load → re-run is bit-identical and performs ZERO objective
//!    evaluations (the ISSUE/ROADMAP acceptance criterion),
//!  * a version-mismatched file is rejected into a cold cache,
//!  * a corrupted file falls back to a cold cache (and heals on save).

use mase::coordinator::sweep::{cell_scope, grid, sweep_with, SweepCell, SweepConfig, SweepItem};
use mase::data::Task;
use mase::formats::FormatKind;
use mase::obs::Registry;
use mase::runtime::BackendKind;
use mase::search::{
    run_batched_cached, Algorithm, BatchOptions, CacheStore, EvalCache, MemoKey, Trial,
    CACHE_SCHEMA, CACHE_VERSION,
};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn tmp_path(tag: &str) -> PathBuf {
    static UNIQUE: AtomicUsize = AtomicUsize::new(0);
    let n = UNIQUE.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("mase-persist-{tag}-{}-{n}.json", std::process::id()))
}

fn toy_sweep_config() -> SweepConfig {
    SweepConfig {
        models: vec!["toy-sim".to_string()],
        tasks: vec![Task::Sst2, Task::Qqp],
        fmts: vec![FormatKind::MxInt, FormatKind::Int],
        trials: 30,
        ..Default::default()
    }
}

/// Drive the full grid through `sweep_with` exactly like `run_sweep`
/// does, but with a synthetic objective whose invocations are counted.
/// The objective is a pure function of the rounded config vector and the
/// cell (each format/task scores differently), producing "ugly" values
/// (thirds, sums of decimals) that only survive bit-exact serialization.
fn drive(
    cfg: &SweepConfig,
    store: &CacheStore,
    evals: &AtomicUsize,
) -> (Vec<Vec<Trial>>, Vec<(usize, usize)>, Arc<Registry>) {
    let mut histories = Vec::new();
    let mut cell_counts = Vec::new();
    let trace = Arc::new(Registry::new());
    let report = sweep_with(cfg, store, grid(cfg), trace, |item: &SweepItem, cache: &EvalCache| {
        let fmt_factor = match item.fmt {
            FormatKind::MxInt => 1.0 / 3.0,
            _ => 0.1 + 0.2,
        };
        let task_bias = item.task as usize as f64 * 0.7;
        let opts = BatchOptions {
            batch: 6,
            threads: 2,
            memo: MemoKey::Rounded,
            ..Default::default()
        };
        let hist = run_batched_cached(
            Algorithm::Random,
            mase::search::Space::uniform(3, 2.0, 5.0),
            42,
            cfg.trials,
            &opts,
            cache,
            |x| {
                evals.fetch_add(1, Ordering::SeqCst);
                let v = task_bias - fmt_factor * x.iter().map(|xi| xi.round()).sum::<f64>();
                (v, vec![v * 0.5, 1.0 / 7.0])
            },
        );
        let best = hist.iter().map(|t| t.value).fold(f64::NEG_INFINITY, f64::max);
        histories.push(hist);
        Ok(SweepCell { value: best, accuracy: best, avg_bits: 4.0, mode: "PTQ".to_string() })
    })
    .expect("sweep failed");
    for row in &report.rows {
        cell_counts.push((row.cache.hits, row.cache.misses));
    }
    (histories, cell_counts, report.trace)
}

#[test]
fn second_sweep_run_is_all_hits_zero_evaluations_and_bit_identical() {
    let path = tmp_path("roundtrip");
    let cfg = toy_sweep_config();
    let evals = AtomicUsize::new(0);

    // cold run: fills and flushes the cache
    let store1 = CacheStore::open(&path);
    assert_eq!(store1.loaded_entries(), 0);
    let (cold_histories, _, _) = drive(&cfg, &store1, &evals);
    let cold_evals = evals.load(Ordering::SeqCst);
    assert!(cold_evals > 0, "cold run must evaluate something");
    assert_eq!(cold_histories.len(), 4, "one history per grid cell");
    assert!(path.exists(), "sweep must flush the cache file");

    // warm run: a fresh process would open the same file
    let store2 = CacheStore::open(&path);
    assert!(store2.load_note().is_none(), "{:?}", store2.load_note());
    assert_eq!(store2.loaded_entries(), store1.total_entries());
    evals.store(0, Ordering::SeqCst);
    let (warm_histories, warm_counts, _) = drive(&cfg, &store2, &evals);

    // THE acceptance criterion: zero evaluator invocations on the
    // second run, 100% hit rate, results identical to the cold run
    assert_eq!(evals.load(Ordering::SeqCst), 0, "warm sweep re-simulated");
    for (hits, misses) in &warm_counts {
        assert_eq!(*misses, 0);
        assert!(*hits > 0);
    }
    assert_eq!(store2.stats().hit_rate(), 1.0);
    for (cold, warm) in cold_histories.iter().zip(warm_histories.iter()) {
        assert_eq!(cold.len(), warm.len());
        for (a, b) in cold.iter().zip(warm.iter()) {
            assert_eq!(a.x, b.x, "proposal sequence diverged");
            assert_eq!(a.value.to_bits(), b.value.to_bits(), "value not bit-identical");
            assert_eq!(a.objectives.len(), b.objectives.len());
            for (oa, ob) in a.objectives.iter().zip(b.objectives.iter()) {
                assert_eq!(oa.to_bits(), ob.to_bits(), "objective component not bit-identical");
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn warm_sweep_reports_full_hit_rate_through_the_trace_registry() {
    // PR 8 counter hygiene: the same warm-sweep guarantee the row-level
    // assertions above make, but observed purely through the obs
    // registry's monotonic `sweep/cell` cache counters.
    let path = tmp_path("trace-warm");
    let cfg = toy_sweep_config();
    let evals = AtomicUsize::new(0);

    let store1 = CacheStore::open(&path);
    let (_, _, cold) = drive(&cfg, &store1, &evals);
    let cold_hits = cold.counter_total("sweep/cell", "cache_hits");
    let cold_misses = cold.counter_total("sweep/cell", "cache_misses");
    assert!(cold_misses > 0, "cold sweep must pay evaluations");
    assert_eq!(
        cold.counter_total("sweep/cell", "cache_inserts"),
        cold_misses,
        "every miss inserts exactly once"
    );

    let store2 = CacheStore::open(&path);
    evals.store(0, Ordering::SeqCst);
    let (_, _, warm) = drive(&cfg, &store2, &evals);
    assert_eq!(warm.counter_total("sweep/cell", "cache_misses"), 0, "warm sweep missed");
    assert_eq!(warm.counter_total("sweep/cell", "cache_inserts"), 0);
    // identical seeded proposal stream => identical lookup count, now
    // served entirely from disk: 100% hit rate through the registry
    assert_eq!(warm.counter_total("sweep/cell", "cache_hits"), cold_hits + cold_misses);
    std::fs::remove_file(&path).ok();
}

#[test]
fn sweep_cells_never_leak_entries_across_scopes() {
    // same search space and seed in every cell, but different objectives
    // per (task, fmt): if scoping broke, a later cell would "hit" an
    // earlier cell's value and report the wrong objective
    let path = tmp_path("scopes");
    let cfg = toy_sweep_config();
    let evals = AtomicUsize::new(0);
    let store = CacheStore::open(&path);
    let (histories, _, _) = drive(&cfg, &store, &evals);
    // every cell proposes the identical x sequence (same seed), yet the
    // values must differ per cell because the objectives differ
    for i in 1..histories.len() {
        assert_eq!(histories[0][0].x, histories[i][0].x, "seeded proposals should match");
        assert_ne!(
            histories[0][0].value, histories[i][0].value,
            "cells {i} and 0 share a value — scope leak"
        );
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn identical_sweeps_under_different_backends_use_disjoint_scopes() {
    // Cache hygiene across execution backends: the SAME grid swept once
    // under the PJRT backend and once under the CPU interpreter must hit
    // entirely disjoint scope sets in a shared store — zero cross-hits,
    // every cell of the second sweep paid in full.
    let path = tmp_path("backends");
    let pjrt_cfg = toy_sweep_config(); // backend: Pjrt (the default)
    assert_eq!(pjrt_cfg.backend, BackendKind::Pjrt);
    let cpu_cfg = SweepConfig { backend: BackendKind::Cpu, ..toy_sweep_config() };

    // scope strings themselves must differ cell-for-cell
    for (a, b) in grid(&pjrt_cfg).iter().zip(grid(&cpu_cfg).iter()) {
        let (sa, sb) = (cell_scope(&pjrt_cfg, a), cell_scope(&cpu_cfg, b));
        assert_ne!(sa, sb, "backend missing from scope: {sa}");
        assert!(sa.ends_with("/pjrt"), "{sa}");
        assert!(sb.ends_with("/cpu"), "{sb}");
    }

    let evals = AtomicUsize::new(0);
    let store = CacheStore::open(&path);
    drive(&pjrt_cfg, &store, &evals);
    let pjrt_evals = evals.load(Ordering::SeqCst);
    assert!(pjrt_evals > 0);

    // identical sweep, different backend, same store: zero cross-hits
    evals.store(0, Ordering::SeqCst);
    let (_, cpu_counts, _) = drive(&cpu_cfg, &store, &evals);
    assert_eq!(
        evals.load(Ordering::SeqCst),
        pjrt_evals,
        "cpu-backend sweep must pay every evaluation the pjrt sweep paid"
    );
    for (hits, misses) in &cpu_counts {
        assert_eq!(*hits, 0, "cpu-backend cell served a pjrt-measured entry");
        assert!(*misses > 0);
    }

    // and a warm re-run of the SAME backend is still fully served
    evals.store(0, Ordering::SeqCst);
    let (_, warm_counts, _) = drive(&cpu_cfg, &store, &evals);
    assert_eq!(evals.load(Ordering::SeqCst), 0);
    for (hits, misses) in &warm_counts {
        assert!(*hits > 0);
        assert_eq!(*misses, 0);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn version_mismatch_is_rejected_into_a_cold_cache() {
    let path = tmp_path("version");
    let future = format!(
        r#"{{"schema": "{CACHE_SCHEMA}", "version": {}, "scopes": {{"s": {{"entries": [{{"k": ["4008000000000000"], "v": "3ff0000000000000", "o": []}}]}}}}}}"#,
        CACHE_VERSION + 1
    );
    std::fs::write(&path, future).unwrap();
    let store = CacheStore::open(&path);
    assert_eq!(store.loaded_entries(), 0, "future-versioned entries must not load");
    assert_eq!(store.total_entries(), 0);
    let note = store.load_note().expect("rejection must be reported");
    assert!(note.contains("version"), "{note}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn foreign_schema_is_rejected() {
    let path = tmp_path("schema");
    std::fs::write(&path, r#"{"schema": "someone-elses-file", "version": 1, "scopes": {}}"#)
        .unwrap();
    let store = CacheStore::open(&path);
    assert_eq!(store.total_entries(), 0);
    assert!(store.load_note().expect("note").contains("schema"));
    std::fs::remove_file(&path).ok();
}

#[test]
fn corrupted_file_falls_back_cold_and_heals_on_save() {
    for garbage in [
        "not json at all",
        r#"{"schema": "mase-eval-cache", "version": 1"#, // truncated
        // right shell, mangled entry (short key hex)
        r#"{"schema": "mase-eval-cache", "version": 1, "scopes": {"s": {"entries": [{"k": ["zz"], "v": "00", "o": []}]}}}"#,
    ] {
        let path = tmp_path("corrupt");
        std::fs::write(&path, garbage).unwrap();
        let store = CacheStore::open(&path);
        assert_eq!(store.total_entries(), 0, "corrupt input {garbage:?} must load cold");
        assert!(store.load_note().is_some(), "corruption must be reported for {garbage:?}");

        // the cache still works and the next save repairs the file
        store.cache("s").insert(vec![1], (0.5, vec![]));
        store.save().unwrap();
        let healed = CacheStore::open(&path);
        assert!(healed.load_note().is_none());
        assert_eq!(healed.loaded_entries(), 1);
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn saved_file_is_stable_across_rewrites() {
    // deterministic serialization: save → load → save must byte-match
    let path = tmp_path("stable");
    let store = CacheStore::open(&path);
    let c = store.cache("b-scope");
    c.insert(vec![2f64.to_bits(), 7f64.to_bits()], (1.0 / 3.0, vec![0.1, 0.2]));
    c.insert(vec![1f64.to_bits(), 9f64.to_bits()], (-0.25, vec![]));
    store.cache("a-scope").insert(vec![5u64], (2.5, vec![f64::MIN_POSITIVE]));
    store.save().unwrap();
    let first = std::fs::read_to_string(&path).unwrap();

    let reopened = CacheStore::open(&path);
    assert_eq!(reopened.loaded_entries(), 3);
    reopened.save().unwrap();
    let second = std::fs::read_to_string(&path).unwrap();
    assert_eq!(first, second);
    std::fs::remove_file(&path).ok();
}
