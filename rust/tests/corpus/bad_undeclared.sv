// Known-bad corpus: PR 5 review finding #3. A register was renamed
// (out_exp_q -> out_exp_r) but one use kept the old name, so the module
// references a signal that is never declared.
// Expected diagnostic: MC001 (undeclared identifier).
module bad_undeclared (
    input  logic       clk,
    input  logic       rst_n,
    input  logic       in_valid,
    output logic       in_ready,
    input  logic [7:0] in_data,
    output logic       out_valid,
    input  logic       out_ready,
    output logic [7:0] out_data
);
    logic [7:0] out_exp_r;
    always_ff @(posedge clk or negedge rst_n) begin
        if (!rst_n) begin
            out_exp_r <= 8'd0;
        end else if (in_valid && in_ready) begin
            out_exp_r <= in_data;
        end
    end
    assign out_data  = out_exp_q;
    assign out_valid = in_valid;
    assign in_ready  = out_ready;
endmodule
