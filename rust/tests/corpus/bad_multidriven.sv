// Known-bad corpus: one net driven from both a continuous assign and a
// clocked process — the bug class the old keyword-counting lint could
// never see. Expected diagnostic: MC005 (multiply-driven signal).
module bad_multidriven (
    input  logic       clk,
    input  logic       in_valid,
    output logic       in_ready,
    input  logic [7:0] in_data,
    output logic       out_valid,
    input  logic       out_ready,
    output logic [7:0] out_data
);
    logic [7:0] stage;
    assign stage = in_data;
    always_ff @(posedge clk) begin
        stage <= 8'd0;
    end
    assign out_data  = stage;
    assign out_valid = in_valid;
    assign in_ready  = out_ready;
endmodule
