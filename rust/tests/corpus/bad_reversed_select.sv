// Known-bad corpus: PR 5 review finding #1. With BEATS == 1 the beat
// assembly update's part-select elaborates to the reversed range
// [CHAN_W-1:CHAN_W] — statically detectable from the parameter values.
// Expected diagnostic: MC002 (reversed part-select).
module bad_reversed_select #(
    parameter CHAN_W = 512,
    parameter BEATS  = 1
) (
    input  logic                        clk,
    input  logic                        rst_n,
    input  logic                        in_valid,
    output logic                        in_ready,
    input  logic [CHAN_W-1:0]           in_data,
    output logic                        out_valid,
    input  logic                        out_ready,
    output logic [BEATS*CHAN_W-1:0]     out_data
);
    logic [BEATS*CHAN_W-1:0] shift;
    always_ff @(posedge clk or negedge rst_n) begin
        if (!rst_n) begin
            out_valid <= 1'b0;
        end else if (in_valid && in_ready) begin
            shift <= {in_data, shift[BEATS*CHAN_W-1:CHAN_W]};
            out_valid <= 1'b1;
        end else if (out_valid && out_ready) begin
            out_valid <= 1'b0;
        end
    end
    assign out_data = shift;
    assign in_ready = !out_valid || out_ready;
endmodule
