// Known-bad corpus: a declaration nothing ever reads or drives.
// Expected diagnostic: MC006 (declared but never referenced, warning).
module bad_unused (
    input  logic       clk,
    input  logic       in_valid,
    output logic       in_ready,
    input  logic [7:0] in_data,
    output logic       out_valid,
    input  logic       out_ready,
    output logic [7:0] out_data
);
    logic [7:0] spare;
    assign out_data  = in_data;
    assign out_valid = in_valid;
    assign in_ready  = out_ready;
endmodule
