// Known-bad corpus: PR 5 review finding #2. The consumer sizes its
// exponent wire from a hardwired constant (8) while the producer port is
// sized from the GROUPS parameter (8*GROUPS = 32 at this instantiation).
// Expected diagnostic: MC004 (port connection width mismatch).
module exp_producer #(
    parameter GROUPS = 2
) (
    input  logic                 clk,
    input  logic                 rst_n,
    input  logic                 in_valid,
    output logic                 in_ready,
    input  logic [63:0]          in_data,
    output logic                 out_valid,
    input  logic                 out_ready,
    output logic [8*GROUPS-1:0]  out_data,
    output logic [8*GROUPS-1:0]  out_exp
);
    assign out_data  = in_data[8*GROUPS-1:0];
    assign out_exp   = in_data[8*GROUPS-1:0];
    assign out_valid = in_valid;
    assign in_ready  = out_ready;
endmodule

module bad_port_width (
    input  logic        clk,
    input  logic        rst_n,
    input  logic        in_valid,
    output logic        in_ready,
    input  logic [63:0] in_data,
    output logic        out_valid,
    input  logic        out_ready,
    output logic [7:0]  out_data
);
    logic [7:0]  exp_w;  // sized from 8, but the port is 8*GROUPS = 32 bits
    logic [31:0] data_w;
    exp_producer #(.GROUPS(4)) u_prod (
        .clk(clk), .rst_n(rst_n),
        .in_valid(in_valid), .in_ready(in_ready), .in_data(in_data),
        .out_valid(out_valid), .out_ready(out_ready),
        .out_data(data_w), .out_exp(exp_w)
    );
    assign out_data = exp_w + data_w[7:0];
endmodule
