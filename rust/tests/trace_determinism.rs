//! The PR 8 determinism contract, end to end: a fixed seed yields a
//! **byte-identical** JSONL trace at any worker-thread count, because
//! every event is recorded at a single-threaded orchestration point
//! (search's serial ask/resolve/tell loop, decode's ordered post-merge)
//! and the stream carries counted work only — never wall-clock.
//!
//! Also here: the exact process-global kernel-tally accounting (unit
//! tests can only assert `>=` because they share the process with other
//! test threads — this binary serializes its tally users behind a lock),
//! and the Chrome golden test for the Fig. 1 toy fork-join graph against
//! the file `scripts/verify_trace_schema.py` generates and re-derives.

use mase::data::MarkovCorpus;
use mase::formats::{FormatKind, Precision};
use mase::frontend::{init_params, ModelMeta};
use mase::obs::{jsonl, Registry};
use mase::packed::{kernel_tally, packed_dot, packed_gemm};
use mase::packed::layout::pack;
use mase::passes::{ProfileData, QuantSolution};
use mase::runtime::{generate_many_traced, CpuBackend, ExecBackend};
use mase::search::{
    run_batched_traced, Algorithm, BatchOptions, EvalCache, MemoKey, Space,
};
use mase::sim::{simulate_traced, NodeSpec, SimConfig};
use mase::util::rng::Rng;
use std::sync::Mutex;

/// Kernel dispatch tallies are process-global atomics; every test in
/// this binary that calls a packed kernel (directly or through decode)
/// takes this lock so the exact-accounting test sees only its own calls.
static TALLY_LOCK: Mutex<()> = Mutex::new(());

// ------------------------------------------------------------- search --

/// One traced cached search with a pure objective; returns the JSONL.
fn search_trace(threads: usize) -> String {
    let cache = EvalCache::new();
    let reg = Registry::new();
    let opts = BatchOptions { batch: 6, threads, memo: MemoKey::Rounded, ..Default::default() };
    run_batched_traced(
        Algorithm::Random,
        Space::uniform(3, 2.0, 5.0),
        42,
        30,
        &opts,
        &cache,
        &reg,
        |x| {
            let v = -x.iter().map(|xi| xi.round()).sum::<f64>();
            (v, vec![v * 0.5])
        },
    );
    jsonl::render(&reg)
}

#[test]
fn search_jsonl_is_byte_identical_across_thread_counts() {
    let one = search_trace(1);
    assert!(one.starts_with(r#"{"schema":"mase-trace","version":1}"#), "{one}");
    assert!(one.contains(r#""path":"search/trial""#), "{one}");
    assert!(one.contains(r#""memo":"#), "trial spans must carry memo tags:\n{one}");
    assert!(!one.contains("wall"), "wall-clock leaked into the stream");
    for threads in [2, 8] {
        assert_eq!(search_trace(threads), one, "threads={threads} diverged from threads=1");
    }
}

// ------------------------------------------------------------- decode --

/// One traced multi-group KV-cached decode; returns (JSONL, tokens).
fn decode_trace(threads: usize) -> (String, Vec<Vec<Vec<i32>>>) {
    let meta = ModelMeta::synthetic("trace-lm", 1, 32, 2, 512, 32, 4, "lm", 2);
    let w = init_params(&meta, 0xC0DE);
    let be = CpuBackend::new();
    let graph = be.prepare(&meta, &w, &[]).expect("prepare");
    let profile = ProfileData::uniform(&meta, 4.0);
    let qcfg = QuantSolution::uniform(FormatKind::MxInt, 5.0, &meta, &profile).to_qconfig();
    let n_seqs = 2 * meta.batch; // two decode groups
    let prompt_len = 4;
    let prompts = MarkovCorpus::new(7).batch(11, n_seqs, prompt_len);
    let reg = Registry::new();
    let (outs, stats) = generate_many_traced(
        &be,
        &graph,
        &meta,
        &w,
        FormatKind::MxInt.name(),
        &qcfg,
        &prompts,
        n_seqs,
        prompt_len,
        2,
        threads,
        &reg,
    )
    .expect("decode");
    assert!(stats.steps > 0);
    (jsonl::render(&reg), outs.into_iter().map(|o| o.tokens).collect())
}

#[test]
fn decode_jsonl_is_byte_identical_across_thread_counts() {
    let _g = TALLY_LOCK.lock().unwrap(); // MxInt decode drives packed kernels
    let (one, toks_one) = decode_trace(1);
    assert!(one.contains(r#""path":"decode/group""#), "{one}");
    assert!(
        one.contains(r#"{"kind":"total","name":"steps","path":"decode/group""#),
        "decode totals missing:\n{one}"
    );
    for threads in [2, 8] {
        let (jt, toks_t) = decode_trace(threads);
        assert_eq!(jt, one, "threads={threads} trace diverged from threads=1");
        assert_eq!(toks_t, toks_one, "threads={threads} tokens diverged");
    }
}

// ------------------------------------------------------- kernel tally --

#[test]
fn kernel_tally_accounts_every_dispatch_exactly() {
    let _g = TALLY_LOCK.lock().unwrap();
    let mut rng = Rng::new(17);
    let x: Vec<f32> = (0..32 * 32).map(|_| rng.normal() as f32).collect();
    let p = Precision::new(5.0, 0.0);
    let wide = pack(&x, 32, 32, FormatKind::MxInt, p); // 32 rows -> tiled
    let flat = pack(&x[..32], 1, 32, FormatKind::MxInt, p); // 1 row -> gemv_tall

    let before = kernel_tally();
    packed_dot(&flat, &flat);
    packed_dot(&flat, &flat);
    packed_gemm(&wide, &wide);
    packed_gemm(&flat, &wide);
    packed_gemm(&flat, &wide);
    packed_gemm(&flat, &wide);
    let d = kernel_tally().delta(&before);
    assert_eq!((d.dot, d.gemm_tiled, d.gemv_tall), (2, 1, 3), "{d:?}");

    let reg = Registry::new();
    d.record_to(&reg, "kernels");
    assert_eq!(reg.counter_total("kernels", "packed_dot"), 2);
    assert_eq!(reg.counter_total("kernels", "packed_gemm_tiled"), 1);
    assert_eq!(reg.counter_total("kernels", "packed_gemv_tall"), 3);
}

// ------------------------------------------------------ chrome golden --

/// The Fig. 1 toy fork-join graph — mirrored line-for-line in
/// `src/obs/chrome.rs` tests and `scripts/verify_trace_schema.py`
/// (which regenerates the golden file).
fn toy_nodes() -> Vec<NodeSpec> {
    vec![
        NodeSpec {
            name: "src".into(),
            preds: vec![],
            pred_buffer: vec![],
            ii: 1,
            tiles_per_inference: 8,
            is_source: true,
            out_tile_bits: 256,
        },
        NodeSpec {
            name: "a".into(),
            preds: vec![0],
            pred_buffer: vec![],
            ii: 2,
            tiles_per_inference: 8,
            is_source: false,
            out_tile_bits: 128,
        },
        NodeSpec {
            name: "b".into(),
            preds: vec![0],
            pred_buffer: vec![],
            ii: 3,
            tiles_per_inference: 8,
            is_source: false,
            out_tile_bits: 128,
        },
        NodeSpec {
            name: "join".into(),
            preds: vec![1, 2],
            pred_buffer: vec![],
            ii: 1,
            tiles_per_inference: 8,
            is_source: false,
            out_tile_bits: 0,
        },
    ]
}

#[test]
fn chrome_sim_export_matches_committed_golden() {
    let nodes = toy_nodes();
    let cfg = SimConfig { inferences: 2, fifo_depth: 2, sequential: false, channel_bits: 32 };
    let (report, trace) = simulate_traced(&nodes, &cfg);
    let got = format!("{}\n", mase::obs::chrome::sim_chrome_json(&nodes, &report, &trace));
    let want = include_str!("golden/fig1_toy_trace.json");
    assert_eq!(
        got, want,
        "golden drift — regenerate with scripts/verify_trace_schema.py --regen"
    );
}
