//! KV-cached decode parity — tier-1, artifact-free, never self-skips.
//!
//! The contract (module docs of `runtime::decode`): a [`Decoder`] step at
//! position `p` must produce logits **bit-identical** to a fresh
//! position-major full forward over the realized `p + 1`-token prefix,
//! for the bit-exact formats (MXInt, fixed point, and fp32 — the packed
//! GEMV and tiled GEMM paths are bitwise-equal, quantizer blocks never
//! straddle positions, and the K2 masking lemma makes truncated
//! single-query attention exact). BMF/BL/FP8 ride the same datapath, but
//! are asserted at the documented 1e-6 relative bound for headroom.
//!
//! Edge cases from the PR 7 checklist: a one-token prompt, a generation
//! that crosses the (16, 2) quantizer-block position boundary at 16, and
//! multi-group batches through `generate_many`.

use mase::data::{Batch, MarkovCorpus};
use mase::formats::FormatKind;
use mase::frontend::ModelMeta;
use mase::ir::Graph;
use mase::passes::{ProfileData, QuantSolution};
use mase::runtime::{generate_many, score_from_steps, CpuBackend, DecodeStats, Decoder, ExecBackend};

const VOCAB: usize = 512;

/// One-layer causal LM; `seq` ≥ 32 lets a generation cross position 16.
fn lm(seq: usize, batch: usize) -> ModelMeta {
    ModelMeta::synthetic("parity-lm", 1, 32, 2, VOCAB, seq, 4, "lm", batch)
}

fn qconfig(meta: &ModelMeta, fmt: FormatKind, bits: f32) -> Vec<f32> {
    let profile = ProfileData::uniform(meta, 4.0);
    QuantSolution::uniform(fmt, bits, meta, &profile).to_qconfig()
}

fn prompt_for(group: usize, prompt_len: usize) -> Vec<i32> {
    MarkovCorpus::new(7).batch(11, group, prompt_len)
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

fn assert_rows_match(want: &[f32], got: &[f32], bitwise: bool, tag: &str) {
    assert_eq!(want.len(), got.len(), "{tag}: row length");
    if bitwise {
        for (i, (w, g)) in want.iter().zip(got).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "{tag}: logit {i}: {w} vs {g}");
        }
    } else {
        let scale = want.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
        for (i, (w, g)) in want.iter().zip(got).enumerate() {
            assert!(
                (w - g).abs() <= 1e-6 * scale,
                "{tag}: logit {i}: {w} vs {g} (scale {scale})"
            );
        }
    }
}

fn setup(meta: &ModelMeta) -> (Vec<f32>, Graph) {
    let w = mase::frontend::init_params(meta, 0xC0DE);
    let graph = CpuBackend::new().prepare(meta, &w, &[]).expect("prepare");
    (w, graph)
}

/// Generate with the KV cache, then replay every step against the
/// stateless full-forward oracle on the realized prefix.
fn assert_cached_decode_matches_oracle(
    meta: &ModelMeta,
    fmt: FormatKind,
    bits: f32,
    prompt_len: usize,
    n_tokens: usize,
    bitwise: bool,
) {
    let group = meta.batch;
    let (w, graph) = setup(meta);
    let qcfg = qconfig(meta, fmt, bits);
    let tag = fmt.name();
    let mut dec = Decoder::new(&CpuBackend::new(), &graph, meta, &w, tag, &qcfg, group).unwrap();
    let prompt = prompt_for(group, prompt_len);
    let out = dec.generate(&prompt, prompt_len, n_tokens).unwrap();
    let total = prompt_len + n_tokens;
    assert_eq!(out.tokens.len(), n_tokens, "{tag}: token-step count");
    assert_eq!(out.step_logits.len(), total, "{tag}: logit-step count");

    // Realized [group, total] token matrix (prompt + generated), batch-major.
    let mut realized = vec![0i32; group * total];
    for bi in 0..group {
        realized[bi * total..bi * total + prompt_len]
            .copy_from_slice(&prompt[bi * prompt_len..(bi + 1) * prompt_len]);
        for (st, tk) in out.tokens.iter().enumerate() {
            realized[bi * total + prompt_len + st] = tk[bi];
        }
    }

    let be = CpuBackend::new();
    let mut oracle = Decoder::new(&be, &graph, meta, &w, tag, &qcfg, group).unwrap();
    for pos in 0..total {
        // Fresh full recompute over the (pos + 1)-token realized prefix.
        let full = oracle.full_forward(&realized, total, pos + 1).unwrap();
        let want = &full[pos];
        assert_rows_match(want, &out.step_logits[pos], bitwise, &format!("{tag} pos {pos}"));
        // Token-for-token: the token emitted at position pos + 1 was the
        // argmax of these logits. Greedy choice must survive recompute.
        if (prompt_len..total).contains(&(pos + 1)) {
            for bi in 0..group {
                assert_eq!(
                    argmax(&want[bi * VOCAB..(bi + 1) * VOCAB]) as i32,
                    out.tokens[pos + 1 - prompt_len][bi],
                    "{tag}: greedy token diverged at pos {} seq {bi}",
                    pos + 1
                );
            }
        }
    }
    // The oracle never touched its cache or step counter.
    assert_eq!(oracle.positions(), 0, "{tag}: oracle cache must stay empty");
    assert_eq!(oracle.stats.steps, 0);
    assert_eq!(oracle.stats.decode_score_dots, 0);

    // Loss over the realized sequences: same accumulation, same bits.
    let full = oracle.full_forward(&realized, total, total).unwrap();
    let oracle_score = score_from_steps(&full, &realized, group, total, VOCAB);
    assert_eq!(oracle_score.correct, out.score.correct, "{tag}: correct-count diverged");
    if bitwise {
        assert_eq!(
            oracle_score.loss.to_bits(),
            out.score.loss.to_bits(),
            "{tag}: loss {} vs cached {}",
            oracle_score.loss,
            out.score.loss
        );
    } else {
        let rel = (oracle_score.loss - out.score.loss).abs() / oracle_score.loss.abs().max(1e-12);
        assert!(rel <= 1e-6, "{tag}: loss rel {rel:e}");
    }
    assert!(out.step_logits.iter().flatten().all(|v| v.is_finite()), "{tag}: non-finite logits");
}

#[test]
fn mxint_cached_decode_is_bitwise_identical_and_crosses_a_block_boundary() {
    // prompt 12 + 6 generated spans positions 12..18: the KV cache grows
    // across the (16, 2) quantizer-block boundary at position 16.
    assert_cached_decode_matches_oracle(&lm(32, 16), FormatKind::MxInt, 7.0, 12, 6, true);
}

#[test]
fn int_cached_decode_is_bitwise_identical_to_recompute() {
    assert_cached_decode_matches_oracle(&lm(32, 16), FormatKind::Int, 8.0, 12, 6, true);
}

#[test]
fn prompt_of_one_token_decodes_bitwise() {
    // Degenerate prefill: one position, then pure cached decode.
    assert_cached_decode_matches_oracle(&lm(16, 16), FormatKind::MxInt, 6.0, 1, 4, true);
}

#[test]
fn bounded_formats_agree_within_the_documented_rel_bound() {
    for (fmt, bits) in [(FormatKind::Bmf, 5.0), (FormatKind::Bl, 7.0), (FormatKind::Fp8, 8.0)] {
        assert_cached_decode_matches_oracle(&lm(16, 16), fmt, bits, 4, 4, false);
    }
}

#[test]
fn fp32_cached_decode_is_bitwise_identical_to_recompute() {
    assert_cached_decode_matches_oracle(&lm(16, 16), FormatKind::Fp32, 32.0, 4, 4, true);
}

#[test]
fn multi_group_generate_matches_per_group_decoders_bitwise() {
    // Batch > 1 twice over: 16 sequences per group in lockstep, and two
    // independent groups through generate_many (single-threaded here;
    // thread-count invariance is property-tested in properties.rs).
    let meta = lm(16, 16);
    let (w, graph) = setup(&meta);
    let qcfg = qconfig(&meta, FormatKind::MxInt, 7.0);
    let (n_seqs, prompt_len, n_tokens) = (32, 5, 4);
    let prompts = prompt_for(n_seqs, prompt_len);
    let be = CpuBackend::new();
    let (outs, stats) = generate_many(
        &be, &graph, &meta, &w, "mxint", &qcfg, &prompts, n_seqs, prompt_len, n_tokens, 1,
    )
    .unwrap();
    assert_eq!(outs.len(), 2, "32 seqs / batch 16 = 2 groups");
    let mut merged = DecodeStats::default();
    for (gi, out) in outs.iter().enumerate() {
        let lo = gi * 16 * prompt_len;
        let mut dec = Decoder::new(&be, &graph, &meta, &w, "mxint", &qcfg, 16).unwrap();
        let solo = dec.generate(&prompts[lo..lo + 16 * prompt_len], prompt_len, n_tokens).unwrap();
        assert_eq!(solo.tokens, out.tokens, "group {gi}: token streams diverged");
        for (si, (a, b)) in solo.step_logits.iter().zip(out.step_logits.iter()).enumerate() {
            assert_rows_match(a, b, true, &format!("group {gi} pos {si}"));
        }
        assert_eq!(solo.score.loss.to_bits(), out.score.loss.to_bits(), "group {gi}: loss");
        merged.merge(&dec.stats);
    }
    assert_eq!(stats, merged, "generate_many stats must be the sum over groups");
}

#[test]
fn teacher_forced_decode_matches_batch_eval_bitwise_for_elementwise_formats() {
    // Element-wise formats (fp32, fixed point) quantize per element, so
    // the position-major decode layout and the batch-major `eval` layout
    // see identical numbers — the loss must agree bit for bit (numpy
    // mirror check K4). Block formats tile differently per layout and are
    // intentionally excluded (K5 negative control).
    let meta = lm(16, 16);
    let (w, graph) = setup(&meta);
    let tokens = MarkovCorpus::new(7).batch(23, meta.batch, meta.seq_len);
    let batch = Batch {
        tokens: tokens.clone(),
        labels: vec![0; meta.batch],
        batch: meta.batch,
        seq: meta.seq_len,
    };
    let be = CpuBackend::new();
    for (fmt, bits) in [(FormatKind::Fp32, 32.0), (FormatKind::Int, 8.0)] {
        let qcfg = qconfig(&meta, fmt, bits);
        let scores = be
            .eval(&graph, &meta, std::slice::from_ref(&batch), fmt.name(), &qcfg, &w)
            .unwrap();
        let mut dec = Decoder::new(&be, &graph, &meta, &w, fmt.name(), &qcfg, 16).unwrap();
        let (_, score) = dec.teacher_forced(&tokens, meta.seq_len, 5).unwrap();
        assert_eq!(
            scores[0].loss.to_bits(),
            score.loss.to_bits(),
            "{}: batch eval loss {} vs teacher-forced {}",
            fmt.name(),
            scores[0].loss,
            score.loss
        );
        assert_eq!(scores[0].correct, score.correct, "{}: correct-count", fmt.name());
    }
}

#[test]
fn decode_steps_do_single_query_attention_only() {
    // Regression for the full-recompute fix: during the decode phase the
    // full-attention counters must not move, and the cached path must do
    // exactly the closed-form O(context) score dots per step.
    let meta = lm(16, 16);
    let (w, graph) = setup(&meta);
    let qcfg = qconfig(&meta, FormatKind::MxInt, 7.0);
    let (prompt_len, n_tokens) = (6, 5);
    let prompt = prompt_for(16, prompt_len);
    let mut dec = Decoder::new(&CpuBackend::new(), &graph, &meta, &w, "mxint", &qcfg, 16).unwrap();
    let logits = dec.prefill(&prompt, prompt_len).unwrap();
    let after_prefill = dec.stats;
    assert_eq!(
        after_prefill.full_attn_rows,
        (16 * meta.n_heads * prompt_len * meta.n_layers) as u64,
        "prefill materializes one attention row per (seq, head, pos, layer)"
    );
    assert_eq!(after_prefill.decode_score_dots, 0);

    let mut cur: Vec<i32> =
        (0..16).map(|bi| argmax(&logits[prompt_len - 1][bi * VOCAB..(bi + 1) * VOCAB]) as i32).collect();
    for _ in 0..n_tokens {
        let lg = dec.decode_step(&cur).unwrap();
        cur = (0..16).map(|bi| argmax(&lg[bi * VOCAB..(bi + 1) * VOCAB]) as i32).collect();
    }
    assert_eq!(
        dec.stats.full_attn_rows, after_prefill.full_attn_rows,
        "decode steps must not fall back to full [s, s] attention"
    );
    assert_eq!(dec.stats.full_score_dots, after_prefill.full_score_dots);
    assert_eq!(dec.stats.steps, n_tokens as u64);
    assert_eq!(
        dec.stats.decode_score_dots,
        DecodeStats::expected_decode_dots(16, meta.n_heads, meta.n_layers, prompt_len, n_tokens),
        "cached attention must cost exactly group*heads*layers*(pos+1) dots per step"
    );
}
