//! Property-based tests (in-tree harness, `mase::util::prop`) over the
//! pure substrates: format invariants, IR round-trips, scheduler
//! invariants, search-space discipline, simulator/regression consistency.

use mase::formats::{self, FormatKind, Precision};
use mase::frontend::{build_graph, manifest::ModelMeta};
use mase::hw::Device;
use mase::ir::{parser::parse_graph, print_graph, verify};
use mase::packed::layout::{pack, packed_bits_for};
use mase::passes::{parallelize, ProfileData, QuantSolution};
use mase::search::{Algorithm, Space, Trial};
use mase::util::prop::prop_check;

fn meta_for(layers: usize, d_model: usize) -> ModelMeta {
    ModelMeta::synthetic("prop", layers, d_model, 2, 512, 32, 4, "classifier", 64)
}

#[test]
fn prop_all_formats_idempotent() {
    prop_check(60, |g| {
        let fmt = *g.choice(&[FormatKind::MxInt, FormatKind::Bmf, FormatKind::Bl, FormatKind::Int, FormatKind::Fp8]);
        let bits = g.int(1, 10) as f32;
        let frac = g.int(0, 6) as f32;
        let x = g.vec_f32_scaled(32 * 8);
        let mut q1 = x.clone();
        formats::quantize_2d(fmt, &mut q1, 32, 8, Precision::new(bits, frac));
        let mut q2 = q1.clone();
        formats::quantize_2d(fmt, &mut q2, 32, 8, Precision::new(bits, frac));
        if q1 == q2 {
            Ok(())
        } else {
            let i = q1.iter().zip(&q2).position(|(a, b)| a != b).unwrap();
            Err(format!("{} not idempotent at {i}: {} -> {}", fmt.name(), q1[i], q2[i]))
        }
    });
}

#[test]
fn prop_pack_unpack_round_trips_bit_exactly() {
    // packed::layout contract 1: unpack(pack(x)) is bit-identical to the
    // fake-quantized grid for all five formats, across random shapes
    // (block-boundary remainders for the element-wise formats),
    // subnormal-heavy data and all-zero blocks. Sole documented
    // exception: fixed point stores two's complement, so the grid's
    // -0.0 canonicalizes to +0.0 (numerically equal).
    let bits_match = |fmt: FormatKind, q: f32, u: f32| {
        q.to_bits() == u.to_bits() || (fmt == FormatKind::Int && q == 0.0 && u == 0.0)
    };
    prop_check(80, |g| {
        let fmt = *g.choice(&[
            FormatKind::MxInt,
            FormatKind::Bmf,
            FormatKind::Bl,
            FormatKind::Int,
            FormatKind::Fp8,
        ]);
        let (rows, cols) = if fmt.is_block_format() {
            (16 * g.int(1, 4) as usize, 2 * g.int(1, 6) as usize)
        } else {
            // arbitrary shapes: exercises partial trailing 32-groups
            (g.int(1, 40) as usize, g.int(1, 9) as usize)
        };
        let n = rows * cols;
        let bits = if fmt == FormatKind::Int { g.int(2, 10) } else { g.int(1, 10) } as f32;
        let p = Precision::new(bits, g.int(-2, 8) as f32);
        let mut x = match g.int(0, 2) {
            0 => g.vec_f32_scaled(n),
            // subnormal-heavy: most magnitudes below 2^-126
            1 => (0..n).map(|_| (g.rng().normal() * 1e-41) as f32).collect(),
            // all-zero blocks with a lone value so some blocks stay zero
            _ => {
                let mut z = vec![0.0f32; n];
                z[n - 1] = g.f32_in(-4.0, 4.0);
                z
            }
        };
        if n > 1 {
            x[0] = -0.0; // signed zeros must survive packing
        }
        let t = pack(&x, rows, cols, fmt, p);
        let u = t.unpack();
        let mut q = x.clone();
        formats::quantize_2d(fmt, &mut q, rows, cols, p);
        for i in 0..n {
            if !bits_match(fmt, q[i], u[i]) {
                return Err(format!(
                    "{} {rows}x{cols} bits={bits}: elem {i} {:?} -> packed {:?}",
                    fmt.name(),
                    q[i],
                    u[i]
                ));
            }
        }
        if t.storage_bits() != packed_bits_for(fmt, p, &[rows, cols]) {
            return Err(format!(
                "{}: storage {} != sizing oracle {}",
                fmt.name(),
                t.storage_bits(),
                packed_bits_for(fmt, p, &[rows, cols])
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_quantization_error_monotone_in_bits() {
    prop_check(40, |g| {
        let fmt = *g.choice(&[FormatKind::MxInt, FormatKind::Int]);
        let x = g.vec_f32_scaled(32 * 8);
        let err = |bits: f32| {
            let mut q = x.clone();
            let frac = if fmt == FormatKind::Int { bits - 3.0 } else { 0.0 };
            formats::quantize_2d(fmt, &mut q, 32, 8, Precision::new(bits, frac));
            x.iter().zip(&q).map(|(a, b)| ((a - b) as f64).abs()).sum::<f64>()
        };
        let lo = g.int(2, 5) as f32;
        let (e_lo, e_hi) = (err(lo), err(lo + 3.0));
        if e_hi <= e_lo + 1e-6 {
            Ok(())
        } else {
            Err(format!("{}: err({lo})={e_lo} < err({})={e_hi}", fmt.name(), lo + 3.0))
        }
    });
}

#[test]
fn prop_ir_print_parse_round_trip() {
    prop_check(20, |g| {
        let layers = g.int(1, 4) as usize;
        let d = 16 * g.int(1, 4) as usize;
        let meta = meta_for(layers, d);
        let mut graph = build_graph(&meta);
        // random quantization applied
        let bits: Vec<f32> = (0..meta.num_qtensors()).map(|_| g.int(1, 8) as f32).collect();
        QuantSolution { fmt: FormatKind::MxInt, bits, fracs: vec![0.0; meta.num_qtensors()] }
            .apply(&mut graph);
        let text = print_graph(&graph);
        let parsed = parse_graph(&text).map_err(|e| e.to_string())?;
        let text2 = print_graph(&parsed);
        if text == text2 {
            Ok(())
        } else {
            Err("print->parse->print not stable".to_string())
        }
    });
}

#[test]
fn prop_built_graphs_always_verify() {
    prop_check(25, |g| {
        let layers = g.int(1, 6) as usize;
        let heads = [1usize, 2, 4][g.int(0, 2) as usize];
        let d = 16 * heads.max(1) * g.int(1, 3) as usize;
        let meta = ModelMeta::synthetic("v", layers, d, heads, 512, 32, 4, "classifier", 64);
        let graph = build_graph(&meta);
        let errs = verify(&graph);
        if errs.is_empty() {
            Ok(())
        } else {
            Err(format!("{errs:?}"))
        }
    });
}

#[test]
fn prop_parallelize_respects_budget_and_improves() {
    prop_check(15, |g| {
        let meta = meta_for(g.int(1, 4) as usize, 32 * g.int(1, 3) as usize);
        let profile = ProfileData::uniform(&meta, 4.0);
        let bits: Vec<f64> = (0..meta.num_qtensors()).map(|_| g.int(2, 8) as f64).collect();
        let sol = QuantSolution::from_search_vector(FormatKind::MxInt, &bits, &meta, &profile);
        let mut graph = build_graph(&meta);
        sol.apply(&mut graph);
        let frac = g.f32_in(0.05, 0.8) as f64;
        let device = Device::u250();
        let dp = parallelize(&mut graph, &device, frac);
        if dp.area_luts > device.luts * frac * 1.001 {
            return Err(format!("area {} exceeds budget {}", dp.area_luts, device.luts * frac));
        }
        if !(dp.throughput > 0.0 && dp.throughput.is_finite()) {
            return Err(format!("bad throughput {}", dp.throughput));
        }
        Ok(())
    });
}

#[test]
fn prop_topo_order_valid_for_random_built_graphs() {
    prop_check(20, |g| {
        let meta = meta_for(g.int(1, 5) as usize, 32);
        let graph = build_graph(&meta);
        let order = graph.topo_order();
        if order.len() != graph.ops.len() {
            return Err("topo order incomplete".into());
        }
        let pos: std::collections::HashMap<_, _> =
            order.iter().enumerate().map(|(i, o)| (*o, i)).collect();
        for op in &graph.ops {
            for &a in &op.args {
                if let Some(p) = graph.value(a).producer {
                    if pos[&p] >= pos[&op.id] {
                        return Err(format!("edge violated: {:?} -> {:?}", p, op.id));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_searchers_respect_bounds_under_adversarial_feedback() {
    prop_check(12, |g| {
        let dims = g.int(2, 20) as usize;
        let lo = g.f32_in(0.0, 4.0) as f64;
        let hi = lo + g.f32_in(1.0, 6.0) as f64;
        let alg = *g.choice(&Algorithm::ALL);
        let mut s = alg.build(Space::new(vec![lo; dims], vec![hi; dims]), g.int(0, 1000) as u64);
        for i in 0..30 {
            let x = s.ask();
            for &xi in &x {
                if !(lo - 1e-9..=hi + 1e-9).contains(&xi) {
                    return Err(format!("{} out of bounds: {xi} not in [{lo},{hi}]", alg.name()));
                }
            }
            // adversarial: constant, NaN-free extreme values
            let v = if i % 3 == 0 { -1e9 } else { 1e9 };
            s.tell(Trial { x, value: v, objectives: vec![v] });
        }
        Ok(())
    });
}

#[test]
fn prop_average_bitwidth_within_knob_range() {
    prop_check(20, |g| {
        let meta = meta_for(2, 32);
        let profile = ProfileData::uniform(&meta, 4.0);
        let bits: Vec<f64> = (0..meta.num_qtensors()).map(|_| g.int(2, 8) as f64).collect();
        let sol = QuantSolution::from_search_vector(FormatKind::MxInt, &bits, &meta, &profile);
        let mut graph = build_graph(&meta);
        sol.apply(&mut graph);
        let b = sol.average_bitwidth(&graph);
        let lo = bits.iter().cloned().fold(f64::MAX, f64::min) + 1.0; // +sign
        let hi = bits.iter().cloned().fold(f64::MIN, f64::max) + 1.0 + 0.25; // +shared
        if b >= lo - 1e-9 && b <= hi + 1e-9 {
            Ok(())
        } else {
            Err(format!("avg bits {b} outside [{lo},{hi}]"))
        }
    });
}

#[test]
fn prop_parallel_decode_is_bit_identical_at_any_thread_count() {
    // PR 7 determinism contract: `generate_many` fans data-independent
    // sequence groups over `par_map` workers and returns them in input
    // order, so for a fixed seed the token streams AND every step's
    // logits are bit-identical at 1, 2, and 8 threads — across random
    // model shapes, formats, prompt lengths, and seeds.
    use mase::runtime::{generate_many, CpuBackend, ExecBackend};
    prop_check(6, |g| {
        let heads = [1usize, 2][g.int(0, 1) as usize];
        let d = 16 * heads.max(2);
        let meta = ModelMeta::synthetic("prop-lm", 1, d, heads, 512, 16, 4, "lm", 16);
        let fmt = *g.choice(&[FormatKind::MxInt, FormatKind::Int, FormatKind::Fp32]);
        let bits = if fmt == FormatKind::Fp32 { 32.0 } else { g.int(4, 8) as f32 };
        let profile = ProfileData::uniform(&meta, 4.0);
        let qcfg = QuantSolution::uniform(fmt, bits, &meta, &profile).to_qconfig();
        let w = mase::frontend::init_params(&meta, g.int(1, 1 << 20) as u64);
        let be = CpuBackend::new();
        let graph = be.prepare(&meta, &w, &[]).map_err(|e| e.to_string())?;
        let n_seqs = 16 * g.int(1, 2) as usize;
        let prompt_len = g.int(1, 6) as usize;
        let n_tokens = g.int(1, 4) as usize;
        let prompts =
            mase::data::MarkovCorpus::new(7).batch(g.int(0, 1000) as u64, n_seqs, prompt_len);
        let run = |threads: usize| {
            generate_many(
                &be, &graph, &meta, &w, fmt.name(), &qcfg, &prompts, n_seqs, prompt_len,
                n_tokens, threads,
            )
            .map_err(|e| e.to_string())
        };
        let (base, base_stats) = run(1)?;
        for threads in [2usize, 8] {
            let (outs, stats) = run(threads)?;
            if stats != base_stats {
                return Err(format!("{}: stats diverged at {threads} threads", fmt.name()));
            }
            for (gi, (a, b)) in base.iter().zip(outs.iter()).enumerate() {
                if a.tokens != b.tokens {
                    return Err(format!(
                        "{}: group {gi} token stream diverged at {threads} threads",
                        fmt.name()
                    ));
                }
                for (si, (la, lb)) in a.step_logits.iter().zip(b.step_logits.iter()).enumerate() {
                    let bitwise =
                        la.iter().zip(lb).all(|(x, y)| x.to_bits() == y.to_bits());
                    if !bitwise {
                        return Err(format!(
                            "{}: group {gi} pos {si} logits diverged at {threads} threads",
                            fmt.name()
                        ));
                    }
                }
                if a.score.loss.to_bits() != b.score.loss.to_bits() {
                    return Err(format!("{}: group {gi} loss diverged", fmt.name()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_simulator_within_bounds_of_regression() {
    prop_check(8, |g| {
        let meta = meta_for(g.int(1, 3) as usize, 32);
        let profile = ProfileData::uniform(&meta, 4.0);
        let bits = vec![g.int(2, 8) as f64; meta.num_qtensors()];
        let sol = QuantSolution::from_search_vector(FormatKind::MxInt, &bits, &meta, &profile);
        let mut graph = build_graph(&meta);
        sol.apply(&mut graph);
        let device = Device::u250();
        let dp = parallelize(&mut graph, &device, 0.3);
        // both sides model the device's channel width (beat model)
        let sim = mase::sim::simulated_throughput_at(
            &graph,
            device.clock_hz,
            6,
            device.channel_bits,
        );
        let ratio = sim / dp.throughput;
        if ratio > 0.2 && ratio < 3.0 {
            Ok(())
        } else {
            Err(format!("sim/regression ratio {ratio} (sim {sim}, reg {})", dp.throughput))
        }
    });
}
