//! CPU-backend interpreter microbench — artifact-free (never skips).
//!
//! Times the packed-arithmetic evaluate path (`CpuBackend::new()`)
//! against the fake-quantized float reference (`CpuBackend::reference()`)
//! per format, in trials/second of the evaluate pass on one eval batch.
//! This is the oracle the `--backend cpu` search loop pays per trial, so
//! these numbers bound artifact-free search throughput directly.
//!
//! Run: `cargo bench --bench cpu_backend`  (knobs: MASE_MODELS)

#[path = "common.rs"]
mod common;

use mase::data::{batches, MarkovCorpus, Task};
use mase::formats::FormatKind;
use mase::frontend::Manifest;
use mase::passes::{profile_model, Evaluator, QuantSolution};
use mase::runtime::{CpuBackend, DecodeStats, Decoder, ExecBackend};
use mase::util::Table;

fn main() {
    common::banner("CPU backend", "packed interpreter evaluate-pass throughput (artifact-free)");
    let manifest = Manifest::synthetic();
    let models: Vec<String> = std::env::var("MASE_MODELS")
        .map(|v| v.split(',').map(str::to_string).collect())
        .unwrap_or_else(|_| vec!["toy-sim".into(), "opt-125m-sim".into()]);

    let mut t = Table::new(vec!["model", "format", "packed ms/eval", "reference ms/eval", "ratio"]);
    for name in &models {
        let meta = manifest.model(name).expect("zoo model").clone();
        let w = mase::frontend::init_params(&meta, 0xC0DE);
        let eval = batches(Task::Sst2, 1, 1, meta.batch, meta.seq_len);
        let profile = profile_model(&CpuBackend::new(), &meta, &w, &eval).expect("profile");
        for (fmt, bits) in [(FormatKind::MxInt, 7.0f32), (FormatKind::Int, 8.0)] {
            let sol = QuantSolution::uniform(fmt, bits, &meta, &profile);
            let time_path = |be: CpuBackend| {
                let ev = Evaluator::new(be, &meta, &w, &eval).expect("evaluator");
                ev.accuracy(&sol).expect("warmup");
                let reps = 3;
                let t0 = std::time::Instant::now();
                for _ in 0..reps {
                    ev.accuracy(&sol).expect("eval");
                }
                t0.elapsed().as_secs_f64() / reps as f64
            };
            let packed = time_path(CpuBackend::new());
            let reference = time_path(CpuBackend::reference());
            t.row(vec![
                name.clone(),
                format!("{}{}", fmt.name(), bits as i32),
                format!("{:.1}", packed * 1e3),
                format!("{:.1}", reference * 1e3),
                format!("{:.2}x", packed / reference),
            ]);
        }
    }
    println!("{}", t.render());
    println!("(each eval = 1 batch; a --backend cpu search pays one eval per uncached trial)");

    prefill_vs_decode();
}

/// PR 7 section: incremental KV-cached decode vs full-recompute
/// generation. Wall-clock ms/token is reported for color, but the
/// complexity claim is *asserted on the counted attention work* (exact,
/// CI-noise-free): the cached path pays O(context) score dots per step,
/// the recompute oracle O(context^2) per re-forward.
fn prefill_vs_decode() {
    common::banner("decode", "prefill vs KV-cached decode vs full recompute (mxint7)");
    let manifest = Manifest::synthetic();
    let meta = manifest.model("toy-lm").expect("toy-lm in zoo").clone();
    let w = mase::frontend::init_params(&meta, 0xC0DE);
    let be = CpuBackend::new();
    let graph = be.prepare(&meta, &w, &[]).expect("prepare");
    let eval = batches(Task::Sst2, 1, 1, meta.batch, meta.seq_len);
    let profile = profile_model(&be, &meta, &w, &eval).expect("profile");
    let qcfg = QuantSolution::uniform(FormatKind::MxInt, 7.0, &meta, &profile).to_qconfig();
    let (group, prompt_len, n_tokens) = (meta.batch, 8, 16);
    let prompt = MarkovCorpus::new(7).batch(42, group, prompt_len);

    let mut dec = Decoder::new(&be, &graph, &meta, &w, "mxint", &qcfg, group).expect("decoder");
    let out = dec.generate(&prompt, prompt_len, n_tokens).expect("generate");
    let cached_dots = dec.stats.decode_score_dots;

    // Recompute oracle: generate the same stream by re-running the full
    // forward over the whole realized prefix at every step.
    let total = prompt_len + n_tokens;
    let mut realized = vec![0i32; group * total];
    for bi in 0..group {
        realized[bi * total..bi * total + prompt_len]
            .copy_from_slice(&prompt[bi * prompt_len..(bi + 1) * prompt_len]);
        for (st, tk) in out.tokens.iter().enumerate() {
            realized[bi * total + prompt_len + st] = tk[bi];
        }
    }
    let mut oracle = Decoder::new(&be, &graph, &meta, &w, "mxint", &qcfg, group).expect("oracle");
    let t0 = std::time::Instant::now();
    for step in 0..n_tokens {
        oracle.full_forward(&realized, total, prompt_len + step + 1).expect("recompute");
    }
    let recompute_seconds = t0.elapsed().as_secs_f64();
    let recompute_dots = oracle.stats.full_score_dots;

    let toks = (group * n_tokens) as f64;
    let mut t = Table::new(vec!["phase", "ms/token", "score dots"]);
    t.row(vec![
        "prefill (full fwd)".into(),
        format!("{:.3}", out.prefill_seconds * 1e3 / (group * prompt_len) as f64),
        format!("{}", dec.stats.full_score_dots),
    ]);
    t.row(vec![
        "decode (KV cache)".into(),
        format!("{:.3}", out.decode_seconds * 1e3 / toks),
        format!("{cached_dots}"),
    ]);
    t.row(vec![
        "decode (recompute)".into(),
        format!("{:.3}", recompute_seconds * 1e3 / toks),
        format!("{recompute_dots}"),
    ]);
    println!("{}", t.render());

    // The asserted scoreboard: exact closed form for the cached path, and
    // strictly superlinear work for the recompute oracle.
    assert_eq!(
        cached_dots,
        DecodeStats::expected_decode_dots(group, meta.n_heads, meta.n_layers, prompt_len, n_tokens),
        "cached decode must cost exactly group*heads*layers*(pos+1) dots per step"
    );
    assert!(
        recompute_dots > cached_dots * 2,
        "full recompute ({recompute_dots} dots) should dwarf cached decode ({cached_dots})"
    );
    println!(
        "(asserted: cached decode = {cached_dots} score dots, O(context)/step; \
         recompute = {recompute_dots}, {:.1}x more)",
        recompute_dots as f64 / cached_dots as f64
    );
}
