//! CPU-backend interpreter microbench — artifact-free (never skips).
//!
//! Times the packed-arithmetic evaluate path (`CpuBackend::new()`)
//! against the fake-quantized float reference (`CpuBackend::reference()`)
//! per format, in trials/second of the evaluate pass on one eval batch.
//! This is the oracle the `--backend cpu` search loop pays per trial, so
//! these numbers bound artifact-free search throughput directly.
//!
//! Run: `cargo bench --bench cpu_backend`  (knobs: MASE_MODELS)

#[path = "common.rs"]
mod common;

use mase::data::{batches, Task};
use mase::formats::FormatKind;
use mase::frontend::Manifest;
use mase::passes::{profile_model, Evaluator, QuantSolution};
use mase::runtime::CpuBackend;
use mase::util::Table;

fn main() {
    common::banner("CPU backend", "packed interpreter evaluate-pass throughput (artifact-free)");
    let manifest = Manifest::synthetic();
    let models: Vec<String> = std::env::var("MASE_MODELS")
        .map(|v| v.split(',').map(str::to_string).collect())
        .unwrap_or_else(|_| vec!["toy-sim".into(), "opt-125m-sim".into()]);

    let mut t = Table::new(vec!["model", "format", "packed ms/eval", "reference ms/eval", "ratio"]);
    for name in &models {
        let meta = manifest.model(name).expect("zoo model").clone();
        let w = mase::frontend::init_params(&meta, 0xC0DE);
        let eval = batches(Task::Sst2, 1, 1, meta.batch, meta.seq_len);
        let profile = profile_model(&CpuBackend::new(), &meta, &w, &eval).expect("profile");
        for (fmt, bits) in [(FormatKind::MxInt, 7.0f32), (FormatKind::Int, 8.0)] {
            let sol = QuantSolution::uniform(fmt, bits, &meta, &profile);
            let time_path = |be: CpuBackend| {
                let ev = Evaluator::new(be, &meta, &w, &eval).expect("evaluator");
                ev.accuracy(&sol).expect("warmup");
                let reps = 3;
                let t0 = std::time::Instant::now();
                for _ in 0..reps {
                    ev.accuracy(&sol).expect("eval");
                }
                t0.elapsed().as_secs_f64() / reps as f64
            };
            let packed = time_path(CpuBackend::new());
            let reference = time_path(CpuBackend::reference());
            t.row(vec![
                name.clone(),
                format!("{}{}", fmt.name(), bits as i32),
                format!("{:.1}", packed * 1e3),
                format!("{:.1}", reference * 1e3),
                format!("{:.2}x", packed / reference),
            ]);
        }
    }
    println!("{}", t.render());
    println!("(each eval = 1 batch; a --backend cpu search pays one eval per uncached trial)");
}
