//! Table 1: evaluation of MX formats at ~8 average bits quantizing the
//! LLaMA simulant on wikitext2-sim — perplexity, memory density,
//! arithmetic density, with the paper's measured values alongside.

#[path = "common.rs"]
mod common;

use mase::formats::{FormatKind, Precision};
use mase::hw::{arithmetic_density, memory_density};
use mase::packed::layout::packed_bits_for;
use mase::passes::QuantSolution;
use mase::util::Table;

fn main() {
    common::banner("Table 1", "MX formats at avg 8 bits, llama-sim on wikitext2-sim");
    let session = common::session();
    let meta = session.manifest.model("llama-sim").unwrap().clone();
    let w = common::weights(&session, &meta, None);
    let eval = common::lm_eval_set(&meta);
    let (ev, profile) = common::evaluator_for(&session, &meta, &w, &eval);

    // (format, bits knob, paper ppl, paper mem, paper arith)
    let rows: [(FormatKind, f32, &str, &str, &str); 6] = [
        (FormatKind::Fp32, 32.0, "7.06", "1x", "1x"),
        (FormatKind::Int, 8.0, "265", "4x", "7.7x"),
        (FormatKind::Fp8, 8.0, "7.18", "4x", "17.4x"),
        (FormatKind::MxInt, 7.0, "7.07", "3.8x", "14.4x"),
        (FormatKind::Bmf, 5.0, "223000", "3.8x", "14.4x"),
        (FormatKind::Bl, 7.0, "18.8", "3.8x", "16.1x"),
    ];

    let mut t = Table::new(vec![
        "Approach",
        "Config",
        "Perplexity",
        "paper-ppl",
        "MemDensity",
        "Measured",
        "paper",
        "ArithDensity",
        "paper",
    ]);
    // Measured density: actual bit-packed storage (packed::layout) of a
    // representative d_model x d_ff weight — shared exponents, BMF/BL
    // field guards and word-alignment padding included — next to the
    // analytic Eq. (1) number so the model-vs-measurement gap is visible.
    let wshape = [meta.d_model, meta.d_ff];
    let welems = (meta.d_model * meta.d_ff) as f64;
    let mut measured = Vec::new();
    for (fmt, bits, ppl_p, mem_p, ari_p) in rows {
        let sol = QuantSolution::uniform(fmt, bits, &meta, &profile);
        let acc = ev.accuracy(&sol).expect("eval failed");
        let p = Precision::new(bits, sol.fracs[0]);
        measured.push((fmt, acc.perplexity()));
        let meas_bits = packed_bits_for(fmt, p, &wshape) as f64 / welems;
        t.row(vec![
            fmt.name().to_string(),
            if fmt == FormatKind::Fp32 { "-".into() } else { "W8A8".to_string() },
            format!("{:.2}", acc.perplexity()),
            ppl_p.to_string(),
            format!("{:.2}x", memory_density(fmt, p)),
            format!("{:.2}x", 32.0 / meas_bits),
            mem_p.to_string(),
            format!("{:.1}x", arithmetic_density(fmt, p)),
            ari_p.to_string(),
        ]);
    }
    println!("{}", t.render());

    // shape assertions the paper's Table 1 implies
    let ppl = |f: FormatKind| measured.iter().find(|(g, _)| *g == f).unwrap().1;
    let ok_int = ppl(FormatKind::Int) > 1.5 * ppl(FormatKind::Fp32);
    let ok_mx = ppl(FormatKind::MxInt) < 1.1 * ppl(FormatKind::Fp32);
    let ok_bmf = ppl(FormatKind::Bmf) > ppl(FormatKind::MxInt);
    let ok_bl = ppl(FormatKind::Bl) > ppl(FormatKind::MxInt);
    println!(
        "shape check: int8 blows up: {ok_int} | mxint8 ~ fp32: {ok_mx} | bmf worse: {ok_bmf} | bl worse: {ok_bl}"
    );
}
