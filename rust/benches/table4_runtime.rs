//! Table 4: runtime breakdown of the toolflow, averaged across models:
//! pre-process (front-end, profile), per-trial search passes (quantize,
//! optional QAT fine-tune, parallelize, evaluate) and post-process
//! (emit; synthesis is reported by the paper at 14.3 h on Vivado and is
//! out of reach here — we report the emit-side cost we control).
//!
//! Also measures the parallel batched search driver on the Fig. 4
//! workload shape (serial vs multi-threaded wall-clock): this section is
//! pure Rust (quantize + parallelize + dataflow-simulate per trial) and
//! runs even without the PJRT artifacts.

#[path = "common.rs"]
mod common;

use mase::data::Task;
use mase::formats::FormatKind;
use mase::frontend::{build_graph, ModelMeta};
use mase::hw::Device;
use mase::passes::{
    emit_pass, parallelize, profile_model, Evaluator, PassManager, ProfileData, QuantSolution,
};
use mase::search::{run_batched_cached, Algorithm, BatchOptions, EvalCache, MemoKey};
use mase::util::{Stopwatch, Table};

/// Serial-vs-parallel wall-clock of the batched search driver on the
/// Fig. 4 workload shape. The objective is the hardware half of the
/// `evaluate` pass (quantize the IR, parallelize, cycle-simulate) on a
/// synthetic transformer — compute-heavy, deterministic, artifact-free.
fn parallel_search_speedup() {
    common::banner("Table 4a", "parallel batched search speedup (Fig. 4 workload)");
    let meta = ModelMeta::synthetic("speedup-sim", 6, 128, 4, 512, 32, 4, "classifier", 64);
    let profile = ProfileData::uniform(&meta, 4.0);
    let g0 = build_graph(&meta);
    let device = Device::u250();
    let objective = |x: &[f64]| {
        let sol = QuantSolution::from_search_vector(FormatKind::MxInt, x, &meta, &profile);
        let mut g = g0.clone();
        sol.apply(&mut g);
        let dp = parallelize(&mut g, &device, 0.4);
        let sim = mase::sim::simulated_throughput(&g, device.clock_hz, 4);
        let bits = sol.average_bitwidth(&g);
        // SW-style objective proxy: prefer fewer bits, break ties on the
        // simulated + regressed throughput agreement
        let value = 0.6 / bits.max(1e-9) + 2e-8 * (dp.throughput + sim);
        (value, vec![])
    };

    let trials = common::env_usize("MASE_SPEEDUP_TRIALS", 48);
    let run_with = |threads: usize| {
        let cache = EvalCache::new();
        let opts = BatchOptions { batch: 8, threads, memo: MemoKey::Rounded, ..Default::default() };
        let sw = Stopwatch::start();
        let hist = run_batched_cached(
            Algorithm::Tpe,
            mase::passes::search_pass::space_for(FormatKind::MxInt, meta.num_qtensors(), 2.0, 8.0),
            0,
            trials,
            &opts,
            &cache,
            &objective,
        );
        (sw.secs(), hist, cache.len())
    };

    let (t1, h1, evals1) = run_with(1);
    let (t4, h4, evals4) = run_with(4);
    let auto = mase::util::pool::threads_from_env(0);
    let (ta, ha, _) = run_with(auto);

    let mut t = Table::new(vec!["threads", "wall_s", "trials", "distinct evals", "speedup"]);
    t.row(vec!["1".to_string(), format!("{t1:.3}"), h1.len().to_string(), evals1.to_string(), "1.00x".into()]);
    t.row(vec![
        "4".to_string(),
        format!("{t4:.3}"),
        h4.len().to_string(),
        evals4.to_string(),
        format!("{:.2}x", t1 / t4),
    ]);
    t.row(vec![
        format!("{auto} (auto)"),
        format!("{ta:.3}"),
        ha.len().to_string(),
        String::new(),
        format!("{:.2}x", t1 / ta),
    ]);
    println!("{}", t.render());

    // the documented determinism convention: identical history for every
    // thread count
    let same = h1.len() == h4.len()
        && h1.iter().zip(h4.iter()).all(|(a, b)| a.x == b.x && a.value == b.value);
    println!("history identical across thread counts: {same}");
    println!("memoized duplicate proposals: {} of {} trials", h1.len() - evals1, h1.len());
    let speedup = t1 / t4;
    println!(
        "4-thread speedup: {speedup:.2}x ({})",
        if speedup >= 2.0 { "meets the >= 2x target" } else { "below the 2x target on this host" }
    );
}

fn main() {
    common::banner("Table 4", "pass runtime breakdown (averaged over models)");
    parallel_search_speedup();

    let Some(session) = common::try_session() else { return };
    let n_models = common::env_usize("MASE_TABLE4_MODELS", 4);
    let mut pm = PassManager::new();
    let tmp = std::env::temp_dir().join("mase_table4");

    for name in common::classifier_names(&session).into_iter().take(n_models) {
        let meta = session.manifest.model(&name).unwrap().clone();
        let w = common::weights(&session, &meta, Some(Task::Sst2));
        let eval = common::eval_set(&meta, Task::Sst2);
        let g0 = pm.run("front-end", || build_graph(&meta));
        let backend = session.pjrt_backend().expect("PJRT session");
        let profile =
            pm.run("profile", || profile_model(&backend, &meta, &w, &eval[..1]).unwrap());
        let ev = Evaluator::new(backend, &meta, &w, &eval).expect("evaluator");

        // one representative search trial, pass by pass
        for trial in 0..4u64 {
            let bits: Vec<f64> =
                (0..meta.num_qtensors()).map(|i| 2.0 + ((trial as usize + i) % 7) as f64).collect();
            let sol = pm.run("quantize", || {
                QuantSolution::from_search_vector(FormatKind::MxInt, &bits, &meta, &profile)
            });
            let mut g = g0.clone();
            sol.apply(&mut g);
            pm.run("parallelize", || parallelize(&mut g, &Device::u250(), 0.4));
            pm.run("evaluate", || ev.evaluate(&sol).unwrap());
        }
        // QAT fine-tune step cost (small models only)
        if meta.artifacts.contains_key("qat_mxint") {
            let art = meta.artifact("qat_mxint").unwrap();
            let sol = QuantSolution::uniform(FormatKind::MxInt, 4.0, &meta, &profile);
            let qcfg = sol.to_qconfig();
            let b = &eval[0];
            pm.run("quantize (fine-tune)", || {
                session
                    .pjrt()
                    .unwrap()
                    .execute(
                        art,
                        &[
                            mase::runtime::TensorData::f32(&w, &[meta.param_size as i64]),
                            mase::runtime::TensorData::i32(&b.tokens, &[b.batch as i64, b.seq as i64]),
                            mase::runtime::TensorData::i32(&b.labels, &[b.batch as i64]),
                            mase::runtime::TensorData::f32(&qcfg, &[meta.num_qtensors() as i64, 2]),
                            mase::runtime::TensorData::scalar_f32(0.002),
                        ],
                    )
                    .unwrap()
            });
        }
        // post-process: emit
        let mut g = g0.clone();
        QuantSolution::uniform(FormatKind::MxInt, 4.0, &meta, &profile).apply(&mut g);
        parallelize(&mut g, &Device::u250(), 0.4);
        pm.run("emit", || emit_pass::emit_to_dir(&g, &tmp.join(&name)).unwrap());
    }
    let _ = std::fs::remove_dir_all(&tmp);

    let mut t = Table::new(vec!["stage", "pass", "per-call", "paper"]);
    let rows = [
        ("Pre-process", "front-end", "12s"),
        ("Pre-process", "profile", "97s"),
        ("Search (single trial)", "quantize", "5.3s"),
        ("Search (single trial)", "quantize (fine-tune)", "3201s"),
        ("Search (single trial)", "parallelize", "21 mins"),
        ("Search (single trial)", "evaluate", "376s"),
        ("Post-process", "emit", "153s"),
        ("Post-process", "synthesize", "14.3 hours"),
    ];
    for (stage, pass, paper) in rows {
        let (secs, calls) = pm.stat(pass);
        let measured = if calls > 0 {
            format!("{:.4}s", secs / calls as f64)
        } else {
            "n/a (Vivado)".to_string()
        };
        t.row(vec![stage.to_string(), pass.to_string(), measured, paper.to_string()]);
    }
    println!("{}", t.render());
    println!("(absolute times differ — the simulants are ~1000x smaller than the paper's");
    println!("LLMs and our 'synthesize' is the SV emission; the *ordering* of pass costs");
    println!("matches: fine-tune >> evaluate > parallelize > quantize, emit cheap.)");
    println!("\nraw pass-manager log:\n{}", pm.report());
}
