//! Table 3: MASE IR scalability across the OPT family — DAG size,
//! code-generation time and emitted code size, with the paper's MLIR-
//! affine comparison quoted. We additionally measure an in-repo
//! "instruction-level" lowering (every op expanded to per-element
//! operations, the mechanism behind MLIR-affine's blowup) to show the
//! module-level-vs-instruction-level gap with measured numbers.

#[path = "common.rs"]
mod common;

use mase::formats::FormatKind;
use mase::frontend::build_graph;
use mase::hw::throughput::op_work;
use mase::hw::Device;
use mase::passes::{emit_pass, parallelize, ProfileData, QuantSolution};
use mase::util::{Stopwatch, Table};

const OPTS: [(&str, &str, &str); 5] = [
    ("opt-125m-sim", "1.9M", "1 week"),
    ("opt-350m-sim", "1.7M", "2 weeks"),
    ("opt-1.3b-sim", "1.7M", ">4 weeks"),
    ("opt-2.7b-sim", "1.9M", ">4 weeks"),
    ("opt-6.7b-sim", "2.3M", ">4 weeks"),
];

fn main() {
    common::banner("Table 3", "IR scalability across the OPT family");
    let session = common::session();
    let tmp = std::env::temp_dir().join("mase_table3");

    let mut t = Table::new(vec![
        "model",
        "affine-DAG(paper)",
        "affine-time(paper)",
        "instr-DAG(measured)",
        "MASE-DAG",
        "codegen",
        "SV-lines",
    ]);
    for (name, paper_dag, paper_time) in OPTS {
        let meta = session.manifest.model(name).unwrap().clone();
        let profile = ProfileData::uniform(&meta, 4.0);
        let sw = Stopwatch::start();
        let mut g = build_graph(&meta);
        QuantSolution::uniform(FormatKind::MxInt, 5.0, &meta, &profile).apply(&mut g);
        parallelize(&mut g, &Device::u250(), 0.3);
        let dir = tmp.join(name);
        let (_design, lines) = emit_pass::emit_to_dir(&g, &dir).unwrap();
        let secs = sw.secs();
        // instruction-level size: one op per scalar multiply-accumulate /
        // element op — what an affine lowering would materialize.
        let instr: f64 = g.ops.iter().map(|o| op_work(&g, o)).sum();
        t.row(vec![
            name.to_string(),
            paper_dag.to_string(),
            paper_time.to_string(),
            format!("{:.1}M", instr / 1e6),
            g.dag_size().to_string(),
            format!("{:.3}s", secs),
            lines.to_string(),
        ]);
    }
    println!("{}", t.render());
    let _ = std::fs::remove_dir_all(&tmp);
    println!("shape: module-level MASE IR stays at ~10^2 ops and sub-second codegen while");
    println!("instruction-level DAGs are 10^6+ — the paper's exponential-compile-time gap.");
}
