//! Fig. 6: accuracy and average bitwidth for MP MXInt vs MP int across
//! the five OPT simulant sizes and all six downstream tasks. Small models
//! run QAT inside the search trials (the trainable-IR claim); larger ones
//! use PTQ, as in the paper.

#[path = "common.rs"]
mod common;

use mase::data::Task;
use mase::formats::FormatKind;
use mase::passes::{run_search, QuantSolution, SearchConfig};
use mase::util::Table;

const OPTS: [&str; 5] =
    ["opt-125m-sim", "opt-350m-sim", "opt-1.3b-sim", "opt-2.7b-sim", "opt-6.7b-sim"];

fn main() {
    common::banner("Fig 6", "OPT sizes x 6 tasks: MP MXInt vs MP int (QAT small / PTQ large)");
    let session = common::session();
    let trials = common::trials();
    let tasks: Vec<Task> = Task::ALL.to_vec();

    let mut t = Table::new(vec![
        "model", "task", "fp32", "MPMXInt_acc", "MPMXInt_bits", "MPint_acc", "MPint_bits", "mode",
    ]);
    let mut d_bits = 0.0f64;
    let mut d_rows = 0usize;
    // Default to the OPT sizes whose 6-task weights are pretrained;
    // MASE_FIG6_MODELS=all sweeps all five (trains the big ones on
    // demand, ~25 extra minutes on a single core).
    let sel = std::env::var("MASE_FIG6_MODELS")
        .unwrap_or_else(|_| "opt-125m-sim,opt-350m-sim,opt-1.3b-sim".into());
    let models: Vec<&str> = OPTS
        .iter()
        .copied()
        .filter(|m| sel == "all" || sel.split(',').any(|s| s == *m))
        .filter(|m| common::classifier_names(&session).iter().any(|n| n == m))
        .collect();
    for name in models {
        let meta = session.manifest.model(name).unwrap().clone();
        // QAT for small models only (paper: QAT small / PTQ large)
        let qat_steps = if meta.artifacts.contains_key("qat_mxint") { 2 } else { 0 };
        for &task in &tasks {
            let w = common::weights(&session, &meta, Some(task));
            let eval = common::eval_set(&meta, task);
            let (ev, profile) = common::evaluator_for(&session, &meta, &w, &eval);
            let fp32 = ev
                .accuracy(&QuantSolution::uniform(FormatKind::Fp32, 32.0, &meta, &profile))
                .unwrap()
                .accuracy();
            let mx = run_search(
                &ev,
                &profile,
                task,
                &SearchConfig { trials, qat_steps, ..Default::default() },
            )
            .unwrap()
            .best_eval;
            let qat_int = if qat_steps > 0 && meta.artifacts.contains_key("qat_int") { qat_steps } else { 0 };
            let ib = run_search(
                &ev,
                &profile,
                task,
                &SearchConfig { fmt: FormatKind::Int, trials, qat_steps: qat_int, ..Default::default() },
            )
            .unwrap()
            .best_eval;
            d_bits += ib.avg_bits - mx.avg_bits;
            d_rows += 1;
            t.row(vec![
                name.to_string(),
                task.name().to_string(),
                format!("{fp32:.3}"),
                format!("{:.3}", mx.accuracy),
                format!("{:.2}", mx.avg_bits),
                format!("{:.3}", ib.accuracy),
                format!("{:.2}", ib.avg_bits),
                if qat_steps > 0 { "QAT".into() } else { "PTQ".to_string() },
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "paper shape: MP MXInt smaller avg bitwidths than MP int by ~0.5 bit at\n\
         better accuracy. measured avg bit gap (MPint - MPMXInt): {:+.2} bits",
        d_bits / d_rows.max(1) as f64
    );
}
