//! Fig. 6: accuracy and average bitwidth for MP MXInt vs MP int across
//! the five OPT simulant sizes and all six downstream tasks. Small models
//! run QAT inside the search trials (the trainable-IR claim); larger ones
//! use PTQ, as in the paper.
//!
//! The grid runs through the `sweep` orchestrator with a persistent
//! evaluation cache (MASE_CACHE, default `<artifacts>/eval_cache.json`),
//! so duplicate configs are memoized across cells AND across invocations:
//! the first run fills the cache, a re-run of the same sweep performs
//! zero re-simulations (100% hit rate — printed below).

#[path = "common.rs"]
mod common;

use mase::coordinator::{run_sweep, Session, SweepConfig};
use mase::data::Task;
use mase::formats::FormatKind;
use mase::passes::QuantSolution;
use mase::util::Table;
use std::path::PathBuf;

const OPTS: [&str; 5] =
    ["opt-125m-sim", "opt-350m-sim", "opt-1.3b-sim", "opt-2.7b-sim", "opt-6.7b-sim"];

fn cache_path() -> PathBuf {
    std::env::var("MASE_CACHE")
        .map(PathBuf::from)
        .unwrap_or_else(|_| Session::default_dir().join("eval_cache.json"))
}

fn main() {
    common::banner("Fig 6", "OPT sizes x 6 tasks: MP MXInt vs MP int (QAT small / PTQ large)");
    let session = common::session();
    // Default to the OPT sizes whose 6-task weights are pretrained;
    // MASE_FIG6_MODELS=all sweeps all five (trains the big ones on
    // demand, ~25 extra minutes on a single core).
    let sel = std::env::var("MASE_FIG6_MODELS")
        .unwrap_or_else(|_| "opt-125m-sim,opt-350m-sim,opt-1.3b-sim".into());
    let models: Vec<String> = OPTS
        .iter()
        .copied()
        .filter(|m| sel == "all" || sel.split(',').any(|s| s == *m))
        .filter(|m| common::classifier_names(&session).iter().any(|n| n == m))
        .map(str::to_string)
        .collect();

    let cfg = SweepConfig {
        models,
        tasks: Task::ALL.to_vec(),
        fmts: vec![FormatKind::MxInt, FormatKind::Int],
        trials: common::trials(),
        eval_batches: common::eval_batches_n(),
        pretrain_steps: common::env_usize("MASE_PRETRAIN_STEPS", 220),
        // QAT where the model ships the artifacts (paper: QAT small / PTQ large)
        qat_steps: 2,
        cache_path: Some(cache_path()),
        ..Default::default()
    };
    let report = run_sweep(&session, &cfg).expect("sweep failed");
    if let Some(note) = &report.load_note {
        println!("eval cache: {note}");
    }

    // pivot the (model, task, fmt) rows into the paper's per-(model, task)
    // comparison, with the FP32 reference computed once per pair
    let mut t = Table::new(vec![
        "model", "task", "fp32", "MPMXInt_acc", "MPMXInt_bits", "MPint_acc", "MPint_bits", "mode",
        "hit%",
    ]);
    let mut d_bits = 0.0f64;
    let mut d_rows = 0usize;
    for pair in report.rows.chunks(2) {
        let [mx, ib] = pair else { continue };
        assert_eq!(mx.item.fmt, FormatKind::MxInt);
        assert_eq!(ib.item.fmt, FormatKind::Int);
        let meta = session.manifest.model(&mx.item.model).unwrap().clone();
        let w = common::weights(&session, &meta, Some(mx.item.task));
        let eval = common::eval_set(&meta, mx.item.task);
        let (ev, profile) = common::evaluator_for(&session, &meta, &w, &eval);
        let fp32 = ev
            .accuracy(&QuantSolution::uniform(FormatKind::Fp32, 32.0, &meta, &profile))
            .unwrap()
            .accuracy();
        d_bits += ib.cell.avg_bits - mx.cell.avg_bits;
        d_rows += 1;
        let pair_hits = mx.cache.hits + ib.cache.hits;
        let pair_lookups = pair_hits + mx.cache.misses + ib.cache.misses;
        t.row(vec![
            mx.item.model.clone(),
            mx.item.task.name().to_string(),
            format!("{fp32:.3}"),
            format!("{:.3}", mx.cell.accuracy),
            format!("{:.2}", mx.cell.avg_bits),
            format!("{:.3}", ib.cell.accuracy),
            format!("{:.2}", ib.cell.avg_bits),
            mx.cell.mode.clone(),
            format!("{:.0}", 100.0 * pair_hits as f64 / pair_lookups.max(1) as f64),
        ]);
    }
    println!("{}", t.render());
    println!(
        "paper shape: MP MXInt smaller avg bitwidths than MP int by ~0.5 bit at\n\
         better accuracy. measured avg bit gap (MPint - MPMXInt): {:+.2} bits",
        d_bits / d_rows.max(1) as f64
    );
    println!(
        "eval cache: {} entries loaded, {} stored, {} evaluations paid, {} memoized ({:.0}% hit rate)",
        report.loaded_entries,
        report.saved_entries,
        report.totals.misses,
        report.totals.hits,
        report.hit_rate() * 100.0,
    );
    println!(
        "persisted to {} — re-run this bench to see a 100% hit rate (zero re-simulations)",
        cache_path().display()
    );
}
