//! Fig. 4: four search algorithms (Random, NSGA-II, QMC, TPE) exploring
//! resource-constrained mixed-precision MXInt quantization of OPT-125M-sim
//! on sst2-sim, with the SW objective acc + k/b. Reports the incumbent
//! cost over trials and each algorithm's wall-clock, serial (1 thread,
//! batch 1) vs parallel (batched ask/tell over the worker pool).
//!
//! Three passes per algorithm: serial and parallel both run against
//! run-local COLD caches (so the speedup column measures threading, not
//! cache warmth), then a third pass reuses ONE persistent cache scope
//! (MASE_CACHE, default `<artifacts>/eval_cache.json`): configurations
//! proposed by several algorithms are simulated once, and a re-run of the
//! bench starts from the warm cache — the per-algorithm hit rates and
//! `cached_s` column make both effects visible.

#[path = "common.rs"]
mod common;

use mase::coordinator::Session;
use mase::data::Task;
use mase::formats::FormatKind;
use mase::passes::{eval_scope, run_search, run_search_cached, Objective, SearchConfig};
use mase::search::{best_curve, Algorithm, CacheStore};
use mase::util::pool::threads_from_env;
use mase::util::{Stopwatch, Table};
use std::path::PathBuf;

fn cache_path() -> PathBuf {
    std::env::var("MASE_CACHE")
        .map(PathBuf::from)
        .unwrap_or_else(|_| Session::default_dir().join("eval_cache.json"))
}

fn main() {
    common::banner("Fig 4", "search algorithms on opt-125m-sim / sst2-sim");
    let session = common::session();
    let meta = session.manifest.model("opt-125m-sim").unwrap().clone();
    let w = common::weights(&session, &meta, Some(Task::Sst2));
    let eval = common::eval_set(&meta, Task::Sst2);
    let (mut ev, profile) = common::evaluator_for(&session, &meta, &w, &eval);
    ev.objective = Objective::sw_only();

    let trials = common::trials().max(32);
    let workers = threads_from_env(0);

    // one scope for all four algorithms: same model/task/format/objective
    let store = CacheStore::open(&cache_path());
    if let Some(note) = store.load_note() {
        println!("eval cache: {note}");
    }
    let scope = eval_scope(
        &meta.name,
        Task::Sst2,
        FormatKind::MxInt,
        0,
        0.002,
        common::eval_batches_n(),
        common::env_usize("MASE_PRETRAIN_STEPS", 220),
        "sw",
        mase::runtime::BackendKind::Pjrt,
        None,
    );
    let cache = store.cache(&scope);

    let mut curves = Vec::new();
    let mut times = Vec::new();
    for alg in Algorithm::ALL {
        // serial reference: one proposal per round, evaluated in-line,
        // run-local cold cache
        let sw = Stopwatch::start();
        let serial = run_search(
            &ev,
            &profile,
            Task::Sst2,
            &SearchConfig { algorithm: alg, trials, threads: 1, batch: 1, ..Default::default() },
        )
        .expect("serial search failed");
        let serial_s = sw.secs();

        // parallel batched driver (default config: batch 8, auto workers),
        // ALSO against a run-local cold cache: the speedup column must
        // measure threading, not how warm the shared store happens to be
        let sw = Stopwatch::start();
        let outcome = run_search(
            &ev,
            &profile,
            Task::Sst2,
            &SearchConfig { algorithm: alg, trials, ..Default::default() },
        )
        .expect("parallel search failed");
        let parallel_s = sw.secs();

        // third pass through the shared persistent scope: identical
        // history (values are pure functions of x), but evaluations are
        // reused across algorithms and across bench re-runs — this is
        // the pass the hit-rate columns report
        let sw = Stopwatch::start();
        let shared = run_search_cached(
            &ev,
            &profile,
            Task::Sst2,
            &SearchConfig { algorithm: alg, trials, ..Default::default() },
            &cache,
        )
        .expect("cached search failed");
        let cached_s = sw.secs();

        times.push((
            alg,
            serial_s,
            parallel_s,
            cached_s,
            shared.best_eval.accuracy,
            shared.best_eval.avg_bits,
            shared.cache,
        ));
        let _ = serial; // serial history differs only by batch cadence
        curves.push((alg, best_curve(&outcome.history)));
    }
    store.save().expect("cache flush failed");

    let mut t = Table::new(vec!["trial", "random", "nsga2", "qmc", "tpe"]);
    for m in [1usize, 2, 4, 8, 12, 16, 24, 32, 48, 64].iter().filter(|&&m| m <= trials) {
        let get = |a: Algorithm| {
            curves.iter().find(|(x, _)| *x == a).map(|(_, c)| format!("{:.4}", c[m - 1])).unwrap()
        };
        t.row(vec![
            m.to_string(),
            get(Algorithm::Random),
            get(Algorithm::NsgaII),
            get(Algorithm::Qmc),
            get(Algorithm::Tpe),
        ]);
    }
    println!("incumbent objective (acc + k/b, maximized):\n{}", t.render());

    let mut t2 = Table::new(vec![
        "algorithm".to_string(),
        "serial_s".to_string(),
        format!("parallel_s ({workers} thr)"),
        "speedup".to_string(),
        "cached_s".to_string(),
        "best_acc".to_string(),
        "best_avg_bits".to_string(),
        "evals".to_string(),
        "hits".to_string(),
        "hit%".to_string(),
    ]);
    for (a, s1, sp, sc, acc, bits, cs) in &times {
        t2.row(vec![
            a.name().to_string(),
            format!("{s1:.1}"),
            format!("{sp:.1}"),
            format!("{:.2}x", s1 / sp),
            format!("{sc:.1}"),
            format!("{acc:.4}"),
            format!("{bits:.2}"),
            cs.misses.to_string(),
            cs.hits.to_string(),
            format!("{:.0}", cs.hit_rate() * 100.0),
        ]);
    }
    println!("{}", t2.render());
    let total = cache.stats();
    println!(
        "shared eval cache ({} entries, {} loaded from disk): later algorithms reuse \
         earlier algorithms' simulations; a re-run of this bench is all hits. \
         flushed to {}",
        total.entries,
        store.loaded_entries(),
        cache_path().display()
    );

    let last = |a: Algorithm| *curves.iter().find(|(x, _)| *x == a).unwrap().1.last().unwrap();
    let tpe_best = Algorithm::ALL.iter().all(|&a| last(Algorithm::Tpe) >= last(a) - 1e-9);
    println!("shape check: TPE ends best-or-tied: {tpe_best} (paper: TPE most efficient)");
}
