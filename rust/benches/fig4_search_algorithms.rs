//! Fig. 4: four search algorithms (Random, NSGA-II, QMC, TPE) exploring
//! resource-constrained mixed-precision MXInt quantization of OPT-125M-sim
//! on sst2-sim, with the SW objective acc + k/b. Reports the incumbent
//! cost over trials and each algorithm's wall-clock.

#[path = "common.rs"]
mod common;

use mase::data::Task;
use mase::passes::{run_search, Objective, SearchConfig};
use mase::search::{best_curve, Algorithm};
use mase::util::{Stopwatch, Table};

fn main() {
    common::banner("Fig 4", "search algorithms on opt-125m-sim / sst2-sim");
    let session = common::session();
    let meta = session.manifest.model("opt-125m-sim").unwrap().clone();
    let w = common::weights(&session, &meta, Some(Task::Sst2));
    let eval = common::eval_set(&meta, Task::Sst2);
    let (mut ev, profile) = common::evaluator_for(&session, &meta, &w, &eval);
    ev.objective = Objective::sw_only();

    let trials = common::trials().max(32);
    let mut curves = Vec::new();
    let mut times = Vec::new();
    for alg in Algorithm::ALL {
        let sw = Stopwatch::start();
        let outcome = run_search(
            &ev,
            &profile,
            Task::Sst2,
            &SearchConfig { algorithm: alg, trials, ..Default::default() },
        )
        .expect("search failed");
        times.push((alg, sw.secs(), outcome.best_eval.accuracy, outcome.best_eval.avg_bits));
        curves.push((alg, best_curve(&outcome.history)));
    }

    let mut t = Table::new(vec!["trial", "random", "nsga2", "qmc", "tpe"]);
    for m in [1usize, 2, 4, 8, 12, 16, 24, 32, 48, 64].iter().filter(|&&m| m <= trials) {
        let get = |a: Algorithm| {
            curves.iter().find(|(x, _)| *x == a).map(|(_, c)| format!("{:.4}", c[m - 1])).unwrap()
        };
        t.row(vec![
            m.to_string(),
            get(Algorithm::Random),
            get(Algorithm::NsgaII),
            get(Algorithm::Qmc),
            get(Algorithm::Tpe),
        ]);
    }
    println!("incumbent objective (acc + k/b, maximized):\n{}", t.render());

    let mut t2 = Table::new(vec!["algorithm", "search_time_s", "best_acc", "best_avg_bits"]);
    for (a, s, acc, bits) in &times {
        t2.row(vec![a.name().to_string(), format!("{s:.1}"), format!("{acc:.4}"), format!("{bits:.2}")]);
    }
    println!("{}", t2.render());

    let last = |a: Algorithm| *curves.iter().find(|(x, _)| *x == a).unwrap().1.last().unwrap();
    let tpe_best = Algorithm::ALL.iter().all(|&a| last(Algorithm::Tpe) >= last(a) - 1e-9);
    println!("shape check: TPE ends best-or-tied: {tpe_best} (paper: TPE most efficient)");
}
