//! Fig. 4: four search algorithms (Random, NSGA-II, QMC, TPE) exploring
//! resource-constrained mixed-precision MXInt quantization of OPT-125M-sim
//! on sst2-sim, with the SW objective acc + k/b. Reports the incumbent
//! cost over trials and each algorithm's wall-clock, serial (1 thread,
//! batch 1) vs parallel (batched ask/tell over the worker pool).

#[path = "common.rs"]
mod common;

use mase::data::Task;
use mase::passes::{run_search, Objective, SearchConfig};
use mase::search::{best_curve, Algorithm};
use mase::util::pool::threads_from_env;
use mase::util::{Stopwatch, Table};

fn main() {
    common::banner("Fig 4", "search algorithms on opt-125m-sim / sst2-sim");
    let session = common::session();
    let meta = session.manifest.model("opt-125m-sim").unwrap().clone();
    let w = common::weights(&session, &meta, Some(Task::Sst2));
    let eval = common::eval_set(&meta, Task::Sst2);
    let (mut ev, profile) = common::evaluator_for(&session, &meta, &w, &eval);
    ev.objective = Objective::sw_only();

    let trials = common::trials().max(32);
    let workers = threads_from_env(0);
    let mut curves = Vec::new();
    let mut times = Vec::new();
    for alg in Algorithm::ALL {
        // serial reference: one proposal per round, evaluated in-line
        let sw = Stopwatch::start();
        let serial = run_search(
            &ev,
            &profile,
            Task::Sst2,
            &SearchConfig { algorithm: alg, trials, threads: 1, batch: 1, ..Default::default() },
        )
        .expect("serial search failed");
        let serial_s = sw.secs();

        // parallel batched driver (the default config: batch 8, auto workers)
        let sw = Stopwatch::start();
        let outcome = run_search(
            &ev,
            &profile,
            Task::Sst2,
            &SearchConfig { algorithm: alg, trials, ..Default::default() },
        )
        .expect("parallel search failed");
        let parallel_s = sw.secs();

        times.push((
            alg,
            serial_s,
            parallel_s,
            outcome.best_eval.accuracy,
            outcome.best_eval.avg_bits,
        ));
        let _ = serial; // serial history differs only by batch cadence
        curves.push((alg, best_curve(&outcome.history)));
    }

    let mut t = Table::new(vec!["trial", "random", "nsga2", "qmc", "tpe"]);
    for m in [1usize, 2, 4, 8, 12, 16, 24, 32, 48, 64].iter().filter(|&&m| m <= trials) {
        let get = |a: Algorithm| {
            curves.iter().find(|(x, _)| *x == a).map(|(_, c)| format!("{:.4}", c[m - 1])).unwrap()
        };
        t.row(vec![
            m.to_string(),
            get(Algorithm::Random),
            get(Algorithm::NsgaII),
            get(Algorithm::Qmc),
            get(Algorithm::Tpe),
        ]);
    }
    println!("incumbent objective (acc + k/b, maximized):\n{}", t.render());

    let mut t2 = Table::new(vec![
        "algorithm".to_string(),
        "serial_s".to_string(),
        format!("parallel_s ({workers} thr)"),
        "speedup".to_string(),
        "best_acc".to_string(),
        "best_avg_bits".to_string(),
    ]);
    for (a, s1, sp, acc, bits) in &times {
        t2.row(vec![
            a.name().to_string(),
            format!("{s1:.1}"),
            format!("{sp:.1}"),
            format!("{:.2}x", s1 / sp),
            format!("{acc:.4}"),
            format!("{bits:.2}"),
        ]);
    }
    println!("{}", t2.render());

    let last = |a: Algorithm| *curves.iter().find(|(x, _)| *x == a).unwrap().1.last().unwrap();
    let tpe_best = Algorithm::ALL.iter().all(|&a| last(Algorithm::Tpe) >= last(a) - 1e-9);
    println!("shape check: TPE ends best-or-tied: {tpe_best} (paper: TPE most efficient)");
}
