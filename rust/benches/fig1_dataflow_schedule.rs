//! Fig. 1e/1f: non-dataflow (sequential) vs dataflow (pipelined)
//! schedules of a transformer block, measured in the cycle-approximate
//! simulator. The dataflow schedule overlaps inferences and wins on
//! throughput; the sequential schedule has the lower single-inference
//! latency-per-resource but serializes tasks.

#[path = "common.rs"]
mod common;

use mase::formats::FormatKind;
use mase::frontend::build_graph;
use mase::hw::Device;
use mase::passes::{parallelize, ProfileData, QuantSolution};
use mase::sim::{nodes_from_graph, simulate, SimConfig};
use mase::util::Table;

fn main() {
    common::banner("Fig 1e/1f", "sequential vs dataflow schedule (simulator)");
    let session = common::session();
    let meta = session.manifest.model("opt-1.3b-sim").unwrap().clone();
    let profile = ProfileData::uniform(&meta, 4.0);
    let mut g = build_graph(&meta);
    QuantSolution::uniform(FormatKind::MxInt, 5.0, &meta, &profile).apply(&mut g);
    let dp = parallelize(&mut g, &Device::u250(), 0.3);
    let nodes = nodes_from_graph(&g);

    let mut t = Table::new(vec![
        "schedule",
        "inferences",
        "cycles",
        "cycles/inf",
        "throughput@250MHz",
        "speedup",
    ]);
    let mut seq_cpi = 0.0;
    for (name, sequential) in [("non-dataflow (Fig 1e)", true), ("dataflow (Fig 1f)", false)] {
        let inferences = 8;
        let r = simulate(&nodes, &SimConfig { inferences, fifo_depth: 4, sequential });
        let cpi = r.cycles as f64 / inferences as f64;
        if sequential {
            seq_cpi = cpi;
        }
        t.row(vec![
            name.to_string(),
            inferences.to_string(),
            r.cycles.to_string(),
            format!("{cpi:.0}"),
            format!("{:.0}/s", 250e6 / cpi),
            format!("{:.2}x", seq_cpi / cpi),
        ]);
    }
    println!("{}", t.render());
    println!("regression-model steady state: {:.0} inf/s", dp.throughput);
    println!("expected shape: dataflow >> sequential throughput (task-level pipelining)");
}
