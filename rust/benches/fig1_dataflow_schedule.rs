//! Fig. 1e/1f: non-dataflow (sequential) vs dataflow (pipelined)
//! schedules of a transformer block, measured in the cycle-approximate
//! simulator. The dataflow schedule overlaps inferences and wins on
//! throughput; the sequential schedule has the lower single-inference
//! latency-per-resource but serializes tasks.
//!
//! Since PR 5 the simulator streams bit-packed tiles over finite-width
//! channels (beats = ceil(tile_bits / channel_bits)), so this bench also
//! reports the dataflow schedule at the device's channel width and at a
//! starved fabric, plus the per-node stall table with transfer waits
//! credited to the channels that caused them.

#[path = "common.rs"]
mod common;

use mase::formats::FormatKind;
use mase::frontend::build_graph;
use mase::hw::{Device, DEFAULT_CHANNEL_BITS};
use mase::passes::{parallelize, ProfileData, QuantSolution};
use mase::sim::{nodes_from_graph, simulate, SimConfig};
use mase::util::Table;

fn main() {
    common::banner("Fig 1e/1f", "sequential vs dataflow schedule (simulator)");
    let session = common::session();
    let meta = session.manifest.model("opt-1.3b-sim").unwrap().clone();
    let profile = ProfileData::uniform(&meta, 4.0);
    let mut g = build_graph(&meta);
    QuantSolution::uniform(FormatKind::MxInt, 5.0, &meta, &profile).apply(&mut g);
    let dp = parallelize(&mut g, &Device::u250(), 0.3);
    let nodes = nodes_from_graph(&g);

    let mut t = Table::new(vec![
        "schedule",
        "inferences",
        "cycles",
        "cycles/inf",
        "throughput@250MHz",
        "speedup",
    ]);
    let mut seq_cpi = 0.0;
    let starved = 32;
    let runs = [
        ("non-dataflow (Fig 1e)", true, SimConfig::UNBOUNDED),
        ("dataflow (Fig 1f)", false, SimConfig::UNBOUNDED),
        ("dataflow, 512b channels", false, DEFAULT_CHANNEL_BITS),
        ("dataflow, 32b channels", false, starved),
    ];
    let inferences = 8;
    let mut starved_report = None;
    for (name, sequential, channel_bits) in runs {
        let r = simulate(
            &nodes,
            &SimConfig { inferences, fifo_depth: 4, sequential, channel_bits },
        );
        let cpi = r.cycles as f64 / inferences as f64;
        if sequential {
            seq_cpi = cpi;
        }
        t.row(vec![
            name.to_string(),
            inferences.to_string(),
            r.cycles.to_string(),
            format!("{cpi:.0}"),
            format!("{:.0}/s", 250e6 / cpi),
            format!("{:.2}x", seq_cpi / cpi),
        ]);
        if channel_bits == starved {
            starved_report = Some(r);
        }
    }
    println!("{}", t.render());
    println!("regression-model steady state: {:.0} inf/s", dp.throughput);
    println!("expected shape: dataflow >> sequential throughput (task-level pipelining);");
    println!("a starved fabric serializes packed-word transfers and closes the gap.");

    // Per-node stall table on the starved fabric: transfer waits belong
    // to the channels (EdgeReport), so the node column stays truthful.
    let r = starved_report.unwrap();
    common::banner("Fig 1f'", "per-node stalls + channel transfer waits (32b fabric)");
    let mut ts = Table::new(vec!["node", "busy", "stalled", "util%"]);
    let mut rows: Vec<usize> = (0..nodes.len()).collect();
    rows.sort_by_key(|&i| std::cmp::Reverse(r.busy[i] + r.stalled[i]));
    for &i in rows.iter().take(8) {
        ts.row(vec![
            nodes[i].name.clone(),
            r.busy[i].to_string(),
            r.stalled[i].to_string(),
            format!("{:.0}", 100.0 * r.busy[i] as f64 / r.cycles as f64),
        ]);
    }
    println!("{}", ts.render());

    let mut te = Table::new(vec!["channel", "tile_bits", "beats/tile", "xfer_cycles", "xfer_stalled"]);
    let mut edges: Vec<&mase::sim::EdgeReport> = r.edges.iter().collect();
    edges.sort_by_key(|e| std::cmp::Reverse(e.transfer_stalled));
    for e in edges.iter().take(8) {
        te.row(vec![
            format!("{} -> {}", nodes[e.producer].name, nodes[e.consumer].name),
            e.tile_bits.to_string(),
            e.beats_per_tile.to_string(),
            e.transfer_cycles.to_string(),
            e.transfer_stalled.to_string(),
        ]);
    }
    println!("{}", te.render());
    println!("stall attribution: consumer waits behind a streaming channel are charged");
    println!("to the channel (xfer_stalled), never to the consumer's stall column.");

    // PR 8: the same accounting folded into the obs registry and printed
    // through the shared TraceSummary renderer — record_bench.sh embeds
    // this block (top-8 nodes/channels, as above) in BENCH_RESULTS.md.
    let reg = mase::obs::Registry::new();
    reg.counter("sim", "cycles", r.cycles);
    for &i in rows.iter().take(8) {
        let path = format!("sim/node/{}", nodes[i].name);
        reg.counter(&path, "busy_cycles", r.busy[i]);
        reg.counter(&path, "stalled_cycles", r.stalled[i]);
    }
    for e in edges.iter().take(8) {
        let path = format!(
            "sim/xfer/{}->{}#{}",
            nodes[e.producer].name, nodes[e.consumer].name, e.slot
        );
        reg.counter(&path, "transfer_stalled", e.transfer_stalled);
    }
    print!("{}", mase::obs::TraceSummary::from_registry(&reg).render());
}
