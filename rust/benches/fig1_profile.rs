//! Fig. 1a: per-tensor activation variance across transformer layers of
//! the LLaMA simulant (the motivation plot: variance heterogeneity and
//! growth with depth), and Fig. 1b: the mixed-precision bitwidth
//! distribution the TPE search assigns afterwards.

#[path = "common.rs"]
mod common;

use mase::data::Task;
use mase::passes::{run_search, SearchConfig};
use mase::util::Table;

fn main() {
    common::banner("Fig 1a", "activation/weight variance per tensor (llama-sim)");
    let session = common::session();
    let meta = session.manifest.model("llama-sim").unwrap().clone();
    let w = common::weights(&session, &meta, None);
    let eval = common::lm_eval_set(&meta);
    let (ev, profile) = common::evaluator_for(&session, &meta, &w, &eval);

    let mut t = Table::new(vec!["qtensor", "variance", "absmax"]);
    for i in 0..profile.names.len() {
        t.row(vec![
            profile.names[i].clone(),
            format!("{:.3e}", profile.variance[i]),
            format!("{:.3}", profile.absmax[i]),
        ]);
    }
    println!("{}", t.render());
    println!(
        "variance spread across tensors: {:.0}x (paper reports up to 7624x on real LLaMA)",
        profile.variance_spread()
    );

    // Fig. 1b: bitwidth distribution after mixed-precision search
    common::banner("Fig 1b", "per-tensor MXInt mantissa widths after TPE search");
    let outcome = run_search(
        &ev,
        &profile,
        Task::Sst2, // LM ignores labels; eval batches are corpus streams
        &SearchConfig { trials: common::trials(), ..Default::default() },
    )
    .expect("search failed");
    let mut hist = [0usize; 9];
    let mut t2 = Table::new(vec!["qtensor", "mantissa_bits", "avg_bitwidth"]);
    for (i, name) in profile.names.iter().enumerate() {
        let b = outcome.best.bits[i];
        hist[(b as usize).min(8)] += 1;
        t2.row(vec![
            name.clone(),
            format!("{b:.0}"),
            format!("{:.2}", mase::formats::Precision::new(b, 0.0).average_bitwidth(mase::formats::FormatKind::MxInt)),
        ]);
    }
    println!("{}", t2.render());
    print!("bitwidth histogram (2..8 bits): ");
    for (b, h) in hist.iter().enumerate().take(9).skip(2) {
        print!("{b}:{h} ");
    }
    println!(
        "\nmodel avg bits: {:.2} (paper: ~4-bit average mantissas)",
        outcome.best_eval.avg_bits
    );
    println!(
        "ppl fp32-ish check: quantized ppl {:.2}",
        outcome.best_eval.perplexity
    );
}
