//! Fig. 7: the headline co-design comparison on sst2-sim across the ten
//! LLM simulants: int8 / MP int / MP MXInt / MP MXInt (SW-only) / MXInt8.
//! Reports area efficiency relative to int8 and Δaccuracy vs FP32 — the
//! paper's claim: MP MXInt reaches ~int8 area efficiency with ~FP32
//! accuracy (on average +24% Δacc vs int8's quantization loss), MP int is
//! infeasible (accuracy collapse), MXInt8 pays ~1.3x area for no accuracy
//! benefit over MP MXInt.

#[path = "common.rs"]
mod common;

use mase::data::Task;
use mase::formats::FormatKind;
use mase::passes::{run_search, Objective, QuantSolution, SearchConfig};
use mase::util::Table;

fn main() {
    common::banner("Fig 7", "int8 | MP int | MP MXInt | MP MXInt(SW) | MXInt8 on sst2-sim");
    let session = common::session();
    let trials = common::trials();

    let mut t = Table::new(vec![
        "model", "fp32", "int8_Δ", "MPint_Δ", "MPMXInt_Δ", "SWonly_Δ", "MXInt8_Δ",
        "MPint_AE", "MPMXInt_AE", "SWonly_AE", "MXInt8_AE",
    ]);
    let names = common::classifier_names(&session);
    let mut avg = vec![0.0f64; 9];
    for name in &names {
        let meta = session.manifest.model(name).unwrap().clone();
        let w = common::weights(&session, &meta, Some(Task::Sst2));
        let eval = common::eval_set(&meta, Task::Sst2);
        let (ev, profile) = common::evaluator_for(&session, &meta, &w, &eval);

        let fp32 = ev
            .accuracy(&QuantSolution::uniform(FormatKind::Fp32, 32.0, &meta, &profile))
            .unwrap()
            .accuracy();
        let int8 = ev.evaluate(&QuantSolution::uniform(FormatKind::Int, 8.0, &meta, &profile)).unwrap();
        let mxint8 =
            ev.evaluate(&QuantSolution::uniform(FormatKind::MxInt, 7.0, &meta, &profile)).unwrap();

        // MP int (hardware-aware search over width+frac)
        let mp_int = run_search(
            &ev,
            &profile,
            Task::Sst2,
            &SearchConfig { fmt: FormatKind::Int, trials, ..Default::default() },
        )
        .unwrap()
        .best_eval;
        // MP MXInt (hardware-aware)
        let mp_mx_outcome = run_search(
            &ev,
            &profile,
            Task::Sst2,
            &SearchConfig { trials, ..Default::default() },
        )
        .unwrap();
        let mp_mx = mp_mx_outcome.best_eval.clone();

        // PR 5 packed-word streaming check (first model): through the
        // same finite-width fabric, the MP MXInt winner's narrower
        // packed tiles must simulate at least as fast as uniform int8.
        if name == &names[0] {
            let d = mase::hw::Device::u250();
            let (_, _, g_mx) = ev.hardware(&mp_mx_outcome.best).unwrap();
            let (_, _, g_i8) = ev
                .hardware(&QuantSolution::uniform(FormatKind::Int, 8.0, &meta, &profile))
                .unwrap();
            let w = d.channel_bits;
            let s_mx = mase::sim::simulated_throughput_at(&g_mx, d.clock_hz, 4, w);
            let s_i8 = mase::sim::simulated_throughput_at(&g_i8, d.clock_hz, 4, w);
            println!(
                "packed-stream sim @{w}b channels ({name}): MP MXInt {s_mx:.0} inf/s vs int8 {s_i8:.0} inf/s ({:.2}x)",
                s_mx / s_i8.max(1e-12)
            );
        }
        // MP MXInt SW-only: search ignores hardware metrics
        let mut ev_sw = mase::passes::Evaluator::new(
            session.pjrt_backend().expect("PJRT session"),
            &meta,
            &w,
            &eval,
        )
        .expect("evaluator");
        ev_sw.objective = Objective::sw_only();
        let sw_only = run_search(
            &ev_sw,
            &profile,
            Task::Sst2,
            &SearchConfig { trials, ..Default::default() },
        )
        .unwrap()
        .best_eval;

        let ae = |r: &mase::passes::EvalResult| {
            r.design.area_efficiency() / int8.design.area_efficiency()
        };
        let row = [
            int8.accuracy - fp32,
            mp_int.accuracy - fp32,
            mp_mx.accuracy - fp32,
            sw_only.accuracy - fp32,
            mxint8.accuracy - fp32,
            ae(&mp_int),
            ae(&mp_mx),
            ae(&sw_only),
            ae(&mxint8),
        ];
        for (a, r) in avg.iter_mut().zip(row.iter()) {
            *a += r;
        }
        t.row(vec![
            name.clone(),
            format!("{fp32:.3}"),
            format!("{:+.3}", row[0]),
            format!("{:+.3}", row[1]),
            format!("{:+.3}", row[2]),
            format!("{:+.3}", row[3]),
            format!("{:+.3}", row[4]),
            format!("{:.2}x", row[5]),
            format!("{:.2}x", row[6]),
            format!("{:.2}x", row[7]),
            format!("{:.2}x", row[8]),
        ]);
    }
    let n = names.len() as f64;
    t.row(vec![
        "AVERAGE".into(),
        "".into(),
        format!("{:+.3}", avg[0] / n),
        format!("{:+.3}", avg[1] / n),
        format!("{:+.3}", avg[2] / n),
        format!("{:+.3}", avg[3] / n),
        format!("{:+.3}", avg[4] / n),
        format!("{:.2}x", avg[5] / n),
        format!("{:.2}x", avg[6] / n),
        format!("{:.2}x", avg[7] / n),
        format!("{:.2}x", avg[8] / n),
    ]);
    println!("{}", t.render());
    println!("paper headline: MP MXInt Δacc beats int8 by ~24% at ~0.97x its area");
    println!("efficiency; MP MXInt ~1.11x area efficiency of SW-only; MP int loses accuracy.");
    println!(
        "measured: Δacc(MP MXInt - int8) = {:+.1}%  |  AE(MP MXInt) = {:.2}x int8  |  AE vs SW-only = {:.2}x",
        100.0 * (avg[2] - avg[0]) / n,
        avg[6] / n,
        (avg[6] / n) / (avg[7] / n).max(1e-12),
    );
}
