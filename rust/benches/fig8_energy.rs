//! Fig. 8: energy efficiency of MP MXInt vs uniform MXInt4 / MXInt6
//! designs across the ten simulants on sst2-sim. The paper's shape:
//! MP MXInt's energy efficiency sits between MXInt4 and MXInt6 (its
//! mantissas average ~4 bits) while its accuracy beats MXInt6 by ~1% and
//! MXInt4 by ~8%.

#[path = "common.rs"]
mod common;

use mase::data::Task;
use mase::formats::FormatKind;
use mase::hw::energy::energy_efficiency;
use mase::hw::Device;
use mase::passes::{run_search, QuantSolution, SearchConfig};
use mase::util::Table;

fn main() {
    common::banner("Fig 8", "energy efficiency: MXInt4 | MP MXInt | MXInt6 on sst2-sim");
    let session = common::session();
    let device = Device::u250();
    let trials = common::trials();

    let mut t = Table::new(vec![
        "model", "mx4_acc", "mp_acc", "mx6_acc", "mx4_inf/J", "mp_inf/J", "mx6_inf/J",
    ]);
    let names = common::classifier_names(&session);
    let mut acc_sum = [0.0f64; 3];
    let mut between = 0usize;
    for name in &names {
        let meta = session.manifest.model(name).unwrap().clone();
        let w = common::weights(&session, &meta, Some(Task::Sst2));
        let eval = common::eval_set(&meta, Task::Sst2);
        let (ev, profile) = common::evaluator_for(&session, &meta, &w, &eval);

        let run_uniform = |bits: f32| {
            let sol = QuantSolution::uniform(FormatKind::MxInt, bits, &meta, &profile);
            let acc = ev.accuracy(&sol).unwrap().accuracy();
            let (dp, _b, g) = ev.hardware(&sol).unwrap();
            let e = energy_efficiency(&g, FormatKind::MxInt, &device, dp.offchip_bits);
            (acc, e)
        };
        let (a4, e4) = run_uniform(3.0); // 4-bit elements: m=3 (+sign)
        let (a6, e6) = run_uniform(5.0); // 6-bit elements: m=5
        let mp = run_search(&ev, &profile, Task::Sst2, &SearchConfig { trials, ..Default::default() })
            .unwrap();
        let (dp, _b, g) = ev.hardware(&mp.best).unwrap();
        let emp = energy_efficiency(&g, FormatKind::MxInt, &device, dp.offchip_bits);
        let amp = mp.best_eval.accuracy;

        acc_sum[0] += a4;
        acc_sum[1] += amp;
        acc_sum[2] += a6;
        if emp >= e6.min(e4) && emp <= e6.max(e4) {
            between += 1;
        }
        t.row(vec![
            name.clone(),
            format!("{a4:.3}"),
            format!("{amp:.3}"),
            format!("{a6:.3}"),
            format!("{e4:.2e}"),
            format!("{emp:.2e}"),
            format!("{e6:.2e}"),
        ]);
    }
    let n = names.len() as f64;
    println!("{}", t.render());
    println!(
        "measured: MP acc beats MXInt6 by {:+.1}% and MXInt4 by {:+.1}% (paper: +1% / +8%);\n\
         energy efficiency between MXInt4 and MXInt6 on {between}/{} models",
        100.0 * (acc_sum[1] - acc_sum[2]) / n,
        100.0 * (acc_sum[1] - acc_sum[0]) / n,
        names.len()
    );
}
