//! Micro-benchmarks for the §Perf optimization pass: hot-path timings of
//! each layer's building blocks — Rust quantizers, IR clone+parallelize,
//! the dataflow simulator, PJRT eval execution (with and without the
//! executable cache), and TPE ask/tell overhead.

#[path = "common.rs"]
mod common;

use mase::data::Task;
use mase::formats::{self, FormatKind, Precision};
use mase::frontend::build_graph;
use mase::hw::Device;
use mase::passes::{parallelize, ProfileData, QuantSolution};
use mase::search::{Algorithm, Space, Trial};
use mase::util::{rng::Rng, Stopwatch, Table};

fn time<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let sw = Stopwatch::start();
    for _ in 0..iters {
        f();
    }
    sw.secs() / iters as f64
}

fn main() {
    common::banner("microbench", "hot-path timings for EXPERIMENTS.md §Perf");
    let mut t = Table::new(vec!["item", "per-op", "throughput"]);

    // L3: quantizers over a 256x256 tensor
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..256 * 256).map(|_| rng.normal() as f32).collect();
    for fmt in [FormatKind::MxInt, FormatKind::Bmf, FormatKind::Bl, FormatKind::Int] {
        let mut buf = x.clone();
        let dt = time(20, || {
            buf.copy_from_slice(&x);
            formats::quantize_2d(fmt, &mut buf, 256, 256, Precision::new(5.0, 2.0));
        });
        t.row(vec![
            format!("quantize_2d {} 256x256", fmt.name()),
            format!("{:.3}ms", dt * 1e3),
            format!("{:.0} Melem/s", 256.0 * 256.0 / dt / 1e6),
        ]);
    }

    // L3: IR clone + parallelize (the per-trial hardware evaluation)
    let session = common::session();
    let meta = session.manifest.model("opt-6.7b-sim").unwrap().clone();
    let profile = ProfileData::uniform(&meta, 4.0);
    let mut g0 = build_graph(&meta);
    QuantSolution::uniform(FormatKind::MxInt, 5.0, &meta, &profile).apply(&mut g0);
    let dt = time(50, || {
        let mut g = g0.clone();
        parallelize(&mut g, &Device::u250(), 0.4);
    });
    t.row(vec![
        "clone+parallelize opt-6.7b-sim".into(),
        format!("{:.3}ms", dt * 1e3),
        format!("{:.0} trials/s", 1.0 / dt),
    ]);

    // L3: dataflow simulator
    let mut g = g0.clone();
    parallelize(&mut g, &Device::u250(), 0.4);
    let nodes = mase::sim::nodes_from_graph(&g);
    let dt = time(5, || {
        mase::sim::simulate(
            &nodes,
            &mase::sim::SimConfig {
                inferences: 4,
                fifo_depth: 4,
                sequential: false,
                channel_bits: mase::hw::DEFAULT_CHANNEL_BITS,
            },
        );
    });
    t.row(vec!["simulate 4 inferences".into(), format!("{:.3}ms", dt * 1e3), String::new()]);

    // Runtime: eval artifact execution (cache warm vs cold compile)
    let meta = session.manifest.model("opt-125m-sim").unwrap().clone();
    let w = common::weights(&session, &meta, Some(Task::Sst2));
    let eval = common::eval_set(&meta, Task::Sst2);
    let (ev, profile) = common::evaluator_for(&session, &meta, &w, &eval);
    let sol = QuantSolution::uniform(FormatKind::MxInt, 5.0, &meta, &profile);
    let c0 = session.pjrt().unwrap().compile_count();
    let sw = Stopwatch::start();
    ev.accuracy(&sol).unwrap();
    let cold = sw.secs();
    let dt = time(5, || {
        ev.accuracy(&sol).unwrap();
    });
    t.row(vec![
        format!("eval 3 batches (cold, {} compiles)", session.pjrt().unwrap().compile_count() - c0),
        format!("{:.1}ms", cold * 1e3),
        String::new(),
    ]);
    t.row(vec![
        "eval 3 batches (warm cache)".into(),
        format!("{:.1}ms", dt * 1e3),
        format!("{:.0} trials/s", 1.0 / dt),
    ]);

    // §Perf A/B: the pre-optimization path re-converted every host buffer
    // (weights vector included) to a literal on every execute call.
    let artifact = meta.artifact("eval_mxint").unwrap().to_string();
    let qcfg = sol.to_qconfig();
    let dt_legacy = time(5, || {
        use mase::runtime::TensorData as TD;
        for b in &eval {
            session
                .pjrt()
                .unwrap()
                .execute(
                    &artifact,
                    &[
                        TD::f32(&w, &[meta.param_size as i64]),
                        TD::i32(&b.tokens, &[b.batch as i64, b.seq as i64]),
                        TD::i32(&b.labels, &[b.batch as i64]),
                        TD::f32(&qcfg, &[meta.num_qtensors() as i64, 2]),
                    ],
                )
                .unwrap();
        }
    });
    t.row(vec![
        "eval 3 batches (legacy per-call copies)".into(),
        format!("{:.1}ms", dt_legacy * 1e3),
        format!("prepared-literal speedup {:.2}x", dt_legacy / dt),
    ]);

    // Search: TPE proposal overhead at 64 observations
    let space = Space::uniform(18, 2.0, 8.0);
    let mut tpe = Algorithm::Tpe.build(space.clone(), 1);
    let mut r2 = Rng::new(2);
    for _ in 0..64 {
        let x = space.sample(&mut r2);
        let v = -x.iter().sum::<f64>();
        tpe.tell(Trial { x, value: v, objectives: vec![] });
    }
    let dt = time(50, || {
        let _ = tpe.ask();
    });
    t.row(vec!["TPE ask() @64 obs, 18 dims".into(), format!("{:.3}ms", dt * 1e3), String::new()]);

    println!("{}", t.render());
}
