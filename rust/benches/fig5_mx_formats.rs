//! Fig. 5: three MX formats (MXInt8 / BMF8 / BL8, block 32, 8-bit shared
//! + 8-bit local) quantizing the ten LLM simulants on sst2-sim. Reports
//! area efficiency relative to the int8 design (bars) and Δaccuracy vs
//! FP32 (curves), per model and averaged.

#[path = "common.rs"]
mod common;

use mase::data::Task;
use mase::formats::FormatKind;
use mase::passes::QuantSolution;
use mase::util::Table;

fn main() {
    common::banner("Fig 5", "MX formats x 10 LLM simulants on sst2-sim");
    let fmts = [
        (FormatKind::MxInt, 7.0f32),
        (FormatKind::Bmf, 5.0),
        (FormatKind::Bl, 7.0),
    ];

    // Artifact-free preamble: the measured bit-packed layout of each MX
    // format at its 8-bit-element config (packed::layout) next to the
    // analytic Eq. (1) average — MXInt packs exactly at the analytic
    // density; BMF pays a bottom-binade guard bit, BL a zero code.
    {
        use mase::packed::layout::{packed_bits_for, ElemLayout};
        let shape = [1024usize, 1024];
        let elems = (shape[0] * shape[1]) as f64;
        let mut lt = Table::new(vec![
            "format", "elem_bits", "pad/block", "analytic_avg", "measured_avg", "overhead",
        ]);
        for (fmt, bits) in fmts {
            let p = mase::formats::Precision::new(bits, 0.0);
            let lay = ElemLayout::new(fmt, p);
            let analytic = p.average_bitwidth(fmt);
            let meas = packed_bits_for(fmt, p, &shape) as f64 / elems;
            lt.row(vec![
                fmt.name().to_string(),
                lay.elem_bits.to_string(),
                lay.padding_bits_per_group().to_string(),
                format!("{analytic:.2}"),
                format!("{meas:.2}"),
                format!("{:+.1}%", (meas / analytic - 1.0) * 100.0),
            ]);
        }
        println!("packed layout, measured on a 1024x1024 weight:\n{}", lt.render());
    }

    let session = common::session();

    let mut t = Table::new(vec![
        "model", "fp32_acc", "mxint8_Δacc", "bmf8_Δacc", "bl8_Δacc",
        "mxint8_AE", "bmf8_AE", "bl8_AE",
    ]);
    let mut sums = vec![0.0f64; 6];
    let names = common::classifier_names(&session);
    for name in &names {
        let meta = session.manifest.model(name).unwrap().clone();
        let w = common::weights(&session, &meta, Some(Task::Sst2));
        let eval = common::eval_set(&meta, Task::Sst2);
        let (ev, profile) = common::evaluator_for(&session, &meta, &w, &eval);

        let fp32 = ev
            .accuracy(&QuantSolution::uniform(FormatKind::Fp32, 32.0, &meta, &profile))
            .unwrap()
            .accuracy();
        let int8 = ev
            .evaluate(&QuantSolution::uniform(FormatKind::Int, 8.0, &meta, &profile))
            .unwrap();

        let mut cells = vec![name.clone(), format!("{fp32:.3}")];
        let mut aes = Vec::new();
        for (i, (fmt, bits)) in fmts.iter().enumerate() {
            let r = ev.evaluate(&QuantSolution::uniform(*fmt, *bits, &meta, &profile)).unwrap();
            let dacc = r.accuracy - fp32;
            let ae = r.design.area_efficiency() / int8.design.area_efficiency();
            cells.push(format!("{dacc:+.3}"));
            aes.push(format!("{ae:.2}x"));
            sums[i] += dacc;
            sums[3 + i] += ae;
        }
        cells.extend(aes);
        t.row(cells);
    }
    let n = names.len() as f64;
    t.row(vec![
        "AVERAGE".to_string(),
        "".to_string(),
        format!("{:+.3}", sums[0] / n),
        format!("{:+.3}", sums[1] / n),
        format!("{:+.3}", sums[2] / n),
        format!("{:.2}x", sums[3] / n),
        format!("{:.2}x", sums[4] / n),
        format!("{:.2}x", sums[5] / n),
    ]);
    println!("{}", t.render());
    println!("paper shape: MXInt best Δacc of the three MX formats; all MX formats");
    println!("have area efficiency < 1x of int8 at 8-bit local components.");
    let ok = sums[0] >= sums[1] && sums[0] >= sums[2];
    println!("shape check: MXInt best Δacc: {ok}");
}
