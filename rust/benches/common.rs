//! Shared plumbing for the benchmark harness (plain `main` benches;
//! criterion is unavailable offline). Every bench regenerates one table
//! or figure of the paper and prints the same rows/series the paper
//! reports, with the paper's own numbers alongside where they exist.
//!
//! Knobs (env): MASE_TRIALS (search trials), MASE_EVAL_BATCHES,
//! MASE_MODELS (comma list to sub-select), MASE_PRETRAIN_STEPS.

#![allow(dead_code)]

use mase::coordinator::{pretrain, PretrainConfig, Session};
use mase::data::{batches, Batch, MarkovCorpus, Task};
use mase::frontend::ModelMeta;
use mase::passes::{profile_model, Evaluator, ProfileData};
use mase::runtime::PjrtBackend;

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn trials() -> usize {
    env_usize("MASE_TRIALS", 24)
}

pub fn eval_batches_n() -> usize {
    env_usize("MASE_EVAL_BATCHES", 3)
}

pub fn session() -> Session {
    Session::open(&Session::default_dir()).expect(
        "artifacts missing — run `make artifacts && cargo build --release` first",
    )
}

/// Like [`session`] but for benches with artifact-free sections: returns
/// `None` (with a printed note) instead of panicking, so the parts that
/// only need the pure-Rust substrate still run.
pub fn try_session() -> Option<Session> {
    match Session::open(&Session::default_dir()) {
        Ok(s) => Some(s),
        Err(e) => {
            println!("[artifacts unavailable: {e:#} — skipping PJRT-backed sections]");
            None
        }
    }
}

/// The ten classifier simulants, optionally filtered by MASE_MODELS.
pub fn classifier_names(session: &Session) -> Vec<String> {
    let filter: Option<Vec<String>> = std::env::var("MASE_MODELS")
        .ok()
        .map(|v| v.split(',').map(str::to_string).collect());
    session
        .manifest
        .classifiers()
        .iter()
        .map(|m| m.name.clone())
        .filter(|n| filter.as_ref().map(|f| f.contains(n)).unwrap_or(true))
        .collect()
}

/// Cached pretrained weights for (model, task).
pub fn weights(session: &Session, meta: &ModelMeta, task: Option<Task>) -> Vec<f32> {
    let cfg = PretrainConfig {
        steps: env_usize("MASE_PRETRAIN_STEPS", 220),
        ..Default::default()
    };
    pretrain::pretrain(session, meta, task, &cfg).expect("pretraining failed")
}

/// Held-out eval batches for a classifier task.
pub fn eval_set(meta: &ModelMeta, task: Task) -> Vec<Batch> {
    batches(task, 1, eval_batches_n(), meta.batch, meta.seq_len)
}

/// Held-out LM corpus batches.
pub fn lm_eval_set(meta: &ModelMeta) -> Vec<Batch> {
    let corpus = MarkovCorpus::new(7);
    (0..eval_batches_n())
        .map(|i| Batch {
            tokens: corpus.batch(1000 + i as u64, meta.batch, meta.seq_len),
            labels: vec![0; meta.batch],
            batch: meta.batch,
            seq: meta.seq_len,
        })
        .collect()
}

/// Evaluator (PJRT-backed) + profile, ready to score solutions.
pub fn evaluator_for<'a>(
    session: &'a Session,
    meta: &'a ModelMeta,
    w: &'a [f32],
    eval: &'a [Batch],
) -> (Evaluator<'a, PjrtBackend<'a>>, ProfileData) {
    let backend = session.pjrt_backend().expect("PJRT session");
    let ev = Evaluator::new(backend, meta, w, eval).expect("evaluator");
    let profile = profile_model(&ev.backend, meta, w, &eval[..1]).expect("profile failed");
    (ev, profile)
}

pub fn banner(name: &str, what: &str) {
    println!("\n=== {name} — {what} ===");
}
