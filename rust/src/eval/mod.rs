//! Evaluation metrics: accuracy, perplexity, Δaccuracy — the software
//! half of the `evaluate` pass (paper §5 reports accuracy relative to
//! FP32 and perplexity on the LM).

/// Aggregate of (loss, correct) pairs returned by the eval artifacts.
#[derive(Debug, Clone, Default)]
pub struct EvalAccumulator {
    pub total_loss: f64,
    pub total_correct: u64,
    pub total_examples: u64,
    pub batches: u64,
}

impl EvalAccumulator {
    pub fn add_batch(&mut self, loss: f32, correct: i32, examples: usize) {
        self.total_loss += loss as f64;
        self.total_correct += correct.max(0) as u64;
        self.total_examples += examples as u64;
        self.batches += 1;
    }

    /// Mean loss across batches (for LMs this is mean token NLL).
    pub fn mean_loss(&self) -> f64 {
        if self.batches == 0 {
            return f64::NAN;
        }
        self.total_loss / self.batches as f64
    }

    /// Classification accuracy in [0, 1].
    pub fn accuracy(&self) -> f64 {
        if self.total_examples == 0 {
            return f64::NAN;
        }
        self.total_correct as f64 / self.total_examples as f64
    }

    /// Perplexity = exp(mean token NLL).
    pub fn perplexity(&self) -> f64 {
        self.mean_loss().exp()
    }
}

/// Δaccuracy as the paper plots it: quantized accuracy minus FP32
/// accuracy (closer to 0 / positive is better).
pub fn delta_accuracy(quantized: f64, fp32: f64) -> f64 {
    quantized - fp32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_means() {
        let mut a = EvalAccumulator::default();
        a.add_batch(1.0, 32, 64);
        a.add_batch(3.0, 48, 64);
        assert!((a.mean_loss() - 2.0).abs() < 1e-12);
        assert!((a.accuracy() - 80.0 / 128.0).abs() < 1e-12);
    }

    #[test]
    fn perplexity_is_exp_loss() {
        let mut a = EvalAccumulator::default();
        a.add_batch(2.0, 0, 16);
        assert!((a.perplexity() - 2.0f64.exp()).abs() < 1e-9);
    }

    #[test]
    fn empty_accumulator_is_nan() {
        let a = EvalAccumulator::default();
        assert!(a.mean_loss().is_nan());
        assert!(a.accuracy().is_nan());
    }

    #[test]
    fn delta_accuracy_sign() {
        assert!(delta_accuracy(0.8, 0.9) < 0.0);
        assert_eq!(delta_accuracy(0.9, 0.9), 0.0);
    }
}
