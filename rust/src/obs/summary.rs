//! The human-facing rendering of a [`Registry`](super::Registry): one
//! table schema shared by `mase e2e`, `mase sweep`, `mase generate`, the
//! benches and `scripts/record_bench.sh` — replacing the three ad-hoc
//! stat printers that predated PR 8.
//!
//! The block is delimited by `== trace summary ==` / `== end trace
//! summary ==` marker lines so `record_bench.sh` can lift it verbatim
//! into BENCH_RESULTS.md. Wall-clock appears here (and only here /
//! in the wall-clock Chrome export) — the JSONL stream stays counted
//! work only.

use super::{EventKind, Registry};
use crate::util::Table;

/// Per-phase roll-up of a registry: span counts + wall seconds per span
/// path, and every monotonic counter total.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceSummary {
    /// (span path, span count, total wall seconds)
    pub spans: Vec<(String, u64, f64)>,
    /// (counter path, counter name, monotonic total)
    pub counters: Vec<(String, String, u64)>,
}

impl TraceSummary {
    pub fn from_registry(reg: &Registry) -> Self {
        let wall = reg.wall();
        let mut spans: Vec<(String, u64, f64)> = Vec::new();
        for ev in reg.sorted_events() {
            if let EventKind::Span { .. } = ev.kind {
                match spans.last_mut() {
                    Some(s) if s.0 == ev.path => s.1 += 1,
                    _ => spans.push((ev.path.clone(), 1, 0.0)),
                }
            }
        }
        for s in spans.iter_mut() {
            s.2 = wall.get(&s.0).map(|&(secs, _)| secs).unwrap_or(0.0);
        }
        let counters =
            reg.counters().into_iter().map(|((p, n), v)| (p, n, v)).collect();
        Self { spans, counters }
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty() && self.counters.is_empty()
    }

    /// Render the delimited summary block (empty string when there is
    /// nothing to report, so callers can print unconditionally).
    pub fn render(&self) -> String {
        if self.is_empty() {
            return String::new();
        }
        let mut out = String::from("== trace summary ==\n");
        if !self.spans.is_empty() {
            let mut t = Table::new(vec!["span", "count", "wall_s"]);
            for (path, count, secs) in &self.spans {
                t.row(vec![path.clone(), count.to_string(), format!("{secs:.3}")]);
            }
            out.push_str(&t.render());
        }
        if !self.counters.is_empty() {
            let mut t = Table::new(vec!["counter", "name", "total"]);
            for (path, name, total) in &self.counters {
                t.row(vec![path.clone(), name.clone(), total.to_string()]);
            }
            out.push_str(&t.render());
        }
        out.push_str("== end trace summary ==\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_rolls_up_spans_and_counters() {
        let reg = Registry::new();
        for _ in 0..3 {
            let _g = reg.span("search/trial");
        }
        {
            let _g = reg.span("pass/emit");
        }
        reg.counter("decode/group", "decode_score_dots", 40);
        reg.counter("decode/group", "decode_score_dots", 2);
        let s = TraceSummary::from_registry(&reg);
        assert_eq!(s.spans.len(), 2);
        assert_eq!(s.spans[0].0, "pass/emit");
        assert_eq!(s.spans[1], ("search/trial".to_string(), 3, s.spans[1].2));
        assert_eq!(
            s.counters,
            vec![("decode/group".to_string(), "decode_score_dots".to_string(), 42)]
        );
        let r = s.render();
        assert!(r.starts_with("== trace summary ==\n"), "{r}");
        assert!(r.ends_with("== end trace summary ==\n"), "{r}");
        assert!(r.contains("search/trial"));
        assert!(r.contains("42"));
    }

    #[test]
    fn empty_registry_renders_nothing() {
        let s = TraceSummary::from_registry(Registry::none());
        assert!(s.is_empty());
        assert_eq!(s.render(), "");
    }
}
