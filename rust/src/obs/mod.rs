//! Observability: deterministic tracing + metrics for every phase the
//! paper makes a per-phase accounting claim about (PR 8).
//!
//! ## Model
//!
//! A [`Registry`] collects two kinds of events into one ordered stream:
//!
//! - **spans** — RAII-guarded regions ([`Registry::span`]) named by a
//!   `/`-separated path (`pass/search`, `search/trial`, `sweep/cell`,
//!   `decode/group`), optionally tagged with string key/values
//!   (`memo=hit`). One event is recorded when the guard drops.
//! - **counters** — monotonic named `u64` totals under a path
//!   ([`Registry::counter`]), e.g. `decode/group` ×
//!   `decode_score_dots`. Every increment appends a counter event
//!   carrying its delta; totals accumulate in a side map.
//!
//! ## Determinism contract
//!
//! The event stream is **counted work, never wall-clock**: events are
//! recorded only at single-threaded orchestration points (batch
//! re-association loops, sweep cells, pass boundaries, post-`par_map`
//! merges), worker threads contribute only via order-independent counter
//! sums, and every event's sort key is `(span_path, seq)` where `seq` is
//! a per-path monotonic index. A fixed seed therefore produces a
//! **byte-identical** JSONL export ([`jsonl::render`]) at any thread
//! count — the same contract PRs 1/7 assert for search histories and
//! decode outputs, asserted for traces by `tests/trace_determinism.rs`.
//!
//! Wall-clock durations ARE measured (spans hold a [`Instant`]) but flow
//! only into the human-facing [`summary::TraceSummary`] table and the
//! wall-clock Chrome export ([`chrome::registry_chrome_json`]) — never
//! into the JSONL stream. The cycle-exact Chrome export of a simulator
//! run ([`chrome::sim_chrome_json`]) uses simulated cycles and is as
//! deterministic as the simulator itself.
//!
//! ## Serialization
//!
//! All `u64` values (seq, deltas, totals) serialize as fixed-width
//! 16-digit lowercase hex — the PR 2 bit-pattern convention
//! (`search::cache::hex_u64`) that makes streams byte-comparable and
//! float-round-trip-proof. `scripts/verify_trace_schema.py` validates
//! the schema and re-derives the simulator's closed-form cycle
//! accounting without a Rust toolchain.

pub mod chrome;
pub mod jsonl;
pub mod summary;

pub use summary::TraceSummary;

use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// One recorded event. `wall` is side data for span events (start offset
/// from registry creation, duration — both seconds); it never enters the
/// deterministic JSONL stream.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub path: String,
    /// Per-path monotonic index: the second half of the documented
    /// `(span_path, seq)` sort key.
    pub seq: u64,
    pub kind: EventKind,
    pub wall: Option<(f64, f64)>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// A completed span with its (insertion-ordered) tags.
    Span { tags: Vec<(String, String)> },
    /// A counter increment: `delta` added to the `(path, name)` total.
    Counter { name: String, delta: u64 },
}

/// The recording contract: thread-safe, and every call a cheap no-op
/// when `enabled()` is false. [`Registry`] is the standard
/// implementation; the trait exists so instrumented code states exactly
/// what it needs.
pub trait Recorder: Send + Sync {
    fn enabled(&self) -> bool;
    /// Record a completed span at `path`. `wall` is (start offset,
    /// duration) in seconds relative to the recorder's origin.
    fn record_span(&self, path: &str, tags: Vec<(String, String)>, wall: Option<(f64, f64)>);
    /// Add `delta` to the monotonic counter `name` under `path` and
    /// append the increment to the event stream.
    fn add_counter(&self, path: &str, name: &str, delta: u64);
}

#[derive(Default)]
struct Inner {
    events: Vec<Event>,
    /// path -> next seq
    seq: BTreeMap<String, u64>,
    /// (path, name) -> monotonic total
    counters: BTreeMap<(String, String), u64>,
    /// path -> (total wall seconds, span count) — summary-table only
    wall: BTreeMap<String, (f64, u64)>,
}

/// The standard [`Recorder`]: a mutex-guarded event log + counter
/// registry. Cheap when disabled (every entry point checks one bool and
/// returns), plain `Mutex` when enabled — recording happens at
/// orchestration points, never in per-element hot loops.
pub struct Registry {
    enabled: bool,
    origin: Instant,
    inner: Mutex<Inner>,
}

impl fmt::Debug for Registry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let inner = self.inner.lock().unwrap();
        f.debug_struct("Registry")
            .field("enabled", &self.enabled)
            .field("events", &inner.events.len())
            .field("counters", &inner.counters.len())
            .finish()
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    /// An enabled, empty registry.
    pub fn new() -> Self {
        Self { enabled: true, origin: Instant::now(), inner: Mutex::new(Inner::default()) }
    }

    /// A registry that drops everything — for plumbing that always takes
    /// a recorder.
    pub fn disabled() -> Self {
        Self { enabled: false, origin: Instant::now(), inner: Mutex::new(Inner::default()) }
    }

    /// The shared disabled registry: the default recorder for untraced
    /// runs, so instrumented code never branches on `Option`.
    pub fn none() -> &'static Registry {
        static NONE: OnceLock<Registry> = OnceLock::new();
        NONE.get_or_init(Registry::disabled)
    }

    /// Inherent mirror of [`Recorder::enabled`], so instrumented code
    /// can gate without importing the trait.
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Open a span at `path`; the event is recorded when the returned
    /// guard drops. Chain [`SpanGuard::tag`] to attach tags.
    pub fn span(&self, path: &str) -> SpanGuard<'_> {
        SpanGuard {
            reg: self.enabled.then_some(self),
            path: if self.enabled { path.to_string() } else { String::new() },
            tags: Vec::new(),
            start: Instant::now(),
        }
    }

    /// Monotonic counter increment (also appends a stream event).
    pub fn counter(&self, path: &str, name: &str, delta: u64) {
        self.add_counter(path, name, delta);
    }

    /// Current total of counter `(path, name)` (0 if never touched).
    pub fn counter_total(&self, path: &str, name: &str) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner.counters.get(&(path.to_string(), name.to_string())).copied().unwrap_or(0)
    }

    /// Snapshot of the event log in recording order.
    pub fn events(&self) -> Vec<Event> {
        self.inner.lock().unwrap().events.clone()
    }

    /// Snapshot of the event log sorted by the documented
    /// `(span_path, seq)` key — the order every exporter uses.
    pub fn sorted_events(&self) -> Vec<Event> {
        let mut ev = self.events();
        ev.sort_by(|a, b| (a.path.as_str(), a.seq).cmp(&(b.path.as_str(), b.seq)));
        ev
    }

    /// Snapshot of all counter totals.
    pub fn counters(&self) -> BTreeMap<(String, String), u64> {
        self.inner.lock().unwrap().counters.clone()
    }

    /// Snapshot of per-path wall-clock (total seconds, span count) —
    /// summary-table data, excluded from the deterministic stream.
    pub fn wall(&self) -> BTreeMap<String, (f64, u64)> {
        self.inner.lock().unwrap().wall.clone()
    }

    fn next_seq(inner: &mut Inner, path: &str) -> u64 {
        let e = inner.seq.entry(path.to_string()).or_insert(0);
        let s = *e;
        *e += 1;
        s
    }
}

impl Recorder for Registry {
    fn enabled(&self) -> bool {
        self.enabled
    }

    fn record_span(&self, path: &str, tags: Vec<(String, String)>, wall: Option<(f64, f64)>) {
        if !self.enabled {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let seq = Self::next_seq(&mut inner, path);
        if let Some((_, dur)) = wall {
            let w = inner.wall.entry(path.to_string()).or_insert((0.0, 0));
            w.0 += dur;
            w.1 += 1;
        }
        inner.events.push(Event {
            path: path.to_string(),
            seq,
            kind: EventKind::Span { tags },
            wall,
        });
    }

    fn add_counter(&self, path: &str, name: &str, delta: u64) {
        if !self.enabled {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let seq = Self::next_seq(&mut inner, path);
        *inner.counters.entry((path.to_string(), name.to_string())).or_insert(0) += delta;
        inner.events.push(Event {
            path: path.to_string(),
            seq,
            kind: EventKind::Counter { name: name.to_string(), delta },
            wall: None,
        });
    }
}

/// RAII span guard from [`Registry::span`]: records one span event (with
/// the tags attached so far) when dropped.
pub struct SpanGuard<'a> {
    reg: Option<&'a Registry>,
    path: String,
    tags: Vec<(String, String)>,
    start: Instant,
}

impl SpanGuard<'_> {
    /// Attach a tag; a no-op on a disabled registry.
    pub fn tag(mut self, key: &str, value: impl Into<String>) -> Self {
        if self.reg.is_some() {
            self.tags.push((key.to_string(), value.into()));
        }
        self
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if let Some(reg) = self.reg {
            let start = self.start.saturating_duration_since(reg.origin).as_secs_f64();
            let dur = self.start.elapsed().as_secs_f64();
            reg.record_span(&self.path, std::mem::take(&mut self.tags), Some((start, dur)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_and_counters_accumulate() {
        let reg = Registry::new();
        {
            let _g = reg.span("pass/search").tag("algo", "tpe");
        }
        reg.counter("decode/group", "dots", 7);
        reg.counter("decode/group", "dots", 3);
        assert_eq!(reg.counter_total("decode/group", "dots"), 10);
        let ev = reg.events();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].path, "pass/search");
        assert!(matches!(&ev[0].kind, EventKind::Span { tags } if tags[0].0 == "algo"));
        assert!(ev[0].wall.is_some(), "spans carry wall side data");
        assert!(ev[1].wall.is_none(), "counters carry none");
    }

    #[test]
    fn seq_is_per_path_monotonic() {
        let reg = Registry::new();
        reg.counter("a", "x", 1);
        reg.counter("b", "x", 1);
        reg.counter("a", "x", 1);
        let ev = reg.sorted_events();
        let seqs: Vec<(String, u64)> = ev.iter().map(|e| (e.path.clone(), e.seq)).collect();
        assert_eq!(
            seqs,
            vec![("a".to_string(), 0), ("a".to_string(), 1), ("b".to_string(), 0)]
        );
    }

    #[test]
    fn disabled_registry_records_nothing() {
        let reg = Registry::none();
        assert!(!reg.enabled());
        {
            let _g = reg.span("pass/search").tag("k", "v");
        }
        reg.counter("a", "x", 5);
        assert!(reg.events().is_empty());
        assert_eq!(reg.counter_total("a", "x"), 0);
    }

    #[test]
    fn registry_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Registry>();
    }
}
