//! JSONL export of a [`Registry`](super::Registry): the deterministic,
//! byte-comparable trace artifact.
//!
//! ## Line schema (`mase-trace` v1)
//!
//! One JSON object per line, compact-printed by [`crate::util::json`]
//! (sorted keys, no whitespace), all `u64` values as fixed-width
//! 16-digit lowercase hex (the PR 2 bit-pattern convention):
//!
//! ```text
//! {"schema":"mase-trace","version":1}                          header
//! {"kind":"span","path":P,"seq":H,"tags":{..}}                 span
//! {"kind":"counter","delta":H,"name":N,"path":P,"seq":H}       increment
//! {"kind":"total","name":N,"path":P,"value":H}                 footer
//! ```
//!
//! Events are sorted by the documented `(span_path, seq)` key; totals
//! (one per counter, in `BTreeMap` order) follow all events. Wall-clock
//! span side data is **excluded** — a fixed seed yields a byte-identical
//! file at any thread count (`tests/trace_determinism.rs`).

use super::{EventKind, Registry};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Schema tag on the header line.
pub const SCHEMA: &str = "mase-trace";
/// Schema version on the header line.
pub const VERSION: u64 = 1;

fn hex(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

/// Render the registry as a complete JSONL document (trailing newline).
pub fn render(reg: &Registry) -> String {
    let mut lines = Vec::new();
    let mut header = BTreeMap::new();
    header.insert("schema".to_string(), Json::Str(SCHEMA.to_string()));
    header.insert("version".to_string(), Json::Num(VERSION as f64));
    lines.push(Json::Obj(header).to_string());

    for ev in reg.sorted_events() {
        let mut o = BTreeMap::new();
        o.insert("path".to_string(), Json::Str(ev.path.clone()));
        o.insert("seq".to_string(), hex(ev.seq));
        match &ev.kind {
            EventKind::Span { tags } => {
                o.insert("kind".to_string(), Json::Str("span".to_string()));
                let t: BTreeMap<String, Json> =
                    tags.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect();
                o.insert("tags".to_string(), Json::Obj(t));
            }
            EventKind::Counter { name, delta } => {
                o.insert("kind".to_string(), Json::Str("counter".to_string()));
                o.insert("name".to_string(), Json::Str(name.clone()));
                o.insert("delta".to_string(), hex(*delta));
            }
        }
        lines.push(Json::Obj(o).to_string());
    }

    for ((path, name), total) in reg.counters() {
        let mut o = BTreeMap::new();
        o.insert("kind".to_string(), Json::Str("total".to_string()));
        o.insert("path".to_string(), Json::Str(path));
        o.insert("name".to_string(), Json::Str(name));
        o.insert("value".to_string(), hex(total));
        lines.push(Json::Obj(o).to_string());
    }

    lines.join("\n") + "\n"
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_then_sorted_events_then_totals() {
        let reg = Registry::new();
        reg.counter("b/path", "n", 2);
        {
            let _g = reg.span("a/path").tag("memo", "hit");
        }
        reg.counter("b/path", "n", 3);
        let out = render(&reg);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines[0], r#"{"schema":"mase-trace","version":1}"#);
        // sorted by (path, seq): span on a/path first despite later record
        assert_eq!(
            lines[1],
            r#"{"kind":"span","path":"a/path","seq":"0000000000000000","tags":{"memo":"hit"}}"#
        );
        assert_eq!(
            lines[2],
            r#"{"delta":"0000000000000002","kind":"counter","name":"n","path":"b/path","seq":"0000000000000000"}"#
        );
        assert_eq!(
            lines[4],
            r#"{"kind":"total","name":"n","path":"b/path","value":"0000000000000005"}"#
        );
        assert!(out.ends_with('\n'));
        // every line parses back
        for l in lines {
            Json::parse(l).expect("valid json line");
        }
    }

    #[test]
    fn wall_clock_never_leaks_into_the_stream() {
        let reg = Registry::new();
        {
            let _g = reg.span("pass/search");
        }
        let out = render(&reg);
        assert!(!out.contains("wall"), "{out}");
        assert!(!out.contains("secs"), "{out}");
    }
}
