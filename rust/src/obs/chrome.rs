//! Chrome Trace Event (Perfetto-loadable) exporters — Fig. 1 as an
//! interactive timeline.
//!
//! Two exporters share the JSON shape (`{"traceEvents":[...]}`, complete
//! "X" events, `ph:"M"` thread-name metadata) but differ in their clock:
//!
//! - [`sim_chrome_json`] renders a [`SimTrace`] with **simulated
//!   cycles** as microseconds — one track per PE (node firings) plus one
//!   track per stalled channel (`transfer_stalled` intervals). Fully
//!   deterministic; golden-tested and re-derived by
//!   `scripts/verify_trace_schema.py`.
//! - [`registry_chrome_json`] renders a flow/sweep [`Registry`] with
//!   **wall-clock** span timings (visualization only — the determinism
//!   contract covers the JSONL export, not this view).
//!
//! Load either in <https://ui.perfetto.dev> (or `chrome://tracing`) via
//! "Open trace file".

use super::{EventKind, Registry};
use crate::sim::{NodeSpec, SimReport, SimTrace};
use crate::util::json::Json;
use std::collections::BTreeMap;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn thread_name(tid: usize, name: &str) -> Json {
    obj(vec![
        ("args", obj(vec![("name", Json::Str(name.to_string()))])),
        ("name", Json::Str("thread_name".to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(tid as f64)),
    ])
}

fn complete(name: &str, cat: &str, ts: u64, dur: u64, tid: usize) -> Json {
    obj(vec![
        ("cat", Json::Str(cat.to_string())),
        ("dur", Json::Num(dur as f64)),
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str("X".to_string())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts as f64)),
    ])
}

/// Render a simulator run as a Chrome trace: tids `0..nodes.len()` are
/// PE tracks (one "X" slice per firing, `dur` = occupancy), and every
/// edge with nonzero [`crate::sim::EdgeReport::transfer_stalled`] gets
/// an `xfer:producer->consumer` track above them.
/// Cycles map 1:1 to trace microseconds. Per PE track, total slice
/// duration equals `SimReport::busy` and the last slice ends at
/// `SimReport::cycles` — the closed-form accounting the golden test and
/// the python mirror assert.
pub fn sim_chrome_json(nodes: &[NodeSpec], report: &SimReport, trace: &SimTrace) -> Json {
    let mut events = Vec::new();
    for (i, nd) in nodes.iter().enumerate() {
        events.push(thread_name(i, &nd.name));
    }
    // stable tid per stalled edge: nodes.len() + position among stalled
    let mut edge_tid: BTreeMap<usize, usize> = BTreeMap::new();
    for (e, edge) in report.edges.iter().enumerate() {
        if edge.transfer_stalled > 0 {
            let tid = nodes.len() + edge_tid.len();
            edge_tid.insert(e, tid);
            let label =
                format!("xfer:{}->{}", nodes[edge.producer].name, nodes[edge.consumer].name);
            events.push(thread_name(tid, &label));
        }
    }
    for f in &trace.firings {
        events.push(complete(&nodes[f.node].name, "firing", f.t, f.occupancy, f.node));
    }
    for s in &trace.stalls {
        if let Some(&tid) = edge_tid.get(&s.edge) {
            events.push(complete("transfer_stalled", "stall", s.t, s.dt, tid));
        }
    }
    obj(vec![
        ("displayTimeUnit", Json::Str("ns".to_string())),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// Render a flow/sweep registry's spans as a wall-clock Chrome trace:
/// one track per top-level path segment (`pass`, `search`, `sweep`,
/// `decode`), spans as "X" slices at microsecond resolution, tags in
/// `args`. Visualization only — timings are wall-clock, so this export
/// is NOT covered by the byte-identical determinism contract (the JSONL
/// export is).
pub fn registry_chrome_json(reg: &Registry) -> Json {
    let spans: Vec<_> = reg
        .sorted_events()
        .into_iter()
        .filter_map(|ev| match ev.kind {
            EventKind::Span { ref tags } => {
                ev.wall.map(|w| (ev.path.clone(), tags.clone(), w))
            }
            EventKind::Counter { .. } => None,
        })
        .collect();
    let mut track: BTreeMap<String, usize> = BTreeMap::new();
    for (path, _, _) in &spans {
        let top = path.split('/').next().unwrap_or(path).to_string();
        let next = track.len();
        track.entry(top).or_insert(next);
    }
    let mut events = Vec::new();
    for (name, &tid) in &track {
        events.push(thread_name(tid, name));
    }
    for (path, tags, (start, dur)) in &spans {
        let top = path.split('/').next().unwrap_or(path);
        let tid = track[top];
        let mut e = complete(path, "span", 0, 0, tid);
        if let Json::Obj(m) = &mut e {
            m.insert("ts".to_string(), Json::Num((start * 1e6).round()));
            m.insert("dur".to_string(), Json::Num((dur * 1e6).round().max(1.0)));
            let t: BTreeMap<String, Json> =
                tags.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect();
            m.insert("args".to_string(), Json::Obj(t));
        }
        events.push(e);
    }
    obj(vec![
        ("displayTimeUnit", Json::Str("ns".to_string())),
        ("traceEvents", Json::Arr(events)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate_traced, SimConfig};

    fn toy_nodes() -> Vec<NodeSpec> {
        // the Fig. 1 toy fork-join graph, also mirrored line-for-line in
        // scripts/verify_trace_schema.py and the golden-trace test
        vec![
            NodeSpec {
                name: "src".into(),
                preds: vec![],
                pred_buffer: vec![],
                ii: 1,
                tiles_per_inference: 8,
                is_source: true,
                out_tile_bits: 256,
            },
            NodeSpec {
                name: "a".into(),
                preds: vec![0],
                pred_buffer: vec![],
                ii: 2,
                tiles_per_inference: 8,
                is_source: false,
                out_tile_bits: 128,
            },
            NodeSpec {
                name: "b".into(),
                preds: vec![0],
                pred_buffer: vec![],
                ii: 3,
                tiles_per_inference: 8,
                is_source: false,
                out_tile_bits: 128,
            },
            NodeSpec {
                name: "join".into(),
                preds: vec![1, 2],
                pred_buffer: vec![],
                ii: 1,
                tiles_per_inference: 8,
                is_source: false,
                out_tile_bits: 0,
            },
        ]
    }

    #[test]
    fn sim_export_durations_match_closed_form_busy() {
        let nodes = toy_nodes();
        let cfg =
            SimConfig { inferences: 2, fifo_depth: 2, sequential: false, channel_bits: 32 };
        let (report, trace) = simulate_traced(&nodes, &cfg);
        let j = sim_chrome_json(&nodes, &report, &trace);
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        // per-PE sum of slice durations == SimReport::busy
        for (i, &busy) in report.busy.iter().enumerate() {
            let total: f64 = events
                .iter()
                .filter(|e| {
                    e.get("ph").and_then(Json::as_str) == Some("X")
                        && e.get("cat").and_then(Json::as_str) == Some("firing")
                        && e.get("tid").and_then(Json::as_f64) == Some(i as f64)
                })
                .map(|e| e.get("dur").unwrap().as_f64().unwrap())
                .sum();
            assert_eq!(total as u64, busy, "node {i}");
        }
        // trace ends exactly at the report's cycle count
        let end = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .map(|e| {
                e.get("ts").unwrap().as_f64().unwrap() + e.get("dur").unwrap().as_f64().unwrap()
            })
            .fold(0.0, f64::max);
        assert_eq!(end as u64, report.cycles);
        // every stalled edge has a named track
        let stalled = report.edges.iter().filter(|e| e.transfer_stalled > 0).count();
        let xfer_tracks = events
            .iter()
            .filter(|e| {
                e.get("ph").and_then(Json::as_str) == Some("M")
                    && e.at(&["args", "name"])
                        .and_then(Json::as_str)
                        .is_some_and(|n| n.starts_with("xfer:"))
            })
            .count();
        assert_eq!(stalled, xfer_tracks);
        assert!(stalled > 0, "32-bit fabric must stall this graph");
    }

    #[test]
    fn registry_export_has_one_track_per_top_segment() {
        let reg = Registry::new();
        {
            let _g = reg.span("pass/search").tag("algo", "tpe");
        }
        {
            let _g = reg.span("pass/emit");
        }
        {
            let _g = reg.span("sweep/cell");
        }
        reg.counter("decode/group", "dots", 3); // counters: not exported
        let j = registry_chrome_json(&reg);
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        let tracks: Vec<&str> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .map(|e| e.at(&["args", "name"]).unwrap().as_str().unwrap())
            .collect();
        assert_eq!(tracks, vec!["pass", "sweep"]);
        let slices = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .count();
        assert_eq!(slices, 3);
    }
}
