//! Static analysis for the compiler's outputs — the correctness
//! backstop the ROADMAP's format/fabric growth runs under (PR 6).
//!
//! Two analyzers share one diagnostics framework:
//!
//!  * [`sv`] — a real SystemVerilog analyzer (tokenizer, module-header/
//!    declaration/instantiation parser, per-module symbol tables) that
//!    checks declared-before-use, part-select bounds and direction,
//!    port-connection widths, multiple drivers, and unused declarations
//!    over every emitted file. It statically catches the PR 5 review
//!    findings: the reversed `[CHAN_W-1:CHAN_W]` part-select (MC002),
//!    the mis-sized `out_exp` connection (MC004), and undeclared signal
//!    references (MC001).
//!  * [`contracts`] — a cross-layer bitwidth-contract checker over the
//!    quantized MASE-IR: re-derives accumulator widths, alignment-shift
//!    spans and tile payload bits from the `formats` + `packed::layout`
//!    closed forms and asserts `packed::kernels`, `sim`,
//!    `hw::throughput` and the emitted unpacker/MAC parameters all
//!    agree (MC020-MC025).
//!
//! Every diagnostic carries a stable `MC0xx` code (table in
//! `docs/ARCHITECTURE.md`), a severity, and a source location. Three
//! surfaces drive the same entry points: the `mase check` subcommand,
//! the hard gate inside `passes::emit_pass::emit_to_dir`, and the
//! `check` stage of `scripts/ci.sh`. The toolchain-free mirror of the
//! SV analyzer lives in `scripts/verify_sv_check.py`; the contract
//! closed forms are mirrored in `scripts/verify_packed_math.py`.

pub mod contracts;
pub mod sv;

use crate::emit::verilog::EmittedDesign;
use crate::ir::Graph;
use std::collections::BTreeMap;

/// Diagnostic severity. Errors fail `mase check`, the emit-pass gate
/// and the ci.sh `check` stage; warnings are reported but non-fatal.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

/// One finding, tagged with a stable code and a source location
/// (file + 1-based line for SV findings; IR op/value path for contract
/// findings, with line 0).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// stable `MC0xx` code (see the table in docs/ARCHITECTURE.md)
    pub code: String,
    pub severity: Severity,
    /// source file (or IR location such as `ir:op3:linear`)
    pub file: String,
    /// 1-based source line; 0 when the location is not a text file
    pub line: u32,
    pub message: String,
}

impl Diagnostic {
    /// Build a diagnostic; the severity comes from the code table so
    /// every producer of an `MC0xx` agrees on how fatal it is.
    pub fn new(code: &str, file: &str, line: u32, message: String) -> Self {
        Diagnostic {
            code: code.to_string(),
            severity: severity_of(code),
            file: file.to_string(),
            line,
            message,
        }
    }

    /// `file:line: severity[CODE] message` (the `rustc`-ish shape the
    /// CLI and the emit gate print).
    pub fn render(&self) -> String {
        let sev = match self.severity {
            Severity::Warning => "warning",
            Severity::Error => "error",
        };
        if self.line > 0 {
            format!("{}:{}: {sev}[{}] {}", self.file, self.line, self.code, self.message)
        } else {
            format!("{}: {sev}[{}] {}", self.file, self.code, self.message)
        }
    }
}

/// Severity table for the stable codes. Unknown codes default to Error
/// so a typo cannot silently demote a finding.
fn severity_of(code: &str) -> Severity {
    match code {
        // SV analyzer warnings: unused declaration, unknown module
        // (libraries may be instantiated without their source on hand)
        "MC006" | "MC007" => Severity::Warning,
        // contract warning: alignment-shift span exceeds the aligner
        // (the kernel falls back to exact f64 adds — legal, but worth
        // surfacing: those groups leave the integer datapath)
        "MC024" => Severity::Warning,
        _ => Severity::Error,
    }
}

/// A batch of findings from one `check::` entry point.
#[derive(Debug, Clone, Default)]
pub struct CheckReport {
    pub diags: Vec<Diagnostic>,
}

impl CheckReport {
    pub fn has_errors(&self) -> bool {
        self.diags.iter().any(|d| d.severity == Severity::Error)
    }

    pub fn errors(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    /// One line per finding plus a summary tail, ready to print.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.diags {
            out.push_str(&d.render());
            out.push('\n');
        }
        out.push_str(&format!(
            "check: {} error(s), {} warning(s)\n",
            self.errors(),
            self.warnings()
        ));
        out
    }
}

/// Analyze a set of SystemVerilog sources (file name -> text). This is
/// the entry point `mase check --sv` drives for on-disk files.
pub fn check_sv_files(files: &BTreeMap<String, String>) -> CheckReport {
    let (diags, _) = sv::check_files(files);
    CheckReport { diags }
}

/// Check the cross-layer bitwidth contracts of a quantized graph at a
/// channel width (no emitted design needed).
pub fn check_graph(g: &Graph, channel_bits: u64) -> CheckReport {
    CheckReport { diags: contracts::check_graph_contracts(g, channel_bits) }
}

/// Full check of an emitted design against its source graph: SV
/// analysis of every file, the IR contracts, and the emitted-parameter
/// agreement (MC025). The single entry point behind `mase check`, the
/// emit-pass gate and the ci.sh `check` stage.
pub fn check_design(design: &EmittedDesign, g: &Graph, channel_bits: u64) -> CheckReport {
    let (mut diags, mtab) = sv::check_files(&design.files);
    diags.extend(contracts::check_graph_contracts(g, channel_bits));
    diags.extend(contracts::check_emitted_params(g, &mtab, channel_bits));
    CheckReport { diags }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn severity_table_is_stable() {
        assert_eq!(Diagnostic::new("MC001", "a.sv", 3, "x".into()).severity, Severity::Error);
        assert_eq!(Diagnostic::new("MC006", "a.sv", 3, "x".into()).severity, Severity::Warning);
        assert_eq!(Diagnostic::new("MC024", "ir:op", 0, "x".into()).severity, Severity::Warning);
        // unknown codes stay fatal
        assert_eq!(Diagnostic::new("MC999", "a.sv", 1, "x".into()).severity, Severity::Error);
    }

    #[test]
    fn report_renders_locations_and_summary() {
        let r = CheckReport {
            diags: vec![
                Diagnostic::new("MC002", "top.sv", 12, "reversed part-select".into()),
                Diagnostic::new("MC006", "top.sv", 4, "unused".into()),
            ],
        };
        assert!(r.has_errors());
        assert_eq!((r.errors(), r.warnings()), (1, 1));
        let text = r.render();
        assert!(text.contains("top.sv:12: error[MC002] reversed part-select"), "{text}");
        assert!(text.contains("top.sv:4: warning[MC006]"), "{text}");
        assert!(text.contains("1 error(s), 1 warning(s)"), "{text}");
    }

    #[test]
    fn ir_located_diagnostics_render_without_line() {
        let d = Diagnostic::new("MC023", "ir:op3:linear", 0, "acc width drift".into());
        assert_eq!(d.render(), "ir:op3:linear: error[MC023] acc width drift");
    }
}
