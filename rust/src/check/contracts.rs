//! Cross-layer bitwidth-contract checker (codes MC020-MC025).
//!
//! The OCP microscaling formats are precise bit-level contracts — block
//! shape, shared-exponent width, element encodings — that five layers
//! must agree on: `formats`/`packed::layout` (the sizing closed forms),
//! `packed::kernels` (the integer datapath), `sim` (tile payloads and
//! beats), `hw::throughput` (the performance model) and the emitted
//! SystemVerilog (unpacker framing and MAC accumulator widths). This
//! module re-derives each quantity independently from first principles
//! and asserts every layer matches — one source of truth, checked,
//! instead of five copies trusted.
//!
//! | code | contract |
//! |---|---|
//! | MC020 | tile payload bits: closed form vs `packed_bits_for` vs `hw::throughput::op_tile_bits` |
//! | MC021 | simulator node payload (`out_tile_bits`, incl. the zero-work interface-op rule) |
//! | MC022 | transfer beats: `hw::throughput::op_transfer_beats` vs `ceil(tile_bits / channel)` |
//! | MC023 | MAC accumulator width: `packed::kernels::mxint_acc_bits` covers the exact worst case |
//! | MC024 | alignment-shift span exceeds `MAX_ALIGN_SHIFT` (warning: f64 fallback segments) |
//! | MC025 | emitted unpacker/MAC parameters vs the IR closed forms (via the parsed module table) |
//!
//! Mirrored toolchain-free in `scripts/verify_packed_math.py` (contract
//! section) so the closed forms stay checkable without cargo.

use super::sv::{self, Module};
use super::Diagnostic;
use crate::emit::templates;
use crate::emit::verilog::design_format;
use crate::formats::{bmf::LOCAL_EXP_BITS, FormatKind, Precision, BLOCK_SHAPE, SHARED_EXPONENT_BITS};
use crate::hw::throughput::{op_cycles, op_tile_bits, op_transfer_beats};
use crate::ir::{Graph, OpKind, Operation};
use crate::packed::kernels::{mxint_acc_bits, MAX_ALIGN_SHIFT};
use crate::packed::layout::{ElemLayout, GROUP_ELEMS};
use crate::packed::packed_bits_for;
use crate::sim::nodes_from_graph;
use std::collections::{BTreeMap, BTreeSet};

/// Independent closed form for a block-format tile's payload bits:
/// `blocks * (ceil(32 * elem_bits / 64) * 64 + 8)` — partial blocks pad
/// to full (16, 2) blocks, every group starts on a fresh u64 word, one
/// shared-exponent byte per block. `None` for element-wise formats
/// (their payload has no block structure to cross-check).
pub fn tile_payload_bits(fmt: FormatKind, p: Precision, tile: (usize, usize)) -> Option<u64> {
    if !fmt.is_block_format() {
        return None;
    }
    let (br, bc) = BLOCK_SHAPE;
    let lay = ElemLayout::new(fmt, p);
    let blocks = (tile.0.div_ceil(br) * tile.1.div_ceil(bc)) as u64;
    let group_w = (GROUP_ELEMS as u64 * lay.elem_bits as u64).div_ceil(64) * 64;
    Some(blocks * (group_w + SHARED_EXPONENT_BITS as u64))
}

/// Minimum signed accumulator width holding one group's exact integer
/// dot-product at `m` mantissa bits, derived from the worst case itself:
/// 32 products of `(2^m - 1)^2` must fit below `2^(w-1)`.
pub fn acc_bits_needed(m: u32) -> u32 {
    let prod = ((1u128 << m) - 1).pow(2);
    let total = prod.max(1) * GROUP_ELEMS as u128;
    (128 - total.leading_zeros()) + 1
}

/// Worst-case exponent span of one group's products for a (format,
/// knob) pair — the alignment distance the integer datapath must cover.
/// Products sum two element exponents, so the span doubles the
/// per-element range: 0 for MXInt/fixed (exponent structurally constant
/// inside a group), `2*(2^LOCAL_EXP_BITS - 1)` for BMF's local codes,
/// 28 for FP8 (e4m3: codes 1..15), `2*(2^eb - 1)` for BL's eb-bit
/// element exponents.
pub fn align_span_bound(fmt: FormatKind, knob: i32) -> i64 {
    match fmt {
        FormatKind::MxInt | FormatKind::Int | FormatKind::Fp32 => 0,
        FormatKind::Bmf => 2 * ((1i64 << LOCAL_EXP_BITS) - 1),
        FormatKind::Fp8 => 28,
        FormatKind::Bl => 2 * ((1i64 << knob.clamp(0, 32)) - 1),
    }
}

fn op_loc(op: &Operation) -> String {
    format!("ir:op{}:{}", op.id.0, op.kind.name())
}

/// Check the cross-layer contracts of a quantized graph at a channel
/// width (MC020-MC024) — no emitted design required.
pub fn check_graph_contracts(g: &Graph, channel_bits: u64) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let nodes = nodes_from_graph(g);
    let mut acc_checked: BTreeSet<u32> = BTreeSet::new();

    for (i, op) in g.ops.iter().enumerate() {
        let Some(&r) = op.results.first() else { continue };
        let v = g.value(r);
        let tile = v.attrs.tile;
        let loc = op_loc(op);
        let measured = packed_bits_for(v.ty.format, v.ty.precision, &[tile.0, tile.1]);

        // MC020: layout closed form vs the sizing oracle vs the
        // performance model's per-tile payload
        if let Some(closed) = tile_payload_bits(v.ty.format, v.ty.precision, tile) {
            if closed != measured {
                diags.push(Diagnostic::new(
                    "MC020",
                    &loc,
                    0,
                    format!(
                        "tile payload closed form {closed} bits != packed_bits_for {measured} \
                         ({} m-knob tile {}x{})",
                        v.ty.format.name(),
                        tile.0,
                        tile.1
                    ),
                ));
            }
        }
        let hw_bits = op_tile_bits(g, op, tile);
        if hw_bits != measured {
            diags.push(Diagnostic::new(
                "MC020",
                &loc,
                0,
                format!("hw::throughput::op_tile_bits {hw_bits} != packed layout {measured}"),
            ));
        }

        // MC021: the simulator charges the measured payload, except for
        // zero-work interface ops (one free token per inference)
        let expect_sim = if op_cycles(g, op, tile) == 0.0 { 0 } else { measured };
        if let Some(node) = nodes.get(i) {
            if node.out_tile_bits != expect_sim {
                diags.push(Diagnostic::new(
                    "MC021",
                    &loc,
                    0,
                    format!(
                        "simulator charges {} bits/tile but the contract requires {expect_sim} \
                         (zero-work rule: interface ops stream free)",
                        node.out_tile_bits
                    ),
                ));
            }
        }

        // MC022: transfer beats against the channel framing rule
        let expect_beats =
            if channel_bits == 0 { 1 } else { measured.div_ceil(channel_bits).max(1) };
        let hw_beats = op_transfer_beats(g, op, tile, channel_bits);
        if hw_beats != expect_beats as f64 {
            diags.push(Diagnostic::new(
                "MC022",
                &loc,
                0,
                format!(
                    "op_transfer_beats {hw_beats} != ceil({measured} / {channel_bits}) = \
                     {expect_beats}"
                ),
            ));
        }

        if !op.kind.is_gemm() {
            continue;
        }

        // MC023: the kernel/template accumulator covers one group's
        // exact worst case at this op's mantissa width
        let m = v.ty.precision.bits.max(1.0) as u32;
        if acc_checked.insert(m) {
            let have = mxint_acc_bits(m);
            let need = acc_bits_needed(m);
            if have < need {
                diags.push(Diagnostic::new(
                    "MC023",
                    &loc,
                    0,
                    format!(
                        "accumulator width {have} bits cannot hold the exact 32-element \
                         group dot-product at m={m} (needs {need})"
                    ),
                ));
            }
        }

        // MC024: operands whose alignment span exceeds the hardware
        // aligner leave the integer datapath (exact-f64 fallback)
        for &a in op.args.iter().chain(op.params.iter()) {
            let va = g.value(a);
            if va.ty.format == FormatKind::Fp32 {
                continue;
            }
            let lay = ElemLayout::new(va.ty.format, va.ty.precision);
            let span = align_span_bound(va.ty.format, lay.knob);
            if span > MAX_ALIGN_SHIFT as i64 {
                diags.push(Diagnostic::new(
                    "MC024",
                    &loc,
                    0,
                    format!(
                        "operand %{} ({}, knob {}) has alignment span {span} > \
                         MAX_ALIGN_SHIFT {MAX_ALIGN_SHIFT}: groups fall back to per-term \
                         f64 accumulation",
                        va.name,
                        va.ty.format.name(),
                        lay.knob
                    ),
                ));
            }
        }
    }
    diags
}

fn expect_param(
    diags: &mut Vec<Diagnostic>,
    env: &std::collections::HashMap<String, Option<i64>>,
    module: &str,
    pname: &str,
    want: i64,
    loc: &str,
) {
    match env.get(pname) {
        Some(Some(v)) if *v == want => {}
        Some(Some(v)) => diags.push(Diagnostic::new(
            "MC025",
            loc,
            0,
            format!("emitted module `{module}` parameter {pname} = {v}, IR closed form requires {want}"),
        )),
        _ => diags.push(Diagnostic::new(
            "MC025",
            loc,
            0,
            format!("emitted module `{module}` has no constant parameter {pname}"),
        )),
    }
}

/// MC025: every gemm's emitted MAC template and unpacker must carry
/// exactly the parameters the IR closed forms dictate. `mtab` is the
/// module table parsed from the emitted files ([`sv::check_files`]), so
/// this checks what the SystemVerilog *says*, not what the generator
/// intended.
pub fn check_emitted_params(
    g: &Graph,
    mtab: &BTreeMap<String, Module>,
    channel_bits: u64,
) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let dfmt = design_format(g);
    for op in &g.ops {
        if !matches!(op.kind, OpKind::Linear | OpKind::Attention) {
            continue;
        }
        let Some(&r) = op.results.first() else { continue };
        let v = g.value(r);
        let tile = v.attrs.tile;
        let mantissa = v.ty.precision.bits.max(1.0) as u32;
        let loc = op_loc(op);

        let (tname, _) = templates::template_for(op.kind, dfmt, mantissa, tile);
        match sv::params_of(mtab, &tname) {
            None => diags.push(Diagnostic::new(
                "MC025",
                &loc,
                0,
                format!("emitted design has no module `{tname}` for this gemm"),
            )),
            Some(env) => {
                let m = mantissa.max(1);
                expect_param(&mut diags, &env, &tname, "MAN_W", (m + 1) as i64, &loc);
                expect_param(&mut diags, &env, &tname, "ACC_W", mxint_acc_bits(m) as i64, &loc);
                expect_param(&mut diags, &env, &tname, "LANES", (tile.0 * tile.1) as i64, &loc);
            }
        }

        // the unpacker framing on the gemm's incoming edge
        let Some(&a) = op.args.first() else { continue };
        let va = g.value(a);
        let m_in = va.ty.precision.bits.max(1.0) as u32;
        let Some((uname, _, _)) =
            templates::unpacker_for(va.ty.format, m_in, va.attrs.tile, channel_bits)
        else {
            continue;
        };
        let cfg = templates::unpacker_config(
            va.ty.format,
            Precision::new(m_in as f32, 0.0),
            va.attrs.tile,
            channel_bits,
        );
        match sv::params_of(mtab, &uname) {
            None => diags.push(Diagnostic::new(
                "MC025",
                &loc,
                0,
                format!("emitted design has no unpacker `{uname}` for this gemm's input edge"),
            )),
            Some(env) => {
                expect_param(&mut diags, &env, &uname, "CHAN_W", cfg.chan as i64, &loc);
                expect_param(&mut diags, &env, &uname, "ELEM_W", cfg.elem_bits as i64, &loc);
                expect_param(&mut diags, &env, &uname, "LANES", cfg.lanes as i64, &loc);
                expect_param(&mut diags, &env, &uname, "GROUPS", cfg.groups as i64, &loc);
                expect_param(&mut diags, &env, &uname, "GROUP_W", cfg.group_w as i64, &loc);
                expect_param(&mut diags, &env, &uname, "BEATS", cfg.beats as i64, &loc);
                expect_param(&mut diags, &env, &uname, "TILE_BITS", cfg.tile_bits as i64, &loc);
            }
        }
    }
    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{build_graph, manifest::ModelMeta};
    use crate::hw::Device;
    use crate::passes::{parallelize, profile::ProfileData, QuantSolution};

    fn quantized_graph(fmt: FormatKind, bits: f32) -> Graph {
        let m = ModelMeta::synthetic("ck", 2, 32, 2, 512, 32, 4, "classifier", 64);
        let p = ProfileData::uniform(&m, 4.0);
        let mut g = build_graph(&m);
        QuantSolution::uniform(fmt, bits, &m, &p).apply(&mut g);
        parallelize(&mut g, &Device::u250(), 0.2);
        g
    }

    #[test]
    fn quantized_designs_satisfy_all_contracts() {
        for fmt in [FormatKind::MxInt, FormatKind::Bmf, FormatKind::Int] {
            for chan in [512, 64, 0] {
                let g = quantized_graph(fmt, 5.0);
                let diags = check_graph_contracts(&g, chan);
                assert!(diags.is_empty(), "{fmt:?} chan={chan}: {diags:?}");
            }
        }
    }

    #[test]
    fn emitted_parameters_match_ir_closed_forms() {
        let g = quantized_graph(FormatKind::MxInt, 5.0);
        let chan = crate::hw::DEFAULT_CHANNEL_BITS;
        let design = crate::emit::verilog::emit_design_at(&g, chan);
        let (sv_diags, mtab) = sv::check_files(&design.files);
        assert!(sv_diags.is_empty(), "{sv_diags:?}");
        let diags = check_emitted_params(&g, &mtab, chan);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn emitted_parameter_drift_is_detected() {
        let g = quantized_graph(FormatKind::MxInt, 5.0);
        let chan = crate::hw::DEFAULT_CHANNEL_BITS;
        let mut design = crate::emit::verilog::emit_design_at(&g, chan);
        // sabotage one MAC accumulator width in the emitted text
        let key = design
            .files
            .keys()
            .find(|k| k.contains("linear"))
            .expect("a linear template")
            .clone();
        let txt = design.files[&key].replace("parameter ACC_W  = ", "parameter ACC_W  = 1 + ");
        design.files.insert(key, txt);
        let (_, mtab) = sv::check_files(&design.files);
        let diags = check_emitted_params(&g, &mtab, chan);
        assert!(
            diags.iter().any(|d| d.code == "MC025" && d.message.contains("ACC_W")),
            "{diags:?}"
        );
    }

    #[test]
    fn acc_width_closed_form_is_sufficient_for_all_mantissas() {
        for m in 1..=24 {
            assert!(
                mxint_acc_bits(m) >= acc_bits_needed(m),
                "m={m}: {} < {}",
                mxint_acc_bits(m),
                acc_bits_needed(m)
            );
        }
        // and tight where the algebra predicts: m=4 -> 32*(15^2) needs 14
        assert_eq!(acc_bits_needed(4), 14);
        assert_eq!(mxint_acc_bits(4), 14);
    }

    #[test]
    fn wide_bl_exponents_warn_about_aligner_fallback() {
        // BL with eb >= 6 spans 2*(2^6 - 1) = 126 > 63: the kernel's
        // documented fallback, now predicted statically
        assert!(align_span_bound(FormatKind::Bl, 7) > MAX_ALIGN_SHIFT as i64);
        assert!(align_span_bound(FormatKind::Bl, 5) <= MAX_ALIGN_SHIFT as i64);
        assert_eq!(align_span_bound(FormatKind::MxInt, 8), 0);
        assert_eq!(align_span_bound(FormatKind::Bmf, 8), 6);
        let g = quantized_graph(FormatKind::Bl, 7.0);
        let diags = check_graph_contracts(&g, 512);
        assert!(
            diags.iter().any(|d| d.code == "MC024"),
            "bl m=7 must predict the fallback: {diags:?}"
        );
        assert!(
            diags.iter().all(|d| d.code == "MC024"),
            "fallback is a warning, not a contract break: {diags:?}"
        );
    }

    #[test]
    fn payload_closed_form_matches_known_values() {
        // mxint m=4, (16,2): one block, 5-bit elems -> 3 words + exp
        // byte = 200 bits (the unpacker test's numbers)
        let p = Precision::new(4.0, 0.0);
        assert_eq!(tile_payload_bits(FormatKind::MxInt, p, (16, 2)), Some(200));
        // partial blocks pad to full ones
        assert_eq!(tile_payload_bits(FormatKind::MxInt, p, (8, 4)), Some(400));
        assert_eq!(tile_payload_bits(FormatKind::Int, Precision::new(8.0, 4.0), (16, 2)), None);
    }
}
