//! A real SystemVerilog analyzer for the emitted RTL subset.
//!
//! Tokenizes the source, parses module headers, declarations, generate
//! constructs and instantiations into per-module symbol tables, then
//! checks declared-before-use (MC001), part-select direction and bounds
//! (MC002/MC003), port-connection width consistency (MC004),
//! multiply-driven nets (MC005), unused declarations (MC006), unknown
//! modules/ports (MC007/MC008), parse errors (MC009) and duplicate
//! declarations (MC010).
//!
//! The algorithm is mirrored line-for-line by
//! `scripts/verify_sv_check.py` so it stays debuggable without a Rust
//! toolchain; keep the two in sync when changing semantics.

use std::collections::{BTreeMap, HashMap, HashSet};

use super::Diagnostic;

type Env = HashMap<String, Option<i64>>;

// ---------------------------------------------------------------------------
// tokenizer
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kind {
    Id,
    Num,
    Sys,
    Punct,
    Str,
    Eof,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

fn tok(kind: Kind, text: &str, line: u32) -> Tok {
    Tok { kind, text: text.to_string(), line }
}

fn eof_tok(line: u32) -> Tok {
    Tok { kind: Kind::Eof, text: String::new(), line }
}

#[derive(Debug)]
pub struct ParseErr {
    pub line: u32,
    pub msg: String,
}

impl ParseErr {
    fn new(line: u32, msg: String) -> Self {
        ParseErr { line, msg }
    }
}

fn is_keyword(s: &str) -> bool {
    matches!(
        s,
        "module"
            | "endmodule"
            | "input"
            | "output"
            | "inout"
            | "logic"
            | "wire"
            | "reg"
            | "signed"
            | "unsigned"
            | "parameter"
            | "localparam"
            | "assign"
            | "always"
            | "always_ff"
            | "always_comb"
            | "always_latch"
            | "begin"
            | "end"
            | "if"
            | "else"
            | "for"
            | "generate"
            | "endgenerate"
            | "genvar"
            | "integer"
            | "posedge"
            | "negedge"
            | "or"
            | "and"
            | "case"
            | "endcase"
            | "default"
            | "initial"
            | "function"
            | "endfunction"
            | "typedef"
            | "enum"
            | "struct"
            | "packed"
            | "int"
            | "bit"
            | "byte"
            | "return"
            | "void"
    )
}

const PUNCTS2: [&str; 10] = ["<=", ">=", "==", "!=", "&&", "||", "<<", ">>", "+:", "-:"];

fn is_open(t: &str) -> bool {
    matches!(t, "(" | "[" | "{")
}

fn is_close(t: &str) -> bool {
    matches!(t, ")" | "]" | "}")
}

/// Tokenize SystemVerilog source into id/num/sys/punct/str tokens.
pub fn tokenize(text: &str) -> Result<Vec<Tok>, ParseErr> {
    let b = text.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            continue;
        }
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut j = i + 2;
            loop {
                if j + 1 >= n {
                    return Err(ParseErr::new(line, "unterminated block comment".into()));
                }
                if b[j] == b'*' && b[j + 1] == b'/' {
                    break;
                }
                if b[j] == b'\n' {
                    line += 1;
                }
                j += 1;
            }
            i = j + 2;
            continue;
        }
        if c == b'"' {
            let mut j = i + 1;
            while j < n && b[j] != b'"' {
                j += 1;
            }
            if j >= n {
                return Err(ParseErr::new(line, "unterminated string".into()));
            }
            toks.push(tok(Kind::Str, &text[i..j + 1], line));
            i = j + 1;
            continue;
        }
        if c.is_ascii_alphabetic() || c == b'_' {
            let mut j = i + 1;
            while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                j += 1;
            }
            toks.push(tok(Kind::Id, &text[i..j], line));
            i = j;
            continue;
        }
        if c == b'$' {
            let mut j = i + 1;
            if j < n && (b[j].is_ascii_alphabetic() || b[j] == b'_') {
                j += 1;
                while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                    j += 1;
                }
                toks.push(tok(Kind::Sys, &text[i..j], line));
                i = j;
                continue;
            }
            return Err(ParseErr::new(line, "stray '$'".into()));
        }
        if c.is_ascii_digit() || c == b'\'' {
            // optional decimal head, then 'sB.. based literal, or plain number
            let start = i;
            let mut j = i;
            while j < n && (b[j].is_ascii_digit() || b[j] == b'_') {
                j += 1;
            }
            let head_len = j - i;
            if j < n && b[j] == b'\'' {
                let mut k = j + 1;
                while k < n && (b[k] == b's' || b[k] == b'S') {
                    k += 1;
                }
                if k < n && matches!(b[k], b'b' | b'B' | b'd' | b'D' | b'o' | b'O' | b'h' | b'H') {
                    let mut m = k + 1;
                    while m < n
                        && (b[m].is_ascii_hexdigit()
                            || matches!(b[m], b'x' | b'X' | b'z' | b'Z' | b'_' | b'?'))
                    {
                        m += 1;
                    }
                    if m == k + 1 {
                        return Err(ParseErr::new(line, "unsupported literal".into()));
                    }
                    toks.push(tok(Kind::Num, &text[start..m], line));
                    i = m;
                    continue;
                }
                if head_len == 0 && k == j + 1 && k < n && matches!(b[k], b'0' | b'1' | b'x' | b'X' | b'z' | b'Z')
                {
                    toks.push(tok(Kind::Num, &text[start..k + 1], line));
                    i = k + 1;
                    continue;
                }
                if head_len == 0 {
                    // bare ' (e.g. '{ aggregate) — not in our subset
                    return Err(ParseErr::new(line, "unsupported literal".into()));
                }
                // plain number followed by a quote that is not a literal base
                toks.push(tok(Kind::Num, &text[start..j], line));
                i = j;
                continue;
            }
            if head_len == 0 {
                return Err(ParseErr::new(line, "unsupported literal".into()));
            }
            toks.push(tok(Kind::Num, &text[start..j], line));
            i = j;
            continue;
        }
        let two = if i + 1 < n { &text[i..i + 2] } else { "" };
        if PUNCTS2.contains(&two) {
            toks.push(tok(Kind::Punct, two, line));
            i += 2;
            continue;
        }
        if (c as char).is_ascii() && "()[]{};:,.@#?!~^&|+-*/%<>=".contains(c as char) {
            toks.push(tok(Kind::Punct, &text[i..i + 1], line));
            i += 1;
            continue;
        }
        return Err(ParseErr::new(line, format!("unexpected character {:?}", c as char)));
    }
    Ok(toks)
}

/// `(width, value, flexible)` of a numeric literal; unbased-unsized
/// literals (`'0`) and widthless decimals stretch to context.
pub fn num_info(txt: &str) -> (Option<i64>, Option<i64>, bool) {
    if let Some(apos) = txt.find('\'') {
        let head = &txt[..apos];
        let rest0 = &txt[apos + 1..];
        let rest = rest0.trim_start_matches(['s', 'S']);
        let first = rest.chars().next();
        if head.is_empty() {
            if let Some(c) = first {
                if matches!(c, '0' | '1' | 'x' | 'X' | 'z' | 'Z') && rest.len() == 1 {
                    let v = match c {
                        '0' => Some(0),
                        '1' => Some(1),
                        _ => None,
                    };
                    return (None, v, true); // unbased-unsized: stretches to context
                }
            }
        }
        let base = match first {
            Some('b') | Some('B') => 2,
            Some('d') | Some('D') => 10,
            Some('o') | Some('O') => 8,
            Some('h') | Some('H') => 16,
            _ => return (None, None, true),
        };
        let digits: String = rest[1..].chars().filter(|&c| c != '_').collect();
        let val = if digits.chars().any(|c| matches!(c, 'x' | 'X' | 'z' | 'Z' | '?')) {
            None
        } else {
            i64::from_str_radix(&digits, base).ok()
        };
        let width = if head.is_empty() {
            None
        } else {
            head.replace('_', "").parse::<i64>().ok()
        };
        let flexible = width.is_none();
        return (width, val, flexible);
    }
    (None, txt.replace('_', "").parse::<i64>().ok(), true)
}

// ---------------------------------------------------------------------------
// parser: token stream -> module structures
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Dir {
    Input,
    Output,
    Inout,
}

#[derive(Clone, Debug)]
pub struct Port {
    pub name: String,
    pub dir: Option<Dir>,
    pub rng: Option<(Vec<Tok>, Vec<Tok>)>,
    pub line: u32,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum DeclKind {
    Net,
    Integer,
    Genvar,
}

#[derive(Clone, Debug)]
pub enum UnpackedDim {
    Size(Vec<Tok>),
    Range(Vec<Tok>, Vec<Tok>),
}

#[derive(Clone, Debug)]
pub struct Decl {
    pub name: String,
    pub kind: DeclKind,
    pub rng: Option<(Vec<Tok>, Vec<Tok>)>,
    pub unpacked: Vec<UnpackedDim>,
    pub line: u32,
}

#[derive(Clone, Debug)]
pub enum Stmt {
    Block(Vec<Stmt>),
    If { cond: Vec<Tok>, then: Box<Stmt>, els: Option<Box<Stmt>>, line: u32 },
    For { init: Box<Stmt>, cond: Vec<Tok>, step: Box<Stmt>, body: Box<Stmt>, line: u32 },
    PAssign { lhs: Vec<Tok>, rhs: Vec<Tok>, line: u32 },
    Expr { toks: Vec<Tok>, line: u32 },
}

#[derive(Clone, Debug)]
pub enum Item {
    LocalParam { name: String, toks: Vec<Tok>, line: u32 },
    Decl { decl: Decl, init: Option<Vec<Tok>> },
    Assign { lhs: Vec<Tok>, rhs: Vec<Tok>, line: u32 },
    Always { sens: Vec<Tok>, stmt: Stmt },
    GenFor { var: String, init: Vec<Tok>, cond: Vec<Tok>, step: Vec<Tok>, body: Vec<Item> },
    GenIf { cond: Vec<Tok>, then: Vec<Item>, els: Vec<Item> },
    Inst {
        module: String,
        overrides: Vec<(String, Vec<Tok>, u32)>,
        conns: Vec<(String, Vec<Tok>, u32)>,
        line: u32,
    },
}

#[derive(Clone, Debug)]
pub struct Module {
    pub name: String,
    pub line: u32,
    pub params: Vec<(String, Vec<Tok>, u32)>,
    pub ports: Vec<Port>,
    pub items: Vec<Item>,
}

pub struct Parser {
    toks: Vec<Tok>,
    i: usize,
}

impl Parser {
    pub fn new(toks: Vec<Tok>) -> Self {
        Parser { toks, i: 0 }
    }

    fn line(&self) -> u32 {
        if self.i < self.toks.len() {
            self.toks[self.i].line
        } else {
            self.toks.last().map(|t| t.line).unwrap_or(0)
        }
    }

    fn peek(&self) -> Tok {
        self.toks.get(self.i).cloned().unwrap_or_else(|| eof_tok(self.line()))
    }

    fn peek_text(&self) -> String {
        self.peek().text
    }

    fn next_tok(&mut self) -> Tok {
        let t = self.peek();
        self.i += 1;
        t
    }

    fn at(&self, text: &str) -> bool {
        let t = self.peek();
        t.text == text && t.kind != Kind::Str
    }

    fn accept(&mut self, text: &str) -> bool {
        if self.at(text) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn expect(&mut self, text: &str) -> Result<Tok, ParseErr> {
        let t = self.next_tok();
        if t.text != text {
            return Err(ParseErr::new(t.line, format!("expected `{}`, found `{}`", text, t.text)));
        }
        Ok(t)
    }

    fn expect_id(&mut self) -> Result<Tok, ParseErr> {
        let t = self.next_tok();
        if t.kind != Kind::Id || is_keyword(&t.text) {
            return Err(ParseErr::new(t.line, format!("expected identifier, found `{}`", t.text)));
        }
        Ok(t)
    }

    /// Collect tokens until a depth-0 stop punct; the stop is not consumed.
    fn toks_until(&mut self, stops: &[&str]) -> Result<Vec<Tok>, ParseErr> {
        let mut out = Vec::new();
        let mut depth = 0i32;
        loop {
            let t = self.peek();
            if t.kind == Kind::Eof {
                return Err(ParseErr::new(t.line, format!("eof looking for one of {stops:?}")));
            }
            if depth == 0 && t.kind == Kind::Punct && stops.contains(&t.text.as_str()) {
                return Ok(out);
            }
            if t.kind == Kind::Punct && is_open(&t.text) {
                depth += 1;
            } else if t.kind == Kind::Punct && is_close(&t.text) {
                if depth == 0 {
                    return Err(ParseErr::new(t.line, format!("unbalanced `{}`", t.text)));
                }
                depth -= 1;
            }
            out.push(self.next_tok());
        }
    }

    /// Consume `(` ... matching `)`; return the inner tokens.
    fn parenthesized(&mut self) -> Result<Vec<Tok>, ParseErr> {
        self.expect("(")?;
        let out = self.toks_until(&[")"])?;
        self.expect(")")?;
        Ok(out)
    }

    /// `[ msb : lsb ]` -> Some((msb, lsb)); None if absent.
    fn packed_range(&mut self) -> Result<Option<(Vec<Tok>, Vec<Tok>)>, ParseErr> {
        if !self.at("[") {
            return Ok(None);
        }
        self.expect("[")?;
        let msb = self.toks_until(&[":"])?;
        self.expect(":")?;
        let lsb = self.toks_until(&["]"])?;
        self.expect("]")?;
        Ok(Some((msb, lsb)))
    }

    fn unpacked_dim(&mut self) -> Result<UnpackedDim, ParseErr> {
        self.expect("[")?;
        let size = self.toks_until(&["]", ":"])?;
        if self.at(":") {
            // [0:N-1] style unpacked range — size = msb..lsb
            self.expect(":")?;
            let hi = self.toks_until(&["]"])?;
            self.expect("]")?;
            return Ok(UnpackedDim::Range(size, hi));
        }
        self.expect("]")?;
        Ok(UnpackedDim::Size(size))
    }

    // -- modules --
    pub fn parse_file(&mut self) -> Result<Vec<Module>, ParseErr> {
        let mut mods = Vec::new();
        while self.peek().kind != Kind::Eof {
            if self.at("module") {
                mods.push(self.parse_module()?);
            } else {
                self.next_tok(); // tolerate leading directives between modules
            }
        }
        Ok(mods)
    }

    fn parse_module(&mut self) -> Result<Module, ParseErr> {
        let ln = self.expect("module")?.line;
        let name = self.expect_id()?.text;
        let mut m = Module { name, line: ln, params: Vec::new(), ports: Vec::new(), items: Vec::new() };
        if self.accept("#") {
            self.expect("(")?;
            while !self.at(")") {
                self.accept("parameter");
                while matches!(
                    self.peek_text().as_str(),
                    "logic" | "int" | "integer" | "bit" | "signed" | "unsigned"
                ) {
                    self.next_tok();
                }
                let name = self.expect_id()?;
                self.expect("=")?;
                let dflt = self.toks_until(&[",", ")"])?;
                m.params.push((name.text, dflt, name.line));
                if !self.accept(",") {
                    break;
                }
            }
            self.expect(")")?;
        }
        self.expect("(")?;
        let mut dir: Option<Dir> = None;
        while !self.at(")") {
            match self.peek_text().as_str() {
                "input" => {
                    dir = Some(Dir::Input);
                    self.next_tok();
                }
                "output" => {
                    dir = Some(Dir::Output);
                    self.next_tok();
                }
                "inout" => {
                    dir = Some(Dir::Inout);
                    self.next_tok();
                }
                _ => {}
            }
            while matches!(self.peek_text().as_str(), "logic" | "wire" | "reg" | "signed" | "unsigned") {
                self.next_tok();
            }
            let rng = self.packed_range()?;
            let name = self.expect_id()?;
            m.ports.push(Port { name: name.text, dir, rng, line: name.line });
            if !self.accept(",") {
                break;
            }
        }
        self.expect(")")?;
        self.expect(";")?;
        m.items = self.parse_items(&["endmodule"])?;
        self.expect("endmodule")?;
        Ok(m)
    }

    // -- body items --
    fn parse_items(&mut self, terminators: &[&str]) -> Result<Vec<Item>, ParseErr> {
        let mut items = Vec::new();
        loop {
            let t = self.peek();
            if t.kind == Kind::Eof {
                return Err(ParseErr::new(t.line, format!("eof looking for {terminators:?}")));
            }
            let txt = t.text.as_str();
            if terminators.contains(&txt) {
                return Ok(items);
            }
            if txt == ";" {
                self.next_tok();
                continue;
            }
            if txt == "localparam" {
                self.next_tok();
                while matches!(
                    self.peek_text().as_str(),
                    "logic" | "int" | "integer" | "bit" | "signed" | "unsigned"
                ) {
                    self.next_tok();
                }
                let name = self.expect_id()?;
                self.expect("=")?;
                let val = self.toks_until(&[";"])?;
                self.expect(";")?;
                items.push(Item::LocalParam { name: name.text, toks: val, line: name.line });
                continue;
            }
            if txt == "genvar" || txt == "integer" {
                let kind = if txt == "genvar" { DeclKind::Genvar } else { DeclKind::Integer };
                self.next_tok();
                loop {
                    let name = self.expect_id()?;
                    items.push(Item::Decl {
                        decl: Decl {
                            name: name.text,
                            kind,
                            rng: None,
                            unpacked: Vec::new(),
                            line: name.line,
                        },
                        init: None,
                    });
                    if !self.accept(",") {
                        break;
                    }
                }
                self.expect(";")?;
                continue;
            }
            if matches!(txt, "logic" | "wire" | "reg") {
                self.next_tok();
                let _ = self.accept("signed") || self.accept("unsigned");
                let rng = self.packed_range()?;
                loop {
                    let name = self.expect_id()?;
                    let mut unpacked = Vec::new();
                    while self.at("[") {
                        unpacked.push(self.unpacked_dim()?);
                    }
                    let mut init = None;
                    if self.accept("=") {
                        init = Some(self.toks_until(&[";", ","])?);
                    }
                    items.push(Item::Decl {
                        decl: Decl {
                            name: name.text,
                            kind: DeclKind::Net,
                            rng: rng.clone(),
                            unpacked,
                            line: name.line,
                        },
                        init,
                    });
                    if !self.accept(",") {
                        break;
                    }
                }
                self.expect(";")?;
                continue;
            }
            if txt == "assign" {
                let ln0 = self.next_tok().line;
                let lhs = self.toks_until(&["="])?;
                self.expect("=")?;
                let rhs = self.toks_until(&[";"])?;
                self.expect(";")?;
                items.push(Item::Assign { lhs, rhs, line: ln0 });
                continue;
            }
            if matches!(txt, "always_ff" | "always_comb" | "always" | "always_latch") {
                self.next_tok();
                let mut sens = Vec::new();
                if self.accept("@") {
                    sens = self.parenthesized()?;
                }
                let stmt = self.parse_stmt()?;
                items.push(Item::Always { sens, stmt });
                continue;
            }
            if txt == "generate" {
                self.next_tok();
                let inner = self.parse_items(&["endgenerate"])?;
                self.expect("endgenerate")?;
                items.extend(inner);
                continue;
            }
            if txt == "for" {
                items.push(self.parse_gen_for()?);
                continue;
            }
            if txt == "if" {
                items.push(self.parse_gen_if()?);
                continue;
            }
            if txt == "begin" {
                self.next_tok();
                if self.accept(":") {
                    self.expect_id()?;
                }
                let inner = self.parse_items(&["end"])?;
                self.expect("end")?;
                items.extend(inner);
                continue;
            }
            if t.kind == Kind::Id && !is_keyword(txt) {
                items.push(self.parse_instance()?);
                continue;
            }
            return Err(ParseErr::new(t.line, format!("unexpected token `{txt}` in module body")));
        }
    }

    /// A generate construct body: `begin[:label] items end`, or one item.
    fn gen_body(&mut self) -> Result<Vec<Item>, ParseErr> {
        if self.at("begin") {
            self.next_tok();
            if self.accept(":") {
                self.expect_id()?;
            }
            let inner = self.parse_items(&["end"])?;
            self.expect("end")?;
            return Ok(inner);
        }
        self.parse_items_one()
    }

    fn parse_items_one(&mut self) -> Result<Vec<Item>, ParseErr> {
        let t = self.peek();
        let mut items = Vec::new();
        match t.text.as_str() {
            "assign" => {
                let ln = self.next_tok().line;
                let lhs = self.toks_until(&["="])?;
                self.expect("=")?;
                let rhs = self.toks_until(&[";"])?;
                self.expect(";")?;
                items.push(Item::Assign { lhs, rhs, line: ln });
            }
            "for" => items.push(self.parse_gen_for()?),
            "if" => items.push(self.parse_gen_if()?),
            other => {
                return Err(ParseErr::new(t.line, format!("unsupported single generate item `{other}`")))
            }
        }
        Ok(items)
    }

    fn parse_gen_for(&mut self) -> Result<Item, ParseErr> {
        let ln = self.expect("for")?.line;
        self.expect("(")?;
        self.accept("genvar");
        let var = self.expect_id()?.text;
        self.expect("=")?;
        let init = self.toks_until(&[";"])?;
        self.expect(";")?;
        let cond = self.toks_until(&[";"])?;
        self.expect(";")?;
        let step_var = self.expect_id()?.text;
        self.expect("=")?;
        let step = self.toks_until(&[")"])?;
        self.expect(")")?;
        if step_var != var {
            return Err(ParseErr::new(ln, "generate for must step its own genvar".into()));
        }
        let body = self.gen_body()?;
        Ok(Item::GenFor { var, init, cond, step, body })
    }

    fn parse_gen_if(&mut self) -> Result<Item, ParseErr> {
        self.expect("if")?;
        let cond = self.parenthesized()?;
        let then = self.gen_body()?;
        let mut els = Vec::new();
        if self.accept("else") {
            if self.at("if") {
                els = vec![self.parse_gen_if()?];
            } else {
                els = self.gen_body()?;
            }
        }
        Ok(Item::GenIf { cond, then, els })
    }

    fn parse_instance(&mut self) -> Result<Item, ParseErr> {
        let module = self.expect_id()?;
        let mut overrides = Vec::new();
        if self.accept("#") {
            self.expect("(")?;
            while !self.at(")") {
                self.expect(".")?;
                let pname = self.expect_id()?;
                let val = self.parenthesized()?;
                overrides.push((pname.text, val, pname.line));
                if !self.accept(",") {
                    break;
                }
            }
            self.expect(")")?;
        }
        self.expect_id()?; // instance name
        self.expect("(")?;
        let mut conns = Vec::new();
        while !self.at(")") {
            self.expect(".")?;
            let pname = self.expect_id()?;
            let conn = self.parenthesized()?;
            conns.push((pname.text, conn, pname.line));
            if !self.accept(",") {
                break;
            }
        }
        self.expect(")")?;
        self.expect(";")?;
        Ok(Item::Inst { module: module.text, overrides, conns, line: module.line })
    }

    // -- statements (inside always) --
    fn parse_stmt(&mut self) -> Result<Stmt, ParseErr> {
        let t = self.peek();
        let ln = t.line;
        if t.text == "begin" {
            self.next_tok();
            if self.accept(":") {
                self.expect_id()?;
            }
            let mut stmts = Vec::new();
            while !self.at("end") {
                if self.peek().kind == Kind::Eof {
                    return Err(ParseErr::new(ln, "eof in begin block".into()));
                }
                stmts.push(self.parse_stmt()?);
            }
            self.expect("end")?;
            return Ok(Stmt::Block(stmts));
        }
        if t.text == "if" {
            self.next_tok();
            let cond = self.parenthesized()?;
            let then = Box::new(self.parse_stmt()?);
            let mut els = None;
            if self.accept("else") {
                els = Some(Box::new(self.parse_stmt()?));
            }
            return Ok(Stmt::If { cond, then, els, line: ln });
        }
        if t.text == "for" {
            self.next_tok();
            self.expect("(")?;
            let init_toks = self.toks_until(&[";"])?;
            let init = Box::new(split_assign(init_toks, ln));
            self.expect(";")?;
            let cond = self.toks_until(&[";"])?;
            self.expect(";")?;
            let step_toks = self.toks_until(&[")"])?;
            let step = Box::new(split_assign(step_toks, ln));
            self.expect(")")?;
            let body = Box::new(self.parse_stmt()?);
            return Ok(Stmt::For { init, cond, step, body, line: ln });
        }
        let toks = self.toks_until(&[";"])?;
        self.expect(";")?;
        Ok(split_assign(toks, ln))
    }
}

fn split_assign(toks: Vec<Tok>, ln: u32) -> Stmt {
    let mut depth = 0i32;
    for (j, t) in toks.iter().enumerate() {
        if t.kind == Kind::Punct {
            if is_open(&t.text) {
                depth += 1;
            } else if is_close(&t.text) {
                depth -= 1;
            } else if depth == 0 && (t.text == "<=" || t.text == "=") {
                let lhs = toks[..j].to_vec();
                let rhs = toks[j + 1..].to_vec();
                return Stmt::PAssign { lhs, rhs, line: ln };
            }
        }
    }
    Stmt::Expr { toks, line: ln }
}

// ---------------------------------------------------------------------------
// analyzer
// ---------------------------------------------------------------------------

/// Analyze every iteration of a constant generate-for up to this many.
const GEN_UNROLL_CAP: usize = 65536;
/// Beyond the cap: analyze the first/last this many iterations.
const GEN_SAMPLE: usize = 512;
/// Hard stop for runaway constant loops.
const LOOP_GUARD: usize = 1 << 21;

#[derive(Clone, PartialEq, Eq, Debug)]
enum Rng {
    /// No packed range: a 1-bit scalar.
    Scalar,
    /// A range whose bounds did not constant-fold.
    Unknown,
    /// Parameters have no intrinsic packed width.
    Param,
    /// Constant (lo, hi) bit bounds.
    Bits(i64, i64),
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum SymKind {
    Port,
    Param,
    Net,
    Integer,
    Genvar,
}

#[derive(Clone, Debug)]
struct Sym {
    kind: SymKind,
    dir: Option<Dir>,
    rng: Rng,
    unpacked: Vec<Option<i64>>,
    refs: u32,
    /// (site id, constant driven (lo, hi) range if any, line)
    drivers: Vec<(u32, Option<(i64, i64)>, u32)>,
    gen_scoped: bool,
    line: u32,
}

impl Sym {
    fn new(kind: SymKind, rng: Rng, line: u32) -> Self {
        Sym {
            kind,
            dir: None,
            rng,
            unpacked: Vec::new(),
            refs: 0,
            drivers: Vec::new(),
            gen_scoped: false,
            line,
        }
    }
}

/// Constant value / width / flexibility of an expression, where derivable.
#[derive(Clone, Copy, Debug)]
struct ExprInfo {
    val: Option<i64>,
    width: Option<i64>,
    flexible: bool,
}

impl ExprInfo {
    fn unknown() -> Self {
        ExprInfo { val: None, width: None, flexible: false }
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum SelKind {
    Index,
    Range,
    Plus,
    Minus,
}

fn split_top(toks: &[Tok], sep: &str) -> Vec<Vec<Tok>> {
    let mut out = Vec::new();
    let mut cur = Vec::new();
    let mut depth = 0i32;
    for t in toks {
        if t.kind == Kind::Punct {
            if is_open(&t.text) {
                depth += 1;
            } else if is_close(&t.text) {
                depth -= 1;
            } else if t.text == sep && depth == 0 {
                out.push(std::mem::take(&mut cur));
                continue;
            }
        }
        cur.push(t.clone());
    }
    out.push(cur);
    out
}

/// Classify one select group: index/range/plus/minus + part expressions.
fn split_sel(toks: &[Tok]) -> (SelKind, Vec<Vec<Tok>>) {
    let mut depth = 0i32;
    for (j, t) in toks.iter().enumerate() {
        if t.kind == Kind::Punct {
            if is_open(&t.text) {
                depth += 1;
            } else if is_close(&t.text) {
                depth -= 1;
            } else if depth == 0 && t.text == "+:" {
                return (SelKind::Plus, vec![toks[..j].to_vec(), toks[j + 1..].to_vec()]);
            } else if depth == 0 && t.text == "-:" {
                return (SelKind::Minus, vec![toks[..j].to_vec(), toks[j + 1..].to_vec()]);
            } else if depth == 0 && t.text == ":" {
                return (SelKind::Range, vec![toks[..j].to_vec(), toks[j + 1..].to_vec()]);
            }
        }
    }
    (SelKind::Index, vec![toks.to_vec()])
}

struct ModAnalyzer {
    file: String,
    env: Env,
    syms: BTreeMap<String, Sym>,
    genvars: HashSet<String>,
    next_site: u32,
    diags: Vec<Diagnostic>,
    lhs_info: Option<ExprInfo>,
}

impl ModAnalyzer {
    fn new(file: &str) -> Self {
        ModAnalyzer {
            file: file.to_string(),
            env: Env::new(),
            syms: BTreeMap::new(),
            genvars: HashSet::new(),
            next_site: 0,
            diags: Vec::new(),
            lhs_info: None,
        }
    }

    fn diag(&mut self, code: &str, line: u32, msg: String) {
        self.diags.push(Diagnostic::new(code, &self.file, line, msg));
    }

    fn site(&mut self) -> u32 {
        self.next_site += 1;
        self.next_site
    }

    fn add_sym(&mut self, name: &str, sym: Sym, line: u32) -> bool {
        if self.syms.contains_key(name) {
            self.diag("MC010", line, format!("duplicate declaration of `{name}`"));
            false
        } else {
            self.syms.insert(name.to_string(), sym);
            true
        }
    }

    // -- setup: params, localparams, symbols --
    fn run(&mut self, m: &Module, mtab: &BTreeMap<String, Module>) {
        let empty = Env::new();
        for (name, toks, _ln) in &m.params {
            let v = self.const_eval(toks, &empty);
            self.env.insert(name.clone(), v);
        }
        for it in &m.items {
            if let Item::LocalParam { name, toks, .. } = it {
                let v = self.const_eval(toks, &empty);
                self.env.insert(name.clone(), v);
            }
        }

        for p in &m.ports {
            let rng = self.eval_range(p.rng.as_ref());
            let mut s = Sym::new(SymKind::Port, rng, p.line);
            s.dir = p.dir;
            let inserted = self.add_sym(&p.name, s, p.line);
            if inserted && p.dir == Some(Dir::Input) {
                let site = self.site();
                if let Some(s) = self.syms.get_mut(&p.name) {
                    s.drivers.push((site, None, p.line));
                }
            }
        }
        for (name, _toks, ln) in &m.params {
            let name = name.clone();
            self.add_sym(&name, Sym::new(SymKind::Param, Rng::Param, *ln), *ln);
        }
        self.collect_syms(&m.items, false);

        // walk
        let genv = Env::new();
        self.walk_items(&m.items, &genv, mtab);

        // MC005: multiply-driven
        let mut mc005 = Vec::new();
        for (name, s) in &self.syms {
            if matches!(s.kind, SymKind::Genvar | SymKind::Integer | SymKind::Param) {
                continue;
            }
            if s.gen_scoped {
                continue; // per-iteration nets: each elaborated copy has one driver
            }
            if s.drivers.len() > 1 {
                if s.drivers.iter().all(|d| d.1.is_some()) {
                    let mut spans: Vec<(i64, i64)> =
                        s.drivers.iter().map(|d| d.1.unwrap()).collect();
                    spans.sort_unstable();
                    let overlap = spans.windows(2).any(|w| w[0].1 >= w[1].0);
                    if !overlap {
                        continue;
                    }
                }
                let sites: HashSet<u32> = s.drivers.iter().map(|d| d.0).collect();
                if sites.len() > 1 {
                    mc005.push((name.clone(), sites.len(), s.drivers[1].2));
                }
            }
        }
        for (name, n, ln) in mc005 {
            self.diag("MC005", ln, format!("`{name}` driven from {n} sites"));
        }
        // MC006: declared but never referenced
        let mut mc006 = Vec::new();
        for (name, s) in &self.syms {
            if s.dir.is_some() || matches!(s.kind, SymKind::Param | SymKind::Genvar) {
                continue;
            }
            if s.refs == 0 && s.drivers.is_empty() {
                mc006.push((name.clone(), s.line));
            }
        }
        for (name, ln) in mc006 {
            self.diag("MC006", ln, format!("`{name}` is never referenced"));
        }
    }

    fn collect_syms(&mut self, items: &[Item], gen_scoped: bool) {
        let empty = Env::new();
        for it in items {
            match it {
                Item::LocalParam { name, line, .. } => {
                    let name = name.clone();
                    self.add_sym(&name, Sym::new(SymKind::Param, Rng::Param, *line), *line);
                }
                Item::Decl { decl: d, .. } => {
                    if gen_scoped && self.syms.contains_key(&d.name) {
                        continue; // replicated per generate iteration/branch
                    }
                    let mut sizes = Vec::new();
                    for dim in &d.unpacked {
                        match dim {
                            UnpackedDim::Size(a) => sizes.push(self.const_eval(a, &empty)),
                            UnpackedDim::Range(a, b) => {
                                let lo = self.const_eval(a, &empty);
                                let hi = self.const_eval(b, &empty);
                                sizes.push(match (lo, hi) {
                                    (Some(lo), Some(hi)) => Some(hi - lo + 1),
                                    _ => None,
                                });
                            }
                        }
                    }
                    let kind = match d.kind {
                        DeclKind::Net => SymKind::Net,
                        DeclKind::Integer => SymKind::Integer,
                        DeclKind::Genvar => SymKind::Genvar,
                    };
                    let rng = self.eval_range(d.rng.as_ref());
                    let mut s = Sym::new(kind, rng, d.line);
                    s.unpacked = sizes;
                    s.gen_scoped = gen_scoped;
                    self.add_sym(&d.name, s, d.line);
                    if d.kind == DeclKind::Genvar {
                        self.genvars.insert(d.name.clone());
                    }
                }
                Item::GenFor { body, .. } => self.collect_syms(body, true),
                Item::GenIf { cond, then, els } => {
                    let c = self.const_eval(cond, &empty);
                    match c {
                        None => {
                            self.collect_syms(then, true);
                            self.collect_syms(els, true);
                        }
                        Some(c) if c != 0 => self.collect_syms(then, true),
                        Some(_) => self.collect_syms(els, true),
                    }
                }
                _ => {}
            }
        }
    }

    fn eval_range(&mut self, rng: Option<&(Vec<Tok>, Vec<Tok>)>) -> Rng {
        let empty = Env::new();
        match rng {
            None => Rng::Scalar,
            Some((msb_toks, lsb_toks)) => {
                let msb = self.const_eval(msb_toks, &empty);
                let lsb = self.const_eval(lsb_toks, &empty);
                match (msb, lsb) {
                    (Some(m), Some(l)) => Rng::Bits(m.min(l), m.max(l)),
                    _ => Rng::Unknown,
                }
            }
        }
    }

    // -- item walking --
    fn walk_items(&mut self, items: &[Item], genv: &Env, mtab: &BTreeMap<String, Module>) {
        for it in items {
            match it {
                Item::LocalParam { .. } => {}
                Item::Decl { decl: d, init } => {
                    if let Some(init) = init {
                        self.scan_expr(init, genv, d.line);
                        let site = self.site();
                        if let Some(s) = self.syms.get_mut(&d.name) {
                            s.drivers.push((site, None, d.line));
                        }
                    }
                }
                Item::Assign { lhs, rhs, line } => {
                    let site = self.site();
                    self.drive_lhs(lhs, genv, *line, site);
                    self.scan_expr(rhs, genv, *line);
                }
                Item::Always { sens, stmt } => {
                    self.scan_sensitivity(sens);
                    let site = self.site();
                    self.walk_stmt(stmt, genv, site);
                }
                Item::GenFor { var, init, cond, step, body } => {
                    self.walk_gen_for(var, init, cond, step, body, genv, mtab);
                }
                Item::GenIf { cond, then, els } => {
                    let c = self.const_eval(cond, genv);
                    match c {
                        None => {
                            // non-elaborable condition: walk both branches
                            self.walk_items(then, genv, mtab);
                            self.walk_items(els, genv, mtab);
                        }
                        Some(c) if c != 0 => self.walk_items(then, genv, mtab),
                        Some(_) => self.walk_items(els, genv, mtab),
                    }
                }
                Item::Inst { module, overrides, conns, line } => {
                    self.walk_inst(module, overrides, conns, *line, genv, mtab);
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn walk_gen_for(
        &mut self,
        var: &str,
        init: &[Tok],
        cond: &[Tok],
        step: &[Tok],
        body: &[Item],
        genv: &Env,
        mtab: &BTreeMap<String, Module>,
    ) {
        let v0 = self.const_eval(init, genv);
        let v0 = match v0 {
            None => {
                let mut genv2 = genv.clone();
                genv2.insert(var.to_string(), None);
                self.walk_items(body, &genv2, mtab);
                return;
            }
            Some(v) => v,
        };
        // count iterations first to decide sampling
        let mut vals = Vec::new();
        let mut x = v0;
        let mut guard = 0usize;
        loop {
            let mut genv2 = genv.clone();
            genv2.insert(var.to_string(), Some(x));
            let c = self.const_eval(cond, &genv2);
            match c {
                None | Some(0) => break,
                _ => {}
            }
            vals.push(x);
            let x2 = self.const_eval(step, &genv2);
            match x2 {
                None => break,
                Some(x2) if x2 == x => break,
                Some(x2) => x = x2,
            }
            guard += 1;
            if guard > LOOP_GUARD {
                break;
            }
        }
        let sample: Vec<i64> = if vals.len() > GEN_UNROLL_CAP {
            let mut s = vals[..GEN_SAMPLE].to_vec();
            s.extend_from_slice(&vals[vals.len() - GEN_SAMPLE..]);
            s
        } else {
            vals
        };
        for x in sample {
            let mut genv2 = genv.clone();
            genv2.insert(var.to_string(), Some(x));
            self.walk_items(body, &genv2, mtab);
        }
    }

    fn scan_sensitivity(&mut self, sens: &[Tok]) {
        for t in sens {
            if t.kind == Kind::Id && !is_keyword(&t.text) {
                let name = t.text.clone();
                self.ref_read(&name, t.line);
            }
        }
    }

    fn walk_stmt(&mut self, stmt: &Stmt, genv: &Env, site: u32) {
        match stmt {
            Stmt::Block(stmts) => {
                for s in stmts {
                    self.walk_stmt(s, genv, site);
                }
            }
            Stmt::If { cond, then, els, line } => {
                self.scan_expr(cond, genv, *line);
                self.walk_stmt(then, genv, site);
                if let Some(els) = els {
                    self.walk_stmt(els, genv, site);
                }
            }
            Stmt::For { init, cond, step, body, line } => {
                for sub in [init.as_ref(), step.as_ref()] {
                    if let Stmt::PAssign { lhs, rhs, line } = sub {
                        self.drive_lhs(lhs, genv, *line, site);
                        self.scan_expr(rhs, genv, *line);
                    }
                }
                self.scan_expr(cond, genv, *line);
                self.walk_stmt(body, genv, site);
            }
            Stmt::PAssign { lhs, rhs, line } => {
                self.drive_lhs(lhs, genv, *line, site);
                self.scan_expr(rhs, genv, *line);
            }
            Stmt::Expr { toks, line } => {
                self.scan_expr(toks, genv, *line);
            }
        }
    }

    // -- instances --
    fn walk_inst(
        &mut self,
        modname: &str,
        overrides: &[(String, Vec<Tok>, u32)],
        conns: &[(String, Vec<Tok>, u32)],
        ln: u32,
        genv: &Env,
        mtab: &BTreeMap<String, Module>,
    ) {
        let target = mtab.get(modname);
        if target.is_none() {
            self.diag("MC007", ln, format!("instantiation of unknown module `{modname}`"));
        }
        // parameter env of the instantiated module
        let mut tenv = Env::new();
        match target {
            Some(t) => {
                let pnames: HashSet<&str> = t.params.iter().map(|p| p.0.as_str()).collect();
                let mut over: Env = Env::new();
                for (pname, vtoks, pln) in overrides {
                    if !pnames.contains(pname.as_str()) {
                        self.diag("MC008", *pln, format!("`{modname}` has no parameter `{pname}`"));
                    }
                    let v = self.const_eval(vtoks, genv);
                    over.insert(pname.clone(), v);
                    self.scan_expr(vtoks, genv, *pln);
                }
                for (pname, dflt, _pln) in &t.params {
                    let v = match over.get(pname) {
                        Some(v) => *v,
                        None => const_eval_in(dflt, &tenv),
                    };
                    tenv.insert(pname.clone(), v);
                }
                for jt in &t.items {
                    if let Item::LocalParam { name, toks, .. } = jt {
                        let v = const_eval_in(toks, &tenv);
                        tenv.insert(name.clone(), v);
                    }
                }
            }
            None => {
                for (_pname, vtoks, pln) in overrides {
                    self.scan_expr(vtoks, genv, *pln);
                }
            }
        }
        for (pname, conn, pln) in conns {
            let fp: Option<&Port> =
                target.and_then(|t| t.ports.iter().find(|p| p.name == *pname));
            if target.is_some() && fp.is_none() {
                self.diag("MC008", *pln, format!("`{modname}` has no port `{pname}`"));
            }
            if conn.is_empty() {
                continue; // explicitly unconnected: .out_exp()
            }
            let drives = matches!(fp, Some(p) if p.dir == Some(Dir::Output));
            let info = if drives {
                let site = self.site();
                self.drive_lhs(conn, genv, *pln, site);
                self.lhs_info
            } else {
                Some(self.scan_expr(conn, genv, *pln))
            };
            self.check_conn_width(modname, pname, fp, &tenv, info, *pln);
        }
    }

    fn check_conn_width(
        &mut self,
        modname: &str,
        pname: &str,
        fp: Option<&Port>,
        tenv: &Env,
        info: Option<ExprInfo>,
        ln: u32,
    ) {
        let (fp, info) = match (fp, info) {
            (Some(fp), Some(info)) => (fp, info),
            _ => return,
        };
        let formal = match &fp.rng {
            None => 1,
            Some((msb_toks, lsb_toks)) => {
                let msb = const_eval_in(msb_toks, tenv);
                let lsb = const_eval_in(lsb_toks, tenv);
                match (msb, lsb) {
                    (Some(m), Some(l)) => (m - l).abs() + 1,
                    _ => return,
                }
            }
        };
        if info.flexible || info.width.is_none() {
            return;
        }
        let w = info.width.unwrap();
        if w != formal {
            self.diag(
                "MC004",
                ln,
                format!("port `{pname}` of `{modname}` is {formal} bits but connection is {w} bits"),
            );
        }
    }

    // -- reference bookkeeping --
    fn ref_read(&mut self, name: &str, ln: u32) {
        if let Some(s) = self.syms.get_mut(name) {
            s.refs += 1;
            return;
        }
        if self.env.contains_key(name) || self.genvars.contains(name) {
            return;
        }
        self.diag("MC001", ln, format!("`{name}` is not declared"));
    }

    /// LHS of an assignment / output-port connection.
    fn drive_lhs(&mut self, toks: &[Tok], genv: &Env, ln: u32, site: u32) {
        self.lhs_info = None;
        if toks.is_empty() {
            return;
        }
        if toks[0].kind == Kind::Punct && toks[0].text == "{" {
            // concat LHS: drive each element
            let inner: &[Tok] = if toks.len() > 1 { &toks[1..toks.len() - 1] } else { &[] };
            for part in split_top(inner, ",") {
                self.drive_lhs(&part, genv, ln, site);
            }
            self.lhs_info = None;
            return;
        }
        let t0 = toks[0].clone();
        if t0.kind != Kind::Id || is_keyword(&t0.text) {
            self.scan_expr(toks, genv, ln);
            return;
        }
        let name = t0.text;
        let (srng, sunpacked, skind) = match self.syms.get(&name) {
            None => {
                if !self.genvars.contains(&name) && !self.env.contains_key(&name) {
                    self.diag("MC001", t0.line, format!("`{name}` is not declared"));
                }
                // genvar loop index: not a driver site
                if toks.len() > 1 {
                    self.scan_expr(toks, genv, ln);
                }
                return;
            }
            Some(s) => (s.rng.clone(), s.unpacked.clone(), s.kind),
        };
        // parse trailing selects: reads for the index exprs + bounds checks
        let rng = self.check_selects(&srng, &sunpacked, &name, &toks[1..], genv, ln);
        if matches!(skind, SymKind::Genvar | SymKind::Integer) {
            return;
        }
        if let Some(s) = self.syms.get_mut(&name) {
            s.drivers.push((site, rng, ln));
        }
        let mut w = None;
        if let Some((lo, hi)) = rng {
            w = Some(hi - lo + 1);
        } else if toks.len() == 1 {
            match &srng {
                Rng::Scalar if sunpacked.is_empty() => w = Some(1),
                Rng::Bits(lo, hi) if sunpacked.is_empty() => w = Some(hi - lo + 1),
                _ => {}
            }
        }
        self.lhs_info = Some(ExprInfo { val: None, width: w, flexible: false });
    }

    /// Walk `[...]` select groups after an identifier; returns the final
    /// constant (lo, hi) bit range into the packed vector, if known.
    #[allow(clippy::too_many_arguments)]
    fn check_selects(
        &mut self,
        srng: &Rng,
        sunpacked: &[Option<i64>],
        name: &str,
        sel_toks: &[Tok],
        genv: &Env,
        ln: u32,
    ) -> Option<(i64, i64)> {
        let mut groups: Vec<Vec<Tok>> = Vec::new();
        let mut i = 0usize;
        while i < sel_toks.len() {
            if sel_toks[i].text != "[" {
                // stray tokens after selects: scan conservatively
                self.scan_expr(&sel_toks[i..], genv, ln);
                break;
            }
            let mut depth = 1i32;
            let mut j = i + 1;
            while j < sel_toks.len() && depth > 0 {
                let t = &sel_toks[j];
                if t.kind == Kind::Punct {
                    if is_open(&t.text) {
                        depth += 1;
                    } else if is_close(&t.text) {
                        depth -= 1;
                    }
                }
                j += 1;
            }
            let hi = if j > i + 1 { j - 1 } else { i + 1 };
            groups.push(sel_toks[i + 1..hi].to_vec());
            i = j;
        }
        let mut unpacked_left: Vec<Option<i64>> = sunpacked.to_vec();
        let mut final_rng: Option<(i64, i64)> = None;
        let mut cur_rng: Rng = srng.clone();
        for g in &groups {
            let (kind, exprs) = split_sel(g);
            for p in &exprs {
                self.scan_expr(p, genv, ln);
            }
            let vals: Vec<Option<i64>> = exprs.iter().map(|e| self.const_eval(e, genv)).collect();
            if !unpacked_left.is_empty() {
                let size = unpacked_left.remove(0);
                if kind == SelKind::Index {
                    if let (Some(v), Some(sz)) = (vals[0], size) {
                        if !(0 <= v && v < sz) {
                            self.diag(
                                "MC003",
                                ln,
                                format!("`{name}` index {v} outside [0:{}]", sz - 1),
                            );
                        }
                    }
                } else {
                    self.diag("MC003", ln, format!("part-select on unpacked dimension of `{name}`"));
                }
                continue;
            }
            if matches!(cur_rng, Rng::Unknown | Rng::Param) {
                continue;
            }
            let (lo, hi) = match cur_rng {
                Rng::Scalar => (0, 0),
                Rng::Bits(l, h) => (l, h),
                _ => unreachable!(),
            };
            match kind {
                SelKind::Index => {
                    if let Some(v) = vals[0] {
                        if !(lo <= v && v <= hi) {
                            self.diag("MC003", ln, format!("`{name}[{v}]` outside [{hi}:{lo}]"));
                        }
                        final_rng = Some((v, v));
                    }
                    cur_rng = Rng::Scalar; // further selects treated as 1-bit
                }
                SelKind::Range => {
                    if let (Some(a), Some(b)) = (vals[0], vals[1]) {
                        if a < b {
                            self.diag("MC002", ln, format!("reversed part-select `{name}[{a}:{b}]`"));
                        } else if !(lo <= b && a <= hi) {
                            self.diag(
                                "MC003",
                                ln,
                                format!("`{name}[{a}:{b}]` outside [{hi}:{lo}]"),
                            );
                        } else {
                            final_rng = Some((b, a));
                        }
                    }
                }
                SelKind::Plus => {
                    let (base, w) = (vals[0], vals[1]);
                    if let Some(w) = w {
                        if w <= 0 {
                            self.diag("MC002", ln, format!("empty `+:` width {w} on `{name}`"));
                            continue;
                        }
                    }
                    if let (Some(base), Some(w)) = (base, w) {
                        if !(lo <= base && base + w - 1 <= hi) {
                            self.diag(
                                "MC003",
                                ln,
                                format!("`{name}[{base} +: {w}]` outside [{hi}:{lo}]"),
                            );
                        } else {
                            final_rng = Some((base, base + w - 1));
                        }
                    }
                }
                SelKind::Minus => {
                    let (base, w) = (vals[0], vals[1]);
                    if let Some(w) = w {
                        if w <= 0 {
                            self.diag("MC002", ln, format!("empty `-:` width {w} on `{name}`"));
                            continue;
                        }
                    }
                    if let (Some(base), Some(w)) = (base, w) {
                        if !(lo <= base - w + 1 && base <= hi) {
                            self.diag(
                                "MC003",
                                ln,
                                format!("`{name}[{base} -: {w}]` outside [{hi}:{lo}]"),
                            );
                        } else {
                            final_rng = Some((base - w + 1, base));
                        }
                    }
                }
            }
        }
        final_rng
    }

    // -- expressions --
    /// Scan an expression: record reads, run select checks, and return
    /// the constant value / width / flexibility when derivable.
    fn scan_expr(&mut self, toks: &[Tok], genv: &Env, ln: u32) -> ExprInfo {
        let mut p = Ep { an: Some(self), toks, env: genv, ln, silent: false, i: 0 };
        match p.expr() {
            Ok(info) => info,
            Err(_) => ExprInfo::unknown(),
        }
    }

    /// Constant evaluation must not double-report: diagnostics and ref
    /// counting happen in scan; here we evaluate silently.
    fn const_eval(&mut self, toks: &[Tok], genv: &Env) -> Option<i64> {
        let saved = self.diags.len();
        let r = {
            let mut p = Ep { an: Some(self), toks, env: genv, ln: 0, silent: true, i: 0 };
            match p.expr() {
                Ok(info) => info.val,
                Err(_) => None,
            }
        };
        self.diags.truncate(saved);
        r
    }
}

/// Evaluate with a plain env only (no module symbols).
fn const_eval_in(toks: &[Tok], env: &Env) -> Option<i64> {
    let mut p = Ep { an: None, toks, env, ln: 0, silent: true, i: 0 };
    match p.expr() {
        Ok(info) => info.val,
        Err(_) => None,
    }
}

// ---------------------------------------------------------------------------
// expression evaluator
// ---------------------------------------------------------------------------

/// Unevaluable expression (out of the supported subset).
struct Bail;

/// Pratt-style expression parser: records reads + select checks via the
/// owning `ModAnalyzer` (unless silent) and computes constant value /
/// width / flexibility where derivable.
struct Ep<'a, 'e> {
    an: Option<&'a mut ModAnalyzer>,
    toks: &'e [Tok],
    env: &'e Env,
    ln: u32,
    silent: bool,
    i: usize,
}

const LEVELS: &[&[&str]] = &[
    &["||"],
    &["&&"],
    &["|"],
    &["^"],
    &["&"],
    &["==", "!="],
    &["<", ">", "<=", ">="],
    &["<<", ">>"],
    &["+", "-"],
    &["*", "/", "%"],
];

impl Ep<'_, '_> {
    fn peek(&self) -> Tok {
        self.toks.get(self.i).cloned().unwrap_or_else(|| eof_tok(self.ln))
    }

    fn next_tok(&mut self) -> Tok {
        let t = self.peek();
        self.i += 1;
        t
    }

    fn at(&self, txt: &str) -> bool {
        let t = self.peek();
        t.kind == Kind::Punct && t.text == txt
    }

    fn expr(&mut self) -> Result<ExprInfo, Bail> {
        let mut info = self.ternary()?;
        // trailing junk is tolerated (scanned conservatively)
        while self.peek().kind != Kind::Eof {
            let t = self.next_tok();
            if t.kind == Kind::Id && !is_keyword(&t.text) {
                self.read(&t.text, t.line);
            }
            info = ExprInfo::unknown();
        }
        Ok(info)
    }

    fn read(&mut self, name: &str, ln: u32) {
        if self.silent {
            return;
        }
        if let Some(an) = self.an.as_deref_mut() {
            an.ref_read(name, ln);
        }
    }

    fn lookup(&self, name: &str) -> Option<i64> {
        if let Some(v) = self.env.get(name) {
            return *v;
        }
        if let Some(an) = self.an.as_deref() {
            if let Some(v) = an.env.get(name) {
                return *v;
            }
        }
        None
    }

    fn ternary(&mut self) -> Result<ExprInfo, Bail> {
        let c = self.binary(0)?;
        if self.at("?") {
            self.next_tok();
            let a = self.ternary()?;
            if self.at(":") {
                self.next_tok();
            }
            let b = self.ternary()?;
            if let Some(cv) = c.val {
                return Ok(if cv != 0 { a } else { b });
            }
            let w = if a.width == b.width { a.width } else { None };
            return Ok(ExprInfo { val: None, width: w, flexible: a.flexible && b.flexible });
        }
        Ok(c)
    }

    fn binary(&mut self, lvl: usize) -> Result<ExprInfo, Bail> {
        if lvl >= LEVELS.len() {
            return self.unary();
        }
        let ops = LEVELS[lvl];
        let mut left = self.binary(lvl + 1)?;
        loop {
            let t = self.peek();
            if t.kind == Kind::Punct && ops.contains(&t.text.as_str()) {
                let op = self.next_tok().text;
                let right = self.binary(lvl + 1)?;
                left = apply(&op, left, right);
            } else {
                return Ok(left);
            }
        }
    }

    fn unary(&mut self) -> Result<ExprInfo, Bail> {
        let t = self.peek();
        if t.kind == Kind::Punct && matches!(t.text.as_str(), "!" | "~" | "-" | "+" | "&" | "|" | "^")
        {
            let op = self.next_tok().text;
            let a = self.unary()?;
            let av = match a.val {
                None => return Ok(ExprInfo::unknown()),
                Some(v) => v,
            };
            let v: Option<i64> = match op.as_str() {
                "!" => Some((av == 0) as i64),
                "~" => Some(!av),
                "-" => av.checked_neg(),
                "+" => Some(av),
                // approximate reductions
                "&" => Some((av != 0) as i64),
                "|" => Some((av != 0) as i64),
                _ => None, // "^"
            };
            return Ok(match v {
                Some(v) => ExprInfo { val: Some(v), width: None, flexible: false },
                None => ExprInfo::unknown(),
            });
        }
        self.primary()
    }

    fn primary(&mut self) -> Result<ExprInfo, Bail> {
        let t = self.next_tok();
        let ln = t.line;
        if t.kind == Kind::Num {
            let (w, v, flex) = num_info(&t.text);
            return Ok(ExprInfo { val: v, width: w, flexible: flex });
        }
        if t.kind == Kind::Sys {
            // $clog2(expr) and friends
            if self.at("(") {
                self.next_tok();
                let mut depth = 1i32;
                let mut inner = Vec::new();
                while depth > 0 {
                    let u = self.next_tok();
                    if u.kind == Kind::Eof {
                        return Err(Bail);
                    }
                    if u.kind == Kind::Punct && u.text == "(" {
                        depth += 1;
                    } else if u.kind == Kind::Punct && u.text == ")" {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    inner.push(u);
                }
                let a = {
                    let mut sub = Ep {
                        an: self.an.as_deref_mut(),
                        toks: &inner,
                        env: self.env,
                        ln,
                        silent: self.silent,
                        i: 0,
                    };
                    sub.expr()?
                };
                if t.text == "$clog2" {
                    if let Some(v) = a.val {
                        if v >= 0 {
                            return Ok(ExprInfo { val: Some(clog2(v)), width: None, flexible: true });
                        }
                    }
                }
                return Ok(ExprInfo::unknown());
            }
            return Ok(ExprInfo::unknown());
        }
        if t.kind == Kind::Punct && t.text == "(" {
            let inner = self.balanced_until(")")?;
            let mut sub = Ep {
                an: self.an.as_deref_mut(),
                toks: &inner,
                env: self.env,
                ln,
                silent: self.silent,
                i: 0,
            };
            return sub.ternary_all();
        }
        if t.kind == Kind::Punct && t.text == "{" {
            let inner = self.balanced_until("}")?;
            return self.concat(&inner, ln);
        }
        if t.kind == Kind::Id && !is_keyword(&t.text) {
            self.read(&t.text, ln);
            let v = self.lookup(&t.text);
            // trailing selects
            let mut sel: Vec<Vec<Tok>> = Vec::new();
            while self.at("[") {
                self.next_tok();
                sel.push(self.balanced_until("]")?);
            }
            if !sel.is_empty() {
                return self.select_info(&t.text, &sel, ln);
            }
            let mut width = None;
            if let Some(an) = self.an.as_deref() {
                if let Some(s) = an.syms.get(&t.text) {
                    if s.unpacked.is_empty() {
                        match s.rng {
                            Rng::Scalar => width = Some(1),
                            Rng::Bits(lo, hi) => width = Some(hi - lo + 1),
                            _ => {}
                        }
                    }
                }
            }
            if let Some(v) = v {
                return Ok(ExprInfo { val: Some(v), width, flexible: width.is_none() });
            }
            return Ok(ExprInfo { val: None, width, flexible: false });
        }
        Err(Bail)
    }

    fn ternary_all(&mut self) -> Result<ExprInfo, Bail> {
        let info = self.ternary()?;
        if self.peek().kind != Kind::Eof {
            while self.peek().kind != Kind::Eof {
                let t = self.next_tok();
                if t.kind == Kind::Id && !is_keyword(&t.text) {
                    self.read(&t.text, t.line);
                }
            }
            return Ok(ExprInfo::unknown());
        }
        Ok(info)
    }

    fn balanced_until(&mut self, close: &str) -> Result<Vec<Tok>, Bail> {
        let mut depth = 1i32;
        let mut out = Vec::new();
        loop {
            let t = self.next_tok();
            if t.kind == Kind::Eof {
                return Err(Bail);
            }
            if t.kind == Kind::Punct {
                if is_open(&t.text) {
                    depth += 1;
                } else if is_close(&t.text) {
                    depth -= 1;
                    if depth == 0 {
                        debug_assert_eq!(t.text, close);
                        break;
                    }
                }
            }
            out.push(t);
        }
        Ok(out)
    }

    /// Identifier followed by select groups: run the analyzer's bounds
    /// checks and derive the selected width.
    fn select_info(&mut self, name: &str, sel_groups: &[Vec<Tok>], ln: u32) -> Result<ExprInfo, Bail> {
        if self.silent {
            return Ok(ExprInfo::unknown());
        }
        let env = self.env;
        let an = match self.an.as_deref_mut() {
            Some(a) => a,
            None => return Ok(ExprInfo::unknown()),
        };
        let (srng, sunpacked) = match an.syms.get(name) {
            // undeclared already reported by self.read
            None => return Ok(ExprInfo::unknown()),
            Some(s) => (s.rng.clone(), s.unpacked.clone()),
        };
        let mut flat: Vec<Tok> = Vec::new();
        for g in sel_groups {
            flat.push(tok(Kind::Punct, "[", ln));
            flat.extend(g.iter().cloned());
            flat.push(tok(Kind::Punct, "]", ln));
        }
        let rng = an.check_selects(&srng, &sunpacked, name, &flat, env, ln);
        if let Some((lo, hi)) = rng {
            return Ok(ExprInfo { val: None, width: Some(hi - lo + 1), flexible: false });
        }
        // non-const select of a packed vector: single index = 1 bit wide
        let unpacked = sunpacked.len();
        let packed_groups = sel_groups.len() as i64 - unpacked as i64;
        if packed_groups == 1 && split_sel(sel_groups.last().unwrap()).0 == SelKind::Index {
            return Ok(ExprInfo { val: None, width: Some(1), flexible: false });
        }
        if packed_groups <= 0 && unpacked > 0 && sel_groups.len() == unpacked {
            // full unpacked index: element width = packed range
            match srng {
                Rng::Bits(lo, hi) => {
                    return Ok(ExprInfo { val: None, width: Some(hi - lo + 1), flexible: false })
                }
                Rng::Scalar => return Ok(ExprInfo { val: None, width: Some(1), flexible: false }),
                _ => {}
            }
        }
        Ok(ExprInfo::unknown())
    }

    /// `{a, b, c}` or replication `{N{expr}}`.
    fn concat(&mut self, inner: &[Tok], ln: u32) -> Result<ExprInfo, Bail> {
        let parts = split_top(inner, ",");
        if parts.len() == 1 {
            let p0 = &parts[0];
            let mut depth = 0i32;
            for (j, t) in p0.iter().enumerate() {
                if t.kind == Kind::Punct {
                    if t.text == "{" && depth == 0 && j > 0 {
                        let count_toks = &p0[..j];
                        // inner body is p0[j+1..len-1] (strip the closing '}')
                        let body: &[Tok] =
                            if p0.len() > j + 1 { &p0[j + 1..p0.len() - 1] } else { &[] };
                        let cnt = {
                            let mut s = Ep {
                                an: self.an.as_deref_mut(),
                                toks: count_toks,
                                env: self.env,
                                ln,
                                silent: true,
                                i: 0,
                            };
                            s.safe_val()
                        };
                        let b = {
                            let mut s = Ep {
                                an: self.an.as_deref_mut(),
                                toks: body,
                                env: self.env,
                                ln,
                                silent: self.silent,
                                i: 0,
                            };
                            s.ternary_all()?
                        };
                        {
                            // count tokens are reads too
                            let mut s = Ep {
                                an: self.an.as_deref_mut(),
                                toks: count_toks,
                                env: self.env,
                                ln,
                                silent: self.silent,
                                i: 0,
                            };
                            let _ = s.ternary_all();
                        }
                        if let Some(c) = cnt {
                            if c < 0 {
                                if !self.silent {
                                    if let Some(an) = self.an.as_deref_mut() {
                                        an.diag("MC002", ln, format!("negative replication count {c}"));
                                    }
                                }
                                return Ok(ExprInfo::unknown());
                            }
                        }
                        if let (Some(c), Some(w)) = (cnt, b.width) {
                            return Ok(ExprInfo { val: None, width: Some(c * w), flexible: false });
                        }
                        if cnt == Some(0) {
                            return Ok(ExprInfo { val: None, width: Some(0), flexible: false });
                        }
                        return Ok(ExprInfo::unknown());
                    }
                    if is_open(&t.text) {
                        depth += 1;
                    } else if is_close(&t.text) {
                        depth -= 1;
                    }
                }
            }
        }
        let mut total = 0i64;
        let mut known = true;
        for p in &parts {
            let info = {
                let mut s = Ep {
                    an: self.an.as_deref_mut(),
                    toks: p,
                    env: self.env,
                    ln,
                    silent: self.silent,
                    i: 0,
                };
                s.ternary_all()?
            };
            match info.width {
                None => known = false,
                Some(w) => total += w,
            }
        }
        if known && !parts.is_empty() {
            return Ok(ExprInfo { val: None, width: Some(total), flexible: false });
        }
        Ok(ExprInfo::unknown())
    }

    fn safe_val(&mut self) -> Option<i64> {
        match self.ternary_all() {
            Ok(info) => info.val,
            Err(_) => None,
        }
    }
}

fn apply(op: &str, a: ExprInfo, b: ExprInfo) -> ExprInfo {
    let (x, y) = match (a.val, b.val) {
        (Some(x), Some(y)) => (x, y),
        _ => return ExprInfo::unknown(),
    };
    let v: Option<i64> = match op {
        "||" => Some(((x != 0) || (y != 0)) as i64),
        "&&" => Some(((x != 0) && (y != 0)) as i64),
        "|" => Some(x | y),
        "^" => Some(x ^ y),
        "&" => Some(x & y),
        "==" => Some((x == y) as i64),
        "!=" => Some((x != y) as i64),
        "<" => Some((x < y) as i64),
        ">" => Some((x > y) as i64),
        "<=" => Some((x <= y) as i64),
        ">=" => Some((x >= y) as i64),
        "<<" => {
            if (0..64).contains(&y) {
                x.checked_shl(y as u32)
            } else {
                None
            }
        }
        ">>" => {
            if (0..64).contains(&y) {
                Some(x >> (y as u32))
            } else {
                None
            }
        }
        "+" => x.checked_add(y),
        "-" => x.checked_sub(y),
        "*" => x.checked_mul(y),
        "/" => {
            if y != 0 {
                Some(x.div_euclid(y))
            } else {
                None
            }
        }
        "%" => {
            if y != 0 {
                Some(x.rem_euclid(y))
            } else {
                None
            }
        }
        _ => None,
    };
    match v {
        Some(v) => ExprInfo { val: Some(v), width: None, flexible: false },
        None => ExprInfo::unknown(),
    }
}

fn clog2(v: i64) -> i64 {
    if v <= 1 {
        0
    } else {
        64 - ((v - 1) as u64).leading_zeros() as i64
    }
}

// ---------------------------------------------------------------------------
// file-set entry point
// ---------------------------------------------------------------------------

/// Parse and analyze a set of named sources together (cross-file module
/// table). Returns deduplicated diagnostics plus the module table for
/// follow-on contract checks.
pub fn check_files(files: &BTreeMap<String, String>) -> (Vec<Diagnostic>, BTreeMap<String, Module>) {
    let mut diags = Vec::new();
    let mut mtab: BTreeMap<String, Module> = BTreeMap::new();
    let mut parsed: Vec<(String, Vec<Module>)> = Vec::new();
    for (fname, src) in files {
        match tokenize(src).and_then(|toks| Parser::new(toks).parse_file()) {
            Ok(mods) => {
                for m in &mods {
                    mtab.insert(m.name.clone(), m.clone());
                }
                parsed.push((fname.clone(), mods));
            }
            Err(e) => diags.push(Diagnostic::new("MC009", fname, e.line, e.msg)),
        }
    }
    for (fname, mods) in &parsed {
        for m in mods {
            let mut an = ModAnalyzer::new(fname);
            an.run(m, &mtab);
            diags.append(&mut an.diags);
        }
    }
    // dedup (code, file, line, message)
    let mut seen = HashSet::new();
    let mut out = Vec::new();
    for d in diags {
        if seen.insert((d.code.clone(), d.file.clone(), d.line, d.message.clone())) {
            out.push(d);
        }
    }
    (out, mtab)
}

/// Evaluated default parameters + localparams of a module, for the
/// cross-layer contract checks.
pub fn params_of(mtab: &BTreeMap<String, Module>, name: &str) -> Option<Env> {
    let m = mtab.get(name)?;
    let mut env = Env::new();
    for (pname, toks, _ln) in &m.params {
        let v = const_eval_in(toks, &env);
        env.insert(pname.clone(), v);
    }
    for it in &m.items {
        if let Item::LocalParam { name, toks, .. } = it {
            let v = const_eval_in(toks, &env);
            env.insert(name.clone(), v);
        }
    }
    Some(env)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_one(src: &str) -> Vec<Diagnostic> {
        let mut files = BTreeMap::new();
        files.insert("t.sv".to_string(), src.to_string());
        check_files(&files).0
    }

    fn codes(src: &str) -> Vec<String> {
        run_one(src).iter().map(|d| d.code.clone()).collect()
    }

    #[test]
    fn tokenizer_basics() {
        let toks = tokenize("assign a = b + 2'b01; // x\n/* y */ wire w;").unwrap();
        let texts: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, ["assign", "a", "=", "b", "+", "2'b01", ";", "wire", "w", ";"]);
        assert_eq!(toks[5].kind, Kind::Num);
        assert_eq!(toks[7].line, 2);
    }

    #[test]
    fn num_info_widths_and_values() {
        assert_eq!(num_info("2'b01"), (Some(2), Some(1), false));
        assert_eq!(num_info("8'd255"), (Some(8), Some(255), false));
        assert_eq!(num_info("16'hff"), (Some(16), Some(255), false));
        assert_eq!(num_info("'0"), (None, Some(0), true));
        assert_eq!(num_info("42"), (None, Some(42), true));
        assert_eq!(num_info("4'bxxxx"), (Some(4), None, false));
    }

    #[test]
    fn clean_module_no_diags() {
        let d = run_one(
            "module m #(parameter W = 8) (input logic clk, input logic [W-1:0] a, output logic [W-1:0] y);\n  always_ff @(posedge clk) y <= a + 1'b1;\nendmodule\n",
        );
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn mc001_undeclared_identifier() {
        assert!(codes("module m (input logic a, output logic y);\n  assign y = a & missing;\nendmodule\n").contains(&"MC001".to_string()));
    }

    #[test]
    fn mc002_reversed_part_select() {
        let c = codes(
            "module m (input logic [7:0] a, output logic [7:0] y);\n  assign y = {a[3:5], a[7:3]};\nendmodule\n",
        );
        assert!(c.contains(&"MC002".to_string()), "{c:?}");
    }

    #[test]
    fn mc003_select_out_of_bounds() {
        let c = codes(
            "module m (input logic [7:0] a, output logic y);\n  assign y = a[8];\nendmodule\n",
        );
        assert_eq!(c, ["MC003"]);
        let ok = codes(
            "module m (input logic [7:0] a, output logic y);\n  assign y = a[7];\nendmodule\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn mc004_port_width_mismatch() {
        let src = "module sub (input logic [31:0] d, output logic q);\n  assign q = ^d;\nendmodule\nmodule top (input logic [7:0] x, output logic y);\n  sub u (.d(x), .q(y));\nendmodule\n";
        let c = codes(src);
        assert_eq!(c, ["MC004"]);
    }

    #[test]
    fn mc004_respects_parameter_overrides() {
        let src = "module sub #(parameter W = 8) (input logic [W-1:0] d, output logic q);\n  assign q = ^d;\nendmodule\nmodule top (input logic [15:0] x, output logic y);\n  sub #(.W(16)) u (.d(x), .q(y));\nendmodule\n";
        let c = codes(src);
        assert!(c.is_empty(), "{c:?}");
    }

    #[test]
    fn mc005_multiply_driven() {
        let src = "module m (input logic a, input logic b, output logic y);\n  logic t;\n  assign t = a;\n  assign t = b;\n  assign y = t;\nendmodule\n";
        assert_eq!(codes(src), ["MC005"]);
        // disjoint constant ranges are one driver each: no diagnostic
        let ok = "module m (input logic a, output logic [1:0] y);\n  assign y[0] = a;\n  assign y[1] = ~a;\nendmodule\n";
        assert!(codes(ok).is_empty());
    }

    #[test]
    fn mc006_unused_declaration() {
        let src = "module m (input logic a, output logic y);\n  logic spare;\n  assign y = a;\nendmodule\n";
        assert_eq!(codes(src), ["MC006"]);
    }

    #[test]
    fn mc007_mc008_unknown_module_and_port() {
        let c = codes("module m (input logic a, output logic y);\n  ghost u (.p(a), .q(y));\n  assign y = a;\nendmodule\n");
        assert!(c.contains(&"MC007".to_string()), "{c:?}");
        let src = "module sub (input logic d, output logic q);\n  assign q = d;\nendmodule\nmodule top (input logic a, output logic y);\n  sub u (.d(a), .nope(y));\nendmodule\n";
        let c = codes(src);
        assert!(c.contains(&"MC008".to_string()), "{c:?}");
    }

    #[test]
    fn mc009_parse_error() {
        assert_eq!(codes("module m (input logic a;\n"), ["MC009"]);
    }

    #[test]
    fn mc010_duplicate_declaration() {
        let src = "module m (input logic a, output logic y);\n  logic t;\n  logic t;\n  assign t = a;\n  assign y = t;\nendmodule\n";
        assert_eq!(codes(src), ["MC010"]);
    }

    #[test]
    fn generate_scoped_decls_do_not_false_positive() {
        let src = "module m #(parameter N = 4) (input logic [N-1:0] a, output logic [N-1:0] y);\n  genvar g;\n  generate\n    for (g = 0; g < N; g = g + 1) begin : lane\n      logic t;\n      assign t = a[g];\n      assign y[g] = t;\n    end\n  endgenerate\nendmodule\n";
        let c = codes(src);
        assert!(c.is_empty(), "{c:?}");
    }

    #[test]
    fn params_of_evaluates_defaults_and_localparams() {
        let src = "module m #(parameter W = 8, parameter D = W * 2) (input logic a, output logic y);\n  localparam TOTAL = D + 1;\n  assign y = a;\nendmodule\n";
        let mut files = BTreeMap::new();
        files.insert("t.sv".to_string(), src.to_string());
        let (d, mtab) = check_files(&files);
        assert!(d.is_empty(), "{d:?}");
        let env = params_of(&mtab, "m").unwrap();
        assert_eq!(env.get("W"), Some(&Some(8)));
        assert_eq!(env.get("D"), Some(&Some(16)));
        assert_eq!(env.get("TOTAL"), Some(&Some(17)));
    }
}


