//! # MASE-RS
//!
//! A dataflow compiler for efficient LLM inference using custom
//! microscaling (MX) formats — a from-scratch reproduction of the MASE
//! paper as a three-layer Rust + JAX + Pallas system.
//!
//! The crate is the paper's Layer-3 contribution: the co-design compiler.
//! Model numerics run behind the [`runtime::ExecBackend`] abstraction —
//! either AOT-lowered HLO artifacts (produced once by
//! `python/compile/aot.py`) through the PJRT adapter, or the artifact-free
//! packed-arithmetic CPU interpreter (`--backend cpu`) — and the crate
//! owns everything else: the MASE IR ([`ir`]), the numeric format library
//! ([`formats`]), the bit-packed MX tensor storage and integer-datapath
//! kernels ([`packed`]), the pass pipeline ([`passes`]), the search algorithms
//! and the persistent evaluation cache ([`search`]), the hardware cost
//! models ([`hw`]), the dataflow simulator ([`sim`]), the SystemVerilog
//! emitter ([`emit`]), the synthetic data substrate ([`data`]), the
//! deterministic tracing/metrics layer ([`obs`]), the HTTP inference
//! service with its continuous-batching decode scheduler ([`serve`])
//! and the end-to-end coordinator ([`coordinator`]).
//!
//! A module-by-module map to the paper's sections and figures lives in
//! `docs/ARCHITECTURE.md` at the repository root.
//!
//! ## Quickstart
//!
//! Build and test (the tier-1 gate), then run the flow end to end:
//!
//! ```text
//! scripts/ci.sh                       # fmt + clippy + doc + build + test
//! cargo run --release -- search --model opt-125m-sim --task sst2
//! cargo run --release -- sweep --cache artifacts/eval_cache.json
//! cargo bench --bench fig4_search_algorithms
//! ```
//!
//! Programmatic use mirrors the CLI: open a [`coordinator::Session`],
//! build a [`coordinator::FlowConfig`] (one model/task/format) or a
//! [`coordinator::SweepConfig`] (the whole Fig. 6 grid) and call
//! [`coordinator::run_flow`] / [`coordinator::run_sweep`]. Lower-level
//! entry points: [`passes::run_search_cached`] for one search against a
//! caller-owned memo cache, and [`search::run_batched`] to drive a bare
//! objective without the evaluator.
//!
//! ## Feature matrix
//!
//! | capability | entry point | needs PJRT artifacts? |
//! |---|---|---|
//! | format emulation + quantizers | [`formats`] | no |
//! | bit-packed MX tensors + integer kernels | [`packed`] | no |
//! | IR build/parse/print/verify | [`ir`], [`frontend`] | no |
//! | search algorithms (Fig. 4) | [`search`] | no |
//! | persistent eval cache | [`search::CacheStore`] | no |
//! | hardware cost models (Table 1) | [`hw`] | no |
//! | dataflow simulation (Fig. 1e/1f), bandwidth-aware beat model | [`sim`] | no |
//! | SystemVerilog emission (Table 3) | [`emit`] | no |
//! | static analysis: SV analyzer + bitwidth contracts (`mase check`) | [`check`] | no |
//! | deterministic tracing/metrics (`mase trace`, `--trace`) | [`obs`] | no |
//! | HTTP serving, continuous-batching scheduler (`mase serve`) | [`serve`] | no |
//! | accuracy evaluation, packed CPU interpreter | [`runtime::CpuBackend`] via [`passes::Evaluator`] | no |
//! | full flow / sweep with `--backend cpu` | [`coordinator`] | no |
//! | accuracy evaluation / QAT via PJRT | [`runtime::PjrtBackend`] via [`passes::Evaluator`] | **yes** |
//! | pretraining the simulants | [`coordinator::pretrain()`] | **yes** |
//! | full flow / sweep / benches via PJRT | [`coordinator`] | **yes** |
//!
//! ## Offline `xla` caveat
//!
//! This environment has no crates.io access and no PJRT toolchain, so
//! `rust/vendor/xla` (and `rust/vendor/anyhow`) are in-tree stand-ins:
//! every PJRT entry point returns a clean error instead of executing an
//! artifact. Everything in the "no" rows above is fully functional —
//! including end-to-end `search`/`e2e`/`sweep` under `--backend cpu`,
//! which interprets the MASE IR with bit-packed integer-datapath matmuls
//! and needs no artifacts at all. The PJRT "yes" rows degrade to errors,
//! and the tests/benches that need them self-skip when
//! `artifacts/manifest.json` is absent. To light up the real thing, swap
//! the `xla` path-dependency in `rust/Cargo.toml` for the real xla-rs
//! bindings — and note the real `PjRtClient` is NOT thread-safe:
//! parallel search then needs a per-worker client (the `Evaluator: Sync`
//! compile-time assertion will flag this).
pub mod formats;
pub mod packed;
pub mod ir;
pub mod frontend;
pub mod data;
pub mod search;
pub mod hw;
pub mod sim;
pub mod passes;
pub mod emit;
pub mod check;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod eval;
pub mod coordinator;
pub mod cli;
pub mod util;
