//! # MASE-RS
//!
//! A dataflow compiler for efficient LLM inference using custom
//! microscaling (MX) formats — a from-scratch reproduction of the MASE
//! paper as a three-layer Rust + JAX + Pallas system.
//!
//! The crate is the paper's Layer-3 contribution: the co-design compiler.
//! It consumes AOT-lowered HLO artifacts (produced once by
//! `python/compile/aot.py`) through the PJRT runtime in [`runtime`], and
//! owns everything else: the MASE IR ([`ir`]), the numeric format library
//! ([`formats`]), the pass pipeline ([`passes`]), the search algorithms
//! ([`search`]), the hardware cost models ([`hw`]), the dataflow simulator
//! ([`sim`]), the SystemVerilog emitter ([`emit`]), the synthetic data
//! substrate ([`data`]) and the end-to-end coordinator ([`coordinator`]).
pub mod formats;
pub mod ir;
pub mod frontend;
pub mod data;
pub mod search;
pub mod hw;
pub mod sim;
pub mod passes;
pub mod emit;
pub mod runtime;
pub mod eval;
pub mod coordinator;
pub mod util;
