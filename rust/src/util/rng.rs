//! Deterministic PRNG (xoshiro256**) — the offline environment has no
//! `rand` crate; everything stochastic in the compiler (search algorithms,
//! synthetic data, weight init) draws from this, seeded explicitly so
//! every experiment is reproducible.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 seeding, as recommended by the xoshiro authors.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            z = z.wrapping_add(0x9E3779B97F4A7C15);
            let mut x = z;
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
            x ^ (x >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s, spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let [ref mut s0, ref mut s1, ref mut s2, ref mut s3] = self.s;
        let result = s1.wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = *s1 << 17;
        *s2 ^= *s0;
        *s3 ^= *s1;
        *s1 ^= *s2;
        *s0 ^= *s3;
        *s2 ^= t;
        *s3 = s3.rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + (self.next_u64() % (hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let (u1, u2) = (self.uniform().max(1e-300), self.uniform());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut t = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut rng = Rng::new(7);
        let n = 10_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            assert!(rng.below(7) < 7);
        }
    }

    #[test]
    fn weighted_prefers_heavy_bucket() {
        let mut rng = Rng::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[rng.weighted(&[1.0, 8.0, 1.0])] += 1;
        }
        assert!(counts[1] > counts[0] * 3 && counts[1] > counts[2] * 3);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
