//! Minimal JSON parser/printer — `serde_json` is unavailable in this
//! offline environment, and the only JSON we handle is our own
//! `artifacts/manifest.json` plus small result files, so a compact
//! recursive-descent parser is sufficient and fully tested.

use std::collections::BTreeMap;
use std::fmt;

/// Maximum container nesting the parser accepts. Deep enough for every
/// artifact we produce (manifests nest ~4 levels), small enough that a
/// hostile request body (`serve` parses network input with this parser)
/// cannot blow the recursive-descent stack with `[[[[...`.
pub const MAX_DEPTH: usize = 64;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access for tests and tools.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = match cur {
                Json::Obj(m) => m.get(*p)?,
                Json::Arr(a) => a.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
    /// Current container nesting, bounded by [`MAX_DEPTH`].
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            // pos points at the opening bracket that crossed the limit
            self.pos -= 1;
            return Err(self.err(&format!("nesting deeper than {MAX_DEPTH} levels")));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.enter()?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.enter()?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a run of plain utf-8 bytes
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| JsonError {
                        pos: start,
                        msg: "invalid utf-8".into(),
                    })?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.at(&["a", "2", "b"]).unwrap().as_str(), Some("c"));
        assert_eq!(j.at(&["a", "0"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes_round_trip() {
        let j = Json::parse(r#""a\nb\t\"q\" é""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"q\" é"));
        let printed = j.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn print_parse_round_trip() {
        let src = r#"{"models": {"m": {"n": 3, "spec": [["w", [16, 2], 0]], "x": true}}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let j = Json::parse(&text).expect("manifest parses");
            assert!(j.get("models").is_some());
            assert_eq!(j.at(&["block_shape", "0"]).unwrap().as_usize(), Some(16));
        }
    }

    #[test]
    fn depth_limit_rejects_with_position() {
        // exactly MAX_DEPTH nests parse; one more is rejected, and the
        // error position points at the offending opening bracket.
        let ok = format!("{}{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&ok).is_ok());
        let deep = format!("{}{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        let e = Json::parse(&deep).unwrap_err();
        assert_eq!(e.pos, MAX_DEPTH, "position of the bracket that crossed the limit");
        assert!(e.msg.contains("nesting"), "{}", e.msg);
        // mixed {"a":[{"a":[... nests two levels per repeat
        let mixed =
            format!("{}0{}", "{\"a\":[".repeat(MAX_DEPTH / 2 + 1), "]}".repeat(MAX_DEPTH / 2 + 1));
        assert!(Json::parse(&mixed).is_err());
        // depth is container nesting, not value count: wide stays fine
        let wide = format!("[{}]", vec!["[0]"; 1000].join(","));
        assert!(Json::parse(&wide).is_ok());
    }

    #[test]
    fn depth_resets_between_siblings() {
        // sibling containers each get the full budget — the counter must
        // decrement on close, not only increment.
        let one = format!("{}{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        let two = format!("[{one},{one}]");
        assert!(Json::parse(&two).is_err(), "outer array adds one level");
        let shallower = format!("{}{}", "[".repeat(MAX_DEPTH - 1), "]".repeat(MAX_DEPTH - 1));
        let flat = format!("[{shallower},{shallower}]");
        assert!(Json::parse(&flat).is_ok());
    }

    #[test]
    fn hex_bit_pattern_round_trip() {
        // the PR 2/8 convention: u64 values cross JSON as fixed-width
        // 16-digit lowercase hex strings (never lossy f64 numbers).
        // Parser and printer must preserve them byte-for-byte.
        for v in [0u64, 1, 0xdead_beef_0123_4567, u64::MAX, 0x3ff0_0000_0000_0000] {
            let src = format!("{{\"bits\":\"{v:016x}\"}}");
            let j = Json::parse(&src).unwrap();
            let s = j.get("bits").unwrap().as_str().unwrap();
            assert_eq!(s.len(), 16);
            assert_eq!(u64::from_str_radix(s, 16).unwrap(), v);
            assert_eq!(j.to_string(), src, "printer preserves the fixed-width form");
        }
        // contrast: the same magnitude as a bare number would round
        // through f64 and lose low bits — which is why the convention
        // exists. (2^53 + 1 is not representable.)
        let j = Json::parse("9007199254740993").unwrap();
        assert_eq!(j.as_f64(), Some(9007199254740992.0));
    }

    #[test]
    fn serializer_output_reparses_identically() {
        // round-trip against the existing serializer on a serve-shaped
        // body: nested objects, arrays of ints, strings with escapes.
        let src = r#"{"max_tokens": 4, "prompt": [1, 2, 511], "tag": "a\"b\\c", "opts": {"deep": [[1], [2, [3]]], "on": true, "off": null}}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), j);
        assert_eq!(Json::parse(&printed).unwrap().to_string(), printed, "printing is a fixpoint");
    }

    #[test]
    fn whitespace_tolerant() {
        let j = Json::parse(" {\n\t\"a\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(j.at(&["a", "1"]).unwrap().as_f64(), Some(2.0));
    }
}
