//! Tiny property-based testing harness (the offline environment has no
//! `proptest`). Supports seeded generation and greedy shrinking of
//! counterexamples for the common case of `Vec<f32>` / integer inputs.
//!
//! Usage:
//! ```ignore
//! prop_check(100, |g| {
//!     let xs = g.vec_f32(64, -10.0, 10.0);
//!     let m = g.int(1, 8);
//!     my_invariant(&xs, m)   // -> Result<(), String>
//! });
//! ```

use super::rng::Rng;

/// Generation context handed to each property iteration.
pub struct Gen {
    rng: Rng,
    /// Log of drawn values for failure reporting.
    pub trace: Vec<String>,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed), trace: Vec::new() }
    }

    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        let v = self.rng.int_range(lo, hi);
        self.trace.push(format!("int[{lo},{hi}]={v}"));
        v
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let v = self.rng.range(lo as f64, hi as f64) as f32;
        self.trace.push(format!("f32[{lo},{hi}]={v}"));
        v
    }

    /// Normal values at one of three magnitudes (stress scale invariance).
    pub fn vec_f32_scaled(&mut self, n: usize) -> Vec<f32> {
        let scale = [1e-3, 1.0, 1e3][self.rng.below(3)];
        let v: Vec<f32> = (0..n).map(|_| (self.rng.normal() * scale) as f32).collect();
        self.trace.push(format!("vec_f32_scaled(n={n}, scale={scale})"));
        v
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        let v: Vec<f32> = (0..n).map(|_| self.rng.range(lo as f64, hi as f64) as f32).collect();
        self.trace.push(format!("vec_f32(n={n})"));
        v
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` for `iters` seeds; panic with the first failing seed and its
/// drawn-value trace. Re-running with the printed seed reproduces exactly.
pub fn prop_check<F>(iters: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for seed in 0..iters {
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed at seed {seed}: {msg}\n  trace: {}",
                g.trace.join(", ")
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        prop_check(50, |g| {
            let n = g.int(0, 100);
            if n >= 0 {
                Ok(())
            } else {
                Err("negative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failing_seed() {
        prop_check(50, |g| {
            let n = g.int(0, 100);
            if n < 95 {
                Ok(())
            } else {
                Err(format!("{n} too big"))
            }
        });
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Gen::new(3);
        let mut b = Gen::new(3);
        assert_eq!(a.vec_f32(8, 0.0, 1.0), b.vec_f32(8, 0.0, 1.0));
    }
}
