//! Minimal CLI argument parsing (no `clap` offline): subcommand + flags.
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and free args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub free: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.free.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Worker-thread request for parallel passes (`--threads N`);
    /// 0 / absent means auto-detect (see `util::pool::threads_from_env`).
    pub fn threads(&self) -> usize {
        self.get_usize("threads", 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("search --model opt-125m-sim --trials 64 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("search"));
        assert_eq!(a.get("model"), Some("opt-125m-sim"));
        assert_eq!(a.get_usize("trials", 0), 64);
        assert!(a.has("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("emit --out=designs --k=0.5");
        assert_eq!(a.get("out"), Some("designs"));
        assert_eq!(a.get_f64("k", 0.0), 0.5);
    }

    #[test]
    fn boolean_flag_before_flag() {
        let a = parse("run --force --model m");
        assert!(a.has("force"));
        assert_eq!(a.get("model"), Some("m"));
    }

    #[test]
    fn free_args_after_subcommand() {
        let a = parse("bench fig5 fig7");
        assert_eq!(a.free, vec!["fig5", "fig7"]);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_or("missing", "d"), "d");
    }

    #[test]
    fn threads_flag() {
        assert_eq!(parse("search --threads 4").threads(), 4);
        assert_eq!(parse("search --threads=2").threads(), 2);
        assert_eq!(parse("search").threads(), 0);
    }
}
