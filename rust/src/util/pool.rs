//! Scoped parallel-map helper over std threads (no `rayon` offline).
//!
//! The search pass evaluates independent trials and the benches sweep
//! independent models; `par_map` fans work over a bounded number of OS
//! threads using `std::thread::scope`, preserving input order.

/// Map `f` over `items` with up to `threads` worker threads.
/// Results are returned in input order. `f` must be `Sync` (called from
/// several threads) and `T`/`R` are moved/collected per item.
pub fn par_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.into_iter().map(f).collect();
    }
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    let work: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let out: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = work[i].lock().unwrap().take().unwrap();
                let r = f(item);
                *out[i].lock().unwrap() = Some(r);
            });
        }
    });
    out.into_iter().map(|m| m.into_inner().unwrap().unwrap()).collect()
}

/// Default worker count: physical parallelism minus one, at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get().saturating_sub(1).max(1)).unwrap_or(1)
}

/// Resolve a worker-thread request, end to end: an explicit nonzero
/// value (e.g. from the `--threads` CLI flag) wins; `0` falls back to
/// the `MASE_THREADS` environment variable, then to [`default_threads`].
/// Always returns at least 1.
pub fn threads_from_env(explicit: usize) -> usize {
    if explicit > 0 {
        return explicit;
    }
    std::env::var("MASE_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(default_threads)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<u64> = (0..100).collect();
        let ys = par_map(xs, 8, |x| x * 2);
        assert_eq!(ys, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_fallback() {
        let ys = par_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(ys, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let ys: Vec<i32> = par_map(Vec::<i32>::new(), 4, |x| x);
        assert!(ys.is_empty());
    }

    #[test]
    fn threads_resolution_order() {
        // explicit request wins; 0 auto-detects to something usable
        // (MASE_THREADS is env-dependent, so only the bounds are checked)
        assert_eq!(threads_from_env(3), 3);
        assert!(threads_from_env(0) >= 1);
    }

    #[test]
    fn actually_runs_concurrently() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static LIVE: AtomicUsize = AtomicUsize::new(0);
        let xs: Vec<u64> = (0..16).collect();
        par_map(xs, 4, |_| {
            let l = LIVE.fetch_add(1, Ordering::SeqCst) + 1;
            PEAK.fetch_max(l, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(20));
            LIVE.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(PEAK.load(Ordering::SeqCst) > 1);
    }
}
