//! In-tree substitutes for crates unavailable in this offline environment:
//! JSON ([`json`]), PRNG ([`rng`]), property testing ([`prop`]), a scoped
//! thread pool ([`pool`]) and CLI parsing ([`cli`]).

pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;

/// Wall-clock helper used by the pass manager (Table 4) and benches.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Simple aligned text table for bench output (the "same rows the paper
/// reports" requirement — printed, not plotted).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        self.rows.push(cells.into_iter().map(Into::into).collect());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                if i >= widths.len() {
                    widths.push(c.len());
                } else {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths.get(i).copied().unwrap_or(c.len())))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns_columns() {
        let mut t = Table::new(vec!["name", "x"]);
        t.row(vec!["a", "1.00"]);
        t.row(vec!["longer", "2"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].starts_with("name  "));
        assert!(lines[2].starts_with("a     "));
        assert!(lines[3].starts_with("longer"));
    }
}
