//! In-tree substitutes for crates unavailable in this offline environment:
//! JSON ([`json`]), PRNG ([`rng`]), property testing ([`prop`]), a scoped
//! thread pool ([`pool`]) and CLI parsing ([`cli`]).

pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;

/// Render a `u64` in the PR 2 on-disk convention: exactly 16 lowercase
/// hex digits (`{:016x}`), the form [`hex_u64`] accepts back.
pub fn hex16(v: u64) -> String {
    format!("{v:016x}")
}

/// Strict fixed-width hex: exactly the 16 lowercase digits `{:016x}`
/// emits, so hand-edited or truncated values read as corruption and a
/// loadable file has exactly one byte representation per value.
pub fn hex_u64(s: &str) -> Option<u64> {
    if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b)) {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

/// Crash-safe file replacement: write a sibling `<file>.tmp`, then rename
/// it over the target, so an interrupted write leaves the previous file
/// intact and readers never observe a half-written one. Creates missing
/// parent directories. This is the one sanctioned way to overwrite an
/// artifact (`CacheStore::save`, `mase pack`'s JSON and `.mxa` outputs).
pub fn write_atomic(path: &std::path::Path, bytes: &[u8]) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let file_name = path
        .file_name()
        .ok_or_else(|| anyhow::anyhow!("target path has no file name: {}", path.display()))?;
    let tmp = path.with_file_name(format!("{}.tmp", file_name.to_string_lossy()));
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Wall-clock helper used by the pass manager (Table 4) and benches.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Simple aligned text table for bench output (the "same rows the paper
/// reports" requirement — printed, not plotted).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        self.rows.push(cells.into_iter().map(Into::into).collect());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                if i >= widths.len() {
                    widths.push(c.len());
                } else {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths.get(i).copied().unwrap_or(c.len())))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_atomic_replaces_without_tmp_residue() {
        let path =
            std::env::temp_dir().join(format!("mase_write_atomic_{}.txt", std::process::id()));
        write_atomic(&path, b"one").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"one");
        write_atomic(&path, b"two").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"two");
        let tmp =
            path.with_file_name(format!("{}.tmp", path.file_name().unwrap().to_string_lossy()));
        assert!(!tmp.exists(), "tmp file must be renamed away");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn table_aligns_columns() {
        let mut t = Table::new(vec!["name", "x"]);
        t.row(vec!["a", "1.00"]);
        t.row(vec!["longer", "2"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].starts_with("name  "));
        assert!(lines[2].starts_with("a     "));
        assert!(lines[3].starts_with("longer"));
    }
}
