//! Fixed-point (Q-format) fake quantization — the int8 / MP-int baselines.
//!
//! `value = clamp(round(x * 2^f), -2^(w-1), 2^(w-1)-1) / 2^f`. No dynamic
//! range: a static (width, frac) pair per tensor, which is exactly what
//! loses accuracy on the large activation variances of deep layers
//! (paper Fig. 1a) and makes MP-int infeasible in Fig. 7.

use super::{pow2, round_ties_even};

/// Fake-quantize in place with `width` total bits (incl. sign) and `frac`
/// fractional bits. Real-valued knobs are *rounded* to integers (the
/// search convention — see `search/mod.rs`) and clamped to sane ranges.
pub fn int_quantize(data: &mut [f32], width: f32, frac: f32) {
    let w = width.round().max(2.0) as i32;
    let f = frac.round() as i32;
    let scale = pow2(-f);
    let qmax = pow2(w - 1) - 1.0;
    let qmin = -pow2(w - 1);
    for x in data {
        *x = round_ties_even(*x / scale).clamp(qmin, qmax) * scale;
    }
}

/// Pick the fraction width that makes `width`-bit fixed point cover
/// `absmax` without saturation: `f = w - 1 - ceil(log2 absmax)` — the
/// calibration rule the quantize pass applies from profile statistics.
pub fn calibrate_frac(width: f32, absmax: f32) -> f32 {
    if absmax <= 0.0 {
        return 0.0;
    }
    let int_bits = (absmax as f64).log2().ceil() as i32;
    (width as i32 - 1 - int_bits) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values_q8_4() {
        let mut x = vec![1.0f32, 1.03125, 1e6, -1e6];
        int_quantize(&mut x, 8.0, 4.0);
        assert_eq!(x[0], 1.0);
        assert_eq!(x[1], 1.0); // 1.03125*16 = 16.5, ties-to-even -> 16/16
        assert_eq!(x[2], 127.0 / 16.0); // saturation high
        assert_eq!(x[3], -128.0 / 16.0); // saturation low
    }

    #[test]
    fn grid_membership() {
        let mut x: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) * 0.0371).collect();
        int_quantize(&mut x, 8.0, 5.0);
        for v in &x {
            let k = v * 32.0;
            assert_eq!(k, k.round());
            assert!((-128.0..=127.0).contains(&k));
        }
    }

    #[test]
    fn idempotent() {
        let mut x: Vec<f32> = (0..64).map(|i| (i as f32).sin() * 3.0).collect();
        int_quantize(&mut x, 6.0, 3.0);
        let q1 = x.clone();
        int_quantize(&mut x, 6.0, 3.0);
        assert_eq!(q1, x);
    }

    #[test]
    fn no_dynamic_range() {
        // 8-bit f=0 loses 1e-4 entirely and saturates 1e4 — Fig. 1a story.
        let mut x = vec![1e-4f32, 1e4];
        int_quantize(&mut x, 8.0, 0.0);
        assert_eq!(x[0], 0.0);
        assert_eq!(x[1], 127.0);
    }

    #[test]
    fn fractional_knobs_round_not_truncate() {
        // w = 7.6 / f = 3.4 must behave exactly like Q8.3
        let x: Vec<f32> = (0..32).map(|i| (i as f32 - 16.0) * 0.21).collect();
        let mut a = x.clone();
        int_quantize(&mut a, 7.6, 3.4);
        let mut b = x;
        int_quantize(&mut b, 8.0, 3.0);
        assert_eq!(a, b);
    }

    #[test]
    fn calibrate_frac_covers_absmax() {
        for &absmax in &[0.1f32, 1.0, 3.7, 100.0] {
            let w = 8.0;
            let f = calibrate_frac(w, absmax);
            let mut x = vec![absmax * 0.999];
            int_quantize(&mut x, w, f);
            // Must not saturate: quantized value within 2% of input.
            assert!((x[0] - absmax * 0.999).abs() / absmax < 0.02, "absmax={absmax} f={f} got {}", x[0]);
        }
    }
}
