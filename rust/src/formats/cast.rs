//! Casting-cost model between formats and precisions (paper §4,
//! "No mixed-arithmetic but mixed-precision quantization").
//!
//! Casting between *different arithmetic types* (e.g. MXInt -> BL) needs
//! dynamic shifters to re-align ranges — large circuits. Casting between
//! *precisions of the same format* is mantissa bit extension/truncation
//! plus a fully-unrollable exponent shift — cheap. The quantize pass uses
//! this model to reject mixed-arithmetic solutions, and the `parallelize`
//! pass adds the intra-format cast LUTs on every edge where producer and
//! consumer precision differ.

use super::FormatKind;

/// Estimated LUT cost of casting one element between two tensor formats.
pub fn cast_cost_luts(
    from: FormatKind,
    from_bits: f32,
    to: FormatKind,
    to_bits: f32,
) -> f64 {
    if from == to {
        match from {
            FormatKind::Fp32 | FormatKind::Fp8 => 0.0,
            // Fixed point / MXInt mantissas: bit extend or truncate-round.
            FormatKind::Int | FormatKind::MxInt => {
                let delta = (from_bits - to_bits).abs() as f64;
                // truncation needs a rounder (~1 LUT/bit); extension is wires
                if to_bits < from_bits {
                    1.0 * delta + 2.0
                } else if to_bits > from_bits {
                    0.0
                } else {
                    0.0
                }
            }
            // BMF/BL share the bias path: small exponent adjust.
            FormatKind::Bmf | FormatKind::Bl => {
                if (from_bits - to_bits).abs() > 0.0 {
                    3.0
                } else {
                    0.0
                }
            }
        }
    } else {
        // Cross-arithmetic cast: de/re-normalization with dynamic shifts.
        // A w-bit dynamic shifter costs ~w*log2(w) LUTs (Coward et al.);
        // both ends pay one.
        let w = from_bits.max(to_bits).max(8.0) as f64;
        2.0 * w * w.log2() + 16.0
    }
}

/// Is a cast between these formats "affordable" per the paper's rule
/// (same arithmetic type)?
pub fn is_affordable(from: FormatKind, to: FormatKind) -> bool {
    from == to || from == FormatKind::Fp32 || to == FormatKind::Fp32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_format_casts_are_cheap() {
        let c = cast_cost_luts(FormatKind::MxInt, 6.0, FormatKind::MxInt, 4.0);
        assert!(c < 10.0);
        let c2 = cast_cost_luts(FormatKind::MxInt, 4.0, FormatKind::MxInt, 6.0);
        assert_eq!(c2, 0.0); // pure bit extension = wires
    }

    #[test]
    fn cross_format_casts_are_expensive() {
        let cheap = cast_cost_luts(FormatKind::MxInt, 6.0, FormatKind::MxInt, 4.0);
        let costly = cast_cost_luts(FormatKind::MxInt, 6.0, FormatKind::Bl, 6.0);
        assert!(costly > 10.0 * cheap);
    }

    #[test]
    fn identity_cast_free() {
        for f in FormatKind::ALL {
            assert_eq!(cast_cost_luts(f, 8.0, f, 8.0), 0.0);
        }
    }

    #[test]
    fn affordability_rule() {
        assert!(is_affordable(FormatKind::MxInt, FormatKind::MxInt));
        assert!(is_affordable(FormatKind::Fp32, FormatKind::MxInt));
        assert!(!is_affordable(FormatKind::MxInt, FormatKind::Bl));
        assert!(!is_affordable(FormatKind::Int, FormatKind::Bmf));
    }
}
