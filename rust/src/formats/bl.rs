//! BL (Block Logarithm, Miyashita et al.) fake quantization.
//!
//! Values are `sign * 2^E_i` with the per-element exponent `E_i` stored in
//! `exp_el_bits` bits below a block-shared 8-bit bias: multiplications
//! become shifts in hardware (the BL operator of Fig. 3 strips the
//! mantissa datapath entirely), at the cost of a power-of-two-only grid.

use super::{block_maxabs, for_each_block, map_block, pow2, shared_exponent};

/// Fake-quantize a row-major 2-D tensor in place. `exp_el_bits` is
/// rounded to the nearest integer (search convention) and clamped >= 1.
pub fn bl_quantize(data: &mut [f32], rows: usize, cols: usize, exp_el_bits: f32) {
    let eb = exp_el_bits.round().max(1.0) as i32;
    let levels = pow2(eb) as i32 - 1; // exponents bias-levels ..= bias
    for_each_block(rows, cols, |start| {
        let bias = shared_exponent(block_maxabs(data, start, cols));
        let e_min = bias - levels;
        let underflow = pow2(e_min - 1);
        map_block(data, start, cols, |x| {
            if x == 0.0 {
                return 0.0;
            }
            let absx = x.abs();
            if absx < underflow {
                return 0.0f32.copysign(x);
            }
            // Log-domain rounding: round(log2 |x|). f64 log2 is exact
            // enough to round correctly for all f32 inputs.
            let e = ((absx as f64).log2().round() as i32).clamp(e_min, bias);
            pow2(e).copysign(x)
        });
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_tensor(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn outputs_are_powers_of_two() {
        let mut x = rand_tensor(32 * 8, 1);
        bl_quantize(&mut x, 32, 8, 7.0);
        for v in x {
            if v != 0.0 {
                let l = (v.abs() as f64).log2();
                assert_eq!(l, l.round(), "{v}");
            }
        }
    }

    #[test]
    fn idempotent() {
        for seed in 0..5 {
            let x = rand_tensor(32 * 4, seed);
            let mut q1 = x.clone();
            bl_quantize(&mut q1, 32, 4, 6.0);
            let mut q2 = q1.clone();
            bl_quantize(&mut q2, 32, 4, 6.0);
            assert_eq!(q1, q2);
        }
    }

    #[test]
    fn relative_error_bounded() {
        // Power-of-two grid rounds within 2^±0.5, and the top of the range
        // clips to 2^bias (matching ref.py): worst-case |q-x|/x < 0.5.
        let mut x: Vec<f32> = rand_tensor(64, 3).iter().map(|v| v.abs() + 1.0).collect();
        let orig = x.clone();
        bl_quantize(&mut x, 16, 4, 7.0);
        for (a, b) in orig.iter().zip(x.iter()) {
            assert!(((a - b) / a).abs() < 0.51, "{a} {b}");
        }
    }

    #[test]
    fn fractional_exp_bits_round_not_truncate() {
        let x = rand_tensor(32 * 4, 4);
        let mut a = x.clone();
        bl_quantize(&mut a, 32, 4, 2.6);
        let mut b = x;
        bl_quantize(&mut b, 32, 4, 3.0);
        assert_eq!(a, b, "eb=2.6 must quantize with 3 exponent bits");
    }

    #[test]
    fn small_exp_bits_flush_small_values() {
        let mut x = vec![1.0f32; 32];
        x[1] = 1e-3; // 2^-10 below peak; with 3 exponent bits range=2^-7
        bl_quantize(&mut x, 16, 2, 3.0);
        assert_eq!(x[1], 0.0);
    }
}
