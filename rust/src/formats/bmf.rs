//! BMF (Block Minifloat, Fox et al.) fake quantization.
//!
//! Each (16, 2) block shares an 8-bit exponent *bias* anchored at the
//! block max; each element is a local minifloat with `LOCAL_EXP_BITS`
//! exponent bits and `m` mantissa bits. The local dynamic range is only
//! `2^(2^LOCAL_EXP_BITS)` below the block max — elements far below the
//! peak flush to zero (denormal rounding), which is the mechanism behind
//! the catastrophic BMF8 perplexity the paper reports for LLaMA (Table 1).

use super::{block_maxabs, floor_log2, for_each_block, map_block, pow2, round_ties_even, shared_exponent};

/// Bitwidth of each element's local exponent (paper Fig. 1c uses a small
/// local exponent; 2 bits gives the 2^3-wide local range that reproduces
/// the BMF failure shape on large-variance tensors).
pub const LOCAL_EXP_BITS: u32 = 2;

/// Fake-quantize a row-major 2-D tensor in place. `mantissa_bits` is
/// rounded to the nearest integer (search convention) and clamped >= 1.
pub fn bmf_quantize(data: &mut [f32], rows: usize, cols: usize, mantissa_bits: f32) {
    let m = mantissa_bits.round().max(1.0) as i32;
    let e_min = -(pow2(LOCAL_EXP_BITS as i32) as i32 - 1); // -(2^eb - 1)
    for_each_block(rows, cols, |start| {
        let bias = shared_exponent(block_maxabs(data, start, cols));
        let top = pow2(bias + 1) - pow2(bias - m);
        map_block(data, start, cols, |x| {
            if x == 0.0 {
                return 0.0;
            }
            let absx = x.abs();
            let e_loc = (floor_log2(absx) - bias).clamp(e_min, 0);
            let scale = pow2(e_loc + bias - m);
            let q = (round_ties_even(absx / scale) * scale).min(top);
            q.copysign(x)
        });
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_tensor(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    #[test]
    fn idempotent() {
        for seed in 0..8 {
            let x = rand_tensor(32 * 4, seed, if seed % 2 == 0 { 1.0 } else { 1e-3 });
            let mut q1 = x.clone();
            bmf_quantize(&mut q1, 32, 4, 4.0);
            let mut q2 = q1.clone();
            bmf_quantize(&mut q2, 32, 4, 4.0);
            assert_eq!(q1, q2, "seed {seed}");
        }
    }

    #[test]
    fn flushes_values_far_below_block_peak() {
        // 1.0 dominates the block; 1e-6 is far outside the 2^-3 local
        // range and must flush to zero — Table 1's BMF failure mode.
        let mut x = vec![1e-6f32; 32];
        x[0] = 1.0;
        bmf_quantize(&mut x, 16, 2, 4.0);
        assert!((x[0] - 1.0).abs() < 0.1);
        assert!(x[1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn keeps_near_peak_values() {
        let mut x = vec![0.5f32; 32];
        x[0] = 1.0;
        let orig = x.clone();
        bmf_quantize(&mut x, 16, 2, 4.0);
        for (a, b) in orig.iter().zip(x.iter()) {
            assert!((a - b).abs() / a < 0.1);
        }
    }

    #[test]
    fn saturates_at_top_of_range() {
        let mut x = vec![1.0f32; 32];
        x[0] = 1.999_999_9; // just below 2.0: must not round past `top`
        bmf_quantize(&mut x, 16, 2, 2.0);
        let bias = 0; // max < 2 -> floor(log2)=0
        let top = pow2(bias + 1) - pow2(bias - 2);
        assert!(x[0] <= top);
    }

    #[test]
    fn fractional_mantissa_bits_round_not_truncate() {
        let x = rand_tensor(32 * 4, 2, 1.0);
        let mut a = x.clone();
        bmf_quantize(&mut a, 32, 4, 3.9);
        let mut b = x;
        bmf_quantize(&mut b, 32, 4, 4.0);
        assert_eq!(a, b, "m=3.9 must quantize with 4 mantissa bits");
    }

    #[test]
    fn error_decreases_with_mantissa_bits(){
        let x = rand_tensor(64 * 8, 5, 1.0);
        let err = |m: f32| {
            let mut q = x.clone();
            bmf_quantize(&mut q, 64, 8, m);
            x.iter().zip(q.iter()).map(|(a, b)| (a - b).abs() as f64).sum::<f64>()
        };
        assert!(err(2.0) > err(6.0));
    }
}
