//! MiniFloat / FP8 (Sun et al., HFP8) fake quantization: sign + e-bit
//! exponent + m-bit mantissa, fixed bias; flush-to-zero, saturate-to-max.

use super::{floor_log2, pow2, round_ties_even};

/// Fake-quantize in place. Defaults in the paper's Table 1 row: e=4, m=3,
/// bias=7.
pub fn minifloat_quantize(data: &mut [f32], exp_bits: i32, mantissa_bits: i32, bias: i32) {
    let e_min = 1 - bias;
    let e_max = pow2(exp_bits) as i32 - 2 - bias;
    let top = pow2(e_max + 1) - pow2(e_max - mantissa_bits);
    let underflow = pow2(e_min - 1);
    for x in data {
        if *x == 0.0 {
            continue;
        }
        let absx = x.abs();
        if absx < underflow {
            *x = 0.0f32.copysign(*x);
            continue;
        }
        let e = floor_log2(absx).clamp(e_min, e_max);
        let scale = pow2(e - mantissa_bits);
        let q = (round_ties_even(absx / scale) * scale).min(top);
        *x = q.copysign(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values_e4m3_bias7() {
        let mut x = vec![1.0f32, 1.125, 240.0, 1000.0, 2.0f32.powi(-7), 0.0, -240.0];
        minifloat_quantize(&mut x, 4, 3, 7);
        assert_eq!(x, vec![1.0, 1.125, 240.0, 240.0, 2.0f32.powi(-7), 0.0, -240.0]);
    }

    #[test]
    fn idempotent() {
        let mut x: Vec<f32> = (0..128).map(|i| ((i as f32) * 0.77).sin() * 10.0).collect();
        minifloat_quantize(&mut x, 4, 3, 7);
        let q1 = x.clone();
        minifloat_quantize(&mut x, 4, 3, 7);
        assert_eq!(q1, x);
    }

    #[test]
    fn underflow_flushes_to_signed_zero() {
        let mut x = vec![1e-10f32, -1e-10];
        minifloat_quantize(&mut x, 4, 3, 7);
        assert_eq!(x[0], 0.0);
        assert!(x[1] == 0.0 && x[1].is_sign_negative());
    }

    #[test]
    fn relative_error_bound() {
        // Normal range: |err| <= 2^-(m+1) relative.
        let mut x: Vec<f32> = (1..100).map(|i| i as f32 * 0.37).collect();
        let orig = x.clone();
        minifloat_quantize(&mut x, 4, 3, 7);
        for (a, b) in orig.iter().zip(x.iter()) {
            if *b < 240.0 {
                assert!(((a - b) / a).abs() <= 2.0f32.powi(-4) + 1e-6, "{a} {b}");
            }
        }
    }
}
