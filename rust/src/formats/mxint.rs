//! MXInt (microscaling integer / block floating point) fake quantization.
//!
//! Each (16, 2) block shares an 8-bit exponent `E = floor(log2 max|x|)`;
//! each element is sign + m-bit integer mantissa:
//! `value = clamp(round(x / 2^(E+1-m)), ±(2^m - 1)) * 2^(E+1-m)`.
//! This is the format the paper finds best suited to LLMs (Table 1, Fig 5).

use super::{
    block_maxabs, for_each_block, map_block, pow2, round_ties_even, shared_exponent,
};

/// Fake-quantize a row-major 2-D tensor in place. `mantissa_bits` is
/// *rounded* to the nearest integer (the search convention for
/// real-valued precision dimensions — see `search/mod.rs`) and clamped
/// to >= 1, matching `ref.mxint_quantize`.
pub fn mxint_quantize(data: &mut [f32], rows: usize, cols: usize, mantissa_bits: f32) {
    let m = mantissa_bits.round().max(1.0) as i32;
    for_each_block(rows, cols, |start| {
        let e = shared_exponent(block_maxabs(data, start, cols));
        quantize_block(data, start, cols, e, m);
    });
}

/// Quantize one block given its shared exponent (exposed for the emitted
/// hardware operator's unit tests, which drive the exponent externally).
pub fn quantize_block(data: &mut [f32], start: usize, cols: usize, e: i32, m: i32) {
    // True division (not reciprocal multiply): scale can be subnormal for
    // all-zero blocks, where 1/scale overflows to inf and 0*inf = NaN.
    let scale = pow2(e + 1 - m);
    let qmax = pow2(m) - 1.0;
    map_block(data, start, cols, |x| {
        round_ties_even(x / scale).clamp(-qmax, qmax) * scale
    });
}

/// Quantize a 1-D tensor (flat blocks of 32 elements).
pub fn mxint_quantize_1d(data: &mut [f32], mantissa_bits: f32) {
    let n = super::BLOCK_SHAPE.0 * super::BLOCK_SHAPE.1;
    assert_eq!(data.len() % n, 0);
    let m = mantissa_bits.round().max(1.0) as i32;
    for b in 0..data.len() / n {
        let chunk = &mut data[b * n..(b + 1) * n];
        let maxabs = chunk.iter().fold(0.0f32, |a, x| a.max(x.abs()));
        let e = shared_exponent(maxabs);
        let scale = pow2(e + 1 - m);
        let qmax = pow2(m) - 1.0;
        for x in chunk {
            *x = round_ties_even(*x / scale).clamp(-qmax, qmax) * scale;
        }
    }
}

/// Mean |x - q(x)| of MXInt quantization — used by the quantize pass's
/// local error model to seed the search.
pub fn quantization_error(data: &[f32], rows: usize, cols: usize, mantissa_bits: f32) -> f64 {
    let mut q = data.to_vec();
    mxint_quantize(&mut q, rows, cols, mantissa_bits);
    let mut err = 0.0f64;
    for (a, b) in data.iter().zip(q.iter()) {
        err += (a - b).abs() as f64;
    }
    err / data.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_tensor(rows: usize, cols: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..rows * cols).map(|_| rng.normal() as f32 * scale).collect()
    }

    #[test]
    fn idempotent() {
        for seed in 0..5 {
            let x = rand_tensor(32, 8, seed, 1.0);
            let mut q1 = x.clone();
            mxint_quantize(&mut q1, 32, 8, 5.0);
            let mut q2 = q1.clone();
            mxint_quantize(&mut q2, 32, 8, 5.0);
            assert_eq!(q1, q2);
        }
    }

    #[test]
    fn error_decreases_with_mantissa_bits() {
        let x = rand_tensor(64, 32, 7, 2.0);
        let e2 = quantization_error(&x, 64, 32, 2.0);
        let e4 = quantization_error(&x, 64, 32, 4.0);
        let e8 = quantization_error(&x, 64, 32, 8.0);
        assert!(e2 > e4 && e4 > e8, "{e2} {e4} {e8}");
    }

    #[test]
    fn zero_tensor_unchanged() {
        let mut x = vec![0.0f32; 16 * 2];
        mxint_quantize(&mut x, 16, 2, 4.0);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn sign_symmetry() {
        let x = rand_tensor(16, 4, 3, 1.0);
        let mut qp = x.clone();
        mxint_quantize(&mut qp, 16, 4, 4.0);
        let mut qn: Vec<f32> = x.iter().map(|v| -v).collect();
        mxint_quantize(&mut qn, 16, 4, 4.0);
        for (a, b) in qp.iter().zip(qn.iter()) {
            assert_eq!(*a, -*b);
        }
    }

    #[test]
    fn per_block_dynamic_range_preserved() {
        // Blocks spanning 2^16 magnitude each keep small relative error —
        // the microscaling property the paper exploits (Fig. 1a).
        let mut x = Vec::new();
        for blk in 0..4 {
            let mag = 2.0f32.powi(blk * 4);
            for _ in 0..32 {
                x.push(mag);
            }
        }
        let mut q = x.clone();
        mxint_quantize(&mut q, 64, 2, 4.0);
        for (a, b) in x.iter().zip(q.iter()) {
            assert!(((a - b) / a).abs() < 0.1, "{a} {b}");
        }
    }

    #[test]
    fn values_on_grid() {
        // Every output must be an integer multiple of the block scale.
        let x = rand_tensor(16, 2, 9, 3.0);
        let mut q = x.clone();
        let m = 4;
        mxint_quantize(&mut q, 16, 2, m as f32);
        let e = shared_exponent(block_maxabs(&x, 0, 2));
        let scale = pow2(e + 1 - m);
        for v in q {
            let k = v / scale;
            assert_eq!(k, k.round(), "{v} not on grid (scale {scale})");
            assert!(k.abs() <= (pow2(m) - 1.0) as f32);
        }
    }

    #[test]
    fn one_d_path_matches_blocked_layout() {
        // The 1-D path groups 32 consecutive elements per block — exactly
        // one row-major (16, 2) block. Quantizing each 32-chunk through
        // the blocked 2-D path must reproduce it element for element.
        let x = rand_tensor(4, 32, 11, 1.0);
        let mut q1 = x.clone();
        mxint_quantize_1d(&mut q1, 5.0);
        let mut q2 = x.clone();
        for chunk in q2.chunks_mut(32) {
            mxint_quantize(chunk, 16, 2, 5.0);
        }
        assert_eq!(q1.len(), x.len());
        for (i, (a, b)) in q1.iter().zip(q2.iter()).enumerate() {
            assert_eq!(a, b, "element {i}: 1-D {a} vs blocked {b}");
        }
    }

    #[test]
    fn fractional_mantissa_bits_round_not_truncate() {
        // Search vectors are real-valued; the convention (search/mod.rs)
        // is that precision dimensions are ROUNDED. m = 4.9 must behave
        // as 5 bits, not truncate to 4. With block max 1.0 (e = 0):
        // 0.1875 = 3/16 is exact on the 5-bit grid (scale 2^-4) but
        // rounds to 0.25 on the 4-bit grid (scale 2^-3, ties-to-even).
        let mut x = vec![1.0f32; 32];
        x[1] = 0.1875;
        let mut q = x.clone();
        mxint_quantize(&mut q, 16, 2, 4.9);
        assert_eq!(q[1], 0.1875, "m=4.9 must quantize with 5 mantissa bits");
        let mut q4 = x.clone();
        mxint_quantize(&mut q4, 16, 2, 4.0);
        assert_eq!(q4[1], 0.25, "4-bit grid sanity check");
        // 1-D path follows the same convention
        let mut q1d = x.clone();
        mxint_quantize_1d(&mut q1d, 4.9);
        assert_eq!(q1d[1], 0.1875);
    }
}
