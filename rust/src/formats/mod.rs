//! Numeric format library — the Rust mirror of `python/compile/kernels/ref.py`.
//!
//! The paper's formats (Fig. 1c): MXInt (block floating point), BMF (block
//! minifloat), BL (block logarithm), fixed point, and MiniFloat/FP8. All
//! functions perform *fake quantization*: outputs are f32 values lying
//! exactly on the target format's representable grid.
//!
//! Two implementation notes that matter for cross-layer agreement with the
//! HLO emulation executed via PJRT:
//!  * powers of two are constructed exactly (never via `exp2`
//!    approximations — XLA CPU's f32 `exp2` is inexact even at integers);
//!  * `floor(log2 |x|)` is the IEEE-754 unbiased exponent, extracted from
//!    the bit pattern, which is exact where XLA's `floor(log2 x)` is
//!    approximate. The integration test tolerates the resulting rare
//!    off-by-one-exponent disagreements (< 0.1% of elements).

pub mod bl;
pub mod bmf;
pub mod cast;
pub mod fixed;
pub mod minifloat;
pub mod mxint;

pub use bl::bl_quantize;
pub use bmf::bmf_quantize;
pub use fixed::int_quantize;
pub use minifloat::minifloat_quantize;
pub use mxint::mxint_quantize;

/// Paper §4.1: unified block shape (rows, cols) for all MX values.
pub const BLOCK_SHAPE: (usize, usize) = (16, 2);
/// Paper §4.1: fixed bitwidth of the shared exponent.
pub const SHARED_EXPONENT_BITS: u32 = 8;
/// Clamp range of the 8-bit shared exponent.
pub const SHARED_EXP_MIN: i32 = -126;
pub const SHARED_EXP_MAX: i32 = 127;

/// Format families explored by the compiler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FormatKind {
    /// Baseline: no quantization.
    Fp32,
    /// Fixed point with per-tensor (width, frac) — `int8` when uniform 8-bit.
    Int,
    /// MiniFloat FP8 (Sun et al.): 1s + 4e + 3m, bias 7.
    Fp8,
    /// Microscaling integer (block floating point) — the paper's winner.
    MxInt,
    /// Block minifloat: shared exponent bias, local minifloat elements.
    Bmf,
    /// Block logarithm: power-of-two values, shared bias.
    Bl,
}

impl FormatKind {
    pub const ALL: [FormatKind; 6] = [
        FormatKind::Fp32,
        FormatKind::Int,
        FormatKind::Fp8,
        FormatKind::MxInt,
        FormatKind::Bmf,
        FormatKind::Bl,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FormatKind::Fp32 => "fp32",
            FormatKind::Int => "int",
            FormatKind::Fp8 => "fp8",
            FormatKind::MxInt => "mxint",
            FormatKind::Bmf => "bmf",
            FormatKind::Bl => "bl",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "fp32" => FormatKind::Fp32,
            "int" => FormatKind::Int,
            "fp8" => FormatKind::Fp8,
            "mxint" | "mxint_pallas" => FormatKind::MxInt,
            "bmf" => FormatKind::Bmf,
            "bl" => FormatKind::Bl,
            _ => return None,
        })
    }

    /// Does this format share a component across a block?
    pub fn is_block_format(&self) -> bool {
        matches!(self, FormatKind::MxInt | FormatKind::Bmf | FormatKind::Bl)
    }
}

/// Per-tensor precision knobs: one row of the f32[V, 2] quant-config input
/// of the HLO artifacts. Interpretation depends on the format family:
/// MXInt/BMF -> (mantissa bits, unused); Int -> (width, frac);
/// BL -> (element exponent bits, unused).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Precision {
    pub bits: f32,
    pub frac: f32,
}

impl Precision {
    pub fn new(bits: f32, frac: f32) -> Self {
        Self { bits, frac }
    }

    /// Average bits per element — paper Eq. (1) for block formats, plain
    /// width otherwise. This is the `b` of the search objective Eq. (4).
    pub fn average_bitwidth(&self, fmt: FormatKind) -> f64 {
        let block = (BLOCK_SHAPE.0 * BLOCK_SHAPE.1) as f64;
        let shared = SHARED_EXPONENT_BITS as f64;
        match fmt {
            FormatKind::Fp32 => 32.0,
            FormatKind::Fp8 => 8.0,
            FormatKind::Int => self.bits as f64,
            // sign + mantissa + amortized shared exponent
            FormatKind::MxInt => shared / block + self.bits as f64 + 1.0,
            // sign + local exponent + mantissa + amortized shared bias
            FormatKind::Bmf => {
                shared / block + self.bits as f64 + bmf::LOCAL_EXP_BITS as f64 + 1.0
            }
            // sign + element exponent + amortized shared bias
            FormatKind::Bl => shared / block + self.bits as f64 + 1.0,
        }
    }
}

/// One fully-specified uniform format choice: family + precision knobs.
///
/// This is the single type the typed CLI (`--fmt/--bits/--frac`), the
/// packed artifact header ([`crate::packed`]'s `.mxa` manifest) and the
/// `mase pack` JSON manifest all share, so no two surfaces can describe
/// the same format differently. The per-family `--bits` default that
/// used to be re-derived by every subcommand handler lives here once.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FormatSpec {
    pub kind: FormatKind,
    /// Family-dependent primary knob (see [`Precision`]).
    pub bits: f32,
    /// Fixed-point fraction bits; 0 for every other family.
    pub frac: f32,
}

impl FormatSpec {
    pub fn new(kind: FormatKind, bits: f32, frac: f32) -> Self {
        Self { kind, bits, frac }
    }

    /// The default primary knob per family: fp32 is exact, fixed/minifloat
    /// default to 8-bit words, MXInt/BL to 7 mantissa/exponent bits
    /// (paper §4.1's 8.25-avg-bit sweet spot), BMF to 5 mantissa bits.
    pub fn default_bits(kind: FormatKind) -> f32 {
        match kind {
            FormatKind::Fp32 => 32.0,
            FormatKind::Bmf => 5.0,
            FormatKind::Int | FormatKind::Fp8 => 8.0,
            FormatKind::MxInt | FormatKind::Bl => 7.0,
        }
    }

    /// Spec at the family's default knobs.
    pub fn with_defaults(kind: FormatKind) -> Self {
        Self { kind, bits: Self::default_bits(kind), frac: 0.0 }
    }

    /// The per-tensor [`Precision`] row this spec denotes.
    pub fn precision(&self) -> Precision {
        Precision::new(self.bits, self.frac)
    }
}

/// Exact 2^e as f32 (e clamped to the representable range; subnormals ok).
#[inline]
pub fn pow2(e: i32) -> f32 {
    let e = e.clamp(-149, 127);
    if e >= -126 {
        f32::from_bits(((e + 127) as u32) << 23)
    } else {
        f32::from_bits(1u32 << (e + 149))
    }
}

/// Exact floor(log2 |x|) via the IEEE-754 exponent (x > 0, finite).
#[inline]
pub fn floor_log2(x: f32) -> i32 {
    debug_assert!(x > 0.0 && x.is_finite());
    let bits = x.to_bits();
    let exp = ((bits >> 23) & 0xff) as i32;
    if exp == 0 {
        // Subnormal: value = mant * 2^-149, mant in [1, 2^23).
        let mant = bits & 0x7f_ffff;
        (31 - mant.leading_zeros()) as i32 - 149
    } else {
        exp - 127
    }
}

/// Round half to even, matching `jnp.round` (banker's rounding).
#[inline]
pub fn round_ties_even(x: f32) -> f32 {
    x.round_ties_even()
}

/// Iterate (16, 2) blocks of a row-major 2-D tensor, calling `f` with the
/// flat start offset of each block (address elements as
/// `start + r * cols + c`, r in 0..16, c in 0..2). Dims must tile exactly.
pub fn for_each_block<F: FnMut(usize)>(rows: usize, cols: usize, mut f: F) {
    let (br, bc) = BLOCK_SHAPE;
    assert_eq!(rows % br, 0, "rows {rows} not divisible by {br}");
    assert_eq!(cols % bc, 0, "cols {cols} not divisible by {bc}");
    for rb in 0..rows / br {
        for cb in 0..cols / bc {
            f(rb * br * cols + cb * bc);
        }
    }
}

/// Max |x| over one (16, 2) block.
#[inline]
pub fn block_maxabs(data: &[f32], start: usize, cols: usize) -> f32 {
    let (br, bc) = BLOCK_SHAPE;
    let mut maxabs = 0.0f32;
    for r in 0..br {
        let row = start + r * cols;
        for c in 0..bc {
            maxabs = maxabs.max(data[row + c].abs());
        }
    }
    maxabs
}

/// Shared exponent of a block: floor(log2 max|x|) clamped to 8-bit range.
/// Returns `SHARED_EXP_MIN` for an all-zero block.
#[inline]
pub fn shared_exponent(maxabs: f32) -> i32 {
    if maxabs == 0.0 || !maxabs.is_finite() {
        return SHARED_EXP_MIN;
    }
    floor_log2(maxabs).clamp(SHARED_EXP_MIN, SHARED_EXP_MAX)
}

/// Apply `f` to every element of one (16, 2) block in place.
#[inline]
pub fn map_block<F: FnMut(f32) -> f32>(data: &mut [f32], start: usize, cols: usize, mut f: F) {
    let (br, bc) = BLOCK_SHAPE;
    for r in 0..br {
        let row = start + r * cols;
        for c in 0..bc {
            data[row + c] = f(data[row + c]);
        }
    }
}

/// Dispatch fake quantization of a row-major 2-D tensor in place.
pub fn quantize_2d(fmt: FormatKind, data: &mut [f32], rows: usize, cols: usize, p: Precision) {
    match fmt {
        FormatKind::Fp32 => {}
        FormatKind::Int => fixed::int_quantize(data, p.bits, p.frac),
        FormatKind::Fp8 => minifloat::minifloat_quantize(data, 4, 3, 7),
        FormatKind::MxInt => mxint::mxint_quantize(data, rows, cols, p.bits),
        FormatKind::Bmf => bmf::bmf_quantize(data, rows, cols, p.bits),
        FormatKind::Bl => bl::bl_quantize(data, rows, cols, p.bits),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_exact_across_range() {
        for e in -149..=127 {
            assert_eq!(pow2(e) as f64, 2f64.powi(e), "e={e}");
        }
    }

    #[test]
    fn pow2_clamps() {
        assert_eq!(pow2(-200), pow2(-149));
        assert_eq!(pow2(300), pow2(127));
    }

    #[test]
    fn floor_log2_matches_f64_reference() {
        for &x in &[
            1.0f32,
            1.5,
            2.0,
            3.9,
            4.0,
            0.5,
            0.49,
            1e-3,
            1e3,
            2.0f32.powi(-126),
            1.1754942e-38, // largest subnormal
            1e-45,         // smallest subnormal
        ] {
            assert_eq!(floor_log2(x), (x as f64).log2().floor() as i32, "x={x}");
        }
    }

    #[test]
    fn round_ties_even_matches_numpy() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(3.49), 3.0);
        assert_eq!(round_ties_even(-1.5), -2.0);
    }

    #[test]
    fn block_iteration_covers_tensor() {
        let mut count = 0;
        for_each_block(32, 4, |start| {
            assert!(start < 32 * 4);
            count += 1;
        });
        assert_eq!(count, (32 / 16) * (4 / 2));
    }

    #[test]
    fn shared_exponent_edge_cases() {
        assert_eq!(shared_exponent(0.0), SHARED_EXP_MIN);
        assert_eq!(shared_exponent(1.0), 0);
        assert_eq!(shared_exponent(0.75), -1);
        assert_eq!(shared_exponent(f32::INFINITY), SHARED_EXP_MIN);
    }

    #[test]
    fn average_bitwidth_paper_example() {
        // MXInt((16,2), 8, 7) -> 8.25 bits (paper §4.1).
        let p = Precision::new(7.0, 0.0);
        assert!((p.average_bitwidth(FormatKind::MxInt) - 8.25).abs() < 1e-9);
    }

    #[test]
    fn format_spec_defaults_cover_all_families() {
        for f in FormatKind::ALL {
            let spec = FormatSpec::with_defaults(f);
            assert_eq!(spec.kind, f);
            assert!(spec.bits > 0.0);
            assert_eq!(spec.frac, 0.0);
            assert_eq!(spec.precision(), Precision::new(spec.bits, 0.0));
        }
        assert_eq!(FormatSpec::default_bits(FormatKind::Fp32), 32.0);
        assert_eq!(FormatSpec::default_bits(FormatKind::MxInt), 7.0);
        assert_eq!(FormatSpec::default_bits(FormatKind::Bmf), 5.0);
        assert_eq!(FormatSpec::default_bits(FormatKind::Int), 8.0);
    }

    #[test]
    fn format_name_round_trip() {
        for f in FormatKind::ALL {
            assert_eq!(FormatKind::from_name(f.name()), Some(f));
        }
        assert_eq!(FormatKind::from_name("nope"), None);
        assert_eq!(FormatKind::from_name("mxint_pallas"), Some(FormatKind::MxInt));
    }
}
