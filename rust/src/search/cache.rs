//! Persistent, versioned storage for [`EvalCache`] — the cross-sweep
//! memoization layer behind `mase sweep` and the Fig. 4/Fig. 6 benches.
//!
//! A [`CacheStore`] holds one [`EvalCache`] per *scope* (a string naming
//! the evaluation context — see `passes::search_pass::eval_scope`) and
//! serializes all of them to a single JSON file through [`crate::util::json`].
//! The design goals, in order:
//!
//!  1. **Bit-exactness.** A warm run must reproduce a cold run exactly,
//!     so every `f64` (memo-key coordinates, objective value, objective
//!     components) is stored as its IEEE-754 bit pattern in fixed-width
//!     hex (`{:016x}`), never as a decimal float. The in-tree JSON
//!     number type is `f64`, which cannot carry a `u64` key losslessly.
//!  2. **Fail-open loading.** A missing file, unparseable JSON, schema
//!     or version mismatch, or any malformed entry degrades to a *cold*
//!     cache with a human-readable note ([`CacheStore::load_note`]) —
//!     a stale or corrupt cache must never abort a sweep.
//!  3. **Atomic flushing.** [`CacheStore::save`] writes a sibling
//!     `<file>.tmp` and renames it over the target, so a crash mid-write
//!     leaves the previous cache intact.
//!
//! The on-disk schema (documented in full in the [`crate::search`]
//! module docs) is:
//!
//! ```text
//! {
//!   "schema":  "mase-eval-cache",
//!   "version": 2,
//!   "scopes": {
//!     "<model>/<task>/<fmt>/<memo>/...": {
//!       "entries": [ {"k": ["<hex u64>", ...],   // canonicalized coords
//!                     "v": "<hex f64>",          // scalarized objective
//!                     "o": ["<hex f64>", ...]},  // objective components
//!                   ... ]
//!     }, ...
//!   }
//! }
//! ```

use super::EvalCache;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// One cache entry as (de)serialized: canonicalized per-dimension key
/// bits, scalarized objective value, raw objective components.
pub type CacheEntry = (Vec<u64>, f64, Vec<f64>);

/// Magic string identifying an eval-cache file.
pub const CACHE_SCHEMA: &str = "mase-eval-cache";
/// On-disk format version. Bump on any change to the entry layout, the
/// memo-key scheme, or the hardware cost model feeding the memoized
/// objectives; old files then load as cold caches (fail-open).
/// v2: `hw::memory` prices tensors with measured packed bits
/// (`packed::layout::packed_bits_for`), changing Eq. (4) objectives for
/// BMF/BL configs — v1 entries would be silently stale.
pub const CACHE_VERSION: u64 = 2;

/// Point-in-time counters of one [`EvalCache`] (or an aggregate over a
/// whole [`CacheStore`]).
///
/// Counter discipline (PR 8): `hits`/`misses`/`inserts` are **monotonic**
/// — cumulative since cache creation, never reset by snapshotting or
/// saving. Per-phase accounting (one search, one sweep cell) is always
/// expressed as the [`CacheStats::delta`] of two snapshots of the same
/// cache, never by zeroing the counters — so any two readers of one
/// cache agree, and the trace registry's own monotonic counters can
/// absorb a delta verbatim ([`CacheStats::record_to`]). `entries` is the
/// absolute current size, not a counter.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups that found a memoized evaluation.
    pub hits: usize,
    /// Lookups that fell through to the objective.
    pub misses: usize,
    /// Fresh evaluations memoized (excludes entries preloaded from disk).
    pub inserts: usize,
    /// Distinct configurations currently cached.
    pub entries: usize,
}

impl CacheStats {
    /// Fraction of lookups served from the cache (0.0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.hits + self.misses;
        if lookups == 0 {
            0.0
        } else {
            self.hits as f64 / lookups as f64
        }
    }

    /// Delta of the monotonic counters relative to an `earlier` snapshot
    /// of the same cache; `entries` stays absolute. This is the ONLY
    /// sanctioned way to report per-phase cache behavior — the
    /// underlying counters are never reset.
    pub fn delta(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.saturating_sub(earlier.hits),
            misses: self.misses.saturating_sub(earlier.misses),
            inserts: self.inserts.saturating_sub(earlier.inserts),
            entries: self.entries,
        }
    }

    /// Fold this snapshot (typically a [`delta`](Self::delta)) into a
    /// trace registry as monotonic counters under `path`. `entries` is
    /// absolute, not monotonic, so it stays out of the counter stream.
    pub fn record_to(&self, rec: &crate::obs::Registry, path: &str) {
        if !rec.is_enabled() {
            return;
        }
        rec.counter(path, "cache_hits", self.hits as u64);
        rec.counter(path, "cache_misses", self.misses as u64);
        rec.counter(path, "cache_inserts", self.inserts as u64);
    }

    /// Accumulate another cache's counters (for store-wide totals).
    pub fn absorb(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.inserts += other.inserts;
        self.entries += other.entries;
    }
}

/// A scope-keyed collection of [`EvalCache`]s with optional disk backing.
///
/// `open` never fails (see the module docs); `save` flushes atomically.
/// Each scope's cache is shared behind an [`Arc`], so several searches —
/// the four Fig. 4 algorithms, or repeated sweeps of one grid cell — can
/// feed the same memo table concurrently.
pub struct CacheStore {
    path: Option<PathBuf>,
    scopes: Mutex<BTreeMap<String, Arc<EvalCache>>>,
    loaded_entries: usize,
    load_note: Option<String>,
}

impl CacheStore {
    /// A store with no disk backing: scoped sharing within one process,
    /// `save` is a no-op.
    pub fn in_memory() -> CacheStore {
        CacheStore {
            path: None,
            scopes: Mutex::new(BTreeMap::new()),
            loaded_entries: 0,
            load_note: None,
        }
    }

    /// Load-or-create a store backed by `path`. A missing file yields an
    /// empty store; an unreadable, mis-versioned or corrupt file yields
    /// an empty store with [`CacheStore::load_note`] explaining why the
    /// previous contents were discarded.
    pub fn open(path: &Path) -> CacheStore {
        let mut store = CacheStore {
            path: Some(path.to_path_buf()),
            scopes: Mutex::new(BTreeMap::new()),
            loaded_entries: 0,
            load_note: None,
        };
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(_) => return store, // fresh file: normal cold start
        };
        match parse_store(&text) {
            Ok(scopes) => {
                let mut map = BTreeMap::new();
                let mut n = 0;
                for (scope, entries) in scopes {
                    n += entries.len();
                    let cache = EvalCache::new();
                    cache.preload(entries);
                    map.insert(scope, Arc::new(cache));
                }
                store.scopes = Mutex::new(map);
                store.loaded_entries = n;
            }
            Err(note) => {
                store.load_note =
                    Some(format!("discarded {}: {note}", path.display()));
            }
        }
        store
    }

    /// Why the on-disk contents were discarded at `open`, if they were.
    pub fn load_note(&self) -> Option<&str> {
        self.load_note.as_deref()
    }

    /// Entries successfully preloaded from disk at `open`.
    pub fn loaded_entries(&self) -> usize {
        self.loaded_entries
    }

    /// The backing file, if any.
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// The cache for `scope`, created empty on first use.
    pub fn cache(&self, scope: &str) -> Arc<EvalCache> {
        self.scopes
            .lock()
            .unwrap()
            .entry(scope.to_string())
            .or_insert_with(|| Arc::new(EvalCache::new()))
            .clone()
    }

    /// All scope names currently present (sorted).
    pub fn scope_names(&self) -> Vec<String> {
        self.scopes.lock().unwrap().keys().cloned().collect()
    }

    /// Distinct configurations across all scopes.
    pub fn total_entries(&self) -> usize {
        self.scopes.lock().unwrap().values().map(|c| c.len()).sum()
    }

    /// Aggregate counters across all scopes.
    pub fn stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for c in self.scopes.lock().unwrap().values() {
            total.absorb(&c.stats());
        }
        total
    }

    /// Atomically flush every scope to the backing file (no-op without
    /// one). Last writer wins: the file is replaced wholesale, not merged
    /// with concurrent writers — one sweep process per cache file.
    pub fn save(&self) -> anyhow::Result<()> {
        let Some(path) = &self.path else { return Ok(()) };
        let mut scopes = BTreeMap::new();
        for (scope, cache) in self.scopes.lock().unwrap().iter() {
            let entries: Vec<Json> = cache
                .snapshot()
                .into_iter()
                .map(|(k, v, o)| {
                    let mut e = BTreeMap::new();
                    e.insert(
                        "k".to_string(),
                        Json::Arr(k.iter().map(|&b| Json::Str(format!("{b:016x}"))).collect()),
                    );
                    e.insert("v".to_string(), Json::Str(format!("{:016x}", v.to_bits())));
                    e.insert(
                        "o".to_string(),
                        Json::Arr(
                            o.iter().map(|f| Json::Str(format!("{:016x}", f.to_bits()))).collect(),
                        ),
                    );
                    Json::Obj(e)
                })
                .collect();
            let mut s = BTreeMap::new();
            s.insert("entries".to_string(), Json::Arr(entries));
            scopes.insert(scope.clone(), Json::Obj(s));
        }
        let mut root = BTreeMap::new();
        root.insert("schema".to_string(), Json::Str(CACHE_SCHEMA.to_string()));
        root.insert("version".to_string(), Json::Num(CACHE_VERSION as f64));
        root.insert("scopes".to_string(), Json::Obj(scopes));
        let text = Json::Obj(root).to_string();
        crate::util::write_atomic(path, text.as_bytes())
    }
}

/// Parse a serialized store into scope -> entries, or a note saying why
/// the file is unusable. Any structural defect rejects the whole file:
/// a partially loaded cache could silently mix key schemes.
fn parse_store(text: &str) -> Result<BTreeMap<String, Vec<CacheEntry>>, String> {
    let root = Json::parse(text).map_err(|e| format!("unreadable JSON ({e})"))?;
    match root.get("schema").and_then(Json::as_str) {
        Some(CACHE_SCHEMA) => {}
        other => return Err(format!("schema {other:?} is not {CACHE_SCHEMA:?}")),
    }
    let version = root.get("version").and_then(Json::as_f64).unwrap_or(-1.0);
    if version != CACHE_VERSION as f64 {
        return Err(format!("cache version {version} (this build writes {CACHE_VERSION})"));
    }
    let scopes = root
        .get("scopes")
        .and_then(Json::as_obj)
        .ok_or_else(|| "missing scopes object".to_string())?;
    let mut out = BTreeMap::new();
    for (scope, body) in scopes {
        let entries = body
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("scope {scope:?} has no entries array"))?;
        let mut parsed = Vec::with_capacity(entries.len());
        for e in entries {
            parsed.push(parse_entry(e).ok_or_else(|| format!("malformed entry in {scope:?}"))?);
        }
        out.insert(scope.clone(), parsed);
    }
    Ok(out)
}

fn parse_entry(e: &Json) -> Option<CacheEntry> {
    let key = e
        .get("k")?
        .as_arr()?
        .iter()
        .map(|j| hex_u64(j.as_str()?))
        .collect::<Option<Vec<u64>>>()?;
    let value = f64::from_bits(hex_u64(e.get("v")?.as_str()?)?);
    let objectives = e
        .get("o")?
        .as_arr()?
        .iter()
        .map(|j| Some(f64::from_bits(hex_u64(j.as_str()?)?)))
        .collect::<Option<Vec<f64>>>()?;
    Some((key, value, objectives))
}

// Strict fixed-width hex ({:016x} digits only) — shared with the packed
// artifact manifest via `util`.
use crate::util::hex_u64;

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> PathBuf {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static UNIQUE: AtomicUsize = AtomicUsize::new(0);
        let n = UNIQUE.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!(
            "mase-cache-{tag}-{}-{n}.json",
            std::process::id()
        ))
    }

    #[test]
    fn hex_is_strict_fixed_width() {
        assert_eq!(hex_u64("00000000000000ff"), Some(255));
        assert_eq!(hex_u64("ff"), None, "short");
        assert_eq!(hex_u64("00000000000000zz"), None, "not hex");
        assert_eq!(hex_u64("00000000000000ff0"), None, "long");
        assert_eq!(hex_u64("00000000000000FF"), None, "uppercase is not what {{:016x}} emits");
    }

    #[test]
    fn floats_round_trip_bit_exact_through_hex() {
        for v in [0.1 + 0.2, -0.0, 1.0 / 3.0, f64::MIN_POSITIVE, -1e300] {
            let hex = format!("{:016x}", v.to_bits());
            let back = f64::from_bits(hex_u64(&hex).unwrap());
            assert_eq!(back.to_bits(), v.to_bits(), "{v}");
        }
    }

    #[test]
    fn empty_store_saves_and_reloads() {
        let path = tmp_path("empty");
        let store = CacheStore::open(&path);
        assert_eq!(store.total_entries(), 0);
        assert!(store.load_note().is_none(), "missing file is a normal cold start");
        store.save().unwrap();
        let again = CacheStore::open(&path);
        assert!(again.load_note().is_none());
        assert_eq!(again.total_entries(), 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn scoped_entries_round_trip() {
        let path = tmp_path("scoped");
        let store = CacheStore::open(&path);
        let a = store.cache("m/sst2/mxint/rounded");
        a.insert(vec![3f64.to_bits(), 5f64.to_bits()], (0.75, vec![0.9, 0.1]));
        let b = store.cache("m/qqp/int/rounded");
        b.insert(vec![4f64.to_bits()], (-0.5, vec![]));
        store.save().unwrap();

        let again = CacheStore::open(&path);
        assert_eq!(again.loaded_entries(), 2);
        assert_eq!(
            again.scope_names(),
            vec!["m/qqp/int/rounded".to_string(), "m/sst2/mxint/rounded".to_string()]
        );
        let a2 = again.cache("m/sst2/mxint/rounded");
        let got = a2.get(&[3f64.to_bits(), 5f64.to_bits()]).expect("preloaded entry");
        assert_eq!(got, (0.75, vec![0.9, 0.1]));
        // preloaded entries do not count as fresh inserts
        assert_eq!(a2.stats().inserts, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_is_atomic_rename() {
        let path = tmp_path("atomic");
        let store = CacheStore::open(&path);
        store.cache("s").insert(vec![1], (1.0, vec![]));
        store.save().unwrap();
        let tmp = path.with_file_name(format!(
            "{}.tmp",
            path.file_name().unwrap().to_string_lossy()
        ));
        assert!(!tmp.exists(), "tmp file must be renamed away");
        assert!(path.exists());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn store_stats_aggregate_scopes() {
        let store = CacheStore::in_memory();
        let a = store.cache("a");
        a.insert(vec![1], (1.0, vec![]));
        a.get(&[1]);
        a.get(&[2]);
        let b = store.cache("b");
        b.get(&[1]);
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.inserts, s.entries), (1, 2, 1, 1));
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn stats_delta_subtracts_counters_keeps_entries() {
        let c = EvalCache::new();
        c.insert(vec![1], (1.0, vec![]));
        c.get(&[1]);
        let before = c.stats();
        c.get(&[1]);
        c.get(&[2]);
        c.insert(vec![2], (2.0, vec![]));
        let delta = c.stats().delta(&before);
        assert_eq!((delta.hits, delta.misses, delta.inserts, delta.entries), (1, 1, 1, 2));
    }

    #[test]
    fn counters_are_monotonic_across_snapshots_and_saves() {
        // snapshotting/saving must never reset the counters: two phase
        // deltas taken independently have to tile the cumulative totals
        let c = EvalCache::new();
        c.insert(vec![1], (1.0, vec![]));
        c.get(&[1]);
        let s1 = c.stats();
        let _ = c.snapshot(); // serialization path: must not disturb counters
        assert_eq!(c.stats(), s1);
        c.get(&[1]);
        let s2 = c.stats();
        let phase1 = s1.delta(&CacheStats::default());
        let phase2 = s2.delta(&s1);
        assert_eq!(phase1.hits + phase2.hits, s2.hits);
        assert_eq!(phase1.misses + phase2.misses, s2.misses);
        assert_eq!(phase1.inserts + phase2.inserts, s2.inserts);
    }

    #[test]
    fn record_to_folds_delta_into_registry() {
        let reg = crate::obs::Registry::new();
        let s = CacheStats { hits: 5, misses: 2, inserts: 2, entries: 9 };
        s.record_to(&reg, "sweep/cell");
        s.record_to(&reg, "sweep/cell"); // monotonic: a second cell adds
        assert_eq!(reg.counter_total("sweep/cell", "cache_hits"), 10);
        assert_eq!(reg.counter_total("sweep/cell", "cache_misses"), 4);
        assert_eq!(reg.counter_total("sweep/cell", "cache_inserts"), 4);
    }
}
