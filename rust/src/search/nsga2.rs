//! NSGA-II (Deb et al. 2002) — the multi-objective evolutionary contender
//! of Fig. 4. Full algorithm: fast non-dominated sorting, crowding
//! distance, binary tournament on the crowded comparison operator, SBX
//! crossover and polynomial mutation. Objectives are the components of
//! Eq. (4) (each maximized); ties fall back to the scalar value.

use super::{Searcher, Space, Trial};
use crate::util::rng::Rng;

const POP: usize = 12;
const SBX_ETA: f64 = 10.0;
const MUT_ETA: f64 = 20.0;

pub struct Nsga2 {
    space: Space,
    rng: Rng,
    /// Evaluated population of the current generation.
    pop: Vec<Trial>,
}

impl Nsga2 {
    pub fn new(space: Space, seed: u64) -> Self {
        Self { space, rng: Rng::new(seed), pop: Vec::new() }
    }

    fn objectives<'a>(t: &'a Trial) -> &'a [f64] {
        if t.objectives.is_empty() {
            std::slice::from_ref(&t.value)
        } else {
            &t.objectives
        }
    }

    fn dominates(a: &Trial, b: &Trial) -> bool {
        let (oa, ob) = (Self::objectives(a), Self::objectives(b));
        let mut strictly = false;
        for (x, y) in oa.iter().zip(ob.iter()) {
            if x < y {
                return false;
            }
            if x > y {
                strictly = true;
            }
        }
        strictly
    }

    /// Fast non-dominated sort: rank per individual (0 = Pareto front).
    fn ranks(pop: &[Trial]) -> Vec<usize> {
        let n = pop.len();
        let mut dominated_by = vec![0usize; n];
        let mut dominates_list: Vec<Vec<usize>> = vec![Vec::new(); n];
        for i in 0..n {
            for j in 0..n {
                if i != j && Self::dominates(&pop[i], &pop[j]) {
                    dominates_list[i].push(j);
                    dominated_by[j] += 1;
                }
            }
        }
        let mut rank = vec![usize::MAX; n];
        let mut front: Vec<usize> = (0..n).filter(|&i| dominated_by[i] == 0).collect();
        let mut r = 0;
        while !front.is_empty() {
            let mut next = Vec::new();
            for &i in &front {
                rank[i] = r;
                for &j in &dominates_list[i] {
                    dominated_by[j] -= 1;
                    if dominated_by[j] == 0 {
                        next.push(j);
                    }
                }
            }
            front = next;
            r += 1;
        }
        rank
    }

    /// Crowding distance within the whole set (per Deb, computed per rank
    /// in selection; a global approximation is fine at POP=12).
    fn crowding(pop: &[Trial]) -> Vec<f64> {
        let n = pop.len();
        let m = pop.iter().map(|t| Self::objectives(t).len()).max().unwrap_or(1);
        let mut d = vec![0.0f64; n];
        for k in 0..m {
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| {
                let va = Self::objectives(&pop[a]).get(k).copied().unwrap_or(0.0);
                let vb = Self::objectives(&pop[b]).get(k).copied().unwrap_or(0.0);
                va.partial_cmp(&vb).unwrap()
            });
            let lo = Self::objectives(&pop[idx[0]]).get(k).copied().unwrap_or(0.0);
            let hi = Self::objectives(&pop[idx[n - 1]]).get(k).copied().unwrap_or(0.0);
            let span = (hi - lo).max(1e-12);
            d[idx[0]] = f64::INFINITY;
            d[idx[n - 1]] = f64::INFINITY;
            for w in 1..n - 1 {
                let prev = Self::objectives(&pop[idx[w - 1]]).get(k).copied().unwrap_or(0.0);
                let next = Self::objectives(&pop[idx[w + 1]]).get(k).copied().unwrap_or(0.0);
                d[idx[w]] += (next - prev) / span;
            }
        }
        d
    }

    /// Binary tournament with the crowded-comparison operator.
    fn select<'a>(&mut self, ranks: &[usize], crowd: &[f64]) -> usize {
        let (a, b) = (self.rng.below(self.pop.len()), self.rng.below(self.pop.len()));
        if ranks[a] < ranks[b] || (ranks[a] == ranks[b] && crowd[a] > crowd[b]) {
            a
        } else {
            b
        }
    }

    fn sbx_crossover(&mut self, p1: &[f64], p2: &[f64]) -> Vec<f64> {
        let mut child = Vec::with_capacity(p1.len());
        for i in 0..p1.len() {
            let u = self.rng.uniform();
            let beta = if u <= 0.5 {
                (2.0 * u).powf(1.0 / (SBX_ETA + 1.0))
            } else {
                (1.0 / (2.0 * (1.0 - u))).powf(1.0 / (SBX_ETA + 1.0))
            };
            let c = if self.rng.uniform() < 0.5 {
                0.5 * ((1.0 + beta) * p1[i] + (1.0 - beta) * p2[i])
            } else {
                0.5 * ((1.0 - beta) * p1[i] + (1.0 + beta) * p2[i])
            };
            child.push(c);
        }
        child
    }

    fn mutate(&mut self, x: &mut [f64]) {
        let pm = 1.0 / x.len() as f64;
        for i in 0..x.len() {
            if self.rng.uniform() < pm {
                let u = self.rng.uniform();
                let span = self.space.hi[i] - self.space.lo[i];
                let delta = if u < 0.5 {
                    (2.0 * u).powf(1.0 / (MUT_ETA + 1.0)) - 1.0
                } else {
                    1.0 - (2.0 * (1.0 - u)).powf(1.0 / (MUT_ETA + 1.0))
                };
                x[i] += delta * span;
            }
        }
        self.space.clamp(x);
    }

    /// Environmental selection: keep the best POP by (rank, crowding).
    fn environmental_selection(&mut self) {
        if self.pop.len() <= POP {
            return;
        }
        let ranks = Self::ranks(&self.pop);
        let crowd = Self::crowding(&self.pop);
        let mut idx: Vec<usize> = (0..self.pop.len()).collect();
        idx.sort_by(|&a, &b| {
            ranks[a]
                .cmp(&ranks[b])
                .then(crowd[b].partial_cmp(&crowd[a]).unwrap_or(std::cmp::Ordering::Equal))
        });
        idx.truncate(POP);
        let mut keep: Vec<bool> = vec![false; self.pop.len()];
        for &i in &idx {
            keep[i] = true;
        }
        let mut i = 0;
        self.pop.retain(|_| {
            let k = keep[i];
            i += 1;
            k
        });
    }
}

impl Searcher for Nsga2 {
    fn name(&self) -> &'static str {
        "nsga2"
    }

    fn ask(&mut self) -> Vec<f64> {
        if self.pop.len() < POP {
            // initial population: random
            return self.space.sample(&mut self.rng);
        }
        // breed one offspring
        let ranks = Self::ranks(&self.pop);
        let crowd = Self::crowding(&self.pop);
        let a = self.select(&ranks, &crowd);
        let b = self.select(&ranks, &crowd);
        let (pa, pb) = (self.pop[a].x.clone(), self.pop[b].x.clone());
        let mut child = self.sbx_crossover(&pa, &pb);
        self.mutate(&mut child);
        child
    }

    fn tell(&mut self, trial: Trial) {
        self.pop.push(trial);
        self.environmental_selection();
    }

    /// Generation-at-a-time batching — NSGA-II's natural form: every
    /// offspring of one batch is bred from the SAME snapshot of the
    /// parent population (ranks and crowding computed once), so the
    /// whole generation can be evaluated concurrently.
    fn ask_batch(&mut self, n: usize) -> Vec<Vec<f64>> {
        let mut out = Vec::with_capacity(n);
        // fill the initial population (counting proposals already in
        // flight within this batch) with random samples
        while out.len() < n && (self.pop.is_empty() || self.pop.len() + out.len() < POP) {
            out.push(self.space.sample(&mut self.rng));
        }
        if out.len() < n {
            let ranks = Self::ranks(&self.pop);
            let crowd = Self::crowding(&self.pop);
            while out.len() < n {
                let a = self.select(&ranks, &crowd);
                let b = self.select(&ranks, &crowd);
                let (pa, pb) = (self.pop[a].x.clone(), self.pop[b].x.clone());
                let mut child = self.sbx_crossover(&pa, &pb);
                self.mutate(&mut child);
                out.push(child);
            }
        }
        out
    }

    /// (μ+λ) generational replacement: merge the evaluated offspring
    /// into the population, then select the best POP once.
    fn tell_batch(&mut self, trials: Vec<Trial>) {
        self.pop.extend(trials);
        self.environmental_selection();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trial(objs: Vec<f64>) -> Trial {
        Trial { x: vec![0.0], value: objs.iter().sum(), objectives: objs }
    }

    #[test]
    fn dominance_relation() {
        let a = trial(vec![1.0, 1.0]);
        let b = trial(vec![0.5, 0.5]);
        let c = trial(vec![1.5, 0.2]);
        assert!(Nsga2::dominates(&a, &b));
        assert!(!Nsga2::dominates(&b, &a));
        assert!(!Nsga2::dominates(&a, &c) && !Nsga2::dominates(&c, &a));
    }

    #[test]
    fn nondominated_sort_ranks_fronts() {
        let pop = vec![
            trial(vec![1.0, 0.0]),
            trial(vec![0.0, 1.0]),
            trial(vec![0.4, 0.4]), // dominated by neither extreme? (0.4<1, 0.4>0) -> front 0
            trial(vec![0.1, 0.1]), // dominated by (0.4,0.4)
        ];
        let ranks = Nsga2::ranks(&pop);
        assert_eq!(ranks[0], 0);
        assert_eq!(ranks[1], 0);
        assert_eq!(ranks[2], 0);
        assert_eq!(ranks[3], 1);
    }

    #[test]
    fn crowding_prefers_extremes() {
        let pop = vec![
            trial(vec![0.0, 1.0]),
            trial(vec![0.5, 0.5]),
            trial(vec![0.52, 0.48]),
            trial(vec![1.0, 0.0]),
        ];
        let c = Nsga2::crowding(&pop);
        assert!(c[0].is_infinite() && c[3].is_infinite());
        assert!(c[1] > 0.0 && c[2] > 0.0);
    }

    #[test]
    fn population_bounded() {
        let mut s = Nsga2::new(Space::uniform(2, 0.0, 1.0), 1);
        for i in 0..60 {
            let x = s.ask();
            let v = -(x[0] - 0.5f64).powi(2);
            s.tell(Trial { x, value: v, objectives: vec![v, i as f64 * 0.0] });
        }
        assert!(s.pop.len() <= POP);
    }
}
