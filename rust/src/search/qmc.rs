//! Quasi-Monte-Carlo search via the Halton low-discrepancy sequence —
//! the paper's "QMC" contender in Fig. 4: fast, even space coverage, but
//! unguided (no exploitation), so it tends to plateau sub-optimally.

use super::{Searcher, Space, Trial};

const PRIMES: [u64; 16] = [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53];

/// Radical-inverse of `index` in base `b` (van der Corput).
fn radical_inverse(mut index: u64, b: u64) -> f64 {
    let mut f = 1.0;
    let mut r = 0.0;
    while index > 0 {
        f /= b as f64;
        r += f * (index % b) as f64;
        index /= b;
    }
    r
}

pub struct HaltonSearch {
    space: Space,
    index: u64,
}

impl HaltonSearch {
    pub fn new(space: Space) -> Self {
        // skip the first few points (standard Halton burn-in)
        Self { space, index: 20 }
    }
}

impl Searcher for HaltonSearch {
    fn name(&self) -> &'static str {
        "qmc"
    }

    fn ask(&mut self) -> Vec<f64> {
        self.index += 1;
        (0..self.space.dims())
            .map(|d| {
                let u = radical_inverse(self.index, PRIMES[d % PRIMES.len()]);
                self.space.lo[d] + u * (self.space.hi[d] - self.space.lo[d])
            })
            .collect()
    }

    fn tell(&mut self, _trial: Trial) {}

    // `ask_batch`/`tell_batch` use the trait defaults: the Halton
    // sequence is feedback-free, so a batch is simply the next n points
    // of the sequence — identical to n serial asks.
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn radical_inverse_base2_known_values() {
        assert_eq!(radical_inverse(1, 2), 0.5);
        assert_eq!(radical_inverse(2, 2), 0.25);
        assert_eq!(radical_inverse(3, 2), 0.75);
        assert_eq!(radical_inverse(4, 2), 0.125);
    }

    #[test]
    fn low_discrepancy_beats_expectation_gap() {
        // Halton points in [0,1): every length-1/8 bin gets hit in 64 draws.
        let mut s = HaltonSearch::new(Space::uniform(1, 0.0, 1.0));
        let mut bins = [0; 8];
        for _ in 0..64 {
            bins[(s.ask()[0] * 8.0) as usize] += 1;
        }
        assert!(bins.iter().all(|&c| c >= 4), "{bins:?}");
    }

    #[test]
    fn batched_asks_continue_the_sequence() {
        let mut serial = HaltonSearch::new(Space::uniform(2, 0.0, 1.0));
        let mut batched = HaltonSearch::new(Space::uniform(2, 0.0, 1.0));
        let want: Vec<Vec<f64>> = (0..8).map(|_| serial.ask()).collect();
        assert_eq!(batched.ask_batch(8), want);
    }

    #[test]
    fn dims_use_distinct_bases() {
        let mut s = HaltonSearch::new(Space::uniform(2, 0.0, 1.0));
        let pts: Vec<Vec<f64>> = (0..32).map(|_| s.ask()).collect();
        // dimensions must not be perfectly correlated
        let corr: f64 = pts.iter().map(|p| (p[0] - 0.5) * (p[1] - 0.5)).sum::<f64>() / 32.0;
        assert!(corr.abs() < 0.05, "{corr}");
    }
}
