//! Search algorithms orchestrated by the `search` pass (paper §3.3,
//! Fig. 4): Random Search, Quasi-Monte-Carlo (Halton), NSGA-II, and TPE.
//! All are implemented from scratch (no external optimizer crates) and
//! share one ask/tell interface so the pass can swap them freely — the
//! paper's "orchestrate existing search algorithms" contribution.
//!
//! Convention: the searcher MAXIMIZES the scalar objective (Eq. 4).

pub mod nsga2;
pub mod qmc;
pub mod random;
pub mod tpe;

use crate::util::rng::Rng;

/// A bounded, real-valued search space; dimensions are rounded to integers
/// by the objective where appropriate (mantissa bits, log2 tile sizes).
#[derive(Debug, Clone)]
pub struct Space {
    pub lo: Vec<f64>,
    pub hi: Vec<f64>,
}

impl Space {
    pub fn new(lo: Vec<f64>, hi: Vec<f64>) -> Self {
        assert_eq!(lo.len(), hi.len());
        Self { lo, hi }
    }

    /// Uniform box `[lo, hi]^dims`.
    pub fn uniform(dims: usize, lo: f64, hi: f64) -> Self {
        Self { lo: vec![lo; dims], hi: vec![hi; dims] }
    }

    pub fn dims(&self) -> usize {
        self.lo.len()
    }

    pub fn sample(&self, rng: &mut Rng) -> Vec<f64> {
        (0..self.dims()).map(|i| rng.range(self.lo[i], self.hi[i])).collect()
    }

    pub fn clamp(&self, x: &mut [f64]) {
        for i in 0..x.len() {
            x[i] = x[i].clamp(self.lo[i], self.hi[i]);
        }
    }
}

/// One evaluated configuration.
#[derive(Debug, Clone)]
pub struct Trial {
    pub x: Vec<f64>,
    /// Scalarized objective (Eq. 4) — maximized.
    pub value: f64,
    /// Raw objective components (acc, k/b, k'θ, k''/A) for NSGA-II's
    /// non-dominated sorting and for reporting.
    pub objectives: Vec<f64>,
}

/// Ask/tell searcher interface.
pub trait Searcher {
    fn name(&self) -> &'static str;
    /// Propose the next configuration.
    fn ask(&mut self) -> Vec<f64>;
    /// Report the evaluated trial.
    fn tell(&mut self, trial: Trial);
}

/// Algorithm selector (Fig. 4 legend).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    Random,
    Qmc,
    NsgaII,
    Tpe,
}

impl Algorithm {
    pub const ALL: [Algorithm; 4] = [Algorithm::Random, Algorithm::Qmc, Algorithm::NsgaII, Algorithm::Tpe];

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Random => "random",
            Algorithm::Qmc => "qmc",
            Algorithm::NsgaII => "nsga2",
            Algorithm::Tpe => "tpe",
        }
    }

    pub fn from_name(s: &str) -> Option<Algorithm> {
        Algorithm::ALL.iter().copied().find(|a| a.name() == s)
    }

    pub fn build(&self, space: Space, seed: u64) -> Box<dyn Searcher> {
        match self {
            Algorithm::Random => Box::new(random::RandomSearch::new(space, seed)),
            Algorithm::Qmc => Box::new(qmc::HaltonSearch::new(space)),
            Algorithm::NsgaII => Box::new(nsga2::Nsga2::new(space, seed)),
            Algorithm::Tpe => Box::new(tpe::Tpe::new(space, seed)),
        }
    }
}

/// Drive a searcher against an objective for `trials` evaluations,
/// returning the history (used by Fig. 4 and the search pass).
pub fn run<F>(alg: Algorithm, space: Space, seed: u64, trials: usize, mut objective: F) -> Vec<Trial>
where
    F: FnMut(&[f64]) -> (f64, Vec<f64>),
{
    let mut s = alg.build(space, seed);
    let mut history = Vec::with_capacity(trials);
    for _ in 0..trials {
        let x = s.ask();
        let (value, objectives) = objective(&x);
        let t = Trial { x, value, objectives };
        s.tell(t.clone());
        history.push(t);
    }
    history
}

/// Best trial so far at each step (the Fig. 4 curves).
pub fn best_curve(history: &[Trial]) -> Vec<f64> {
    let mut best = f64::NEG_INFINITY;
    history
        .iter()
        .map(|t| {
            best = best.max(t.value);
            best
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smooth unimodal objective: -(x - 0.7)^2 summed, peak at 0.7^d.
    fn sphere(x: &[f64]) -> (f64, Vec<f64>) {
        let v = -x.iter().map(|xi| (xi - 0.7) * (xi - 0.7)).sum::<f64>();
        (v, vec![v])
    }

    #[test]
    fn all_algorithms_improve_on_sphere() {
        for alg in Algorithm::ALL {
            let hist = run(alg, Space::uniform(4, 0.0, 1.0), 1, 80, sphere);
            let curve = best_curve(&hist);
            assert!(
                curve.last().unwrap() > &-0.08,
                "{} final {}",
                alg.name(),
                curve.last().unwrap()
            );
            // curve is monotone nondecreasing
            for w in curve.windows(2) {
                assert!(w[1] >= w[0]);
            }
        }
    }

    #[test]
    fn guided_beats_random_on_average() {
        // TPE should beat random search on the sphere across seeds.
        let mut tpe_sum = 0.0;
        let mut rnd_sum = 0.0;
        for seed in 0..5 {
            let t = run(Algorithm::Tpe, Space::uniform(6, 0.0, 1.0), seed, 60, sphere);
            let r = run(Algorithm::Random, Space::uniform(6, 0.0, 1.0), seed, 60, sphere);
            tpe_sum += best_curve(&t).last().unwrap();
            rnd_sum += best_curve(&r).last().unwrap();
        }
        assert!(tpe_sum > rnd_sum, "tpe {tpe_sum} vs random {rnd_sum}");
    }

    #[test]
    fn proposals_stay_in_bounds() {
        for alg in Algorithm::ALL {
            let space = Space::uniform(3, 2.0, 8.0);
            let mut s = alg.build(space.clone(), 3);
            for i in 0..40 {
                let x = s.ask();
                for &xi in &x {
                    assert!((2.0..=8.0).contains(&xi), "{} out of bounds {xi}", alg.name());
                }
                s.tell(Trial { x, value: -(i as f64), objectives: vec![] });
            }
        }
    }

    #[test]
    fn algorithm_name_round_trip() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::from_name(a.name()), Some(a));
        }
    }
}
