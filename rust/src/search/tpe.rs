//! Tree-structured Parzen Estimator (Bergstra et al. 2011) — the winner
//! in the paper's Fig. 4 and the algorithm used for all experiments.
//!
//! Univariate TPE: split observed trials into "good" (top gamma quantile
//! of the maximized objective) and "bad"; model each dimension of each set
//! with a Parzen window (Gaussian KDE, bandwidth from neighbor spacing);
//! draw candidates from the good model and keep the one maximizing
//! l_good(x)/l_bad(x) (equivalent to maximizing expected improvement).

use super::{Searcher, Space, Trial};
use crate::util::rng::Rng;

const GAMMA: f64 = 0.25;
const N_STARTUP: usize = 10;
const N_EI_CANDIDATES: usize = 24;

/// Value assigned to constant-liar placeholders during `ask_batch`
/// (Ginsbourger et al.'s kriging-believer family, applied to TPE).
///
/// `Min` — the worst observed value: maximally repels the rest of the
/// batch from in-flight proposals, at the cost of branding every pending
/// region "bad". `Mean` — the mean observed value: a neutral belief that
/// still discourages exact duplicates but lets the KDE keep treating a
/// promising region as promising, which helps at large batch sizes
/// (ROADMAP: evaluate vs Fig. 4 convergence at batch 8–16).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LieStrategy {
    /// Worst (minimum) finite observed value — the conservative default.
    #[default]
    Min,
    /// Mean of the finite observed values.
    Mean,
}

pub struct Tpe {
    space: Space,
    rng: Rng,
    history: Vec<Trial>,
    /// Number of constant-liar placeholders currently at the tail of
    /// `history` (see `ask_batch`); retracted before real results land.
    lies: usize,
    lie_strategy: LieStrategy,
}

impl Tpe {
    pub fn new(space: Space, seed: u64) -> Self {
        Self {
            space,
            rng: Rng::new(seed),
            history: Vec::new(),
            lies: 0,
            lie_strategy: LieStrategy::Min,
        }
    }

    /// Select the constant-liar variant used by `ask_batch`.
    pub fn with_lie(mut self, lie: LieStrategy) -> Self {
        self.lie_strategy = lie;
        self
    }

    /// The placeholder value for the current history (0.0 when empty).
    fn lie_value(&self) -> f64 {
        let finite: Vec<f64> =
            self.history.iter().map(|t| t.value).filter(|v| v.is_finite()).collect();
        if finite.is_empty() {
            return 0.0;
        }
        match self.lie_strategy {
            LieStrategy::Min => finite.iter().copied().fold(f64::INFINITY, f64::min),
            LieStrategy::Mean => finite.iter().sum::<f64>() / finite.len() as f64,
        }
    }

    fn retract_lies(&mut self) {
        let keep = self.history.len() - self.lies;
        self.history.truncate(keep);
        self.lies = 0;
    }

    /// Parzen-window log density of `x` under samples `mu` with per-sample
    /// bandwidth, truncated to the search box.
    fn log_density(x: f64, mu: &[f64], lo: f64, hi: f64) -> f64 {
        let span = (hi - lo).max(1e-12);
        let n = mu.len() as f64;
        // bandwidth: Silverman-ish, floored to keep the KDE from collapsing
        let sigma = (span / n.powf(0.8)).max(span * 0.05);
        let mut acc = 0.0f64;
        for &m in mu {
            let z = (x - m) / sigma;
            acc += (-0.5 * z * z).exp();
        }
        ((acc / (n * sigma * (2.0 * std::f64::consts::PI).sqrt())).max(1e-300)).ln()
    }
}

impl Searcher for Tpe {
    fn name(&self) -> &'static str {
        "tpe"
    }

    fn ask(&mut self) -> Vec<f64> {
        // startup gate counts REAL trials only: constant-liar placeholders
        // must not flip a large first batch into KDE mode over fabricated
        // values (lies still feed the model once real history exists —
        // sitting at the worst value, they repel in-flight duplicates)
        if self.history.len() - self.lies < N_STARTUP {
            return self.space.sample(&mut self.rng);
        }
        // split good/bad by the gamma quantile of the (maximized) value
        let mut sorted: Vec<usize> = (0..self.history.len()).collect();
        sorted.sort_by(|&a, &b| {
            self.history[b].value.partial_cmp(&self.history[a].value).unwrap()
        });
        let n_good = ((self.history.len() as f64 * GAMMA).ceil() as usize).max(2);
        let good: Vec<usize> = sorted[..n_good].to_vec();
        let bad: Vec<usize> = sorted[n_good..].to_vec();

        let dims = self.space.dims();
        let mut best_x = vec![0.0; dims];
        let mut best_score = f64::NEG_INFINITY;
        for _ in 0..N_EI_CANDIDATES {
            // sample each dim from the good KDE: pick a good point, jitter
            let mut x = Vec::with_capacity(dims);
            for d in 0..dims {
                let pick = good[self.rng.below(good.len())];
                let span = self.space.hi[d] - self.space.lo[d];
                let sigma = (span / (good.len() as f64).powf(0.8)).max(span * 0.05);
                let v = self.history[pick].x[d] + self.rng.normal() * sigma;
                x.push(v.clamp(self.space.lo[d], self.space.hi[d]));
            }
            // score = sum_d log l_g - log l_b
            let mut score = 0.0;
            for d in 0..dims {
                let gmu: Vec<f64> = good.iter().map(|&i| self.history[i].x[d]).collect();
                let bmu: Vec<f64> = bad.iter().map(|&i| self.history[i].x[d]).collect();
                let lg = Self::log_density(x[d], &gmu, self.space.lo[d], self.space.hi[d]);
                let lb = if bmu.is_empty() {
                    0.0
                } else {
                    Self::log_density(x[d], &bmu, self.space.lo[d], self.space.hi[d])
                };
                score += lg - lb;
            }
            if score > best_score {
                best_score = score;
                best_x = x;
            }
        }
        best_x
    }

    fn tell(&mut self, trial: Trial) {
        self.retract_lies();
        self.history.push(trial);
    }

    /// Constant-liar batching (Ginsbourger et al.): after proposing each
    /// point, provisionally record it with a fabricated value (the
    /// [`LieStrategy`]: worst-observed by default, or the observed mean),
    /// so the next proposal of the same batch treats that region as
    /// already claimed and explores elsewhere. The lies are retracted
    /// when the real evaluations arrive.
    fn ask_batch(&mut self, n: usize) -> Vec<Vec<f64>> {
        self.retract_lies();
        let lie = self.lie_value();
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let x = self.ask();
            self.history.push(Trial { x: x.clone(), value: lie, objectives: vec![] });
            self.lies += 1;
            out.push(x);
        }
        out
    }

    fn tell_batch(&mut self, trials: Vec<Trial>) {
        self.retract_lies();
        self.history.extend(trials);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn startup_phase_is_random_exploration() {
        let mut s = Tpe::new(Space::uniform(2, 0.0, 1.0), 1);
        for _ in 0..N_STARTUP - 1 {
            let x = s.ask();
            s.tell(Trial { x, value: 0.0, objectives: vec![] });
        }
        assert_eq!(s.history.len(), N_STARTUP - 1);
    }

    #[test]
    fn concentrates_near_good_region() {
        // feed trials where value peaks at x=0.2; proposals should cluster
        let mut s = Tpe::new(Space::uniform(1, 0.0, 1.0), 2);
        for i in 0..30 {
            let x = vec![(i as f64) / 30.0];
            let v = -(x[0] - 0.2f64).powi(2);
            s.tell(Trial { x, value: v, objectives: vec![] });
        }
        let proposals: Vec<f64> = (0..30).map(|_| s.ask()[0]).collect();
        let near = proposals.iter().filter(|&&p| (p - 0.2).abs() < 0.2).count();
        assert!(near > 20, "only {near}/30 proposals near optimum: {proposals:?}");
    }

    #[test]
    fn large_first_batch_stays_in_startup_exploration() {
        // lies must not count toward N_STARTUP: a first batch larger than
        // N_STARTUP is pure random exploration, not a KDE fitted to
        // fabricated 0.0-valued placeholders
        let mut s = Tpe::new(Space::uniform(2, 0.0, 1.0), 7);
        let xs = s.ask_batch(N_STARTUP + 6);
        let space = Space::uniform(2, 0.0, 1.0);
        let mut rng = Rng::new(7);
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(x, &space.sample(&mut rng), "proposal {i} left the startup phase");
        }
    }

    #[test]
    fn constant_liar_batch_records_then_retracts_lies() {
        let mut s = Tpe::new(Space::uniform(1, 0.0, 1.0), 3);
        for i in 0..N_STARTUP + 5 {
            let x = vec![(i as f64) / 15.0];
            let v = -(x[0] - 0.2f64).powi(2);
            s.tell(Trial { x, value: v, objectives: vec![] });
        }
        let len_before = s.history.len();
        let worst = s.history.iter().map(|t| t.value).fold(f64::INFINITY, f64::min);
        let xs = s.ask_batch(6);
        assert_eq!(xs.len(), 6);
        // lies present during the batch, all at the pessimistic value
        assert_eq!(s.history.len(), len_before + 6);
        assert!(s.history[len_before..].iter().all(|t| t.value == worst));
        let trials: Vec<Trial> = xs
            .into_iter()
            .map(|x| {
                let v = -(x[0] - 0.2f64).powi(2);
                Trial { x, value: v, objectives: vec![] }
            })
            .collect();
        s.tell_batch(trials);
        // lies retracted, truth recorded, no growth beyond the batch
        assert_eq!(s.history.len(), len_before + 6);
        for t in &s.history[len_before..] {
            assert_eq!(t.value, -(t.x[0] - 0.2f64).powi(2), "lie left in history");
        }
    }

    #[test]
    fn mean_lie_places_placeholders_at_observed_mean() {
        let mut s = Tpe::new(Space::uniform(1, 0.0, 1.0), 4).with_lie(LieStrategy::Mean);
        for v in [1.0, 2.0, 6.0] {
            s.tell(Trial { x: vec![0.5], value: v, objectives: vec![] });
        }
        let len_before = s.history.len();
        s.ask_batch(3);
        assert!(s.history[len_before..].iter().all(|t| t.value == 3.0), "mean of 1,2,6 is 3");

        // the default stays at the worst observed value
        let mut m = Tpe::new(Space::uniform(1, 0.0, 1.0), 4);
        for v in [1.0, 2.0, 6.0] {
            m.tell(Trial { x: vec![0.5], value: v, objectives: vec![] });
        }
        m.ask_batch(2);
        assert!(m.history[3..].iter().all(|t| t.value == 1.0));
    }

    #[test]
    fn lie_value_ignores_failed_trials() {
        let mut s = Tpe::new(Space::uniform(1, 0.0, 1.0), 4).with_lie(LieStrategy::Mean);
        s.tell(Trial { x: vec![0.1], value: f64::NEG_INFINITY, objectives: vec![] });
        s.tell(Trial { x: vec![0.2], value: 4.0, objectives: vec![] });
        assert_eq!(s.lie_value(), 4.0, "non-finite failures must not poison the mean");
    }

    #[test]
    fn log_density_higher_at_samples() {
        let mu = vec![0.5, 0.52, 0.48];
        let at_mode = Tpe::log_density(0.5, &mu, 0.0, 1.0);
        let far = Tpe::log_density(0.95, &mu, 0.0, 1.0);
        assert!(at_mode > far);
    }
}
