//! Random search (Bergstra & Bengio) — the Fig. 4 baseline.

use super::{Searcher, Space, Trial};
use crate::util::rng::Rng;

pub struct RandomSearch {
    space: Space,
    rng: Rng,
}

impl RandomSearch {
    pub fn new(space: Space, seed: u64) -> Self {
        Self { space, rng: Rng::new(seed) }
    }
}

impl Searcher for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn ask(&mut self) -> Vec<f64> {
        self.space.sample(&mut self.rng)
    }

    fn tell(&mut self, _trial: Trial) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_cover_space() {
        let mut s = RandomSearch::new(Space::uniform(1, 0.0, 1.0), 1);
        let xs: Vec<f64> = (0..200).map(|_| s.ask()[0]).collect();
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(lo < 0.1 && hi > 0.9);
    }
}
