//! Random search (Bergstra & Bengio) — the Fig. 4 baseline.

use super::{Searcher, Space, Trial};
use crate::util::rng::Rng;

pub struct RandomSearch {
    space: Space,
    rng: Rng,
}

impl RandomSearch {
    pub fn new(space: Space, seed: u64) -> Self {
        Self { space, rng: Rng::new(seed) }
    }
}

impl Searcher for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn ask(&mut self) -> Vec<f64> {
        self.space.sample(&mut self.rng)
    }

    fn tell(&mut self, _trial: Trial) {}

    // `ask_batch`/`tell_batch` use the trait defaults: n independent
    // draws ARE random search's batched form (proposals never depend on
    // feedback), so batching changes nothing but the evaluation cadence.
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batched_asks_match_serial_asks() {
        // same seed: one ask_batch(6) must replay six serial asks
        let mut serial = RandomSearch::new(Space::uniform(3, 0.0, 1.0), 9);
        let mut batched = RandomSearch::new(Space::uniform(3, 0.0, 1.0), 9);
        let want: Vec<Vec<f64>> = (0..6).map(|_| serial.ask()).collect();
        assert_eq!(batched.ask_batch(6), want);
    }

    #[test]
    fn samples_cover_space() {
        let mut s = RandomSearch::new(Space::uniform(1, 0.0, 1.0), 1);
        let xs: Vec<f64> = (0..200).map(|_| s.ask()[0]).collect();
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(lo < 0.1 && hi > 0.9);
    }
}
