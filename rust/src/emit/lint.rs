//! Structural linter for the emitted SystemVerilog — the closest
//! verification we can run without a synthesis tool: balanced
//! module/endmodule and begin/end, no unterminated strings, referenced
//! handshake signals present, generate blocks closed.

#[derive(Debug, Clone, PartialEq)]
pub enum LintError {
    UnbalancedModule { modules: usize, endmodules: usize },
    UnbalancedBegin { begins: usize, ends: usize },
    UnbalancedGenerate,
    UnbalancedParens { open: usize, close: usize },
    MissingHandshake(&'static str),
    EmptyModuleName,
}

/// Count whole-word occurrences.
fn count_word(text: &str, word: &str) -> usize {
    let mut count = 0;
    let b = text.as_bytes();
    let mut start = 0;
    while let Some(pos) = text[start..].find(word) {
        let i = start + pos;
        let before_ok = i == 0 || !(b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'_');
        let j = i + word.len();
        let after_ok = j >= b.len() || !(b[j].is_ascii_alphanumeric() || b[j] == b'_');
        if before_ok && after_ok {
            count += 1;
        }
        start = i + word.len();
    }
    count
}

/// Strip `//` line comments and `/* ... */` block comments (including
/// multi-line) so keyword counting ignores them. Newlines inside block
/// comments are preserved, keeping the output line-aligned with the
/// source. An unterminated block comment swallows the rest of the text
/// — which then fails the balance checks, as it should.
fn strip_comments(text: &str) -> String {
    let b = text.as_bytes();
    let mut out = String::with_capacity(text.len());
    let mut i = 0;
    let mut run = 0; // start of the current non-comment byte run
    while i < b.len() {
        if b[i] == b'/' && b.get(i + 1) == Some(&b'/') {
            out.push_str(&text[run..i]);
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            run = i;
        } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
            out.push_str(&text[run..i]);
            i += 2;
            while i < b.len() && !(b[i] == b'*' && b.get(i + 1) == Some(&b'/')) {
                if b[i] == b'\n' {
                    out.push('\n');
                }
                i += 1;
            }
            i = (i + 2).min(b.len());
            run = i;
        } else {
            i += 1;
        }
    }
    out.push_str(&text[run..]);
    out
}

pub fn lint_sv(text: &str) -> Vec<LintError> {
    let code = strip_comments(text);
    let mut errors = Vec::new();

    let modules = count_word(&code, "module");
    let endmodules = count_word(&code, "endmodule");
    if modules != endmodules {
        errors.push(LintError::UnbalancedModule { modules, endmodules });
    }
    if endmodules == 0 {
        errors.push(LintError::EmptyModuleName);
    }

    let begins = count_word(&code, "begin");
    let ends = count_word(&code, "end");
    if begins != ends {
        errors.push(LintError::UnbalancedBegin { begins, ends });
    }

    if count_word(&code, "generate") != count_word(&code, "endgenerate") {
        errors.push(LintError::UnbalancedGenerate);
    }

    let open = code.matches('(').count();
    let close = code.matches(')').count();
    if open != close {
        errors.push(LintError::UnbalancedParens { open, close });
    }

    // every streaming module must expose the handshake contract
    for sig in ["in_valid", "in_ready", "out_valid", "out_ready"] {
        if !code.contains(sig) {
            errors.push(LintError::MissingHandshake(match sig {
                "in_valid" => "in_valid",
                "in_ready" => "in_ready",
                "out_valid" => "out_valid",
                _ => "out_ready",
            }));
        }
    }

    errors
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "module m (\n input logic in_valid,\n output logic in_ready,\n output logic out_valid,\n input logic out_ready\n);\n always_ff begin\n x <= 1;\n end\nendmodule\n";

    #[test]
    fn accepts_balanced_module() {
        assert!(lint_sv(GOOD).is_empty(), "{:?}", lint_sv(GOOD));
    }

    #[test]
    fn detects_missing_endmodule() {
        let bad = GOOD.replace("endmodule", "");
        assert!(lint_sv(&bad).iter().any(|e| matches!(e, LintError::UnbalancedModule { .. })));
    }

    #[test]
    fn detects_unbalanced_begin() {
        let bad = GOOD.replace(" end\n", "\n");
        assert!(lint_sv(&bad).iter().any(|e| matches!(e, LintError::UnbalancedBegin { .. })));
    }

    #[test]
    fn detects_missing_handshake() {
        let bad = GOOD.replace("out_ready", "oready");
        assert!(lint_sv(&bad).iter().any(|e| matches!(e, LintError::MissingHandshake(_))));
    }

    #[test]
    fn word_counting_ignores_substrings() {
        // "endmodule" contains "module" but must not count as one.
        assert_eq!(count_word("endmodule", "module"), 0);
        assert_eq!(count_word("module m; endmodule", "module"), 1);
    }

    #[test]
    fn comments_are_ignored() {
        let with_comment = format!("// module ghost\n{GOOD}");
        assert!(lint_sv(&with_comment).is_empty());
    }

    #[test]
    fn block_comments_are_ignored() {
        // A multi-line block comment full of keywords must not skew the
        // counters (the old line-oriented stripper only handled `//`).
        let with_block = format!("/* module ghost\n   begin generate (\n */\n{GOOD}");
        assert!(lint_sv(&with_block).is_empty(), "{:?}", lint_sv(&with_block));
        // Inline block comment in the middle of a line.
        let inline = GOOD.replace("always_ff begin", "always_ff /* begin ( */ begin");
        assert!(lint_sv(&inline).is_empty(), "{:?}", lint_sv(&inline));
        // An unterminated block comment swallows the endmodule and fails.
        let bad = format!("{GOOD}/* dangling");
        assert!(lint_sv(&bad).iter().any(|e| matches!(e, LintError::UnbalancedModule { .. })));
    }
}
