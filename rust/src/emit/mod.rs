//! SystemVerilog emission — the open-source MX hardware operator library
//! the paper ships (§3.2): parameterized dataflow operator templates with
//! handshake interfaces, plus the top-level generator that wires the IR's
//! dataflow edges together.
//!
//! We cannot run Vivado in this environment; the emitted SV is validated
//! structurally by [`lint`] (balanced modules, declared/driven signals,
//! instantiation arity) and its size/emit time feed Table 3.

pub mod lint;
pub mod templates;
pub mod verilog;

pub use lint::{lint_sv, LintError};
pub use verilog::{emit_design, EmittedDesign};
