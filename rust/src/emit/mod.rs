//! SystemVerilog emission — the open-source MX hardware operator library
//! the paper ships (§3.2): parameterized dataflow operator templates with
//! handshake interfaces, plus the top-level generator that wires the IR's
//! dataflow edges together.
//!
//! We cannot run Vivado in this environment; the emitted SV is validated
//! structurally by [`lint`] (balanced modules, declared/driven signals,
//! instantiation arity) and its size/emit time feed Table 3.
//!
//! Submodule map:
//!
//!  * [`templates`] — the parameterized operator library: one SV module
//!    skeleton per IR op kind (matmul, layernorm, softmax, …) with
//!    ready/valid handshakes and per-port WIDTH/FRAC parameters taken
//!    from the quantize pass's per-tensor precisions.
//!  * [`verilog`] — the top-level generator: instantiates one template
//!    per IR op, wires the dataflow edges (inserting the §4.2 skip-edge
//!    buffers the parallelize pass sized), and returns an
//!    [`EmittedDesign`] of named files.
//!  * [`lint`] — the structural validator standing in for a real
//!    elaboration: balanced `module`/`endmodule`, every signal declared
//!    and driven, instantiation arity against the local module set.
//!
//! Entry points: [`emit_design`] for an in-memory design,
//! `passes::emit_pass::emit_to_dir` to write it out (the `emit`
//! subcommand and the Table 3 bench).

pub mod lint;
pub mod templates;
pub mod verilog;

pub use lint::{lint_sv, LintError};
pub use verilog::{emit_design, EmittedDesign};
