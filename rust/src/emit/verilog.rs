//! Top-level SystemVerilog generation: instantiate one operator template
//! per IR op, wire the dataflow edges with handshake nets, insert FIFOs on
//! every producer/consumer edge and precision casts where neighboring
//! qtensor precisions differ (§4's cheap intra-format casts).
//!
//! Wiring discipline (checked, not trusted — `check::sv` analyzes every
//! emitted file and the emit pass gates on the result):
//! - every edge net is declared at the producing template's real output
//!   width (`width` map below), never as a 32-bit alias;
//! - consumers read the post-FIFO `v*_q_*` stream of their argument, and
//!   each consumer contributes one `v*_in_rdy` term to the producer's
//!   `q_ready` fan-in (unconsumed streams are tied ready);
//! - width changes between edges are explicit zero-extends/truncations
//!   ([`adapt`]), so every port connection is width-consistent;
//! - block-format gemm inputs pass through a channel-framed
//!   [`templates::mx_unpacker`] sized by [`templates::unpacker_config`],
//!   the same closed forms `sim`/`hw::throughput` charge.
//!
//! Simplification: the operator templates expose a single streaming input
//! port; multi-argument operators (add, attention) are wired from their
//! first dataflow argument and the side-stream handshakes are elided —
//! the emitted design is a structural skeleton of the accelerator (the
//! per-operator datapaths are the hand-written template bodies), not a
//! synthesis-ready netlist; see DESIGN.md §3 (no Vivado available).

use super::templates;
use crate::formats::{FormatKind, Precision};
use crate::ir::{Graph, OpKind};
use std::collections::BTreeMap;

/// The emitted design: file name -> SystemVerilog source.
#[derive(Debug, Clone)]
pub struct EmittedDesign {
    pub files: BTreeMap<String, String>,
    pub top_module: String,
    /// operator instances in the top level
    pub instances: usize,
}

/// The design's single arithmetic format (paper §4: one per design) —
/// the first non-fp32 value format. Shared with `check::contracts` so
/// the checker reconstructs exactly the template names this generator
/// emitted.
pub fn design_format(g: &Graph) -> FormatKind {
    g.values
        .iter()
        .map(|v| v.ty.format)
        .find(|f| *f != FormatKind::Fp32)
        .unwrap_or(FormatKind::Fp32)
}

/// Pass a net expression between two declared widths: zero-extend,
/// truncate, or pass through. These explicit adapters replace the old
/// `[31:0]`-alias convention, which the SV analyzer now rejects as a
/// port-width mismatch (MC004).
fn adapt(net: &str, frm: usize, to: usize) -> String {
    use std::cmp::Ordering;
    match frm.cmp(&to) {
        Ordering::Equal => net.to_string(),
        Ordering::Greater => format!("{net}[{}:0]", to - 1),
        Ordering::Less => format!("{{{{{n}{{1'b0}}}}, {net}}}", n = to - frm),
    }
}

/// Emit the full design for a quantized+parallelized graph at the
/// default fabric width ([`crate::hw::DEFAULT_CHANNEL_BITS`], which is
/// what [`crate::hw::Device::u250`] provisions). For a device with a
/// different `channel_bits`, use [`emit_design_at`] so the emitted
/// deserializers frame tiles at the same beat counts the performance
/// model charges.
pub fn emit_design(g: &Graph) -> EmittedDesign {
    emit_design_at(g, crate::hw::DEFAULT_CHANNEL_BITS)
}

/// Emit the full design with every dataflow channel `channel_bits` wide.
pub fn emit_design_at(g: &Graph, channel_bits: u64) -> EmittedDesign {
    let fmt = design_format(g);
    let mut files: BTreeMap<String, String> = BTreeMap::new();
    files.insert("stream_fifo.sv".into(), templates::stream_fifo("stream_fifo", 4));
    files.insert("block_exponent.sv".into(), templates::block_exponent_unit("block_exponent"));

    // Per-edge data widths: the producing operator template's real
    // output port width, so every connection in the top level is
    // width-consistent under `check::sv`. Gemm templates stream
    // 2*LANES*MAN_W in and LANES*MAN_W*2 out (equal); fixed-function
    // templates stream W(=32)*LANES; the AXI wrapper edges are 32.
    let mut width: BTreeMap<usize, usize> = BTreeMap::new();
    for op in &g.ops {
        let Some(&r) = op.results.first() else { continue };
        let v = g.value(r);
        let lanes = v.attrs.tile.0 * v.attrs.tile.1;
        let w = match op.kind {
            OpKind::Input | OpKind::Output => 32,
            OpKind::Linear | OpKind::Attention => {
                lanes * (v.ty.precision.bits.max(1.0) as usize + 1) * 2
            }
            _ => 32 * lanes,
        };
        width.insert(r.0, w);
    }

    let mut wires = String::new();
    let mut body = String::new();
    let mut instances = 0usize;
    // result ids with a `v*_q_*` stream, in emit order
    let mut streams: Vec<usize> = Vec::new();
    // value id -> ready terms contributed by its consumers
    let mut ready_of: BTreeMap<usize, Vec<String>> = BTreeMap::new();
    let mut src_ready_expr: Option<String> = None;
    let mut sink_done = false;

    for op in &g.ops {
        if op.kind == OpKind::Input {
            // inputs enter from the AXI-stream wrapper; the first one
            // aliases the src_* ports, any extras are tied idle (the
            // wrapper exposes a single stream)
            let Some(&r) = op.results.first() else { continue };
            let net = format!("v{}", r.0);
            wires.push_str(&format!(
                "    logic {net}_q_valid, {net}_q_ready;\n    logic [31:0] {net}_q_data;\n"
            ));
            streams.push(r.0);
            if src_ready_expr.is_none() {
                body.push_str(&format!(
                    "    assign {net}_q_valid = src_valid;\n\
                     \x20   assign {net}_q_data = src_data;\n"
                ));
                src_ready_expr = Some(format!("{net}_q_ready"));
            } else {
                body.push_str(&format!(
                    "    assign {net}_q_valid = 1'b0;\n    assign {net}_q_data = '0;\n"
                ));
            }
            continue;
        }
        if op.kind == OpKind::Output {
            if sink_done {
                continue;
            }
            let Some(a) = op.args.first().map(|a| a.0).filter(|a| width.contains_key(a)) else {
                continue;
            };
            body.push_str(&format!(
                "    assign sink_valid = v{a}_q_valid;\n\
                 \x20   assign sink_data = {data};\n",
                data = adapt(&format!("v{a}_q_data"), width[&a], 32),
            ));
            ready_of.entry(a).or_default().push("sink_ready".into());
            sink_done = true;
            continue;
        }
        let Some(&r) = op.results.first() else { continue };
        let v = g.value(r);
        let tile = v.attrs.tile;
        let mantissa = v.ty.precision.bits.max(1.0) as u32;
        let (mod_name, src) = templates::template_for(op.kind, fmt, mantissa, tile);
        files.entry(format!("{mod_name}.sv")).or_insert(src);

        let net = format!("v{}", r.0);
        let w_out = width[&r.0];
        wires.push_str(&format!(
            "    logic {net}_valid, {net}_ready, {net}_q_valid, {net}_q_ready;\n\
             \x20   logic [{wm}:0] {net}_data;\n\
             \x20   logic [{wm}:0] {net}_q_data;\n\
             \x20   logic {net}_in_rdy;\n",
            wm = w_out - 1,
        ));
        streams.push(r.0);

        let is_gemm = matches!(op.kind, OpKind::Linear | OpKind::Attention);
        // first dataflow argument (side streams elided, module doc);
        // args without an emitted producer stream feed an idle channel
        let a = op.args.first().copied().filter(|a| width.contains_key(&a.0));
        if let Some(av) = a {
            ready_of.entry(av.0).or_default().push(format!("{net}_in_rdy"));
        }

        // Block-format gemms consume bit-packed streams: deserialize the
        // channel beats through the matching mx_unpacker and feed the
        // recovered shared exponent to the MAC array. The unpacker is
        // sized from the INCOMING edge — the producer value's format,
        // precision and tile, exactly the payload the simulator charges
        // that channel (`nodes_from_graph` prices the producer's result
        // tile) — never from this op's own result.
        let mut up: Option<(String, usize)> = None;
        if is_gemm {
            if let Some(av) = a {
                let va = g.value(av);
                let m_in = va.ty.precision.bits.max(1.0) as u32;
                if let Some((up_name, up_src, groups)) =
                    templates::unpacker_for(va.ty.format, m_in, va.attrs.tile, channel_bits)
                {
                    let cfg = templates::unpacker_config(
                        va.ty.format,
                        Precision::new(m_in as f32, 0.0),
                        va.attrs.tile,
                        channel_bits,
                    );
                    files.entry(format!("{up_name}.sv")).or_insert(up_src);
                    let upw = cfg.lanes * cfg.elem_bits as usize;
                    wires.push_str(&format!(
                        "    logic {net}_up_valid, {net}_up_ready;\n\
                         \x20   logic [{dw}:0] {net}_up_data;\n\
                         \x20   logic [{ew}:0] {net}_up_exp;\n",
                        dw = upw - 1,
                        ew = 8 * groups - 1,
                    ));
                    body.push_str(&format!(
                        "    {up_name} u_{net}_up (\n\
                         \x20       .clk(clk), .rst_n(rst_n),\n\
                         \x20       .in_valid(v{a}_q_valid), .in_ready({net}_in_rdy), .in_data({in_data}),\n\
                         \x20       .out_valid({net}_up_valid), .out_ready({net}_up_ready), .out_data({net}_up_data),\n\
                         \x20       .out_exp({net}_up_exp)\n\
                         \x20   );\n",
                        a = av.0,
                        in_data =
                            adapt(&format!("v{}_q_data", av.0), width[&av.0], cfg.chan as usize),
                    ));
                    instances += 1;
                    up = Some((format!("{net}_up"), upw));
                }
            }
        }

        let (feed_valid, feed_rdy, feed_data, exp_a) = match (&up, a) {
            (Some((up_net, upw)), _) => (
                format!("{up_net}_valid"),
                format!("{up_net}_ready"),
                adapt(&format!("{up_net}_data"), *upw, w_out),
                format!("{net}_up_exp[7:0]"),
            ),
            (None, Some(av)) => (
                format!("v{}_q_valid", av.0),
                format!("{net}_in_rdy"),
                adapt(&format!("v{}_q_data", av.0), width[&av.0], w_out),
                "8'd0".to_string(),
            ),
            (None, None) => (
                "1'b0".to_string(),
                format!("{net}_in_rdy"),
                "'0".to_string(),
                "8'd0".to_string(),
            ),
        };

        body.push_str(&format!(
            "    {mod_name} u_{net} (\n\
             \x20       .clk(clk), .rst_n(rst_n),\n\
             \x20       .in_valid({feed_valid}), .in_ready({feed_rdy}), .in_data({feed_data}),\n\
             \x20       .out_valid({net}_valid), .out_ready({net}_ready), .out_data({net}_data){extra}\n\
             \x20   );\n",
            extra = if is_gemm {
                format!(",\n        .in_exp_a({exp_a}), .in_exp_b(8'd0), .out_exp()")
            } else {
                String::new()
            },
        ));
        instances += 1;

        // FIFO on the edge to decouple stages (buffer insertion, §4.2),
        // at the edge's real width
        body.push_str(&format!(
            "    stream_fifo #(.W({w_out}), .DEPTH(4)) fifo_{net} (\n\
             \x20       .clk(clk), .rst_n(rst_n),\n\
             \x20       .in_valid({net}_valid), .in_ready({net}_ready), .in_data({net}_data),\n\
             \x20       .out_valid({net}_q_valid), .out_ready({net}_q_ready), .out_data({net}_q_data)\n\
             \x20   );\n",
        ));
        instances += 1;
    }

    // each buffered stream's ready is the AND of its consumers' ready
    // terms; unconsumed streams are tied ready so they drain freely
    for r in &streams {
        let rdys = ready_of.remove(r).unwrap_or_default();
        let expr = if rdys.is_empty() { "1'b1".to_string() } else { rdys.join(" & ") };
        body.push_str(&format!("    assign v{r}_q_ready = {expr};\n"));
    }
    let mut tail = String::new();
    match &src_ready_expr {
        Some(e) => tail.push_str(&format!("    assign src_ready  = {e};\n")),
        None => tail.push_str("    assign src_ready  = 1'b1;\n"),
    }
    if !sink_done {
        tail.push_str("    assign sink_valid = 1'b0;\n    assign sink_data  = 32'd0;\n");
    }

    let top = format!(
        "// Auto-generated by MASE-RS: top-level dataflow accelerator for @{name}\n\
         // format = {fmt}, operators = {instances}\n\
         module {name}_top (\n\
         \x20   input  logic        clk,\n\
         \x20   input  logic        rst_n,\n\
         \x20   input  logic        src_valid,\n\
         \x20   output logic        src_ready,\n\
         \x20   input  logic [31:0] src_data,\n\
         \x20   output logic        sink_valid,\n\
         \x20   input  logic        sink_ready,\n\
         \x20   output logic [31:0] sink_data\n\
         );\n\
         {wires}\n{body}{tail}\
         endmodule\n",
        name = sanitize(&g.name),
        fmt = fmt.name(),
    );
    files.insert("top.sv".into(), top);

    EmittedDesign { files, top_module: format!("{}_top", sanitize(&g.name)), instances }
}

fn sanitize(name: &str) -> String {
    name.chars().map(|c| if c.is_alphanumeric() { c } else { '_' }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emit::lint::lint_sv;
    use crate::frontend::{build_graph, manifest::ModelMeta};
    use crate::hw::Device;
    use crate::passes::{parallelize, profile::ProfileData, QuantSolution};

    fn emitted() -> EmittedDesign {
        let m = ModelMeta::synthetic("opt-test", 2, 32, 2, 512, 32, 4, "classifier", 64);
        let p = ProfileData::uniform(&m, 4.0);
        let mut g = build_graph(&m);
        QuantSolution::uniform(FormatKind::MxInt, 5.0, &m, &p).apply(&mut g);
        parallelize(&mut g, &Device::u250(), 0.2);
        emit_design(&g)
    }

    #[test]
    fn all_emitted_files_lint_clean() {
        let d = emitted();
        for (name, text) in &d.files {
            let errs = lint_sv(text);
            assert!(errs.is_empty(), "{name}: {errs:?}");
        }
    }

    #[test]
    fn top_instantiates_operators_and_fifos() {
        let d = emitted();
        let top = &d.files["top.sv"];
        assert!(top.contains("module opt_test_top"));
        assert!(top.contains("stream_fifo #("));
        assert!(top.contains("mxint_linear"));
        assert!(d.instances > 10);
    }

    #[test]
    fn one_template_file_per_distinct_parameterization() {
        let d = emitted();
        // linear ops share precision 5 but differ in tile -> several files
        let linear_files = d.files.keys().filter(|k| k.contains("linear")).count();
        assert!(linear_files >= 1);
        // every file is a module
        for (name, text) in &d.files {
            assert!(text.contains("module "), "{name} has no module");
        }
    }

    #[test]
    fn block_format_gemms_get_stream_unpackers() {
        let d = emitted();
        // one unpacker file per distinct (mantissa, tile) gemm config
        let unpack_files: Vec<_> = d.files.keys().filter(|k| k.contains("_unpack_")).collect();
        assert!(!unpack_files.is_empty(), "{:?}", d.files.keys().collect::<Vec<_>>());
        let top = &d.files["top.sv"];
        assert!(top.contains("mxint_unpack_"), "unpacker must be instantiated in the top level");
        // the recovered shared exponent feeds the MAC array, replacing
        // the old hardwired 8'd0 on the gemm's A port
        assert!(top.contains("_up_exp)"), "gemm in_exp_a must come from the unpacker");
        // every unpacker advertises the device channel width
        for f in &unpack_files {
            assert!(
                f.contains(&format!("_c{}", crate::hw::DEFAULT_CHANNEL_BITS)),
                "{f} missing channel-width suffix"
            );
        }
    }

    #[test]
    fn consumers_read_buffered_streams_and_drive_ready() {
        let d = emitted();
        let top = &d.files["top.sv"];
        // every buffered stream's q_ready is assigned exactly once
        // (consumer fan-in or tied ready) — the old emitter left them
        // all undriven, which check::sv now reports
        let assigns = top.matches("_q_ready = ").count();
        let streams = top.matches("_q_valid,").count();
        assert!(assigns >= streams, "{assigns} ready assigns for {streams} streams");
        // the sink is wired from a real stream, not stubbed dead
        assert!(top.contains("assign sink_valid = v"), "{top}");
        assert!(top.contains("assign src_ready  = v"), "{top}");
    }

    #[test]
    fn elementwise_format_designs_have_no_unpackers() {
        let m = ModelMeta::synthetic("intdesign", 2, 32, 2, 512, 32, 4, "classifier", 64);
        let p = ProfileData::uniform(&m, 4.0);
        let mut g = build_graph(&m);
        QuantSolution::uniform(FormatKind::Int, 8.0, &m, &p).apply(&mut g);
        parallelize(&mut g, &Device::u250(), 0.2);
        let d = emit_design(&g);
        assert!(d.files.keys().all(|k| !k.contains("_unpack_")), "fixed point streams plain lanes");
    }

    #[test]
    fn mixed_precision_emits_distinct_templates() {
        let m = ModelMeta::synthetic("mp", 2, 32, 2, 512, 32, 4, "classifier", 64);
        let mut g = build_graph(&m);
        let mut bits = vec![3.0f32; m.num_qtensors()];
        bits[1] = 7.0; // layer0.w_qkv wider
        QuantSolution { fmt: FormatKind::MxInt, bits, fracs: vec![0.0; m.num_qtensors()] }
            .apply(&mut g);
        parallelize(&mut g, &Device::u250(), 0.2);
        let d = emit_design(&g);
        let m3 = d.files.keys().any(|k| k.contains("_m3_") || k.contains("_m1_"));
        assert!(m3, "{:?}", d.files.keys().collect::<Vec<_>>());
    }
}
