//! Top-level SystemVerilog generation: instantiate one operator template
//! per IR op, wire the dataflow edges with handshake nets, insert FIFOs on
//! every producer/consumer edge and precision casts where neighboring
//! qtensor precisions differ (§4's cheap intra-format casts).
//!
//! Simplification: the operator templates expose a single streaming input
//! port; multi-argument operators (add, attention) are wired from their
//! first dataflow argument and the side-stream handshakes are elided —
//! the emitted design is a structural skeleton of the accelerator (the
//! per-operator datapaths are the hand-written template bodies), not a
//! synthesis-ready netlist; see DESIGN.md §3 (no Vivado available).

use super::templates;
use crate::formats::FormatKind;
use crate::ir::{Graph, OpKind};
use std::collections::BTreeMap;

/// The emitted design: file name -> SystemVerilog source.
#[derive(Debug, Clone)]
pub struct EmittedDesign {
    pub files: BTreeMap<String, String>,
    pub top_module: String,
    /// operator instances in the top level
    pub instances: usize,
}

fn design_format(g: &Graph) -> FormatKind {
    g.values
        .iter()
        .map(|v| v.ty.format)
        .find(|f| *f != FormatKind::Fp32)
        .unwrap_or(FormatKind::Fp32)
}

/// Emit the full design for a quantized+parallelized graph at the
/// default fabric width ([`crate::hw::DEFAULT_CHANNEL_BITS`], which is
/// what [`crate::hw::Device::u250`] provisions). For a device with a
/// different `channel_bits`, use [`emit_design_at`] so the emitted
/// deserializers frame tiles at the same beat counts the performance
/// model charges.
pub fn emit_design(g: &Graph) -> EmittedDesign {
    emit_design_at(g, crate::hw::DEFAULT_CHANNEL_BITS)
}

/// Emit the full design with every dataflow channel `channel_bits` wide.
pub fn emit_design_at(g: &Graph, channel_bits: u64) -> EmittedDesign {
    let fmt = design_format(g);
    let mut files: BTreeMap<String, String> = BTreeMap::new();
    files.insert("stream_fifo.sv".into(), templates::stream_fifo("stream_fifo", 4));
    files.insert("block_exponent.sv".into(), templates::block_exponent_unit("block_exponent"));

    let mut body = String::new();
    let mut instances = 0;
    let mut wires = String::new();

    for op in &g.ops {
        if op.kind == OpKind::Input {
            // inputs enter from the AXI-stream wrapper: alias their nets
            if let Some(&r) = op.results.first() {
                let net = format!("v{}", r.0);
                wires.push_str(&format!(
                    "    logic {net}_valid, {net}_ready;\n    logic [31:0] {net}_data;\n\
                     \x20   assign {net}_valid = src_valid;\n    assign {net}_data = src_data;\n"
                ));
            }
            continue;
        }
        if op.kind == OpKind::Output {
            continue;
        }
        let r = match op.results.first() {
            Some(&r) => r,
            None => continue,
        };
        let v = g.value(r);
        let tile = v.attrs.tile;
        let mantissa = v.ty.precision.bits.max(1.0) as u32;
        let (mod_name, src) = templates::template_for(op.kind, fmt, mantissa, tile);
        files.entry(format!("{mod_name}.sv")).or_insert(src);

        // wires for this op's output edge
        let net = format!("v{}", r.0);
        wires.push_str(&format!(
            "    logic {net}_valid, {net}_ready;\n    logic [31:0] {net}_data;\n"
        ));

        // input edge: first arg's net (inputs of the whole design come
        // from the AXI-stream wrapper)
        let in_net = op
            .args
            .first()
            .map(|&a| format!("v{}", a.0))
            .unwrap_or_else(|| "src".to_string());

        // Block-format gemms consume bit-packed streams: deserialize the
        // channel beats through the matching mx_unpacker and feed the
        // recovered shared exponent to the MAC array. The unpacker is
        // sized from the INCOMING edge — the producer value's format,
        // precision and tile, exactly the payload the simulator charges
        // that channel (`nodes_from_graph` prices the producer's result
        // tile) — never from this op's own result.
        let is_gemm = matches!(op.kind, OpKind::Linear | OpKind::Attention);
        let unpacker = if is_gemm {
            op.args.first().and_then(|&a| {
                let v = g.value(a);
                let m = v.ty.precision.bits.max(1.0) as u32;
                templates::unpacker_for(v.ty.format, m, v.attrs.tile, channel_bits)
            })
        } else {
            None
        };
        // Skeleton convention: all data nets in the top level are 32-bit
        // aliases (module doc) — wide operator/unpacker data ports are
        // sliced/truncated exactly as the pre-existing gemm wiring is.
        // The exponent path, the part the datapath consumes, is sized
        // for real: one byte per (16, 2) block, block 0 feeding the MAC
        // array's shared-exponent adder.
        let (feed_net, exp_net) = match unpacker {
            Some((up_name, up_src, groups)) => {
                files.entry(format!("{up_name}.sv")).or_insert(up_src);
                let up = format!("{net}_up");
                wires.push_str(&format!(
                    "    logic {up}_valid, {up}_ready;\n    logic [31:0] {up}_data;\n\
                     \x20   logic [{w}:0] {up}_exp;\n",
                    w = 8 * groups - 1
                ));
                body.push_str(&format!(
                    "    {up_name} u_{up} (\n\
                     \x20       .clk(clk), .rst_n(rst_n),\n\
                     \x20       .in_valid({in_net}_valid), .in_ready({in_net}_ready), .in_data({in_net}_data[31:0]),\n\
                     \x20       .out_valid({up}_valid), .out_ready({up}_ready), .out_data({up}_data),\n\
                     \x20       .out_exp({up}_exp)\n\
                     \x20   );\n",
                ));
                instances += 1;
                (up.clone(), format!("{up}_exp[7:0]"))
            }
            None => (in_net.clone(), "8'd0".to_string()),
        };

        body.push_str(&format!(
            "    {mod_name} u_{net} (\n\
             \x20       .clk(clk), .rst_n(rst_n),\n\
             \x20       .in_valid({feed_net}_valid), .in_ready({feed_net}_ready), .in_data({feed_net}_data[31:0]),\n\
             \x20       .out_valid({net}_valid), .out_ready({net}_ready), .out_data({net}_data){extra}\n\
             \x20   );\n",
            extra = if is_gemm {
                format!(",\n        .in_exp_a({exp_net}), .in_exp_b(8'd0), .out_exp()")
            } else {
                String::new()
            },
        ));
        instances += 1;

        // FIFO on the edge to decouple stages (buffer insertion, §4.2)
        body.push_str(&format!(
            "    stream_fifo #(.W(32), .DEPTH(4)) fifo_{net} (\n\
             \x20       .clk(clk), .rst_n(rst_n),\n\
             \x20       .in_valid({net}_valid), .in_ready({net}_ready), .in_data({net}_data),\n\
             \x20       .out_valid({net}_q_valid), .out_ready({net}_q_ready), .out_data({net}_q_data)\n\
             \x20   );\n",
        ));
        wires.push_str(&format!(
            "    logic {net}_q_valid, {net}_q_ready;\n    logic [31:0] {net}_q_data;\n"
        ));
        instances += 1;
    }

    let top = format!(
        "// Auto-generated by MASE-RS: top-level dataflow accelerator for @{name}\n\
         // format = {fmt}, operators = {instances}\n\
         module {name}_top (\n\
         \x20   input  logic        clk,\n\
         \x20   input  logic        rst_n,\n\
         \x20   input  logic        src_valid,\n\
         \x20   output logic        src_ready,\n\
         \x20   input  logic [31:0] src_data,\n\
         \x20   output logic        sink_valid,\n\
         \x20   input  logic        sink_ready,\n\
         \x20   output logic [31:0] sink_data\n\
         );\n\
         {wires}\n{body}\
         \x20   // sink: last op's buffered stream\n\
         \x20   assign sink_valid = 1'b0;\n\
         \x20   assign sink_data  = 32'd0;\n\
         \x20   assign src_ready  = 1'b1;\n\
         endmodule\n",
        name = sanitize(&g.name),
        fmt = fmt.name(),
    );
    files.insert("top.sv".into(), top);

    EmittedDesign { files, top_module: format!("{}_top", sanitize(&g.name)), instances }
}

fn sanitize(name: &str) -> String {
    name.chars().map(|c| if c.is_alphanumeric() { c } else { '_' }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emit::lint::lint_sv;
    use crate::frontend::{build_graph, manifest::ModelMeta};
    use crate::hw::Device;
    use crate::passes::{parallelize, profile::ProfileData, QuantSolution};

    fn emitted() -> EmittedDesign {
        let m = ModelMeta::synthetic("opt-test", 2, 32, 2, 512, 32, 4, "classifier", 64);
        let p = ProfileData::uniform(&m, 4.0);
        let mut g = build_graph(&m);
        QuantSolution::uniform(FormatKind::MxInt, 5.0, &m, &p).apply(&mut g);
        parallelize(&mut g, &Device::u250(), 0.2);
        emit_design(&g)
    }

    #[test]
    fn all_emitted_files_lint_clean() {
        let d = emitted();
        for (name, text) in &d.files {
            let errs = lint_sv(text);
            assert!(errs.is_empty(), "{name}: {errs:?}");
        }
    }

    #[test]
    fn top_instantiates_operators_and_fifos() {
        let d = emitted();
        let top = &d.files["top.sv"];
        assert!(top.contains("module opt_test_top"));
        assert!(top.contains("stream_fifo #("));
        assert!(top.contains("mxint_linear"));
        assert!(d.instances > 10);
    }

    #[test]
    fn one_template_file_per_distinct_parameterization() {
        let d = emitted();
        // linear ops share precision 5 but differ in tile -> several files
        let linear_files = d.files.keys().filter(|k| k.contains("linear")).count();
        assert!(linear_files >= 1);
        // every file is a module
        for (name, text) in &d.files {
            assert!(text.contains("module "), "{name} has no module");
        }
    }

    #[test]
    fn block_format_gemms_get_stream_unpackers() {
        let d = emitted();
        // one unpacker file per distinct (mantissa, tile) gemm config
        let unpack_files: Vec<_> = d.files.keys().filter(|k| k.contains("_unpack_")).collect();
        assert!(!unpack_files.is_empty(), "{:?}", d.files.keys().collect::<Vec<_>>());
        let top = &d.files["top.sv"];
        assert!(top.contains("mxint_unpack_"), "unpacker must be instantiated in the top level");
        // the recovered shared exponent feeds the MAC array, replacing
        // the old hardwired 8'd0 on the gemm's A port
        assert!(top.contains("_up_exp)"), "gemm in_exp_a must come from the unpacker");
        // every unpacker advertises the device channel width
        for f in &unpack_files {
            assert!(
                f.contains(&format!("_c{}", crate::hw::DEFAULT_CHANNEL_BITS)),
                "{f} missing channel-width suffix"
            );
        }
    }

    #[test]
    fn elementwise_format_designs_have_no_unpackers() {
        let m = ModelMeta::synthetic("intdesign", 2, 32, 2, 512, 32, 4, "classifier", 64);
        let p = ProfileData::uniform(&m, 4.0);
        let mut g = build_graph(&m);
        QuantSolution::uniform(FormatKind::Int, 8.0, &m, &p).apply(&mut g);
        parallelize(&mut g, &Device::u250(), 0.2);
        let d = emit_design(&g);
        assert!(d.files.keys().all(|k| !k.contains("_unpack_")), "fixed point streams plain lanes");
    }

    #[test]
    fn mixed_precision_emits_distinct_templates() {
        let m = ModelMeta::synthetic("mp", 2, 32, 2, 512, 32, 4, "classifier", 64);
        let mut g = build_graph(&m);
        let mut bits = vec![3.0f32; m.num_qtensors()];
        bits[1] = 7.0; // layer0.w_qkv wider
        QuantSolution { fmt: FormatKind::MxInt, bits, fracs: vec![0.0; m.num_qtensors()] }
            .apply(&mut g);
        parallelize(&mut g, &Device::u250(), 0.2);
        let d = emit_design(&g);
        let m3 = d.files.keys().any(|k| k.contains("_m3_") || k.contains("_m1_"));
        assert!(m3, "{:?}", d.files.keys().collect::<Vec<_>>());
    }
}
