//! The PJRT client wrapper: artifact loading, executable cache, typed
//! tensor transfer.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A typed host tensor heading into (or out of) an execution.
#[derive(Debug, Clone)]
pub enum TensorData {
    F32(Vec<f32>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

impl TensorData {
    pub fn f32(data: &[f32], dims: &[i64]) -> Self {
        // empty dims = scalar, whose product is the empty product 1;
        // no clamp, so legitimate zero-element tensors stay consistent
        debug_assert_eq!(data.len() as i64, dims.iter().product::<i64>());
        TensorData::F32(data.to_vec(), dims.to_vec())
    }

    pub fn i32(data: &[i32], dims: &[i64]) -> Self {
        debug_assert_eq!(data.len() as i64, dims.iter().product::<i64>());
        TensorData::I32(data.to_vec(), dims.to_vec())
    }

    pub fn scalar_f32(v: f32) -> Self {
        TensorData::F32(vec![v], vec![])
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            TensorData::F32(d, dims) => {
                let l = xla::Literal::vec1(d);
                if dims.is_empty() {
                    l.reshape(&[])?
                } else {
                    l.reshape(dims)?
                }
            }
            TensorData::I32(d, dims) => {
                let l = xla::Literal::vec1(d);
                l.reshape(dims)?
            }
        })
    }
}

/// A host tensor already converted to the device literal format.
///
/// §Perf/L3: converting `TensorData` -> literal copies the buffer; the
/// search loop executes the same weights (and often the same batches)
/// hundreds of times, so the `Evaluator` prepares them once and reuses
/// them across `execute_prepared` calls.
pub struct PreparedTensor(xla::Literal);

impl TensorData {
    pub fn prepare(&self) -> Result<PreparedTensor> {
        Ok(PreparedTensor(self.to_literal()?))
    }
}

/// One output tensor of an execution.
pub struct OutputTensor(xla::Literal);

impl OutputTensor {
    pub fn to_vec_f32(&self) -> Result<Vec<f32>> {
        Ok(self.0.to_vec::<f32>()?)
    }

    pub fn to_vec_i32(&self) -> Result<Vec<i32>> {
        Ok(self.0.to_vec::<i32>()?)
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        Ok(self.0.get_first_element::<f32>()?)
    }

    pub fn scalar_i32(&self) -> Result<i32> {
        Ok(self.0.get_first_element::<i32>()?)
    }
}

/// PJRT CPU runtime with a per-artifact executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    compiles: AtomicUsize,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            dir: artifacts_dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
            compiles: AtomicUsize::new(0),
        })
    }

    /// Number of HLO compilations performed (perf counter for tests).
    pub fn compile_count(&self) -> usize {
        self.compiles.load(Ordering::Relaxed)
    }

    fn executable(&self, artifact: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(artifact) {
            return Ok(e.clone());
        }
        let path = self.dir.join(artifact);
        if !path.exists() {
            return Err(anyhow!("artifact not found: {}", path.display()));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {artifact}"))?;
        self.compiles.fetch_add(1, Ordering::Relaxed);
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(artifact.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact. All artifacts are lowered with
    /// `return_tuple=True`, so the single result literal is a tuple that
    /// we decompose into output tensors.
    pub fn execute(&self, artifact: &str, inputs: &[TensorData]) -> Result<Vec<OutputTensor>> {
        let exe = self.executable(artifact)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        let first = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("no output buffers from {artifact}"))?;
        let lit = first.to_literal_sync()?;
        let parts = lit.to_tuple()?;
        Ok(parts.into_iter().map(OutputTensor).collect())
    }

    /// Execute with pre-converted literals (the search-loop hot path:
    /// no per-call host-buffer copies for reused tensors).
    pub fn execute_prepared(
        &self,
        artifact: &str,
        inputs: &[&PreparedTensor],
    ) -> Result<Vec<OutputTensor>> {
        let exe = self.executable(artifact)?;
        let literals: Vec<&xla::Literal> = inputs.iter().map(|t| &t.0).collect();
        let result = exe.execute::<&xla::Literal>(&literals)?;
        let first = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("no output buffers from {artifact}"))?;
        let lit = first.to_literal_sync()?;
        let parts = lit.to_tuple()?;
        Ok(parts.into_iter().map(OutputTensor).collect())
    }

    /// Pre-compile an artifact without executing (warm-up).
    pub fn warm(&self, artifact: &str) -> Result<()> {
        self.executable(artifact).map(|_| ())
    }
}
