//! KV-cached autoregressive decode engine for [`CpuBackend`] — the
//! serving-side counterpart of the batch interpreter ([`super::interp`]).
//!
//! ## The decode convention (and why it is bitwise-reproducible)
//!
//! A [`Decoder`] runs one *group* of sequences in lockstep, one position
//! at a time. Activations are laid out **position-major**: a step is a
//! `[group, k]` matrix, and a prefill of `t` positions is the `[t *
//! group, k]` stack of those step matrices. This is the crux of the
//! bitwise KV-cache contract: quantizer blocks are `(16, 2)`, so with
//! `group % 16 == 0` no block ever straddles two positions — quantizing
//! (and bit-packing) a position's rows gives the same bits whether the
//! position is processed alone (a decode step) or stacked with others (a
//! prefill / full recompute). The batch-major `[batch * seq, k]` layout
//! of [`super::interp::Interp::forward`] does *not* have this property
//! for block formats (blocks there mix positions of one sequence), which
//! is why the decode engine defines its own full-forward oracle,
//! [`Decoder::full_forward`], in the same position-major convention. For
//! element-wise formats (`fp32`, `int`, `fp8`) quantization is
//! per-element and every matmul output element is accumulated
//! identically, so the decode convention also matches the batch-major
//! forward bit for bit. All of this is machine-checked by the numpy
//! mirror (`scripts/verify_interp_math.py`, checks K1-K5) and by
//! `tests/decode_parity.rs`.
//!
//! Attention during decode is the single-query path: one
//! [`attn_query_row`] per (sequence, head) over the `pos + 1` cached
//! K/V rows — O(context) score dots per step instead of the full
//! O(context^2) recompute, counted (not timed) in [`DecodeStats`] so
//! tests and benches can assert the complexity claim deterministically.
//!
//! Cached K/V are the *pre-quantization* attention inputs (attention
//! internals are unquantized in the L2 model, and Q/K/V come out of the
//! same qkv matmul in both paths), so cache rows are bit-identical to
//! recomputed ones by construction; parity tests assert it end to end.
//!
//! [`generate_many`] fans independent groups over
//! [`crate::util::pool::par_map`] workers. Groups are data-independent
//! and results are returned in input order, so a fixed seed yields
//! bit-identical token streams at any thread count (property-tested in
//! `tests/properties.rs`).

use super::backend::{BatchScore, DecodeReport, ExecBackend};
use super::interp::{argmax, attn_query_row, bias_name_for, gelu, nll, CpuBackend, Interp, Tensor};
use crate::formats::FormatKind;
use crate::frontend::ModelMeta;
use crate::ir::{Graph, OpKind};
use crate::util::pool::par_map;
use anyhow::{anyhow, ensure, Result};
use std::time::Instant;

/// Counted attention work — the deterministic scoreboard for the O(1)
/// per-step claim (wall-clock is CI-noise; counters are exact).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DecodeStats {
    /// KV-cached decode steps executed.
    pub steps: u64,
    /// Score dot-products computed by single-query (cached) attention.
    pub decode_score_dots: u64,
    /// Score dot-products computed by full attention (prefill / oracle).
    pub full_score_dots: u64,
    /// Query rows materialized by full attention (prefill / oracle).
    pub full_attn_rows: u64,
}

impl DecodeStats {
    pub fn merge(&mut self, other: &DecodeStats) {
        self.steps += other.steps;
        self.decode_score_dots += other.decode_score_dots;
        self.full_score_dots += other.full_score_dots;
        self.full_attn_rows += other.full_attn_rows;
    }

    /// Fold these counted-work totals into a PR 8 trace registry as
    /// monotonic counters under `path`. Counted work, never wall-clock —
    /// folding at a single-threaded merge point keeps the event stream
    /// byte-identical at any thread count.
    pub fn record_to(&self, rec: &crate::obs::Registry, path: &str) {
        if !rec.is_enabled() {
            return;
        }
        rec.counter(path, "steps", self.steps);
        rec.counter(path, "decode_score_dots", self.decode_score_dots);
        rec.counter(path, "full_score_dots", self.full_score_dots);
        rec.counter(path, "full_attn_rows", self.full_attn_rows);
    }

    /// Exact closed form for the cached decode phase: the step at
    /// position `t` costs `group * heads * layers * (t + 1)` score dots.
    pub fn expected_decode_dots(
        group: usize,
        heads: usize,
        layers: usize,
        prefill: usize,
        n_tokens: usize,
    ) -> u64 {
        (prefill..prefill + n_tokens)
            .map(|t| (group * heads * layers * (t + 1)) as u64)
            .sum()
    }
}

/// One Linear site of the causal-LM graph, resolved at construction.
#[derive(Debug, Clone)]
struct LinSpec {
    wid: usize,
    name: String,
    act_q: Option<usize>,
}

#[derive(Debug, Clone)]
struct LayerSpec {
    ln1: String,
    ln2: String,
    qkv: LinSpec,
    proj: LinSpec,
    fc1: LinSpec,
    fc2: LinSpec,
}

/// Per-layer KV cache, position-major: row `(pos * group + bi) * d_model`
/// holds sequence `bi`'s key (resp. value) at position `pos`.
#[derive(Debug, Default)]
struct LayerKv {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// Output of one [`Decoder::generate`] call over one group.
#[derive(Debug, Clone)]
pub struct GenOut {
    /// Generated tokens, one `[group]` row per decode step.
    pub tokens: Vec<Vec<i32>>,
    /// Logits per position: `prompt_len + n_tokens` entries of
    /// `[group * vocab]`.
    pub step_logits: Vec<Vec<f32>>,
    /// Teacher-forced score of the realized (prompt + generated)
    /// sequences, accumulated exactly like `Interp::eval_batch`.
    pub score: BatchScore,
    pub prefill_seconds: f64,
    pub decode_seconds: f64,
}

/// Incremental causal-LM engine: an [`Interp`] (same packed weights,
/// same quantizers) plus a per-layer KV cache and the step loop.
pub struct Decoder<'a> {
    interp: Interp<'a>,
    meta: &'a ModelMeta,
    /// Sequences run in lockstep (block formats need `group % 16 == 0`).
    group: usize,
    layers: Vec<LayerSpec>,
    head: LinSpec,
    cache: Vec<LayerKv>,
    /// Positions currently cached (the next step decodes position `len`).
    len: usize,
    /// Per-slot context start (absolute cached position). Slot `bi`
    /// attends `starts[bi]..=pos` and embeds at the *logical* position
    /// `pos - starts[bi]` — all zero for a fresh decoder, advanced by
    /// [`Decoder::evict`] so a slot can be reused for a new sequence
    /// without clearing the whole group's cache (PR 9 serving).
    starts: Vec<usize>,
    pub stats: DecodeStats,
}

impl<'a> Decoder<'a> {
    pub fn new(
        backend: &CpuBackend,
        graph: &'a Graph,
        meta: &'a ModelMeta,
        weights: &'a [f32],
        fmt_tag: &str,
        qcfg: &'a [f32],
        group: usize,
    ) -> Result<Decoder<'a>> {
        ensure!(
            meta.kind == "lm",
            "decode needs a causal LM; model {} is a {}",
            meta.name,
            meta.kind
        );
        ensure!(group >= 1, "decode group must be non-empty");
        ensure!(
            meta.d_model % meta.n_heads == 0,
            "d_model {} not divisible by {} heads",
            meta.d_model,
            meta.n_heads
        );
        let fmt = FormatKind::from_name(fmt_tag)
            .ok_or_else(|| anyhow!("decode: unknown format tag '{fmt_tag}'"))?;
        let interp = Interp::new(meta, graph, weights, fmt, qcfg, backend)?;
        interp.check_tiling(group, meta.d_model, "decode group")?;
        let mut lins = Vec::new();
        for op in &graph.ops {
            if op.kind == OpKind::Linear {
                let wid = op.params[0];
                lins.push(LinSpec {
                    wid: wid.0,
                    name: graph.value(wid).name.clone(),
                    act_q: graph.value(op.args[0]).qtensor,
                });
            }
        }
        ensure!(
            lins.len() == 4 * meta.n_layers + 1,
            "decode: expected {} Linear ops in the graph, found {}",
            4 * meta.n_layers + 1,
            lins.len()
        );
        let head = lins.pop().unwrap();
        ensure!(head.name == "head_w", "decode: last Linear is '{}', not the LM head", head.name);
        let mut layers = Vec::with_capacity(meta.n_layers);
        for (l, chunk) in lins.chunks(4).enumerate() {
            let expect = [
                format!("layer{l}.w_qkv"),
                format!("layer{l}.w_proj"),
                format!("layer{l}.w_fc1"),
                format!("layer{l}.w_fc2"),
            ];
            for (spec, want) in chunk.iter().zip(expect.iter()) {
                ensure!(
                    &spec.name == want,
                    "decode: Linear '{}' where '{want}' was expected",
                    spec.name
                );
            }
            layers.push(LayerSpec {
                ln1: format!("layer{l}.ln1"),
                ln2: format!("layer{l}.ln2"),
                qkv: chunk[0].clone(),
                proj: chunk[1].clone(),
                fc1: chunk[2].clone(),
                fc2: chunk[3].clone(),
            });
        }
        let cap = meta.seq_len * group * meta.d_model;
        let cache = (0..meta.n_layers)
            .map(|_| LayerKv { k: Vec::with_capacity(cap), v: Vec::with_capacity(cap) })
            .collect();
        Ok(Decoder {
            interp,
            meta,
            group,
            layers,
            head,
            cache,
            len: 0,
            starts: vec![0; group],
            stats: DecodeStats::default(),
        })
    }

    /// Positions currently held in the KV cache (absolute — reduced by
    /// [`Decoder::compact`], not by [`Decoder::evict`]).
    pub fn positions(&self) -> usize {
        self.len
    }

    /// Per-slot context starts (absolute cached positions).
    pub fn context_starts(&self) -> &[usize] {
        &self.starts
    }

    /// Retire slot `slot`'s sequence: zero its cached K/V rows (hygiene —
    /// they are never read again, but stale bits should not survive in
    /// memory) and advance its context start to the present, so the next
    /// token fed on this slot begins a fresh sequence at logical position
    /// 0. Other slots are untouched: attention reads only the queried
    /// slot's rows, quantization acts on step matrices (never the cache),
    /// so eviction cannot perturb in-flight sequences bitwise.
    pub fn evict(&mut self, slot: usize) -> Result<()> {
        ensure!(slot < self.group, "evict: slot {slot} outside group {}", self.group);
        let (b, d) = (self.group, self.meta.d_model);
        for kv in &mut self.cache {
            for pos in self.starts[slot]..self.len {
                let lo = (pos * b + slot) * d;
                kv.k[lo..lo + d].fill(0.0);
                kv.v[lo..lo + d].fill(0.0);
            }
        }
        self.starts[slot] = self.len;
        Ok(())
    }

    /// Rewind the whole group to `pos` cached positions, discarding the
    /// tail. Context starts past `pos` are clamped, so an evicted-at-the-
    /// tip slot stays evicted. Re-feeding the same tokens after a
    /// truncate reproduces the discarded logits bitwise (the cache holds
    /// pre-quantization rows; steps depend only on the retained prefix).
    pub fn truncate(&mut self, pos: usize) -> Result<()> {
        ensure!(pos <= self.len, "truncate to {pos} but only {} positions cached", self.len);
        let rows = pos * self.group * self.meta.d_model;
        for kv in &mut self.cache {
            kv.k.truncate(rows);
            kv.v.truncate(rows);
        }
        self.len = pos;
        for s in &mut self.starts {
            *s = (*s).min(pos);
        }
        Ok(())
    }

    /// Drop cached positions no slot can still attend (those before
    /// `min(starts)`), shifting the cache down. Bit-invariant: attention
    /// indexes rows relative to each slot's start, and logical positions
    /// are start-relative already. This is what bounds cache memory (and
    /// the absolute position index) on a long-running server: with every
    /// slot periodically evicted, `len` never exceeds the longest live
    /// context. Returns the number of positions dropped.
    pub fn compact(&mut self) -> usize {
        let base = self.starts.iter().copied().min().unwrap_or(0).min(self.len);
        if base == 0 {
            return 0;
        }
        let rows = base * self.group * self.meta.d_model;
        for kv in &mut self.cache {
            kv.k.drain(..rows);
            kv.v.drain(..rows);
        }
        self.len -= base;
        for s in &mut self.starts {
            *s -= base;
        }
        base
    }

    /// One Linear site through the shared quantized-matmul path
    /// (activation quantized on its `[rows, k]` shape — a step or a
    /// position-major stack, bit-compatible per the module docs).
    fn linear(&self, spec: &LinSpec, act: &Tensor) -> Result<Tensor> {
        let bias = self.interp.param(&bias_name_for(&spec.name)).ok().map(|(bv, _)| bv);
        let y = self.interp.qmm(act, spec.act_q, spec.wid, &spec.name, bias, None)?;
        let (rows, _) = act.as_2d();
        let (_, w_shape) = self.interp.param(&spec.name)?;
        Ok(Tensor::new(y, vec![rows, w_shape[1]]))
    }

    /// Run one token per sequence through the layer stack, appending
    /// this position's K/V to the cache. Returns `[group * vocab]`
    /// logits for the decoded position. Each slot `bi` embeds at its
    /// *logical* position `pos - starts[bi]` and attends only its own
    /// context window `starts[bi]..=pos` — identical to the pre-eviction
    /// behavior when all starts are zero.
    pub fn decode_step(&mut self, toks: &[i32]) -> Result<Vec<f32>> {
        let (b, d) = (self.group, self.meta.d_model);
        let heads = self.meta.n_heads;
        let dh = d / heads;
        ensure!(toks.len() == b, "decode step expects {b} tokens (one per sequence), got {}", toks.len());
        let pos = self.len;
        let min_start = self.starts.iter().copied().min().unwrap_or(0);
        ensure!(
            pos - min_start < self.meta.seq_len,
            "KV cache is full: model {} supports seq_len {}",
            self.meta.name,
            self.meta.seq_len
        );
        let scale = (dh as f32).sqrt();
        let uniform = self.starts.iter().all(|&s| s == min_start);
        let xdata = if uniform {
            // fast path (fresh decoders, lockstep groups): one call, one
            // shared logical position — bitwise what the per-slot path
            // computes, since embed_rows is per-row.
            self.interp.embed_rows(toks, pos - min_start)?
        } else {
            let mut xd = Vec::with_capacity(b * d);
            for (bi, tok) in toks.iter().enumerate() {
                xd.extend_from_slice(
                    &self.interp.embed_rows(std::slice::from_ref(tok), pos - self.starts[bi])?,
                );
            }
            xd
        };
        let mut x = Tensor::new(xdata, vec![b, d]);
        for l in 0..self.layers.len() {
            let h = self.interp.layer_norm(&x, &self.layers[l].ln1)?;
            let qkv = self.linear(&self.layers[l].qkv, &h)?; // [b, 3d]
            {
                let kv = &mut self.cache[l];
                for bi in 0..b {
                    let base = bi * 3 * d;
                    kv.k.extend_from_slice(&qkv.data[base + d..base + 2 * d]);
                    kv.v.extend_from_slice(&qkv.data[base + 2 * d..base + 3 * d]);
                }
            }
            let mut attn_out = vec![0.0f32; b * d];
            let mut att = vec![0.0f32; pos + 1 - min_start];
            let kv = &self.cache[l];
            let mut dots = 0u64;
            for bi in 0..b {
                let st = self.starts[bi];
                let n_ctx = pos + 1 - st;
                for hd in 0..heads {
                    let off = hd * dh;
                    let q_lo = bi * 3 * d + off;
                    let o_lo = bi * d + off;
                    attn_query_row(
                        &qkv.data[q_lo..q_lo + dh],
                        scale,
                        n_ctx,
                        |sj| {
                            let lo = ((st + sj) * b + bi) * d + off;
                            &kv.k[lo..lo + dh]
                        },
                        |sj| {
                            let lo = ((st + sj) * b + bi) * d + off;
                            &kv.v[lo..lo + dh]
                        },
                        &mut att[..n_ctx],
                        &mut attn_out[o_lo..o_lo + dh],
                    );
                    dots += n_ctx as u64;
                }
            }
            self.stats.decode_score_dots += dots;
            let proj = self.linear(&self.layers[l].proj, &Tensor::new(attn_out, vec![b, d]))?;
            let res1 = Tensor::new(
                x.data.iter().zip(proj.data.iter()).map(|(a, c)| a + c).collect(),
                vec![b, d],
            );
            let h2 = self.interp.layer_norm(&res1, &self.layers[l].ln2)?;
            let fc1 = self.linear(&self.layers[l].fc1, &h2)?;
            let g = Tensor::new(fc1.data.iter().map(|&v| gelu(v)).collect(), fc1.shape.clone());
            let fc2 = self.linear(&self.layers[l].fc2, &g)?;
            x = Tensor::new(
                res1.data.iter().zip(fc2.data.iter()).map(|(a, c)| a + c).collect(),
                vec![b, d],
            );
        }
        let hf = self.interp.layer_norm(&x, "lnf")?;
        let logits = self.linear(&self.head, &hf)?;
        self.len = pos + 1;
        self.stats.steps += 1;
        Ok(logits.data)
    }

    /// Full position-major forward over `t` positions. Token `(bi, si)`
    /// is read at `tokens[bi * stride + si]`. With `fill_cache` the KV
    /// cache is reset and filled (prefill); without, state is untouched
    /// (the stateless recompute oracle). Returns per-position
    /// `[group * vocab]` logits.
    fn forward_block(
        &mut self,
        tokens: &[i32],
        stride: usize,
        t: usize,
        fill_cache: bool,
    ) -> Result<Vec<Vec<f32>>> {
        let (b, d) = (self.group, self.meta.d_model);
        let heads = self.meta.n_heads;
        let dh = d / heads;
        ensure!(
            t >= 1 && t <= self.meta.seq_len,
            "forward block of {t} positions outside 1..={}",
            self.meta.seq_len
        );
        ensure!(
            stride >= t && tokens.len() >= (b - 1) * stride + t,
            "token buffer does not cover [group {b}, {t}] at stride {stride}"
        );
        let scale = (dh as f32).sqrt();
        if fill_cache {
            for kv in &mut self.cache {
                kv.k.clear();
                kv.v.clear();
            }
            self.len = 0;
            self.starts.fill(0);
        }
        let mut xdata = Vec::with_capacity(t * b * d);
        let mut col = vec![0i32; b];
        for si in 0..t {
            for (bi, c) in col.iter_mut().enumerate() {
                *c = tokens[bi * stride + si];
            }
            xdata.extend_from_slice(&self.interp.embed_rows(&col, si)?);
        }
        let mut x = Tensor::new(xdata, vec![t * b, d]);
        for l in 0..self.layers.len() {
            let h = self.interp.layer_norm(&x, &self.layers[l].ln1)?;
            let qkv = self.linear(&self.layers[l].qkv, &h)?; // [t*b, 3d]
            if fill_cache {
                let kv = &mut self.cache[l];
                for r in 0..t * b {
                    let base = r * 3 * d;
                    kv.k.extend_from_slice(&qkv.data[base + d..base + 2 * d]);
                    kv.v.extend_from_slice(&qkv.data[base + 2 * d..base + 3 * d]);
                }
            }
            let mut attn_out = vec![0.0f32; t * b * d];
            let mut att = vec![0.0f32; t];
            let mut dots = 0u64;
            for bi in 0..b {
                for hd in 0..heads {
                    let off = hd * dh;
                    for si in 0..t {
                        let n_ctx = si + 1; // decode graphs are causal
                        let q_lo = (si * b + bi) * 3 * d + off;
                        let o_lo = (si * b + bi) * d + off;
                        attn_query_row(
                            &qkv.data[q_lo..q_lo + dh],
                            scale,
                            n_ctx,
                            |sj| {
                                let lo = (sj * b + bi) * 3 * d + d + off;
                                &qkv.data[lo..lo + dh]
                            },
                            |sj| {
                                let lo = (sj * b + bi) * 3 * d + 2 * d + off;
                                &qkv.data[lo..lo + dh]
                            },
                            &mut att,
                            &mut attn_out[o_lo..o_lo + dh],
                        );
                        dots += n_ctx as u64;
                    }
                }
            }
            self.stats.full_score_dots += dots;
            self.stats.full_attn_rows += (b * heads * t) as u64;
            let proj =
                self.linear(&self.layers[l].proj, &Tensor::new(attn_out, vec![t * b, d]))?;
            let res1 = Tensor::new(
                x.data.iter().zip(proj.data.iter()).map(|(a, c)| a + c).collect(),
                vec![t * b, d],
            );
            let h2 = self.interp.layer_norm(&res1, &self.layers[l].ln2)?;
            let fc1 = self.linear(&self.layers[l].fc1, &h2)?;
            let g = Tensor::new(fc1.data.iter().map(|&v| gelu(v)).collect(), fc1.shape.clone());
            let fc2 = self.linear(&self.layers[l].fc2, &g)?;
            x = Tensor::new(
                res1.data.iter().zip(fc2.data.iter()).map(|(a, c)| a + c).collect(),
                vec![t * b, d],
            );
        }
        if fill_cache {
            self.len = t;
        }
        let hf = self.interp.layer_norm(&x, "lnf")?;
        let logits = self.linear(&self.head, &hf)?; // [t*b, vocab]
        let v = self.meta.vocab;
        Ok((0..t).map(|si| logits.data[si * b * v..(si + 1) * b * v].to_vec()).collect())
    }

    /// Reset the cache and run the prompt (`[group, prompt_len]`,
    /// batch-major) through the full forward, caching every position's
    /// K/V. Returns per-position logits.
    pub fn prefill(&mut self, prompt: &[i32], prompt_len: usize) -> Result<Vec<Vec<f32>>> {
        self.forward_block(prompt, prompt_len, prompt_len, true)
    }

    /// The stateless recompute oracle: a full position-major forward over
    /// `t` positions (token `(bi, si)` at `tokens[bi * stride + si]`)
    /// that leaves the KV cache and step counter untouched. The parity
    /// suite compares every decode step against this at the same prefix.
    pub fn full_forward(&mut self, tokens: &[i32], stride: usize, t: usize) -> Result<Vec<Vec<f32>>> {
        self.forward_block(tokens, stride, t, false)
    }

    /// Greedy generation: prefill the prompt, then `n_tokens` argmax
    /// decode steps. The prompt is `[group, prompt_len]`, batch-major.
    pub fn generate(&mut self, prompt: &[i32], prompt_len: usize, n_tokens: usize) -> Result<GenOut> {
        let (b, v) = (self.group, self.meta.vocab);
        ensure!(prompt_len >= 1, "generate needs a prompt of at least one token");
        ensure!(
            prompt_len + n_tokens <= self.meta.seq_len,
            "prompt {prompt_len} + {n_tokens} new tokens exceeds model seq_len {}",
            self.meta.seq_len
        );
        ensure!(prompt.len() == b * prompt_len, "prompt is not [group {b}, {prompt_len}]");
        let t0 = Instant::now();
        let mut step_logits = self.prefill(prompt, prompt_len)?;
        let prefill_seconds = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let mut cur: Vec<i32> = (0..b)
            .map(|bi| argmax(&step_logits[prompt_len - 1][bi * v..(bi + 1) * v]) as i32)
            .collect();
        let mut tokens: Vec<Vec<i32>> = Vec::with_capacity(n_tokens);
        for _ in 0..n_tokens {
            tokens.push(cur.clone());
            let lg = self.decode_step(&cur)?;
            cur = (0..b).map(|bi| argmax(&lg[bi * v..(bi + 1) * v]) as i32).collect();
            step_logits.push(lg);
        }
        let decode_seconds = t1.elapsed().as_secs_f64();
        // realized [group, prompt + generated] token matrix, batch-major
        let total = prompt_len + n_tokens;
        let mut realized = vec![0i32; b * total];
        for bi in 0..b {
            realized[bi * total..bi * total + prompt_len]
                .copy_from_slice(&prompt[bi * prompt_len..(bi + 1) * prompt_len]);
            for (st, tk) in tokens.iter().enumerate() {
                realized[bi * total + prompt_len + st] = tk[bi];
            }
        }
        let score = score_from_steps(&step_logits, &realized, b, total, v);
        Ok(GenOut { tokens, step_logits, score, prefill_seconds, decode_seconds })
    }

    /// Teacher-forced pass over known tokens (`[group, s]`, batch-major):
    /// prefill the first `prefill_len` positions, then feed the remaining
    /// tokens one decode step at a time. Returns per-position logits and
    /// the score — for element-wise formats, bitwise what
    /// `Interp::eval_batch` computes on the same tokens.
    pub fn teacher_forced(
        &mut self,
        tokens: &[i32],
        s: usize,
        prefill_len: usize,
    ) -> Result<(Vec<Vec<f32>>, BatchScore)> {
        let (b, v) = (self.group, self.meta.vocab);
        ensure!(tokens.len() == b * s, "tokens are not [group {b}, {s}]");
        ensure!((1..=s).contains(&prefill_len), "prefill_len {prefill_len} outside 1..={s}");
        ensure!(s <= self.meta.seq_len, "{s} positions exceed model seq_len {}", self.meta.seq_len);
        let mut step_logits = self.forward_block(tokens, s, prefill_len, true)?;
        let mut col = vec![0i32; b];
        for si in prefill_len..s {
            for (bi, c) in col.iter_mut().enumerate() {
                *c = tokens[bi * s + si];
            }
            step_logits.push(self.decode_step(&col)?);
        }
        let score = score_from_steps(&step_logits, tokens, b, s, v);
        Ok((step_logits, score))
    }
}

/// Next-token NLL + argmax accuracy from per-position logits — the same
/// bi-outer / si-inner f64 accumulation as `Interp::eval_batch`, so the
/// two are bitwise-comparable. `tokens` is `[group, s]` batch-major;
/// `step_logits[si]` is `[group * vocab]`.
pub fn score_from_steps(
    step_logits: &[Vec<f32>],
    tokens: &[i32],
    group: usize,
    s: usize,
    vocab: usize,
) -> BatchScore {
    if s < 2 {
        return BatchScore { loss: 0.0, correct: 0 };
    }
    let mut nll_sum = 0.0f64;
    let mut correct = 0i32;
    for bi in 0..group {
        for si in 0..s - 1 {
            let lg = &step_logits[si][bi * vocab..(bi + 1) * vocab];
            let tgt = tokens[bi * s + si + 1] as usize;
            nll_sum += nll(lg, tgt);
            if argmax(lg) == tgt {
                correct += 1;
            }
        }
    }
    BatchScore { loss: (nll_sum / (group * (s - 1)) as f64) as f32, correct }
}

/// Generate over many sequences: `prompts` is `[n_seqs, prompt_len]`
/// (sequence-major), split into groups of `min(meta.batch, n_seqs)`
/// sequences and fanned over [`par_map`] workers. Groups are
/// data-independent and results come back in input order, so the output
/// is bit-identical at any `threads` value.
#[allow(clippy::too_many_arguments)]
pub fn generate_many(
    backend: &CpuBackend,
    graph: &Graph,
    meta: &ModelMeta,
    weights: &[f32],
    fmt_tag: &str,
    qcfg: &[f32],
    prompts: &[i32],
    n_seqs: usize,
    prompt_len: usize,
    n_tokens: usize,
    threads: usize,
) -> Result<(Vec<GenOut>, DecodeStats)> {
    generate_many_traced(
        backend,
        graph,
        meta,
        weights,
        fmt_tag,
        qcfg,
        prompts,
        n_seqs,
        prompt_len,
        n_tokens,
        threads,
        crate::obs::Registry::none(),
    )
}

/// [`generate_many`] with a PR 8 trace registry attached: after the
/// ordered [`par_map`] merge, each group's counted-work stats are
/// recorded as one `decode/group` span (tagged with the group index)
/// plus monotonic counters — **in input order**, on the calling thread,
/// so a fixed seed yields a byte-identical event stream at any
/// `threads` value (asserted by `tests/trace_determinism.rs`).
pub fn generate_many_traced(
    backend: &CpuBackend,
    graph: &Graph,
    meta: &ModelMeta,
    weights: &[f32],
    fmt_tag: &str,
    qcfg: &[f32],
    prompts: &[i32],
    n_seqs: usize,
    prompt_len: usize,
    n_tokens: usize,
    threads: usize,
    rec: &crate::obs::Registry,
) -> Result<(Vec<GenOut>, DecodeStats)> {
    let group = meta.batch.min(n_seqs).max(1);
    ensure!(
        n_seqs > 0 && n_seqs % group == 0,
        "n_seqs {n_seqs} must be a positive multiple of the group size {group}"
    );
    ensure!(prompts.len() == n_seqs * prompt_len, "prompts are not [n_seqs, prompt_len]");
    let idx: Vec<usize> = (0..n_seqs / group).collect();
    let results = par_map(idx, threads, |gi| -> Result<(GenOut, DecodeStats)> {
        let mut dec = Decoder::new(backend, graph, meta, weights, fmt_tag, qcfg, group)?;
        let lo = gi * group * prompt_len;
        let out = dec.generate(&prompts[lo..lo + group * prompt_len], prompt_len, n_tokens)?;
        Ok((out, dec.stats))
    });
    let mut outs = Vec::with_capacity(results.len());
    let mut stats = DecodeStats::default();
    for (gi, r) in results.into_iter().enumerate() {
        let (o, s) = r?;
        if rec.is_enabled() {
            let span = rec.span("decode/group").tag("group", gi.to_string());
            drop(span);
            s.record_to(rec, "decode/group");
        }
        stats.merge(&s);
        outs.push(o);
    }
    Ok((outs, stats))
}

/// [`ExecBackend::profile_decode`] body for the CPU backend: build the
/// graph, generate over every sequence, aggregate one [`DecodeReport`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn profile_decode_cpu(
    backend: &CpuBackend,
    meta: &ModelMeta,
    weights: &[f32],
    fmt_tag: &str,
    qcfg: &[f32],
    prompts: &[i32],
    n_seqs: usize,
    prompt_len: usize,
    n_tokens: usize,
    threads: usize,
) -> Result<DecodeReport> {
    let graph = backend.prepare(meta, weights, &[])?;
    let (outs, stats) = generate_many(
        backend, &graph, meta, weights, fmt_tag, qcfg, prompts, n_seqs, prompt_len, n_tokens,
        threads,
    )?;
    let mut tokens = Vec::with_capacity(n_seqs * n_tokens);
    let mut loss = 0.0f64;
    let mut correct = 0i32;
    let (mut prefill_seconds, mut decode_seconds) = (0.0f64, 0.0f64);
    for o in &outs {
        let group = o.tokens.first().map_or(0, |t| t.len());
        for bi in 0..group {
            for st in &o.tokens {
                tokens.push(st[bi]);
            }
        }
        loss += o.score.loss as f64;
        correct += o.score.correct;
        prefill_seconds += o.prefill_seconds;
        decode_seconds += o.decode_seconds;
    }
    Ok(DecodeReport {
        tokens,
        loss: (loss / outs.len().max(1) as f64) as f32,
        correct,
        prefill_seconds,
        decode_seconds,
        stats,
        n_seqs,
        prompt_len,
        n_tokens,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::init_params;

    fn tiny_lm() -> ModelMeta {
        ModelMeta::synthetic("tiny-lm", 1, 32, 2, 512, 16, 4, "lm", 16)
    }

    #[test]
    fn expected_decode_dots_closed_form() {
        // prefill 3 + 2 new tokens: positions 3 and 4 cost 4 resp. 5
        // score dots per (sequence, head, layer).
        assert_eq!(DecodeStats::expected_decode_dots(2, 3, 1, 3, 2), 2 * 3 * (4 + 5));
        assert_eq!(DecodeStats::expected_decode_dots(1, 1, 2, 0, 1), 2);
    }

    #[test]
    fn merge_accumulates_all_counters() {
        let mut a =
            DecodeStats { steps: 1, decode_score_dots: 2, full_score_dots: 3, full_attn_rows: 4 };
        a.merge(&DecodeStats {
            steps: 10,
            decode_score_dots: 20,
            full_score_dots: 30,
            full_attn_rows: 40,
        });
        assert_eq!(
            a,
            DecodeStats {
                steps: 11,
                decode_score_dots: 22,
                full_score_dots: 33,
                full_attn_rows: 44
            }
        );
    }

    #[test]
    fn generate_produces_finite_logits_and_counts_decode_work() {
        let meta = tiny_lm();
        let w = init_params(&meta, 0xC0DE);
        let be = CpuBackend::new();
        let graph = be.prepare(&meta, &w, &[]).unwrap();
        let qcfg = vec![0.0f32; 2 * meta.num_qtensors()];
        let prompt: Vec<i32> = (0..16 * 4).map(|i| (i % 512) as i32).collect();
        let mut dec = Decoder::new(&be, &graph, &meta, &w, "fp32", &qcfg, 16).unwrap();
        let out = dec.generate(&prompt, 4, 3).unwrap();
        assert_eq!(out.tokens.len(), 3);
        assert_eq!(out.step_logits.len(), 4 + 3);
        assert!(out.step_logits.iter().flatten().all(|v| v.is_finite()));
        assert_eq!(dec.positions(), 7);
        assert_eq!(dec.stats.steps, 3);
        assert_eq!(
            dec.stats.decode_score_dots,
            DecodeStats::expected_decode_dots(16, meta.n_heads, meta.n_layers, 4, 3)
        );
    }

    #[test]
    fn generate_many_traced_records_groups_in_input_order() {
        let meta = tiny_lm();
        let w = init_params(&meta, 0xC0DE);
        let be = CpuBackend::new();
        let graph = be.prepare(&meta, &w, &[]).unwrap();
        let qcfg = vec![0.0f32; 2 * meta.num_qtensors()];
        let n_seqs = 2 * meta.batch; // two groups
        let prompts: Vec<i32> = (0..n_seqs * 4).map(|i| (i % 512) as i32).collect();
        let reg = crate::obs::Registry::new();
        let (outs, stats) = generate_many_traced(
            &be, &graph, &meta, &w, "fp32", &qcfg, &prompts, n_seqs, 4, 2, 2, &reg,
        )
        .unwrap();
        assert_eq!(outs.len(), 2);
        let spans: Vec<_> = reg
            .sorted_events()
            .into_iter()
            .filter(|e| matches!(e.kind, crate::obs::EventKind::Span { .. }))
            .collect();
        assert_eq!(spans.len(), 2, "one decode/group span per group");
        for (i, e) in spans.iter().enumerate() {
            assert_eq!(e.path, "decode/group");
            match &e.kind {
                crate::obs::EventKind::Span { tags } => {
                    assert_eq!(tags[0], ("group".to_string(), i.to_string()));
                }
                _ => unreachable!(),
            }
        }
        // counter totals reconcile with the merged aggregate
        assert_eq!(reg.counter_total("decode/group", "steps"), stats.steps);
        assert_eq!(
            reg.counter_total("decode/group", "decode_score_dots"),
            stats.decode_score_dots
        );
        assert_eq!(reg.counter_total("decode/group", "full_score_dots"), stats.full_score_dots);
        assert_eq!(reg.counter_total("decode/group", "full_attn_rows"), stats.full_attn_rows);
    }

    fn ctx(meta: &ModelMeta) -> (Vec<f32>, CpuBackend) {
        (init_params(meta, 0xC0DE), CpuBackend::new())
    }

    fn qcfg_bits(meta: &ModelMeta, bits: f32) -> Vec<f32> {
        let mut q = vec![0.0f32; 2 * meta.num_qtensors()];
        for i in 0..meta.num_qtensors() {
            q[2 * i] = bits;
        }
        q
    }

    fn bits_of(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn evicted_slot_reused_matches_fresh_decoder_bitwise() {
        // The PR 9 no-stale-leakage regression: after `evict(slot)`, a
        // new sequence on that slot must produce logits bit-identical to
        // a decoder that never saw the old one — while the neighbouring
        // slot's sequence keeps running.
        let meta = tiny_lm();
        let (w, be) = ctx(&meta);
        let graph = be.prepare(&meta, &w, &[]).unwrap();
        let qcfg = qcfg_bits(&meta, 32.0);
        let v = meta.vocab;
        let xs = [5i32, 9, 13, 2, 7, 11, 3, 40];
        let ys = [101i32, 42, 33];
        let mut dec = Decoder::new(&be, &graph, &meta, &w, "fp32", &qcfg, 2).unwrap();
        for &t in &xs[..5] {
            dec.decode_step(&[t, t]).unwrap();
        }
        dec.evict(1).unwrap();
        assert_eq!(dec.context_starts(), &[0, 5]);
        let mut reused = Vec::new();
        for (i, &t) in ys.iter().enumerate() {
            // slot 0 continues its sequence, slot 1 starts over on Y
            let lg = dec.decode_step(&[xs[5 + i], t]).unwrap();
            reused.push(lg[v..2 * v].to_vec());
        }
        let mut fresh = Decoder::new(&be, &graph, &meta, &w, "fp32", &qcfg, 2).unwrap();
        for (i, &t) in ys.iter().enumerate() {
            let lg = fresh.decode_step(&[t, t]).unwrap();
            assert_eq!(bits_of(&reused[i]), bits_of(&lg[v..2 * v]), "step {i} leaked stale cache");
        }
        // negative control: WITHOUT evict the old context bleeds in
        let mut stale = Decoder::new(&be, &graph, &meta, &w, "fp32", &qcfg, 2).unwrap();
        for &t in &xs[..5] {
            stale.decode_step(&[t, t]).unwrap();
        }
        let lg = stale.decode_step(&[xs[5], ys[0]]).unwrap();
        assert_ne!(
            bits_of(&reused[0]),
            bits_of(&lg[v..2 * v]),
            "stale cache should perturb the logits (else this test checks nothing)"
        );
    }

    #[test]
    fn evict_whole_group_block_format_matches_fresh() {
        // Block formats run whole 16-row groups in lockstep; evicting all
        // slots and reusing the group must be bitwise a fresh decoder.
        let meta = tiny_lm();
        let (w, be) = ctx(&meta);
        let graph = be.prepare(&meta, &w, &[]).unwrap();
        let qcfg = qcfg_bits(&meta, 7.0);
        let mut dec = Decoder::new(&be, &graph, &meta, &w, "mxint", &qcfg, 16).unwrap();
        for &t in &[3i32, 77, 8] {
            dec.decode_step(&[t; 16]).unwrap();
        }
        for bi in 0..16 {
            dec.evict(bi).unwrap();
        }
        let mut fresh = Decoder::new(&be, &graph, &meta, &w, "mxint", &qcfg, 16).unwrap();
        for &t in &[200i32, 14, 360, 9] {
            let a = dec.decode_step(&[t; 16]).unwrap();
            let b = fresh.decode_step(&[t; 16]).unwrap();
            assert_eq!(bits_of(&a), bits_of(&b));
        }
    }

    #[test]
    fn truncate_rewind_and_refeed_is_bitwise() {
        let meta = tiny_lm();
        let (w, be) = ctx(&meta);
        let graph = be.prepare(&meta, &w, &[]).unwrap();
        let qcfg = qcfg_bits(&meta, 32.0);
        let toks = [5i32, 9, 13, 2, 7, 11];
        let mut dec = Decoder::new(&be, &graph, &meta, &w, "fp32", &qcfg, 2).unwrap();
        let mut logits = Vec::new();
        for &t in &toks {
            logits.push(dec.decode_step(&[t, t]).unwrap());
        }
        dec.truncate(3).unwrap();
        assert_eq!(dec.positions(), 3);
        for (i, &t) in toks[3..].iter().enumerate() {
            let lg = dec.decode_step(&[t, t]).unwrap();
            assert_eq!(bits_of(&lg), bits_of(&logits[3 + i]), "re-fed step {i}");
        }
    }

    #[test]
    fn compact_drops_dead_prefix_bitwise() {
        let meta = tiny_lm();
        let (w, be) = ctx(&meta);
        let graph = be.prepare(&meta, &w, &[]).unwrap();
        let qcfg = qcfg_bits(&meta, 32.0);
        let mut a = Decoder::new(&be, &graph, &meta, &w, "fp32", &qcfg, 2).unwrap();
        let mut b = Decoder::new(&be, &graph, &meta, &w, "fp32", &qcfg, 2).unwrap();
        for &t in &[5i32, 9, 13, 2] {
            a.decode_step(&[t, t]).unwrap();
            b.decode_step(&[t, t]).unwrap();
        }
        for bi in 0..2 {
            a.evict(bi).unwrap();
            b.evict(bi).unwrap();
        }
        assert_eq!(b.compact(), 4);
        assert_eq!(b.positions(), 0);
        assert_eq!(a.positions(), 4);
        for &t in &[60i32, 7, 300] {
            let la = a.decode_step(&[t, t]).unwrap();
            let lb = b.decode_step(&[t, t]).unwrap();
            assert_eq!(bits_of(&la), bits_of(&lb));
        }
    }

    #[test]
    fn decoder_rejects_classifier_graphs() {
        let meta = ModelMeta::synthetic("t", 1, 32, 2, 512, 16, 4, "classifier", 16);
        let w = init_params(&meta, 1);
        let be = CpuBackend::new();
        let graph = be.prepare(&meta, &w, &[]).unwrap();
        let qcfg = vec![0.0f32; 2 * meta.num_qtensors()];
        assert!(Decoder::new(&be, &graph, &meta, &w, "fp32", &qcfg, 16).is_err());
    }
}
