//! `CpuBackend` — a pure-Rust MASE-IR interpreter for the evaluate pass:
//! packed inference without PJRT, artifacts, or Python.
//!
//! The interpreter walks the [`crate::frontend::build_graph`] transformer
//! graph op by op (Embed, LayerNorm, Linear, Attention, Gelu, Add,
//! Softmax, Reorder/Transpose, MeanPool), mirroring the L2 model
//! (`python/compile/model.py`) semantically: pre-LN transformer with the
//! injected outlier channels (pinned LN scales + depth-growing gains),
//! tanh-approximate GELU, mean-pooled classifier head / causal LM head,
//! and fake quantization of every searchable operand through the official
//! [`crate::formats`] quantizers.
//!
//! ## The two matmul paths
//!
//! Every Linear matmul runs in one of two modes ([`MatmulPath`]):
//!
//!  * **`Packed`** (the default): both operands are bit-packed with
//!    [`crate::packed::layout::pack`] (which quantizes onto the format
//!    grid and then encodes exactly) and the product is computed by
//!    [`crate::packed::kernels::packed_gemm`] on the integer datapath —
//!    real packed inference, the software mirror of the paper's §4
//!    hardware dot product. The Embed lookup reads its rows from a
//!    bit-packed (raw-bits fp32) table — the degenerate one-hot matmul.
//!  * **`Reference`**: fake-quantize with [`crate::formats::quantize_2d`]
//!    and multiply with [`crate::packed::kernels::gemm_f64_segmented`],
//!    the float half of PR 3's golden kernel pair.
//!
//! Per that kernel contract, the two paths agree **bitwise** for MXInt
//! and fixed point (every logit, hence loss and accuracy, is identical),
//! and within the documented `n * 2^-50 * sum|a_i b_i|` per-output bound
//! for BMF/BL/FP8 — `tests/backend_parity.rs` asserts both.
//!
//! Activations are quantized on their `[rows, k]` matmul reshape; because
//! every model dimension (and `batch`/`seq_len`) is a multiple of the
//! (16, 2) block shape, the tiling is identical to the L2 emulation's
//! blocks-over-trailing-dims convention.
//!
//! Limitations (enforced with clean errors, see [`CpuBackend`]): no QAT
//! (the interpreter has no gradient path) and no pretraining — on hosts
//! without cached weights the flow evaluates the deterministic
//! `frontend::init_params` model.

use super::backend::{BackendKind, BatchScore, DecodeReport, ExecBackend};
use crate::data::Batch;
use crate::formats::{quantize_2d, FormatKind, FormatSpec, Precision, BLOCK_SHAPE};
use crate::frontend::{ModelMeta, OUTLIER_BASE_GAIN, OUTLIER_CHANNELS};
use crate::ir::{Graph, OpKind, ValueId};
use crate::packed::artifact::{source_hash, ArtifactWeights, ArtifactWriter, TensorDesc};
use crate::packed::kernels::{gemm_f64_segmented, note_weight_pack, packed_gemm};
use crate::packed::layout::{pack, ElemLayout, PackedTensor};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

/// How the interpreter multiplies quantized operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MatmulPath {
    /// Bit-packed operands through `packed::kernels::packed_gemm`.
    #[default]
    Packed,
    /// Fake-quantized f32 operands through `gemm_f64_segmented` (the
    /// golden float reference; used by the parity tests and `profile`).
    Reference,
}

/// The PJRT-free execution backend. Construct with [`CpuBackend::new`]
/// (packed datapath), [`CpuBackend::reference`] (float golden path), or
/// [`CpuBackend::with_artifact`] (packed datapath seeded from a `.mxa`
/// packed-weight container so warm sessions skip the quantize+pack work).
#[derive(Debug, Clone, Default)]
pub struct CpuBackend {
    pub path: MatmulPath,
    /// Pre-packed weights loaded from a `.mxa` artifact. Tensors whose
    /// name/layout/shape/source bits match the live model are reused as
    /// shared `Arc`s with zero re-quantize and zero re-pack; anything
    /// else falls back to `pack()` (bit-identical, since `pack` is
    /// deterministic — the artifact stores exactly its output).
    pub artifact: Option<Arc<ArtifactWeights>>,
}

impl CpuBackend {
    pub fn new() -> Self {
        Self { path: MatmulPath::Packed, artifact: None }
    }

    pub fn reference() -> Self {
        Self { path: MatmulPath::Reference, artifact: None }
    }

    /// Packed backend that serves weight tensors out of a loaded `.mxa`
    /// artifact (see [`crate::packed::artifact`]).
    pub fn with_artifact(artifact: Arc<ArtifactWeights>) -> Self {
        Self { path: MatmulPath::Packed, artifact: Some(artifact) }
    }
}

impl ExecBackend for CpuBackend {
    /// The IR is model-shaped, not trial-shaped: build it once per
    /// evaluator and walk it for every trial/batch.
    type Prepared = Graph;

    fn kind(&self) -> BackendKind {
        BackendKind::Cpu
    }

    fn prepare(&self, meta: &ModelMeta, weights: &[f32], _batches: &[Batch]) -> Result<Graph> {
        ensure!(
            weights.len() == meta.param_size,
            "cpu backend: weight vector has {} params, model {} expects {}",
            weights.len(),
            meta.name,
            meta.param_size
        );
        Ok(crate::frontend::build_graph(meta))
    }

    fn eval(
        &self,
        graph: &Graph,
        meta: &ModelMeta,
        batches: &[Batch],
        fmt_tag: &str,
        qcfg: &[f32],
        weights: &[f32],
    ) -> Result<Vec<BatchScore>> {
        let fmt = FormatKind::from_name(fmt_tag)
            .ok_or_else(|| anyhow!("cpu backend: unknown format tag '{fmt_tag}'"))?;
        let interp = Interp::new(meta, graph, weights, fmt, qcfg, self)?;
        batches.iter().map(|b| interp.eval_batch(b)).collect()
    }

    /// Content hash of the attached `.mxa` artifact, if any — folded into
    /// cache eval scopes so artifact-backed results never collide with
    /// in-memory-pack results from a different weight container.
    fn weights_hash(&self) -> Option<u64> {
        self.artifact.as_ref().map(|a| a.content_hash)
    }

    fn profile_batch(
        &self,
        meta: &ModelMeta,
        weights: &[f32],
        batch: &Batch,
    ) -> Result<Vec<[f32; 3]>> {
        // Profiling runs the unquantized model (fmt = fp32, zero qconfig)
        // and taps every searchable operand pre-quantization, exactly
        // like the L2 `profile_forward`. The float path is used: stats do
        // not depend on the matmul datapath, and it skips the packing.
        let graph = crate::frontend::build_graph(meta);
        let qcfg = vec![0.0f32; 2 * meta.num_qtensors()];
        let interp = Interp::new(meta, &graph, weights, FormatKind::Fp32, &qcfg, &CpuBackend::reference())?;
        let mut taps: Vec<Option<[f32; 3]>> = vec![None; meta.num_qtensors()];
        interp.forward(batch, Some(&mut taps[..]))?;
        taps.into_iter()
            .enumerate()
            .map(|(i, t)| t.ok_or_else(|| anyhow!("qtensor {i} never reached a matmul")))
            .collect()
    }

    fn qat_available(&self, _meta: &ModelMeta, _fmt: FormatKind) -> Result<()> {
        bail!("cpu backend has no gradient path: QAT needs --backend pjrt (or --qat-steps 0)")
    }

    fn profile_decode(
        &self,
        meta: &ModelMeta,
        weights: &[f32],
        fmt_tag: &str,
        qcfg: &[f32],
        prompts: &[i32],
        n_seqs: usize,
        prompt_len: usize,
        n_tokens: usize,
        threads: usize,
    ) -> Result<DecodeReport> {
        super::decode::profile_decode_cpu(
            self, meta, weights, fmt_tag, qcfg, prompts, n_seqs, prompt_len, n_tokens, threads,
        )
    }

    fn qat_tune(
        &self,
        meta: &ModelMeta,
        _weights: &[f32],
        _train: &[Batch],
        fmt: FormatKind,
        _qcfg: &[f32],
        _lr: f32,
    ) -> Result<Vec<f32>> {
        self.qat_available(meta, fmt).map(|_| Vec::new())
    }
}

/// A dense row-major f32 tensor (interpreter values).
#[derive(Debug, Clone)]
pub(crate) struct Tensor {
    pub(crate) data: Vec<f32>,
    pub(crate) shape: Vec<usize>,
}

impl Tensor {
    pub(crate) fn new(data: Vec<f32>, shape: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Self { data, shape }
    }

    /// (rows, cols) view for a matmul over the trailing dim.
    pub(crate) fn as_2d(&self) -> (usize, usize) {
        let cols = *self.shape.last().unwrap_or(&1);
        (self.data.len() / cols.max(1), cols)
    }
}

/// One model + one quantization configuration, ready to run batches.
/// Weight operands are quantized/packed once here and reused per batch.
/// `pub(crate)` so [`super::decode::Decoder`] can drive the same packed
/// weights / quantizers incrementally.
pub(crate) struct Interp<'a> {
    meta: &'a ModelMeta,
    graph: &'a Graph,
    weights: &'a [f32],
    fmt: FormatKind,
    qcfg: &'a [f32],
    path: MatmulPath,
    /// Packed weight per Linear weight value id (`Packed` path). Shared
    /// `Arc`s so artifact-loaded tensors are reused without copying.
    packed_w: HashMap<usize, Arc<PackedTensor>>,
    /// Fake-quantized weight per Linear weight value id (`Reference`).
    quant_w: HashMap<usize, Vec<f32>>,
    /// Bit-packed (raw fp32) embedding table for the Embed gather.
    packed_embed: Option<Arc<PackedTensor>>,
}

/// Look up `name` in the backend's artifact (if any) and return the
/// pre-packed tensor when it matches the live request exactly: same
/// packing layout, same shape, and the same source f32 bits. Anything
/// short of a full match returns `None` and the caller re-packs —
/// bit-identical, since the artifact stores `pack()`'s own output.
fn artifact_tensor(
    backend: &CpuBackend,
    name: &str,
    layout: &ElemLayout,
    rows: usize,
    cols: usize,
    source: &[f32],
) -> Option<Arc<PackedTensor>> {
    let art = backend.artifact.as_ref()?;
    let t = art.tensors.get(name)?;
    (t.packed.layout == *layout
        && t.packed.rows == rows
        && t.packed.cols == cols
        && t.desc.source_hash == source_hash(source))
    .then(|| Arc::clone(&t.packed))
}

/// Pack every weight tensor of `graph` exactly as the packed interpreter
/// does — same names, layouts and source f32 bits — and assemble them
/// into an [`ArtifactWriter`]. `mase pack --out model.mxa` and the
/// round-trip tests both build artifacts through this one path, so a
/// loaded artifact always satisfies [`artifact_tensor`]'s full-match
/// test on the warm run (zero re-quantize, zero re-pack).
///
/// `qcfg` must be the same flat per-qtensor `[bits, frac]` vector the
/// warm session will evaluate with (e.g. `QuantSolution::to_qconfig`);
/// `spec` is the uniform format recorded in the artifact header.
pub fn build_weights_artifact(
    meta: &ModelMeta,
    graph: &Graph,
    weights: &[f32],
    spec: FormatSpec,
    qcfg: &[f32],
) -> Result<ArtifactWriter> {
    let interp = Interp::new(meta, graph, weights, spec.kind, qcfg, &CpuBackend::new())?;
    let mut writer = ArtifactWriter::new(&meta.name, spec);
    let mut ids: Vec<usize> = interp.packed_w.keys().copied().collect();
    ids.sort_unstable();
    for id in ids {
        let t = &interp.packed_w[&id];
        let name = &graph.value(ValueId(id)).name;
        let (src, _) = interp.param(name)?;
        writer.add_tensor(TensorDesc::for_tensor(name, "weight", t, src), t)?;
    }
    if let Some(t) = &interp.packed_embed {
        let (src, _) = interp.param("embed")?;
        writer.add_tensor(TensorDesc::for_tensor("embed", "embed", t, src), t)?;
    }
    Ok(writer)
}

impl<'a> Interp<'a> {
    pub(crate) fn new(
        meta: &'a ModelMeta,
        graph: &'a Graph,
        weights: &'a [f32],
        fmt: FormatKind,
        qcfg: &'a [f32],
        backend: &CpuBackend,
    ) -> Result<Interp<'a>> {
        ensure!(
            qcfg.len() == 2 * meta.num_qtensors(),
            "cpu backend: qconfig has {} entries, expected {}",
            qcfg.len(),
            2 * meta.num_qtensors()
        );
        let path = backend.path;
        let mut interp = Interp {
            meta,
            graph,
            weights,
            fmt,
            qcfg,
            path,
            packed_w: HashMap::new(),
            quant_w: HashMap::new(),
            packed_embed: None,
        };
        for op in &graph.ops {
            match op.kind {
                OpKind::Linear => {
                    let wid = op.params[0];
                    let wv = graph.value(wid);
                    let (w, shape) = interp.param(&wv.name)?;
                    ensure!(shape.len() == 2, "linear weight {} is not 2-D", wv.name);
                    let (k, n) = (shape[0], shape[1]);
                    let prec = interp.precision_of(wv.qtensor)?;
                    interp.check_tiling(k, n, &wv.name)?;
                    match path {
                        MatmulPath::Packed => {
                            let layout = ElemLayout::new(fmt, prec);
                            let pw = match artifact_tensor(backend, &wv.name, &layout, k, n, w) {
                                Some(pw) => pw,
                                None => {
                                    note_weight_pack();
                                    Arc::new(pack(w, k, n, fmt, prec))
                                }
                            };
                            interp.packed_w.insert(wid.0, pw);
                        }
                        MatmulPath::Reference => {
                            note_weight_pack();
                            let mut q = w.to_vec();
                            quantize_2d(fmt, &mut q, k, n, prec);
                            interp.quant_w.insert(wid.0, q);
                        }
                    }
                }
                OpKind::Embed => {
                    // The embedding lookup is a one-hot matmul; it
                    // degenerates to a row gather from the bit-packed
                    // (raw-bits fp32, exact) table on both paths.
                    let (embed, shape) = interp.param("embed")?;
                    let layout = ElemLayout::new(FormatKind::Fp32, Precision::new(32.0, 0.0));
                    let table =
                        match artifact_tensor(backend, "embed", &layout, shape[0], shape[1], embed)
                        {
                            Some(t) => t,
                            None => {
                                note_weight_pack();
                                Arc::new(pack(
                                    embed,
                                    shape[0],
                                    shape[1],
                                    FormatKind::Fp32,
                                    Precision::new(32.0, 0.0),
                                ))
                            }
                        };
                    interp.packed_embed = Some(table);
                }
                _ => {}
            }
        }
        Ok(interp)
    }

    /// Flat-parameter slice + shape by `param_spec` name.
    pub(crate) fn param(&self, name: &str) -> Result<(&'a [f32], &'a [usize])> {
        let spec = self
            .meta
            .param_spec
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| anyhow!("model {} has no parameter '{name}'", self.meta.name))?;
        let n: usize = spec.shape.iter().product();
        Ok((&self.weights[spec.offset..spec.offset + n], &spec.shape))
    }

    fn precision_of(&self, qtensor: Option<usize>) -> Result<Precision> {
        let qi = qtensor.ok_or_else(|| anyhow!("operand is not quantization-searchable"))?;
        Ok(Precision::new(self.qcfg[2 * qi], self.qcfg[2 * qi + 1]))
    }

    /// Block formats need (16, 2)-tileable operands (same constraint the
    /// quantizers assert; every model-zoo shape satisfies it).
    pub(crate) fn check_tiling(&self, rows: usize, cols: usize, what: &str) -> Result<()> {
        let (br, bc) = BLOCK_SHAPE;
        ensure!(
            !self.fmt.is_block_format() || (rows % br == 0 && cols % bc == 0),
            "cpu backend: {what} [{rows}, {cols}] does not tile into ({br}, {bc}) blocks \
             required by {}",
            self.fmt.name()
        );
        Ok(())
    }

    /// Quantized matmul `act[rows, k] @ w[k, n] (+ bias)` through the
    /// configured datapath. `act_q` indexes the activation's qtensor knob.
    pub(crate) fn qmm(
        &self,
        act: &Tensor,
        act_q: Option<usize>,
        wid: usize,
        w_name: &str,
        bias: Option<&[f32]>,
        taps: Option<&mut [Option<[f32; 3]>]>,
    ) -> Result<Vec<f32>> {
        let (rows, k) = act.as_2d();
        let (w, w_shape) = self.param(w_name)?;
        let n = w_shape[1];
        ensure!(w_shape[0] == k, "{w_name}: inner dims {k} vs {}", w_shape[0]);
        let a_prec = self.precision_of(act_q).with_context(|| format!("{w_name} activation"))?;
        self.check_tiling(rows, k, "activation")?;
        if let Some(taps) = taps {
            // the profile pass observes operands BEFORE quantization
            let wv = self.graph.value(crate::ir::ValueId(wid));
            tap(taps, act_q, &act.data)?;
            tap(taps, wv.qtensor, w)?;
        }
        let mut out = match self.path {
            MatmulPath::Packed => {
                let pa = pack(&act.data, rows, k, self.fmt, a_prec);
                let pw = self.packed_w.get(&wid).ok_or_else(|| anyhow!("{w_name} not packed"))?;
                packed_gemm(&pa, pw.as_ref())
            }
            MatmulPath::Reference => {
                let mut qa = act.data.clone();
                quantize_2d(self.fmt, &mut qa, rows, k, a_prec);
                let qw = self.quant_w.get(&wid).ok_or_else(|| anyhow!("{w_name} not quantized"))?;
                gemm_f64_segmented(&qa, qw, rows, k, n)
            }
        };
        if let Some(b) = bias {
            ensure!(b.len() == n, "{w_name}: bias length {} vs {n}", b.len());
            for r in 0..rows {
                for j in 0..n {
                    out[r * n + j] += b[j];
                }
            }
        }
        Ok(out)
    }

    /// One full forward pass: walk the IR ops in builder (topological)
    /// order. With `taps`, also record per-qtensor profile statistics.
    pub(crate) fn forward(
        &self,
        batch: &Batch,
        mut taps: Option<&mut [Option<[f32; 3]>]>,
    ) -> Result<Rc<Tensor>> {
        let (b, s, d) = (batch.batch, batch.seq, self.meta.d_model);
        ensure!(batch.tokens.len() == b * s, "token buffer does not match [batch, seq]");
        let mut vals: Vec<Option<Rc<Tensor>>> = vec![None; self.graph.values.len()];
        // remaining-consumer counts so large activations free eagerly
        let mut uses: Vec<usize> = vec![0; self.graph.values.len()];
        for op in &self.graph.ops {
            for a in &op.args {
                uses[a.0] += 1;
            }
        }
        let read = |vals: &mut Vec<Option<Rc<Tensor>>>,
                    uses: &mut Vec<usize>,
                    id: crate::ir::ValueId|
         -> Result<Rc<Tensor>> {
            let t = vals[id.0]
                .clone()
                .ok_or_else(|| anyhow!("value '{}' used before defined", self.graph.value(id).name))?;
            uses[id.0] -= 1;
            if uses[id.0] == 0 {
                vals[id.0] = None;
            }
            Ok(t)
        };

        let mut out: Option<Rc<Tensor>> = None;
        for op in &self.graph.ops {
            let rid = op.results[0];
            let rname = &self.graph.value(rid).name;
            let result: Option<Rc<Tensor>> = match op.kind {
                OpKind::Input => None, // tokens come straight from the batch
                OpKind::Embed => Some(Rc::new(self.embed(batch, b, s, d)?)),
                OpKind::LayerNorm => {
                    let x = read(&mut vals, &mut uses, op.args[0])?;
                    Some(Rc::new(self.layer_norm(&x, rname)?))
                }
                OpKind::Linear => {
                    let x = read(&mut vals, &mut uses, op.args[0])?;
                    let wid = op.params[0];
                    let w_name = self.graph.value(wid).name.clone();
                    let bias = match self.param(&bias_name_for(&w_name)) {
                        Ok((bv, _)) => Some(bv),
                        Err(_) => None,
                    };
                    let act_q = self.graph.value(op.args[0]).qtensor;
                    let y = self.qmm(&x, act_q, wid.0, &w_name, bias, taps.as_deref_mut())?;
                    let (_, w_shape) = self.param(&w_name)?;
                    let mut shape = x.shape.clone();
                    *shape.last_mut().unwrap() = w_shape[1];
                    Some(Rc::new(Tensor::new(y, shape)))
                }
                // Stream-layout ops: numerically identity. The interpreter
                // keeps the producer's dense layout (aliased, not copied);
                // Attention consumes the underlying [b, s, 3d] qkv directly.
                OpKind::Reorder | OpKind::Transpose => {
                    Some(read(&mut vals, &mut uses, op.args[0])?)
                }
                OpKind::Attention => {
                    let qkv = read(&mut vals, &mut uses, op.args[0])?;
                    // drop the transposed-K edge (same underlying data)
                    let _ = read(&mut vals, &mut uses, op.args[1])?;
                    Some(Rc::new(self.attention(&qkv, b, s, d)?))
                }
                OpKind::Gelu => {
                    let x = read(&mut vals, &mut uses, op.args[0])?;
                    Some(Rc::new(Tensor::new(
                        x.data.iter().map(|&v| gelu(v)).collect(),
                        x.shape.clone(),
                    )))
                }
                OpKind::Add => {
                    let x = read(&mut vals, &mut uses, op.args[0])?;
                    let y = read(&mut vals, &mut uses, op.args[1])?;
                    ensure!(x.data.len() == y.data.len(), "add operands differ in size");
                    Some(Rc::new(Tensor::new(
                        x.data.iter().zip(y.data.iter()).map(|(a, c)| a + c).collect(),
                        x.shape.clone(),
                    )))
                }
                OpKind::Softmax => {
                    let x = read(&mut vals, &mut uses, op.args[0])?;
                    let (rows, cols) = x.as_2d();
                    let mut y = x.data.clone();
                    for r in 0..rows {
                        softmax_row(&mut y[r * cols..(r + 1) * cols]);
                    }
                    Some(Rc::new(Tensor::new(y, x.shape.clone())))
                }
                OpKind::MeanPool => {
                    let x = read(&mut vals, &mut uses, op.args[0])?;
                    let mut y = vec![0.0f32; b * d];
                    for bi in 0..b {
                        for j in 0..d {
                            let mut acc = 0.0f64;
                            for si in 0..s {
                                acc += x.data[(bi * s + si) * d + j] as f64;
                            }
                            y[bi * d + j] = (acc / s as f64) as f32;
                        }
                    }
                    Some(Rc::new(Tensor::new(y, vec![b, d])))
                }
                OpKind::Output => {
                    let x = read(&mut vals, &mut uses, op.args[0])?;
                    out = Some(x.clone());
                    Some(x)
                }
            };
            if let Some(t) = result {
                vals[rid.0] = Some(t);
            }
        }
        out.ok_or_else(|| anyhow!("graph has no Output op"))
    }

    /// Embedding lookup + learned positional embedding, gathering rows
    /// from the bit-packed table.
    fn embed(&self, batch: &Batch, b: usize, s: usize, d: usize) -> Result<Tensor> {
        let table = self.packed_embed.as_ref().ok_or_else(|| anyhow!("embed table not packed"))?;
        let (pos, pos_shape) = self.param("pos")?;
        ensure!(pos_shape[0] >= s, "seq {s} exceeds positional table {}", pos_shape[0]);
        let vocab = self.meta.vocab;
        let mut x = vec![0.0f32; b * s * d];
        for bi in 0..b {
            for si in 0..s {
                let tok = batch.tokens[bi * s + si];
                ensure!(
                    (0..vocab as i32).contains(&tok),
                    "token id {tok} out of vocabulary range 0..{vocab}"
                );
                let row = &mut x[(bi * s + si) * d..(bi * s + si + 1) * d];
                for j in 0..d {
                    row[j] = table.get(tok as usize, j) + pos[si * d + j];
                }
            }
        }
        Ok(Tensor::new(x, vec![b, s, d]))
    }

    /// LayerNorm over the last dim; `layerN.ln1`/`.ln2` additionally pin
    /// the learnable scale/shift on the outlier channels and inject the
    /// depth-growing gain, mirroring `_layer_norm_with_outliers`.
    pub(crate) fn layer_norm(&self, x: &Tensor, name: &str) -> Result<Tensor> {
        let d = *x.shape.last().unwrap();
        let rows = x.data.len() / d;
        let (g, _) = self.param(&format!("{name}_g"))?;
        let (bb, _) = self.param(&format!("{name}_b"))?;
        let layer_idx = name
            .strip_prefix("layer")
            .and_then(|r| r.split('.').next())
            .and_then(|l| l.parse::<usize>().ok());
        let inject = layer_idx.is_some();
        let gain = OUTLIER_BASE_GAIN * (1.0 + layer_idx.unwrap_or(0) as f32);
        let mut y = vec![0.0f32; x.data.len()];
        for r in 0..rows {
            let row = &x.data[r * d..(r + 1) * d];
            let mut mu = 0.0f64;
            for &v in row {
                mu += v as f64;
            }
            mu /= d as f64;
            let mut var = 0.0f64;
            for &v in row {
                var += (v as f64 - mu) * (v as f64 - mu);
            }
            var /= d as f64;
            let denom = (var + 1e-5).sqrt();
            for j in 0..d {
                let core = ((row[j] as f64 - mu) / denom) as f32;
                let pinned = inject && j < OUTLIER_CHANNELS;
                let (gj, bj) = if pinned { (1.0, 0.0) } else { (g[j], bb[j]) };
                let mut v = core * gj + bj;
                if inject && j < OUTLIER_CHANNELS {
                    v *= gain;
                }
                y[r * d + j] = v;
            }
        }
        Ok(Tensor::new(y, x.shape.clone()))
    }

    /// Fused multi-head attention from the fused `[b, s, 3d]` qkv tensor
    /// (unquantized internals, exactly like the L2 `_attention`). Each
    /// query row runs through the shared [`attn_query_row`] primitive;
    /// for the causal case the context is truncated to `si + 1` keys,
    /// which is bitwise-identical to scoring the full masked row (a
    /// `-1e9` masked score underflows to an exact `0.0` softmax weight,
    /// a no-op under the sequential f64 mix — `scripts/verify_interp_math.py`
    /// check K2).
    fn attention(&self, qkv: &Tensor, b: usize, s: usize, d: usize) -> Result<Tensor> {
        ensure!(qkv.data.len() == b * s * 3 * d, "qkv tensor has unexpected size");
        let heads = self.meta.n_heads;
        ensure!(d % heads == 0, "d_model {d} not divisible by {heads} heads");
        let dh = d / heads;
        let causal = self.meta.kind == "lm";
        let scale = (dh as f32).sqrt();
        let row = |bi: usize, si: usize| &qkv.data[(bi * s + si) * 3 * d..(bi * s + si + 1) * 3 * d];
        let mut out = vec![0.0f32; b * s * d];
        let mut att = vec![0.0f32; s];
        for bi in 0..b {
            for h in 0..heads {
                let off = h * dh;
                for si in 0..s {
                    let n_ctx = if causal { si + 1 } else { s };
                    let o_lo = (bi * s + si) * d + off;
                    attn_query_row(
                        &row(bi, si)[off..off + dh],
                        scale,
                        n_ctx,
                        |sj| &row(bi, sj)[d + off..d + off + dh],
                        |sj| &row(bi, sj)[2 * d + off..2 * d + off + dh],
                        &mut att,
                        &mut out[o_lo..o_lo + dh],
                    );
                }
            }
        }
        Ok(Tensor::new(out, vec![b, s, d]))
    }

    /// Embedding + positional rows for one decode step: the `[b, d]`
    /// tensor whose row `bi` is exactly the `(bi, si = pos_idx)` row
    /// [`Interp::embed`] produces for a full batch.
    pub(crate) fn embed_rows(&self, toks: &[i32], pos_idx: usize) -> Result<Vec<f32>> {
        let table = self.packed_embed.as_ref().ok_or_else(|| anyhow!("embed table not packed"))?;
        let (pos, pos_shape) = self.param("pos")?;
        ensure!(
            pos_idx < pos_shape[0],
            "position {pos_idx} exceeds positional table {}",
            pos_shape[0]
        );
        let d = self.meta.d_model;
        let vocab = self.meta.vocab;
        let mut x = vec![0.0f32; toks.len() * d];
        for (bi, &tok) in toks.iter().enumerate() {
            ensure!(
                (0..vocab as i32).contains(&tok),
                "token id {tok} out of vocabulary range 0..{vocab}"
            );
            for j in 0..d {
                x[bi * d + j] = table.get(tok as usize, j) + pos[pos_idx * d + j];
            }
        }
        Ok(x)
    }

    /// Forward + loss for one batch — the L2 `eval_batch` contract:
    /// classifier = (mean cross-entropy, correct count); LM = (mean
    /// next-token NLL, correct next-token count).
    pub(crate) fn eval_batch(&self, batch: &Batch) -> Result<BatchScore> {
        let logits = self.forward(batch, None)?;
        let (b, s) = (batch.batch, batch.seq);
        if self.meta.kind == "lm" {
            ensure!(s >= 2, "LM eval needs seq_len >= 2");
            let v = self.meta.vocab;
            let mut nll_sum = 0.0f64;
            let mut correct = 0i32;
            for bi in 0..b {
                for si in 0..s - 1 {
                    let lg = &logits.data[(bi * s + si) * v..(bi * s + si + 1) * v];
                    let tgt = batch.tokens[bi * s + si + 1] as usize;
                    nll_sum += nll(lg, tgt);
                    if argmax(lg) == tgt {
                        correct += 1;
                    }
                }
            }
            Ok(BatchScore { loss: (nll_sum / (b * (s - 1)) as f64) as f32, correct })
        } else {
            let c = self.meta.n_classes;
            ensure!(logits.data.len() == b * c, "classifier logits are not [batch, classes]");
            let mut nll_sum = 0.0f64;
            let mut correct = 0i32;
            for bi in 0..b {
                let lg = &logits.data[bi * c..(bi + 1) * c];
                let label = batch.labels[bi] as usize;
                ensure!(label < c, "label {label} out of range 0..{c}");
                nll_sum += nll(lg, label);
                if argmax(lg) == label {
                    correct += 1;
                }
            }
            Ok(BatchScore { loss: (nll_sum / b as f64) as f32, correct })
        }
    }
}

/// Record profile statistics for one tapped operand.
fn tap(taps: &mut [Option<[f32; 3]>], qtensor: Option<usize>, data: &[f32]) -> Result<()> {
    let qi = qtensor.ok_or_else(|| anyhow!("tapped operand has no qtensor index"))?;
    ensure!(taps[qi].is_none(), "qtensor {qi} tapped twice in one forward");
    let n = data.len().max(1) as f64;
    let mut mean = 0.0f64;
    let (mut absmax, mut absmean) = (0.0f64, 0.0f64);
    for &v in data {
        mean += v as f64;
        absmax = absmax.max((v as f64).abs());
        absmean += (v as f64).abs();
    }
    mean /= n;
    let mut var = 0.0f64;
    for &v in data {
        var += (v as f64 - mean) * (v as f64 - mean);
    }
    taps[qi] = Some([(var / n) as f32, absmax as f32, (absmean / n) as f32]);
    Ok(())
}

/// One attention query row against an arbitrary key/value store — the
/// shared primitive behind both the full `[s, s]` pass and the KV-cached
/// single-query decode path. Scores the first `n_ctx` context positions
/// (sequential f64 dot, f32 `/ scale` cast), masks the rest of the `att`
/// buffer to `-1e9`, softmaxes in place, and mixes values with the
/// sequential f64 accumulation the L2 model uses. The `att` buffer's
/// length (not `n_ctx`) decides how many value rows the mix touches, so
/// callers with a short buffer (decode: exactly `n_ctx` cached rows)
/// and callers with a full-length buffer (prefill) get bitwise-equal
/// results per the K2 masking lemma.
pub(crate) fn attn_query_row<'k>(
    q: &[f32],
    scale: f32,
    n_ctx: usize,
    key: impl Fn(usize) -> &'k [f32],
    val: impl Fn(usize) -> &'k [f32],
    att: &mut [f32],
    out: &mut [f32],
) {
    let dh = q.len();
    for (sj, a) in att.iter_mut().enumerate() {
        *a = if sj >= n_ctx {
            -1e9
        } else {
            let k = key(sj);
            let mut acc = 0.0f64;
            for t in 0..dh {
                acc += q[t] as f64 * k[t] as f64;
            }
            acc as f32 / scale
        };
    }
    softmax_row(att);
    for (t, ot) in out.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for (sj, a) in att.iter().enumerate() {
            acc += *a as f64 * val(sj)[t] as f64;
        }
        *ot = acc as f32;
    }
}

/// Weight name -> bias name per the `param_spec` convention
/// (`layerN.w_X` -> `layerN.b_X`, `head_w` -> `head_b`).
pub(crate) fn bias_name_for(w_name: &str) -> String {
    if w_name == "head_w" {
        "head_b".to_string()
    } else {
        w_name.replacen("w_", "b_", 1)
    }
}

/// tanh-approximate GELU (`jax.nn.gelu`'s default), in f32.
pub(crate) fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// In-place stable softmax of one row.
pub(crate) fn softmax_row(row: &mut [f32]) {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f64;
    for v in row.iter_mut() {
        *v = (*v - m).exp();
        sum += *v as f64;
    }
    for v in row.iter_mut() {
        *v = (*v as f64 / sum) as f32;
    }
}

/// -log_softmax(logits)[target], computed in f64 from the f32 logits.
pub(crate) fn nll(logits: &[f32], target: usize) -> f64 {
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut sum = 0.0f64;
    for &v in logits {
        sum += (v as f64 - m).exp();
    }
    m + sum.ln() - logits[target] as f64
}

/// First index of the maximum (matches `jnp.argmax` tie-breaking).
pub(crate) fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_row_normalizes() {
        let mut r = [1.0f32, 2.0, 3.0];
        softmax_row(&mut r);
        let s: f32 = r.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(r[2] > r[1] && r[1] > r[0]);
    }

    #[test]
    fn nll_matches_log_softmax() {
        let lg = [0.0f32, 0.0, 0.0, 0.0];
        assert!((nll(&lg, 1) - (4.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn argmax_takes_first_tie() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn gelu_fixed_points() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-5);
        assert!(gelu(-10.0).abs() < 1e-4);
        assert!((gelu(10.0) - 10.0).abs() < 1e-4);
    }

    #[test]
    fn bias_names() {
        assert_eq!(bias_name_for("layer0.w_qkv"), "layer0.b_qkv");
        assert_eq!(bias_name_for("layer3.w_fc2"), "layer3.b_fc2");
        assert_eq!(bias_name_for("head_w"), "head_b");
    }

    #[test]
    fn single_query_row_matches_full_masked_row_bitwise() {
        // The K2 masking lemma in Rust (mirrored in
        // scripts/verify_interp_math.py): scoring only the live context
        // with a short buffer gives the same bits as the full buffer
        // whose tail is -1e9 masked — exp underflows to an exact 0.0
        // weight, a no-op under the sequential f64 mix.
        let mut rng = crate::util::rng::Rng::new(42);
        let (s, dh, n_ctx) = (19usize, 8usize, 11usize);
        let kv: Vec<f32> = (0..2 * s * dh).map(|_| rng.normal() as f32).collect();
        let q: Vec<f32> = (0..dh).map(|_| rng.normal() as f32).collect();
        let key = |sj: usize| &kv[sj * dh..(sj + 1) * dh];
        let val = |sj: usize| &kv[(s + sj) * dh..(s + sj + 1) * dh];
        let scale = (dh as f32).sqrt();
        let (mut att_full, mut out_full) = (vec![0.0f32; s], vec![0.0f32; dh]);
        attn_query_row(&q, scale, n_ctx, key, val, &mut att_full, &mut out_full);
        let (mut att_short, mut out_short) = (vec![0.0f32; n_ctx], vec![0.0f32; dh]);
        attn_query_row(&q, scale, n_ctx, key, val, &mut att_short, &mut out_short);
        for (a, b) in out_full.iter().zip(out_short.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
        assert_eq!(att_full[..n_ctx], att_short[..]);
        assert!(att_full[n_ctx..].iter().all(|&w| w == 0.0));
    }

    #[test]
    fn cpu_backend_runs_a_tiny_classifier_forward() {
        let meta = ModelMeta::synthetic("t", 1, 32, 2, 512, 16, 4, "classifier", 16);
        let w = crate::frontend::init_params(&meta, 7);
        let be = CpuBackend::new();
        let g = be.prepare(&meta, &w, &[]).unwrap();
        let batch = &crate::data::batches(crate::data::Task::Sst2, 1, 1, 16, 16)[0];
        let qcfg = vec![0.0f32; 2 * meta.num_qtensors()];
        let scores = be.eval(&g, &meta, std::slice::from_ref(batch), "fp32", &qcfg, &w).unwrap();
        assert_eq!(scores.len(), 1);
        assert!(scores[0].loss.is_finite());
        assert!((0..=16).contains(&scores[0].correct));
    }

    #[test]
    fn cpu_profile_taps_every_qtensor() {
        let meta = ModelMeta::synthetic("t", 1, 32, 2, 512, 16, 4, "classifier", 16);
        let w = crate::frontend::init_params(&meta, 7);
        let batch = &crate::data::batches(crate::data::Task::Sst2, 1, 1, 16, 16)[0];
        let rows = CpuBackend::new().profile_batch(&meta, &w, batch).unwrap();
        assert_eq!(rows.len(), meta.num_qtensors());
        for r in &rows {
            assert!(r[0] >= 0.0 && r[1] >= 0.0 && r[2] >= 0.0);
            assert!(r[1] >= r[2], "absmax must dominate absmean");
        }
    }

    #[test]
    fn cpu_backend_rejects_qat() {
        let meta = ModelMeta::synthetic("t", 1, 32, 2, 512, 16, 4, "classifier", 16);
        assert!(CpuBackend::new().qat_available(&meta, FormatKind::MxInt).is_err());
    }
}
