//! The execution-backend abstraction: everything the `evaluate` pass
//! needs from "something that can run the quantized model" behind one
//! trait, so accuracy evaluation is no longer hard-wired to PJRT.
//!
//! Two implementations exist:
//!
//!  * [`PjrtBackend`] — a thin adapter over [`Runtime`] /
//!    [`PreparedTensor`] / `execute_prepared`: the original artifact-keyed
//!    path, behavior-preserving down to the prepared-literal reuse and
//!    the per-batch QAT error swallowing.
//!  * [`crate::runtime::CpuBackend`] — a pure-Rust MASE-IR interpreter
//!    (`runtime::interp`) that fake-quantizes via the official
//!    [`crate::formats`] quantizers and drives every Linear/Embed matmul
//!    through `packed::kernels` on bit-packed operands. No PJRT, no
//!    artifacts.
//!
//! The backend identity ([`BackendKind::name`]) is folded into
//! [`crate::passes::eval_scope`], so a persistent
//! [`crate::search::CacheStore`] never mixes PJRT-measured and
//! CPU-measured objectives.

use super::client::{PreparedTensor, Runtime, TensorData};
use super::decode::DecodeStats;
use crate::data::Batch;
use crate::formats::FormatKind;
use crate::frontend::ModelMeta;
use anyhow::{bail, Result};

/// Which execution backend scores solutions — the `--backend` CLI knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum BackendKind {
    /// AOT-lowered HLO artifacts executed through the PJRT CPU client.
    #[default]
    Pjrt,
    /// The pure-Rust packed-arithmetic interpreter (artifact-free).
    Cpu,
}

impl BackendKind {
    /// Stable identity string — part of every eval-cache scope.
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Cpu => "cpu",
        }
    }

    pub fn from_name(s: &str) -> Option<BackendKind> {
        Some(match s {
            "pjrt" => BackendKind::Pjrt,
            "cpu" => BackendKind::Cpu,
            _ => return None,
        })
    }
}

/// What one batch's evaluation produced: the same (loss, correct) pair
/// the HLO eval artifacts return.
#[derive(Debug, Clone, Copy)]
pub struct BatchScore {
    pub loss: f32,
    pub correct: i32,
}

/// What [`ExecBackend::profile_decode`] hands back: one autoregressive
/// generation run, with counted attention work ([`DecodeStats`]) as the
/// deterministic complexity scoreboard.
#[derive(Debug, Clone)]
pub struct DecodeReport {
    /// Generated token ids, sequence-major: `[n_seqs, n_tokens]`.
    pub tokens: Vec<i32>,
    /// Mean teacher-forced NLL over the realized sequences (mean of the
    /// per-group scores).
    pub loss: f32,
    /// Correct next-token predictions over the realized sequences.
    pub correct: i32,
    /// Wall-clock spent in prefill, summed across worker groups.
    pub prefill_seconds: f64,
    /// Wall-clock spent in cached decode steps, summed across groups.
    pub decode_seconds: f64,
    pub stats: DecodeStats,
    pub n_seqs: usize,
    pub prompt_len: usize,
    pub n_tokens: usize,
}

/// An execution engine for the `evaluate`/`profile` passes.
///
/// Implementations must be `Sync`: the parallel search pass shares one
/// evaluator (and therefore one backend + one `Prepared`) across worker
/// threads. The quant config is passed as the flat f32[V, 2] row-major
/// (bits, frac) tensor (`QuantSolution::to_qconfig`), which keeps this
/// trait independent of the pass layer.
pub trait ExecBackend: Sync {
    /// Per-(weights, batches) state built once at `Evaluator`
    /// construction and reused across every trial (§Perf/L3: for PJRT
    /// this is the prepared weight/batch literals).
    type Prepared: Sync;

    fn kind(&self) -> BackendKind;

    fn prepare(&self, meta: &ModelMeta, weights: &[f32], batches: &[Batch])
        -> Result<Self::Prepared>;

    /// Score one quantized configuration over `batches` (one
    /// [`BatchScore`] per batch, same order). `fmt_tag` names the
    /// emulation variant — usually `FormatKind::name()`, but PJRT also
    /// accepts artifact variants like `"mxint_pallas"`. `weights` is the
    /// prepared base vector on the common path; QAT hands in tuned
    /// copies.
    fn eval(
        &self,
        prep: &Self::Prepared,
        meta: &ModelMeta,
        batches: &[Batch],
        fmt_tag: &str,
        qcfg: &[f32],
        weights: &[f32],
    ) -> Result<Vec<BatchScore>>;

    /// Per-qtensor (variance, absmax, absmean) rows for one calibration
    /// batch, in qtensor order (the `profile` pass kernel).
    fn profile_batch(
        &self,
        meta: &ModelMeta,
        weights: &[f32],
        batch: &Batch,
    ) -> Result<Vec<[f32; 3]>>;

    /// Can this backend QAT-fine-tune (model, fmt)? `Err` explains why
    /// not (missing artifact, or no gradient path at all).
    fn qat_available(&self, meta: &ModelMeta, fmt: FormatKind) -> Result<()>;

    /// One QAT fine-tune run (STE sign-SGD over `train`), returning the
    /// tuned weights.
    fn qat_tune(
        &self,
        meta: &ModelMeta,
        weights: &[f32],
        train: &[Batch],
        fmt: FormatKind,
        qcfg: &[f32],
        lr: f32,
    ) -> Result<Vec<f32>>;

    /// Content hash of the packed-weight artifact backing this backend,
    /// when one is loaded (`--weights model.mxa`). Folded into
    /// [`crate::passes::eval_scope`] so cached objectives are keyed to
    /// the exact weight bits they were measured on. `None` for the
    /// in-memory pack path (scope strings stay byte-identical to every
    /// pre-artifact cache file).
    fn weights_hash(&self) -> Option<u64> {
        None
    }

    /// Autoregressive generation profile: prefill `prompts`
    /// (`[n_seqs, prompt_len]`, sequence-major) and greedily decode
    /// `n_tokens` per sequence through a KV cache, fanning sequence
    /// groups over `threads` workers. Only the CPU interpreter implements
    /// an incremental engine; the default bails with a pointer there.
    #[allow(clippy::too_many_arguments)]
    fn profile_decode(
        &self,
        _meta: &ModelMeta,
        _weights: &[f32],
        _fmt_tag: &str,
        _qcfg: &[f32],
        _prompts: &[i32],
        _n_seqs: usize,
        _prompt_len: usize,
        _n_tokens: usize,
        _threads: usize,
    ) -> Result<DecodeReport> {
        bail!(
            "backend '{}' has no incremental decode engine (use --backend cpu)",
            self.kind().name()
        )
    }
}

/// The PJRT adapter: artifact-keyed execution through [`Runtime`],
/// exactly as the pre-trait `Evaluator` did it.
#[derive(Clone, Copy)]
pub struct PjrtBackend<'a> {
    rt: &'a Runtime,
}

impl<'a> PjrtBackend<'a> {
    pub fn new(rt: &'a Runtime) -> Self {
        Self { rt }
    }

    pub fn runtime(&self) -> &'a Runtime {
        self.rt
    }
}

/// Weight + batch literals converted once and reused across every
/// execution (§Perf/L3: the weights vector alone is 0.1-3 MB copied per
/// batch per trial otherwise).
pub struct PjrtPrepared {
    /// Address/length of the base weight slice, to recognize it at
    /// `eval` time without holding a borrow (QAT passes fresh copies).
    weights_addr: usize,
    weights_len: usize,
    weights: PreparedTensor,
    batches: Vec<(PreparedTensor, PreparedTensor)>,
}

impl ExecBackend for PjrtBackend<'_> {
    type Prepared = PjrtPrepared;

    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    fn prepare(
        &self,
        meta: &ModelMeta,
        weights: &[f32],
        batches: &[Batch],
    ) -> Result<PjrtPrepared> {
        let weights_prep = TensorData::f32(weights, &[meta.param_size as i64]).prepare()?;
        let batches_prep = batches
            .iter()
            .map(|b| {
                Ok((
                    TensorData::i32(&b.tokens, &[b.batch as i64, b.seq as i64]).prepare()?,
                    TensorData::i32(&b.labels, &[b.batch as i64]).prepare()?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(PjrtPrepared {
            weights_addr: weights.as_ptr() as usize,
            weights_len: weights.len(),
            weights: weights_prep,
            batches: batches_prep,
        })
    }

    fn eval(
        &self,
        prep: &PjrtPrepared,
        meta: &ModelMeta,
        batches: &[Batch],
        fmt_tag: &str,
        qcfg: &[f32],
        weights: &[f32],
    ) -> Result<Vec<BatchScore>> {
        let artifact = meta.artifact(&format!("eval_{fmt_tag}"))?;
        let v = meta.num_qtensors() as i64;
        debug_assert_eq!(qcfg.len() as i64, 2 * v);
        assert_eq!(batches.len(), prep.batches.len(), "prepared batches out of sync");
        // weights literal: reuse the prepared one on the common path, only
        // converting fresh buffers (QAT-tuned copies) when they differ
        let w_prep;
        let w_ref = if weights.as_ptr() as usize == prep.weights_addr
            && weights.len() == prep.weights_len
        {
            &prep.weights
        } else {
            w_prep = TensorData::f32(weights, &[meta.param_size as i64]).prepare()?;
            &w_prep
        };
        let q_prep = TensorData::f32(qcfg, &[v, 2]).prepare()?;
        let mut scores = Vec::with_capacity(batches.len());
        for (toks, labs) in prep.batches.iter() {
            let out = self.rt.execute_prepared(artifact, &[w_ref, toks, labs, &q_prep])?;
            scores.push(BatchScore { loss: out[0].scalar_f32()?, correct: out[1].scalar_i32()? });
        }
        Ok(scores)
    }

    fn profile_batch(
        &self,
        meta: &ModelMeta,
        weights: &[f32],
        batch: &Batch,
    ) -> Result<Vec<[f32; 3]>> {
        let artifact = meta.artifact("profile")?;
        let out = self.rt.execute(
            artifact,
            &[
                TensorData::f32(weights, &[meta.param_size as i64]),
                TensorData::i32(&batch.tokens, &[batch.batch as i64, batch.seq as i64]),
            ],
        )?;
        let stats = out[0].to_vec_f32()?; // [V, 3] row-major
        Ok((0..meta.num_qtensors())
            .map(|i| [stats[i * 3], stats[i * 3 + 1], stats[i * 3 + 2]])
            .collect())
    }

    fn qat_available(&self, meta: &ModelMeta, fmt: FormatKind) -> Result<()> {
        meta.artifact(&format!("qat_{}", fmt.name())).map(|_| ())
    }

    fn qat_tune(
        &self,
        meta: &ModelMeta,
        weights: &[f32],
        train: &[Batch],
        fmt: FormatKind,
        qcfg: &[f32],
        lr: f32,
    ) -> Result<Vec<f32>> {
        let artifact = meta.artifact(&format!("qat_{}", fmt.name()))?;
        let v = meta.num_qtensors() as i64;
        let mut w = weights.to_vec();
        // Per-batch execution errors are swallowed (the step is skipped),
        // matching the pre-trait search pass: a transient failure mid-tune
        // degrades the fine-tune, it does not kill the trial.
        for b in train {
            if let Ok(out) = self.rt.execute(
                artifact,
                &[
                    TensorData::f32(&w, &[meta.param_size as i64]),
                    TensorData::i32(&b.tokens, &[b.batch as i64, b.seq as i64]),
                    TensorData::i32(&b.labels, &[b.batch as i64]),
                    TensorData::f32(qcfg, &[v, 2]),
                    TensorData::scalar_f32(lr),
                ],
            ) {
                if let Ok(new_w) = out[0].to_vec_f32() {
                    w = new_w;
                }
            }
        }
        Ok(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_names_round_trip() {
        for k in [BackendKind::Pjrt, BackendKind::Cpu] {
            assert_eq!(BackendKind::from_name(k.name()), Some(k));
        }
        assert_eq!(BackendKind::from_name("tpu"), None);
        assert_eq!(BackendKind::default(), BackendKind::Pjrt);
    }
}
