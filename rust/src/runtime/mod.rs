//! Execution runtimes — the only place the Rust coordinator touches the
//! models' numerics; Python never runs here.
//!
//! The [`ExecBackend`] trait ([`backend`]) abstracts "something that can
//! run the quantized model" for the evaluate/profile passes, with two
//! implementations:
//!
//!  * [`PjrtBackend`] over [`Runtime`] ([`client`]): loads the
//!    AOT-lowered HLO text artifacts and executes them on the CPU PJRT
//!    client (`xla` crate). HLO *text* is the interchange format:
//!    jax >= 0.5 emits protos with 64-bit instruction ids that
//!    xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//!    /opt/xla-example/README.md). Executables are compiled once per
//!    artifact and cached — compilation is 10-100x the cost of a single
//!    execution, and the search loop re-executes the same artifact with
//!    hundreds of different quant configs (§Perf/L3).
//!  * [`CpuBackend`] ([`interp`]): a pure-Rust MASE-IR interpreter that
//!    fake-quantizes through [`crate::formats`] and runs every matmul on
//!    bit-packed operands via [`crate::packed::kernels`] — the
//!    artifact-free path (`--backend cpu`).
//!
//! On top of the interpreter, [`decode`] adds the KV-cached
//! autoregressive engine ([`Decoder`], `mase generate`,
//! [`ExecBackend::profile_decode`]): same packed weights and quantizers,
//! position-major incremental steps, bitwise-parity-tested against the
//! full recompute. The engine's per-slot context windows
//! ([`Decoder::evict`] / [`Decoder::truncate`] / [`Decoder::compact`])
//! let [`crate::serve`] reuse cache slots across requests — the
//! substrate for the continuous-batching scheduler behind `mase serve`.

pub mod backend;
pub mod client;
pub mod decode;
pub mod interp;

pub use backend::{BackendKind, BatchScore, DecodeReport, ExecBackend, PjrtBackend};
pub use client::{OutputTensor, PreparedTensor, Runtime, TensorData};
pub use decode::{generate_many, generate_many_traced, score_from_steps, DecodeStats, Decoder, GenOut};
pub use interp::{build_weights_artifact, CpuBackend, MatmulPath};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::manifest::Manifest;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn quant_ref_artifact_matches_rust_formats() {
        // The cross-layer golden test: the HLO emulation (L2, executed via
        // PJRT) and the Rust formats module (L3) must agree on q(x).
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let manifest = Manifest::load(&artifacts_dir()).unwrap();
        let rt = Runtime::new(&artifacts_dir()).unwrap();
        let mut rng = crate::util::rng::Rng::new(42);
        let x: Vec<f32> = (0..32 * 32).map(|_| rng.normal() as f32).collect();

        for (fmt_name, file) in &manifest.quant_refs {
            let fmt = crate::formats::FormatKind::from_name(fmt_name).unwrap();
            let cfg = match fmt {
                crate::formats::FormatKind::Int => [6.0f32, 2.0],
                _ => [5.0f32, 0.0],
            };
            let out = rt
                .execute(
                    file,
                    &[TensorData::f32(&x, &[32, 32]), TensorData::f32(&cfg, &[2])],
                )
                .unwrap();
            let got = out[0].to_vec_f32().unwrap();
            let mut want = x.clone();
            crate::formats::quantize_2d(
                fmt,
                &mut want,
                32,
                32,
                crate::formats::Precision::new(cfg[0], cfg[1]),
            );
            // Exact agreement except where XLA's approximate floor(log2)
            // lands on the other side of a power of two (rare).
            let mismatches = got
                .iter()
                .zip(want.iter())
                .filter(|(a, b)| (*a - *b).abs() > 1e-6 * b.abs().max(1e-6))
                .count();
            assert!(
                mismatches * 1000 < x.len(),
                "{fmt_name}: {mismatches}/{} mismatches",
                x.len()
            );
        }
    }

    #[test]
    fn executable_cache_hits() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(&artifacts_dir()).unwrap();
        let x = vec![0.5f32; 32 * 32];
        let args = [TensorData::f32(&x, &[32, 32]), TensorData::f32(&[4.0, 0.0], &[2])];
        rt.execute("quant_ref_mxint.hlo.txt", &args).unwrap();
        let before = rt.compile_count();
        rt.execute("quant_ref_mxint.hlo.txt", &args).unwrap();
        assert_eq!(rt.compile_count(), before, "second execute must not recompile");
    }

    #[test]
    fn missing_artifact_is_an_error() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(&artifacts_dir()).unwrap();
        assert!(rt.execute("no_such_artifact.hlo.txt", &[]).is_err());
    }
}
