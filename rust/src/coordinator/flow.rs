//! The full MASE flow (paper Fig. 3 left): front-end -> profile ->
//! [quantize + parallelize + evaluate]* under `search` -> emit.

use super::pretrain::{have_trained_weights, pretrain, PretrainConfig};
use super::Session;
use crate::data::{batches, Task};
use crate::formats::FormatKind;
use crate::obs::Registry;
use crate::passes::{
    emit_pass, eval_scope, profile_model, run_search_traced, Evaluator, Objective, PassManager,
    QuantSolution, SearchConfig, SearchOutcome,
};
use crate::runtime::{BackendKind, CpuBackend, ExecBackend};
use crate::search::{Algorithm, CacheStore, EvalCache};
use anyhow::Result;
use std::path::PathBuf;
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct FlowConfig {
    pub model: String,
    pub task: Task,
    pub fmt: FormatKind,
    pub algorithm: Algorithm,
    pub trials: usize,
    pub eval_batches: usize,
    pub qat_steps: usize,
    pub hw_aware: bool,
    pub seed: u64,
    pub emit_dir: Option<PathBuf>,
    pub pretrain_steps: usize,
    /// Worker threads for the parallel search pass (0 = auto; see
    /// `util::pool::threads_from_env`).
    pub threads: usize,
    /// Search proposals evaluated concurrently per ask/tell round.
    pub batch: usize,
    /// Persistent evaluation cache (`--cache`): loaded before the search
    /// pass, flushed atomically after it. Entries are scoped by
    /// [`eval_scope`], so one file safely serves many (model, task,
    /// format) contexts. `None` = run-local memoization only.
    pub cache_path: Option<PathBuf>,
    /// TPE constant-liar variant (see `search::LieStrategy`).
    pub tpe_mean_lie: bool,
    /// Execution backend scoring the trials (`--backend {pjrt,cpu}`).
    /// Folded into the eval-cache scope, so the two backends' measured
    /// objectives never mix in a shared cache file.
    pub backend: BackendKind,
    /// PR 8 observability (`--trace`): when set, the flow records pass
    /// spans, per-trial memo status and cache counters into
    /// [`FlowReport::trace`] for export/summary by the caller.
    pub trace: bool,
    /// `.mxa` packed-weight artifact (`--weights`): loaded into the CPU
    /// backend so warm sessions serve pre-packed tensors with zero
    /// re-quantize/re-pack work. The artifact's content hash joins the
    /// eval-cache scope. CPU backend only; PJRT feeds raw f32 weights to
    /// the device and has nothing to reuse.
    pub weights_artifact: Option<PathBuf>,
}

impl Default for FlowConfig {
    fn default() -> Self {
        Self {
            model: "opt-125m-sim".into(),
            task: Task::Sst2,
            fmt: FormatKind::MxInt,
            algorithm: Algorithm::Tpe,
            trials: 64,
            eval_batches: 4,
            qat_steps: 0,
            hw_aware: true,
            seed: 0,
            emit_dir: None,
            pretrain_steps: 220,
            threads: 0,
            batch: 8,
            cache_path: None,
            tpe_mean_lie: false,
            backend: BackendKind::Pjrt,
            trace: false,
            weights_artifact: None,
        }
    }
}

#[derive(Debug)]
pub struct FlowReport {
    pub outcome: SearchOutcome,
    pub fp32_accuracy: f64,
    pub int8_baseline: crate::passes::EvalResult,
    pub pass_manager: PassManager,
    pub emitted_files: usize,
    pub emitted_lines: usize,
    pub dag_size: usize,
    /// The flow's trace registry: disabled (and empty) unless
    /// [`FlowConfig::trace`] was set. The caller renders/exports it
    /// ([`crate::obs::jsonl`], [`crate::obs::chrome`],
    /// [`crate::obs::TraceSummary`]).
    pub trace: Arc<Registry>,
}

/// Run the complete flow for one (model, task): returns the search
/// outcome plus FP32 and int8 reference points (the Fig. 7 comparison
/// anchors). Dispatches on [`FlowConfig::backend`]: PJRT (artifact-keyed
/// HLO execution) or the artifact-free packed CPU interpreter.
pub fn run_flow(session: &Session, cfg: &FlowConfig) -> Result<FlowReport> {
    match cfg.backend {
        BackendKind::Pjrt => {
            anyhow::ensure!(
                cfg.weights_artifact.is_none(),
                "--weights is a packed-CPU-backend feature: the PJRT backend feeds raw f32 \
                 weights to the device and cannot serve a .mxa artifact (use --backend cpu)"
            );
            run_flow_with(session, cfg, session.pjrt_backend()?)
        }
        BackendKind::Cpu => run_flow_with(session, cfg, cpu_backend_for(cfg.weights_artifact.as_deref())?),
    }
}

/// Packed CPU backend, warm-started from a `.mxa` artifact when given.
/// The one loader path behind `--weights` for flow, sweep, generate and
/// serve, so every surface reports loader failures identically.
pub fn cpu_backend_for(weights: Option<&std::path::Path>) -> Result<CpuBackend> {
    Ok(match weights {
        Some(p) => CpuBackend::with_artifact(Arc::new(
            crate::packed::ArtifactWeights::load(p)
                .map_err(|e| anyhow::anyhow!("loading weights artifact {}: {e:#}", p.display()))?,
        )),
        None => CpuBackend::new(),
    })
}

/// The backend-generic flow core.
fn run_flow_with<B: ExecBackend>(
    session: &Session,
    cfg: &FlowConfig,
    backend: B,
) -> Result<FlowReport> {
    let trace =
        Arc::new(if cfg.trace { Registry::new() } else { Registry::disabled() });
    let mut pm = PassManager::new();
    if cfg.trace {
        pm.attach(trace.clone());
    }
    let meta = session.manifest.model(&cfg.model)?.clone();

    // front-end: weights + IR
    let weights = pm.run("front-end", || {
        pretrain(
            session,
            &meta,
            if meta.kind == "lm" { None } else { Some(cfg.task) },
            &PretrainConfig { steps: cfg.pretrain_steps, ..Default::default() },
        )
    })?;

    let eval_batches = batches(cfg.task, 1, cfg.eval_batches, meta.batch, meta.seq_len);
    let mut ev = Evaluator::new(backend, &meta, &weights, &eval_batches)?;
    ev.objective = if cfg.hw_aware { Objective::default() } else { Objective::sw_only() };

    // profile (calibration for int + Fig. 1a data)
    let profile = pm.run("profile", || {
        profile_model(&ev.backend, &meta, &weights, &eval_batches[..1])
    })?;

    // reference points
    let fp32_sol = QuantSolution::uniform(FormatKind::Fp32, 32.0, &meta, &profile);
    let fp32_accuracy = pm.run("evaluate", || ev.accuracy(&fp32_sol))?.accuracy();
    let int8_sol = QuantSolution::uniform(FormatKind::Int, 8.0, &meta, &profile);
    let int8_baseline = pm.run("evaluate", || ev.evaluate(&int8_sol))?;

    // search, memoized through the persistent cache when configured
    let scfg = SearchConfig {
        algorithm: cfg.algorithm,
        trials: cfg.trials,
        fmt: cfg.fmt,
        seed: cfg.seed,
        qat_steps: cfg.qat_steps,
        threads: cfg.threads,
        batch: cfg.batch.max(1),
        tpe_mean_lie: cfg.tpe_mean_lie,
        ..Default::default()
    };
    // The scope must reflect the weights actually evaluated: a CPU-backend
    // session with no runtime and no valid cached weight file scored the
    // UNTRAINED init_params model, i.e. an effective pretrain budget of 0
    // — caching that under ps{N} would poison warm runs made after real
    // weights appear on the host.
    let task = if meta.kind == "lm" { None } else { Some(cfg.task) };
    let effective_ps =
        if have_trained_weights(session, &meta, task) { cfg.pretrain_steps } else { 0 };
    let store = cfg.cache_path.as_deref().map(CacheStore::open);
    let cache = match &store {
        Some(s) => {
            if let Some(note) = s.load_note() {
                eprintln!("eval cache: {note}");
            }
            s.cache(&eval_scope(
                &cfg.model,
                cfg.task,
                cfg.fmt,
                cfg.qat_steps,
                scfg.qat_lr,
                cfg.eval_batches,
                effective_ps,
                if cfg.hw_aware { "hw" } else { "sw" },
                cfg.backend,
                ev.backend.weights_hash(),
            ))
        }
        None => Arc::new(EvalCache::new()),
    };
    let outcome =
        pm.run("search", || run_search_traced(&ev, &profile, cfg.task, &scfg, &cache, &trace));
    // flush BEFORE surfacing a search failure: evaluations already paid
    // (memoized before the failing trial) must survive for the re-run —
    // the same guarantee coordinator::sweep::sweep_with gives per cell
    if let Some(s) = &store {
        s.save()?;
    }
    let outcome = outcome?;

    // emit the winning design
    let (mut emitted_files, mut emitted_lines) = (0, 0);
    let dag_size;
    if let Some(dir) = &cfg.emit_dir {
        let (_dp, _bits, g) = ev.hardware(&outcome.best)?;
        dag_size = g.dag_size();
        let (design, lines) = pm.run("emit", || emit_pass::emit_to_dir(&g, dir))?;
        emitted_files = design.files.len();
        emitted_lines = lines;
    } else {
        let (_dp, _bits, g) = ev.hardware(&outcome.best)?;
        dag_size = g.dag_size();
    }

    Ok(FlowReport {
        outcome,
        fp32_accuracy,
        int8_baseline,
        pass_manager: pm,
        emitted_files,
        emitted_lines,
        dag_size,
        trace,
    })
}
