//! The `sweep` orchestrator: the full Fig. 6 grid (models × tasks ×
//! format families) driven through ONE shared, optionally disk-backed
//! evaluation cache, so re-running a sweep re-simulates nothing.
//!
//! Layering: [`sweep_with`] is the generic core — grid iteration, cache
//! scoping, per-cell hit/miss accounting and the final atomic flush —
//! and is independent of the PJRT evaluator, so the persistence
//! guarantees are integration-tested without artifacts (see
//! `tests/cache_persistence.rs`). [`run_sweep`] instantiates it with the
//! real pipeline (pretrain → profile → [`run_search_traced`]) and is
//! what `mase sweep` and `benches/fig6_opt_sweep.rs` call.

use super::pretrain::{have_trained_weights, pretrain, PretrainConfig};
use super::Session;
use crate::data::{batches, Task};
use crate::formats::FormatKind;
use crate::obs::Registry;
use crate::passes::{
    eval_scope, profile_model, run_search_traced, Evaluator, Objective, SearchConfig,
};
use crate::runtime::{BackendKind, CpuBackend, ExecBackend};
use crate::search::{Algorithm, CacheStats, CacheStore, EvalCache};
use anyhow::Result;
use std::path::PathBuf;
use std::sync::Arc;

/// Grid + search hyperparameters for one sweep. Everything that changes
/// the objective is folded into each cell's cache scope (see
/// [`eval_scope`]), so sweeps with different settings can safely share
/// one cache file.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Model names (manifest keys), outermost grid axis.
    pub models: Vec<String>,
    pub tasks: Vec<Task>,
    pub fmts: Vec<FormatKind>,
    pub algorithm: Algorithm,
    pub trials: usize,
    pub seed: u64,
    /// Search proposals per ask/tell round.
    pub batch: usize,
    /// Worker threads (0 = auto, see `util::pool::threads_from_env`).
    pub threads: usize,
    pub eval_batches: usize,
    pub pretrain_steps: usize,
    /// QAT fine-tune steps *requested* per trial; applied only to cells
    /// whose model ships the matching `qat_<fmt>` artifact (the paper's
    /// QAT-small / PTQ-large split). 0 = PTQ everywhere.
    pub qat_steps: usize,
    /// QAT learning rate (part of the objective, hence of the scope).
    pub qat_lr: f32,
    /// Hardware-aware objective (Eq. 4) vs the SW-only `acc + k/b`.
    pub hw_aware: bool,
    /// Use TPE's mean-value constant lie (see `search::LieStrategy`).
    pub tpe_mean_lie: bool,
    /// Disk-backed cache; `None` = in-memory sharing only.
    pub cache_path: Option<PathBuf>,
    /// Execution backend scoring every cell (`--backend {pjrt,cpu}`).
    /// Part of each cell's cache scope: one cache file can serve sweeps
    /// under both backends without ever mixing their objectives.
    pub backend: BackendKind,
    /// PR 8 observability (`--trace`): when set, the sweep records a
    /// `sweep/cell` span per grid cell (tagged model/task/fmt), folds
    /// each cell's cache-counter delta into the registry, and the search
    /// inside every cell records per-trial memo status. The caller
    /// exports/summarizes [`SweepReport::trace`].
    pub trace: bool,
    /// `.mxa` packed-weight artifact (`--weights`) serving every cell's
    /// weight tensors pre-packed (CPU backend only — see
    /// [`crate::coordinator::FlowConfig::weights_artifact`]).
    pub weights_artifact: Option<PathBuf>,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            // the three OPT sizes whose 6-task weights pretrain quickly
            models: vec![
                "opt-125m-sim".to_string(),
                "opt-350m-sim".to_string(),
                "opt-1.3b-sim".to_string(),
            ],
            tasks: Task::ALL.to_vec(),
            fmts: vec![FormatKind::MxInt, FormatKind::Int],
            algorithm: Algorithm::Tpe,
            trials: 24,
            seed: 0,
            batch: 8,
            threads: 0,
            eval_batches: 3,
            pretrain_steps: 220,
            qat_steps: 0,
            qat_lr: 0.002,
            hw_aware: true,
            tpe_mean_lie: false,
            cache_path: None,
            backend: BackendKind::Pjrt,
            trace: false,
            weights_artifact: None,
        }
    }
}

/// One (model, task, format) cell of the grid.
#[derive(Debug, Clone)]
pub struct SweepItem {
    pub model: String,
    pub task: Task,
    pub fmt: FormatKind,
    /// *Effective* QAT fine-tune steps for this cell — after any
    /// per-model downgrade to PTQ (see [`run_sweep`]). Part of the cache
    /// scope, so it must reflect the objective actually evaluated, not
    /// the requested [`SweepConfig::qat_steps`].
    pub qat_steps: usize,
    /// *Effective* pretrain budget for this cell — 0 when a runtime-less
    /// (CPU-backend) session has no cached weight file and therefore
    /// evaluates the untrained `init_params` model (see [`run_sweep`]).
    /// Part of the cache scope for the same reason as `qat_steps`.
    pub pretrain_steps: usize,
    /// Content hash of the `.mxa` artifact serving this cell's weights
    /// (`None` without `--weights`). Part of the cache scope: artifact-
    /// backed and in-memory-packed runs never share entries unless they
    /// came from the same container bytes.
    pub weights_hash: Option<u64>,
}

/// What one cell's evaluation produced (the Fig. 6 data points).
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// Best scalarized objective value.
    pub value: f64,
    pub accuracy: f64,
    pub avg_bits: f64,
    /// "QAT" or "PTQ" (the paper's per-model split).
    pub mode: String,
}

/// A finished cell: the result plus this cell's cache activity.
/// `cache.misses` is exactly the number of evaluator invocations paid;
/// a re-run with a warm cache shows `misses == 0`, `hit_rate() == 1`.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub item: SweepItem,
    pub cell: SweepCell,
    pub cache: CacheStats,
}

/// Sweep outcome: all rows plus store-wide cache accounting.
#[derive(Debug)]
pub struct SweepReport {
    pub rows: Vec<SweepRow>,
    /// Aggregate counters over every scope touched.
    pub totals: CacheStats,
    /// Entries preloaded from disk at open (0 on a cold start).
    pub loaded_entries: usize,
    /// Entries flushed back at the end (0 when not disk-backed).
    pub saved_entries: usize,
    /// Why on-disk contents were discarded, if they were (version
    /// mismatch / corruption — see `CacheStore::load_note`).
    pub load_note: Option<String>,
    /// The sweep's trace registry: disabled (and empty) unless
    /// [`SweepConfig::trace`] was set. The caller renders/exports it
    /// ([`crate::obs::jsonl`], [`crate::obs::chrome`],
    /// [`crate::obs::TraceSummary`]).
    pub trace: Arc<Registry>,
}

impl SweepReport {
    /// Store-wide hit rate for this sweep's lookups.
    pub fn hit_rate(&self) -> f64 {
        self.totals.hit_rate()
    }
}

/// The grid in deterministic model → task → format order. Every cell
/// starts with the *requested* `cfg.qat_steps`; callers that gate QAT on
/// per-model capability (like [`run_sweep`]) must downgrade
/// `SweepItem::qat_steps` BEFORE handing items to [`sweep_with`], so the
/// cache scope matches the objective actually evaluated.
pub fn grid(cfg: &SweepConfig) -> Vec<SweepItem> {
    let mut items = Vec::new();
    for model in &cfg.models {
        for &task in &cfg.tasks {
            for &fmt in &cfg.fmts {
                items.push(SweepItem {
                    model: model.clone(),
                    task,
                    fmt,
                    qat_steps: cfg.qat_steps,
                    pretrain_steps: cfg.pretrain_steps,
                    weights_hash: None,
                });
            }
        }
    }
    items
}

/// The scope string for one cell under this sweep's hyperparameters.
/// Uses the cell's *effective* `qat_steps`, not the requested one.
pub fn cell_scope(cfg: &SweepConfig, item: &SweepItem) -> String {
    eval_scope(
        &item.model,
        item.task,
        item.fmt,
        item.qat_steps,
        cfg.qat_lr,
        cfg.eval_batches,
        item.pretrain_steps,
        if cfg.hw_aware { "hw" } else { "sw" },
        cfg.backend,
        item.weights_hash,
    )
}

/// Generic sweep core: run `run_one` for every cell of `items` against
/// that cell's scoped cache from `store`, account per-cell and total
/// cache activity, and flush the store once at the end (atomic; no-op
/// for in-memory stores). A cell failure aborts the sweep *after*
/// flushing what completed, so paid evaluations are never lost.
///
/// `trace` receives one `sweep/cell` span per cell (tagged
/// model/task/fmt) plus that cell's cache-counter delta — the grid loop
/// is single-threaded, so the event stream is deterministic regardless
/// of how many worker threads each cell's search uses. Pass
/// `Arc::new(Registry::disabled())` for an untraced sweep.
pub fn sweep_with<F>(
    cfg: &SweepConfig,
    store: &CacheStore,
    items: Vec<SweepItem>,
    trace: Arc<Registry>,
    mut run_one: F,
) -> Result<SweepReport>
where
    F: FnMut(&SweepItem, &EvalCache) -> Result<SweepCell>,
{
    let mut rows = Vec::new();
    let mut failure: Option<anyhow::Error> = None;
    for item in items {
        let cache = store.cache(&cell_scope(cfg, &item));
        let before = cache.stats();
        let span = trace
            .span("sweep/cell")
            .tag("model", item.model.as_str())
            .tag("task", item.task.name())
            .tag("fmt", item.fmt.name());
        let out = run_one(&item, &cache);
        drop(span);
        match out {
            Ok(cell) => {
                let delta = cache.stats().delta(&before);
                delta.record_to(&trace, "sweep/cell");
                rows.push(SweepRow { item, cell, cache: delta });
            }
            Err(e) => {
                failure = Some(e.context(format!(
                    "sweep cell {}/{}/{}",
                    item.model,
                    item.task.name(),
                    item.fmt.name()
                )));
                break;
            }
        }
    }
    store.save()?;
    if let Some(e) = failure {
        return Err(e);
    }
    Ok(SweepReport {
        rows,
        totals: store.stats(),
        loaded_entries: store.loaded_entries(),
        saved_entries: store.total_entries(),
        load_note: store.load_note().map(str::to_string),
        trace,
    })
}

/// Run the full sweep against the real pipeline. Weights are pulled from
/// the pretrain cache (trained on first use), so repeated sweeps pay at
/// most the search evaluations — and with a warm `cache_path`, none.
/// Dispatches on [`SweepConfig::backend`].
pub fn run_sweep(session: &Session, cfg: &SweepConfig) -> Result<SweepReport> {
    match cfg.backend {
        BackendKind::Pjrt => {
            anyhow::ensure!(
                cfg.weights_artifact.is_none(),
                "--weights is a packed-CPU-backend feature: the PJRT backend feeds raw f32 \
                 weights to the device and cannot serve a .mxa artifact (use --backend cpu)"
            );
            run_sweep_with(session, cfg, session.pjrt_backend()?)
        }
        BackendKind::Cpu => run_sweep_with(
            session,
            cfg,
            super::flow::cpu_backend_for(cfg.weights_artifact.as_deref())?,
        ),
    }
}

/// The backend-generic sweep driver over [`sweep_with`].
fn run_sweep_with<B: ExecBackend + Clone>(
    session: &Session,
    cfg: &SweepConfig,
    backend: B,
) -> Result<SweepReport> {
    let store = match &cfg.cache_path {
        Some(p) => CacheStore::open(p),
        None => CacheStore::in_memory(),
    };
    let trace =
        Arc::new(if cfg.trace { Registry::new() } else { Registry::disabled() });
    // Resolve each cell's EFFECTIVE QAT budget up front (the paper's
    // QAT-small / PTQ-large split: only models the backend can fine-tune
    // — i.e. shipping the matching `qat_<fmt>` artifact under PJRT;
    // never, under the gradient-free CPU interpreter). This must happen
    // before `sweep_with` computes cache scopes — a PTQ-evaluated cell
    // stored under a `qatN` scope would poison later QAT-capable runs.
    let mut items = grid(cfg);
    for item in &mut items {
        // Stamp the serving artifact's content hash into every cell's
        // scope (None without --weights; see SweepItem::weights_hash).
        item.weights_hash = backend.weights_hash();
        // A runtime-less session with no valid cached weights evaluates
        // the untrained init_params model: record an effective pretrain
        // budget of 0 so the cell's scope never aliases trained runs
        // (same predicate `pretrain` itself decides by).
        if let Ok(meta) = session.manifest.model(&item.model) {
            let task = if meta.kind == "lm" { None } else { Some(item.task) };
            if !have_trained_weights(session, meta, task) {
                item.pretrain_steps = 0;
            }
        }
        if item.qat_steps > 0 {
            let has_qat = session
                .manifest
                .model(&item.model)
                .ok()
                .map(|m| backend.qat_available(m, item.fmt).is_ok())
                .unwrap_or(false);
            if !has_qat {
                item.qat_steps = 0;
            }
        }
    }
    let tr = trace.clone();
    sweep_with(cfg, &store, items, trace, move |item, cache| {
        let meta = session.manifest.model(&item.model)?.clone();
        let w = pretrain(
            session,
            &meta,
            if meta.kind == "lm" { None } else { Some(item.task) },
            &PretrainConfig { steps: cfg.pretrain_steps, log_every: 0, ..Default::default() },
        )?;
        let eval = batches(item.task, 1, cfg.eval_batches, meta.batch, meta.seq_len);
        let mut ev = Evaluator::new(backend.clone(), &meta, &w, &eval)?;
        ev.objective = if cfg.hw_aware { Objective::default() } else { Objective::sw_only() };
        let profile = profile_model(&ev.backend, &meta, &w, &eval[..1])?;

        let scfg = SearchConfig {
            algorithm: cfg.algorithm,
            trials: cfg.trials,
            fmt: item.fmt,
            seed: cfg.seed,
            qat_steps: item.qat_steps,
            qat_lr: cfg.qat_lr,
            batch: cfg.batch.max(1),
            threads: cfg.threads,
            tpe_mean_lie: cfg.tpe_mean_lie,
            ..Default::default()
        };
        let outcome = run_search_traced(&ev, &profile, item.task, &scfg, cache, &tr)?;
        Ok(SweepCell {
            value: outcome.best_eval.value,
            accuracy: outcome.best_eval.accuracy,
            avg_bits: outcome.best_eval.avg_bits,
            mode: if item.qat_steps > 0 { "QAT".to_string() } else { "PTQ".to_string() },
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_deterministic_and_complete() {
        let cfg = SweepConfig {
            models: vec!["a".into(), "b".into()],
            tasks: vec![Task::Sst2, Task::Qqp],
            fmts: vec![FormatKind::MxInt],
            ..Default::default()
        };
        let g = grid(&cfg);
        assert_eq!(g.len(), 4);
        assert_eq!((g[0].model.as_str(), g[0].task), ("a", Task::Sst2));
        assert_eq!((g[3].model.as_str(), g[3].task), ("b", Task::Qqp));
        assert!(g.iter().all(|i| i.qat_steps == cfg.qat_steps));
    }

    #[test]
    fn cells_share_scope_only_with_identical_context() {
        let cfg = SweepConfig::default();
        let a = SweepItem {
            model: "m".into(),
            task: Task::Sst2,
            fmt: FormatKind::MxInt,
            qat_steps: 0,
            pretrain_steps: cfg.pretrain_steps,
            weights_hash: None,
        };
        let b = SweepItem { fmt: FormatKind::Int, ..a.clone() };
        assert_ne!(cell_scope(&cfg, &a), cell_scope(&cfg, &b));
        assert_eq!(cell_scope(&cfg, &a), cell_scope(&cfg, &a.clone()));
        let sw = SweepConfig { hw_aware: false, ..SweepConfig::default() };
        assert_ne!(cell_scope(&cfg, &a), cell_scope(&sw, &a));
        // the execution backend is part of the scope: a CPU-interpreter
        // sweep never reads (or pollutes) PJRT-measured entries
        let cpu = SweepConfig { backend: BackendKind::Cpu, ..SweepConfig::default() };
        assert_ne!(cell_scope(&cfg, &a), cell_scope(&cpu, &a));
        // the scope tracks the cell's EFFECTIVE qat budget, not the
        // sweep-wide request: a PTQ-downgraded cell must not alias a
        // QAT-evaluated one
        let qat = SweepItem { qat_steps: 2, ..a.clone() };
        assert_ne!(cell_scope(&cfg, &a), cell_scope(&cfg, &qat));
        // likewise the EFFECTIVE pretrain budget: an untrained
        // (init_params) cell must not alias a pretrained one
        let untrained = SweepItem { pretrain_steps: 0, ..a.clone() };
        assert_ne!(cell_scope(&cfg, &a), cell_scope(&cfg, &untrained));
        // and the serving artifact: a .mxa-backed cell only shares
        // entries with cells served by the same container bytes
        let mxa = SweepItem { weights_hash: Some(0xFEED), ..a.clone() };
        assert_ne!(cell_scope(&cfg, &a), cell_scope(&cfg, &mxa));
        let other = SweepItem { weights_hash: Some(0xFEEE), ..a.clone() };
        assert_ne!(cell_scope(&cfg, &mxa), cell_scope(&cfg, &other));
    }

    #[test]
    fn sweep_with_accounts_per_cell_and_flushes_nothing_in_memory() {
        let cfg = SweepConfig {
            models: vec!["toy".into()],
            tasks: vec![Task::Sst2, Task::Qqp],
            fmts: vec![FormatKind::MxInt],
            ..Default::default()
        };
        let store = CacheStore::in_memory();
        let trace = Arc::new(Registry::disabled());
        let report = sweep_with(&cfg, &store, grid(&cfg), trace, |item, cache| {
            // two lookups per cell: one miss+insert, one hit
            let key = vec![7u64];
            assert!(cache.get(&key).is_none());
            cache.insert(key.clone(), (1.0, vec![]));
            assert!(cache.get(&key).is_some());
            Ok(SweepCell {
                value: 1.0,
                accuracy: 0.9,
                avg_bits: 4.0,
                mode: item.task.name().to_string(),
            })
        })
        .unwrap();
        assert_eq!(report.rows.len(), 2);
        for row in &report.rows {
            assert_eq!((row.cache.hits, row.cache.misses, row.cache.inserts), (1, 1, 1));
            assert_eq!(row.cache.hit_rate(), 0.5);
        }
        assert_eq!(report.totals.entries, 2);
        assert_eq!(report.loaded_entries, 0);
        assert!(report.load_note.is_none());
    }

    #[test]
    fn traced_sweep_records_cell_spans_and_cache_deltas() {
        let cfg = SweepConfig {
            models: vec!["toy".into()],
            tasks: vec![Task::Sst2, Task::Qqp],
            fmts: vec![FormatKind::MxInt],
            trace: true,
            ..Default::default()
        };
        let store = CacheStore::in_memory();
        let report =
            sweep_with(&cfg, &store, grid(&cfg), Arc::new(Registry::new()), |_, cache| {
                // one miss+insert, one hit per cell
                let key = vec![1u64];
                assert!(cache.get(&key).is_none());
                cache.insert(key.clone(), (1.0, vec![]));
                assert!(cache.get(&key).is_some());
                Ok(SweepCell {
                    value: 0.0,
                    accuracy: 0.0,
                    avg_bits: 4.0,
                    mode: "PTQ".into(),
                })
            })
            .unwrap();
        let reg = &report.trace;
        let spans: Vec<_> = reg
            .sorted_events()
            .into_iter()
            .filter(|e| matches!(e.kind, crate::obs::EventKind::Span { .. }))
            .collect();
        assert_eq!(spans.len(), 2, "one span per grid cell");
        assert!(spans.iter().all(|e| e.path == "sweep/cell"));
        match &spans[0].kind {
            crate::obs::EventKind::Span { tags } => {
                assert_eq!(tags[0], ("model".to_string(), "toy".to_string()));
                assert_eq!(tags[1].0, "task");
                assert_eq!(tags[2].0, "fmt");
            }
            _ => unreachable!(),
        }
        // per-cell deltas folded into the registry: 1 hit/miss/insert × 2
        assert_eq!(reg.counter_total("sweep/cell", "cache_hits"), 2);
        assert_eq!(reg.counter_total("sweep/cell", "cache_misses"), 2);
        assert_eq!(reg.counter_total("sweep/cell", "cache_inserts"), 2);
    }

    #[test]
    fn sweep_failure_reports_cell_context() {
        let cfg = SweepConfig {
            models: vec!["toy".into()],
            tasks: vec![Task::Sst2],
            fmts: vec![FormatKind::Int],
            ..Default::default()
        };
        let store = CacheStore::in_memory();
        let trace = Arc::new(Registry::disabled());
        let err = sweep_with(&cfg, &store, grid(&cfg), trace, |_, _| -> Result<SweepCell> {
            Err(anyhow::anyhow!("boom"))
        })
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("toy/sst2/int"), "{msg}");
    }
}
