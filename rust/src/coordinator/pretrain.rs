//! Pretraining driver: the Rust coordinator trains every model-zoo
//! simulant from scratch by driving the AOT-lowered `train` artifact
//! (fwd+bwd+SGD fused in HLO) over the synthetic task streams — no Python
//! anywhere. Weights are cached under `artifacts/weights/` so benches and
//! examples reuse them.

use super::Session;
use crate::data::{MarkovCorpus, Task};
use crate::frontend::ModelMeta;
use crate::runtime::TensorData;
use anyhow::{Context, Result};
use std::path::PathBuf;

#[derive(Debug, Clone)]
pub struct PretrainConfig {
    pub steps: usize,
    pub lr: f32,
    /// cosine-ish decay to this fraction of lr
    pub final_lr_frac: f32,
    pub log_every: usize,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        Self { steps: 220, lr: 0.02, final_lr_frac: 0.1, log_every: 100 }
    }
}

/// Cache path for (model, task) weights. LMs use task name "lm".
pub fn weights_path(session: &Session, model: &str, task_name: &str) -> PathBuf {
    session.dir.join("weights").join(format!("{model}__{task_name}.bin"))
}

/// Will [`pretrain`] return *trained* weights for (model, task) — loaded
/// from a valid cache file or trainable via the PJRT runtime — rather
/// than the untrained `init_params` fallback of a runtime-less session?
/// Uses the same `load_weights` validation as [`pretrain`] itself (a
/// stale or truncated file counts as absent), so cache scopes keyed on
/// this predicate always match the weights actually evaluated.
pub fn have_trained_weights(session: &Session, meta: &ModelMeta, task: Option<Task>) -> bool {
    if session.runtime.is_some() {
        return true;
    }
    let task_name = task.map(|t| t.name()).unwrap_or("lm");
    load_weights(&weights_path(session, &meta.name, task_name), meta.param_size).is_ok()
}

fn save_weights(path: &PathBuf, w: &[f32]) -> Result<()> {
    std::fs::create_dir_all(path.parent().unwrap())?;
    let bytes: Vec<u8> = w.iter().flat_map(|v| v.to_le_bytes()).collect();
    std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
}

fn load_weights(path: &PathBuf, expect: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    anyhow::ensure!(bytes.len() == expect * 4, "weight file size mismatch");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Train (or load cached) weights for one (model, task).
/// For LM models pass `task = None` (trains on the Markov corpus).
///
/// Training needs the PJRT `train` artifact. On a CPU-backend session
/// (no runtime) cached weights are still used when present — e.g. synced
/// from an artifact host — but otherwise the deterministic
/// `frontend::init_params` initialization is returned: the packed
/// interpreter then evaluates the untrained model, which keeps the whole
/// search→evaluate loop runnable (and quantization-sensitive) on a bare
/// host. Callers that cache objectives must record the *effective*
/// pretrain budget — 0 on the init-params fallback — in their
/// `eval_scope` (flow and sweep both do, via [`have_trained_weights`]),
/// so untrained scores never alias trained ones.
pub fn pretrain(
    session: &Session,
    meta: &ModelMeta,
    task: Option<Task>,
    cfg: &PretrainConfig,
) -> Result<Vec<f32>> {
    let task_name = task.map(|t| t.name()).unwrap_or("lm");
    let path = weights_path(session, &meta.name, task_name);
    if let Ok(w) = load_weights(&path, meta.param_size) {
        return Ok(w);
    }
    let Some(runtime) = session.runtime.as_ref() else {
        return Ok(crate::frontend::init_params(meta, 0xC0DE));
    };

    let artifact = meta.artifact("train")?;
    let mut w = crate::frontend::init_params(meta, 0xC0DE);
    let corpus = MarkovCorpus::new(7);
    let mut last_loss = f32::NAN;
    for step in 0..cfg.steps {
        let (tokens, labels) = match task {
            Some(t) => {
                // fresh train-split batch per step (deterministic stream)
                let mut bt = crate::data::Batch::new(meta.batch, meta.seq_len);
                for i in 0..meta.batch {
                    bt.push(t.sample(0, (step * meta.batch + i) as u64, meta.seq_len));
                }
                (bt.tokens, bt.labels)
            }
            None => {
                let toks = corpus.batch(step as u64, meta.batch, meta.seq_len);
                (toks, vec![0i32; meta.batch])
            }
        };
        // linear decay
        let frac = step as f32 / cfg.steps.max(1) as f32;
        let lr = cfg.lr * (1.0 - frac * (1.0 - cfg.final_lr_frac));
        let out = runtime.execute(
            artifact,
            &[
                TensorData::f32(&w, &[meta.param_size as i64]),
                TensorData::i32(&tokens, &[meta.batch as i64, meta.seq_len as i64]),
                TensorData::i32(&labels, &[meta.batch as i64]),
                TensorData::scalar_f32(lr),
            ],
        )?;
        w = out[0].to_vec_f32()?;
        last_loss = out[1].scalar_f32()?;
        if cfg.log_every > 0 && (step + 1) % cfg.log_every == 0 {
            eprintln!("  [{}/{task_name}] step {} loss {:.4}", meta.name, step + 1, last_loss);
        }
    }
    anyhow::ensure!(last_loss.is_finite(), "pretraining diverged (loss={last_loss})");
    save_weights(&path, &w)?;
    Ok(w)
}

/// The (model, task) pairs the experiments need: all 10 classifiers on
/// sst2, the 5 OPT sizes on all six tasks (Fig. 6), the LM on the corpus.
pub fn pretrain_units(session: &Session) -> Vec<(String, Option<Task>)> {
    let mut units = Vec::new();
    for (name, meta) in &session.manifest.models {
        if meta.kind == "lm" {
            units.push((name.clone(), None));
        } else {
            let tasks: Vec<Task> = if name.starts_with("opt-") {
                Task::ALL.to_vec()
            } else {
                vec![Task::Sst2]
            };
            for t in tasks {
                units.push((name.clone(), Some(t)));
            }
        }
    }
    units
}

/// Pretrain everything, fanned over worker threads. `PjRtClient` is not
/// `Send` (Rc internally), so each worker opens its own `Session`/client;
/// grouping by model amortizes the per-worker artifact compilation.
pub fn pretrain_all(session: &Session, cfg: &PretrainConfig) -> Result<()> {
    // group units by model so each worker compiles each train artifact once
    let mut by_model: std::collections::BTreeMap<String, Vec<Option<Task>>> = Default::default();
    for (m, t) in pretrain_units(session) {
        by_model.entry(m).or_default().push(t);
    }
    let dir = session.dir.clone();
    let cfg = cfg.clone();
    let jobs: Vec<(String, Vec<Option<Task>>)> = by_model.into_iter().collect();
    let threads = crate::util::pool::default_threads().min(jobs.len());
    let results = crate::util::pool::par_map(jobs, threads, |(name, tasks)| -> Result<()> {
        let local = Session::open(&dir)?;
        let meta = local.manifest.model(&name)?.clone();
        for t in tasks {
            eprintln!("pretraining {name} ({})...", t.map(|t| t.name()).unwrap_or("lm"));
            // the LM's next-token objective converges slower than the
            // classification tasks: give it 2x the steps
            let mut unit_cfg = cfg.clone();
            if t.is_none() {
                unit_cfg.steps = cfg.steps * 2;
            }
            pretrain(&local, &meta, t, &unit_cfg)?;
        }
        Ok(())
    });
    for r in results {
        r?;
    }
    Ok(())
}
