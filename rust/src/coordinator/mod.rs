//! The end-to-end coordinator: process lifecycle, pretraining driver,
//! and the full MASE flow (front-end -> profile -> search -> emit). This
//! is the L3 "leader" the CLI and the examples call into.

pub mod flow;
pub mod pretrain;
pub mod sweep;

pub use flow::{run_flow, FlowConfig, FlowReport};
pub use pretrain::{pretrain, weights_path, PretrainConfig};
pub use sweep::{run_sweep, SweepConfig, SweepReport};

use crate::frontend::Manifest;
use crate::runtime::Runtime;
use anyhow::Result;
use std::path::{Path, PathBuf};

/// Shared session state: manifest + runtime + artifact directory.
pub struct Session {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub runtime: Runtime,
}

impl Session {
    /// Open the artifacts directory (default: `<repo>/artifacts`).
    pub fn open(dir: &Path) -> Result<Session> {
        let manifest = Manifest::load(dir)?;
        let runtime = Runtime::new(dir)?;
        Ok(Session { dir: dir.to_path_buf(), manifest, runtime })
    }

    pub fn default_dir() -> PathBuf {
        std::env::var("MASE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }
}
