//! The end-to-end coordinator: process lifecycle, pretraining driver,
//! and the full MASE flow (front-end -> profile -> search -> emit). This
//! is the L3 "leader" the CLI and the examples call into.

pub mod flow;
pub mod pretrain;
pub mod sweep;

pub use flow::{cpu_backend_for, run_flow, FlowConfig, FlowReport};
pub use pretrain::{pretrain, weights_path, PretrainConfig};
pub use sweep::{run_sweep, SweepConfig, SweepReport};

use crate::frontend::Manifest;
use crate::runtime::{BackendKind, PjrtBackend, Runtime};
use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};

/// Shared session state: manifest + (optional) PJRT runtime + artifact
/// directory. PJRT sessions require the AOT artifacts; CPU-backend
/// sessions fall back to the synthetic model-zoo manifest and never
/// construct a PJRT client, so the full flow runs on a bare host.
pub struct Session {
    pub dir: PathBuf,
    pub manifest: Manifest,
    /// Present for [`BackendKind::Pjrt`] sessions only.
    pub runtime: Option<Runtime>,
}

impl Session {
    /// Open the artifacts directory for the PJRT backend (default:
    /// `<repo>/artifacts`). Requires `manifest.json` + HLO artifacts.
    pub fn open(dir: &Path) -> Result<Session> {
        Self::open_for(dir, BackendKind::Pjrt)
    }

    /// Open a session for the given execution backend.
    pub fn open_for(dir: &Path, backend: BackendKind) -> Result<Session> {
        match backend {
            BackendKind::Pjrt => {
                let manifest = Manifest::load(dir)?;
                let runtime = Runtime::new(dir)?;
                Ok(Session { dir: dir.to_path_buf(), manifest, runtime: Some(runtime) })
            }
            BackendKind::Cpu => {
                // Artifact-free: use the real manifest when it exists (so
                // cached pretrained weights keep matching their layouts),
                // else the synthetic zoo mirrored from python MODEL_ZOO.
                // Only an ABSENT manifest falls back — a present-but-
                // unparsable one is real breakage and must surface, not
                // silently swap in differently-shaped models whose
                // objectives would share cache scopes with the real ones.
                let manifest = if dir.join("manifest.json").exists() {
                    Manifest::load(dir)?
                } else {
                    Manifest::synthetic()
                };
                Ok(Session { dir: dir.to_path_buf(), manifest, runtime: None })
            }
        }
    }

    /// The PJRT runtime, or a clean error for CPU-backend sessions.
    pub fn pjrt(&self) -> Result<&Runtime> {
        self.runtime
            .as_ref()
            .ok_or_else(|| anyhow!("this session has no PJRT runtime (opened with --backend cpu)"))
    }

    /// The PJRT execution backend over this session's runtime.
    pub fn pjrt_backend(&self) -> Result<PjrtBackend<'_>> {
        Ok(PjrtBackend::new(self.pjrt()?))
    }

    pub fn default_dir() -> PathBuf {
        std::env::var("MASE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }
}
