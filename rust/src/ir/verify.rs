//! MASE IR verifier: SSA discipline, graph well-formedness, and the
//! paper's format rules (unified block shape divisibility, single
//! arithmetic type per design — §4).

use super::graph::Graph;
use crate::formats::{FormatKind, BLOCK_SHAPE};

#[derive(Debug, PartialEq)]
pub enum VerifyError {
    Orphan(String),
    Reassigned(String),
    BadValueId(String),
    BadBlockShape(String, Vec<usize>, (usize, usize)),
    MixedArithmetic(&'static str, &'static str),
    NoOutputs,
    Cycle,
}

impl std::fmt::Display for VerifyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            VerifyError::Orphan(v) => {
                write!(f, "value %{v} has no producer and is not an input/param")
            }
            VerifyError::Reassigned(v) => {
                write!(f, "value %{v} produced more than once (SSA violation)")
            }
            VerifyError::BadValueId(op) => {
                write!(f, "op {op} references out-of-range value id")
            }
            VerifyError::BadBlockShape(v, shape, block) => {
                write!(f, "block format tensor %{v} has shape {shape:?} not tiling into {block:?} blocks")
            }
            VerifyError::MixedArithmetic(a, b) => {
                write!(f, "mixed arithmetic types in one design: {a} and {b} (paper §4 forbids)")
            }
            VerifyError::NoOutputs => write!(f, "graph has no outputs"),
            VerifyError::Cycle => write!(f, "cycle detected in dataflow graph"),
        }
    }
}

impl std::error::Error for VerifyError {}

/// Verify the graph; returns all findings (empty = valid).
pub fn verify(g: &Graph) -> Vec<VerifyError> {
    let mut errors = Vec::new();

    if g.outputs.is_empty() {
        errors.push(VerifyError::NoOutputs);
    }

    // SSA: every value produced at most once; producer back-links correct.
    let mut produced = vec![0usize; g.values.len()];
    for op in &g.ops {
        for &r in &op.results {
            if r.0 >= g.values.len() {
                errors.push(VerifyError::BadValueId(format!("{:?}", op.id)));
                continue;
            }
            produced[r.0] += 1;
        }
        for &a in op.args.iter().chain(op.params.iter()) {
            if a.0 >= g.values.len() {
                errors.push(VerifyError::BadValueId(format!("{:?}", op.id)));
            }
        }
    }
    for v in &g.values {
        match produced[v.id.0] {
            0 => {
                // weight/param values are defined without a producing op
                let is_param = g.ops.iter().any(|o| o.params.contains(&v.id));
                if !is_param {
                    errors.push(VerifyError::Orphan(v.name.clone()));
                }
            }
            1 => {}
            _ => errors.push(VerifyError::Reassigned(v.name.clone())),
        }
    }

    // Block-format tensors must tile into the unified block shape (§4.1).
    for v in &g.values {
        if v.ty.format.is_block_format() && !v.ty.shape.is_empty() {
            let ok = if v.ty.shape.len() == 1 {
                v.ty.shape[0] % (BLOCK_SHAPE.0 * BLOCK_SHAPE.1) == 0
            } else {
                let r = v.ty.shape[v.ty.shape.len() - 2];
                let c = v.ty.shape[v.ty.shape.len() - 1];
                r % BLOCK_SHAPE.0 == 0 && c % BLOCK_SHAPE.1 == 0
            };
            if !ok {
                errors.push(VerifyError::BadBlockShape(v.name.clone(), v.ty.shape.clone(), BLOCK_SHAPE));
            }
        }
    }

    // Single arithmetic type across the design (fp32 edges are allowed:
    // non-quantized interconnect like residuals/softmax).
    let mut block_fmt: Option<FormatKind> = None;
    for v in &g.values {
        let f = v.ty.format;
        if f == FormatKind::Fp32 {
            continue;
        }
        match block_fmt {
            None => block_fmt = Some(f),
            Some(prev) if prev != f => {
                errors.push(VerifyError::MixedArithmetic(prev.name(), f.name()));
                break;
            }
            _ => {}
        }
    }

    // Acyclicity via topo order length.
    if g.topo_order().len() != g.ops.len() {
        errors.push(VerifyError::Cycle);
    }

    errors
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Precision;
    use crate::ir::{OpKind, TensorType};

    fn quantized_ty(fmt: FormatKind, shape: Vec<usize>) -> TensorType {
        TensorType { shape, format: fmt, precision: Precision::new(5.0, 0.0) }
    }

    fn valid_graph() -> Graph {
        let mut g = Graph::new("ok");
        let x = g.add_input("x", TensorType::fp32(vec![32, 64]));
        let w = g.new_value("w", quantized_ty(FormatKind::MxInt, vec![64, 64]), Some(1));
        let y = g.add_op(
            OpKind::Linear,
            vec![x],
            vec![w],
            "y",
            quantized_ty(FormatKind::MxInt, vec![32, 64]),
            Some(0),
        );
        g.outputs.push(y);
        g
    }

    #[test]
    fn valid_graph_passes() {
        assert!(verify(&valid_graph()).is_empty());
    }

    #[test]
    fn detects_orphan_value() {
        let mut g = valid_graph();
        g.new_value("dangling", TensorType::fp32(vec![4]), None);
        assert!(verify(&g).iter().any(|e| matches!(e, VerifyError::Orphan(n) if n == "dangling")));
    }

    #[test]
    fn detects_bad_block_shape() {
        let mut g = valid_graph();
        let bad = g.new_value("bad", quantized_ty(FormatKind::MxInt, vec![15, 3]), None);
        let z = g.add_op(OpKind::Gelu, vec![g.inputs[0]], vec![bad], "z", TensorType::fp32(vec![32, 64]), None);
        g.outputs.push(z);
        assert!(verify(&g).iter().any(|e| matches!(e, VerifyError::BadBlockShape(..))));
    }

    #[test]
    fn detects_mixed_arithmetic() {
        let mut g = valid_graph();
        let w2 = g.new_value("w2", quantized_ty(FormatKind::Bl, vec![64, 64]), None);
        let y2 = g.add_op(
            OpKind::Linear,
            vec![g.inputs[0]],
            vec![w2],
            "y2",
            TensorType::fp32(vec![32, 64]),
            None,
        );
        g.outputs.push(y2);
        assert!(verify(&g).iter().any(|e| matches!(e, VerifyError::MixedArithmetic(..))));
    }

    #[test]
    fn fp32_edges_do_not_count_as_mixed() {
        let g = valid_graph(); // fp32 input + mxint weight/result
        assert!(verify(&g).iter().all(|e| !matches!(e, VerifyError::MixedArithmetic(..))));
    }

    #[test]
    fn detects_missing_outputs() {
        let mut g = valid_graph();
        g.outputs.clear();
        assert!(verify(&g).contains(&VerifyError::NoOutputs));
    }
}
