//! MASE IR — the paper's co-design intermediate representation (§3).
//!
//! An SSA dataflow graph of *module-level* operations (linear, attention,
//! layernorm, ...), where every operation and every value carries both
//! software attributes (shape, format, precision) and hardware attributes
//! (IP block, streaming tile shape, streaming order, estimated area and
//! throughput) — Fig. 2. Module-level granularity is what gives the
//! Table 3 scalability: a 6-layer model is ~100 ops, not ~2M affine
//! instructions.
//!
//! The IR stays "trainable" by construction: it never lowers the model's
//! compute — the numerical forward/backward lives in the AOT-compiled HLO
//! artifacts keyed by the same qtensor names the IR carries, so QAT can
//! run at any point of the hardware exploration loop (paper §3, Fig. 6).

pub mod graph;
pub mod parser;
pub mod printer;
pub mod verify;

pub use graph::{Graph, OpAttrs, OpId, OpKind, Operation, StreamOrder, Value, ValueAttrs, ValueId};
pub use printer::print_graph;
pub use verify::{verify, VerifyError};

use crate::formats::{FormatKind, Precision};

/// Tensor type: shape + numeric format + precision (paper Fig. 2b types
/// like `MXint((16,2), 8, 7)` — block shape and shared-exponent width are
/// global constants in this work, §4.1).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorType {
    pub shape: Vec<usize>,
    pub format: FormatKind,
    pub precision: Precision,
}

impl TensorType {
    pub fn fp32(shape: Vec<usize>) -> Self {
        Self { shape, format: FormatKind::Fp32, precision: Precision::new(32.0, 0.0) }
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// Memory footprint in bits under this type's format (Eq. 1).
    pub fn bits(&self) -> f64 {
        self.elements() as f64 * self.precision.average_bitwidth(self.format)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_type_bits_uses_average_bitwidth() {
        let t = TensorType {
            shape: vec![16, 2],
            format: FormatKind::MxInt,
            precision: Precision::new(7.0, 0.0),
        };
        assert!((t.bits() - 32.0 * 8.25).abs() < 1e-9);
    }

    #[test]
    fn fp32_constructor() {
        let t = TensorType::fp32(vec![4, 8]);
        assert_eq!(t.elements(), 32);
        assert_eq!(t.bits(), 32.0 * 32.0);
    }
}
