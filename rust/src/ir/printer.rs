//! Textual form of MASE IR, following the paper's §3 syntax:
//!
//! ```text
//! %h: f32[32x64] = linear(%x: f32[32x64]) [%w0: mxint(5)[64x64]]
//!     {q=0, tile=16x2, order=row, ip="mxint_linear", area=1234.0}
//! ```

use super::graph::{Graph, Operation, StreamOrder};
use super::TensorType;
use crate::formats::FormatKind;

pub fn type_str(t: &TensorType) -> String {
    let dims = t.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x");
    match t.format {
        FormatKind::Fp32 => format!("f32[{dims}]"),
        FormatKind::Fp8 => format!("fp8[{dims}]"),
        FormatKind::Int => format!("int({},{})[{dims}]", t.precision.bits, t.precision.frac),
        FormatKind::MxInt => format!("mxint({})[{dims}]", t.precision.bits),
        FormatKind::Bmf => format!("bmf({})[{dims}]", t.precision.bits),
        FormatKind::Bl => format!("bl({})[{dims}]", t.precision.bits),
    }
}

fn operand(g: &Graph, id: super::ValueId) -> String {
    let v = g.value(id);
    format!("%{}: {}", v.name, type_str(&v.ty))
}

fn op_line(g: &Graph, op: &Operation) -> String {
    let results = op
        .results
        .iter()
        .map(|&r| operand(g, r))
        .collect::<Vec<_>>()
        .join(", ");
    let args = op.args.iter().map(|&a| format!("%{}", g.value(a).name)).collect::<Vec<_>>().join(", ");
    let mut line = format!("{results} = {}({args})", op.kind.name());
    if !op.params.is_empty() {
        let params = op
            .params
            .iter()
            .map(|&p| operand(g, p))
            .collect::<Vec<_>>()
            .join(", ");
        line.push_str(&format!(" [{params}]"));
    }
    // attributes: software (qtensor index) + hardware (tile/order/ip/area)
    let mut attrs: Vec<String> = Vec::new();
    for &r in &op.results {
        let v = g.value(r);
        if let Some(q) = v.qtensor {
            attrs.push(format!("q={q}"));
        }
        if v.attrs.tile != (1, 1) {
            attrs.push(format!("tile={}x{}", v.attrs.tile.0, v.attrs.tile.1));
        }
        if v.attrs.order != StreamOrder::RowMajor {
            attrs.push(format!("order={}", v.attrs.order.name()));
        }
        if v.attrs.throughput > 0.0 {
            attrs.push(format!("thr={:.3}", v.attrs.throughput));
        }
    }
    if !op.attrs.hw_ip.is_empty() {
        attrs.push(format!("ip=\"{}\"", op.attrs.hw_ip));
    }
    if op.attrs.area_luts > 0.0 {
        attrs.push(format!("area={:.1}", op.attrs.area_luts));
    }
    if op.attrs.ii_cycles > 0.0 {
        attrs.push(format!("ii={:.2}", op.attrs.ii_cycles));
    }
    if !attrs.is_empty() {
        line.push_str(&format!(" {{{}}}", attrs.join(", ")));
    }
    line
}

/// Print the whole module.
pub fn print_graph(g: &Graph) -> String {
    let mut out = format!("module @{} {{\n", g.name);
    for op in &g.ops {
        out.push_str("  ");
        out.push_str(&op_line(g, op));
        out.push('\n');
    }
    let outs = g.outputs.iter().map(|&o| format!("%{}", g.value(o).name)).collect::<Vec<_>>().join(", ");
    out.push_str(&format!("  return {outs}\n}}\n"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Precision;
    use crate::ir::graph::OpKind;

    #[test]
    fn prints_paper_like_syntax() {
        let mut g = Graph::new("toy");
        let x = g.add_input("x", TensorType::fp32(vec![32, 64]));
        let w = g.new_value(
            "w0",
            TensorType { shape: vec![64, 64], format: FormatKind::MxInt, precision: Precision::new(5.0, 0.0) },
            Some(1),
        );
        let h = g.add_op(OpKind::Linear, vec![x], vec![w], "h", TensorType::fp32(vec![32, 64]), Some(0));
        g.outputs.push(h);
        let text = print_graph(&g);
        assert!(text.contains("module @toy {"), "{text}");
        assert!(text.contains("%h: f32[32x64] = linear(%x) [%w0: mxint(5)[64x64]] {q=0}"), "{text}");
        assert!(text.contains("return %h"), "{text}");
    }

    #[test]
    fn type_strings() {
        assert_eq!(type_str(&TensorType::fp32(vec![4])), "f32[4]");
        let t = TensorType {
            shape: vec![16, 2],
            format: FormatKind::Int,
            precision: Precision::new(8.0, 4.0),
        };
        assert_eq!(type_str(&t), "int(8,4)[16x2]");
    }
}
