//! Parser for the textual MASE IR — round-trips `printer::print_graph`.
//! Used by tools and tests; the compiler pipeline itself passes `Graph`s
//! in memory.

use super::graph::{Graph, OpAttrs, OpKind, StreamOrder};
use super::TensorType;
use crate::formats::{FormatKind, Precision};
use std::collections::HashMap;

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "IR parse error (line {}): {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

fn err(line: usize, msg: impl Into<String>) -> ParseError {
    ParseError { line, msg: msg.into() }
}

/// Parse `f32[32x64]`, `mxint(5)[64x64]`, `int(8,4)[16x2]`, ...
pub fn parse_type(s: &str, line: usize) -> Result<TensorType, ParseError> {
    let (head, dims) = s
        .split_once('[')
        .ok_or_else(|| err(line, format!("missing '[' in type '{s}'")))?;
    let dims = dims.strip_suffix(']').ok_or_else(|| err(line, "missing ']'"))?;
    let shape: Vec<usize> = if dims.is_empty() {
        vec![]
    } else {
        dims.split('x')
            .map(|d| d.parse().map_err(|_| err(line, format!("bad dim '{d}'"))))
            .collect::<Result<_, _>>()?
    };
    let (fmt_name, args) = match head.split_once('(') {
        Some((n, rest)) => (n, rest.strip_suffix(')').unwrap_or(rest)),
        None => (head, ""),
    };
    let (format, precision) = match fmt_name {
        "f32" => (FormatKind::Fp32, Precision::new(32.0, 0.0)),
        "fp8" => (FormatKind::Fp8, Precision::new(8.0, 0.0)),
        "int" => {
            let (w, f) = args.split_once(',').ok_or_else(|| err(line, "int needs (w,f)"))?;
            (
                FormatKind::Int,
                Precision::new(
                    w.parse().map_err(|_| err(line, "bad width"))?,
                    f.parse().map_err(|_| err(line, "bad frac"))?,
                ),
            )
        }
        "mxint" | "bmf" | "bl" => {
            let bits: f32 = args.parse().map_err(|_| err(line, "bad bits"))?;
            let fmt = FormatKind::from_name(fmt_name).unwrap();
            (fmt, Precision::new(bits, 0.0))
        }
        other => return Err(err(line, format!("unknown format '{other}'"))),
    };
    Ok(TensorType { shape, format, precision })
}

/// Parse `%name: type` returning (name, type).
fn parse_operand(s: &str, line: usize) -> Result<(String, TensorType), ParseError> {
    let s = s.trim();
    let s = s.strip_prefix('%').ok_or_else(|| err(line, format!("operand must start with %: '{s}'")))?;
    let (name, ty) = s.split_once(':').ok_or_else(|| err(line, "operand missing ':'"))?;
    Ok((name.trim().to_string(), parse_type(ty.trim(), line)?))
}

/// Split a comma-separated list at depth 0 (no nested brackets in operands).
fn split_list(s: &str) -> Vec<&str> {
    s.split(',').map(str::trim).filter(|p| !p.is_empty()).collect()
}

/// Parse a full module printed by `print_graph`.
pub fn parse_graph(text: &str) -> Result<Graph, ParseError> {
    let mut lines = text.lines().enumerate();
    let (ln, first) = lines
        .next()
        .ok_or_else(|| err(0, "empty module"))?;
    let name = first
        .trim()
        .strip_prefix("module @")
        .and_then(|r| r.strip_suffix(" {"))
        .ok_or_else(|| err(ln, "expected 'module @name {'"))?;
    let mut g = Graph::new(name);
    let mut by_name: HashMap<String, super::ValueId> = HashMap::new();

    for (ln, raw) in lines {
        let line = raw.trim();
        if line == "}" || line.is_empty() {
            continue;
        }
        if let Some(rets) = line.strip_prefix("return ") {
            for r in split_list(rets) {
                let n = r.trim_start_matches('%');
                let id = *by_name.get(n).ok_or_else(|| err(ln, format!("unknown return %{n}")))?;
                g.outputs.push(id);
            }
            continue;
        }
        // result(s) = opname(args) [params] {attrs}
        let (lhs, rhs) = line.split_once(" = ").ok_or_else(|| err(ln, "missing ' = '"))?;
        // attrs
        let (rhs, attrs_str) = match rhs.rsplit_once(" {") {
            Some((r, a)) => (r, a.strip_suffix('}').unwrap_or(a)),
            None => (rhs, ""),
        };
        // params
        let (call, params_str) = match rhs.split_once(" [") {
            Some((c, p)) => (c, p.strip_suffix(']').unwrap_or(p)),
            None => (rhs, ""),
        };
        let (op_name, args_str) = call
            .split_once('(')
            .ok_or_else(|| err(ln, "missing '(' in op"))?;
        let args_str = args_str.strip_suffix(')').ok_or_else(|| err(ln, "missing ')'"))?;
        let kind = OpKind::from_name(op_name.trim())
            .ok_or_else(|| err(ln, format!("unknown op '{op_name}'")))?;

        // parse attrs into a map
        let mut amap: HashMap<&str, String> = HashMap::new();
        for kv in split_list(attrs_str) {
            if let Some((k, v)) = kv.split_once('=') {
                amap.insert(k.trim(), v.trim().trim_matches('"').to_string());
            }
        }
        let qtensor: Option<usize> = amap.get("q").and_then(|v| v.parse().ok());

        // arguments reference existing values by bare name
        let mut args = Vec::new();
        for a in split_list(args_str) {
            let n = a.trim_start_matches('%');
            let id = *by_name.get(n).ok_or_else(|| err(ln, format!("unknown arg %{n}")))?;
            args.push(id);
        }
        // params declare new (weight) values inline
        let mut params = Vec::new();
        for p in split_list(params_str) {
            let (pname, pty) = parse_operand(p, ln)?;
            // weight qtensor indices are printed on the op result line; we
            // recover weight q-indices from a `wq<i>=<idx>` attr if present,
            // else None (verifier tolerates it).
            let id = g.new_value(&pname, pty, amap.get(format!("wq{}", params.len()).as_str()).and_then(|v| v.parse().ok()));
            by_name.insert(pname, id);
            params.push(id);
        }

        if kind == OpKind::Input {
            let (rname, rty) = parse_operand(lhs, ln)?;
            let id = g.add_input(&rname, rty);
            by_name.insert(rname, id);
            continue;
        }
        let (rname, rty) = parse_operand(lhs, ln)?;
        let rid = g.add_op(kind, args, params, &rname, rty, qtensor);
        // restore hardware attrs
        {
            let v = g.value_mut(rid);
            if let Some(t) = amap.get("tile") {
                if let Some((a, b)) = t.split_once('x') {
                    v.attrs.tile = (a.parse().unwrap_or(1), b.parse().unwrap_or(1));
                }
            }
            if amap.get("order").map(|o| o == "col").unwrap_or(false) {
                v.attrs.order = StreamOrder::ColMajor;
            }
            if let Some(t) = amap.get("thr") {
                v.attrs.throughput = t.parse().unwrap_or(0.0);
            }
        }
        let op_id = g.value(rid).producer.unwrap();
        let op = &mut g.ops[op_id.0];
        op.attrs = OpAttrs {
            hw_ip: amap.get("ip").cloned().unwrap_or_default(),
            area_luts: amap.get("area").and_then(|v| v.parse().ok()).unwrap_or(0.0),
            ii_cycles: amap.get("ii").and_then(|v| v.parse().ok()).unwrap_or(0.0),
        };
        by_name.insert(rname, rid);
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::super::printer::print_graph;
    use super::*;
    use crate::ir::graph::OpKind;

    #[test]
    fn type_round_trip() {
        for s in ["f32[32x64]", "mxint(5)[64x64]", "int(8,4)[16x2]", "bl(7)[4]", "fp8[8x8]"] {
            let t = parse_type(s, 0).unwrap();
            assert_eq!(super::super::printer::type_str(&t), s);
        }
    }

    #[test]
    fn graph_round_trip() {
        let mut g = Graph::new("toy");
        let x = g.add_input("x", TensorType::fp32(vec![32, 64]));
        let w = g.new_value(
            "w0",
            TensorType {
                shape: vec![64, 64],
                format: FormatKind::MxInt,
                precision: Precision::new(5.0, 0.0),
            },
            None,
        );
        let h = g.add_op(OpKind::Linear, vec![x], vec![w], "h", TensorType::fp32(vec![32, 64]), Some(0));
        let y = g.add_op(OpKind::Gelu, vec![h], vec![], "y", TensorType::fp32(vec![32, 64]), None);
        g.outputs.push(y);

        let text = print_graph(&g);
        let g2 = parse_graph(&text).unwrap();
        assert_eq!(g2.name, "toy");
        assert_eq!(g2.dag_size(), g.dag_size());
        assert_eq!(print_graph(&g2), text, "round trip stable");
    }

    #[test]
    fn rejects_unknown_op() {
        let text = "module @m {\n  %y: f32[4] = frobnicate(%x)\n}\n";
        assert!(parse_graph(text).is_err());
    }

    #[test]
    fn rejects_undefined_arg() {
        let text = "module @m {\n  %y: f32[4] = gelu(%nope)\n}\n";
        assert!(parse_graph(text).is_err());
    }
}
