//! The MASE IR graph: operations, values, attributes, and a builder API.

use super::TensorType;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ValueId(pub usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpId(pub usize);

/// Streaming order of a dataflow edge (paper Fig. 1d: tensors stream
/// row-by-row or column-by-column; `transpose`/`reorder` ops switch it).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StreamOrder {
    #[default]
    RowMajor,
    ColMajor,
}

impl StreamOrder {
    pub fn name(&self) -> &'static str {
        match self {
            StreamOrder::RowMajor => "row",
            StreamOrder::ColMajor => "col",
        }
    }
}

/// Hardware attributes of a dataflow edge (paper Fig. 2c, value attrs).
#[derive(Debug, Clone, PartialEq)]
pub struct ValueAttrs {
    /// Streaming tile shape (rows, cols) — the data-parallelism knob the
    /// `parallelize` pass tunes.
    pub tile: (usize, usize),
    pub order: StreamOrder,
    /// Handshake interface is the only interface in this work.
    pub interface: &'static str,
    /// Estimated elements/cycle on this edge (filled by `parallelize`).
    pub throughput: f64,
}

impl Default for ValueAttrs {
    fn default() -> Self {
        Self { tile: (1, 1), order: StreamOrder::RowMajor, interface: "handshake", throughput: 0.0 }
    }
}

/// An SSA value: one dataflow edge of Fig. 1d.
#[derive(Debug, Clone)]
pub struct Value {
    pub id: ValueId,
    pub name: String,
    pub ty: TensorType,
    pub attrs: ValueAttrs,
    /// Index into the model's qtensor list if this value is quantization-
    /// searchable (weights and streamed activations), else None.
    pub qtensor: Option<usize>,
    pub producer: Option<OpId>,
}

/// Module-level operator kinds — each maps 1:1 onto a hardware IP template
/// in `emit/templates.rs` and a cost model in `hw/`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    Input,
    /// Embedding table lookup (token ids -> vectors).
    Embed,
    LayerNorm,
    /// Dense GEMM; the weight is the op's parameter.
    Linear,
    /// Fused scaled-dot-product attention (QK^T, softmax, AV).
    Attention,
    Gelu,
    /// Elementwise residual add.
    Add,
    Softmax,
    /// Streaming-order switch (dataflow-specific op, Fig. 1d).
    Transpose,
    /// Tile re-order between producer/consumer tilings (dataflow-specific).
    Reorder,
    MeanPool,
    Output,
}

impl OpKind {
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Input => "input",
            OpKind::Embed => "embed",
            OpKind::LayerNorm => "layernorm",
            OpKind::Linear => "linear",
            OpKind::Attention => "attention",
            OpKind::Gelu => "gelu",
            OpKind::Add => "add",
            OpKind::Softmax => "softmax",
            OpKind::Transpose => "transpose",
            OpKind::Reorder => "reorder",
            OpKind::MeanPool => "meanpool",
            OpKind::Output => "output",
        }
    }

    pub fn from_name(s: &str) -> Option<OpKind> {
        use OpKind::*;
        Some(match s {
            "input" => Input,
            "embed" => Embed,
            "layernorm" => LayerNorm,
            "linear" => Linear,
            "attention" => Attention,
            "gelu" => Gelu,
            "add" => Add,
            "softmax" => Softmax,
            "transpose" => Transpose,
            "reorder" => Reorder,
            "meanpool" => MeanPool,
            "output" => Output,
            _ => return None,
        })
    }

    /// Ops whose main datapath is a quantized GEMM (drive area/Δacc).
    pub fn is_gemm(&self) -> bool {
        matches!(self, OpKind::Linear | OpKind::Attention | OpKind::Embed)
    }
}

/// Hardware attributes of an operation (paper Fig. 2c, operation attrs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct OpAttrs {
    /// Name of the hardware IP template instantiated for this op.
    pub hw_ip: String,
    /// Estimated circuit area in LUT-equivalents (filled by `parallelize`).
    pub area_luts: f64,
    /// Initiation interval in cycles per streaming tile.
    pub ii_cycles: f64,
}

/// One operation in the SSA graph:
/// `result: type = operator(arg, ...) [param, ...] {attr, ...}`.
#[derive(Debug, Clone)]
pub struct Operation {
    pub id: OpId,
    pub kind: OpKind,
    /// Dataflow arguments (streamed activations).
    pub args: Vec<ValueId>,
    /// Parameters (stationary weights) — also SSA values.
    pub params: Vec<ValueId>,
    pub results: Vec<ValueId>,
    pub attrs: OpAttrs,
}

/// The MASE IR module for one model.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub name: String,
    pub ops: Vec<Operation>,
    pub values: Vec<Value>,
    pub inputs: Vec<ValueId>,
    pub outputs: Vec<ValueId>,
}

impl Graph {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), ..Default::default() }
    }

    pub fn value(&self, id: ValueId) -> &Value {
        &self.values[id.0]
    }

    pub fn value_mut(&mut self, id: ValueId) -> &mut Value {
        &mut self.values[id.0]
    }

    pub fn op(&self, id: OpId) -> &Operation {
        &self.ops[id.0]
    }

    /// DAG size in the paper's Table 3 sense: number of operations.
    pub fn dag_size(&self) -> usize {
        self.ops.len()
    }

    pub fn new_value(&mut self, name: &str, ty: TensorType, qtensor: Option<usize>) -> ValueId {
        let id = ValueId(self.values.len());
        self.values.push(Value {
            id,
            name: name.to_string(),
            ty,
            attrs: ValueAttrs::default(),
            qtensor,
            producer: None,
        });
        id
    }

    pub fn add_input(&mut self, name: &str, ty: TensorType) -> ValueId {
        let v = self.new_value(name, ty, None);
        let id = OpId(self.ops.len());
        self.ops.push(Operation {
            id,
            kind: OpKind::Input,
            args: vec![],
            params: vec![],
            results: vec![v],
            attrs: OpAttrs::default(),
        });
        self.values[v.0].producer = Some(id);
        self.inputs.push(v);
        v
    }

    /// Append an op producing one result value.
    pub fn add_op(
        &mut self,
        kind: OpKind,
        args: Vec<ValueId>,
        params: Vec<ValueId>,
        result_name: &str,
        result_ty: TensorType,
        result_qtensor: Option<usize>,
    ) -> ValueId {
        let r = self.new_value(result_name, result_ty, result_qtensor);
        let id = OpId(self.ops.len());
        self.ops.push(Operation { id, kind, args, params, results: vec![r], attrs: OpAttrs::default() });
        self.values[r.0].producer = Some(id);
        r
    }

    /// All consumer ops of a value (linear scan; graphs are ~100 ops).
    pub fn consumers(&self, v: ValueId) -> Vec<OpId> {
        self.ops
            .iter()
            .filter(|o| o.args.contains(&v) || o.params.contains(&v))
            .map(|o| o.id)
            .collect()
    }

    /// Values that take part in quantization search, in qtensor order.
    pub fn qtensor_values(&self) -> Vec<ValueId> {
        let mut with_idx: Vec<(usize, ValueId)> =
            self.values.iter().filter_map(|v| v.qtensor.map(|q| (q, v.id))).collect();
        with_idx.sort();
        with_idx.into_iter().map(|(_, v)| v).collect()
    }

    /// Ops in topological order (ops are appended post-order by the
    /// builder, but passes may rely on an explicit check).
    pub fn topo_order(&self) -> Vec<OpId> {
        // Kahn's algorithm over value edges.
        let mut indeg = vec![0usize; self.ops.len()];
        for op in &self.ops {
            for &a in op.args.iter().chain(op.params.iter()) {
                if self.values[a.0].producer.is_some() {
                    indeg[op.id.0] += 1;
                }
            }
        }
        let mut ready: Vec<OpId> =
            self.ops.iter().filter(|o| indeg[o.id.0] == 0).map(|o| o.id).collect();
        ready.reverse();
        let mut order = Vec::with_capacity(self.ops.len());
        while let Some(op) = ready.pop() {
            order.push(op);
            for &r in &self.ops[op.0].results {
                for c in self.consumers(r) {
                    indeg[c.0] -= 1;
                    if indeg[c.0] == 0 {
                        ready.push(c);
                    }
                }
            }
        }
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FormatKind, Precision};

    fn tiny_graph() -> Graph {
        let mut g = Graph::new("toy");
        let x = g.add_input("x", TensorType::fp32(vec![32, 64]));
        let w_ty = TensorType {
            shape: vec![64, 64],
            format: FormatKind::MxInt,
            precision: Precision::new(5.0, 0.0),
        };
        let w = g.new_value("w0", w_ty, Some(1));
        let h = g.add_op(
            OpKind::Linear,
            vec![x],
            vec![w],
            "h",
            TensorType::fp32(vec![32, 64]),
            Some(0),
        );
        let y = g.add_op(OpKind::Gelu, vec![h], vec![], "y", TensorType::fp32(vec![32, 64]), None);
        g.outputs.push(y);
        g
    }

    #[test]
    fn builder_wires_producers() {
        let g = tiny_graph();
        let y = g.outputs[0];
        let gelu = g.value(y).producer.unwrap();
        assert_eq!(g.op(gelu).kind, OpKind::Gelu);
    }

    #[test]
    fn consumers_found() {
        let g = tiny_graph();
        let x = g.inputs[0];
        let cons = g.consumers(x);
        assert_eq!(cons.len(), 1);
        assert_eq!(g.op(cons[0]).kind, OpKind::Linear);
    }

    #[test]
    fn qtensor_values_sorted_by_index() {
        let g = tiny_graph();
        let q = g.qtensor_values();
        assert_eq!(q.len(), 2);
        assert_eq!(g.value(q[0]).qtensor, Some(0));
        assert_eq!(g.value(q[1]).qtensor, Some(1));
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = tiny_graph();
        let order = g.topo_order();
        assert_eq!(order.len(), g.ops.len());
        let pos: std::collections::HashMap<OpId, usize> =
            order.iter().enumerate().map(|(i, o)| (*o, i)).collect();
        for op in &g.ops {
            for &a in &op.args {
                if let Some(p) = g.value(a).producer {
                    assert!(pos[&p] < pos[&op.id], "{:?} before {:?}", p, op.id);
                }
            }
        }
    }

    #[test]
    fn opkind_name_round_trip() {
        for k in [
            OpKind::Input,
            OpKind::Embed,
            OpKind::LayerNorm,
            OpKind::Linear,
            OpKind::Attention,
            OpKind::Gelu,
            OpKind::Add,
            OpKind::Softmax,
            OpKind::Transpose,
            OpKind::Reorder,
            OpKind::MeanPool,
            OpKind::Output,
        ] {
            assert_eq!(OpKind::from_name(k.name()), Some(k));
        }
    }
}
